"""Property-based delta-replanning suite (hypothesis).

The bit-level contract — ``apply_edge_delta(plan, delta)`` equals
``build_plan_tree`` on the mutated CSR field-by-field — over *random*
mutation batches (reweights, insertions, deletions, symmetric and not)
against random symmetric CSR matrices and random partitions, at tree
depths 1-3.  ``tests/test_replan.py`` holds the deterministic sweeps and
adversarial shapes; this module searches the space between them.
"""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from replan_equiv import check_patch_equals_fresh, random_csr, random_delta

FANOUTS = {1: (4,), 2: (2, 2), 3: (2, 2, 2)}


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**32 - 1),
       depth=st.sampled_from([1, 2, 3]),
       n_reweight=st.integers(0, 6),
       n_add=st.integers(0, 5),
       n_drop=st.integers(0, 5),
       symmetric=st.booleans())
def test_random_mutations_patch_exactly(seed, depth, n_reweight, n_add,
                                        n_drop, symmetric):
    rng = np.random.default_rng(seed)
    k = int(np.prod(FANOUTS[depth]))
    n = rng.integers(24, 56)
    ip, ix, d = random_csr(rng, int(n), density=0.1)
    part = rng.integers(0, k, size=int(n)).astype(np.int32)
    delta = random_delta(rng, ip, ix, int(n), n_reweight=n_reweight,
                         n_add=n_add, n_drop=n_drop, symmetric=symmetric)
    if len(delta) == 0:
        return
    check_patch_equals_fresh(ip, ix, d, part, None, k, delta,
                             fanouts=FANOUTS[depth])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), steps=st.integers(2, 4))
def test_random_patch_chains_stay_exact(seed, steps):
    """Patch-of-patch over random deltas: the cache carried by a patched
    plan must itself be exact input for the next patch."""
    from repro.sparse.distributed import build_plan_tree
    from repro.sparse.replan import apply_delta_csr, apply_edge_delta

    from replan_equiv import assert_plan_equal

    rng = np.random.default_rng(seed)
    n, k, fanouts = 48, 4, (2, 2)
    ip, ix, d = random_csr(rng, n, density=0.1)
    part = rng.integers(0, k, size=n).astype(np.int32)
    plan = build_plan_tree(ip, ix, d, part, None, k, fanouts=fanouts)
    for _ in range(steps):
        delta = random_delta(rng, ip, ix, n, n_reweight=3, n_add=2,
                             n_drop=2, symmetric=bool(rng.integers(2)))
        if len(delta) == 0:
            continue
        plan = apply_edge_delta(plan, delta)
        ip, ix, d = apply_delta_csr(ip, ix, d, delta)
        fresh = build_plan_tree(ip, ix, d, part, None, k, fanouts=fanouts)
        assert_plan_equal(plan, fresh)
