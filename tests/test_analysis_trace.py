"""Trace-auditor tests (ISSUE 8 tentpole): per-rule mutation suite,
clean corpus over every backend, and the static cost model's acceptance
oracle against the metrics-side communication volumes.

The mutation pattern mirrors ``test_analysis_verify.py``: corrupt a
traced program *or its plan* and assert exactly the right TRACE code
fires.  The headline case is the seeded drift the PR 6 plan verifier
provably cannot catch — a fully self-consistent swap of two exchange
rounds (perms + send schedule + the halo slot ranges the edges read)
passes every PLAN0xx invariant, but the staged program still replays the
*original* round order, so only the jaxpr-level audit sees the mismatch.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import compat
from repro.analysis import TRACE_RULES, audit_backend, audit_jaxpr, \
    audit_operator, verify_plan
from repro.core.metrics import comm_volumes, tree_comm_volumes
from repro.core.topology import canonical_ancestors
from repro.launch.mesh import tree_axis_names
from repro.launch.roofline import static_roofline
from repro.sparse.generators import GENERATORS, grid
from repro.sparse.graph import laplacian_csr
from repro.sparse.operator import _HIER_BACKENDS, BACKENDS, make_operator

pytestmark = pytest.mark.skipif(
    not compat.HAS_ABSTRACT_MESH,
    reason="device-free tracing needs jax.sharding.AbstractMesh")


def _system(n=144, seed=0, generator="grid_2d"):
    g = GENERATORS[generator](n, seed=seed)
    nv = len(g.indptr) - 1
    return (g, nv) + laplacian_csr(g, shift=0.1)


def _rng_part(nv, k, seed=0):
    # a random partition gives every level several distinct non-empty
    # rounds — what the round-swap mutations need
    return np.random.default_rng(seed).integers(0, k, size=nv)


def _flat_op(comm="halo", k=4, seed=0):
    _, nv, indptr, indices, data = _system(seed=seed)
    backend = {"halo": "dist_halo", "halo_seq": "dist_halo_seq",
               "allgather": "dist_allgather"}[comm]
    mesh = compat.abstract_mesh({"pu": k})
    return make_operator(indptr, indices, data, backend,
                         part=_rng_part(nv, k, seed), k=k, mesh=mesh)


def _tree_op(fanouts=(2, 2), seed=0):
    _, nv, indptr, indices, data = _system(seed=seed)
    k = int(np.prod(fanouts))
    names = tree_axis_names(len(fanouts))
    mesh = compat.abstract_mesh(dict(zip(names, fanouts)))
    return make_operator(indptr, indices, data, "dist_hier",
                         part=_rng_part(nv, k, seed), k=k, mesh=mesh,
                         fanouts=fanouts)


def _matvec_jaxpr(op):
    return jax.make_jaxpr(op.matvec)(op.operand_spec())


# ------------------------------------------------------------ clean corpus

@pytest.mark.parametrize("backend", BACKENDS)
def test_clean_corpus_default_backends(backend):
    """Every backend of the cross-backend operator matrix traces with
    zero diagnostics on the default fixture (matvec AND fused CG)."""
    rep = audit_backend(backend, n=144, fanouts=(2, 2))
    assert rep.ok, str(rep)
    assert rep.info["cost_matvec"] is not None
    assert rep.info["cost_cg"] is not None


@pytest.mark.parametrize("backend", _HIER_BACKENDS)
def test_clean_corpus_depth3(backend):
    rep = audit_backend(backend, n=144, fanouts=(2, 2, 2))
    assert rep.ok, str(rep)


@pytest.mark.parametrize("backend", ["coo", "dist_halo", "dist_hier"])
def test_clean_corpus_batched(backend):
    rep = audit_backend(backend, n=144, fanouts=(2, 2), nb=3)
    assert rep.ok, str(rep)


@pytest.mark.parametrize("precondition", ["jacobi", "block_jacobi"])
def test_clean_corpus_preconditioned(precondition):
    rep = audit_backend("dist_hier", n=144, fanouts=(2, 2),
                        precondition=precondition)
    assert rep.ok, str(rep)


# ------------------------------------------------------------------ rules

def test_rule_table_is_complete():
    assert set(TRACE_RULES) == {"TRACE001", "TRACE002", "TRACE003",
                                "TRACE004", "TRACE005"}
    for code, desc in TRACE_RULES.items():
        assert desc and code.startswith("TRACE")


# --------------------------------------------------------------- TRACE001

def test_trace001_dropped_round():
    """Plan claims one round fewer than the program stages."""
    op = _flat_op()
    mut = dataclasses.replace(op.plan,
                              round_perms=tuple(op.plan.round_perms[:-1]))
    rep = audit_jaxpr(_matvec_jaxpr(op), plan=mut, axis="pu", comm="halo")
    assert rep.codes() == {"TRACE001"}, str(rep)


def test_trace001_level_with_no_rounds():
    """A level whose schedule was emptied still stages its ppermutes."""
    op = _tree_op()
    lvl = next(l for l in range(op.plan.h)
               if any(p for p in op.plan.round_perms_lvl[l]))
    rp = list(op.plan.round_perms_lvl)
    rp[lvl] = ((),) * len(rp[lvl])
    mut = dataclasses.replace(op.plan, round_perms_lvl=tuple(rp))
    rep = audit_jaxpr(_matvec_jaxpr(op), plan=mut, axis=op.axis,
                      comm="hier")
    assert rep.codes() == {"TRACE001"}, str(rep)
    assert any(f"level {lvl}" in d.where for d in rep.diagnostics)


# --------------------------------------------------------------- TRACE002

def _two_distinct_rounds(perms):
    """(c0, c1) of two non-empty rounds with different pair sets."""
    ne = [(c, frozenset(map(tuple, p))) for c, p in enumerate(perms) if p]
    for i, (c0, s0) in enumerate(ne):
        for c1, s1 in ne[i + 1:]:
            if s0 != s1:
                return c0, c1
    raise AssertionError("fixture has no two distinct rounds")


def test_trace002_swapped_permutation():
    op = _flat_op()
    c0, c1 = _two_distinct_rounds(op.plan.round_perms)
    pm = list(op.plan.round_perms)
    pm[c0], pm[c1] = pm[c1], pm[c0]
    mut = dataclasses.replace(op.plan, round_perms=tuple(pm))
    rep = audit_jaxpr(_matvec_jaxpr(op), plan=mut, axis="pu", comm="halo")
    assert rep.codes() == {"TRACE002"}, str(rep)
    assert len(rep.diagnostics) == 2        # both swapped rounds named


def _swap_rounds_consistently(plan, lvl, c0, c1):
    """Exchange rounds c0 and c1 of tree level ``lvl`` *consistently*:
    perms, send schedule columns, and the halo slot ranges every edge
    reads all move together, so the mutated plan satisfies every PLAN0xx
    invariant — it is simply a different (equally valid) schedule than
    the one the program was staged from."""
    offs = plan.level_offsets()
    S = int(plan.S_lvl[lvl])
    a0, a1 = int(offs[lvl]) + c0 * S, int(offs[lvl]) + c1 * S

    def remap(cols):
        cols = np.asarray(cols).copy()
        in0 = (cols >= a0) & (cols < a0 + S)
        in1 = (cols >= a1) & (cols < a1 + S)
        cols[in0] += a1 - a0
        cols[in1] += a0 - a1
        return jnp.asarray(cols)

    perms = list(plan.round_perms_lvl[lvl])
    perms[c0], perms[c1] = perms[c1], perms[c0]
    si = np.asarray(plan.send_idx_lvl[lvl]).copy()
    sm = np.asarray(plan.send_mask_lvl[lvl]).copy()
    si[:, [c0, c1]] = si[:, [c1, c0]]
    sm[:, [c0, c1]] = sm[:, [c1, c0]]
    rp = list(plan.round_perms_lvl)
    rp[lvl] = tuple(perms)
    sil = list(plan.send_idx_lvl)
    sil[lvl] = jnp.asarray(si)
    sml = list(plan.send_mask_lvl)
    sml[lvl] = jnp.asarray(sm)
    return dataclasses.replace(
        plan, round_perms_lvl=tuple(rp), send_idx_lvl=tuple(sil),
        send_mask_lvl=tuple(sml), cols=remap(plan.cols),
        cols_bnd_lvl=tuple(remap(c) for c in plan.cols_bnd_lvl))


def test_trace002_drift_the_plan_verifier_cannot_catch():
    """The acceptance-criterion drift: a consistent round swap passes the
    full PR 6 structural verifier (it IS a valid plan — just not the one
    the program was staged from), and only the trace auditor flags it."""
    op = _tree_op()
    lvl = next(l for l in range(op.plan.h)
               if sum(1 for p in op.plan.round_perms_lvl[l] if p) >= 2)
    c0, c1 = _two_distinct_rounds(op.plan.round_perms_lvl[lvl])
    mut = _swap_rounds_consistently(op.plan, lvl, c0, c1)

    vrep = verify_plan(mut)
    assert vrep.ok, "the plan verifier must be blind to this drift:\n" \
        + str(vrep)

    rep = audit_jaxpr(_matvec_jaxpr(op), plan=mut, axis=op.axis,
                      comm="hier")
    assert rep.codes() == {"TRACE002"}, str(rep)


# --------------------------------------------------------------- TRACE003

def test_trace003_wrong_axis_name():
    """Auditing the program against a different axis leaves its staged
    ppermutes underivable (TRACE003) and the expected axis empty-handed
    (TRACE001)."""
    op = _flat_op()
    rep = audit_jaxpr(_matvec_jaxpr(op), plan=op.plan, axis="data",
                      comm="halo")
    assert rep.codes() == {"TRACE001", "TRACE003"}, str(rep)


def test_trace003_collective_in_single_device_program():
    op = _flat_op()
    rep = audit_jaxpr(_matvec_jaxpr(op), plan=None, comm=None)
    assert rep.codes() == {"TRACE003"}, str(rep)


def test_trace003_allgather_not_in_schedule():
    op = _flat_op(comm="allgather")
    rep = audit_jaxpr(_matvec_jaxpr(op), plan=None, comm=None)
    assert "TRACE003" in rep.codes(), str(rep)


# --------------------------------------------------------------- TRACE004

def test_trace004_injected_bf16_roundtrip():
    _, _, indptr, indices, data = _system()
    op = make_operator(indptr, indices, data, "coo")

    def f(x):
        return op.matvec(x.astype(jnp.bfloat16).astype(jnp.float32))

    rep = audit_jaxpr(jax.make_jaxpr(f)(op.operand_spec()))
    assert rep.codes() == {"TRACE004"}, str(rep)
    dirs = {(d.details["src"], d.details["dst"]) for d in rep.diagnostics}
    assert dirs == {("float32", "bfloat16"), ("bfloat16", "float32")}


# --------------------------------------------------------------- TRACE005

def test_trace005_f64_leak_under_x64():
    with jax.experimental.enable_x64():
        closed = jax.make_jaxpr(lambda x: x * np.float64(2.0))(
            jax.ShapeDtypeStruct((8,), np.float32))
    rep = audit_jaxpr(closed, base_dtype=np.float32)
    assert "TRACE005" in rep.codes(), str(rep)


def test_trace005_silent_without_x64():
    # without x64 the same program stays f32: no leak, no diagnostic
    closed = jax.make_jaxpr(lambda x: x * np.float64(2.0))(
        jax.ShapeDtypeStruct((8,), np.float32))
    rep = audit_jaxpr(closed, base_dtype=np.float32)
    assert rep.ok, str(rep)


# ------------------------------------------------ static cost model oracle

def _stripes_fixture(shape, k):
    g = grid(shape)
    nv = g.n
    indptr, indices, data = laplacian_csr(g, shift=0.1)
    part = (np.arange(nv) * k) // nv
    return g, indptr, indices, data, part


@pytest.mark.parametrize("fanouts", [(2, 2), (2, 2, 2)])
def test_payload_bytes_match_tree_comm_volumes(fanouts):
    """Acceptance oracle: per-level payload bytes equal the metrics-side
    deduplicated received-word volumes x itemsize exactly — counted
    elements x dtype size, no tolerance."""
    k = int(np.prod(fanouts))
    g, indptr, indices, data, part = _stripes_fixture((32, 64), k)
    names = tree_axis_names(len(fanouts))
    mesh = compat.abstract_mesh(dict(zip(names, fanouts)))
    op = make_operator(indptr, indices, data, "dist_hier", part=part,
                       k=k, mesh=mesh, fanouts=fanouts)
    rep = audit_operator(op, solver=False)
    assert rep.ok, str(rep)
    cost = rep.info["cost_matvec"]
    vols = tree_comm_volumes(g, part, k, canonical_ancestors(fanouts))
    itemsize = np.dtype(np.float32).itemsize
    expect = tuple(float(v.sum()) * itemsize for v in vols)
    assert cost.comm_payload_bytes_lvl == expect


def test_payload_bytes_match_flat_comm_volumes():
    k = 4
    g, indptr, indices, data, part = _stripes_fixture((32, 64), k)
    op = make_operator(indptr, indices, data, "dist_halo", part=part,
                       k=k, mesh=compat.abstract_mesh({"pu": k}))
    rep = audit_operator(op, solver=False)
    assert rep.ok, str(rep)
    cost = rep.info["cost_matvec"]
    expect = float(comm_volumes(g, part, k).sum()) * 4
    assert cost.comm_payload_bytes_lvl == (expect,)


def test_patched_plan_traces_like_fresh_build():
    """A delta-patched plan (ISSUE 10) drives the staged program through
    TRACE001-005 clean, and its static comm cost equals the fresh build's
    on the mutated matrix — the audit can't tell patch from rebuild."""
    import dataclasses

    from repro.sparse.replan import (EdgeDelta, apply_delta_csr,
                                     apply_edge_delta)

    _, nv, indptr, indices, data = _system()
    k, fanouts = 4, (2, 2)
    part = _rng_part(nv, k)
    mesh = compat.abstract_mesh(dict(zip(tree_axis_names(2), fanouts)))
    op = make_operator(indptr, indices, data, "dist_hier", part=part,
                       k=k, mesh=mesh, fanouts=fanouts)
    # structural mutation: a new symmetric corner-to-corner edge crosses
    # every tree level, so the patched schedules must re-trace cleanly
    delta = EdgeDelta(nv, set_rows=[0, nv - 1], set_cols=[nv - 1, 0],
                      set_vals=[-1.0, -1.0])
    op2 = dataclasses.replace(op, plan=apply_edge_delta(op.plan, delta))
    rep = audit_operator(op2, solver=False)
    assert rep.ok, str(rep)
    ip2, ix2, d2 = apply_delta_csr(indptr, indices, data, delta)
    fresh = make_operator(ip2, ix2, d2, "dist_hier", part=part, k=k,
                          mesh=mesh, fanouts=fanouts)
    ref = audit_operator(fresh, solver=False)
    assert ref.ok, str(ref)
    assert rep.info["cost_matvec"].comm_payload_bytes_lvl == \
        ref.info["cost_matvec"].comm_payload_bytes_lvl


def test_batched_payload_scales_with_nb():
    k = 4
    _, indptr, indices, data, part = _stripes_fixture((16, 16), k)
    op = make_operator(indptr, indices, data, "dist_halo", part=part,
                       k=k, mesh=compat.abstract_mesh({"pu": k}))
    one = audit_operator(op, solver=False).info["cost_matvec"]
    three = audit_operator(op, solver=False, nb=3).info["cost_matvec"]
    assert three.comm_payload_bytes_lvl == tuple(
        3 * b for b in one.comm_payload_bytes_lvl)


def test_cost_is_roofline_consumable():
    rep = audit_backend("dist_hier", n=144, fanouts=(2, 2))
    cost = rep.info["cost_cg"]
    for out in (cost.roofline(), static_roofline(cost)):
        assert {"compute_s", "memory_s", "collective_s",
                "dominant"} <= set(out)
        assert out["per_iteration"] is True
        assert out["n_devices"] == 4
        assert all(np.isfinite(out[t]) and out[t] >= 0
                   for t in ("compute_s", "memory_s", "collective_s"))
    assert cost.flops_per_iter > 0
    assert cost.hbm_bytes_per_iter > 0
    # the fused CG stages its dot-product psums: all-reduce bytes appear
    assert cost.collectives().get("all-reduce", 0) > 0


def test_cg_cost_separates_loop_body():
    """``flops_per_iter`` counts only the while-body; ``flops`` is the
    setup outside it (the initial residual's matvec etc.) — both must be
    populated for a CG program, and the loop body strictly exceeds one
    bare matvec (it adds the axpy/dot vector work)."""
    rep = audit_backend("dist_halo", n=144, fanouts=(2, 2))
    cg = rep.info["cost_cg"]
    mv = rep.info["cost_matvec"]
    assert cg.flops > 0 and cg.flops_per_iter > 0
    # one CG iteration does one matvec plus vector work
    assert cg.flops_per_iter > mv.flops_per_iter
    # the matvec program has no loop: per-iter == whole program
    assert mv.flops_per_iter == mv.flops


def test_cost_to_dict_is_jsonable():
    import json

    rep = audit_backend("dist_hier", n=144, fanouts=(2, 2))
    payload = json.dumps(rep.to_dict())
    back = json.loads(payload)
    assert back["ok"] is True
    assert back["info"]["cost_cg"]["n_devices"] == 4
    assert isinstance(back["info"]["cost_cg"]["comm_payload_bytes_lvl"],
                      list)


# ------------------------------------------------------- serving pricing

def test_solver_service_static_cost():
    from repro.launch.serve import SolverService

    g = grid((12, 12))
    indptr, indices, data = laplacian_csr(g, shift=0.1)
    svc = SolverService(backend="coo", buckets=(1, 2, 4), max_iters=50)
    out = svc.static_cost(indptr, indices, data, nb=3)
    assert out["bucket"] == 4 and out["ok"]
    assert out["roofline"]["static_flops_per_iter"] > 0
    # same size class -> cached price object, no re-trace
    assert svc.static_cost(indptr, indices, data, nb=4) is out
    assert svc.static_cost(indptr, indices, data, nb=1) is not out


def test_solver_service_static_cost_distributed():
    from repro.launch.serve import SolverService

    g = grid((16, 16))
    indptr, indices, data = laplacian_csr(g, shift=0.1)
    part = (np.arange(g.n) * 4) // g.n
    svc = SolverService(backend="dist_halo", part=part, k=4,
                        mesh=compat.abstract_mesh({"pu": 4}),
                        max_iters=50)
    out = svc.static_cost(indptr, indices, data, nb=2)
    assert out["ok"], out["diagnostics"]
    assert out["roofline"]["n_devices"] == 4
    assert out["cost"].comm_payload_bytes_lvl[0] > 0
