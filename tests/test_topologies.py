"""TOPO1/2/3 constructors and the paper's Table III target-weight ratios."""
import numpy as np
import pytest

from repro.core.block_sizes import target_block_sizes
from repro.core.topology import (TABLE_III_FAST_SPECS, Topology,
                                 scale_to_load)


@pytest.mark.parametrize("frac,expected", [(1 / 12, 9.4), (1 / 6, 11.5)])
def test_table3_fs16_ratio(frac, expected):
    """Table III last column: tw(fast)/tw(slow) ~ 9.4 / 11.5 at fs=16."""
    topo = scale_to_load(Topology.topo1(96, frac, 16.0, 13.8), 1e6)
    tw = target_block_sizes(1e6, topo)
    ratio = tw[0] / tw[-1]
    assert abs(ratio - expected) / expected < 0.02


def test_topo1_homogeneous_step():
    """Table III exp 1: same specs => equal weights."""
    topo = scale_to_load(Topology.topo1(24, 1 / 12, 1.0, 2.0), 2400)
    tw = target_block_sizes(2400, topo)
    assert np.allclose(tw, 100.0)


def test_topo2_eq5_ordering():
    """Eq. 5 holds: r(s1) = r(f)/2; at fs=16 (Table III exp 5) the greedy
    order is F, then S1, then S2 as the paper states."""
    topo = Topology.topo2(24, 1 / 6, 16.0, 13.8)
    r = topo.speeds / topo.memories
    n_fast, n_s1 = 4, 10
    assert np.allclose(r[n_fast:n_fast + n_s1], 0.5 * r[0])   # Eq. 5
    assert np.all(r[:n_fast] > r[n_fast])                     # F first
    assert np.all(r[n_fast:n_fast + n_s1] > r[n_fast + n_s1:].max())


def test_topo3_hierarchy():
    topo = Topology.topo3(nodes=4, cores_per_node=6, fast_nodes=1)
    assert topo.k == 24
    assert topo.fanouts == (4, 6)
    assert topo.pus[0].speed == 1.0
    assert topo.pus[-1].speed == 0.5


def test_table3_specs_monotone():
    speeds = [s for s, _ in TABLE_III_FAST_SPECS]
    mems = [m for _, m in TABLE_III_FAST_SPECS]
    assert speeds == sorted(speeds)
    assert mems == sorted(mems)
