"""Roofline extraction: HLO collective parser + term math."""
import pytest

from repro.launch.roofline import (collective_bytes, roofline_terms,
                                   _shape_bytes)


HLO = """
HloModule test
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %x), dimensions={0}
  %ar = f32[256,64]{1,0} all-reduce(f32[256,64]{1,0} %y), to_apply=%add
  %rs = f32[2,8]{1,0} reduce-scatter(f32[16,8]{1,0} %z), dimensions={0}
  %a2a = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(f32[4,4]{1,0} %a, f32[4,4]{1,0} %b)
  %cp = u32[128]{0} collective-permute(u32[128]{0} %c), source_target_pairs={{0,1}}
  %dot = f32[8,8]{1,0} dot(f32[8,8]{1,0} %p, f32[8,8]{1,0} %q)
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,1024]") == 16 * 1024 * 2
    assert _shape_bytes("(f32[4,4], f32[4,4])") == 2 * 16 * 4
    assert _shape_bytes("u32[128]") == 512
    assert _shape_bytes("f32[]") == 4


def test_collective_parser():
    c = collective_bytes(HLO)
    assert c["all-gather"] == 16 * 1024 * 2
    assert c["all-reduce"] == 256 * 64 * 4
    assert c["reduce-scatter"] == 2 * 8 * 4
    assert c["all-to-all"] == 2 * 16 * 4
    assert c["collective-permute"] == 128 * 4


def test_dot_not_counted():
    c = collective_bytes(HLO)
    expected = (16 * 1024 * 2 + 256 * 64 * 4 + 2 * 8 * 4 + 2 * 16 * 4
                + 128 * 4)
    assert sum(c.values()) == expected        # exactly the collectives


def test_roofline_terms():
    r = roofline_terms(197e12, 819e9, {"all-gather": 50e9, "all-reduce": 0,
                                       "reduce-scatter": 0, "all-to-all": 0,
                                       "collective-permute": 0})
    assert abs(r["compute_s"] - 1.0) < 1e-9
    assert abs(r["memory_s"] - 1.0) < 1e-9
    assert abs(r["collective_s"] - 1.0) < 1e-9
    assert r["roofline_fraction"] == pytest.approx(1.0)


def test_allreduce_double_counted():
    r = roofline_terms(0, 0, {"all-gather": 0, "all-reduce": 50e9,
                              "reduce-scatter": 0, "all-to-all": 0,
                              "collective-permute": 0})
    assert abs(r["collective_s"] - 2.0) < 1e-9


def test_dominant_label():
    r = roofline_terms(1e15, 1e9, {"all-gather": 0, "all-reduce": 0,
                                   "reduce-scatter": 0, "all-to-all": 0,
                                   "collective-permute": 0})
    assert r["dominant"] == "compute_s"
