"""Partitioner invariants: validity, balance, memory constraint, and the
paper's qualitative claims (refinement improves cut; combinatorial beats
SFC)."""
import numpy as np
import pytest

from repro.core import (METHODS, Topology, partition, scale_to_load,
                        target_block_sizes)
from repro.core.metrics import (block_sizes_of, edge_cut, imbalance,
                                max_comm_volume, memory_violations)
from repro.sparse.generators import grid, rdg, rgg


@pytest.fixture(scope="module")
def mesh2d():
    return rdg(2500, seed=3)


@pytest.fixture(scope="module")
def topo8(mesh2d):
    return scale_to_load(Topology.topo1(8, 2 / 8, 4.0, 5.2), mesh2d.n)


@pytest.mark.parametrize("method", METHODS)
def test_partition_valid(mesh2d, topo8, method):
    if method == "geoHier":
        pytest.skip("hierarchical needs fanouts; covered separately")
    part, tw = partition(mesh2d, topo8, method)
    assert part.shape == (mesh2d.n,)
    assert part.min() >= 0 and part.max() < topo8.k
    # every block non-empty
    assert len(np.unique(part)) == topo8.k
    # balance: within 5% of Algorithm-1 targets
    assert imbalance(part, tw) < 1.06
    # constraint (3) with small slack
    assert memory_violations(part, topo8, slack=0.06) == 0


def test_refinement_improves_cut(mesh2d, topo8):
    p0, tw = partition(mesh2d, topo8, "geoKM")
    p1, _ = partition(mesh2d, topo8, "geoRef", tw=tw)
    assert edge_cut(mesh2d, p1) <= edge_cut(mesh2d, p0)


def test_combinatorial_beats_sfc(mesh2d, topo8):
    """Paper Sec. VI: refined methods < space-filling-curve quality."""
    p_sfc, tw = partition(mesh2d, topo8, "sfc")
    p_ref, _ = partition(mesh2d, topo8, "geoRef", tw=tw)
    assert edge_cut(mesh2d, p_ref) < edge_cut(mesh2d, p_sfc)


def test_hierarchical_kmeans():
    g = rdg(1600, seed=5)
    topo = scale_to_load(
        Topology.topo3(nodes=2, cores_per_node=4, fast_nodes=1), g.n)
    part, tw = partition(g, topo, "geoHier")
    assert len(np.unique(part)) == 8
    assert imbalance(part, tw) < 1.10


def test_grid_partition_cut_scales():
    """On a k-partitioned sqrt-grid the cut should be O(k * sqrt(n/k))."""
    g = grid((40, 40))
    topo = scale_to_load(Topology.homogeneous(4), g.n)
    part, tw = partition(g, topo, "geoRef")
    cut = edge_cut(g, part)
    assert cut < 8 * 40          # generous: 2 straight cuts would be 80


def test_heterogeneous_block_sizes_respected():
    g = rgg(3000, dim=2, seed=7)
    topo = scale_to_load(Topology.topo1(6, 1 / 6, 16.0, 13.8), g.n)
    part, tw = partition(g, topo, "geoKM")
    sizes = block_sizes_of(part, 6)
    # fast PU block ~ tw[0], slow ~ tw[-1]; ratio must carry through
    assert sizes[0] > 2.0 * sizes[-1]
    assert abs(sizes[0] - tw[0]) / tw[0] < 0.05


def test_rcb_extreme_weight_skew_leaves_no_empty_block():
    """Degenerate-split regression: a target-weight ratio so extreme that
    ``round(frac * n)`` hits 0 (or n) used to hand one side an empty
    vertex set and emit empty blocks.  Every block must own >= 1 vertex
    as long as it holds at least one target weight."""
    from repro.core.rcb import partition_rcb

    g = grid((8, 8))
    for tw in ([1000.0, 1.0], [1.0, 1000.0], [1000.0, 1.0, 1.0, 1000.0]):
        part = partition_rcb(g, np.asarray(tw))
        sizes = np.bincount(part, minlength=len(tw))
        assert sizes.min() >= 1, (tw, sizes.tolist())
    # the skew still steers nearly everything to the heavy block
    part = partition_rcb(g, np.asarray([1000.0, 1.0]))
    assert np.bincount(part, minlength=2)[0] >= 60


def test_comm_volume_sane(mesh2d, topo8):
    part, tw = partition(mesh2d, topo8, "geoRef")
    mcv = max_comm_volume(mesh2d, part, topo8.k)
    assert 0 < mcv < mesh2d.n // topo8.k
