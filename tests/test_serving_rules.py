"""Batch-aware serving weight layout policy (§Perf cell 3)."""
import jax
import pytest

from repro.configs.registry import get_config
from repro.launch.dryrun import (ACCUM_STEPS, REMAT_CHUNKS, REMAT_POLICY,
                                 serving_weight_rules)


class FakeMesh:
    """Just enough of a Mesh for the policy (shape dict + axis names)."""

    def __init__(self, shape):
        self.shape = shape

    @property
    def axis_names(self):
        return tuple(self.shape)


MESH = FakeMesh({"data": 16, "model": 16})
POD_MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_small_model_batched_gets_tp_only():
    cfg = get_config("mamba2-130m")
    assert serving_weight_rules(cfg, MESH, batch=128) == {"embed": None}


def test_unsharded_batch_keeps_fsdp():
    # measured: B=1 decode is faster under FSDP weight-splitting
    cfg = get_config("mamba2-130m")
    assert serving_weight_rules(cfg, MESH, batch=1) == {}


def test_large_model_keeps_fsdp():
    # mistral-large: 123B bf16 / 16-way TP = ~15 GB/chip > budget
    cfg = get_config("mistral-large-123b")
    assert serving_weight_rules(cfg, MESH, batch=128) == {}


def test_multi_pod_dp_degree():
    cfg = get_config("mamba2-130m")
    # dp = 2*16 = 32; batch 128 still divides, batch 48 does not
    assert serving_weight_rules(cfg, POD_MESH, batch=128) == {"embed": None}
    assert serving_weight_rules(cfg, POD_MESH, batch=48) == {}


def test_policy_tables_cover_known_archs():
    from repro.configs.registry import ARCHS
    for a in ACCUM_STEPS:
        assert a in ARCHS
    for a in REMAT_POLICY:
        assert a in ARCHS
        assert REMAT_POLICY[a] in ("full", "dots", "dots_nb", "none")
    for a, c in REMAT_CHUNKS.items():
        assert a in ARCHS
        from repro.configs.registry import get_config
        assert get_config(a).n_groups % c == 0
