"""Property-based tests for the Misra–Gries edge coloring that schedules
the halo-exchange ppermute rounds (core.refinement.vizing_edge_coloring).

The coloring is the load-bearing combinatorial piece of the distributed
SpMV: every color class must be a matching (one ppermute partner per
device per round) and the Delta+1 bound is what caps the number of rounds
at quotient-degree + 1.  Quotient graphs are *simple* by construction
(sparse.distributed dedupes directed pairs into undirected edges before
coloring), so the strategy generates random simple graphs.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.refinement import vizing_edge_coloring


@st.composite
def simple_weighted_graph(draw):
    """Random simple undirected graph as (pairs (m, 2), weights (m,))."""
    v = draw(st.integers(min_value=2, max_value=14))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    density = draw(st.floats(min_value=0.0, max_value=1.0))
    rng = np.random.default_rng(seed)
    iu = np.triu_indices(v, k=1)
    all_pairs = np.stack(iu, axis=1)
    m = int(round(density * len(all_pairs)))
    sel = rng.permutation(len(all_pairs))[:m]
    pairs = all_pairs[np.sort(sel)].astype(np.int64)
    weights = rng.uniform(0.5, 100.0, size=len(pairs))
    return pairs, weights


@settings(max_examples=60, deadline=None)
@given(simple_weighted_graph())
def test_proper_coloring_within_vizing_bound(gw):
    pairs, weights = gw
    colors = vizing_edge_coloring(pairs, weights)
    assert colors.shape == (len(pairs),)
    if len(pairs) == 0:
        return
    deg = np.bincount(pairs.ravel())
    delta = int(deg.max())
    # Vizing / Misra–Gries bound: at most Delta + 1 colors, labeled 0..
    assert colors.min() >= 0
    assert colors.max() <= delta            # i.e. < Delta + 1 colors
    # proper: no two edges sharing a vertex get the same color => each
    # color class is a matching (what makes it a valid ppermute round)
    for vtx in np.unique(pairs):
        incident = colors[(pairs[:, 0] == vtx) | (pairs[:, 1] == vtx)]
        assert len(np.unique(incident)) == len(incident), (
            f"vertex {vtx} has repeated colors {sorted(incident.tolist())}")


@settings(max_examples=30, deadline=None)
@given(simple_weighted_graph())
def test_heaviest_class_scheduled_first(gw):
    """Classes are relabeled heaviest-first: round 0 carries the largest
    total communication volume, preserving the heaviest-first scheduling
    of the greedy coloring at class granularity."""
    pairs, weights = gw
    colors = vizing_edge_coloring(pairs, weights)
    if len(pairs) == 0:
        return
    n_col = int(colors.max()) + 1
    class_w = np.zeros(n_col)
    np.add.at(class_w, colors, weights)
    assert np.all(np.diff(class_w) <= 1e-9), class_w


def test_empty_edge_set_regression():
    """k=1 or fully-internal partitions produce an empty quotient graph;
    the coloring must return an empty int32 array, not crash."""
    colors = vizing_edge_coloring(np.zeros((0, 2), dtype=np.int64),
                                  np.zeros(0, dtype=np.float64))
    assert colors.shape == (0,)
    assert colors.dtype == np.int32


def test_single_edge():
    colors = vizing_edge_coloring(np.array([[0, 1]], dtype=np.int64),
                                  np.array([3.0]))
    assert colors.tolist() == [0]


def test_triangle_needs_three_colors():
    # K3: Delta = 2 and chromatic index 3 = Delta + 1 (class-1 tightness)
    pairs = np.array([[0, 1], [1, 2], [0, 2]], dtype=np.int64)
    colors = vizing_edge_coloring(pairs, np.ones(3))
    assert sorted(colors.tolist()) == [0, 1, 2]
