"""Causal chunk-skipping attention path == the full lax.map reference
(values and grads), plus gating rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import gqa_attend

BIG = 1 << 30      # min_seq sentinel that disables the skip path


def _qkv(key, B, S, Hq, Hkv, hd, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(key), 3)
    return (jax.random.normal(k1, (B, S, Hq, hd), dtype),
            jax.random.normal(k2, (B, S, Hkv, hd), dtype),
            jax.random.normal(k3, (B, S, Hkv, hd), dtype))


def test_values_match_reference():
    q, k, v = _qkv(0, 2, 256, 4, 2, 16)
    ref = gqa_attend(q, k, v, causal=True, causal_skip_min_seq=BIG)
    new = gqa_attend(q, k, v, causal=True, causal_skip_min_seq=64)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(new),
                               atol=2e-6)


def test_grads_match_reference():
    q, k, v = _qkv(1, 1, 128, 2, 2, 8)

    def loss(q, min_seq):
        return jnp.sum(gqa_attend(q, k, v, causal=True,
                                  causal_skip_min_seq=min_seq) ** 2)

    g0 = jax.grad(loss)(q, BIG)
    g1 = jax.grad(loss)(q, 64)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), atol=2e-6)


def test_gating():
    # windowed / non-causal / offset queries must NOT take the skip path
    # (it assumes full prefix visibility) — just check numerics still hold
    q, k, v = _qkv(2, 1, 128, 2, 2, 8)
    w_ref = gqa_attend(q, k, v, causal=True, window=32,
                       causal_skip_min_seq=64)
    w_base = gqa_attend(q, k, v, causal=True, window=32,
                        causal_skip_min_seq=BIG)
    np.testing.assert_allclose(np.asarray(w_ref), np.asarray(w_base),
                               atol=2e-6)


@given(st.sampled_from([64, 128, 192]), st.integers(0, 3))
@settings(max_examples=6, deadline=None)
def test_property_random_shapes(S, seed):
    q, k, v = _qkv(10 + seed, 1, S, 2, 1, 8)
    ref = gqa_attend(q, k, v, causal=True, causal_skip_min_seq=BIG)
    new = gqa_attend(q, k, v, causal=True, causal_skip_min_seq=32)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(new), atol=3e-6)
