"""Pod-aware two-level partitioning pipeline (ISSUE 4 acceptance).

The hier runtime (``comm='hier'``) pays only the inter-pod cut at
slow-link latency; these tests lock down that the pod-aware pipeline
actually *reduces* that component versus the pod-oblivious baseline
(same method, contiguous pods), that the pod-level sweep derives
non-contiguous pod assignments from the partition, and that
``build_plan_hier`` consumes the partitioner's pod assignment without
relabeling errors (dense-oracle agreement vs the ``coo`` backend).
"""
import numpy as np
import pytest

from hier_sim import hier_spmv_numpy
from repro.core import (HierPartition, Topology, contiguous_pods,
                        evaluate, partition, partition_hier,
                        pod_assignment_for, scale_to_load)
from repro.core.metrics import (comm_volumes, edge_cut, pod_comm_volumes,
                                pod_cut_split, summarize_hier,
                                two_level_objective)
from repro.core.refinement import (quotient_graph, refine_partition,
                                   refine_pod_assignment)
from repro.sparse import make_operator
from repro.sparse.distributed import build_plan_hier
from repro.sparse.generators import grid, rdg
from repro.sparse.graph import laplacian_csr


@pytest.fixture(scope="module")
def striped_grid():
    """The acceptance configuration: a grid whose 8 stripes cross the
    long axis, so each stripe boundary (and the contiguous-pod cut)
    costs a full 128-wide grid line."""
    g = grid((16, 128))
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    part = ((np.arange(g.n) * 8) // g.n).astype(np.int32)
    return g, (indptr, indices, data), part


def test_pod_aware_beats_stripes_baseline(striped_grid):
    """Acceptance: strictly lower inter-pod comm volume and <= inter-pod
    ppermute rounds than the stripes partition with contiguous pods."""
    g, (indptr, indices, data), part_s = striped_grid
    topo = scale_to_load(Topology.homogeneous(8), g.n)
    pod_c = contiguous_pods(8, 2)

    res = partition_hier(g, topo, "geoRef", pods=2)
    assert isinstance(res, HierPartition)
    assert res.k == 8 and res.n_pods == 2

    _, inter_base = pod_comm_volumes(g, part_s, 8, pod_c)
    _, inter_pa = pod_comm_volumes(g, res.part, 8, res.pod_of)
    assert inter_pa.sum() < inter_base.sum()          # strictly lower

    plan_base = build_plan_hier(indptr, indices, data, part_s, 2, 8)
    plan_pa = build_plan_hier(indptr, indices, data, res.part,
                              res.pod_of, 8)
    assert plan_pa.n_rounds_inter <= plan_base.n_rounds_inter


def test_pod_aware_beats_flat_same_method():
    """Same method, pod-aware vs pod-oblivious: the pipeline's inter-pod
    comm volume is strictly below flat greedyRef + contiguous pods (the
    combinatorial method whose flat labels carry no pod locality)."""
    g = rdg(2500, seed=3)
    topo = scale_to_load(Topology.homogeneous(8), g.n)
    part_flat, _ = partition(g, topo, "greedyRef", seed=0)
    res = partition_hier(g, topo, "greedyRef", pods=2, seed=0)

    pod_c = contiguous_pods(8, 2)
    _, inter_flat = pod_comm_volumes(g, part_flat, 8, pod_c)
    _, inter_pa = pod_comm_volumes(g, res.part, 8, res.pod_of)
    assert inter_pa.sum() < inter_flat.sum()
    # and the weighted objective improves too
    assert (two_level_objective(g, res.part, res.pod_of, res.lam)
            < two_level_objective(g, part_flat, pod_c, res.lam))


def test_pod_sweep_derives_noncontiguous_assignment(striped_grid):
    """Permuted stripe labels: the contiguous grouping interleaves the
    stripes (7 pod-crossing boundaries) while the KL sweep recovers the
    geometric halves — a non-contiguous, partition-derived pod
    assignment with the minimum single-boundary inter volume."""
    g, _, _ = striped_grid
    topo = scale_to_load(Topology.homogeneous(8), g.n)
    perm = np.array([0, 4, 1, 5, 2, 6, 3, 7])
    part = perm[(np.arange(g.n) * 8) // g.n].astype(np.int32)

    pod_sw = pod_assignment_for(g, part, topo, 2)
    pod_c = contiguous_pods(8, 2)
    assert not np.array_equal(pod_sw, pod_c)          # non-contiguous
    np.testing.assert_array_equal(np.bincount(pod_sw), [4, 4])
    _, inter_c = pod_comm_volumes(g, part, 8, pod_c)
    _, inter_sw = pod_comm_volumes(g, part, 8, pod_sw)
    assert inter_sw.sum() < inter_c.sum()
    # the sweep recovered the single-boundary grouping: stripes 0-3
    # (labels 0,4,1,5) share one pod, stripes 4-7 the other
    assert inter_sw.sum() == 2 * 128


def test_build_plan_hier_consumes_partition_pods(striped_grid):
    """Acceptance: build_plan_hier consumes the partitioner's (swept,
    non-contiguous) pod assignment without relabeling errors — the hier
    schedule agrees with the coo backend to < 1e-5."""
    g, (indptr, indices, data), _ = striped_grid
    topo = scale_to_load(Topology.homogeneous(8), g.n)
    perm = np.array([0, 4, 1, 5, 2, 6, 3, 7])
    part = perm[(np.arange(g.n) * 8) // g.n].astype(np.int32)
    pod_sw = pod_assignment_for(g, part, topo, 2)

    plan = build_plan_hier(indptr, indices, data, part, pod_sw, 8)
    op = make_operator(indptr, indices, data, "coo")
    x = np.random.default_rng(2).normal(size=g.n).astype(np.float32)
    ref = op.gather(op.matvec(op.scatter(x)))
    y = hier_spmv_numpy(plan, x)
    assert np.abs(y - ref).max() / np.abs(ref).max() < 1e-5


def test_make_operator_accepts_hier_partition(striped_grid):
    """make_operator unpacks a HierPartition (part, k, pod assignment)
    so the partitioner output drives the runtime directly."""
    g, (indptr, indices, data), _ = striped_grid
    topo = scale_to_load(Topology.homogeneous(8), g.n)
    res = partition_hier(g, topo, "sfc", pods=2)
    op = make_operator(indptr, indices, data, "coo")
    x = np.random.default_rng(3).normal(size=g.n).astype(np.float32)
    ref = op.gather(op.matvec(op.scatter(x)))
    # the k/part unpacking path (mesh-free plan construction)
    plan = build_plan_hier(indptr, indices, data, res.part, res.pod_of,
                           res.k)
    y = hier_spmv_numpy(plan, x)
    assert np.abs(y - ref).max() / np.abs(ref).max() < 1e-5


def test_weighted_refinement_never_worsens_objective():
    """Stage-D FM against the weighted objective: the two-level objective
    never increases, for several lambda values."""
    g = rdg(900, seed=7)
    rng = np.random.default_rng(0)
    part = rng.integers(0, 8, g.n).astype(np.int32)
    pod_of = contiguous_pods(8, 2)
    tw = np.full(8, g.n / 8)
    for lam in (1.0, 4.0, 16.0):
        before = two_level_objective(g, part, pod_of, lam)
        ref = refine_partition(g, part, tw, eps=0.05, pod_of=pod_of,
                               lam=lam)
        after = two_level_objective(g, ref, pod_of, lam)
        assert after <= before + 1e-6
        assert np.bincount(ref, minlength=8).max() <= np.ceil(
            tw.max() * 1.05)


def test_partition_hier_single_pod_degenerates():
    g = rdg(600, seed=5)
    topo = scale_to_load(Topology.homogeneous(4), g.n)
    res = partition_hier(g, topo, "geoKM", pods=1)
    flat, _ = partition(g, topo, "geoKM")
    np.testing.assert_array_equal(res.part, flat)
    np.testing.assert_array_equal(res.pod_of, [0, 0, 0, 0])


def test_partition_pods_kwarg_routes_hier():
    g = rdg(600, seed=6)
    topo = scale_to_load(Topology.homogeneous(4), g.n)
    part, tw = partition(g, topo, "sfc", pods=2)
    res = partition_hier(g, topo, "sfc", pods=2)
    np.testing.assert_array_equal(part, res.part)
    np.testing.assert_array_equal(tw, res.tw)


def test_refine_pod_assignment_respects_spec_groups():
    """Heterogeneous PUs: a fast block may never trade its pod slot with
    a slow one — per spec group, the pod multiset is preserved."""
    g = rdg(900, seed=8)
    topo = scale_to_load(Topology.topo1(8, 2 / 8, 4.0, 5.2), g.n)
    part, _ = partition(g, topo, "greedyRef", seed=1)
    pod_sw = pod_assignment_for(g, part, topo, 2)
    pod_c = contiguous_pods(8, 2)
    np.testing.assert_array_equal(np.bincount(pod_sw), np.bincount(pod_c))
    # fast PUs are 0, 1 — their pods must be a permutation of the
    # contiguous grouping's fast-pod multiset
    assert sorted(pod_sw[:2].tolist()) == sorted(pod_c[:2].tolist())
    pairs, w = quotient_graph(g, part, topo.k)
    again = refine_pod_assignment(pairs, w, pod_sw)
    # idempotent-ish: a second unconstrained sweep from the swept state
    # cannot increase the inter weight
    W = np.zeros((8, 8))
    W[pairs[:, 0], pairs[:, 1]] = w
    W += W.T

    def inter(p):
        return W[np.asarray(p)[:, None] != np.asarray(p)[None, :]].sum() / 2

    assert inter(again) <= inter(pod_sw) <= inter(pod_c)


def test_evaluate_reports_intra_inter_split():
    g = rdg(800, seed=9)
    topo = scale_to_load(Topology.homogeneous(4), g.n)
    out = evaluate(g, topo, methods=("sfc", "greedyRef"), pods=2,
                   verbose=False)
    for m, s in out.items():
        assert s["cut_intra"] + s["cut_inter"] == pytest.approx(s["cut"])
        assert (s["comm_volume_intra"] + s["comm_volume_inter"]
                == s["total_comm_volume"])
        assert s["two_level_objective"] == pytest.approx(
            s["cut_intra"] + s["lam"] * s["cut_inter"])


def test_link_cost_model():
    """LinkCosts: lambda ratio, per-pair cost matrix, and the topology
    override hook (calibrating from measured round latencies)."""
    topo = Topology.homogeneous(4)
    lc = topo.link_costs()
    assert lc.lam == pytest.approx(4.0)          # default round-latency ratio
    lc2 = topo.link_costs(intra=2.0, inter=10.0)
    assert lc2.lam == pytest.approx(5.0)
    pod_of = np.array([0, 1, 0, 1])
    C = lc2.matrix(pod_of)
    assert C.shape == (4, 4) and (np.diag(C) == 0).all()
    assert C[0, 2] == 2.0 and C[0, 1] == 10.0    # same pod vs pod-crossing
    np.testing.assert_array_equal(C, C.T)
    # the matrix is the per-edge price of the two-level objective: the
    # weighted cut equals sum over cut block pairs of quotient weight * C
    g = grid((8, 8))
    rng = np.random.default_rng(0)
    part = rng.integers(0, 4, g.n).astype(np.int32)
    pairs, w = quotient_graph(g, part, 4)
    priced = float(np.sum(w * C[pairs[:, 0], pairs[:, 1]] / lc2.intra))
    assert priced == pytest.approx(
        two_level_objective(g, part, pod_of, lam=lc2.lam))
    with pytest.raises(ValueError):
        topo.link_costs(intra=0.0)


def test_summarize_hier_matches_componentwise():
    g = grid((12, 12))
    rng = np.random.default_rng(1)
    part = rng.integers(0, 4, g.n).astype(np.int32)
    pod_of = np.array([0, 1, 0, 1])
    topo = scale_to_load(Topology.homogeneous(4), g.n)
    tw = np.full(4, g.n / 4)
    s = summarize_hier(g, part, topo, tw, pod_of, lam=3.0)
    ia, ie = pod_cut_split(g, part, pod_of)
    assert s["cut_intra"] == ia and s["cut_inter"] == ie
    assert ia + ie == pytest.approx(edge_cut(g, part))
    iv, ev = pod_comm_volumes(g, part, 4, pod_of)
    np.testing.assert_array_equal(iv + ev, comm_volumes(g, part, 4))
    assert s["max_comm_volume_inter"] == ev.max()
    assert s["two_level_objective"] == pytest.approx(ia + 3.0 * ie)
