"""Property-style tests for the vectorized distributed plan builder.

These run entirely on the host (no devices, no shard_map): the ppermute
round schedule is simulated in NumPy, so plan *semantics* — scatter/gather
round trip, halo-exchange SpMV against the dense oracle, round bounds —
are checked for many partitions cheaply.  The device-level shard_map
execution of the same plans is covered by tests/test_distributed.py.
"""
import time

import numpy as np
import pytest

from repro.sparse.distributed import build_plan, build_plan_reference
from repro.sparse.generators import grid, rdg
from repro.sparse.graph import laplacian_csr


def dense_of(indptr, indices, data, n):
    a = np.zeros((n, n), dtype=np.float64)
    src = np.repeat(np.arange(n), np.diff(indptr))
    np.add.at(a, (src, indices), data)
    return a


def halo_spmv_numpy(plan, x):
    """Execute the plan's halo schedule + local matvec in NumPy."""
    k, B, S, R = plan.k, plan.B, plan.S, plan.n_rounds
    xb = plan.scatter_vec(x)                          # (k, B)
    send_idx = np.asarray(plan.send_idx)
    send_mask = np.asarray(plan.send_mask)
    ext = np.zeros((k, B + R * S), dtype=np.float64)
    ext[:, :B] = xb
    for c in range(R):
        send = xb[np.arange(k)[:, None],
                  send_idx[:, c, :]] * send_mask[:, c, :]
        recv = np.zeros_like(send)
        for (s, d) in plan.round_perms[c]:            # O(k) pairs per round
            recv[d] = send[s]
        ext[:, B + c * S:B + (c + 1) * S] = recv
    rows = np.asarray(plan.rows)
    cols = np.asarray(plan.cols)
    vals = np.asarray(plan.vals)
    y = np.zeros((k, B), dtype=np.float64)
    for b in range(k):
        np.add.at(y[b], rows[b], vals[b] * ext[b, cols[b]])
    return plan.gather_vec(y * np.asarray(plan.row_mask))


@pytest.fixture(scope="module")
def lap():
    g = rdg(800, seed=7)
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    return g, indptr, indices, data


@pytest.mark.parametrize("k", [2, 4, 8])
def test_scatter_gather_roundtrip(lap, k):
    g, indptr, indices, data = lap
    part = np.random.default_rng(k).integers(0, k, g.n)
    plan = build_plan(indptr, indices, data, part, k)
    x = np.random.default_rng(1).normal(size=g.n).astype(np.float32)
    rt = plan.gather_vec(plan.scatter_vec(x))
    np.testing.assert_array_equal(rt, x)


@pytest.mark.parametrize("k", [2, 4, 8])
def test_halo_spmv_matches_dense_oracle(lap, k):
    g, indptr, indices, data = lap
    part = np.random.default_rng(100 + k).integers(0, k, g.n)
    plan = build_plan(indptr, indices, data, part, k)
    A = dense_of(indptr, indices, data, g.n)
    x = np.random.default_rng(2).normal(size=g.n)
    np.testing.assert_allclose(halo_spmv_numpy(plan, x), A @ x.astype(
        np.float32), atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize("k", [2, 4, 8])
def test_matches_reference_builder(lap, k):
    g, indptr, indices, data = lap
    part = np.random.default_rng(200 + k).integers(0, k, g.n)
    p1 = build_plan(indptr, indices, data, part, k)
    p0 = build_plan_reference(indptr, indices, data, part, k)
    assert (p1.k, p1.B, p1.S, p1.n_rounds, p1.n) == \
           (p0.k, p0.B, p0.S, p0.n_rounds, p0.n)
    np.testing.assert_array_equal(p1.perm, p0.perm)
    assert p1.round_perms == p0.round_perms
    for f in ("rows", "cols", "vals", "row_mask", "send_idx", "send_mask",
              "rows_int", "cols_int", "vals_int", "rows_bnd", "cols_bnd",
              "vals_bnd", "interior_mask", "diag", "cols_global"):
        np.testing.assert_array_equal(np.asarray(getattr(p1, f)),
                                      np.asarray(getattr(p0, f)), err_msg=f)


def test_edge_coloring_rounds_within_degree_bound(lap):
    g, indptr, indices, data = lap
    for k in (2, 4, 8):
        part = np.random.default_rng(300 + k).integers(0, k, g.n)
        plan = build_plan(indptr, indices, data, part, k)
        # quotient-graph max degree
        src = np.repeat(np.arange(g.n), np.diff(indptr))
        pa, pb = part[src], part[indices]
        ext = pa != pb
        pairs = np.unique(pa[ext] * k + pb[ext])
        deg = np.bincount(pairs // k, minlength=k)
        delta = int(deg.max()) if len(deg) else 0
        assert 1 <= plan.n_rounds <= max(delta + 1, 1)


def test_empty_and_singleton_blocks():
    g = grid((16, 16))
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    # k=4 but only blocks {0, 2} populated: empty blocks must not break
    part = np.where(np.arange(g.n) < g.n // 2, 0, 2)
    plan = build_plan(indptr, indices, data, part, 4)
    A = dense_of(indptr, indices, data, g.n)
    x = np.random.default_rng(3).normal(size=g.n)
    np.testing.assert_allclose(halo_spmv_numpy(plan, x),
                               A @ x.astype(np.float32),
                               atol=1e-3, rtol=1e-4)
    # k=1: no halo at all
    plan1 = build_plan(indptr, indices, data, np.zeros(g.n, int), 1)
    np.testing.assert_allclose(halo_spmv_numpy(plan1, x),
                               A @ x.astype(np.float32),
                               atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize("k", [2, 8])
@pytest.mark.parametrize("limit", [0, 777, 4096])
def test_sharded_bitmap_path_matches_dense_and_reference(lap, k, limit,
                                                         monkeypatch):
    """Force the k*n > DENSE_PLAN_LIMIT vertex-range-sharded bitmap path
    (the one production-scale instances take) and check it against both
    the single-shot dense path and the seed reference builder.  The limit
    values exercise one-vertex chunks (0), chunks that straddle the
    vertex range unevenly (777), and a few large chunks (4096)."""
    import repro.sparse.distributed as dmod
    g, indptr, indices, data = lap
    part = np.random.default_rng(400 + k).integers(0, k, g.n)
    p_dense = build_plan(indptr, indices, data, part, k)
    monkeypatch.setattr(dmod, "DENSE_PLAN_LIMIT", limit)
    p_shard = dmod.build_plan(indptr, indices, data, part, k)
    p_ref = build_plan_reference(indptr, indices, data, part, k)
    for other, tag in ((p_dense, "dense"), (p_ref, "reference")):
        assert (p_shard.k, p_shard.B, p_shard.S, p_shard.n_rounds) == \
               (other.k, other.B, other.S, other.n_rounds), tag
        assert p_shard.round_perms == other.round_perms, tag
        for f in ("perm", "rows", "cols", "vals", "row_mask", "send_idx",
                  "send_mask", "rows_int", "cols_int", "vals_int",
                  "rows_bnd", "cols_bnd", "vals_bnd", "interior_mask",
                  "diag", "cols_global"):
            np.testing.assert_array_equal(
                np.asarray(getattr(p_shard, f)),
                np.asarray(getattr(other, f)), err_msg=f"{tag}:{f}")


def test_build_plan_has_no_per_edge_python_iteration():
    """Regression guard: ~100k-edge mesh (201k directed Laplacian entries),
    worst-case random partition.  Asserted as a *ratio* against the seed
    per-edge reference on the same machine (robust to CI load), plus a
    generous absolute ceiling as a backstop."""
    g = grid((224, 224))          # 50176 vertices, ~100k undirected edges
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    part = np.random.default_rng(0).integers(0, 8, g.n)
    # validate=False: the conftest turns REPRO_VALIDATE on, and the O(nnz)
    # verifier would be timed against an unverified reference build below —
    # this test measures builder complexity, not verification cost.
    build_plan(indptr, indices, data, part, 8,
               validate=False)                      # warm (jax init etc.)
    t0 = time.perf_counter()
    plan = build_plan(indptr, indices, data, part, 8, validate=False)
    dt = time.perf_counter() - t0
    t0 = time.perf_counter()
    build_plan_reference(indptr, indices, data, part, 8)
    dt_ref = time.perf_counter() - t0
    assert plan.n == g.n
    assert dt < dt_ref / 3, (
        f"build_plan {dt:.3f}s vs reference {dt_ref:.3f}s — "
        "per-edge loop regression?")
    assert dt < 3.0, f"build_plan took {dt:.3f}s on a 100k-edge mesh"
