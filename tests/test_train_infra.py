"""Optimizer, checkpointing, data pipeline, trainer fault tolerance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.topology import Topology
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.train.checkpoint import (latest_checkpoint, restore_checkpoint,
                                    save_checkpoint)
from repro.train.optimizer import (AdamWConfig, adamw_update, global_norm,
                                   init_opt_state, lr_schedule)
from repro.train.trainer import Trainer, TrainerConfig


# -- optimizer ----------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=200)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip():
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=1)
    grads = {"w": jnp.asarray([1e6, 0.0, 0.0])}
    _, _, m = adamw_update(cfg, params, grads, state)
    assert float(m["grad_norm"]) > 1e5          # reported pre-clip


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert abs(lrs[99] - 0.1) < 0.05
    assert max(lrs) <= 1.0 + 1e-6


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6


# -- checkpoint ----------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
             "opt": {"step": jnp.int32(7)}}
    save_checkpoint(tmp_path, state, step=7)
    path = latest_checkpoint(tmp_path)
    assert path is not None and path.name == "step_00000007"
    restored, manifest = restore_checkpoint(path, state)
    assert manifest["step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_gc(tmp_path):
    state = {"w": jnp.zeros(2)}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, state, step=s, keep=2)
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == ["step_00000004", "step_00000005"]


def test_checkpoint_shape_mismatch(tmp_path):
    save_checkpoint(tmp_path, {"w": jnp.zeros(3)}, step=1)
    with pytest.raises(ValueError):
        restore_checkpoint(latest_checkpoint(tmp_path),
                           {"w": jnp.zeros(4)})


# -- data ------------------------------------------------------------------------

def test_data_deterministic():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=3)
    a = SyntheticLM(cfg).batch(5)
    b = SyntheticLM(cfg).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg).batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_labels_shifted():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4)
    b = SyntheticLM(cfg).batch(0)
    assert b["tokens"].shape == (4, 16)
    assert np.all(b["labels"] < 100) and np.all(b["tokens"] >= 0)


def test_data_rank_disjoint():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8)
    d = SyntheticLM(cfg)
    r0 = d.batch(0, rank=0, world=2)
    r1 = d.batch(0, rank=1, world=2)
    assert r0["tokens"].shape == (4, 16)
    assert not np.array_equal(r0["tokens"], r1["tokens"])


# -- trainer fault tolerance -----------------------------------------------------

def test_trainer_fault_and_resume(tmp_path):
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    tcfg = TrainerConfig(steps=30, seq_len=32, global_batch=4,
                         ckpt_every=10, ckpt_dir=str(tmp_path),
                         log_every=1000, fail_at_step=25)
    tr = Trainer(cfg, tcfg)
    with pytest.raises(RuntimeError, match="injected fault"):
        tr.run()
    # restart
    tcfg2 = TrainerConfig(steps=30, seq_len=32, global_batch=4,
                          ckpt_every=10, ckpt_dir=str(tmp_path),
                          log_every=1000)
    tr2 = Trainer(cfg, tcfg2)
    assert tr2.maybe_resume()
    assert tr2.step == 20
    losses = tr2.run()
    assert tr2.step == 30
    assert np.isfinite(losses).all()


def test_trainer_loss_decreases(tmp_path):
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    tcfg = TrainerConfig(steps=40, seq_len=32, global_batch=8,
                         ckpt_every=1000, ckpt_dir=str(tmp_path),
                         log_every=1000, lr=3e-3)
    losses = Trainer(cfg, tcfg).run()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_trainer_elastic_rebalance(tmp_path):
    cfg = get_config("mamba2-130m", smoke=True)
    topo = Topology.topo1(8, 2 / 8, 4.0, 5.2)
    tcfg = TrainerConfig(steps=1, seq_len=16, global_batch=64,
                         ckpt_dir=str(tmp_path), log_every=1000)
    tr = Trainer(cfg, tcfg, topo=topo)
    assert tr.shares.sum() == 64
    assert tr.shares[0] > tr.shares[-1]          # fast PU gets more
    # lose the two fast PUs -> survivors re-balance uniformly
    survivors = Topology(topo.pus[2:])
    shares = tr.rebalance(survivors)
    assert shares.sum() == 64
    assert len(shares) == 6
    assert shares.max() - shares.min() <= 1
