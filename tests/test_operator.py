"""Operator protocol: single-device backends through the one cg_solve,
plus the cross-backend agreement matrix (promoted from benchmarks/
bench_cg.py): every backend/preconditioner combination solves the same
2-D grid Laplacian in an 8-device subprocess and must agree to < 1e-5.
"""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.sparse import (BlockEllOperator, CooOperator, Operator,
                          cg_solve, make_operator, cg_solve_global)
from repro.sparse.generators import rdg
from repro.sparse.graph import laplacian_csr


@pytest.fixture(scope="module")
def system():
    # small instance: the interpreted Pallas kernel's grid is O(S * NNZB)
    # and a whole-CG trace multiplies it; shift=0.1 keeps the condition
    # number low enough for tight cross-backend agreement in f32
    g = rdg(300, seed=5)
    indptr, indices, data = laplacian_csr(g, shift=0.1)
    import scipy.sparse as sp
    A = sp.csr_matrix((data, indices, indptr), shape=(g.n, g.n))
    b = np.random.default_rng(1).normal(size=g.n).astype(np.float32)
    return (indptr, indices, data), A, b


def test_factory_and_protocol(system):
    (indptr, indices, data), A, b = system
    for backend in ("coo", "bell"):
        op = make_operator(indptr, indices, data, backend)
        assert isinstance(op, Operator)
        assert op.n == A.shape[0]
    assert isinstance(make_operator(indptr, indices, data, "coo"),
                      CooOperator)
    assert isinstance(make_operator(indptr, indices, data, "bell"),
                      BlockEllOperator)
    with pytest.raises(ValueError):
        make_operator(indptr, indices, data, "nope")
    with pytest.raises(ValueError):
        make_operator(indptr, indices, data, "dist_halo")   # missing part/k
    with pytest.raises(ValueError):
        make_operator(indptr, indices, data, "dist_hier")   # missing part/k


def test_block_jacobi_requires_distributed_backend(system):
    (indptr, indices, data), A, b = system
    import jax.numpy as jnp
    op = make_operator(indptr, indices, data, "coo")
    with pytest.raises(ValueError):
        cg_solve(op, jnp.asarray(b), precondition="block_jacobi")


@pytest.mark.parametrize("backend", ["coo", "bell"])
def test_matvec_matches_scipy(system, backend):
    (indptr, indices, data), A, b = system
    op = make_operator(indptr, indices, data, backend)
    x = np.random.default_rng(0).normal(size=op.n).astype(np.float32)
    y = op.gather(op.matvec(op.scatter(x)))
    np.testing.assert_allclose(y, A @ x, atol=1e-4, rtol=1e-4)


def test_cg_backends_agree(system):
    (indptr, indices, data), A, b = system
    sols = {}
    for backend in ("coo", "bell"):
        op = make_operator(indptr, indices, data, backend)
        x, iters, res = cg_solve_global(op, b, tol=1e-7, max_iters=2000)
        rel = np.linalg.norm(A @ x - b) / np.linalg.norm(b)
        assert rel < 1e-4, (backend, rel)
        sols[backend] = x
    scale = np.abs(sols["coo"]).max()
    assert np.abs(sols["coo"] - sols["bell"]).max() / scale < 1e-5


def test_cg_solve_accepts_operator_or_callable(system):
    (indptr, indices, data), A, b = system
    import jax.numpy as jnp
    op = make_operator(indptr, indices, data, "coo")
    r1 = cg_solve(op, jnp.asarray(b), tol=1e-6, max_iters=2000)
    r2 = cg_solve(op.matvec, jnp.asarray(b), tol=1e-6, max_iters=2000)
    np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x),
                               atol=1e-6)
    assert int(r1.iters) == int(r2.iters)


def test_jacobi_preconditioned_cg_single_device(system):
    (indptr, indices, data), A, b = system
    op = make_operator(indptr, indices, data, "coo")
    # diag() matches scipy
    np.testing.assert_allclose(np.asarray(op.diag()), A.diagonal(),
                               atol=1e-5, rtol=1e-5)
    x_pl, it_pl, _ = cg_solve_global(op, b, tol=1e-7, max_iters=2000)
    x_pc, it_pc, _ = cg_solve_global(op, b, tol=1e-7, max_iters=2000,
                                     precondition="jacobi")
    # both stop on the same unpreconditioned tolerance => same quality
    for x in (x_pl, x_pc):
        rel = np.linalg.norm(A @ x - b) / np.linalg.norm(b)
        assert rel < 1e-4
    scale = np.abs(x_pl).max()
    assert np.abs(x_pl - x_pc).max() / scale < 1e-5


def test_jacobi_requires_operator():
    import jax.numpy as jnp
    with pytest.raises(ValueError):
        cg_solve(lambda x: x, jnp.ones(4), precondition="jacobi")


# -- cross-backend agreement matrix (one subprocess, 8 host devices) -------
# The dist_hier rows run on the two-level (pods=2, k=8) mesh from
# make_test_mesh(8, pods=2); the dist_tree3 rows on the depth-3
# (2, 2, 2) ("pod", "host", "pu") mesh from make_test_mesh(8,
# fanouts=(2, 2, 2)) — the ISSUE 5 acceptance configuration, run in
# both CI matrix jobs (latest + JAX 0.4.37) so the compat shims see the
# suffix-combined-axes ppermutes.

CROSS_BACKENDS = ("coo", "coo+jacobi", "bell", "bell+jacobi",
                  "dist_halo", "dist_halo+jacobi",
                  "dist_halo+jacobi_fused", "dist_halo+block_jacobi",
                  "dist_halo_seq", "dist_bell",
                  "dist_allgather", "dist_hier", "dist_hier+jacobi",
                  "dist_hier+block_jacobi_fused", "dist_hier_podaware",
                  "dist_hier_bell", "dist_tree3", "dist_tree3_bell",
                  "dist_tree3_aware", "dist_tree3_bottleneck",
                  "dist_tree3+block_jacobi_fused",
                  "dist_hier_batched")

CROSS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.sparse.generators import grid
    from repro.sparse.graph import laplacian_csr
    from repro.sparse import make_operator, cg_solve_global
    from repro.launch.mesh import make_test_mesh

    g = grid((24, 24))                       # the 2-D grid Laplacian
    indptr, indices, data = laplacian_csr(g, shift=0.1)
    part = np.random.default_rng(0).integers(0, 8, g.n)
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("pu",))
    mesh_hier = make_test_mesh(8, pods=2)    # ("pod", "pu") = (2, 4)
    mesh_tree = make_test_mesh(8, fanouts=(2, 2, 2))   # depth 3
    b = np.random.default_rng(1).normal(size=g.n).astype(np.float32)

    # partition-derived (swept, generally non-contiguous) pod assignment
    # driving the hier runtime — the ISSUE 4 acceptance path
    from repro.core import (Topology, partition_tree, pod_assignment_for,
                            scale_to_load)
    topo8 = scale_to_load(Topology.homogeneous(8), g.n)
    pod_sw = pod_assignment_for(g, part, topo8, 2)
    # tree-aware depth-3 partition driving the runtime (ISSUE 5)
    topo_t = scale_to_load(Topology.homogeneous(8, fanouts=(2, 2, 2)), g.n)
    res_tree = partition_tree(g, topo_t, "greedyRef", seed=0)
    # bottleneck-refined depth-3 partition on the same mesh (ISSUE 9):
    # the makespan objective must only reshape the partition, never the
    # solution the runtime computes on it
    res_btree = partition_tree(g, topo_t, "greedyRef", seed=0,
                               objective="bottleneck")
    assert res_btree.objective == "bottleneck"

    sols = {}
    extra = {}
    for name in %r:
        backend, _, variant = name.partition("+")
        kw = {}
        if backend == "dist_hier_batched":
            # fused multi-RHS masked CG on the two-level mesh: column 0 is
            # the shared b (feeds the agreement matrix); the whole batch
            # must match per-column sequential fused solves, with
            # per-column iteration counts equal to the sequential ones
            op = make_operator(indptr, indices, data, "dist_hier",
                               part=part, k=8, mesh=mesh_hier, pods=2)
            rngb = np.random.default_rng(7)
            bb = np.stack(
                [b, rngb.normal(size=g.n).astype(np.float32),
                 0.01 * b + rngb.normal(
                     scale=0.1, size=g.n).astype(np.float32)], axis=1)
            resb = op.solve(bb, tol=1e-7, max_iters=2000)
            xb = op.gather(resb.x)
            sols[name] = xb[:, 0]
            seq = [op.solve(bb[:, j], tol=1e-7, max_iters=2000)
                   for j in range(3)]
            extra["batched_vs_seq"] = max(
                float(np.abs(xb[:, j] - op.gather(seq[j].x)).max())
                / max(float(np.abs(op.gather(seq[j].x)).max()), 1e-30)
                for j in range(3))
            extra["batched_iters"] = np.asarray(resb.iters).tolist()
            extra["seq_iters"] = [int(s.iters) for s in seq]
            continue
        if backend == "dist_hier_podaware":
            backend = "dist_hier"
            kw = dict(part=part, k=8, mesh=mesh_hier, pods=pod_sw)
        elif backend == "dist_tree3_aware":
            backend = "dist_hier"            # HierPartition unpack path
            kw = dict(part=res_tree, mesh=mesh_tree)
        elif backend == "dist_tree3_bottleneck":
            backend = "dist_hier"
            kw = dict(part=res_btree, mesh=mesh_tree)
        elif backend.startswith("dist_tree3"):
            backend = ("dist_hier_bell" if backend.endswith("bell")
                       else "dist_hier")
            kw = dict(part=part, k=8, mesh=mesh_tree, fanouts=(2, 2, 2))
        elif backend.startswith("dist"):
            kw = dict(part=part, k=8, mesh=mesh)
            if backend in ("dist_hier", "dist_hier_bell"):
                kw.update(mesh=mesh_hier, pods=2)
        op = make_operator(indptr, indices, data, backend, **kw)
        if variant.endswith("fused"):
            res = op.solve(b, tol=1e-7, max_iters=2000,
                           precondition=variant[:-6] or None)
            sols[name] = op.gather(res.x)
        else:
            x, _, _ = cg_solve_global(op, b, tol=1e-7, max_iters=2000,
                                      precondition=variant or None)
            sols[name] = x
    ref = sols["coo"]
    scale = float(np.abs(ref).max())
    rel = {name: float(np.abs(x - ref).max()) / scale
           for name, x in sols.items()}
    rel.update({"_" + key: v for key, v in extra.items()})
    print(json.dumps(rel))
""") % (CROSS_BACKENDS,)


@pytest.fixture(scope="module")
def cross_backend_rel():
    proc = subprocess.run([sys.executable, "-c", CROSS_SCRIPT],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("name", CROSS_BACKENDS)
def test_cross_backend_agreement_2d_grid(cross_backend_rel, name):
    assert cross_backend_rel[name] < 1e-5, (name, cross_backend_rel)


def test_batched_dist_hier_matches_sequential(cross_backend_rel):
    """Fused multi-RHS CG on the two-level mesh: every column of the
    batched solve matches its per-column sequential fused solve."""
    assert cross_backend_rel["_batched_vs_seq"] < 1e-5, cross_backend_rel


def test_batched_dist_hier_per_column_iters(cross_backend_rel):
    """Per-column convergence masks do per-column work: each column's
    iteration count tracks its sequential solve (the masked loop freezes
    converged columns instead of running everyone to the max), and total
    work never exceeds nb * max(iters)."""
    batched = cross_backend_rel["_batched_iters"]
    seq = cross_backend_rel["_seq_iters"]
    assert len(batched) == len(seq) == 3
    for bi, si in zip(batched, seq):
        assert abs(bi - si) <= 2, (batched, seq)
    assert sum(batched) <= len(batched) * max(batched)


def test_spmv_coo_accepts_explicit_static_n():
    # regression: n was a traced arg under jit and crashed jnp.zeros(n)
    import jax.numpy as jnp
    from repro.sparse.spmv import spmv_coo
    rows = jnp.asarray([0, 1, 2])
    cols = jnp.asarray([0, 1, 0])
    vals = jnp.asarray([1.0, 2.0, 3.0])
    x = jnp.asarray([1.0, 1.0])
    y = spmv_coo(rows, cols, vals, x, n=3)
    np.testing.assert_allclose(np.asarray(y), [1.0, 2.0, 3.0])
