"""Operator protocol: single-device backends through the one cg_solve.

(The distributed backends go through the same interface in the
8-device subprocess of tests/test_distributed.py.)
"""
import numpy as np
import pytest

from repro.sparse import (BlockEllOperator, CooOperator, Operator,
                          cg_solve, make_operator, cg_solve_global)
from repro.sparse.generators import rdg
from repro.sparse.graph import laplacian_csr


@pytest.fixture(scope="module")
def system():
    # small instance: the interpreted Pallas kernel's grid is O(S * NNZB)
    # and a whole-CG trace multiplies it; shift=0.1 keeps the condition
    # number low enough for tight cross-backend agreement in f32
    g = rdg(300, seed=5)
    indptr, indices, data = laplacian_csr(g, shift=0.1)
    import scipy.sparse as sp
    A = sp.csr_matrix((data, indices, indptr), shape=(g.n, g.n))
    b = np.random.default_rng(1).normal(size=g.n).astype(np.float32)
    return (indptr, indices, data), A, b


def test_factory_and_protocol(system):
    (indptr, indices, data), A, b = system
    for backend in ("coo", "bell"):
        op = make_operator(indptr, indices, data, backend)
        assert isinstance(op, Operator)
        assert op.n == A.shape[0]
    assert isinstance(make_operator(indptr, indices, data, "coo"),
                      CooOperator)
    assert isinstance(make_operator(indptr, indices, data, "bell"),
                      BlockEllOperator)
    with pytest.raises(ValueError):
        make_operator(indptr, indices, data, "nope")
    with pytest.raises(ValueError):
        make_operator(indptr, indices, data, "dist_halo")   # missing part/k


@pytest.mark.parametrize("backend", ["coo", "bell"])
def test_matvec_matches_scipy(system, backend):
    (indptr, indices, data), A, b = system
    op = make_operator(indptr, indices, data, backend)
    x = np.random.default_rng(0).normal(size=op.n).astype(np.float32)
    y = op.gather(op.matvec(op.scatter(x)))
    np.testing.assert_allclose(y, A @ x, atol=1e-4, rtol=1e-4)


def test_cg_backends_agree(system):
    (indptr, indices, data), A, b = system
    sols = {}
    for backend in ("coo", "bell"):
        op = make_operator(indptr, indices, data, backend)
        x, iters, res = cg_solve_global(op, b, tol=1e-7, max_iters=2000)
        rel = np.linalg.norm(A @ x - b) / np.linalg.norm(b)
        assert rel < 1e-4, (backend, rel)
        sols[backend] = x
    scale = np.abs(sols["coo"]).max()
    assert np.abs(sols["coo"] - sols["bell"]).max() / scale < 1e-5


def test_cg_solve_accepts_operator_or_callable(system):
    (indptr, indices, data), A, b = system
    import jax.numpy as jnp
    op = make_operator(indptr, indices, data, "coo")
    r1 = cg_solve(op, jnp.asarray(b), tol=1e-6, max_iters=2000)
    r2 = cg_solve(op.matvec, jnp.asarray(b), tol=1e-6, max_iters=2000)
    np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x),
                               atol=1e-6)
    assert int(r1.iters) == int(r2.iters)


def test_spmv_coo_accepts_explicit_static_n():
    # regression: n was a traced arg under jit and crashed jnp.zeros(n)
    import jax.numpy as jnp
    from repro.sparse.spmv import spmv_coo
    rows = jnp.asarray([0, 1, 2])
    cols = jnp.asarray([0, 1, 0])
    vals = jnp.asarray([1.0, 2.0, 3.0])
    x = jnp.asarray([1.0, 1.0])
    y = spmv_coo(rows, cols, vals, x, n=3)
    np.testing.assert_allclose(np.asarray(y), [1.0, 2.0, 3.0])
