"""MoE dispatch invariants + LDHT expert-placement integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import ParamCollector
from repro.models.mlp import init_moe, moe_forward


def _setup(E=8, K=2, D=32, F=16, seed=0):
    col = ParamCollector(jax.random.PRNGKey(seed), dtype=jnp.float32)
    p, _ = init_moe(col, D, E, F)
    return p


def _dense_moe_ref(p, x, E, K):
    """Oracle: dense gating with the same renormalized top-k gates and NO
    capacity limit."""
    B, S, D = x.shape
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, K)
    gate = gate / gate.sum(-1, keepdims=True)
    y = jnp.zeros_like(x)
    for e in range(E):
        h = jax.nn.silu(x @ p["w1"][e]) * (x @ p["w3"][e])
        out_e = h @ p["w2"][e]
        w_e = jnp.where(ids == e, gate, 0.0).sum(-1)    # (B, S)
        y = y + out_e * w_e[..., None]
    return y


def test_moe_matches_dense_reference_when_capacity_ample():
    E, K, D = 8, 2, 32
    p = _setup(E, K, D)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, D)) * 0.5
    y, aux = moe_forward(p, x, n_experts=E, top_k=K, capacity_factor=8.0)
    y_ref = _dense_moe_ref(p, x, E, K)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_overflow():
    """With capacity ~0, output ~0 (all tokens dropped)."""
    E, K, D = 4, 2, 16
    p = _setup(E, K, D)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, D))
    y_lo, _ = moe_forward(p, x, n_experts=E, top_k=K,
                          capacity_factor=0.01)
    y_hi, _ = moe_forward(p, x, n_experts=E, top_k=K, capacity_factor=8.0)
    assert float(jnp.abs(y_lo).sum()) < float(jnp.abs(y_hi).sum())


def test_moe_grad_flows():
    E, K, D = 4, 2, 16
    p = _setup(E, K, D)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, D))

    def loss(p):
        y, aux = moe_forward(p, x, n_experts=E, top_k=K,
                             capacity_factor=4.0)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    norms = [float(jnp.abs(v).max()) for v in jax.tree.leaves(g)]
    assert max(norms) > 0
    assert all(np.isfinite(n) for n in norms)


def test_expert_perm_is_relabeling():
    """LDHT expert placement: permuting experts+weights leaves output
    invariant."""
    E, K, D = 4, 2, 16
    p = _setup(E, K, D)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, D))
    y0, _ = moe_forward(p, x, n_experts=E, top_k=K, capacity_factor=8.0)
    perm = jnp.asarray([2, 0, 3, 1])
    p2 = dict(p)
    for k in ("w1", "w2", "w3"):
        p2[k] = p[k][jnp.argsort(perm)][perm][perm.argsort()][perm] * 0 + \
            p[k]  # placeholder to keep shapes; real check below
    # permute expert weights to positions given by perm, route with perm
    p3 = dict(p)
    inv = jnp.argsort(perm)
    for k in ("w1", "w2", "w3"):
        p3[k] = p[k][inv]
    y1, _ = moe_forward(p3, x, n_experts=E, top_k=K, capacity_factor=8.0,
                        expert_perm=perm)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)


def test_expert_placement_partitioner():
    """Expert co-activation graph partitioned under heterogeneous HBM caps:
    placement respects memory and balances load."""
    from repro.core import PU, Topology, target_block_sizes
    from repro.core.api import _greedy_growing
    from repro.sparse.graph import from_edges
    rng = np.random.default_rng(0)
    E = 16
    # co-activation graph: experts that fire together, weighted edges
    src, dst = np.triu_indices(E, k=1)
    keep = rng.random(len(src)) < 0.3
    g = from_edges(E, src[keep], dst[keep], symmetrize=True)
    topo = Topology((PU(2, 6), PU(1, 6), PU(1, 6)))
    tw = target_block_sizes(E, topo, integral=True)
    part = _greedy_growing(g, tw, seed=0)
    sizes = np.bincount(part, minlength=3)
    assert sizes.sum() == E
    assert np.all(sizes <= topo.memories)
    assert sizes[0] >= sizes[1]              # fast PU hosts more experts
