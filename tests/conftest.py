"""Shared pytest configuration.

Graceful degradation when optional dev dependencies are missing: test
modules that import ``hypothesis`` are excluded from collection (instead
of erroring the whole run) when the package is not installed.  Install
dev deps with ``pip install -r requirements-dev.txt`` (or ``make deps``)
to run the property-based suites too.

On CI (``CI`` set, as GitHub Actions does) the escape hatch is a hard
error instead: the property-based modules must actually execute there,
never silently skip.

The whole suite also runs with build-time plan verification on
(``REPRO_VALIDATE=1`` unless the caller already set it): every plan any
test builds goes through the ``repro.analysis`` structural verifier, so
the existing test matrix doubles as the verifier's clean corpus.
"""
import importlib.util
import os
import pathlib
import re
import warnings

os.environ.setdefault("REPRO_VALIDATE", "1")

collect_ignore = []

_IMPORTS_HYPOTHESIS = re.compile(r"^\s*(from|import)\s+hypothesis\b", re.M)

if importlib.util.find_spec("hypothesis") is None:
    _here = pathlib.Path(__file__).parent
    collect_ignore = sorted(
        p.name for p in _here.glob("test_*.py")
        if _IMPORTS_HYPOTHESIS.search(p.read_text(encoding="utf-8")))
    if collect_ignore:
        if os.environ.get("CI"):
            raise RuntimeError(
                "hypothesis is not installed but CI must run the "
                f"property-based modules ({', '.join(collect_ignore)}); "
                "pip install -r requirements-dev.txt")
        warnings.warn(
            "hypothesis is not installed; skipping property-based test "
            f"modules: {', '.join(collect_ignore)} "
            "(pip install -r requirements-dev.txt)")
