"""Expert-parallel shard_map MoE vs the dense-dispatch oracle — run in a
subprocess with 8 forced host devices (main pytest process keeps 1 device)."""
import json
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.compat import use_mesh
    from repro.models.common import ParamCollector
    from repro.models.mlp import init_moe, moe_forward

    B, S, D, E, K, F = 4, 16, 32, 8, 2, 64
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    col = ParamCollector(jax.random.PRNGKey(0), dtype=jnp.float32)
    p, _ = init_moe(col, D, E, F)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32)

    def loss(p, x, impl):
        y, a = moe_forward(p, x, n_experts=E, top_k=K,
                           capacity_factor=1.25, impl=impl)
        return jnp.sum(y ** 2) + 0.01 * a

    out = {}
    with use_mesh(mesh):
        y_d, a_d = jax.jit(lambda p, x: moe_forward(
            p, x, n_experts=E, top_k=K, capacity_factor=1.25,
            impl="dense"))(p, x)
        y_s, a_s = jax.jit(lambda p, x: moe_forward(
            p, x, n_experts=E, top_k=K, capacity_factor=1.25,
            impl="shard_map"))(p, x)
        out["y_maxdiff"] = float(jnp.abs(y_d - y_s).max())
        out["aux_diff"] = float(jnp.abs(a_d - a_s))
        g_d = jax.jit(jax.grad(loss), static_argnums=2)(p, x, "dense")
        g_s = jax.jit(jax.grad(loss), static_argnums=2)(p, x, "shard_map")
        out["grad_maxdiff"] = max(
            float(jnp.abs(g_d[k] - g_s[k]).max()) for k in g_d)

        # seq-sharded combine path (psum_scatter)
        y_sp, _ = jax.jit(lambda p, x: moe_forward(
            p, x, n_experts=E, top_k=K, capacity_factor=1.25,
            impl="shard_map", seq_sharded=True))(p, x)
        out["y_sp_maxdiff"] = float(jnp.abs(y_d - y_sp).max())
    print(json.dumps(out))
""")


def test_shard_map_matches_dense_oracle():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["y_maxdiff"] < 1e-5
    assert out["aux_diff"] < 1e-6
    assert out["grad_maxdiff"] < 5e-3
    assert out["y_sp_maxdiff"] < 1e-5
