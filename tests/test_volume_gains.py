"""Property-based suite for the bottleneck objective layer (ISSUE 9).

Invariants:
  * ``VolumeGainTracker`` stays *exactly* (int64) consistent with a
    from-scratch ``metrics.tree_comm_volumes`` recompute after every
    applied FM move — the net-degree counters, the per-level volume
    table, and the sizes all match, and ``apply`` is its own inverse;
  * ``peek``/``peek_key`` restore all state bit-for-bit;
  * ``bottleneck_objective`` agrees with a brute-force dense NumPy
    oracle (per-PU compute + per-level dedup halo, max over PUs);
  * bottleneck-mode ``refine_partition`` never increases the bottleneck
    objective and respects the caps.

Each property lives in a plain ``check_*`` function with the hypothesis
test as a thin wrapper, so the invariants can also be driven directly
(no hypothesis) when debugging.  Host-only NumPy — runs unskipped in
both CI matrix jobs.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import canonical_ancestors
from repro.core.metrics import (bottleneck_objective, per_pu_model_costs,
                                tree_comm_volumes)
from repro.core.refinement import VolumeGainTracker, refine_partition
from repro.core.topology import level_matrix
from repro.sparse.graph import from_edges

# (k, fanouts-or-None): flat, two-level, and depth-3 machines
MACHINES = [(4, None), (4, (2, 2)), (6, (3, 2)), (8, (2, 2, 2))]


def random_instance(seed: int, k: int, fanouts):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 48))
    m = int(rng.integers(n, 4 * n))
    g = from_edges(n, rng.integers(0, n, m), rng.integers(0, n, m),
                   symmetrize=True)
    part = rng.integers(0, k, n).astype(np.int32)
    anc = None if fanouts is None else canonical_ancestors(fanouts)
    h = 1 if anc is None else anc.shape[0] + 1
    lams = tuple(float(x) for x in rng.uniform(0.5, 8.0, h))
    speeds = rng.uniform(0.5, 4.0, k)
    c_comp = float(rng.uniform(0.0, 4.0))
    return g, part, anc, lams, speeds, c_comp


def tracker_anc(anc, k):
    return (np.zeros((0, k), dtype=np.int64) if anc is None else anc)


def assert_tracker_consistent(t, g, part, k, anc):
    """Tracker state == from-scratch recompute (exact int64)."""
    np.testing.assert_array_equal(
        t.vols, tree_comm_volumes(g, part, k, tracker_anc(anc, k)))
    src, dst, _ = g.edge_list()
    cnt = np.zeros((k, g.n), dtype=np.int32)
    np.add.at(cnt, (part[src], dst), 1)
    np.testing.assert_array_equal(t.nbr_cnt, cnt)
    np.testing.assert_array_equal(t.sizes, np.bincount(part, minlength=k))


def check_tracker_matches_recompute(seed, k, fanouts, moves=30):
    g, part, anc, lams, speeds, c_comp = random_instance(seed, k, fanouts)
    t = VolumeGainTracker(g, part, k, anc=anc, lams=lams, speeds=speeds,
                          c_comp=c_comp)
    assert t.part is part                    # shared, mutated in place
    rng = np.random.default_rng(seed + 1)
    for _ in range(moves):
        v = int(rng.integers(0, g.n))
        to = int(rng.integers(0, k))
        t.apply(v, to)
        assert_tracker_consistent(t, g, part, k, anc)
        pp = per_pu_model_costs(g, part, tracker_anc(anc, k), lams=lams,
                                speeds=speeds, c_comp=c_comp)
        np.testing.assert_allclose(t.totals(), pp["total"])
        assert t.bottleneck() == pytest.approx(
            bottleneck_objective(g, part, tracker_anc(anc, k), lams=lams,
                                 speeds=speeds, c_comp=c_comp))
        assert t.critical_pu() == int(np.argmax(pp["total"]))


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from(MACHINES))
def test_tracker_matches_recompute_after_every_move(seed, machine):
    check_tracker_matches_recompute(seed, *machine)


def check_apply_inverse_and_peek_restores(seed, k, fanouts):
    g, part, anc, lams, speeds, c_comp = random_instance(seed, k, fanouts)
    t = VolumeGainTracker(g, part, k, anc=anc, lams=lams, speeds=speeds,
                          c_comp=c_comp)
    snap = (t.vols.copy(), t.nbr_cnt.copy(), t.sizes.copy(), part.copy())
    rng = np.random.default_rng(seed + 2)
    for _ in range(10):
        v = int(rng.integers(0, g.n))
        to = int(rng.integers(0, k))
        frm = int(part[v])
        # peek == bottleneck-after-apply, and restores everything
        t.apply(v, to)
        want = t.bottleneck()
        want_key = t.totals_key()
        t.apply(v, frm)                      # apply is its own inverse
        assert t.peek(v, to) == want
        assert t.peek_key(v, to) == want_key
        assert want_key[0] == pytest.approx(want)
        assert want_key == tuple(sorted(want_key, reverse=True))
    vols, cnt, sizes, p0 = snap
    np.testing.assert_array_equal(t.vols, vols)
    np.testing.assert_array_equal(t.nbr_cnt, cnt)
    np.testing.assert_array_equal(t.sizes, sizes)
    np.testing.assert_array_equal(part, p0)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from(MACHINES))
def test_apply_inverse_and_peek_restores(seed, machine):
    check_apply_inverse_and_peek_restores(seed, *machine)


def oracle_bottleneck(g, part, anc, lams, speeds, c_comp, k):
    """Brute-force per-PU makespan: loops over (block, vertex) pairs."""
    lev = np.maximum(level_matrix(tracker_anc(anc, k)), 0)
    totals = np.zeros(k)
    for b in range(k):
        comm = 0.0
        for v in range(g.n):
            if part[v] == b:
                continue
            nb = g.indices[g.indptr[v]:g.indptr[v + 1]]
            if len(nb) and np.any(part[nb] == b):
                comm += lams[lev[b, part[v]]]
        totals[b] = c_comp * np.sum(part == b) / speeds[b] + comm
    return totals.max(initial=0.0)


def check_bottleneck_matches_dense_oracle(seed, k, fanouts):
    g, part, anc, lams, speeds, c_comp = random_instance(seed, k, fanouts)
    got = bottleneck_objective(g, part, tracker_anc(anc, k), lams=lams,
                               speeds=speeds, c_comp=c_comp)
    want = oracle_bottleneck(g, part, anc, lams, speeds, c_comp, k)
    assert got == pytest.approx(want)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from(MACHINES))
def test_bottleneck_matches_dense_oracle(seed, machine):
    check_bottleneck_matches_dense_oracle(seed, *machine)


def check_bottleneck_refine_never_worse(seed, k, fanouts):
    g, part, anc, lams, speeds, c_comp = random_instance(seed, k, fanouts)
    sizes = np.bincount(part, minlength=k)
    tw = np.maximum(sizes, 1).astype(np.float64)     # initially feasible
    a = tracker_anc(anc, k)
    before = bottleneck_objective(g, part, a, lams=lams, speeds=speeds,
                                  c_comp=c_comp)
    out = refine_partition(g, part, tw, eps=0.3, anc=anc, lams=lams,
                           objective="bottleneck", speeds=speeds,
                           c_comp=c_comp)
    after = bottleneck_objective(g, out, a, lams=lams, speeds=speeds,
                                 c_comp=c_comp)
    assert after <= before + 1e-9
    caps = np.ceil(tw * 1.3)
    assert (np.bincount(out, minlength=k) <= caps).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from(MACHINES))
def test_bottleneck_refine_never_worse(seed, machine):
    check_bottleneck_refine_never_worse(seed, *machine)
