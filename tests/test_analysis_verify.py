"""Deterministic mutation suite for the ``repro.analysis`` plan verifier.

Every corruption class from the ISSUE acceptance list gets a seeded
instance: a valid plan is built, one field is corrupted, and the verifier
must name the violated invariant (by diagnostic code).  Clean plans of
every builder must verify with zero diagnostics — the suite-wide
``REPRO_VALIDATE=1`` (conftest) already re-checks every other test's
plans at build time.
"""
import dataclasses

import numpy as np
import pytest

from repro.analysis import (PlanVerificationError, check_mesh_axes,
                            partner_table, verify_partition, verify_plan)
from repro.sparse.distributed import (build_plan, build_plan_hier,
                                      build_plan_reference, build_plan_tree)
from repro.sparse.generators import grid
from repro.sparse.graph import laplacian_csr


@pytest.fixture(scope="module")
def system():
    g = grid((12, 12))
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    part = np.random.default_rng(3).integers(0, 8, g.n).astype(np.int64)
    return indptr, indices, data, part


@pytest.fixture(scope="module")
def tree_plan(system):
    indptr, indices, data, part = system
    return build_plan_tree(indptr, indices, data, part, None, 8,
                           fanouts=(2, 2, 2), validate=False)


@pytest.fixture(scope="module")
def flat_plan(system):
    indptr, indices, data, part = system
    return build_plan(indptr, indices, data, part, 8, validate=False)


def test_clean_plans_verify(system, tree_plan, flat_plan):
    indptr, indices, data, part = system
    for plan in (flat_plan, tree_plan,
                 build_plan_reference(indptr, indices, data, part, 8),
                 build_plan_hier(indptr, indices, data, part, 2, 8,
                                 validate=False)):
        rep = verify_plan(plan)
        assert rep.ok, str(rep)


def test_raise_for_errors_carries_report(flat_plan):
    bad = dataclasses.replace(flat_plan, n=flat_plan.n + 1)
    rep = verify_plan(bad)
    assert "PLAN001" in rep.codes()
    with pytest.raises(PlanVerificationError) as ei:
        rep.raise_for_errors()
    assert ei.value.report is rep
    assert isinstance(ei.value, ValueError)      # historical contract


def test_perm_corruption_is_plan001(flat_plan):
    perm = np.asarray(flat_plan.perm).copy()
    perm[0] = perm[1]                            # no longer injective
    rep = verify_plan(dataclasses.replace(flat_plan, perm=perm))
    assert "PLAN001" in rep.codes()


def test_dropped_level_is_plan002(tree_plan):
    rep = verify_plan(dataclasses.replace(tree_plan,
                                          S_lvl=tree_plan.S_lvl[:-1]))
    assert "PLAN002" in rep.codes()


def test_grown_slot_width_is_plan002(tree_plan):
    s = list(tree_plan.S_lvl)
    s[-1] += 1                                   # arrays no longer match
    rep = verify_plan(dataclasses.replace(tree_plan, S_lvl=tuple(s)))
    assert "PLAN002" in rep.codes()


def _level_with_rounds(plan, r_min=2):
    for l in range(plan.h):
        if plan.n_rounds_lvl[l] >= r_min:
            return l
    pytest.skip(f"no level with >= {r_min} rounds in this instance")


def test_merged_colors_are_plan003_or_plan004(tree_plan):
    l = _level_with_rounds(tree_plan)
    perms = [list(r) for r in tree_plan.round_perms_lvl[l]]
    # put round 1's pairs into round 0: some node now talks twice in one
    # round — flagged as an improper coloring (PLAN003) or, equivalently,
    # as a broken permutation (PLAN004: the node is a duplicate src/dst)
    merged = perms[0] + perms[1]
    nodes = [p for pr in perms[0] for p in pr]
    assert any(p in nodes for pr in perms[1] for p in pr)
    new_lvl = list(tree_plan.round_perms_lvl)
    new_lvl[l] = tuple([tuple(merged)] + [tuple(r) for r in perms[1:]])
    rep = verify_plan(dataclasses.replace(
        tree_plan, round_perms_lvl=tuple(new_lvl)))
    assert rep.codes() & {"PLAN003", "PLAN004"}


def test_cycle_round_is_plan003(flat_plan):
    # a directed 3-cycle is a true permutation (each node one src, one
    # dst) but NOT a matching: only the proper-coloring check catches it
    perms = [list(r) for r in flat_plan.round_perms]
    c = next(i for i, r in enumerate(perms) if r)
    perms[c] = [(0, 1), (1, 2), (2, 0)]
    rep = verify_plan(dataclasses.replace(
        flat_plan, round_perms=tuple(tuple(r) for r in perms)))
    assert "PLAN003" in rep.codes()


def test_one_directional_pair_is_plan003(flat_plan):
    perms = [list(r) for r in flat_plan.round_perms]
    c = next(i for i, r in enumerate(perms) if r)
    perms[c] = perms[c][:-1]                     # drop one direction
    rep = verify_plan(dataclasses.replace(
        flat_plan, round_perms=tuple(tuple(r) for r in perms)))
    assert "PLAN003" in rep.codes()


def test_duplicate_destination_is_plan004_and_races_plan006(flat_plan):
    perms = [list(r) for r in flat_plan.round_perms]
    c = next(i for i, r in enumerate(perms) if r)
    a, b = perms[c][0]
    perms[c] = perms[c] + [(a, b)]               # same src AND same dst
    rep = verify_plan(dataclasses.replace(
        flat_plan, round_perms=tuple(tuple(r) for r in perms)))
    assert "PLAN004" in rep.codes()


def test_permuted_rounds_are_plan009(flat_plan):
    # swap two round permutations while keeping the send schedule: every
    # slot is still written exactly once, but holds the wrong vertex
    perms = [list(r) for r in flat_plan.round_perms]
    full = [i for i, r in enumerate(perms) if r]
    assert len(full) >= 2
    i, j = full[0], full[1]
    assert set(perms[i]) != set(perms[j])
    perms[i], perms[j] = perms[j], perms[i]
    rep = verify_plan(dataclasses.replace(
        flat_plan, round_perms=tuple(tuple(r) for r in perms)))
    assert not rep.ok
    assert rep.codes() & {"PLAN009", "PLAN006", "PLAN007"}


def test_ghost_row_send_is_plan005(tree_plan):
    sizes = np.asarray(tree_plan.sizes)
    for l in range(tree_plan.h):
        mask = np.asarray(tree_plan.send_mask_lvl[l])
        live = np.argwhere(mask > 0)
        if len(live):
            b, c, s = live[0]
            idx = np.asarray(tree_plan.send_idx_lvl[l]).copy()
            idx[b, c, s] = sizes[b]              # first ghost row
            si = list(tree_plan.send_idx_lvl)
            si[l] = idx
            rep = verify_plan(dataclasses.replace(
                tree_plan, send_idx_lvl=tuple(si)))
            assert "PLAN005" in rep.codes()
            return
    pytest.skip("no live send entries")


def test_aliased_slot_is_plan009(flat_plan):
    cols = np.asarray(flat_plan.cols).copy()
    nnz = np.asarray(flat_plan.nnz_blk)
    B = flat_plan.B
    for b in range(flat_plan.k):
        ext = np.flatnonzero(cols[b, :nnz[b]] >= B)
        two = np.unique(cols[b, ext])
        if len(two) >= 2:
            # point one boundary edge at another (written) slot
            e = ext[cols[b, ext] == two[0]][0]
            cols[b, e] = two[1]
            rep = verify_plan(dataclasses.replace(flat_plan, cols=cols))
            assert "PLAN009" in rep.codes()
            return
    pytest.skip("no block reads two distinct halo slots")


def test_unwritten_slot_read_is_plan007(flat_plan):
    cols = np.asarray(flat_plan.cols).copy()
    nnz = np.asarray(flat_plan.nnz_blk)
    ext_len = flat_plan.B + flat_plan.n_rounds * flat_plan.S
    b = int(np.argmax(nnz))
    cols[b, 0] = ext_len - 1                     # last slot of last round
    # ensure it's genuinely unwritten for this block: pad rounds exist
    # whenever some pair has fewer halo words than S
    from repro.analysis.verify import _level_offsets, _levels_of, _replay
    from repro.analysis.diagnostics import Report
    r = Report(subject="probe")
    levels = _levels_of(flat_plan, r)
    _, writes = _replay(flat_plan, levels, _level_offsets(flat_plan, levels),
                        r)
    if writes[b, ext_len - 1] != 0:
        pytest.skip("every slot of this block is written")
    rep = verify_plan(dataclasses.replace(flat_plan, cols=cols))
    assert "PLAN007" in rep.codes()


def test_segment_ordering_violation_is_plan007(tree_plan):
    offs = tree_plan.level_offsets()
    vals0 = np.asarray(tree_plan.vals_bnd_lvl[0])
    live = np.argwhere(vals0 != 0)
    if not len(live):
        pytest.skip("level 0 has no boundary edges")
    b, e = live[0]
    cols0 = np.asarray(tree_plan.cols_bnd_lvl[0]).copy()
    cols0[b, e] = offs[-1] - 1                   # slower level's slot range
    cb = list(tree_plan.cols_bnd_lvl)
    cb[0] = cols0
    rep = verify_plan(dataclasses.replace(tree_plan,
                                          cols_bnd_lvl=tuple(cb)))
    assert "PLAN007" in rep.codes()


def test_segment_multiset_mismatch_is_plan008(tree_plan):
    for l in range(tree_plan.h):
        vals = np.asarray(tree_plan.vals_bnd_lvl[l])
        live = np.argwhere(vals != 0)
        if len(live):
            b, e = live[0]
            v = vals.copy()
            v[b, e] += 1.0                       # value no longer matches
            vb = list(tree_plan.vals_bnd_lvl)
            vb[l] = v
            rep = verify_plan(dataclasses.replace(
                tree_plan, vals_bnd_lvl=tuple(vb)))
            assert "PLAN008" in rep.codes()
            return
    pytest.skip("no boundary edges at any level")


def test_interior_mask_corruption_is_plan008(flat_plan):
    m = np.asarray(flat_plan.interior_mask).copy()
    m[0, 0] = 1.0 - m[0, 0]
    rep = verify_plan(dataclasses.replace(flat_plan, interior_mask=m))
    assert "PLAN008" in rep.codes()


# ---- mesh/axis checker ----------------------------------------------------

def test_mesh_axes_clean_and_partner_table(tree_plan):
    rep = check_mesh_axes(tree_plan, {"pod": 2, "host": 2, "pu": 2},
                          ("pod", "host", "pu"))
    assert rep.ok, str(rep)
    table = rep.info["partner_table"]
    assert set(table) == set(range(tree_plan.h))
    k = tree_plan.k
    for l, rounds in table.items():
        assert len(rounds) == tree_plan.n_rounds_lvl[l]
        for pairs in rounds:
            assert all(0 <= a < k and 0 <= b < k for a, b in pairs)
            # device-level delivery is still a permutation
            dsts = [b for _, b in pairs]
            assert len(set(dsts)) == len(dsts)


def test_mesh_axes_shape_mismatch_is_mesh002(tree_plan):
    rep = check_mesh_axes(tree_plan, {"pod": 1, "host": 2, "pu": 4},
                          ("pod", "host", "pu"))
    assert "MESH002" in rep.codes()


def test_mesh_axes_unknown_axis_is_mesh001(tree_plan):
    rep = check_mesh_axes(tree_plan, {"pod": 2, "pu": 2}, ("pod", "nope"))
    assert "MESH001" in rep.codes()


def test_mesh_axes_flat_span_is_mesh003(flat_plan):
    assert check_mesh_axes(flat_plan, {"data": 8}, ("data",)).ok
    rep = check_mesh_axes(flat_plan, {"data": 4}, ("data",))
    assert "MESH003" in rep.codes()


def test_mesh_axes_too_few_axes_is_mesh004(tree_plan):
    rep = check_mesh_axes(tree_plan, {"pod": 2, "pu": 4}, ("pod", "pu"))
    assert rep.codes() <= {"MESH002", "MESH004"} and not rep.ok


def test_partner_table_flat_matches_round_perms(flat_plan):
    table = partner_table(flat_plan)
    assert set(table) == {0}
    for c, pairs in enumerate(table[0]):
        assert sorted(pairs) == sorted(flat_plan.round_perms[c])


# ---- partition verifier ---------------------------------------------------

def test_partition_verifies_clean_and_catches_broken_nesting():
    from repro.core.api import partition_tree
    from repro.core.topology import Topology
    g = grid((12, 12))
    topo = Topology.homogeneous(8, memory=2.0 * g.n / 8,
                                fanouts=(2, 2, 2))
    res = partition_tree(g, topo, fanouts=(2, 2, 2), validate=True)
    assert verify_partition(res, g.n).ok
    bad_anc = res.anc.copy()
    bad_anc[0, 0] = 1 - bad_anc[0, 0]            # unequal / broken nesting
    bad = dataclasses.replace(res, anc=bad_anc)
    rep = verify_partition(bad, g.n)
    assert "PART002" in rep.codes()
    part = res.part.copy()
    part[0] = 8                                  # out of range
    rep = verify_partition(dataclasses.replace(res, part=part), g.n)
    assert "PART001" in rep.codes()
