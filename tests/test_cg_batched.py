"""Hypothesis property tests for the multi-RHS batched CG path.

On random sparse SPD systems (varying n, density, shift) with random RHS
batches that mix hard columns, zero columns and "easy" columns (b = A e_i,
converging in a handful of iterations — so per-column convergence happens
at genuinely different iteration counts):

  * the batched masked loop matches per-column sequential ``cg_solve`` to
    < 1e-5 — plain and Jacobi-preconditioned, through the batch-native
    operator path *and* the vmapped bare-callable path;
  * per-column ``iters`` track the sequential counts (converged columns
    freeze instead of riding along to the slowest column's count), so a
    zero column always reports 0 iterations.
"""
import numpy as np
import scipy.sparse as sp
from hypothesis import given, settings, strategies as st

from repro.sparse import CooOperator, cg_solve


@st.composite
def spd_batch(draw):
    """Random sparse SPD system + mixed-difficulty RHS batch."""
    n = draw(st.integers(min_value=3, max_value=32))
    nb = draw(st.integers(min_value=1, max_value=5))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    density = draw(st.floats(min_value=0.05, max_value=0.4))
    shift = draw(st.floats(min_value=0.1, max_value=2.0))
    rng = np.random.default_rng(seed)
    m = max(int(round(density * n * n)), 1)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    vals = rng.uniform(0.1, 1.0, size=m)
    G = sp.csr_matrix((vals, (src, dst)), shape=(n, n))
    A = (G.T @ G + shift * sp.eye(n)).tocsr()     # SPD by construction
    A.sum_duplicates()
    cols = [rng.normal(size=n)]
    for _ in range(nb - 1):
        kind = draw(st.sampled_from(["random", "zero", "easy"]))
        if kind == "zero":
            cols.append(np.zeros(n))
        elif kind == "easy":
            e = np.zeros(n)
            e[int(rng.integers(0, n))] = 1.0
            cols.append(A @ e)        # exact solution e_i: converges fast
        else:
            cols.append(rng.normal(size=n))
    b = np.stack(cols, axis=1).astype(np.float32)
    return (A.indptr, A.indices, A.data.astype(np.float32)), b


@settings(max_examples=25, deadline=None)
@given(spd_batch(), st.sampled_from([None, "jacobi"]))
def test_batched_matches_per_column_sequential(sys_b, precondition):
    (indptr, indices, data), b = sys_b
    op = CooOperator.from_csr(indptr, indices, data)
    res = cg_solve(op, op.scatter(b), tol=1e-6, max_iters=400,
                   precondition=precondition, batched=True)
    xb = np.asarray(res.x)
    itb = np.asarray(res.iters)
    assert xb.shape == b.shape
    assert itb.shape == (b.shape[1],)
    for j in range(b.shape[1]):
        r = cg_solve(op, op.scatter(b[:, j]), tol=1e-6, max_iters=400,
                     precondition=precondition)
        xs = np.asarray(r.x)
        scale = max(float(np.abs(xs).max()), 1.0)
        assert np.abs(xb[:, j] - xs).max() / scale < 1e-5, j
        # converged columns freeze: each column's count tracks its own
        # sequential solve, not the batch straggler's
        assert abs(int(itb[j]) - int(r.iters)) <= 2, (j, itb, int(r.iters))
        if not np.any(b[:, j]):
            assert int(itb[j]) == 0


@settings(max_examples=10, deadline=None)
@given(spd_batch())
def test_batched_vmapped_callable_matches_batch_native(sys_b):
    """A bare matvec callable without ``batch_native`` goes through the
    vmap fallback — it must produce the same solve as the batch-native
    operator path."""
    (indptr, indices, data), b = sys_b
    op = CooOperator.from_csr(indptr, indices, data)
    native = cg_solve(op, op.scatter(b), tol=1e-6, max_iters=400,
                      batched=True)
    mv = lambda x: op.matvec(x)          # plain callable: vmapped per column
    vmapped = cg_solve(mv, op.scatter(b), tol=1e-6, max_iters=400,
                       batched=True)
    scale = max(float(np.abs(np.asarray(native.x)).max()), 1.0)
    assert (np.abs(np.asarray(native.x) - np.asarray(vmapped.x)).max()
            / scale) < 1e-5
    np.testing.assert_array_equal(np.asarray(native.iters),
                                  np.asarray(vmapped.iters))
