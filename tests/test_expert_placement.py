"""LDHT expert placement: Eq.2 balance under the exact slot constraint,
heterogeneous speeds, co-activation cut reduction, and end-to-end routing
equivalence through moe_forward(expert_perm)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.expert_placement import (PlacementResult, coactivation_graph,
                                         expert_loads, place_experts,
                                         permute_expert_params)
from repro.core.topology import Topology


def _topo(ep, fast=0, speed=4.0):
    from repro.core.topology import PU
    pus = [PU(speed=speed if i < fast else 1.0, memory=1e9)
           for i in range(ep)]
    return Topology(pus=pus)


class TestPlacement:
    def test_perm_is_permutation(self):
        rng = np.random.default_rng(0)
        loads = expert_loads(rng.integers(1, 100, size=64))
        r = place_experts(loads, _topo(16))
        assert sorted(r.perm.tolist()) == list(range(64))

    def test_exact_slot_counts(self):
        rng = np.random.default_rng(1)
        loads = expert_loads(rng.integers(1, 100, size=32))
        r = place_experts(loads, _topo(8))
        counts = np.bincount(r.rank_of, minlength=8)
        assert (counts == 4).all()

    def test_slots_match_ranks(self):
        rng = np.random.default_rng(2)
        loads = expert_loads(rng.integers(1, 100, size=32))
        r = place_experts(loads, _topo(8))
        E_loc = 32 // 8
        for e in range(32):
            assert r.perm[e] // E_loc == r.rank_of[e]

    def test_balances_hot_experts(self):
        # two hot experts must land on different ranks
        loads = np.array([0.4, 0.4] + [0.2 / 14] * 14)
        r = place_experts(loads, _topo(2))
        assert r.rank_of[0] != r.rank_of[1]
        assert r.max_load_ratio < 0.8       # not both on one rank

    def test_hetero_speed_gets_more_load(self):
        rng = np.random.default_rng(3)
        loads = expert_loads(rng.uniform(1, 2, size=64))
        topo = _topo(4, fast=1, speed=3.0)
        r = place_experts(loads, topo)
        # fast rank should carry the largest share
        assert np.argmax(r.load_per_rank) == 0
        # and the ratio should beat the uniform assignment's worst case
        uniform = loads.reshape(4, 16).sum(1)
        assert r.max_load_ratio <= (uniform / topo.speeds).max() + 1e-12

    def test_coactivation_reduces_cut(self):
        # experts 2i and 2i+1 always co-fire -> should co-locate
        E, ep = 16, 4
        ids = np.array([[2 * i, 2 * i + 1] for i in range(8)] * 50)
        W = coactivation_graph(ids, E)
        loads = expert_loads(np.ones(E))
        r_with = place_experts(loads, _topo(ep), coact=W)
        r_wo = place_experts(loads, _topo(ep), coact=None)
        cut_wo = float(W[r_wo.rank_of[:, None] != r_wo.rank_of[None, :]]
                       .sum())
        assert r_with.coact_cut <= cut_wo + 1e-9

    @given(st.integers(2, 8), st.integers(0, 6))
    @settings(max_examples=20, deadline=None)
    def test_property_valid_placement(self, ep, seed):
        E = ep * 4
        rng = np.random.default_rng(seed)
        loads = expert_loads(rng.uniform(0.1, 10.0, size=E))
        r = place_experts(loads, _topo(ep))
        assert sorted(r.perm.tolist()) == list(range(E))
        assert (np.bincount(r.rank_of, minlength=ep) == 4).all()
        # Eq.2 sanity: never worse than putting everything on one rank
        assert r.max_load_ratio <= loads.sum() + 1e-9
        # load accounting
        for j in range(ep):
            np.testing.assert_allclose(
                r.load_per_rank[j], loads[r.rank_of == j].sum(), atol=1e-12)


class TestMoEIntegration:
    def test_perm_routing_equivalence(self):
        """moe_forward with (permuted weights, expert_perm) must equal the
        unpermuted model — placement is numerics-neutral."""
        import jax
        import jax.numpy as jnp
        from repro.models.common import ParamCollector
        from repro.models.mlp import init_moe, moe_forward

        B, S, D, E, K, F = 2, 8, 16, 8, 2, 32
        col = ParamCollector(jax.random.PRNGKey(0), dtype=jnp.float32)
        p, _ = init_moe(col, D, E, F)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32)
        y0, a0 = moe_forward(p, x, n_experts=E, top_k=K, impl="dense")

        rng = np.random.default_rng(7)
        loads = expert_loads(rng.uniform(1, 5, size=E))
        r = place_experts(loads, _topo(4))
        p2 = dict(p)
        p2.update(permute_expert_params(
            {k: p[k] for k in ("w1", "w2", "w3")}, r.perm))
        y1, a1 = moe_forward(p2, x, n_experts=E, top_k=K, impl="dense",
                             expert_perm=jnp.asarray(r.perm))
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   atol=1e-5)

    def test_perm_travels_in_param_tree(self):
        """permute_expert_params embeds 'perm'; moe_forward must pick it up
        without the caller passing expert_perm (train/serve paths)."""
        import jax
        import jax.numpy as jnp
        from repro.models.common import ParamCollector
        from repro.models.mlp import init_moe, moe_forward

        B, S, D, E, K, F = 2, 8, 16, 8, 2, 32
        col = ParamCollector(jax.random.PRNGKey(2), dtype=jnp.float32)
        p, _ = init_moe(col, D, E, F)
        x = jax.random.normal(jax.random.PRNGKey(3), (B, S, D), jnp.float32)
        y0, _ = moe_forward(p, x, n_experts=E, top_k=K, impl="dense")

        rng = np.random.default_rng(11)
        r = place_experts(expert_loads(rng.uniform(1, 5, size=E)), _topo(4))
        p2 = dict(p)
        p2.update(permute_expert_params(
            {k: p[k] for k in ("w1", "w2", "w3")}, r.perm))
        y1, _ = moe_forward(p2, x, n_experts=E, top_k=K, impl="dense")
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   atol=1e-5)
