"""AST lint rule tests (ISSUE 6 tentpole, lint half).

Each rule gets a tmp_path offender file that must be flagged with the
right rule ID and line, plus a negative twin that must stay clean; the
final test lints the real ``src/`` tree and requires zero findings —
the satellite-1 migration contract (all sharding imports flow through
``compat.py``, which is the single allowlisted file).
"""
import textwrap
from pathlib import Path

import pytest

from repro.analysis.lint import LINT_RULES, lint_paths

SRC = Path(__file__).resolve().parents[1] / "src"


def _lint_snippet(tmp_path, code, rel="mod.py"):
    f = tmp_path / rel
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(textwrap.dedent(code))
    return lint_paths([f], root=tmp_path)


def _where(d):
    path, _, line = d.where.rpartition(":")
    return path, int(line)


def _codes_lines(rep):
    return {(d.code, _where(d)[1]) for d in rep.diagnostics}


def test_rule_table_is_complete():
    assert set(LINT_RULES) == {"REPRO001", "REPRO002", "REPRO003",
                               "REPRO004"}
    for code, desc in LINT_RULES.items():
        assert desc and code.startswith("REPRO")


# ---------------------------------------------------------------- REPRO001

def test_repro001_from_import(tmp_path):
    rep = _lint_snippet(tmp_path, """\
        from jax.sharding import Mesh, PartitionSpec
    """)
    assert ("REPRO001", 1) in _codes_lines(rep)


def test_repro001_plain_import_and_attribute(tmp_path):
    rep = _lint_snippet(tmp_path, """\
        import jax.sharding
        import jax

        def f():
            return jax.sharding.Mesh((), ())
    """)
    codes = _codes_lines(rep)
    assert ("REPRO001", 1) in codes
    assert ("REPRO001", 5) in codes
    # the attribute chain is flagged once, not once per nesting level
    assert sum(1 for c, ln in codes if c == "REPRO001" and ln == 5) == 1


def test_repro001_shard_map_import(tmp_path):
    rep = _lint_snippet(tmp_path, """\
        from jax.experimental.shard_map import shard_map
    """)
    assert ("REPRO001", 1) in _codes_lines(rep)


def test_repro001_compat_is_allowlisted(tmp_path):
    rep = _lint_snippet(tmp_path, """\
        import jax
        Mesh = jax.sharding.Mesh
    """, rel="repro/compat.py")
    assert rep.ok, str(rep)


def test_repro001_allowlist_is_per_rule(tmp_path):
    # compat.py is allowlisted for REPRO001 only; other rules still fire
    rep = _lint_snippet(tmp_path, """\
        try:
            x = 1
        except Exception:
            pass
    """, rel="repro/compat.py")
    assert {d.code for d in rep.diagnostics} == {"REPRO002"}


# ---------------------------------------------------------------- REPRO002

def test_repro002_swallowed_exception(tmp_path):
    rep = _lint_snippet(tmp_path, """\
        try:
            risky()
        except Exception:
            pass
        try:
            risky()
        except:
            ...
    """)
    codes = _codes_lines(rep)
    assert ("REPRO002", 3) in codes
    assert ("REPRO002", 7) in codes


def test_repro002_negative(tmp_path):
    rep = _lint_snippet(tmp_path, """\
        import logging
        try:
            risky()
        except Exception:
            logging.exception("boom")
        try:
            risky()
        except ValueError:
            pass
    """)
    assert rep.ok, str(rep)


# ---------------------------------------------------------------- REPRO003

def test_repro003_unseeded_rng_in_core(tmp_path):
    rep = _lint_snippet(tmp_path, """\
        import numpy as np
        x = np.random.rand(4)
        np.random.seed(0)
    """, rel="repro/core/foo.py")
    codes = _codes_lines(rep)
    assert ("REPRO003", 2) in codes
    assert ("REPRO003", 3) in codes


def test_repro003_scoped_to_solver_modules(tmp_path):
    # same code outside core/ or sparse/ is not the solver's concern
    rep = _lint_snippet(tmp_path, """\
        import numpy as np
        x = np.random.rand(4)
    """, rel="repro/launch/foo.py")
    assert rep.ok, str(rep)


def test_repro003_seeded_generator_is_fine(tmp_path):
    rep = _lint_snippet(tmp_path, """\
        import numpy as np
        rng = np.random.default_rng(0)
        x = rng.random(4)
    """, rel="repro/sparse/foo.py")
    assert rep.ok, str(rep)


# ---------------------------------------------------------------- REPRO004

def test_repro004_item_in_solver(tmp_path):
    rep = _lint_snippet(tmp_path, """\
        def step(r):
            return r.item()
    """, rel="repro/sparse/foo.py")
    assert ("REPRO004", 2) in _codes_lines(rep)


def test_repro004_float_inside_jit(tmp_path):
    rep = _lint_snippet(tmp_path, """\
        import jax
        from functools import partial

        @jax.jit
        def f(x):
            return float(x)

        @partial(jax.jit, static_argnums=0)
        def g(n, x):
            return int(x)

        def h(x):
            return float(x)   # not jitted: fine
    """)
    codes = _codes_lines(rep)
    assert ("REPRO004", 6) in codes
    assert ("REPRO004", 10) in codes
    assert not any(ln == 13 for _, ln in codes)


def test_repro004_np_asarray_inside_jit(tmp_path):
    rep = _lint_snippet(tmp_path, """\
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.asarray(x)

        @jax.jit
        def g(x):
            return np.array(x)
    """)
    codes = _codes_lines(rep)
    assert ("REPRO004", 6) in codes
    assert ("REPRO004", 10) in codes


def test_repro004_np_asarray_outside_jit_is_fine(tmp_path):
    # host plan-building is where np.asarray belongs — even in sparse/
    rep = _lint_snippet(tmp_path, """\
        import numpy as np

        def build_plan(edges):
            return np.asarray(edges)
    """, rel="repro/sparse/foo.py")
    assert rep.ok, str(rep)


def test_repro004_device_get_in_solver(tmp_path):
    rep = _lint_snippet(tmp_path, """\
        import jax

        def fetch(y):
            return jax.device_get(y)
    """, rel="repro/sparse/foo.py")
    assert ("REPRO004", 4) in _codes_lines(rep)


def test_repro004_device_get_inside_jit_anywhere(tmp_path):
    rep = _lint_snippet(tmp_path, """\
        import jax

        @jax.jit
        def step(y):
            return jax.device_get(y)
    """, rel="repro/launch/foo.py")
    assert ("REPRO004", 5) in _codes_lines(rep)


def test_repro004_device_get_outside_solver_not_jitted_is_fine(tmp_path):
    rep = _lint_snippet(tmp_path, """\
        import jax

        def report(y):
            return jax.device_get(y)
    """, rel="repro/launch/foo.py")
    assert rep.ok, str(rep)


# ----------------------------------------------------------------- corpus

def test_syntax_error_reported_not_raised(tmp_path):
    rep = _lint_snippet(tmp_path, "def broken(:\n")
    assert {d.code for d in rep.diagnostics} == {"REPRO000"}


def test_real_source_tree_is_clean():
    rep = lint_paths([SRC])
    assert rep.ok, "migrated tree must lint clean:\n" + str(rep)
    assert rep.info["files"] > 50


def test_reintroduced_violation_has_file_and_line(tmp_path):
    rep = _lint_snippet(tmp_path, """\
        from jax.sharding import NamedSharding
    """, rel="repro/models/new_model.py")
    assert not rep.ok
    d = rep.diagnostics[0]
    assert d.code == "REPRO001"
    path, line = _where(d)
    assert path.endswith("new_model.py")
    assert line == 1
    assert "compat" in d.message


@pytest.mark.parametrize("code", sorted(LINT_RULES))
def test_every_rule_has_a_description(code):
    assert len(LINT_RULES[code]) > 10
