"""Block-Jacobi (per-PU diagonal-block) PCG regression.

The anisotropic grid Laplacian (strong coupling along axis 0, weak along
axis 1 — ``generators.aniso_grid``) is the classic system where
point-Jacobi barely helps: the diagonal carries no directional
information.  Partitioning into axis-0 stripes keeps whole strong lines
inside each PU's diagonal block, so block-Jacobi — built from the local
blocks the distributed plan already extracted (``plan.block_jacobi_inv``)
— must not iterate more than point-Jacobi, and in this regime iterates
strictly less.  Runs the real shard_map operators on 4 forced host
devices in a subprocess; both preconditioners stop on the same
unpreconditioned residual, so solution quality is identical (checked
against the ``coo`` reference).
"""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    import jax
    from repro.sparse.generators import aniso_grid
    from repro.sparse.graph import laplacian_csr
    from repro.sparse import make_operator, cg_solve_global

    g = aniso_grid((64, 16), eps=0.01)         # strong lines along axis 0
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    # axis-0 stripes: each PU owns contiguous whole strong lines
    part = (np.arange(g.n) * 4) // g.n
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("pu",))
    b = np.random.default_rng(1).normal(size=g.n).astype(np.float32)

    out = {}
    sols = {}
    op_ref = make_operator(indptr, indices, data, "coo")
    sols["coo"], out["iters_coo"], _ = cg_solve_global(
        op_ref, b, tol=1e-6, max_iters=4000)
    op = make_operator(indptr, indices, data, "dist_halo",
                       part=part, k=4, mesh=mesh)
    for pre in (None, "jacobi", "block_jacobi"):
        x, iters, res = cg_solve_global(op, b, tol=1e-6, max_iters=4000,
                                        precondition=pre)
        out[f"iters_{pre}"] = iters
        sols[pre] = x
    # fused whole-CG path with block-Jacobi
    res = op.solve(b, tol=1e-6, max_iters=4000,
                   precondition="block_jacobi")
    out["iters_block_jacobi_fused"] = int(res.iters)
    sols["bj_fused"] = op.gather(res.x)
    scale = float(np.abs(sols["coo"]).max())
    out["max_rel"] = max(float(np.abs(x - sols["coo"]).max()) / scale
                         for x in sols.values())
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def aniso_result():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_block_jacobi_iters_at_most_jacobi(aniso_result):
    r = aniso_result
    assert r["iters_block_jacobi"] <= r["iters_jacobi"], r
    # in the stripes-capture-strong-lines regime it is strictly better
    assert r["iters_block_jacobi"] < r["iters_None"], r


def test_block_jacobi_fused_matches_composable(aniso_result):
    r = aniso_result
    assert abs(r["iters_block_jacobi_fused"] - r["iters_block_jacobi"]) <= 1
    assert r["max_rel"] < 1e-4, r
