"""CLI contract tests for ``python -m repro.analysis`` (ISSUE 8
satellites 2 + 6): the unified exit code (nonzero iff *any* pass reported
a diagnostic), the machine-readable ``--format=json`` / ``--format=github``
output, and the ``trace --out`` CI artifact.

In-process ``main(argv)`` calls cover the format/exit matrix cheaply;
two real subprocesses pin down the actual shell contract CI depends on.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro import compat
from repro.analysis.__main__ import main

REPO = Path(__file__).resolve().parents[1]

needs_abstract_mesh = pytest.mark.skipif(
    not compat.HAS_ABSTRACT_MESH,
    reason="device-free tracing needs jax.sharding.AbstractMesh")


@pytest.fixture()
def offender_dir(tmp_path):
    bad = tmp_path / "mod.py"
    bad.write_text(textwrap.dedent("""\
        from jax.sharding import Mesh
    """))
    return tmp_path


# ------------------------------------------------------------- exit codes

def test_lint_clean_exits_zero(tmp_path, capsys):
    ok = tmp_path / "fine.py"
    ok.write_text("x = 1\n")
    assert main(["lint", str(ok)]) == 0
    assert "0 failing" in capsys.readouterr().out


def test_lint_offender_exits_nonzero(offender_dir, capsys):
    assert main(["lint", str(offender_dir)]) == 1
    assert "REPRO001" in capsys.readouterr().out


def test_verify_clean_exits_zero(capsys):
    assert main(["verify", "--n", "80", "--fanouts", "2,2",
                 "--generator", "grid_2d"]) == 0
    out = capsys.readouterr().out
    assert "0 failing" in out


@needs_abstract_mesh
def test_trace_clean_exits_zero(capsys):
    assert main(["trace", "--backend", "coo", "--backend", "dist_halo",
                 "--n", "64"]) == 0
    out = capsys.readouterr().out
    assert "flop/it" in out and "0 failing" in out


# ---------------------------------------------------------------- formats

def test_lint_json_format(offender_dir, capsys):
    rc = main(["lint", str(offender_dir), "--format=json"])
    assert rc == 1
    reports = json.loads(capsys.readouterr().out)
    assert isinstance(reports, list) and not reports[0]["ok"]
    d = reports[0]["diagnostics"][0]
    assert d["code"] == "REPRO001"
    assert d["where"].endswith("mod.py:1")


def test_lint_github_format(offender_dir, capsys, monkeypatch):
    monkeypatch.chdir(offender_dir)
    rc = main(["lint", str(offender_dir), "--format=github"])
    assert rc == 1
    out = capsys.readouterr().out
    assert "::error file=mod.py,line=1::REPRO001:" in out


@needs_abstract_mesh
def test_trace_json_and_artifact(tmp_path, capsys):
    art = tmp_path / "trace_audit.json"
    rc = main(["trace", "--backend", "dist_halo", "--n", "64",
               "--format=json", "--out", str(art)])
    assert rc == 0
    console = json.loads(capsys.readouterr().out)
    on_disk = json.loads(art.read_text())
    assert console == on_disk
    rep = on_disk[0]
    assert rep["ok"] and rep["subject"].startswith("dist_halo")
    cost = rep["info"]["cost_cg"]
    assert cost["flops_per_iter"] > 0
    assert len(cost["comm_payload_bytes_lvl"]) == 1


@needs_abstract_mesh
def test_trace_github_format_on_failure(capsys, monkeypatch):
    """Non-file diagnostics still come out as ::error annotations.  A
    trace failure is simulated by auditing a mutated schedule through the
    plain Report path the formatter consumes."""
    from repro.analysis.__main__ import _print_github
    from repro.analysis.diagnostics import Report

    rep = Report(subject="dist_halo grid_2d")
    rep.add("TRACE002", "staged permutation differs",
            where="level 0 round 1")
    _print_github([rep])
    out = capsys.readouterr().out
    assert out.startswith("::error::dist_halo grid_2d [level 0 round 1]:")
    assert "TRACE002" in out


# ------------------------------------------------------------ subprocesses

def _run_cli(args, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=cwd or REPO,
        timeout=600)


def test_subprocess_exit_code_contract(offender_dir):
    bad = _run_cli(["lint", str(offender_dir)])
    assert bad.returncode == 1, bad.stderr
    assert "REPRO001" in bad.stdout
    good = _run_cli(["lint", str(REPO / "src" / "repro" / "analysis")])
    assert good.returncode == 0, good.stderr + good.stdout


@needs_abstract_mesh
def test_subprocess_trace_smoke(tmp_path):
    art = tmp_path / "audit.json"
    res = _run_cli(["trace", "--backend", "coo", "--backend", "dist_hier",
                    "--n", "64", "--fanouts", "2,2", "--out", str(art)])
    assert res.returncode == 0, res.stderr + res.stdout
    reports = json.loads(art.read_text())
    assert all(r["ok"] for r in reports)
    assert {r["subject"].split()[0] for r in reports} == \
        {"coo", "dist_hier"}
