"""Hypothesis property tests for the hierarchical (two-level) plan builder.

On random CSR matrices (varying n, k, pod count, degree, duplicate edges,
empty/disconnected blocks):

  * the interior segment is *bit-identical* to the flat ``build_plan``'s
    (the interior criterion — no halo reads — is partition-level, not
    pod-level);
  * the intra-pod + inter-pod boundary segments exactly tile the flat
    plan's boundary set, per block and edge-multiset-exact; intra columns
    never reach the inter slot range and every inter row reads >= 1 inter
    slot;
  * the three-stage hier schedule (NumPy-simulated by ``hier_sim``)
    agrees with the flat sequential halo schedule and the dense oracle to
    < 1e-5.
"""
import numpy as np
import scipy.sparse as sp
from hypothesis import given, settings, strategies as st

from hier_sim import hier_spmv_numpy
from repro.sparse.distributed import build_plan, build_plan_hier


@st.composite
def hier_csr_system(draw):
    """Random CSR + partition + pod count: (indptr, indices, data, part,
    k, pods) with pods | k."""
    k = draw(st.integers(min_value=1, max_value=8))
    pods = draw(st.sampled_from(
        [d for d in range(1, k + 1) if k % d == 0]))
    n = draw(st.integers(min_value=1, max_value=48))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    density = draw(st.floats(min_value=0.0, max_value=0.3))
    blocks_used = draw(st.integers(min_value=1, max_value=k))
    rng = np.random.default_rng(seed)
    m = int(round(density * n * n))
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)        # duplicates summed by scipy
    vals = rng.uniform(0.5, 2.0, size=m)    # positive: no exact-0 cancel
    A = sp.csr_matrix((vals, (src, dst)), shape=(n, n))
    A.sum_duplicates()
    part = rng.permutation(k)[:blocks_used][rng.integers(0, blocks_used,
                                                         size=n)]
    return (A.indptr.astype(np.int64), A.indices.astype(np.int64),
            A.data.astype(np.float32), part.astype(np.int64), k, pods)


@settings(max_examples=60, deadline=None)
@given(hier_csr_system())
def test_interior_bit_identical_to_flat(system):
    indptr, indices, data, part, k, pods = system
    hp = build_plan_hier(indptr, indices, data, part, pods, k)
    fp = build_plan(indptr, indices, data, part, k)
    for f in ("rows_int", "cols_int", "vals_int", "interior_mask", "diag",
              "rows", "row_mask", "perm", "sizes", "nnz_blk"):
        np.testing.assert_array_equal(np.asarray(getattr(hp, f)),
                                      np.asarray(getattr(fp, f)),
                                      err_msg=f)


@settings(max_examples=60, deadline=None)
@given(hier_csr_system())
def test_intra_inter_tile_flat_boundary_set(system):
    indptr, indices, data, part, k, pods = system
    hp = build_plan_hier(indptr, indices, data, part, pods, k)
    fp = build_plan(indptr, indices, data, part, k)
    B = hp.B
    intra_hi = B + hp.n_rounds_intra * hp.S_intra
    fr, fv = np.asarray(fp.rows_bnd), np.asarray(fp.vals_bnd)
    ra, ca, va = (np.asarray(a) for a in (hp.rows_bnd_intra,
                                          hp.cols_bnd_intra,
                                          hp.vals_bnd_intra))
    re, ce, ve = (np.asarray(a) for a in (hp.rows_bnd_inter,
                                          hp.cols_bnd_inter,
                                          hp.vals_bnd_inter))
    for b in range(k):
        flat_bnd = sorted(zip(fr[b][fv[b] != 0].tolist(),
                              fv[b][fv[b] != 0].tolist()))
        ia = list(zip(ra[b][va[b] != 0].tolist(),
                      va[b][va[b] != 0].tolist()))
        ie = list(zip(re[b][ve[b] != 0].tolist(),
                      ve[b][ve[b] != 0].tolist()))
        assert sorted(ia + ie) == flat_bnd
        # intra / inter rows are disjoint
        assert not (set(r for r, _ in ia) & set(r for r, _ in ie))
        # intra reads stay below the inter slot range
        assert not (ca[b][va[b] != 0] >= intra_hi).any()
        # every inter row reads at least one inter slot
        keep = ve[b] != 0
        for r in np.unique(re[b][keep]):
            assert (ce[b][keep & (re[b] == r)] >= intra_hi).any()
        # pods=1 degenerates to the flat overlap split
        if pods == 1:
            assert len(ie) == 0


@settings(max_examples=60, deadline=None)
@given(hier_csr_system())
def test_hier_schedule_matches_flat_and_dense(system):
    indptr, indices, data, part, k, pods = system
    n = len(indptr) - 1
    hp = build_plan_hier(indptr, indices, data, part, pods, k)
    A = sp.csr_matrix((data, indices, indptr), shape=(n, n))
    x = np.random.default_rng(0).normal(size=n).astype(np.float32)
    y_hier = hier_spmv_numpy(hp, x)
    y_dense = A @ x
    scale = max(np.abs(y_dense).max(), 1.0)
    assert np.abs(y_hier - y_dense).max() / scale < 1e-5
