"""Hypothesis property tests for the arbitrary-depth tree plan builder
and the per-level tree metrics (ISSUE 5 satellite; logic pre-verified
over 150 random systems with a plain NumPy driver).

On random CSR matrices (varying n, fanouts of depth 1-4 including
degenerate fanout-1 levels, shuffled non-contiguous ancestor tables,
duplicate edges, empty/disconnected blocks):

  * the interior segment is *bit-identical* to the flat ``build_plan``'s
    modulo the tree-major block relabeling (the interior criterion — no
    halo reads — is partition-level, not tree-level);
  * the h per-level boundary segments exactly tile the flat plan's
    boundary set, per block and edge-multiset-exact, with disjoint row
    classes; level-l columns never reach a slower level's slot range and
    every level-l row reads >= 1 level-l slot;
  * the multi-stage tree schedule (NumPy-simulated by
    ``hier_sim.tree_spmv_numpy``) agrees with the dense oracle < 1e-5
    at every depth — the ISSUE depth-3 plan/COO-oracle acceptance;
  * per-level cut/comm-volume splits exactly tile the flat metrics;
  * at ``h == 2`` the tree path is bit-identical to the PR 3-4 pod path
    (same schedules, slots, segments).
"""
import numpy as np
import scipy.sparse as sp
from hypothesis import given, settings, strategies as st

from hier_sim import tree_spmv_numpy
from repro.core.metrics import (comm_volumes, edge_cut, tree_comm_volumes,
                                tree_cut_split)
from repro.core.topology import canonical_ancestors
from repro.sparse.distributed import (build_plan, build_plan_hier,
                                      build_plan_tree)
from repro.sparse.graph import Graph

FANOUTS = [(2,), (4,), (2, 2), (2, 3), (3, 2), (2, 4), (2, 2, 2),
           (2, 2, 3), (1, 2, 2), (2, 1, 3), (2, 2, 2, 2)]


@st.composite
def tree_csr_system(draw):
    """Random CSR + partition + shuffled nested ancestor table."""
    fanouts = draw(st.sampled_from(FANOUTS))
    k = int(np.prod(fanouts))
    n = draw(st.integers(min_value=1, max_value=48))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    density = draw(st.floats(min_value=0.0, max_value=0.3))
    blocks_used = draw(st.integers(min_value=1, max_value=k))
    rng = np.random.default_rng(seed)
    m = int(round(density * n * n))
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)        # duplicates summed by scipy
    vals = rng.uniform(0.5, 2.0, size=m)    # positive: no exact-0 cancel
    A = sp.csr_matrix((vals, (src, dst)), shape=(n, n))
    A.sum_duplicates()
    part = rng.permutation(k)[:blocks_used][rng.integers(0, blocks_used,
                                                         size=n)]
    # column-permuted canonical table: non-contiguous but still nested
    anc = canonical_ancestors(fanouts)[:, rng.permutation(k)]
    return (A.indptr.astype(np.int64), A.indices.astype(np.int64),
            A.data.astype(np.float32), part.astype(np.int64), k, fanouts,
            anc)


@settings(max_examples=60, deadline=None)
@given(tree_csr_system())
def test_interior_bit_identical_to_flat_modulo_relabel(system):
    indptr, indices, data, part, k, fanouts, anc = system
    tp = build_plan_tree(indptr, indices, data, part, anc, k)
    fp = build_plan(indptr, indices, data, part, k)
    bm = tp.block_map                       # original block -> device pos
    for f in ("rows_int", "cols_int", "vals_int", "interior_mask", "diag",
              "rows", "row_mask", "sizes", "nnz_blk"):
        np.testing.assert_array_equal(np.asarray(getattr(tp, f))[bm],
                                      np.asarray(getattr(fp, f)),
                                      err_msg=f)


@settings(max_examples=60, deadline=None)
@given(tree_csr_system())
def test_level_segments_tile_flat_boundary_set(system):
    indptr, indices, data, part, k, fanouts, anc = system
    tp = build_plan_tree(indptr, indices, data, part, anc, k)
    fp = build_plan(indptr, indices, data, part, k)
    bm = tp.block_map
    offs = tp.level_offsets()
    fr, fv = np.asarray(fp.rows_bnd), np.asarray(fp.vals_bnd)
    for b in range(k):
        d = bm[b]
        flat_bnd = sorted(zip(fr[b][fv[b] != 0].tolist(),
                              fv[b][fv[b] != 0].tolist()))
        allseg, rows_by_lvl = [], []
        for l in range(tp.h):
            rl = np.asarray(tp.rows_bnd_lvl[l][d])
            cl = np.asarray(tp.cols_bnd_lvl[l][d])
            vl = np.asarray(tp.vals_bnd_lvl[l][d])
            seg = list(zip(rl[vl != 0].tolist(), vl[vl != 0].tolist()))
            allseg += seg
            rows_by_lvl.append(set(r for r, _ in seg))
            # level-l reads never exceed level l's slot range
            assert not (cl[vl != 0] >= offs[l + 1]).any()
            # every level-l row has >= 1 read in level l's own range
            for r in np.unique(rl[vl != 0]):
                assert (cl[(rl == r) & (vl != 0)] >= offs[l]).any()
        assert sorted(allseg) == flat_bnd
        for i in range(tp.h):                # row classes are disjoint
            for j in range(i + 1, tp.h):
                assert not (rows_by_lvl[i] & rows_by_lvl[j])


@settings(max_examples=60, deadline=None)
@given(tree_csr_system())
def test_tree_schedule_matches_dense_oracle(system):
    indptr, indices, data, part, k, fanouts, anc = system
    n = len(indptr) - 1
    tp = build_plan_tree(indptr, indices, data, part, anc, k)
    A = sp.csr_matrix((data, indices, indptr), shape=(n, n))
    x = np.random.default_rng(0).normal(size=n).astype(np.float32)
    y = tree_spmv_numpy(tp, x)
    y_dense = A @ x
    scale = max(np.abs(y_dense).max(), 1.0)
    assert np.abs(y - y_dense).max() / scale < 1e-5


@settings(max_examples=60, deadline=None)
@given(tree_csr_system())
def test_level_splits_tile_flat_metrics(system):
    indptr, indices, data, part, k, fanouts, anc = system
    g = Graph(indptr=indptr, indices=indices,
              weights=np.asarray(data, dtype=np.float64))
    cuts = tree_cut_split(g, part, anc)
    vols = tree_comm_volumes(g, part, k, anc)
    assert cuts.shape == (len(fanouts),)
    assert abs(cuts.sum() - edge_cut(g, part)) < 1e-6
    np.testing.assert_array_equal(vols.sum(axis=0),
                                  comm_volumes(g, part, k))


@settings(max_examples=60, deadline=None)
@given(tree_csr_system())
def test_h2_tree_path_bit_identical_to_pod_path(system):
    indptr, indices, data, part, k, fanouts, anc = system
    if len(fanouts) != 2 or fanouts[0] == 1:
        return                               # two-level instances only
    tp = build_plan_tree(indptr, indices, data, part, anc, k)
    hp = build_plan_hier(indptr, indices, data, part, anc[0], k)
    assert tp.S_lvl == hp.S_lvl and tp.n_rounds_lvl == hp.n_rounds_lvl
    assert tp.round_perms_lvl == hp.round_perms_lvl
    np.testing.assert_array_equal(tp.block_map, hp.block_map)
    for l in range(2):
        for fam in ("rows_bnd_lvl", "cols_bnd_lvl", "vals_bnd_lvl",
                    "send_idx_lvl", "send_mask_lvl"):
            np.testing.assert_array_equal(
                np.asarray(getattr(tp, fam)[l]),
                np.asarray(getattr(hp, fam)[l]), err_msg=f"{fam}[{l}]")
    for f in ("perm", "rows", "cols", "vals", "interior_mask", "diag"):
        np.testing.assert_array_equal(np.asarray(getattr(tp, f)),
                                      np.asarray(getattr(hp, f)),
                                      err_msg=f)
