"""Satellite regressions for ISSUE 4.

* multilevel supernode weights: coarse-level FM must account block sizes
  and caps in true finest-vertex units (``vw``), not mean-scaled counts —
  a heavy supernode could silently violate the memory caps (Eq. 3);
* ``partition`` forwards ``seed`` into the multilevel refinement, so
  ``heavy_edge_matching`` is actually seed-varied;
* ``imbalance`` and ``_greedy_growing`` guard zero-target blocks
  (fully saturated topologies).
"""
import numpy as np
import pytest

from repro.core import Topology, partition, scale_to_load
from repro.core.api import _greedy_growing
from repro.core.metrics import imbalance, memory_violations
from repro.core.multilevel import (contract, heavy_edge_matching,
                                   partition_multilevel_refine)
from repro.core.refinement import fm_pair_refine, refine_partition
from repro.sparse.generators import rdg
from repro.sparse.graph import from_edges


# -- per-vertex weights in FM size/cap accounting ---------------------------

def _heavy_vertex_instance():
    """Vertex 0 (weight 3) sits in block 0 but is wired to block 1: the
    cut gain of moving it is strongly positive, and only *weighted* cap
    accounting can see that block 1 has no room for it."""
    # blocks: {0,1,2} and {3,4,5}; vertex 0 heavy, pulled toward block 1
    src = [0, 0, 0, 1, 2, 3, 4]
    dst = [3, 4, 5, 2, 1, 4, 5]
    w = [5.0, 5.0, 5.0, 1.0, 1.0, 1.0, 1.0]
    g = from_edges(6, src, dst, w, symmetrize=True)
    part = np.array([0, 0, 0, 1, 1, 1], dtype=np.int32)
    vw = np.array([3, 1, 1, 1, 1, 1], dtype=np.int64)
    return g, part, vw


def test_fm_respects_weighted_caps():
    g, part, vw = _heavy_vertex_instance()
    caps = np.array([5.0, 5.0])           # weighted sizes start at (5, 3)
    # unweighted accounting would admit vertex 0 into block 1 (3+1 <= 5)
    p_unw = part.copy()
    fm_pair_refine(g, p_unw, 0, 1, caps)
    assert p_unw[0] == 1
    # weighted accounting must refuse (3 + 3 > 5)
    p_w = part.copy()
    fm_pair_refine(g, p_w, 0, 1, caps, vw=vw)
    assert p_w[0] == 0
    sizes_w = np.bincount(p_w, weights=vw.astype(float), minlength=2)
    assert (sizes_w <= caps).all()


def test_refine_partition_threads_vertex_weights():
    g, part, vw = _heavy_vertex_instance()
    tw = np.array([4.0, 4.0])
    out = refine_partition(g, part, tw, mems=np.array([5.0, 5.0]),
                           eps=0.25, vw=vw)
    sizes_w = np.bincount(out, weights=vw.astype(float), minlength=2)
    assert (sizes_w <= 5.0).all()


def test_multilevel_skewed_matching_respects_caps():
    """End-to-end: heavy intra-block edges force a skewed matching (some
    supernodes weight 2, some 1); with per-vertex weights threaded
    through, the refined partition never exceeds the memory caps."""
    g = rdg(1500, seed=13)
    topo = scale_to_load(Topology.topo1(6, 2 / 6, 4.0, 5.2), g.n)
    from repro.core import target_block_sizes
    tw = target_block_sizes(g.n, topo)
    from repro.core.balanced_kmeans import partition_balanced_kmeans
    part0 = partition_balanced_kmeans(g, tw, seed=0)
    # force real coarsening on this small instance
    out = partition_multilevel_refine(g, part0, tw, mems=topo.memories,
                                      eps=0.03, coarsest=128, max_levels=3)
    assert memory_violations(out, topo, slack=0.03) == 0
    sizes = np.bincount(out, minlength=topo.k)
    caps = np.minimum(np.ceil(tw * 1.03), np.floor(topo.memories))
    assert (sizes <= caps).all()


def test_contract_weights_are_cumulative():
    """A twice-contracted supernode's weight is its finest-vertex count
    — the accounting ``partition_multilevel_refine`` now relies on."""
    g = rdg(400, seed=3)
    part = np.zeros(g.n, dtype=np.int32)
    vw = np.ones(g.n, dtype=np.int64)
    cur = g
    for lvl in range(2):
        match = heavy_edge_matching(cur, part, seed=lvl)
        cg, part, f2c, cvw = contract(cur, part, match)
        vw = np.bincount(f2c, weights=vw, minlength=cg.n).astype(np.int64)
        cur = cg
    assert vw.sum() == g.n
    assert vw.max() >= 2          # something actually matched twice


# -- seed forwarding --------------------------------------------------------

def test_partition_forwards_seed_to_multilevel(monkeypatch):
    import repro.core.multilevel as ml
    seen = []
    orig = ml.heavy_edge_matching

    def spy(g, part, seed=0):
        seen.append(seed)
        return orig(g, part, seed=seed)

    monkeypatch.setattr(ml, "heavy_edge_matching", spy)
    g = rdg(5000, seed=2)        # above the multilevel coarsest threshold
    topo = scale_to_load(Topology.homogeneous(4), g.n)
    partition(g, topo, "geoRef", seed=7)
    assert seen and seen[0] == 7          # seed + level offset
    seen.clear()
    partition(g, topo, "geoRef", seed=11)
    assert seen and seen[0] == 11


def test_evaluate_seed_varies_results():
    from repro.core import evaluate
    g = rdg(1200, seed=4)
    topo = scale_to_load(Topology.homogeneous(4), g.n)
    a = evaluate(g, topo, methods=("greedyRef",), seed=1, verbose=False)
    b = evaluate(g, topo, methods=("greedyRef",), seed=2, verbose=False)
    assert a["greedyRef"]["cut"] != b["greedyRef"]["cut"]


# -- zero-target guards -----------------------------------------------------

def test_imbalance_zero_target_blocks():
    tw = np.array([4.0, 4.0, 0.0])
    # empty zero-target block: ignored, not inf / not 1e12-ish garbage
    part_ok = np.array([0, 0, 0, 0, 1, 1, 1, 1])
    assert imbalance(part_ok, tw) == pytest.approx(1.0)
    # populated zero-target block: any load is a violation -> inf
    part_bad = np.array([0, 0, 0, 0, 1, 1, 1, 2])
    assert imbalance(part_bad, tw) == float("inf")
    # all-zero targets, empty partition arrays degenerate to 1.0
    assert imbalance(np.zeros(0, dtype=np.int32), np.zeros(2)) == 1.0


def test_greedy_growing_skips_zero_target_blocks():
    g = rdg(300, seed=6)
    tw = np.array([g.n / 2.0, g.n / 2.0, 0.0])
    part = _greedy_growing(g, tw, seed=0)
    sizes = np.bincount(part, minlength=3)
    assert sizes[2] == 0                       # no seed, no orphans
    assert sizes.sum() == g.n
    assert imbalance(part, tw) < 1.2


def test_partition_greedy_ref_with_zero_target():
    """greedyRef end-to-end with an explicit zero target: the saturated
    pipeline leaves the zero-target block empty and finite-imbalanced."""
    g = rdg(500, seed=7)
    topo = scale_to_load(Topology.homogeneous(4), g.n)
    tw = np.array([g.n / 3.0, g.n / 3.0, g.n / 3.0, 0.0])
    part, _ = partition(g, topo, "greedyRef", tw=tw)
    assert np.bincount(part, minlength=4)[3] == 0
    assert np.isfinite(imbalance(part, tw))
