"""Tree-aware partitioning pipeline (ISSUE 5 acceptance) — NumPy-only.

The tree runtime (``comm='hier'`` on a depth-h plan) pays each cut edge
at the link latency of its LCA level; these tests lock down that the
recursive pipeline (``partition_tree``) actually *reduces* the
outermost-level component versus the pod-oblivious stripes baseline on
the (2, 2, 2) acceptance mesh, that the per-level metrics/objective/FM
are bit-identical to the PR 4 pod path at h == 2, and that the
tree-aware Algorithm 1 (``tree_target_block_sizes`` / the recursion's
water-fill) removes the stage-B rescale.
"""
import numpy as np
import pytest

from hier_sim import tree_spmv_numpy
from repro.core import (HierPartition, Topology, canonical_ancestors,
                        contiguous_pods, level_matrix, partition,
                        partition_hier, partition_tree, scale_to_load,
                        target_block_sizes, tree_assignment_for,
                        tree_target_block_sizes, waterfill)
from repro.core.metrics import (comm_volumes, edge_cut, tree_comm_volumes,
                                tree_cut_split, tree_objective,
                                two_level_objective, summarize_tree)
from repro.core.refinement import (fm_pair_refine, quotient_graph,
                                   refine_partition,
                                   refine_pod_assignment,
                                   refine_tree_assignment)
from repro.core.topology import PU, normalize_tree_of
from repro.sparse import make_operator
from repro.sparse.distributed import build_plan_tree
from repro.sparse.generators import grid, rdg
from repro.sparse.graph import laplacian_csr


@pytest.fixture(scope="module")
def striped_grid():
    """The acceptance configuration: a grid whose 8 stripes cross the
    long axis, so every stripe boundary (and every canonical-tree
    group boundary) costs a full 128-wide grid line."""
    g = grid((16, 128))
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    part = ((np.arange(g.n) * 8) // g.n).astype(np.int32)
    return g, (indptr, indices, data), part


def test_level_splits_tile_flat_metrics():
    """Per-level cut/volume splits exactly tile the flat metrics on a
    depth-3 table (deterministic twin of the hypothesis suite)."""
    g = rdg(800, seed=3)
    rng = np.random.default_rng(0)
    part = rng.integers(0, 8, g.n).astype(np.int32)
    anc = canonical_ancestors((2, 2, 2))[:, rng.permutation(8)]
    cuts = tree_cut_split(g, part, anc)
    vols = tree_comm_volumes(g, part, 8, anc)
    assert cuts.shape == (3,) and vols.shape == (3, 8)
    assert cuts.sum() == pytest.approx(edge_cut(g, part))
    np.testing.assert_array_equal(vols.sum(axis=0),
                                  comm_volumes(g, part, 8))


def test_tree_objective_h2_bit_identical_to_two_level():
    g = rdg(700, seed=4)
    rng = np.random.default_rng(1)
    part = rng.integers(0, 8, g.n).astype(np.int32)
    pod_of = contiguous_pods(8, 2)
    for lam in (1.0, 4.0, 16.0):
        assert tree_objective(g, part, pod_of[None, :], (1.0, lam)) == \
            two_level_objective(g, part, pod_of, lam)


def test_fm_gains_h2_bit_identical_to_pod_path():
    """Acceptance: at h == 2 the tree FM gains are bit-identical to the
    PR 4 pod gains — same moves, same partitions."""
    g = rdg(900, seed=7)
    rng = np.random.default_rng(0)
    part = rng.integers(0, 8, g.n).astype(np.int32)
    pod_of = contiguous_pods(8, 2)
    tw = np.maximum(np.bincount(part, minlength=8), 1).astype(np.float64)
    for lam in (2.0, 4.0):
        out_pod = refine_partition(g, part, tw, eps=0.1,
                                   pod_of=pod_of, lam=lam)
        out_anc = refine_partition(g, part, tw, eps=0.1,
                                   anc=pod_of[None, :], lams=(1.0, lam))
        np.testing.assert_array_equal(out_pod, out_anc)
    # single-pair FM: same gain, same mutation
    pa, pb = part.copy(), part.copy()
    caps = np.ceil(tw * 1.1)
    g1 = fm_pair_refine(g, pa, 0, 5, caps, pod_of=pod_of, lam=4.0)
    g2 = fm_pair_refine(g, pb, 0, 5, caps, anc=pod_of[None, :],
                        lams=(1.0, 4.0))
    assert g1 == g2
    np.testing.assert_array_equal(pa, pb)
    with pytest.raises(ValueError):
        fm_pair_refine(g, pa, 0, 5, caps, pod_of=pod_of,
                       anc=pod_of[None, :])


def test_tree_sweep_h2_bit_identical_to_pod_sweep():
    g = rdg(900, seed=8)
    part = np.random.default_rng(2).integers(0, 8, g.n).astype(np.int32)
    pairs, w = quotient_graph(g, part, 8)
    pod_of = contiguous_pods(8, 2)
    a = refine_pod_assignment(pairs, w, pod_of)
    b = refine_tree_assignment(pairs, w, pod_of[None, :])
    np.testing.assert_array_equal(a, b[0])


def test_tree_sweep_per_level_invariants():
    """The per-level sweep keeps the table nested with preserved group
    sizes and never increases any level's crossing weight."""
    g = rdg(1200, seed=9)
    part = np.random.default_rng(3).integers(0, 8, g.n).astype(np.int32)
    pairs, w = quotient_graph(g, part, 8)
    anc0 = canonical_ancestors((2, 2, 2))
    anc = refine_tree_assignment(pairs, w, anc0)
    normalize_tree_of(anc, 8, (2, 2, 2))         # still nested/rectangular
    W = np.zeros((8, 8))
    W[pairs[:, 0], pairs[:, 1]] = w
    W += W.T
    lev0 = level_matrix(anc0)
    lev1 = level_matrix(anc)
    for l in (2, 1):                              # crossing at level >= l
        assert W[lev1 >= l].sum() <= W[lev0 >= l].sum() + 1e-9


def test_tree_aware_beats_oblivious_on_depth3_stripes(striped_grid):
    """ISSUE acceptance: on the (2, 2, 2) mesh the tree-aware pipeline's
    outermost-level comm volume is strictly below the pod-oblivious
    stripes baseline's, at a lower tree objective."""
    g, (indptr, indices, data), part_s = striped_grid
    topo = scale_to_load(Topology.homogeneous(8, fanouts=(2, 2, 2)), g.n)
    anc_c = canonical_ancestors((2, 2, 2))

    res = partition_tree(g, topo, "geoRef")
    assert isinstance(res, HierPartition)
    assert res.h == 3 and res.fanouts == (2, 2, 2)
    assert res.anc.shape == (2, 8)
    assert res.lams == (1.0, 4.0, 16.0)          # link-cost ladder

    vol_base = tree_comm_volumes(g, part_s, 8, anc_c)
    vol_pa = tree_comm_volumes(g, res.part, 8, res.anc)
    assert vol_pa[-1].sum() < vol_base[-1].sum()  # strictly lower outer
    assert tree_objective(g, res.part, res.anc, res.lams) < \
        tree_objective(g, part_s, anc_c, res.lams)


def test_build_plan_tree_consumes_partition_table(striped_grid):
    """Acceptance: the depth-3 plan consumes the partitioner's (swept,
    non-contiguous) ancestor table without relabeling errors — the tree
    schedule agrees with the coo backend to < 1e-5."""
    g, (indptr, indices, data), _ = striped_grid
    topo = scale_to_load(Topology.homogeneous(8, fanouts=(2, 2, 2)), g.n)
    perm = np.array([0, 4, 1, 5, 2, 6, 3, 7])
    part = perm[(np.arange(g.n) * 8) // g.n].astype(np.int32)
    anc = tree_assignment_for(g, part, topo)
    assert anc.shape == (2, 8)

    plan = build_plan_tree(indptr, indices, data, part, anc, 8)
    op = make_operator(indptr, indices, data, "coo")
    x = np.random.default_rng(2).normal(size=g.n).astype(np.float32)
    ref = op.gather(op.matvec(op.scatter(x)))
    y = tree_spmv_numpy(plan, x)
    assert np.abs(y - ref).max() / np.abs(ref).max() < 1e-5


def test_make_operator_unpacks_depth3_hier_partition(striped_grid):
    """make_operator unpacks a depth-3 HierPartition (part, k, ancestor
    table) so the partitioner output drives the tree runtime directly
    (mesh-free plan check through the NumPy simulator)."""
    g, (indptr, indices, data), _ = striped_grid
    topo = scale_to_load(Topology.homogeneous(8, fanouts=(2, 2, 2)), g.n)
    res = partition_tree(g, topo, "sfc")
    plan = build_plan_tree(indptr, indices, data, res.part, res.anc, res.k)
    assert plan.h == 3
    op = make_operator(indptr, indices, data, "coo")
    x = np.random.default_rng(3).normal(size=g.n).astype(np.float32)
    ref = op.gather(op.matvec(op.scatter(x)))
    y = tree_spmv_numpy(plan, x)
    assert np.abs(y - ref).max() / np.abs(ref).max() < 1e-5


def test_partition_hier_routes_through_tree_pipeline():
    """The two-level wrapper is the h == 2 instance of the recursion:
    same partition through either entry point."""
    g = rdg(600, seed=6)
    topo = scale_to_load(Topology.homogeneous(4), g.n)
    r2 = partition_hier(g, topo, "sfc", pods=2)
    rt = partition_tree(g, topo, "sfc", tree=contiguous_pods(4, 2),
                        lams=(1.0, topo.link_costs().lam))
    np.testing.assert_array_equal(r2.part, rt.part)
    np.testing.assert_array_equal(r2.anc, rt.anc)
    # and partition() routes fanouts= the same way
    p, tw = partition(g, topo, "sfc", fanouts=(2, 2))
    rf = partition_tree(g, topo, "sfc", fanouts=(2, 2))
    np.testing.assert_array_equal(p, rf.part)
    np.testing.assert_array_equal(tw, rf.tw)


def test_tree_targets_match_flat_when_unsaturated():
    """Tree-aware Algorithm 1 == flat Algorithm 1 whenever no PU
    saturates (proportional shares compose down the tree)."""
    topo = scale_to_load(Topology.topo1(8, 2 / 8, 2.0, 3.2), 1000)
    flat = target_block_sizes(1000, topo)
    assert not np.isclose(flat, topo.memories).any()   # truly unsaturated
    np.testing.assert_allclose(
        tree_target_block_sizes(1000, topo, fanouts=(2, 2, 2)),
        flat, rtol=1e-12)


def test_tree_targets_absorb_saturation_within_subtree():
    """A saturated member inside an unsaturated pod: the sibling absorbs
    the overflow (no rescale), the per-pod sums equal the aggregate
    water-fill, and memory caps hold exactly."""
    topo = Topology(
        (PU(4.0, 1.0), PU(1.0, 10.0), PU(1.0, 10.0), PU(1.0, 10.0)),
        (2, 2))
    tw = tree_target_block_sizes(14.0, topo)
    assert (tw <= topo.memories + 1e-9).all()
    assert tw.sum() == pytest.approx(14.0)
    assert tw[0] == pytest.approx(1.0)           # saturated at its cap
    agg = topo.pod_aggregate(2)
    shares = waterfill(14.0, agg.speeds, agg.memories)
    np.testing.assert_allclose([tw[:2].sum(), tw[2:].sum()], shares)
    # the flat optimum spreads the overflow over *all* other PUs; the
    # tree version keeps it inside the saturated member's pod
    flat = target_block_sizes(14.0, topo)
    assert tw[1] > flat[1]


def test_partition_tree_respects_memory_on_saturated_topo():
    """End to end: the recursion's water-fill keeps every realized block
    within memory where the old rescale could overfill the saturated
    member (stage-B rescale removal, ROADMAP satellite)."""
    g = grid((20, 20))
    topo = Topology(
        (PU(8.0, 60.0), PU(1.0, 250.0), PU(1.0, 250.0), PU(1.0, 250.0)),
        (2, 2))
    res = partition_hier(g, topo, "greedyRef", pods=2, seed=1)
    sizes = np.bincount(res.part, minlength=4)
    slack = np.ceil(topo.memories * 1.03)
    assert (sizes <= slack).all(), sizes


def test_summarize_tree_reports_per_level():
    g = grid((12, 12))
    rng = np.random.default_rng(1)
    part = rng.integers(0, 8, g.n).astype(np.int32)
    topo = scale_to_load(Topology.homogeneous(8, fanouts=(2, 2, 2)), g.n)
    tw = np.full(8, g.n / 8)
    anc = canonical_ancestors((2, 2, 2))
    s = summarize_tree(g, part, topo, tw, anc, lams=(1.0, 3.0, 9.0))
    assert sum(s["cut_by_level"]) == pytest.approx(s["cut"])
    assert sum(s["comm_volume_by_level"]) == s["total_comm_volume"]
    expect = (s["cut_by_level"][0] + 3.0 * s["cut_by_level"][1]
              + 9.0 * s["cut_by_level"][2])
    assert s["tree_objective"] == pytest.approx(expect)


def test_hier_partition_defaults_respect_table_depth():
    """A manually built HierPartition with a depth-3 table infers a
    depth-3 fanouts/lams, so (anc, lams) pairs feed the tree metrics
    directly; the h == 2 defaults are unchanged."""
    anc3 = canonical_ancestors((2, 2, 2))
    hp = HierPartition(part=np.zeros(10, np.int32), tw=np.ones(8),
                       pod_of=anc3[0], lam=16.0, anc=anc3)
    assert hp.fanouts == (2, 2, 2) and hp.h == 3
    assert hp.lams == pytest.approx((1.0, 4.0, 16.0))
    g = grid((8, 8))
    part = np.random.default_rng(0).integers(0, 8, g.n).astype(np.int32)
    tree_objective(g, part, hp.anc, hp.lams)     # lengths consistent
    hp2 = HierPartition(part=np.zeros(10, np.int32), tw=np.ones(8),
                        pod_of=contiguous_pods(8, 2), lam=4.0)
    assert hp2.fanouts == (2, 4) and hp2.lams == (1.0, 4.0)


def test_linkcosts_ladder_and_tree_matrix():
    topo = Topology.homogeneous(8, fanouts=(2, 2, 2))
    lc = topo.link_costs()
    assert lc.costs == (1.0, 4.0, 16.0)
    assert lc.lams == (1.0, 4.0, 16.0)
    assert lc.lam == 16.0                        # outer/inner ratio
    C = lc.tree_matrix(topo.ancestor_table())
    assert C[0, 1] == 1.0 and C[0, 2] == 4.0 and C[0, 4] == 16.0
    assert C[3, 3] == 0.0
    np.testing.assert_array_equal(C, C.T)
    with pytest.raises(ValueError):              # table deeper than costs
        Topology.homogeneous(8).link_costs(levels=2).tree_matrix(
            topo.ancestor_table())
    # level_of agrees with the matrix
    assert topo.level_of(0, 1) == 0
    assert topo.level_of(0, 2) == 1
    assert topo.level_of(0, 7) == 2
    assert topo.level_of(5, 5) == -1
