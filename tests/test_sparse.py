"""SpMV / CG substrate (single device)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sparse.cg import cg_solve
from repro.sparse.generators import rdg
from repro.sparse.graph import laplacian_csr
from repro.sparse.spmv import csr_to_padded_coo, spmv_coo


@pytest.fixture(scope="module")
def lap():
    g = rdg(500, seed=4)
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    import scipy.sparse as sp
    A = sp.csr_matrix((data, indices, indptr), shape=(g.n, g.n))
    return A


def test_spmv_coo_matches_scipy(lap):
    n = lap.shape[0]
    rows, cols, vals = csr_to_padded_coo(lap.indptr, lap.indices, lap.data,
                                         nnz_pad=len(lap.data) + 37)
    x = np.random.default_rng(0).normal(size=n).astype(np.float32)
    y = np.asarray(spmv_coo(jnp.asarray(rows), jnp.asarray(cols),
                            jnp.asarray(vals), jnp.asarray(x)))
    np.testing.assert_allclose(y, lap @ x, atol=1e-4, rtol=1e-4)


def test_cg_converges(lap):
    n = lap.shape[0]
    rows, cols, vals = csr_to_padded_coo(lap.indptr, lap.indices, lap.data)
    rows, cols, vals = (jnp.asarray(a) for a in (rows, cols, vals))
    b = np.random.default_rng(1).normal(size=n).astype(np.float32)

    res = cg_solve(lambda x: spmv_coo(rows, cols, vals, x),
                   jnp.asarray(b), tol=1e-6, max_iters=2000)
    x = np.asarray(res.x)
    rel = np.linalg.norm(lap @ x - b) / np.linalg.norm(b)
    assert rel < 1e-4
    assert int(res.iters) < 2000


def test_cg_identity_one_step():
    b = jnp.asarray(np.random.default_rng(2).normal(size=32),
                    jnp.float32)
    res = cg_solve(lambda x: x, b, tol=1e-8)
    assert int(res.iters) <= 2
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(b), atol=1e-5)
