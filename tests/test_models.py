"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs, plus decode-vs-forward consistency
for every cache type."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config
from repro.models import encdec, transformer
from repro.models.config import ModelConfig
from repro.models.steps import (input_specs, loss_fn, make_decode_step,
                                make_train_step)
from repro.train.optimizer import AdamWConfig, init_opt_state


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                               jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                               jnp.int32)}
    if cfg.family == "vlm":
        b["img_embeds"] = jnp.asarray(
            rng.normal(scale=0.02, size=(B, cfg.n_img_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(
            rng.normal(scale=0.02, size=(B, cfg.n_frames, cfg.d_model)),
            jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train(arch):
    cfg = get_config(arch, smoke=True)
    mod = encdec if cfg.family == "audio" else transformer
    params, specs = mod.init_model(jax.random.PRNGKey(0), cfg)
    # twin trees line up
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: not isinstance(x, dict))
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    if cfg.family == "audio":
        logits, _ = encdec.forward(params, cfg, batch["frames"],
                                   batch["tokens"])
    else:
        logits, _ = transformer.forward(params, cfg, batch["tokens"],
                                        img_embeds=batch.get("img_embeds"))
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    state = {"params": params, "opt": init_opt_state(params)}
    step = jax.jit(make_train_step(cfg, AdamWConfig()))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, state["params"]))
    assert max(delta) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_decode_smoke(arch):
    cfg = get_config(arch, smoke=True)
    B, L = 2, 16
    if cfg.family == "audio":
        params, _ = encdec.init_model(jax.random.PRNGKey(0), cfg)
        frames = jnp.asarray(np.random.default_rng(0).normal(
            scale=0.02, size=(B, cfg.n_frames, cfg.d_model)), jnp.float32)
        cache = encdec.init_cache(params, cfg, frames, L)
    else:
        params, _ = transformer.init_model(jax.random.PRNGKey(0), cfg)
        cache = transformer.init_cache(cfg, B, L)
    step = jax.jit(make_decode_step(cfg))
    tok = jnp.ones((B, 1), jnp.int32)
    for t in range(3):
        logits, cache = step(params, cache, tok, jnp.int32(t))
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("family,kw", [
    ("dense", dict(qkv_bias=True)),
    ("ssm", dict(ssm_state=16, ssm_headdim=16, n_layers=2, d_ff=0)),
    ("hybrid", dict(pattern=("rec", "rec", "attn"), window=8, n_layers=6)),
    ("moe", dict(n_experts=4, top_k=2, d_expert=32, d_ff=0, n_layers=2,
                 moe_capacity=4.0)),
])
def test_decode_matches_forward(family, kw):
    base = dict(name=f"t-{family}", family=family, n_layers=4, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                dtype="float32")
    base.update(kw)
    cfg = ModelConfig(**base)
    params, _ = transformer.init_model(jax.random.PRNGKey(1), cfg)
    S = 20
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, S), 0, cfg.vocab)
    full, _ = transformer.forward(params, cfg, toks, remat=False)
    cache = transformer.init_cache(cfg, 2, S)
    step = jax.jit(lambda p, c, t, pos:
                   transformer.decode_step(p, cfg, c, t, pos))
    errs = []
    for t in range(S):
        lg, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert max(errs) < 2e-2, errs


def test_prefill_matches_forward_then_decode():
    cfg = ModelConfig("t", "dense", 4, 64, 4, 2, 128, 256, dtype="float32")
    params, _ = transformer.init_model(jax.random.PRNGKey(1), cfg)
    S, T0 = 24, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, S), 0, 256)
    full, _ = transformer.forward(params, cfg, toks, remat=False)
    lg, cache = transformer.prefill_forward(params, cfg, toks[:, :T0],
                                            cache_len=S)
    assert float(jnp.max(jnp.abs(lg[:, 0] - full[:, T0 - 1]))) < 1e-3
    for t in range(T0, S):
        lg, cache = transformer.decode_step(params, cfg, cache,
                                            toks[:, t:t + 1], jnp.int32(t))
        assert float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))) < 1e-3


def test_costmode_equivalence():
    from repro.models.costmode import cost_mode
    cfg = ModelConfig("t", "dense", 4, 64, 4, 2, 128, 256, dtype="float32")
    params, _ = transformer.init_model(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 256)
    l1, _ = transformer.forward(params, cfg, toks)
    with cost_mode():
        l2, _ = transformer.forward(params, cfg, toks)
    assert float(jnp.max(jnp.abs(l1 - l2))) < 1e-4


def test_input_specs_cover_all_archs():
    for arch in ARCHS:
        cfg = get_config(arch)
        for mode in ("train", "prefill", "decode"):
            spec = input_specs(cfg, 4, 128, mode)
            assert "tokens" in spec
            for v in spec.values():
                assert isinstance(v, jax.ShapeDtypeStruct)


def test_accum_steps_equivalent():
    """Gradient accumulation == single big batch (same loss trajectory)."""
    cfg = ModelConfig("t", "dense", 2, 32, 2, 2, 64, 128, dtype="float32")
    params, _ = transformer.init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, B=4, S=16)
    opt = AdamWConfig(lr=1e-3)
    s1 = {"params": params, "opt": init_opt_state(params)}
    s2 = jax.tree.map(lambda x: x, s1)
    st1, m1 = jax.jit(make_train_step(cfg, opt, accum_steps=1))(s1, batch)
    st2, m2 = jax.jit(make_train_step(cfg, opt, accum_steps=2))(s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     st1["params"], st2["params"])
    assert max(jax.tree.leaves(d)) < 1e-4
