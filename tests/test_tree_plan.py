"""Deterministic tests for the arbitrary-depth tree plan
(``build_plan_tree``) — the ISSUE 5 tentpole's runtime layer.

Host-only (the per-level ppermute schedules are simulated in NumPy by
``hier_sim.tree_spmv_numpy``); the device-level shard_map execution of
the depth-3 ``comm='hier'`` schedule is covered by the 8-device
subprocess matrix in tests/test_operator.py.
"""
import numpy as np
import pytest

from hier_sim import tree_spmv_numpy
from repro.core.topology import canonical_ancestors
from repro.sparse.distributed import (HierPlan, TreePlan, build_plan,
                                      build_plan_hier, build_plan_tree,
                                      _local_matvec_builder)
from repro.sparse.generators import grid, rdg
from repro.sparse.graph import laplacian_csr


def dense_of(indptr, indices, data, n):
    a = np.zeros((n, n), dtype=np.float64)
    src = np.repeat(np.arange(n), np.diff(indptr))
    np.add.at(a, (src, indices), data)
    return a


@pytest.fixture(scope="module")
def lap():
    g = rdg(600, seed=11)
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    return g, indptr, indices, data


@pytest.mark.parametrize("fanouts", [(2, 2, 2), (2, 2, 3), (3, 2, 2),
                                     (2, 3, 2), (2, 2, 2, 2)])
def test_tree_spmv_matches_dense_oracle(lap, fanouts):
    g, indptr, indices, data = lap
    k = int(np.prod(fanouts))
    part = np.random.default_rng(k + len(fanouts)).integers(0, k, g.n)
    plan = build_plan_tree(indptr, indices, data, part, None, k,
                           fanouts=fanouts)
    assert isinstance(plan, TreePlan)
    assert plan.h == len(fanouts) and plan.fanouts == fanouts
    assert len(plan.n_rounds_lvl) == plan.h
    A = dense_of(indptr, indices, data, g.n)
    x = np.random.default_rng(2).normal(size=g.n)
    np.testing.assert_allclose(tree_spmv_numpy(plan, x),
                               A @ x.astype(np.float32),
                               atol=1e-3, rtol=1e-4)


def test_h2_tree_plan_bit_identical_to_pod_plan(lap):
    """Acceptance: at h == 2 the tree path is bit-identical to the PR 3-4
    pod path — same schedules, same slot layout, same segments."""
    g, indptr, indices, data = lap
    part = np.random.default_rng(0).integers(0, 8, g.n)
    pod_of = np.array([0, 1, 0, 1, 1, 0, 1, 0])
    hp = build_plan_hier(indptr, indices, data, part, pod_of, 8)
    tp = build_plan_tree(indptr, indices, data, part, pod_of[None, :], 8)
    assert isinstance(hp, HierPlan) and hp.h == 2
    assert tp.fanouts == hp.fanouts == (2, 4)
    assert tp.S_lvl == hp.S_lvl and tp.n_rounds_lvl == hp.n_rounds_lvl
    assert tp.round_perms_lvl == hp.round_perms_lvl
    for f in ("perm", "block_map", "rows", "cols", "vals", "rows_int",
              "cols_int", "vals_int", "interior_mask", "diag"):
        np.testing.assert_array_equal(np.asarray(getattr(tp, f)),
                                      np.asarray(getattr(hp, f)), err_msg=f)
    for l in range(2):
        for fam in ("rows_bnd_lvl", "cols_bnd_lvl", "vals_bnd_lvl",
                    "send_idx_lvl", "send_mask_lvl"):
            np.testing.assert_array_equal(
                np.asarray(getattr(tp, fam)[l]),
                np.asarray(getattr(hp, fam)[l]), err_msg=f"{fam}[{l}]")
    # the two-level property views expose the level tuples
    assert hp.n_rounds_intra == hp.n_rounds_lvl[0]
    assert hp.n_rounds_inter == hp.n_rounds_lvl[1]
    np.testing.assert_array_equal(np.asarray(hp.rows_bnd_intra),
                                  np.asarray(hp.rows_bnd_lvl[0]))
    np.testing.assert_array_equal(np.asarray(hp.send_idx_inter),
                                  np.asarray(hp.send_idx_lvl[1]))


def test_depth3_interior_bit_equal_to_flat_plan(lap):
    """The interior criterion (no halo reads) is partition-level, not
    tree-level — the depth-3 interior segment must be bit-identical to
    the flat plan's on the same partition."""
    g, indptr, indices, data = lap
    part = np.random.default_rng(1).integers(0, 8, g.n)
    tp = build_plan_tree(indptr, indices, data, part, None, 8,
                         fanouts=(2, 2, 2))
    fp = build_plan(indptr, indices, data, part, 8)
    for f in ("rows_int", "cols_int", "vals_int", "interior_mask", "diag",
              "rows", "row_mask", "perm"):
        np.testing.assert_array_equal(np.asarray(getattr(tp, f)),
                                      np.asarray(getattr(fp, f)), err_msg=f)


def test_depth3_level_segments_tile_flat_boundary(lap):
    """The h per-level boundary segments exactly tile the PR 2 flat
    boundary set, each level's columns stay inside its slot range, and
    every level-l row reads at least one level-l slot."""
    g, indptr, indices, data = lap
    part = np.random.default_rng(3).integers(0, 8, g.n)
    tp = build_plan_tree(indptr, indices, data, part, None, 8,
                         fanouts=(2, 2, 2))
    fp = build_plan(indptr, indices, data, part, 8)
    offs = tp.level_offsets()
    assert offs[0] == tp.B and len(offs) == tp.h + 1

    def triples(rows, vals):
        keep = np.asarray(vals) != 0
        return sorted(zip(np.asarray(rows)[keep].tolist(),
                          np.asarray(vals)[keep].tolist()))

    for b in range(8):
        flat_bnd = triples(fp.rows_bnd[b], fp.vals_bnd[b])
        per_lvl = [triples(tp.rows_bnd_lvl[l][b], tp.vals_bnd_lvl[l][b])
                   for l in range(tp.h)]
        assert sorted(sum(per_lvl, [])) == flat_bnd
        for l in range(tp.h):
            cl = np.asarray(tp.cols_bnd_lvl[l][b])
            vl = np.asarray(tp.vals_bnd_lvl[l][b])
            rl = np.asarray(tp.rows_bnd_lvl[l][b])
            # level-l reads never exceed level l's slot range
            assert cl.size == 0 or cl[vl != 0].size == 0 or \
                cl[vl != 0].max() < offs[l + 1]
            # every level-l row has >= 1 read in level l's own range
            for r in np.unique(rl[vl != 0]):
                sel = (rl == r) & (vl != 0)
                assert (cl[sel] >= offs[l]).any()


def test_depth3_stripes_outer_rounds_below_flat():
    """The ISSUE acceptance shape: on the stripes-grid partition spanning
    a (2, 2, 2) mesh, the outermost-level round count is strictly below
    the flat plan's total round count — only the root-crossing cut pays
    the slowest links — and the schedule stays exact."""
    g = grid((16, 128))
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    part = (np.arange(g.n) * 8) // g.n           # contiguous stripes
    tp = build_plan_tree(indptr, indices, data, part, None, 8,
                         fanouts=(2, 2, 2))
    fp = build_plan(indptr, indices, data, part, 8)
    assert tp.n_rounds_lvl[-1] >= 1
    assert tp.n_rounds_lvl[-1] < fp.n_rounds
    # middle level is also cheaper than the flat total
    assert tp.n_rounds_lvl[1] < fp.n_rounds
    A = dense_of(indptr, indices, data, g.n)
    x = np.random.default_rng(3).normal(size=g.n)
    np.testing.assert_allclose(tree_spmv_numpy(tp, x),
                               A @ x.astype(np.float32),
                               atol=1e-3, rtol=1e-4)


def test_explicit_ancestor_table_relabels_tree_major(lap):
    """A shuffled (non-contiguous) ancestor table must be relabeled
    tree-major with a correct block_map and still produce an exact
    plan."""
    g, indptr, indices, data = lap
    part = np.random.default_rng(5).integers(0, 8, g.n)
    anc = canonical_ancestors((2, 2, 2))
    perm = np.array([3, 6, 1, 4, 7, 0, 5, 2])
    anc = anc[:, perm]                           # shuffle block columns
    tp = build_plan_tree(indptr, indices, data, part, anc, 8)
    assert tp.fanouts == (2, 2, 2)
    # block_map sorts blocks lexicographically by ancestor path
    order = np.lexsort(tuple(anc[::-1]))
    np.testing.assert_array_equal(tp.block_map[order], np.arange(8))
    # the canonical device-side table is contiguous
    np.testing.assert_array_equal(tp.anc, canonical_ancestors((2, 2, 2)))
    sizes = np.bincount(part, minlength=8)
    np.testing.assert_array_equal(tp.sizes, sizes[order])
    A = dense_of(indptr, indices, data, g.n)
    x = np.random.default_rng(6).normal(size=g.n)
    np.testing.assert_allclose(tree_spmv_numpy(tp, x),
                               A @ x.astype(np.float32),
                               atol=1e-3, rtol=1e-4)


def test_degenerate_levels_have_empty_schedules(lap):
    """fanout-1 levels and single-pod trees produce empty round classes,
    not errors (the pods=1 behavior of PR 3)."""
    g, indptr, indices, data = lap
    part = np.random.default_rng(2).integers(0, 4, g.n)
    tp = build_plan_tree(indptr, indices, data, part, None, 4,
                         fanouts=(1, 2, 2))
    assert tp.n_rounds_lvl[2] == 0               # no root-crossing pairs
    assert not np.asarray(tp.vals_bnd_lvl[2]).any()
    A = dense_of(indptr, indices, data, g.n)
    x = np.random.default_rng(4).normal(size=g.n)
    np.testing.assert_allclose(tree_spmv_numpy(tp, x),
                               A @ x.astype(np.float32),
                               atol=1e-3, rtol=1e-4)


def test_tree_validation_errors(lap):
    g, indptr, indices, data = lap
    part = np.zeros(g.n, dtype=np.int64)
    with pytest.raises(ValueError):              # prod(fanouts) != k
        build_plan_tree(indptr, indices, data, part, None, 8,
                        fanouts=(2, 2))
    with pytest.raises(ValueError):              # non-nested table
        build_plan_tree(indptr, indices, data, part,
                        np.array([[0, 0, 1, 1], [0, 1, 0, 1]]), 4)
    with pytest.raises(ValueError):              # unequal group sizes
        build_plan_tree(indptr, indices, data, part,
                        np.array([[0, 0, 0, 1]]), 4)
    with pytest.raises(ValueError):              # neither tree nor fanouts
        build_plan_tree(indptr, indices, data, part, None, 4)


def test_depth3_matvec_builder_needs_three_axes(lap):
    g, indptr, indices, data = lap
    part = np.random.default_rng(7).integers(0, 8, g.n)
    tp = build_plan_tree(indptr, indices, data, part, None, 8,
                         fanouts=(2, 2, 2))
    with pytest.raises(ValueError):              # two axes < depth 3
        _local_matvec_builder(tp, "hier", ("pod", "pu"))
    with pytest.raises(ValueError):              # flat comm on a TreePlan
        _local_matvec_builder(tp, "halo", "pu")
    # two-level views raise on a depth-3 plan instead of lying
    with pytest.raises(AttributeError):
        tp.n_rounds_intra
    with pytest.raises(AttributeError):
        tp.send_idx_inter


def test_validate_tree_axes_catches_shape_mismatch(lap):
    """Axis mapping is validated by *size*, not count: a mesh whose
    trailing-axis products don't match the plan's fanouts suffixes must
    raise instead of silently misrouting halo words (e.g. a depth-2
    plan from a dropped trivial level on the original 3-axis mesh)."""
    import types
    from repro.sparse.distributed import _validate_tree_axes
    g, indptr, indices, data = lap
    part = np.random.default_rng(11).integers(0, 4, g.n)
    tp = build_plan_tree(indptr, indices, data, part, None, 4,
                         fanouts=(2, 2))

    def mesh_of(shape: dict):
        return types.SimpleNamespace(shape=shape,
                                     axis_names=tuple(shape))

    # matching 2-axis mesh passes; so does an extra mesh axis that
    # subdivides the *innermost* level (the production (pod, data,
    # model) shape of two-level plans)
    _validate_tree_axes(tp, mesh_of({"pod": 2, "pu": 2}), ("pod", "pu"))
    _validate_tree_axes(tp, mesh_of({"pod": 2, "a": 2, "b": 1}),
                        ("pod", "a", "b"))
    # the reproduced failure: (1, 2, 2) mesh — level 0 would ppermute
    # over 4 devices while its schedule spans 2
    with pytest.raises(ValueError):
        _validate_tree_axes(tp, mesh_of({"pod": 1, "host": 2, "pu": 2}),
                            ("pod", "host", "pu"))
    with pytest.raises(ValueError):                  # unknown axis name
        _validate_tree_axes(tp, mesh_of({"pod": 2, "pu": 2}),
                            ("pod", "nope"))
    # depth-3 plan: suffix sizes checked per level
    tp3 = build_plan_tree(indptr, indices, data,
                          np.random.default_rng(12).integers(0, 8, g.n),
                          None, 8, fanouts=(2, 2, 2))
    _validate_tree_axes(tp3, mesh_of({"pod": 2, "host": 2, "pu": 2}),
                        ("pod", "host", "pu"))
    with pytest.raises(ValueError):                  # transposed shape
        _validate_tree_axes(tp3, mesh_of({"pod": 2, "host": 4, "pu": 1}),
                            ("pod", "host", "pu"))


@pytest.mark.parametrize("limit", [0, 777])
def test_tree_sharded_bitmap_path_bit_identical(lap, limit, monkeypatch):
    """build_plan_tree shares build_plan's dense/vertex-sharded bitmap
    extraction: forcing the sharded path must give a bit-identical
    plan at depth 3."""
    import repro.sparse.distributed as dmod
    g, indptr, indices, data = lap
    part = np.random.default_rng(9).integers(0, 8, g.n)
    ref = build_plan_tree(indptr, indices, data, part, None, 8,
                          fanouts=(2, 2, 2))
    monkeypatch.setattr(dmod, "DENSE_PLAN_LIMIT", limit)
    p = dmod.build_plan_tree(indptr, indices, data, part, None, 8,
                             fanouts=(2, 2, 2))
    assert p.round_perms_lvl == ref.round_perms_lvl
    for f in ("perm", "rows", "cols", "vals", "rows_int", "cols_int",
              "vals_int", "interior_mask", "diag"):
        np.testing.assert_array_equal(np.asarray(getattr(p, f)),
                                      np.asarray(getattr(ref, f)),
                                      err_msg=f)
    for l in range(3):
        for fam in ("rows_bnd_lvl", "cols_bnd_lvl", "vals_bnd_lvl",
                    "send_idx_lvl", "send_mask_lvl"):
            np.testing.assert_array_equal(
                np.asarray(getattr(p, fam)[l]),
                np.asarray(getattr(ref, fam)[l]), err_msg=f"{fam}[{l}]")
