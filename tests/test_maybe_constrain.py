"""``models.common.maybe_constrain`` compat-policy regression.

The 0.4.x bug: the old implementation called
``jax.sharding.get_abstract_mesh`` directly (absent on 0.4.x) inside a
blanket ``except Exception: return x`` — so on old JAX every internal
sharding constraint silently vanished (XLA involuntary-remat warnings on
the dry-run), and on current JAX genuine ``logical_to_spec`` errors were
swallowed too.  Now it routes through ``compat.get_ambient_mesh`` /
``compat.manual_axis_names``:

  * no ambient mesh -> identity (single-device tests);
  * ambient mesh -> the constraint is *applied* (committed sharding
    matches the logical rules) on every JAX version;
  * fully-manual shard_map region -> skipped (constraining over manual
    axes is an error);
  * genuine spec bugs (rank mismatch) -> raise instead of no-op.

Device-dependent cases run on 8 forced host devices in a subprocess.
"""
import json
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import maybe_constrain


def test_identity_without_ambient_mesh():
    x = jnp.ones((4, 8))
    assert maybe_constrain(x, ("batch", "act_embed")) is x


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.compat import shard_map, use_mesh
    from repro.models.common import maybe_constrain

    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    out = {}

    # 1) ambient mesh: the constraint must actually be applied — the old
    #    0.4.x code path returned x unconstrained here
    x = jnp.ones((8, 16))
    with use_mesh(mesh):
        y = jax.jit(lambda a: maybe_constrain(
            a, ("batch", "act_embed")))(x)
    expect = NamedSharding(mesh, P("data", None))
    out["constrained"] = y.sharding.is_equivalent_to(expect, 2)

    # 2) fully-manual shard_map region: constraint skipped, no crash
    def body(a):
        return maybe_constrain(a, ("batch", "act_embed")) * 2.0

    with use_mesh(mesh):
        fn = shard_map(body, mesh=mesh,
                       in_specs=(P(("data", "model")),),
                       out_specs=P(("data", "model")))
        z = jax.jit(fn)(jnp.ones((8, 4)))
    out["manual_ok"] = bool(np.allclose(np.asarray(z), 2.0))

    # 3) genuine spec bug (rank mismatch) surfaces instead of no-op
    try:
        with use_mesh(mesh):
            jax.jit(lambda a: maybe_constrain(
                a, ("batch", "seq", "act_embed")))(jnp.ones((8, 16)))
        out["raises_on_rank_mismatch"] = False
    except Exception:
        out["raises_on_rank_mismatch"] = True

    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def device_result():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_constraint_applied_under_ambient_mesh(device_result):
    assert device_result["constrained"], device_result


def test_skipped_inside_manual_shard_map_region(device_result):
    assert device_result["manual_ok"], device_result


def test_spec_errors_surface(device_result):
    assert device_result["raises_on_rank_mismatch"], device_result
