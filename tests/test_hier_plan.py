"""Deterministic tests for the hierarchical (two-level, multi-pod) plan.

Host-only (the ppermute schedules are simulated in NumPy by
``hier_sim.py``); the device-level shard_map execution of ``comm='hier'``
is covered by the 8-device subprocess matrix in tests/test_operator.py.
"""
import numpy as np
import pytest
import scipy.sparse as sp

from hier_sim import hier_spmv_numpy
from repro.core.topology import Topology, contiguous_pods
from repro.sparse.distributed import (HierPlan, build_plan, build_plan_hier,
                                      _local_matvec_builder)
from repro.sparse.generators import grid, rdg
from repro.sparse.graph import laplacian_csr


def dense_of(indptr, indices, data, n):
    a = np.zeros((n, n), dtype=np.float64)
    src = np.repeat(np.arange(n), np.diff(indptr))
    np.add.at(a, (src, indices), data)
    return a


@pytest.fixture(scope="module")
def lap():
    g = rdg(600, seed=11)
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    return g, indptr, indices, data


@pytest.mark.parametrize("k,pods", [(4, 2), (8, 2), (8, 4), (6, 3)])
def test_hier_spmv_matches_dense_oracle(lap, k, pods):
    g, indptr, indices, data = lap
    part = np.random.default_rng(10 * k + pods).integers(0, k, g.n)
    plan = build_plan_hier(indptr, indices, data, part, pods, k)
    assert isinstance(plan, HierPlan)
    assert plan.pods == pods and plan.k_local == k // pods
    A = dense_of(indptr, indices, data, g.n)
    x = np.random.default_rng(2).normal(size=g.n)
    np.testing.assert_allclose(hier_spmv_numpy(plan, x),
                               A @ x.astype(np.float32),
                               atol=1e-3, rtol=1e-4)


def test_interior_bit_equal_to_flat_plan(lap):
    """The interior criterion (no halo reads) is partition-level, not
    pod-level — so the hier interior segment must be bit-identical to the
    flat plan's on the same partition."""
    g, indptr, indices, data = lap
    part = np.random.default_rng(0).integers(0, 8, g.n)
    hp = build_plan_hier(indptr, indices, data, part, 2, 8)
    fp = build_plan(indptr, indices, data, part, 8)
    for f in ("rows_int", "cols_int", "vals_int", "interior_mask", "diag",
              "rows", "row_mask", "perm"):
        np.testing.assert_array_equal(np.asarray(getattr(hp, f)),
                                      np.asarray(getattr(fp, f)), err_msg=f)


def test_intra_inter_tile_flat_boundary(lap):
    """Intra + inter segments exactly tile the PR 2 boundary set: per
    block, the multiset of boundary (row, val) edges is preserved, intra
    columns stay below the inter slot range, and every inter row reads at
    least one inter slot."""
    g, indptr, indices, data = lap
    part = np.random.default_rng(1).integers(0, 8, g.n)
    hp = build_plan_hier(indptr, indices, data, part, 2, 8)
    fp = build_plan(indptr, indices, data, part, 8)
    intra_hi = hp.B + hp.n_rounds_intra * hp.S_intra

    def triples(rows, vals):
        keep = np.asarray(vals) != 0
        return sorted(zip(np.asarray(rows)[keep].tolist(),
                          np.asarray(vals)[keep].tolist()))

    for b in range(8):
        flat_bnd = triples(fp.rows_bnd[b], fp.vals_bnd[b])
        ia = triples(hp.rows_bnd_intra[b], hp.vals_bnd_intra[b])
        ie = triples(hp.rows_bnd_inter[b], hp.vals_bnd_inter[b])
        assert sorted(ia + ie) == flat_bnd
        # intra segment never reads the inter slot range
        ca = np.asarray(hp.cols_bnd_intra[b])[
            np.asarray(hp.vals_bnd_intra[b]) != 0]
        assert ca.size == 0 or ca.max() < intra_hi
        # every inter row has at least one inter-slot read
        ce = np.asarray(hp.cols_bnd_inter[b])
        ve = np.asarray(hp.vals_bnd_inter[b])
        re = np.asarray(hp.rows_bnd_inter[b])
        for r in np.unique(re[ve != 0]):
            assert (ce[(re == r) & (ve != 0)] >= intra_hi).any()


def test_stripes_cut_inter_rounds_below_flat(lap):
    """The acceptance shape: on a locality-preserving partition spanning 2
    pods, the slow inter-pod round count is strictly below the flat plan's
    total round count — only the pod-crossing cut pays the slow links."""
    g = grid((32, 16))
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    part = (np.arange(g.n) * 8) // g.n           # contiguous stripes
    hp = build_plan_hier(indptr, indices, data, part, 2, 8)
    fp = build_plan(indptr, indices, data, part, 8)
    assert hp.n_rounds_inter >= 1
    assert hp.n_rounds_inter < fp.n_rounds
    A = dense_of(indptr, indices, data, g.n)
    x = np.random.default_rng(3).normal(size=g.n)
    np.testing.assert_allclose(hier_spmv_numpy(hp, x),
                               A @ x.astype(np.float32),
                               atol=1e-3, rtol=1e-4)


@pytest.mark.parametrize("limit", [0, 777])
def test_hier_sharded_bitmap_path_bit_identical(lap, limit, monkeypatch):
    """build_plan_hier shares build_plan's dense/vertex-sharded bitmap
    extraction: forcing the sharded path must give a bit-identical plan."""
    import repro.sparse.distributed as dmod
    g, indptr, indices, data = lap
    part = np.random.default_rng(9).integers(0, 8, g.n)
    ref = build_plan_hier(indptr, indices, data, part, 2, 8)
    monkeypatch.setattr(dmod, "DENSE_PLAN_LIMIT", limit)
    p = dmod.build_plan_hier(indptr, indices, data, part, 2, 8)
    assert p.round_perms_intra == ref.round_perms_intra
    assert p.round_perms_inter == ref.round_perms_inter
    for f in ("perm", "rows", "cols", "vals", "rows_int", "cols_int",
              "vals_int", "rows_bnd_intra", "cols_bnd_intra",
              "vals_bnd_intra", "rows_bnd_inter", "cols_bnd_inter",
              "vals_bnd_inter", "send_idx_intra", "send_mask_intra",
              "send_idx_inter", "send_mask_inter", "interior_mask", "diag"):
        np.testing.assert_array_equal(np.asarray(getattr(p, f)),
                                      np.asarray(getattr(ref, f)),
                                      err_msg=f)


def test_single_pod_degenerates_to_intra_only(lap):
    g, indptr, indices, data = lap
    part = np.random.default_rng(2).integers(0, 4, g.n)
    hp = build_plan_hier(indptr, indices, data, part, 1, 4)
    assert hp.n_rounds_inter == 0
    assert not np.asarray(hp.vals_bnd_inter).any()
    A = dense_of(indptr, indices, data, g.n)
    x = np.random.default_rng(4).normal(size=g.n)
    np.testing.assert_allclose(hier_spmv_numpy(hp, x),
                               A @ x.astype(np.float32),
                               atol=1e-3, rtol=1e-4)


def test_explicit_pod_array_relabels_pod_major(lap):
    """An interleaved pod assignment must be relabeled pod-major and still
    produce a correct plan; block_map records the relabeling."""
    g, indptr, indices, data = lap
    part = np.random.default_rng(5).integers(0, 4, g.n)
    pod_of = np.array([0, 1, 0, 1])              # interleaved
    hp = build_plan_hier(indptr, indices, data, part, pod_of, 4)
    assert hp.pods == 2 and hp.k_local == 2
    # original blocks 0,2 -> pod 0 (devices 0,1); 1,3 -> pod 1 (2,3)
    np.testing.assert_array_equal(hp.block_map, [0, 2, 1, 3])
    np.testing.assert_array_equal(hp.pod_of, [0, 0, 1, 1])
    sizes = np.bincount(part, minlength=4)
    np.testing.assert_array_equal(hp.sizes, sizes[[0, 2, 1, 3]])
    A = dense_of(indptr, indices, data, g.n)
    x = np.random.default_rng(6).normal(size=g.n)
    np.testing.assert_allclose(hier_spmv_numpy(hp, x),
                               A @ x.astype(np.float32),
                               atol=1e-3, rtol=1e-4)


def test_pod_validation_errors(lap):
    g, indptr, indices, data = lap
    part = np.zeros(g.n, dtype=np.int64)
    with pytest.raises(ValueError):              # pods must divide k
        build_plan_hier(indptr, indices, data, part, 3, 4)
    with pytest.raises(ValueError):              # unequal pod sizes
        build_plan_hier(indptr, indices, data, part,
                        np.array([0, 0, 0, 1]), 4)


def test_hier_plan_rejects_flat_comm_modes(lap):
    g, indptr, indices, data = lap
    part = np.random.default_rng(7).integers(0, 4, g.n)
    hp = build_plan_hier(indptr, indices, data, part, 2, 4)
    fp = build_plan(indptr, indices, data, part, 4)
    with pytest.raises(ValueError):
        _local_matvec_builder(hp, "halo", "pu")
    with pytest.raises(ValueError):
        _local_matvec_builder(fp, "hier", ("pod", "pu"))
    with pytest.raises(ValueError):              # needs a multi-axis tuple
        _local_matvec_builder(hp, "hier", "pu")


def test_topology_pod_assignment_contiguous():
    topo = Topology.topo1(8, 2 / 8, 8.0, 8.5)
    pods = topo.pod_assignment(2)
    np.testing.assert_array_equal(pods, [0, 0, 0, 0, 1, 1, 1, 1])
    np.testing.assert_array_equal(pods, contiguous_pods(8, 2))
    # the fast PUs are listed first, so contiguous grouping puts both in
    # pod 0 — the fast PUs (heaviest cut) share the fast links
    assert [p.name for p in topo.pus[:2]] == ["fast0", "fast1"]
    assert pods[0] == pods[1] == 0
    with pytest.raises(ValueError):
        contiguous_pods(8, 3)


def test_block_jacobi_inv_inverts_local_blocks(lap):
    """M^-1 from the plan matches dense inversion of the per-PU principal
    submatrices, for flat and hier plans alike."""
    g, indptr, indices, data = lap
    part = np.random.default_rng(8).integers(0, 4, g.n)
    A = sp.csr_matrix((data, indices, indptr), shape=(g.n, g.n))
    for plan in (build_plan(indptr, indices, data, part, 4),
                 build_plan_hier(indptr, indices, data, part, 2, 4)):
        minv = np.asarray(plan.block_jacobi_inv())
        order = np.argsort(np.asarray(plan.perm))   # vertices by padded id
        starts = np.concatenate([[0], np.cumsum(plan.sizes)])
        for b in range(4):
            nb = int(plan.sizes[b])
            mine = order[starts[b]:starts[b] + nb]
            Ab = A[np.ix_(mine, mine)].toarray()
            np.testing.assert_allclose(minv[b, :nb, :nb],
                                       np.linalg.inv(Ab),
                                       atol=1e-4, rtol=1e-3)
            # ghost rows are identity
            np.testing.assert_allclose(minv[b, nb:, nb:],
                                       np.eye(plan.B - nb), atol=1e-6)
