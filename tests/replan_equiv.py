"""Shared helpers for the delta-replanning equality suites.

Not a test module (no ``test_`` prefix): both the deterministic sweeps
(tests/test_replan.py) and the hypothesis suite
(tests/test_replan_properties.py) import these, and conftest.py only
collect-skips ``test_*.py`` files when hypothesis is missing locally.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.distributed import build_plan_tree
from repro.sparse.replan import EdgeDelta, apply_delta_csr, apply_edge_delta

# plan fields that are lazy caches / replan bookkeeping, not plan content
_SKIP_FIELDS = {"_bell", "_bj_inv", "_cols_global", "_replan"}


def _eq(a, b, path: str):
    if a is None or b is None:
        assert a is None and b is None, f"{path}: {a!r} != {b!r}"
        return
    if isinstance(a, (tuple, list)):
        assert isinstance(b, (tuple, list)) and len(a) == len(b), \
            f"{path}: length {len(a)} != {len(b)}"
        for i, (x, y) in enumerate(zip(a, b)):
            _eq(x, y, f"{path}[{i}]")
        return
    if isinstance(a, (int, float, str, bool)):
        assert a == b, f"{path}: {a!r} != {b!r}"
        return
    an, bn = np.asarray(a), np.asarray(b)
    assert an.dtype == bn.dtype, f"{path}: dtype {an.dtype} != {bn.dtype}"
    assert an.shape == bn.shape, f"{path}: shape {an.shape} != {bn.shape}"
    assert np.array_equal(an, bn), \
        f"{path}: values differ at {np.argwhere(an != bn)[:4].tolist()}"


def assert_plan_equal(patched, fresh) -> None:
    """Field-by-field bit equality of two plans (every dataclass field —
    including the ``_pack_*`` packing bookkeeping — except lazy caches)."""
    assert type(patched) is type(fresh)
    for f in dataclasses.fields(fresh):
        if f.name in _SKIP_FIELDS:
            continue
        _eq(getattr(patched, f.name), getattr(fresh, f.name), f.name)


def random_csr(rng: np.random.Generator, n: int, density: float = 0.05):
    """Random symmetric canonical CSR (Laplacian-like: symmetric
    structure, nonzero diagonal) for the mutation suites."""
    m = max(1, int(n * n * density / 2))
    u = rng.integers(0, n, size=m)
    v = rng.integers(0, n, size=m)
    keep = u != v
    u, v = u[keep], v[keep]
    w = rng.uniform(0.5, 2.0, size=len(u))
    src = np.concatenate([u, v, np.arange(n)])
    dst = np.concatenate([v, u, np.arange(n)])
    val = np.concatenate([w, w, rng.uniform(3.0, 9.0, size=n)])
    key = src.astype(np.int64) * n + dst
    order = np.argsort(key, kind="stable")
    key, src, dst, val = key[order], src[order], dst[order], val[order]
    uniq, start = np.unique(key, return_index=True)
    src, dst, val = src[start], dst[start], val[start]
    counts = np.bincount(src, minlength=n)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, dst.astype(np.int32), val.astype(np.float32)


def random_delta(rng: np.random.Generator, indptr, indices, n: int,
                 n_reweight: int = 0, n_add: int = 0, n_drop: int = 0,
                 symmetric: bool = True) -> EdgeDelta:
    """Random mutation batch against a canonical CSR.

    With ``symmetric`` every structural mutation is mirrored (the matrix
    stays structurally symmetric, like a time-stepping mesh); reweights
    are per-entry.  Self-edges (diagonal) can be reweighted but are
    never added/dropped.
    """
    indptr = np.asarray(indptr)
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    dst = np.asarray(indices, dtype=np.int64)
    keys = src * n + dst
    nnz = len(keys)

    set_r, set_c, set_v, drop_r, drop_c = [], [], [], [], []
    used = set()

    if n_reweight and nnz:
        pos = rng.choice(nnz, size=min(n_reweight, nnz), replace=False)
        for p in pos:
            used.add(int(keys[p]))
            set_r.append(int(src[p]))
            set_c.append(int(dst[p]))
            set_v.append(float(rng.uniform(-2.0, 2.0)))

    if n_drop and nnz:
        off = np.flatnonzero(src != dst)
        rng.shuffle(off)
        for p in off:
            if len(drop_r) >= n_drop:
                break
            a, b = int(src[p]), int(dst[p])
            pair = {a * n + b, b * n + a}
            if pair & used:
                continue
            used |= pair
            drop_r.append(a)
            drop_c.append(b)
            if symmetric:
                drop_r.append(b)
                drop_c.append(a)

    added, tries = 0, 0
    while added < n_add and tries < 100 * (n_add + 1):
        tries += 1
        a, b = int(rng.integers(0, n)), int(rng.integers(0, n))
        if a == b:
            continue
        pair = {a * n + b, b * n + a}
        if pair & used:
            continue
        p = int(np.searchsorted(keys, a * n + b))
        if p < nnz and keys[p] == a * n + b:
            continue                      # already present
        used |= pair
        w = float(rng.uniform(0.1, 2.0))
        set_r.append(a)
        set_c.append(b)
        set_v.append(w)
        if symmetric:
            set_r.append(b)
            set_c.append(a)
            set_v.append(w)
        else:
            used.discard(b * n + a)
        added += 1

    return EdgeDelta(n, set_rows=set_r, set_cols=set_c, set_vals=set_v,
                     drop_rows=drop_r, drop_cols=drop_c)


def check_patch_equals_fresh(indptr, indices, data, part, tree, k,
                             delta: EdgeDelta, fanouts=None):
    """The contract: patching == fresh build on the merged CSR.

    Returns (patched, fresh) for further checks.  Both are built under
    whatever REPRO_VALIDATE says (conftest defaults it on), so the plan
    verifier also runs on every patched plan.
    """
    base = build_plan_tree(indptr, indices, data, part, tree, k,
                           fanouts=fanouts)
    patched = apply_edge_delta(base, delta)
    ip2, ix2, d2 = apply_delta_csr(indptr, indices, data, delta)
    fresh = build_plan_tree(ip2, ix2, d2, part, tree, k, fanouts=fanouts)
    assert_plan_equal(patched, fresh)
    return patched, fresh
