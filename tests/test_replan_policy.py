"""Drift-monitor policy tests (ISSUE 10) — NumPy-only, no JAX import.

The :class:`repro.core.replan_policy.DriftMonitor` watches a patched
plan's quality decay (cost-model objective + work imbalance vs the last
full partition's baseline) and decides when delta patching should give
way to a full repartition.
"""
import numpy as np
import pytest

from repro.core.replan_policy import (DriftDecision, DriftMonitor,
                                      DriftPolicy)
from repro.sparse.graph import from_edges, structure_graph


def _path_graph(n=24, w=1.0):
    src = np.arange(n - 1)
    return from_edges(n, src, src + 1, np.full(n - 1, w, np.float32),
                      symmetrize=True)


def _stripes(n, k):
    return ((np.arange(n) * k) // n).astype(np.int32)


def test_observe_before_reset_raises():
    mon = DriftMonitor()
    with pytest.raises(RuntimeError):
        mon.observe(_path_graph(), _stripes(24, 4))


def test_no_drift_no_trip():
    g = _path_graph()
    part = _stripes(g.n, 4)
    mon = DriftMonitor(DriftPolicy(max_objective_ratio=1.5))
    mon.reset(g, part)
    d = mon.observe(g, part)
    assert isinstance(d, DriftDecision)
    assert not d.repartition and d.reason is None
    assert d.objective_ratio == pytest.approx(1.0)
    assert d.imbalance_ratio == pytest.approx(1.0)
    assert d.deltas_since_full == 1


def test_objective_growth_trips():
    """Adding cross-partition edges inflates the cut objective past the
    threshold."""
    g = _path_graph()
    part = _stripes(g.n, 4)
    mon = DriftMonitor(DriftPolicy(max_objective_ratio=1.5))
    mon.reset(g, part)
    # every new edge crosses the outermost boundary
    g2 = g.add_edges(np.arange(4), g.n - 1 - np.arange(4))
    d = mon.observe(g2, part)
    assert d.repartition and "objective" in d.reason
    assert d.objective_ratio > 1.5


def test_imbalance_trips_without_objective_motion():
    """Piling intra-block edges onto one PU moves imbalance, not cut."""
    g = _path_graph(n=32)
    part = _stripes(g.n, 4)
    mon = DriftMonitor(DriftPolicy(max_objective_ratio=50.0,
                                   max_imbalance_ratio=1.2))
    mon.reset(g, part)
    u = np.zeros(6, dtype=np.int64)
    v = np.arange(2, 8, dtype=np.int64)      # all inside block 0
    d = mon.observe(g.add_edges(u, v), part)
    assert d.repartition and "imbalance" in d.reason


def test_max_deltas_trips_unconditionally():
    g = _path_graph()
    part = _stripes(g.n, 4)
    mon = DriftMonitor(DriftPolicy(max_objective_ratio=100.0,
                                   max_imbalance_ratio=100.0,
                                   max_deltas=3))
    mon.reset(g, part)
    assert not mon.observe(g, part).repartition
    assert not mon.observe(g, part).repartition
    d = mon.observe(g, part)
    assert d.repartition and "deltas" in d.reason
    mon.reset(g, part)
    assert mon.deltas_since_full == 0


def test_reset_rebaselines():
    g = _path_graph()
    part = _stripes(g.n, 4)
    mon = DriftMonitor(DriftPolicy(max_objective_ratio=1.5))
    mon.reset(g, part)
    g2 = g.add_edges(np.arange(4), g.n - 1 - np.arange(4))
    assert mon.observe(g2, part).repartition
    mon.reset(g2, part)                       # as after a full repartition
    assert not mon.observe(g2, part).repartition


def test_hierarchical_pricing_uses_ancestors():
    """With an ancestor table the objective prices per-level cuts; a
    pod-crossing edge costs more than a within-pod one under skewed
    lams."""
    g = _path_graph(n=16)
    part = _stripes(g.n, 4)
    anc = np.array([[0, 0, 1, 1]])
    # lams are innermost-first: the pod level is lams[-1]
    pol = DriftPolicy(lams=(1.0, 10.0), max_objective_ratio=1.4)
    inner = DriftMonitor(pol)
    inner.reset(g, part, anc)
    # one extra within-pod cut edge (blocks 0-1) vs one pod-crossing
    within = g.add_edges([3], [4])            # blocks 0 | 1, same pod
    across = g.add_edges([7], [8])            # blocks 1 | 2, pod boundary
    d_within = inner.observe(within, part, anc)
    inner.reset(g, part, anc)
    d_across = inner.observe(across, part, anc)
    assert d_across.objective > d_within.objective


def test_structure_graph_prices_like_rebuilt_graph():
    """The monitor's cheap structure_graph path must price identically to
    a full from_edges rebuild."""
    rng = np.random.default_rng(0)
    n = 30
    u = rng.integers(0, n, 60)
    v = rng.integers(0, n, 60)
    g = from_edges(n, u, v, symmetrize=True)
    # a CSR with an explicit diagonal, like the Laplacians served
    src, dst, w = g.edge_list()
    all_src = np.concatenate([src, np.arange(n)])
    all_dst = np.concatenate([dst, np.arange(n)])
    all_val = np.concatenate([-w, np.full(n, 4.0, np.float32)])
    order = np.lexsort((all_dst, all_src))
    counts = np.bincount(all_src, minlength=n)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    gs = structure_graph(indptr, all_dst[order].astype(np.int32),
                         all_val[order])
    part = _stripes(n, 4)
    a = DriftMonitor()
    a.reset(gs, part)
    b = DriftMonitor()
    b.reset(from_edges(n, src, dst, np.abs(w)), part)
    assert a.baseline == pytest.approx(b.baseline)
