"""NumPy simulation of the hierarchical (arbitrary-depth tree) device
schedule.

Mirrors the ``comm='hier'`` shard_map program in
``repro.sparse.distributed``: interior matvec from the local vector, then
one ppermute round class per tree level — level ``l``'s suffix-linearized
schedule fires independently inside every depth-``(h-1-l)`` subtree (the
shared schedule over the axis suffix), the outermost level over fully
linearized device indices — and per-level boundary accumulations from the
extended vector ``[x_loc | lvl-0 slots | ... | lvl-(h-1) slots]``.  The
two-level (``pods=``) plans of PR 3-4 are the ``h == 2`` instance, so
``hier_ext``/``hier_spmv_numpy`` keep their names and semantics.  Shared
by the deterministic and hypothesis plan suites so hundreds of random
plans are checked without devices.
"""
import numpy as np


def tree_ext(plan, xb):
    """Run every level's round class: (k, B) -> (k, B + sum_l R_l*S_l)."""
    k, B = plan.k, plan.B
    h = plan.h
    offs = plan.level_offsets()
    ext = np.zeros((k, offs[-1]))
    ext[:, :B] = xb
    rows = np.arange(k)[:, None]
    for l in range(h):
        R_l, S_l = plan.n_rounds_lvl[l], plan.S_lvl[l]
        si = np.asarray(plan.send_idx_lvl[l])
        sm = np.asarray(plan.send_mask_lvl[l])
        sz = plan.k // int(np.prod(plan.fanouts[:h - 1 - l]))
        n_sub = k // sz                      # subtrees sharing the schedule
        for c in range(R_l):
            send = xb[rows, si[:, c, :]] * sm[:, c, :]
            recv = np.zeros_like(send)
            for (a, b) in plan.round_perms_lvl[l][c]:  # suffix indices
                for p in range(n_sub):       # fires in every subtree
                    recv[p * sz + b] = send[p * sz + a]
            ext[:, offs[l] + c * S_l:offs[l] + (c + 1) * S_l] = recv
    return ext


def tree_spmv_numpy(plan, x):
    """Execute the full multi-stage tree schedule on a global (n,) x."""
    xb = plan.scatter_vec(x)
    ext = tree_ext(plan, xb)
    y = np.zeros((plan.k, plan.B))
    segs = [(plan.rows_int, plan.cols_int, plan.vals_int)]
    segs += [(plan.rows_bnd_lvl[l], plan.cols_bnd_lvl[l],
              plan.vals_bnd_lvl[l]) for l in range(plan.h)]
    for seg in segs:
        r, c, v = (np.asarray(a) for a in seg)
        for b in range(plan.k):
            np.add.at(y[b], r[b], v[b] * ext[b, c[b]])
    return plan.gather_vec(y * np.asarray(plan.row_mask))


# -- two-level names (the PR 3-4 API) ---------------------------------------

def hier_ext(plan, xb):
    """Run both round classes of an h == 2 plan (tree-general)."""
    return tree_ext(plan, xb)


def hier_spmv_numpy(plan, x):
    """Execute the full three-stage hier schedule on a global (n,) x."""
    return tree_spmv_numpy(plan, x)


def segment_triples(rows, cols, vals, count):
    """The first ``count`` packed (row, col, val) triples of one block."""
    return list(zip(np.asarray(rows)[:count].tolist(),
                    np.asarray(cols)[:count].tolist(),
                    np.asarray(vals)[:count].tolist()))
