"""NumPy simulation of the hierarchical (two-level) device schedule.

Mirrors the ``comm='hier'`` shard_map program in
``repro.sparse.distributed``: interior matvec from the local vector,
intra-pod ppermute rounds (the shared local-index schedule fires in every
pod), inter-pod rounds over linearized device indices, then the intra- and
inter-boundary accumulations from the extended vector
``[x_loc | intra slots | inter slots]``.  Shared by the deterministic and
hypothesis hier-plan suites so hundreds of random plans are checked
without devices.
"""
import numpy as np


def hier_ext(plan, xb):
    """Run both round classes: (k, B) -> (k, B + Ra*Sa + Re*Se)."""
    k, B = plan.k, plan.B
    kl, pods = plan.k_local, plan.pods
    Ra, Sa = plan.n_rounds_intra, plan.S_intra
    Re, Se = plan.n_rounds_inter, plan.S_inter
    sia = np.asarray(plan.send_idx_intra)
    mia = np.asarray(plan.send_mask_intra)
    sie = np.asarray(plan.send_idx_inter)
    mie = np.asarray(plan.send_mask_inter)
    ext = np.zeros((k, B + Ra * Sa + Re * Se))
    ext[:, :B] = xb
    rows = np.arange(k)[:, None]
    for c in range(Ra):
        send = xb[rows, sia[:, c, :]] * mia[:, c, :]
        recv = np.zeros_like(send)
        for (a, b) in plan.round_perms_intra[c]:   # local pairs, every pod
            for p in range(pods):
                recv[p * kl + b] = send[p * kl + a]
        ext[:, B + c * Sa:B + (c + 1) * Sa] = recv
    off = B + Ra * Sa
    for c in range(Re):
        send = xb[rows, sie[:, c, :]] * mie[:, c, :]
        recv = np.zeros_like(send)
        for (s, d) in plan.round_perms_inter[c]:   # linearized device ids
            recv[d] = send[s]
        ext[:, off + c * Se:off + (c + 1) * Se] = recv
    return ext


def hier_spmv_numpy(plan, x):
    """Execute the full three-stage hier schedule on a global (n,) x."""
    xb = plan.scatter_vec(x)
    ext = hier_ext(plan, xb)
    y = np.zeros((plan.k, plan.B))
    for seg in (("rows_int", "cols_int", "vals_int"),
                ("rows_bnd_intra", "cols_bnd_intra", "vals_bnd_intra"),
                ("rows_bnd_inter", "cols_bnd_inter", "vals_bnd_inter")):
        r, c, v = (np.asarray(getattr(plan, f)) for f in seg)
        for b in range(plan.k):
            np.add.at(y[b], r[b], v[b] * ext[b, c[b]])
    return plan.gather_vec(y * np.asarray(plan.row_mask))


def segment_triples(rows, cols, vals, count):
    """The first ``count`` packed (row, col, val) triples of one block."""
    return list(zip(np.asarray(rows)[:count].tolist(),
                    np.asarray(cols)[:count].tolist(),
                    np.asarray(vals)[:count].tolist()))
