"""Hypothesis property tests for the distributed plan builder.

Three invariant families, each on random CSR matrices (varying n, k,
degree, duplicate edges, empty/disconnected blocks):

  * ``build_plan`` — both the single-shot dense-bitmap path and the
    vertex-range-sharded bitmap path it takes beyond DENSE_PLAN_LIMIT —
    stays *bit-identical* to the seed per-edge ``build_plan_reference``
    on every plan field;
  * the interior/boundary split exactly tiles each block's true nnz set,
    preserves packed edge order, keeps interior columns local (< B), and
    extracts the correct diagonal;
  * the overlapped schedule (interior matvec before the halo rounds,
    boundary accumulation after) matches the sequential halo path and the
    dense oracle to < 1e-5 — simulated in NumPy, so hundreds of random
    plans are checked without devices.
"""
import numpy as np
import scipy.sparse as sp
from hypothesis import given, settings, strategies as st

import repro.sparse.distributed as dmod
from repro.sparse.distributed import build_plan, build_plan_reference

SCALAR_FIELDS = ("k", "B", "S", "n_rounds", "n")
ARRAY_FIELDS = ("perm", "block_of", "sizes", "rows", "cols", "vals",
                "row_mask", "send_idx", "send_mask", "rows_int", "cols_int",
                "vals_int", "rows_bnd", "cols_bnd", "vals_bnd",
                "interior_mask", "diag", "nnz_blk", "cols_global")


@st.composite
def csr_system(draw):
    """Random CSR matrix + partition: (indptr, indices, data, part, k)."""
    n = draw(st.integers(min_value=1, max_value=48))
    k = draw(st.integers(min_value=1, max_value=6))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    density = draw(st.floats(min_value=0.0, max_value=0.3))
    blocks_used = draw(st.integers(min_value=1, max_value=k))
    rng = np.random.default_rng(seed)
    m = int(round(density * n * n))
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)        # duplicates summed by scipy
    vals = rng.uniform(0.5, 2.0, size=m)    # positive: no exact-0 cancel
    A = sp.csr_matrix((vals, (src, dst)), shape=(n, n))
    A.sum_duplicates()
    # partition over a random subset of blocks => empty / disconnected
    # blocks occur regularly
    part = rng.permutation(k)[:blocks_used][rng.integers(0, blocks_used,
                                                         size=n)]
    return (A.indptr.astype(np.int64), A.indices.astype(np.int64),
            A.data.astype(np.float32), part.astype(np.int64), k)


def assert_plans_identical(p, ref, tag):
    for f in SCALAR_FIELDS:
        assert getattr(p, f) == getattr(ref, f), (tag, f)
    assert p.round_perms == ref.round_perms, tag
    for f in ARRAY_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(p, f)),
                                      np.asarray(getattr(ref, f)),
                                      err_msg=f"{tag}:{f}")


@settings(max_examples=40, deadline=None)
@given(csr_system())
def test_build_plan_bit_identical_to_reference(system):
    indptr, indices, data, part, k = system
    ref = build_plan_reference(indptr, indices, data, part, k)
    assert_plans_identical(build_plan(indptr, indices, data, part, k),
                           ref, "dense")
    # force the sharded-bitmap extraction path production-scale k*n takes
    old = dmod.DENSE_PLAN_LIMIT
    dmod.DENSE_PLAN_LIMIT = 0
    try:
        p_sharded = dmod.build_plan(indptr, indices, data, part, k)
    finally:
        dmod.DENSE_PLAN_LIMIT = old
    assert_plans_identical(p_sharded, ref, "sharded")


def _valid_triples(rows, cols, vals, count):
    return list(zip(rows[:count].tolist(), cols[:count].tolist(),
                    vals[:count].tolist()))


@settings(max_examples=40, deadline=None)
@given(csr_system())
def test_interior_boundary_tile_local_nnz(system):
    indptr, indices, data, part, k = system
    plan = build_plan(indptr, indices, data, part, k)
    B = plan.B
    rows = np.asarray(plan.rows)
    cols = np.asarray(plan.cols)
    vals = np.asarray(plan.vals)
    ri, ci, vi = (np.asarray(a) for a in (plan.rows_int, plan.cols_int,
                                          plan.vals_int))
    rb, cb, vb = (np.asarray(a) for a in (plan.rows_bnd, plan.cols_bnd,
                                          plan.vals_bnd))
    im = np.asarray(plan.interior_mask)
    for b in range(k):
        nb = int(plan.nnz_blk[b])
        orig = _valid_triples(rows[b], cols[b], vals[b], nb)
        # boundary rows: any edge reading a halo slot (col >= B)
        bnd_rows = {r for r, c, _ in orig if c >= B}
        exp_int = [t for t in orig if t[0] not in bnd_rows]
        exp_bnd = [t for t in orig if t[0] in bnd_rows]
        # split preserves packed order and exactly tiles the nnz set
        assert _valid_triples(ri[b], ci[b], vi[b], len(exp_int)) == exp_int
        assert _valid_triples(rb[b], cb[b], vb[b], len(exp_bnd)) == exp_bnd
        # padding beyond the true counts is all-zero (masked padding rows)
        assert not vi[b, len(exp_int):].any()
        assert not vb[b, len(exp_bnd):].any()
        # interior columns never touch halo slots
        assert len(exp_int) == 0 or ci[b, :len(exp_int)].max() < B
        # interior_mask = real rows minus boundary rows
        real = int(plan.sizes[b])
        expect_mask = np.zeros(B, dtype=np.float32)
        expect_mask[:real] = 1.0
        for r in bnd_rows:
            expect_mask[r] = 0.0
        np.testing.assert_array_equal(im[b], expect_mask)


@settings(max_examples=40, deadline=None)
@given(csr_system())
def test_diag_matches_scipy(system):
    indptr, indices, data, part, k = system
    n = len(indptr) - 1
    plan = build_plan(indptr, indices, data, part, k)
    A = sp.csr_matrix((data, indices, indptr), shape=(n, n))
    d = plan.gather_vec(np.asarray(plan.diag))
    np.testing.assert_allclose(d, A.diagonal().astype(np.float32),
                               atol=1e-6)


# -- NumPy simulation of the device schedules ------------------------------

def _halo_ext(plan, xb):
    """Simulate the edge-colored ppermute rounds: (k, B) -> (k, B+R*S)."""
    k, B, S, R = plan.k, plan.B, plan.S, plan.n_rounds
    send_idx = np.asarray(plan.send_idx)
    send_mask = np.asarray(plan.send_mask)
    ext = np.zeros((k, B + R * S))
    ext[:, :B] = xb
    for c in range(R):
        send = xb[np.arange(k)[:, None],
                  send_idx[:, c, :]] * send_mask[:, c, :]
        recv = np.zeros_like(send)
        for (s, d) in plan.round_perms[c]:
            recv[d] = send[s]
        ext[:, B + c * S:B + (c + 1) * S] = recv
    return ext


def seq_halo_spmv(plan, x):
    """The sequential schedule: exchange all rounds, then one full matvec."""
    xb = plan.scatter_vec(x)
    ext = _halo_ext(plan, xb)
    rows, cols, vals = (np.asarray(a) for a in (plan.rows, plan.cols,
                                                plan.vals))
    y = np.zeros((plan.k, plan.B))
    for b in range(plan.k):
        np.add.at(y[b], rows[b], vals[b] * ext[b, cols[b]])
    return plan.gather_vec(y * np.asarray(plan.row_mask))


def overlapped_halo_spmv(plan, x):
    """The overlapped schedule: interior matvec from x_loc only (issued
    before the rounds on device), boundary accumulation from the extended
    vector afterward."""
    xb = plan.scatter_vec(x)
    ri, ci, vi = (np.asarray(a) for a in (plan.rows_int, plan.cols_int,
                                          plan.vals_int))
    rb, cb, vb = (np.asarray(a) for a in (plan.rows_bnd, plan.cols_bnd,
                                          plan.vals_bnd))
    y = np.zeros((plan.k, plan.B))
    for b in range(plan.k):
        np.add.at(y[b], ri[b], vi[b] * xb[b, ci[b]])   # no halo dependence
    ext = _halo_ext(plan, xb)
    for b in range(plan.k):
        np.add.at(y[b], rb[b], vb[b] * ext[b, cb[b]])
    return plan.gather_vec(y * np.asarray(plan.row_mask))


@settings(max_examples=40, deadline=None)
@given(csr_system())
def test_overlapped_matches_sequential_and_dense(system):
    indptr, indices, data, part, k = system
    n = len(indptr) - 1
    plan = build_plan(indptr, indices, data, part, k)
    A = sp.csr_matrix((data, indices, indptr), shape=(n, n))
    x = np.random.default_rng(0).normal(size=n).astype(np.float32)
    y_seq = seq_halo_spmv(plan, x)
    y_ovl = overlapped_halo_spmv(plan, x)
    scale = max(np.abs(y_seq).max(), 1.0)
    assert np.abs(y_ovl - y_seq).max() / scale < 1e-5
    np.testing.assert_allclose(y_ovl, A @ x, atol=1e-3, rtol=1e-4)
