"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.pdist import pairwise_sqdist_pallas
from repro.kernels.spmv_bell import csr_to_block_ell, spmv_block_ell


@pytest.mark.parametrize("n,k,d", [(32, 8, 2), (100, 7, 3), (257, 33, 2),
                                   (512, 128, 3), (65, 1, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pdist_shapes(n, k, d, dtype):
    rng = np.random.default_rng(n + k)
    x = jnp.asarray(rng.normal(size=(n, d)), dtype)
    c = jnp.asarray(rng.normal(size=(k, d)), dtype)
    got = pairwise_sqdist_pallas(x, c, interpret=True)
    want = ref.pairwise_sqdist_ref(x, c)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)


@settings(max_examples=20, deadline=None)
@given(st.integers(8, 200), st.integers(1, 24))
def test_pdist_property(n, k):
    rng = np.random.default_rng(n * 131 + k)
    x = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(k, 2)), jnp.float32)
    got = np.asarray(pairwise_sqdist_pallas(x, c, interpret=True))
    assert got.shape == (n, k)
    assert np.all(got >= -1e-4)             # distances non-negative
    want = np.asarray(ref.pairwise_sqdist_ref(x, c))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("n,density,bm,bk", [
    (64, 0.1, 8, 128), (300, 0.02, 8, 128), (513, 0.01, 8, 128),
    (128, 0.05, 16, 128), (200, 0.03, 8, 256),
])
def test_spmv_block_ell(n, density, bm, bk):
    from scipy.sparse import random as sprand
    A = sprand(n, n, density=density, random_state=n, format="csr")
    A = (A + A.T).tocsr()
    blocks, cols, meta = csr_to_block_ell(A.indptr, A.indices,
                                          A.data.astype(np.float32), n,
                                          bm=bm, bk=bk)
    assert meta["fill"] == 1.0               # lossless conversion
    rng = np.random.default_rng(n)
    x = rng.normal(size=n).astype(np.float32)
    got = np.asarray(spmv_block_ell(jnp.asarray(blocks), jnp.asarray(cols),
                                    jnp.asarray(x), interpret=True))
    want = A @ x
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
    # oracle agrees too
    want2 = np.asarray(ref.spmv_block_ell_ref(jnp.asarray(blocks),
                                              jnp.asarray(cols),
                                              jnp.asarray(x)))
    np.testing.assert_allclose(got, want2, atol=1e-4, rtol=1e-4)


def test_spmv_empty_rows():
    """Rows with no nonzeros must produce exact zeros."""
    n = 40
    indptr = np.zeros(n + 1, dtype=np.int64)
    indptr[2:] = 3                           # only row 1 has entries
    indices = np.array([0, 5, 7], dtype=np.int32)
    data = np.array([1.0, 2.0, 3.0], dtype=np.float32)
    blocks, cols, _ = csr_to_block_ell(indptr, indices, data, n)
    x = np.arange(n, dtype=np.float32)
    y = np.asarray(spmv_block_ell(jnp.asarray(blocks), jnp.asarray(cols),
                                  jnp.asarray(x), interpret=True))
    assert y[1] == pytest.approx(0 * 1 + 5 * 2 + 7 * 3)
    assert np.all(y[2:] == 0) and y[0] == 0


def test_ops_wrappers():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(50, 3)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(6, 3)), jnp.float32)
    d = ops.pairwise_sqdist(x, c)
    assert d.shape == (50, 6)


def test_flash_attention_kernel():
    from repro.kernels.flash import flash_attention
    rng = np.random.default_rng(1)
    B, H, S, D = 2, 4, 256, 64
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("S,causal", [(128, True), (128, False),
                                      (384, True)])
def test_flash_attention_sweep(S, causal):
    from repro.kernels.flash import flash_attention
    rng = np.random.default_rng(S)
    B, H, D = 1, 2, 64
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)
