"""Dry-run machinery smoke test — subprocess with 16 forced host devices and
a reduced 2x2 mesh + smoke configs (the production 512-device sweep lives in
experiments/, driven by launch/dryrun.py)."""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json
    import jax
    import numpy as np
    from repro.configs.registry import get_config
    from repro.launch.dryrun import _lower
    from repro.launch.roofline import analyze_compiled

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    out = {}
    for arch, mode, B, S in [
        ("qwen1.5-0.5b", "train", 4, 64),
        ("mamba2-130m", "decode", 4, 128),
        ("olmoe-1b-7b", "prefill", 4, 64),
        ("whisper-tiny", "train", 4, 64),
        ("recurrentgemma-2b", "decode", 4, 128),
    ]:
        cfg = get_config(arch, smoke=True)
        lowered, compiled = _lower(cfg, mode, B, S, mesh)
        rec = analyze_compiled(lowered, compiled)
        out[f"{arch}:{mode}"] = {
            "flops": rec["hlo_flops"], "bytes": rec["hlo_bytes"],
            "coll": sum(rec["collectives"].values()),
        }
    # multi-pod-shaped mesh too
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    _, compiled = _lower(cfg, "train", 8, 64, mesh3)
    out["multipod"] = {"ok": True}
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def results():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_all_modes_compile(results):
    for key in ("qwen1.5-0.5b:train", "mamba2-130m:decode",
                "olmoe-1b-7b:prefill", "whisper-tiny:train",
                "recurrentgemma-2b:decode"):
        assert key in results
        assert results[key]["flops"] > 0
        assert results[key]["bytes"] > 0


def test_sharded_program_has_collectives(results):
    assert results["qwen1.5-0.5b:train"]["coll"] > 0


def test_multipod_mesh_compiles(results):
    assert results["multipod"]["ok"]
