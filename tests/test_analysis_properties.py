"""Hypothesis property tests for the plan verifier (ISSUE 6 tentpole).

Over random CSR systems (the ``test_tree_properties`` generator idiom:
varying n, fanouts of depth 1-4, shuffled non-contiguous ancestor
tables, duplicate edges, empty blocks):

  * every plan the builders produce verifies clean (flat, reference,
    tree — and the tree plan's mesh/axis folding checks out against its
    canonical mesh shape);
  * a randomly chosen seeded corruption of one plan field is always
    caught, with a diagnostic from that corruption's expected code set
    (the ISSUE mutation classes: color swaps, broken permutations, slot
    aliasing, ghost sends, dropped level structure, segment tampering).
"""
import dataclasses

import numpy as np
import scipy.sparse as sp
from hypothesis import assume, given, settings, strategies as st

from repro.analysis import check_mesh_axes, verify_plan
from repro.core.topology import canonical_ancestors
from repro.launch.mesh import tree_axis_names
from repro.sparse.distributed import build_plan, build_plan_tree

FANOUTS = [(2,), (4,), (2, 2), (2, 3), (3, 2), (2, 2, 2), (1, 2, 2),
           (2, 2, 2, 2)]


@st.composite
def tree_csr_system(draw):
    """Random CSR + partition + shuffled nested ancestor table."""
    fanouts = draw(st.sampled_from(FANOUTS))
    k = int(np.prod(fanouts))
    n = draw(st.integers(min_value=1, max_value=48))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    density = draw(st.floats(min_value=0.0, max_value=0.3))
    blocks_used = draw(st.integers(min_value=1, max_value=k))
    rng = np.random.default_rng(seed)
    m = int(round(density * n * n))
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    vals = rng.uniform(0.5, 2.0, size=m)
    A = sp.csr_matrix((vals, (src, dst)), shape=(n, n))
    A.sum_duplicates()
    part = rng.permutation(k)[:blocks_used][rng.integers(0, blocks_used,
                                                         size=n)]
    anc = canonical_ancestors(fanouts)[:, rng.permutation(k)]
    return (A.indptr.astype(np.int64), A.indices.astype(np.int64),
            A.data.astype(np.float32), part.astype(np.int64), k, fanouts,
            anc)


@settings(max_examples=40, deadline=None)
@given(tree_csr_system())
def test_built_plans_verify_clean(system):
    indptr, indices, data, part, k, fanouts, anc = system
    tp = build_plan_tree(indptr, indices, data, part, anc, k,
                         validate=False)
    fp = build_plan(indptr, indices, data, part, k, validate=False)
    for plan in (tp, fp):
        rep = verify_plan(plan)
        assert rep.ok, str(rep)
    axes = tree_axis_names(tp.h)
    mesh = dict(zip(axes, tp.fanouts))
    rep = check_mesh_axes(tp, mesh, axes)
    assert rep.ok, str(rep)


def _corrupt_send_idx(plan, rng):
    sizes = np.asarray(plan.sizes)
    for l in rng.permutation(plan.h):
        mask = np.asarray(plan.send_mask_lvl[l])
        live = np.argwhere(mask > 0)
        if len(live):
            b, c, s = live[rng.integers(len(live))]
            idx = np.asarray(plan.send_idx_lvl[l]).copy()
            idx[b, c, s] = sizes[b]
            si = list(plan.send_idx_lvl)
            si[l] = idx
            return (dataclasses.replace(plan, send_idx_lvl=tuple(si)),
                    {"PLAN005", "PLAN009"})
    return None


def _corrupt_round_perm(plan, rng):
    for l in rng.permutation(plan.h):
        perms = [list(r) for r in plan.round_perms_lvl[l]]
        full = [i for i, r in enumerate(perms) if r]
        if not full:
            continue
        c = full[rng.integers(len(full))]
        a, b = perms[c][rng.integers(len(perms[c]))]
        perms[c] = perms[c] + [(a, b)]           # duplicate delivery
        new = list(plan.round_perms_lvl)
        new[l] = tuple(tuple(r) for r in perms)
        return (dataclasses.replace(plan, round_perms_lvl=tuple(new)),
                {"PLAN004"})
    return None


def _corrupt_drop_level(plan, rng):
    if plan.h < 2:
        return None
    return (dataclasses.replace(plan, S_lvl=plan.S_lvl[:-1]),
            {"PLAN002"})


def _corrupt_alias_slot(plan, rng):
    cols = np.asarray(plan.cols).copy()
    nnz = np.asarray(plan.nnz_blk)
    B = plan.B
    for b in rng.permutation(plan.k):
        ext = np.flatnonzero(cols[b, :nnz[b]] >= B)
        two = np.unique(cols[b, ext])
        if len(two) >= 2:
            e = ext[cols[b, ext] == two[0]][0]
            cols[b, e] = two[1]
            return (dataclasses.replace(plan, cols=cols),
                    {"PLAN009", "PLAN008"})
    return None


def _corrupt_segment_value(plan, rng):
    for l in rng.permutation(plan.h):
        vals = np.asarray(plan.vals_bnd_lvl[l])
        live = np.argwhere(vals != 0)
        if len(live):
            b, e = live[rng.integers(len(live))]
            v = vals.copy()
            v[b, e] += 1.0
            vb = list(plan.vals_bnd_lvl)
            vb[l] = v
            return (dataclasses.replace(plan, vals_bnd_lvl=tuple(vb)),
                    {"PLAN008"})
    return None


_CORRUPTIONS = [_corrupt_send_idx, _corrupt_round_perm,
                _corrupt_drop_level, _corrupt_alias_slot,
                _corrupt_segment_value]


@settings(max_examples=40, deadline=None)
@given(tree_csr_system(),
       st.integers(min_value=0, max_value=len(_CORRUPTIONS) - 1),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_seeded_corruption_is_caught(system, which, cseed):
    indptr, indices, data, part, k, fanouts, anc = system
    plan = build_plan_tree(indptr, indices, data, part, anc, k,
                           validate=False)
    assert verify_plan(plan).ok
    out = _CORRUPTIONS[which](plan, np.random.default_rng(cseed))
    assume(out is not None)        # corruption not expressible here
    bad, expected = out
    rep = verify_plan(bad)
    assert not rep.ok
    assert rep.codes() & expected, (
        f"{_CORRUPTIONS[which].__name__} expected one of {expected}, "
        f"got {rep.codes()}: {rep}")
