"""Roofline HLO parsing: collective byte accounting (f32-promotion
resolution) and the structural byte counter."""
import numpy as np

from repro.launch.roofline import (collective_bytes, roofline_terms,
                                   structural_bytes)

HLO = """\
HloModule test

%add.1.clone_promoted (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%fused_computation.1 (p: bf16[8,16]) -> f32[8,16] {
  %p = bf16[8,16] parameter(0)
  ROOT %c = f32[8,16] convert(%p)
}

ENTRY %main (x: bf16[8,16], y: f32[4,4]) -> f32[8,16] {
  %x = bf16[8,16]{1,0} parameter(0)
  %y = f32[4,4]{1,0} parameter(1)
  %convert_fusion = f32[8,16]{1,0} fusion(%x), kind=kLoop, calls=%fused_computation.1
  %ag = f32[8,16]{1,0} all-gather(%convert_fusion), channel_id=1, dimensions={0}
  %ar = f32[8,16]{1,0} all-reduce(%ag), channel_id=2, to_apply=%add.1.clone_promoted
  %ar2 = f32[4,4]{1,0} all-reduce(%y), channel_id=3, to_apply=%add.1.clone
  %dot = f32[8,16]{1,0} dot(%ar, %ar), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  %cv = bf16[8,16]{1,0} convert(%dot)
  ROOT %out = f32[8,16]{1,0} convert(%cv)
}
"""


class TestCollectiveBytes:
    def test_raw_counts_f32(self):
        raw = collective_bytes(HLO, resolve_promotion=False)
        assert raw["all-gather"] == 8 * 16 * 4
        assert raw["all-reduce"] == 8 * 16 * 4 + 4 * 4 * 4

    def test_promoted_payloads_halved(self):
        res = collective_bytes(HLO, resolve_promotion=True)
        # all-gather fed by a convert-fusion of a bf16 value -> bf16 width
        assert res["all-gather"] == 8 * 16 * 2
        # first all-reduce uses a "_promoted" reducer -> halved;
        # second is genuine f32 -> full width
        assert res["all-reduce"] == 8 * 16 * 2 + 4 * 4 * 4

    def test_allreduce_counts_double_in_terms(self):
        coll = {"all-gather": 100, "all-reduce": 100, "reduce-scatter": 0,
                "all-to-all": 0, "collective-permute": 0}
        t = roofline_terms(0.0, 0.0, coll)
        assert np.isclose(t["collective_bytes"], 300)  # AR moves 2x


class TestStructuralBytes:
    def test_skips_cpu_artifacts(self):
        total, s2 = structural_bytes(HLO)
        # entry ops counted: fusion(8x16 f32), ag, ar, ar2, dot — each 2x
        # output bytes; converts / parameters skipped
        expected = 2 * (8 * 16 * 4) * 4 + 2 * (4 * 4 * 4)
        assert total == expected
        assert s2 == 0.0

    def test_s2_detection(self):
        hlo = """\
ENTRY %main (q: bf16[2,64,64]) -> f32[2,64,64] {
  %q = bf16[2,64,64]{2,1,0} parameter(0)
  ROOT %dot = f32[2,64,64]{2,1,0} dot(%q, %q), lhs_contracting_dims={2}, rhs_contracting_dims={2}
}
"""
        total, s2 = structural_bytes(hlo, s2_dim=64)
        assert s2 == 2 * (2 * 64 * 64 * 4)
        assert total == s2
        # different seq -> no match
        _, s2b = structural_bytes(hlo, s2_dim=128)
        assert s2b == 0.0
