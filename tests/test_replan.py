"""Deterministic delta-replanning suite (ISSUE 10).

The contract under test is *bit-level*: ``apply_edge_delta(plan, delta)``
must equal ``build_plan_tree`` on the mutated CSR field-by-field (same
dtypes, same packed-edge order, same schedules, same float bits) at every
tree depth.  ``tests/test_replan_properties.py`` is the hypothesis
counterpart over random mutation batches; this module pins the seeded
sweeps and the adversarial shapes (chained patches, emptied levels,
emptied rows) plus the ``EdgeDelta`` validation surface.

Host-side NumPy only — no devices (conftest's ``REPRO_VALIDATE=1``
additionally runs the PLAN001-010 verifier on every plan built here).
"""
import dataclasses

import numpy as np
import pytest

from repro.sparse.distributed import build_plan_tree
from repro.sparse.graph import from_edges, structure_graph
from repro.sparse.replan import (EdgeDelta, apply_delta_csr,
                                 apply_edge_delta)

from replan_equiv import (assert_plan_equal, check_patch_equals_fresh,
                          random_csr, random_delta)

DEPTHS = [(4, (4,)), (4, (2, 2)), (8, (2, 2, 2))]
CASES = [
    ("reweight", dict(n_reweight=6)),
    ("add", dict(n_add=4)),
    ("drop", dict(n_drop=4)),
    ("mixed", dict(n_reweight=5, n_add=3, n_drop=3)),
    ("asymmetric", dict(n_add=3, n_drop=2, symmetric=False)),
]


@pytest.mark.parametrize("k,fanouts", DEPTHS)
@pytest.mark.parametrize("case,kwargs", CASES)
def test_patch_equals_fresh(k, fanouts, case, kwargs):
    rng = np.random.default_rng(hash((k, case)) % 2**32)
    n = 48 if k == 4 else 64
    for _ in range(3):
        ip, ix, d = random_csr(rng, n, density=0.08)
        part = rng.integers(0, k, size=n).astype(np.int32)
        delta = random_delta(rng, ip, ix, n, **kwargs)
        if len(delta) == 0:
            continue
        check_patch_equals_fresh(ip, ix, d, part, None, k, delta,
                                 fanouts=fanouts)


def test_chained_patches_stay_exact():
    """Five sequential patches (each on the previous patch's output) keep
    bit-equality — the patched replan cache is itself patch-ready."""
    rng = np.random.default_rng(7)
    n, k, fanouts = 64, 8, (2, 4)
    ip, ix, d = random_csr(rng, n, density=0.08)
    part = rng.integers(0, k, size=n).astype(np.int32)
    plan = build_plan_tree(ip, ix, d, part, None, k, fanouts=fanouts)
    for _ in range(5):
        delta = random_delta(rng, ip, ix, n, n_reweight=4, n_add=3,
                             n_drop=2)
        plan = apply_edge_delta(plan, delta)
        ip, ix, d = apply_delta_csr(ip, ix, d, delta)
        fresh = build_plan_tree(ip, ix, d, part, None, k, fanouts=fanouts)
        assert_plan_equal(plan, fresh)


def _grid_csr(n_side=8, k=4):
    from repro.sparse.generators import grid
    from repro.sparse.graph import laplacian_csr

    g = grid((n_side, n_side))
    ip, ix, d = laplacian_csr(g, shift=0.1)
    n = g.n
    part = ((np.arange(n) * k) // n).astype(np.int32)
    return ip, ix, d, part, n


def test_emptying_a_level_matches_fresh():
    """Dropping every cross-edge of the outermost level leaves that level
    with an empty schedule — identical to the fresh build's."""
    ip, ix, d, part, n = _grid_csr()
    src = np.repeat(np.arange(n), np.diff(ip))
    cross = (part[src] < 2) != (part[ix] < 2)
    delta = EdgeDelta(n, drop_rows=src[cross], drop_cols=ix[cross])
    patched, _fresh = check_patch_equals_fresh(ip, ix, d, part, None, 4,
                                               delta, fanouts=(2, 2))
    assert min(int(r) for r in patched.n_rounds_lvl) == 0


def test_emptying_a_row_matches_fresh():
    ip, ix, d, part, n = _grid_csr()
    src = np.repeat(np.arange(n), np.diff(ip))
    m = (src == 9) & (ix != 9)
    delta = EdgeDelta(n, drop_rows=np.concatenate([src[m], ix[m]]),
                      drop_cols=np.concatenate([ix[m], src[m]]))
    check_patch_equals_fresh(ip, ix, d, part, None, 4, delta,
                             fanouts=(2, 2))


def test_patched_cache_passes_plan010():
    """The patched plan's replan cache stays verifier-consistent, and a
    corrupted cache is caught (PLAN010)."""
    from repro.analysis.verify import verify_plan

    ip, ix, d, part, n = _grid_csr()
    plan = build_plan_tree(ip, ix, d, part, None, 4, fanouts=(2, 2))
    delta = EdgeDelta(n, set_rows=[0, 1], set_cols=[1, 0],
                      set_vals=[-0.25, -0.25])
    patched = apply_edge_delta(plan, delta)
    assert verify_plan(patched).ok
    bad = dataclasses.replace(
        patched, _replan=dataclasses.replace(
            patched._replan, per_blk=patched._replan.per_blk + 1))
    rep = verify_plan(bad)
    assert not rep.ok
    assert any("PLAN010" in str(x) for x in rep.diagnostics)


def test_migrate_state_permutes_exactly():
    """Solver state moved between plans with *different* partitions keeps
    every value — only the layout changes (the post-repartition
    warm-start path)."""
    from repro.sparse.replan import migrate_state

    ip, ix, d, part, n = _grid_csr()
    rng = np.random.default_rng(13)
    part2 = rng.integers(0, 4, size=n).astype(np.int32)
    old = build_plan_tree(ip, ix, d, part, None, 4, fanouts=(2, 2))
    new = build_plan_tree(ip, ix, d, part2, None, 4, fanouts=(2, 2))
    x = rng.normal(size=n).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    xs, ys = old.scatter_vec(x), old.scatter_vec(y)
    moved = migrate_state(old, new, xs)          # single array: unwrapped
    assert np.array_equal(np.asarray(new.gather_vec(moved)), x)
    mx, my = migrate_state(old, new, xs, ys)     # tuple in, tuple out
    assert np.array_equal(np.asarray(new.gather_vec(mx)), x)
    assert np.array_equal(np.asarray(new.gather_vec(my)), y)
    # size-mismatched plans refuse to migrate
    ip3, ix3, d3 = random_csr(np.random.default_rng(1), n + 8,
                              density=0.1)
    part3 = np.zeros(n + 8, np.int32)
    other = build_plan_tree(ip3, ix3, d3, part3, None, 4, fanouts=(2, 2))
    with pytest.raises(ValueError):
        migrate_state(old, other, xs)


# --------------------------------------------------------------------------
# EdgeDelta / apply_delta_csr surface
# --------------------------------------------------------------------------

def test_edge_delta_validation():
    with pytest.raises(ValueError):            # set/drop overlap
        EdgeDelta(4, set_rows=[0], set_cols=[1], set_vals=[1.0],
                  drop_rows=[0], drop_cols=[1])
    with pytest.raises(ValueError):            # duplicate set key
        EdgeDelta(4, set_rows=[0, 0], set_cols=[1, 1], set_vals=[1.0, 2.0])
    with pytest.raises(ValueError):            # out of range
        EdgeDelta(4, set_rows=[4], set_cols=[0], set_vals=[1.0])
    with pytest.raises(ValueError):            # ragged set triple
        EdgeDelta(4, set_rows=[0], set_cols=[1, 2], set_vals=[1.0])
    assert len(EdgeDelta(4)) == 0


def test_apply_delta_csr_matches_dense():
    rng = np.random.default_rng(3)
    n = 12
    ip, ix, d = random_csr(rng, n, density=0.2)
    delta = random_delta(rng, ip, ix, n, n_reweight=3, n_add=2, n_drop=2)
    ip2, ix2, d2 = apply_delta_csr(ip, ix, d, delta)

    dense = np.zeros((n, n), dtype=np.float64)
    src = np.repeat(np.arange(n), np.diff(ip))
    dense[src, ix] = d
    dense2 = np.zeros((n, n), dtype=np.float64)
    dense2[np.repeat(np.arange(n), np.diff(ip2)), ix2] = d2
    expect = dense.copy()
    expect[np.asarray(delta.set_rows), np.asarray(delta.set_cols)] = \
        np.asarray(delta.set_vals)
    expect[np.asarray(delta.drop_rows, dtype=np.int64),
           np.asarray(delta.drop_cols, dtype=np.int64)] = 0.0
    np.testing.assert_allclose(dense2, expect)
    assert d2.dtype == d.dtype and ix2.dtype == ix.dtype
    assert ip2.dtype == ip.dtype


def test_delta_diff_roundtrip():
    """EdgeDelta.diff(old, new) reproduces new when applied to old."""
    rng = np.random.default_rng(5)
    n = 16
    ip, ix, d = random_csr(rng, n, density=0.15)
    fwd = random_delta(rng, ip, ix, n, n_reweight=3, n_add=2, n_drop=2)
    ip2, ix2, d2 = apply_delta_csr(ip, ix, d, fwd)
    back = EdgeDelta.diff(ip, ix, d, ip2, ix2, d2)
    ip3, ix3, d3 = apply_delta_csr(ip, ix, d, back)
    assert np.array_equal(ip2, ip3) and np.array_equal(ix2, ix3)
    assert np.array_equal(d2, d3)


def test_drop_missing_edge_raises():
    ip, ix, d, _part, n = _grid_csr()
    with pytest.raises(KeyError):
        apply_delta_csr(ip, ix, d, EdgeDelta(n, drop_rows=[0],
                                             drop_cols=[n - 1]))


def test_patch_without_cache_or_wrong_n_raises():
    ip, ix, d, part, n = _grid_csr()
    plan = build_plan_tree(ip, ix, d, part, None, 4, fanouts=(2, 2),
                           cache=False)
    delta = EdgeDelta(n, set_rows=[0], set_cols=[1], set_vals=[-1.0])
    with pytest.raises(ValueError):
        apply_edge_delta(plan, delta)
    cached = build_plan_tree(ip, ix, d, part, None, 4, fanouts=(2, 2))
    with pytest.raises(ValueError):
        apply_edge_delta(cached, EdgeDelta(n + 1, set_rows=[0],
                                           set_cols=[1], set_vals=[1.0]))


# --------------------------------------------------------------------------
# Graph edge-mutation helpers
# --------------------------------------------------------------------------

def test_graph_mutation_helpers():
    g = from_edges(5, [0, 1, 2, 3], [1, 2, 3, 4], symmetrize=True)
    g2 = g.add_edges([0], [4], [2.0])
    assert g2.num_edges == g.num_edges + 1
    pos = g2._edge_positions([0], [4])
    assert g2.weights[pos[0]] == 2.0
    g3 = g2.remove_edges([0], [4])
    assert g3.num_edges == g.num_edges
    with pytest.raises(KeyError):
        g3.remove_edges([0], [4])
    g4 = g.reweight_edges([1], [2], [7.0])
    assert g4.weights[g4._edge_positions([1], [2])[0]] == 7.0
    assert g4.weights[g4._edge_positions([2], [1])[0]] == 7.0
    assert g4.indices is g.indices          # structure shared
    g4.validate()


def test_structure_graph_matches_from_edges():
    rng = np.random.default_rng(11)
    n = 20
    ip, ix, d = random_csr(rng, n, density=0.15)
    g = structure_graph(ip, ix, d)
    src = np.repeat(np.arange(n), np.diff(ip))
    off = src != ix
    ref = from_edges(n, src[off], ix[off], np.abs(d[off]))
    assert np.array_equal(g.indptr, ref.indptr)
    assert np.array_equal(g.indices, ref.indices)
    np.testing.assert_allclose(g.weights, ref.weights)
    g.validate()
