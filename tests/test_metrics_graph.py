"""Graph container, generators, and partition metrics on known instances."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.metrics import (boundary_mask, comm_volumes, edge_cut,
                                max_comm_volume, total_comm_volume)
from repro.core.refinement import greedy_edge_coloring, quotient_graph
from repro.sparse.generators import GENERATORS, grid, rdg, rgg
from repro.sparse.graph import Graph, from_edges, laplacian_csr


def path_graph(n):
    return from_edges(n, np.arange(n - 1), np.arange(1, n),
                      symmetrize=True)


def test_from_edges_symmetric_dedup():
    g = from_edges(3, [0, 0, 1, 0], [1, 1, 2, 0], symmetrize=True)
    g.validate()
    assert g.num_edges == 2                   # dedup + self-loop dropped
    assert g.degrees.tolist() == [1, 2, 1]


def test_edge_cut_path():
    g = path_graph(10)
    part = np.array([0] * 5 + [1] * 5)
    assert edge_cut(g, part) == 1.0
    assert max_comm_volume(g, part, 2) == 1
    assert boundary_mask(g, part).sum() == 2


def test_comm_volume_star():
    """Star: center in block 0, leaves in k-1 other blocks — each leaf block
    receives 1 (the center); block 0 receives all leaves."""
    n = 9
    g = from_edges(n, np.zeros(8, int), np.arange(1, 9), symmetrize=True)
    part = np.array([0, 1, 1, 2, 2, 3, 3, 4, 4])
    cv = comm_volumes(g, part, 5)
    assert cv[0] == 8
    assert np.all(cv[1:] == 1)
    assert total_comm_volume(g, part, 5) == 12


def test_quotient_and_coloring():
    g = grid((6, 6))
    part = (np.arange(36) // 9).astype(np.int32)    # 4 blocks
    pairs, w = quotient_graph(g, part, 4)
    assert len(pairs) >= 3
    colors = greedy_edge_coloring(pairs, w)
    # proper edge coloring: no two same-colored edges share a block
    for c in range(colors.max() + 1):
        seen = set()
        for e in np.nonzero(colors == c)[0]:
            a, b = pairs[e]
            assert a not in seen and b not in seen
            seen.update((int(a), int(b)))


@pytest.mark.parametrize("name", sorted(GENERATORS))
def test_generators_valid(name):
    g = GENERATORS[name](800, seed=1)
    g.validate()
    assert g.n > 100
    assert g.num_edges > g.n * 0.8
    assert g.coords is not None


def test_laplacian_spd():
    g = rdg(300, seed=2)
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    import scipy.sparse as sp
    L = sp.csr_matrix((data, indices, indptr), shape=(g.n, g.n)).toarray()
    assert np.allclose(L, L.T, atol=1e-5)
    w = np.linalg.eigvalsh(L)
    assert w.min() > 0                       # positive definite after shift


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 6), st.integers(20, 120))
def test_cut_invariant_relabel(k, n):
    """Edge cut is invariant under block relabeling."""
    g = path_graph(n)
    rng = np.random.default_rng(n * k)
    part = rng.integers(0, k, n).astype(np.int32)
    perm = rng.permutation(k)
    assert edge_cut(g, part) == edge_cut(g, perm[part])
