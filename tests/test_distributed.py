"""Distributed SpMV/CG over shard_map — run in a subprocess with 8 forced
host devices (the main pytest process must keep the default 1 device)."""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import Topology, scale_to_load, partition
    from repro.sparse.generators import rdg
    from repro.sparse.graph import laplacian_csr
    from repro.sparse.distributed import (build_plan, make_dist_spmv,
        make_dist_cg, build_allgather_cols, make_dist_spmv_allgather)
    import scipy.sparse as sp

    g = rdg(2000, seed=11)
    topo = scale_to_load(Topology.topo1(8, 2/8, 8.0, 8.5), g.n)
    part, tw = partition(g, topo, "geoRef")
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    A = sp.csr_matrix((data, indices, indptr), shape=(g.n, g.n))
    plan = build_plan(indptr, indices, data, part, 8)
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("pu",))

    rng = np.random.default_rng(3)
    x = rng.normal(size=g.n).astype(np.float32)
    xb = jnp.asarray(plan.scatter_vec(x))

    spmv = make_dist_spmv(plan, mesh)
    err_halo = float(np.abs(plan.gather_vec(np.asarray(spmv(xb)))
                            - A @ x).max())

    cols_g = build_allgather_cols(plan, indptr, indices, part)
    spmv2 = make_dist_spmv_allgather(plan, cols_g, mesh)
    err_ag = float(np.abs(plan.gather_vec(np.asarray(spmv2(xb)))
                          - A @ x).max())

    b = rng.normal(size=g.n).astype(np.float32)
    cg = make_dist_cg(plan, mesh, tol=1e-6, max_iters=1500)
    xs, res, iters = cg(jnp.asarray(plan.scatter_vec(b)))
    xg = plan.gather_vec(np.asarray(xs))
    rel = float(np.linalg.norm(A @ xg - b) / np.linalg.norm(b))

    # round-trip of scatter/gather
    rt = float(np.abs(plan.gather_vec(plan.scatter_vec(x)) - x).max())

    print(json.dumps({
        "err_halo": err_halo, "err_ag": err_ag, "cg_rel": rel,
        "iters": int(iters), "roundtrip": rt,
        "rounds": plan.n_rounds, "halo_slots": plan.S,
    }))
""")


@pytest.fixture(scope="module")
def dist_results():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_halo_spmv_exact(dist_results):
    assert dist_results["err_halo"] < 1e-3


def test_allgather_spmv_exact(dist_results):
    assert dist_results["err_ag"] < 1e-3


def test_distributed_cg_converges(dist_results):
    assert dist_results["cg_rel"] < 1e-3
    assert dist_results["iters"] < 1500


def test_scatter_gather_roundtrip(dist_results):
    assert dist_results["roundtrip"] == 0.0


def test_edge_coloring_rounds_bounded(dist_results):
    # 8 blocks => quotient graph degree <= 7; greedy coloring <= 2*7-1
    assert 1 <= dist_results["rounds"] <= 13
