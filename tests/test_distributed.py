"""Distributed SpMV/CG over shard_map — run in a subprocess with 8 forced
host devices (the main pytest process must keep the default 1 device).

Exercises the Operator protocol end-to-end: dist_halo and dist_allgather
backends against the scipy oracle, the fused whole-CG shard_map program,
the generic cg_solve driving the distributed operator, and cross-backend
agreement with the single-device padded-COO operator."""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import Topology, scale_to_load, partition
    from repro.sparse.generators import rdg
    from repro.sparse.graph import laplacian_csr
    from repro.sparse import make_operator, cg_solve_global
    import scipy.sparse as sp

    g = rdg(2000, seed=11)
    topo = scale_to_load(Topology.topo1(8, 2/8, 8.0, 8.5), g.n)
    part, tw = partition(g, topo, "geoRef")
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    A = sp.csr_matrix((data, indices, indptr), shape=(g.n, g.n))
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("pu",))

    rng = np.random.default_rng(3)
    x = rng.normal(size=g.n).astype(np.float32)
    b = rng.normal(size=g.n).astype(np.float32)

    op_h = make_operator(indptr, indices, data, "dist_halo",
                         part=part, k=8, mesh=mesh)
    op_s = make_operator(indptr, indices, data, "dist_halo_seq",
                         part=part, k=8, mesh=mesh)
    op_a = make_operator(indptr, indices, data, "dist_allgather",
                         part=part, k=8, mesh=mesh)
    err_halo = float(np.abs(op_h.gather(op_h.matvec(op_h.scatter(x)))
                            - A @ x).max())
    err_seq = float(np.abs(op_s.gather(op_s.matvec(op_s.scatter(x)))
                           - A @ x).max())
    err_ag = float(np.abs(op_a.gather(op_a.matvec(op_a.scatter(x)))
                          - A @ x).max())
    # overlapped vs sequential halo schedule: same plan, same numbers
    ovl_vs_seq = float(np.abs(
        np.asarray(op_h.matvec(op_h.scatter(x)))
        - np.asarray(op_s.matvec(op_s.scatter(x)))).max()
        / max(np.abs(x).max(), 1e-30))

    # fused whole-CG shard_map program (halo and allgather comm modes)
    res = op_h.solve(b, tol=1e-6, max_iters=1500)
    xg = op_h.gather(res.x)
    rel = float(np.linalg.norm(A @ xg - b) / np.linalg.norm(b))
    res_a = op_a.solve(b, tol=1e-6, max_iters=1500)
    rel_ag = float(np.linalg.norm(A @ op_a.gather(res_a.x) - b)
                   / np.linalg.norm(b))

    # generic cg_solve driving the same operator (composable path)
    xg2, iters2, _ = cg_solve_global(op_h, b, tol=1e-6, max_iters=1500)
    rel2 = float(np.linalg.norm(A @ xg2 - b) / np.linalg.norm(b))

    # fused Jacobi-preconditioned CG off the on-device plan diagonal
    res_j = op_h.solve(b, tol=1e-6, max_iters=1500, precondition="jacobi")
    rel_j = float(np.linalg.norm(A @ op_h.gather(res_j.x) - b)
                  / np.linalg.norm(b))

    # cross-backend agreement: single-device COO on the same system
    xc, _, _ = cg_solve_global(make_operator(indptr, indices, data, "coo"), b,
                        tol=1e-6, max_iters=1500)
    cross = float(np.abs(np.asarray(xc) - xg2).max()
                  / max(np.abs(xc).max(), 1e-30))

    plan = op_h.plan
    rt = float(np.abs(plan.gather_vec(plan.scatter_vec(x)) - x).max())

    print(json.dumps({
        "err_halo": err_halo, "err_seq": err_seq, "err_ag": err_ag,
        "ovl_vs_seq": ovl_vs_seq, "cg_rel": rel,
        "iters": int(res.iters), "cg_rel_generic": rel2,
        "iters_generic": int(iters2), "cross_backend_rel": cross,
        "cg_rel_allgather_fused": rel_ag,
        "iters_allgather_fused": int(res_a.iters),
        "cg_rel_jacobi_fused": rel_j, "iters_jacobi_fused": int(res_j.iters),
        "roundtrip": rt, "rounds": plan.n_rounds, "halo_slots": plan.S,
    }))
""")


@pytest.fixture(scope="module")
def dist_results():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_halo_spmv_exact(dist_results):
    assert dist_results["err_halo"] < 1e-3


def test_sequential_halo_spmv_exact(dist_results):
    assert dist_results["err_seq"] < 1e-3


def test_overlapped_matches_sequential_schedule(dist_results):
    # same plan, reordered accumulation only — f32 rounding at most
    assert dist_results["ovl_vs_seq"] < 1e-5


def test_allgather_spmv_exact(dist_results):
    assert dist_results["err_ag"] < 1e-3


def test_distributed_cg_converges(dist_results):
    assert dist_results["cg_rel"] < 1e-3
    assert dist_results["iters"] < 1500


def test_generic_cg_drives_distributed_operator(dist_results):
    assert dist_results["cg_rel_generic"] < 1e-3
    assert dist_results["iters_generic"] < 1500


def test_fused_cg_allgather_comm_mode(dist_results):
    # regression: solve() must honor comm="allgather", not silently halo
    assert dist_results["cg_rel_allgather_fused"] < 1e-3
    assert dist_results["iters_allgather_fused"] < 1500


def test_fused_cg_jacobi_preconditioned(dist_results):
    # PCG off plan.diag converges to the same unpreconditioned tolerance
    assert dist_results["cg_rel_jacobi_fused"] < 1e-3
    assert dist_results["iters_jacobi_fused"] < 1500


def test_cross_backend_agreement(dist_results):
    # COO (single device) and halo shard_map CG agree on the solution
    assert dist_results["cross_backend_rel"] < 1e-3


def test_scatter_gather_roundtrip(dist_results):
    assert dist_results["roundtrip"] == 0.0


def test_edge_coloring_rounds_bounded(dist_results):
    # 8 blocks => quotient degree <= 7; Misra-Gries (Vizing) <= Delta+1 = 8
    assert 1 <= dist_results["rounds"] <= 8
