"""Property-based suite for the hierarchical (pod-aware) metrics and
refinement (ISSUE 4 satellite).

Invariants, against a brute-force dense NumPy oracle:
  * intra + inter pod cut exactly tiles the flat edge cut;
  * intra + inter pod comm volumes exactly tile the flat comm volumes;
  * pod-aware FM (``refine_partition(pod_of=..., lam=...)``) never
    increases the weighted two-level objective and respects the caps;
  * the pod-level KL sweep (``refine_pod_assignment``) never increases
    the inter-pod quotient weight, preserves pod sizes, and preserves
    the per-spec-group pod multiset.

Everything here is host-only NumPy (no devices, no JAX version
sensitivity) — it runs unskipped in both CI matrix jobs.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.metrics import (comm_volumes, edge_cut, pod_comm_volumes,
                                pod_cut_split, two_level_objective)
from repro.core.refinement import (quotient_graph, refine_partition,
                                   refine_pod_assignment)
from repro.sparse.graph import Graph, from_edges


def random_instance(seed: int, k: int, pods: int):
    """Random weighted graph + partition + (shuffled) equal-size pods."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 48))
    m = int(rng.integers(n, 4 * n))
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.integers(1, 6, m).astype(np.float64)
    g = from_edges(n, src, dst, w, symmetrize=True)
    part = rng.integers(0, k, n).astype(np.int32)
    perm = rng.permutation(k)
    pod_of = np.empty(k, dtype=np.int64)
    pod_of[perm] = np.arange(k) // (k // pods)
    return g, part, pod_of


def oracle_split(g: Graph, part: np.ndarray, pod_of: np.ndarray, k: int):
    """O(n^2) dense reference for the pod cut/volume split."""
    A = np.zeros((g.n, g.n))
    src, dst, w = g.edge_list()
    A[src, dst] = w
    intra_cut = inter_cut = 0.0
    for i in range(g.n):
        for j in range(i + 1, g.n):
            if A[i, j] and part[i] != part[j]:
                if pod_of[part[i]] == pod_of[part[j]]:
                    intra_cut += A[i, j]
                else:
                    inter_cut += A[i, j]
    intra_v = np.zeros(k, dtype=np.int64)
    inter_v = np.zeros(k, dtype=np.int64)
    for b in range(k):
        for v in range(g.n):
            if part[v] == b:
                continue
            nb = g.indices[g.indptr[v]:g.indptr[v + 1]]
            if len(nb) and np.any(part[nb] == b):
                if pod_of[part[v]] == pod_of[b]:
                    intra_v[b] += 1
                else:
                    inter_v[b] += 1
    return intra_cut, inter_cut, intra_v, inter_v


KP = [(2, 2), (4, 2), (6, 3), (8, 2), (8, 4)]


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from(KP))
def test_split_tiles_flat_and_matches_oracle(seed, kp):
    k, pods = kp
    g, part, pod_of = random_instance(seed, k, pods)
    ia, ie = pod_cut_split(g, part, pod_of)
    iv, ev = pod_comm_volumes(g, part, k, pod_of)
    # exact tiling of the flat metrics
    assert ia + ie == pytest.approx(edge_cut(g, part))
    np.testing.assert_array_equal(iv + ev, comm_volumes(g, part, k))
    # brute-force oracle agreement
    o_ia, o_ie, o_iv, o_ev = oracle_split(g, part, pod_of, k)
    assert ia == pytest.approx(o_ia) and ie == pytest.approx(o_ie)
    np.testing.assert_array_equal(iv, o_iv)
    np.testing.assert_array_equal(ev, o_ev)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from(KP),
       st.sampled_from([1.0, 2.0, 4.0, 16.0]))
def test_pod_aware_refinement_objective_and_caps(seed, kp, lam):
    k, pods = kp
    g, part, pod_of = random_instance(seed, k, pods)
    sizes = np.bincount(part, minlength=k)
    tw = np.maximum(sizes, 1).astype(np.float64)     # initially feasible
    before = two_level_objective(g, part, pod_of, lam)
    out = refine_partition(g, part, tw, eps=0.25, pod_of=pod_of, lam=lam)
    after = two_level_objective(g, out, pod_of, lam)
    assert after <= before + 1e-6
    caps = np.ceil(tw * 1.25)
    assert (np.bincount(out, minlength=k) <= caps).all()


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from(KP), st.booleans())
def test_pod_sweep_invariants(seed, kp, grouped):
    k, pods = kp
    g, part, pod_of = random_instance(seed, k, pods)
    rng = np.random.default_rng(seed + 1)
    groups = (rng.integers(0, 2, k) if grouped
              else np.zeros(k, dtype=np.int64))
    pairs, w = quotient_graph(g, part, k)
    out = refine_pod_assignment(pairs, w, pod_of, groups=groups)

    W = np.zeros((k, k))
    if len(pairs):
        W[pairs[:, 0], pairs[:, 1]] = w
        W += W.T

    def inter(p):
        return W[np.asarray(p)[:, None] != np.asarray(p)[None, :]].sum() / 2

    assert inter(out) <= inter(pod_of) + 1e-9
    np.testing.assert_array_equal(np.bincount(out, minlength=pods),
                                  np.bincount(pod_of, minlength=pods))
    for grp in np.unique(groups):
        assert sorted(out[groups == grp].tolist()) == \
            sorted(pod_of[groups == grp].tolist())


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from([2, 4, 6]))
def test_fm_weighted_sizes_respect_caps(seed, k):
    """Per-vertex weights: refinement never pushes a block's *weighted*
    size past its cap when the input partition is feasible."""
    rng = np.random.default_rng(seed)
    g, part, _ = random_instance(seed, k, 1 if k % 2 else 2)
    vw = rng.integers(1, 5, g.n).astype(np.int64)
    wsizes = np.bincount(part, weights=vw.astype(float), minlength=k)
    tw = np.maximum(wsizes, 1.0)
    out = refine_partition(g, part, tw, eps=0.2, vw=vw)
    caps = np.ceil(tw * 1.2)
    after = np.bincount(out, weights=vw.astype(float), minlength=k)
    assert (after <= caps).all()
