"""Solver-serving layer (`repro.launch.serve.SolverService`) plus the CG
dtype/epsilon bugfix sweep that rides along with it.

Deterministic coverage (the randomized property suite lives in
``test_cg_batched.py``):

  * matrix fingerprint: content-sensitive, structure-prefixed;
  * operator cache: hit/miss counters, LRU eviction purging warm classes;
  * bucketed admission: size classes, padding counters, shape round-trips;
  * served batched solves match per-column sequential solves, with
    per-column iteration counts (a zero column costs 0 iterations);
  * dtype-aware epsilon guards: float32 solves at ~1e-35 scale converge
    (the old additive ``1e-30`` guard drowned ``p^T A p`` and produced a
    garbage step), zero RHS short-circuits cleanly;
  * dtype preservation end to end, incl. a float64 agreement subprocess
    (``JAX_ENABLE_X64=1``);
  * ``--gen 0`` token-serving guard (used to divide by ``args.gen``).
"""
import argparse
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import scipy.sparse as sp

from repro.launch.serve import (SolverService, matrix_fingerprint,
                                _token_serving)
from repro.sparse import CooOperator, cg_solve
from repro.sparse.generators import grid
from repro.sparse.graph import laplacian_csr


def _system(side=10, shift=0.05):
    g = grid((side, side))
    return laplacian_csr(g, shift=shift)


# --------------------------------------------------------------------------
# fingerprint
# --------------------------------------------------------------------------

def test_fingerprint_deterministic_and_structure_prefixed():
    indptr, indices, data = _system()
    fp = matrix_fingerprint(indptr, indices, data)
    assert fp == matrix_fingerprint(indptr, indices, data)
    n, nnz, digest = fp.split(":")
    assert int(n) == len(indptr) - 1
    assert int(nnz) == len(indices)
    assert len(digest) == 32          # blake2b-16 hex


def test_fingerprint_is_content_sensitive():
    indptr, indices, data = _system()
    fp = matrix_fingerprint(indptr, indices, data)
    bumped = data.copy()
    bumped[0] += 1e-3
    # same sparsity structure, different values -> different key
    assert matrix_fingerprint(indptr, indices, bumped) != fp
    assert matrix_fingerprint(indptr, indices,
                              data.astype(np.float64)) != fp


# --------------------------------------------------------------------------
# admission + cache
# --------------------------------------------------------------------------

def test_bucket_classes():
    svc = SolverService(buckets=(1, 2, 4, 8, 16))
    assert [svc.bucket_for(nb) for nb in (1, 2, 3, 5, 16)] == [1, 2, 4, 8, 16]
    assert svc.bucket_for(40) == 40   # oversize: exact-width class


def test_service_validates_configuration():
    with pytest.raises(ValueError):
        SolverService(buckets=(4, 2, 1))
    with pytest.raises(ValueError):
        SolverService(buckets=())
    with pytest.raises(ValueError):
        SolverService(capacity=0)


def test_operator_cache_hits_and_lru_eviction():
    A = _system(8, 0.05)
    B = _system(8, 0.10)
    rng = np.random.default_rng(0)
    b = rng.normal(size=len(A[0]) - 1).astype(np.float32)

    svc = SolverService(capacity=1, max_iters=200)
    r1 = svc.solve(*A, b)
    assert not r1.cache_hit and not r1.warm
    r2 = svc.solve(*A, b)
    assert r2.cache_hit and r2.warm    # same matrix, same size class
    svc.solve(*B, b)                   # capacity 1: evicts A
    r4 = svc.solve(*A, b)
    assert not r4.cache_hit
    assert not r4.warm                 # eviction purged A's warm classes
    s = svc.stats
    assert (s.operator_hits, s.operator_misses, s.operator_evictions) == \
        (1, 3, 2)
    assert s.solves == 4
    # no stale warm entries for evicted fingerprints
    live = {fp for fp, _ in svc._warm}
    assert live <= set(svc._ops)


def test_padding_counters_and_shapes():
    indptr, indices, data = _system(8)
    n = len(indptr) - 1
    rng = np.random.default_rng(1)
    svc = SolverService(max_iters=200)

    resp = svc.solve(indptr, indices, data,
                     rng.normal(size=(n, 3)).astype(np.float32))
    assert resp.bucket == 4
    assert resp.x.shape == (n, 3)      # padding stripped
    assert resp.iters.shape == (3,)
    assert resp.residual.shape == (3,)
    assert svc.stats.real_cols == 3 and svc.stats.padded_cols == 1
    assert svc.stats.padding_waste == pytest.approx(0.25)

    single = svc.solve(indptr, indices, data,
                       rng.normal(size=n).astype(np.float32))
    assert single.bucket == 1
    assert single.x.shape == (n,)
    assert np.ndim(single.iters) == 0


# --------------------------------------------------------------------------
# served solves: correctness + per-column convergence
# --------------------------------------------------------------------------

def test_served_batch_matches_sequential_and_scipy():
    indptr, indices, data = _system(10)
    n = len(indptr) - 1
    A = sp.csr_matrix((data, indices, indptr), shape=(n, n))
    rng = np.random.default_rng(2)
    hard = rng.normal(size=n).astype(np.float32)
    easy = (A @ np.eye(n, dtype=np.float32)[:, 3]).astype(np.float32)
    zero = np.zeros(n, np.float32)
    b = np.stack([hard, easy, zero], axis=1)

    svc = SolverService(tol=1e-7, max_iters=1000)
    resp = svc.solve(indptr, indices, data, b)

    op = CooOperator.from_csr(indptr, indices, data)
    for j, col in enumerate((hard, easy, zero)):
        seq = cg_solve(op, op.scatter(col), tol=1e-7, max_iters=1000)
        xs = np.asarray(seq.x)
        scale = max(float(np.abs(xs).max()), 1.0)
        assert np.abs(resp.x[:, j] - xs).max() / scale < 1e-5
        assert abs(int(resp.iters[j]) - int(seq.iters)) <= 2
    # columns converge at genuinely different counts; converged ones freeze
    assert int(resp.iters[2]) == 0                 # zero column is free
    assert int(resp.iters[1]) < int(resp.iters[0])  # b = A e_3 is easy
    dense = sp.linalg.spsolve(A.astype(np.float64),
                              hard.astype(np.float64))
    assert np.abs(resp.x[:, 0] - dense).max() / np.abs(dense).max() < 1e-4


# --------------------------------------------------------------------------
# dtype/epsilon bugfix sweep
# --------------------------------------------------------------------------

def test_float32_tiny_scale_converges():
    """A = 1e-35 * I in float32.  ``p^T A p ~ 1e-34`` is representable but
    far below the old additive ``1e-30`` guard, which dominated the
    denominator and shrank the step by ~1e4x.  The dtype-aware safe
    division takes the exact Newton step: one iteration."""
    n = 8
    s = np.float32(1e-35)
    indptr = np.arange(n + 1, dtype=np.int64)
    indices = np.arange(n, dtype=np.int32)
    data = np.full(n, s, dtype=np.float32)
    b = np.ones(n, np.float32)
    op = CooOperator.from_csr(indptr, indices, data)
    res = cg_solve(op, op.scatter(b), tol=1e-6, max_iters=50)
    x = np.asarray(res.x)
    assert int(res.iters) <= 2
    np.testing.assert_allclose(x, np.full(n, 1.0 / s), rtol=1e-5)


def test_zero_rhs_short_circuits():
    indptr, indices, data = _system(6)
    n = len(indptr) - 1
    op = CooOperator.from_csr(indptr, indices, data)
    res = cg_solve(op, op.scatter(np.zeros(n, np.float32)),
                   tol=1e-6, max_iters=50)
    assert int(res.iters) == 0
    assert np.all(np.asarray(res.x) == 0)
    assert np.isfinite(float(res.residual))


def test_operator_preserves_float32_and_promotes_ints():
    indptr, indices, data = _system(6)
    n = len(indptr) - 1
    op = CooOperator.from_csr(indptr, indices, data)
    assert op.vals.dtype == np.float32
    assert np.asarray(op.diag()).dtype == np.float32
    x = np.ones(n, np.float32)
    assert np.asarray(op.matvec(op.scatter(x))).dtype == np.float32
    res = cg_solve(op, op.scatter(x), tol=1e-6, max_iters=200)
    assert np.asarray(res.x).dtype == np.float32
    # integer values promote to f32 rather than staying int
    op_i = CooOperator.from_csr(indptr, indices,
                                np.ones_like(data, dtype=np.int32))
    assert op_i.vals.dtype == np.float32


F64_SCRIPT = textwrap.dedent("""
    import os
    os.environ["JAX_ENABLE_X64"] = "1"
    import json
    import numpy as np
    import scipy.sparse as sp
    from repro.sparse import CooOperator, cg_solve
    from repro.sparse.generators import grid
    from repro.sparse.graph import laplacian_csr

    g = grid((10, 10))
    indptr, indices, data = laplacian_csr(g, shift=0.05)
    data64 = data.astype(np.float64)
    A = sp.csr_matrix((data64, indices, indptr), shape=(g.n, g.n))
    rng = np.random.default_rng(5)
    b = rng.normal(size=g.n)

    op = CooOperator.from_csr(indptr, indices, data64)
    res = cg_solve(op, op.scatter(b), tol=1e-12, max_iters=2000)
    x64 = np.asarray(res.x)
    dense = sp.linalg.spsolve(A, b)
    rel64 = float(np.abs(x64 - dense).max() / np.abs(dense).max())

    op32 = CooOperator.from_csr(indptr, indices, data)
    res32 = cg_solve(op32, op32.scatter(b.astype(np.float32)),
                     tol=1e-6, max_iters=2000)
    rel32 = float(np.abs(np.asarray(res32.x) - dense).max()
                  / np.abs(dense).max())
    print(json.dumps({"dtype": str(x64.dtype), "rel64": rel64,
                      "dtype32": str(np.asarray(res32.x).dtype),
                      "rel32": rel32}))
""")


def test_float64_agreement_subprocess():
    """With x64 enabled, float64 inputs stay float64 end to end (the old
    operator path forced f32) and CG reaches direct-solver accuracy."""
    proc = subprocess.run([sys.executable, "-c", F64_SCRIPT],
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["dtype"] == "float64"
    assert out["rel64"] < 1e-10
    assert out["dtype32"] == "float32"
    assert out["rel32"] < 1e-4


# --------------------------------------------------------------------------
# streaming updates (update_matrix) — fingerprint motion under mutation
# --------------------------------------------------------------------------

def _reweight_pair(indptr, indices, data, i, j, val):
    """EdgeDelta setting the symmetric (i, j) off-diagonal pair plus the
    mutated CSR it should produce."""
    from repro.sparse.replan import EdgeDelta, apply_delta_csr

    n = len(indptr) - 1
    delta = EdgeDelta(n, set_rows=[i, j], set_cols=[j, i],
                      set_vals=[val, val])
    return delta, apply_delta_csr(indptr, indices, data, delta)


def test_update_matrix_moves_fingerprint():
    """A served delta retires the old fingerprint entirely: the mutated
    matrix hits, the *unmutated* one misses — never a stale hit."""
    indptr, indices, data = _system(8)
    n = len(indptr) - 1
    rng = np.random.default_rng(2)
    b = rng.normal(size=n).astype(np.float32)

    svc = SolverService(max_iters=400, tol=1e-7)
    r0 = svc.solve(indptr, indices, data, b)
    delta, (ip2, ix2, d2) = _reweight_pair(indptr, indices, data,
                                           0, 1, -0.5)
    resp = svc.update_matrix(r0.fingerprint, delta)
    assert resp.old_fingerprint == r0.fingerprint
    assert resp.fingerprint == matrix_fingerprint(ip2, ix2, d2)
    assert resp.fingerprint != r0.fingerprint
    # coo operators carry no plan/replan cache -> full rebuild path
    assert not resp.patched and not resp.repartitioned
    assert resp.drift is None and resp.state is None
    assert svc.stats.plan_rebuilds == 1 and svc.stats.plan_patches == 0

    r_new = svc.solve(ip2, ix2, d2, b)
    assert r_new.cache_hit and r_new.fingerprint == resp.fingerprint
    r_old = svc.solve(indptr, indices, data, b)
    assert not r_old.cache_hit            # old matrix: no stale operator

    A2 = sp.csr_matrix((d2, ix2, ip2), shape=(n, n))
    ref = sp.linalg.spsolve(A2.astype(np.float64), b.astype(np.float64))
    assert np.abs(np.asarray(r_new.x) - ref).max() \
        / np.abs(ref).max() < 1e-4


def test_update_matrix_unknown_or_evicted_fingerprint_raises():
    indptr, indices, data = _system(8)
    B = _system(8, 0.10)
    rng = np.random.default_rng(3)
    b = rng.normal(size=len(indptr) - 1).astype(np.float32)

    svc = SolverService(capacity=1, max_iters=200)
    with pytest.raises(KeyError):
        svc.update_matrix("0:0:deadbeef", _reweight_pair(
            indptr, indices, data, 0, 1, -0.5)[0])
    rA = svc.solve(indptr, indices, data, b)
    svc.solve(*B, b)                       # capacity 1: evicts A
    with pytest.raises(KeyError):          # evicted == unknown
        svc.update_matrix(rA.fingerprint, _reweight_pair(
            indptr, indices, data, 0, 1, -0.5)[0])


def test_eviction_purges_update_state():
    """LRU eviction of an updated matrix drops its CSR snapshot, drift
    monitor, warm classes and jit programs — no stale streaming state."""
    from repro.core.replan_policy import DriftPolicy

    indptr, indices, data = _system(8)
    B = _system(8, 0.10)
    rng = np.random.default_rng(4)
    b = rng.normal(size=len(indptr) - 1).astype(np.float32)

    n = len(indptr) - 1
    # part is a factory-level hint the coo backend ignores, but it lets
    # the drift monitor price plan-less operators
    svc = SolverService(capacity=1, max_iters=200,
                        part=((np.arange(n) * 4) // n).astype(np.int32),
                        drift=DriftPolicy(max_objective_ratio=1e6,
                                          max_imbalance_ratio=1e6))
    r0 = svc.solve(indptr, indices, data, b)
    delta, (ip2, ix2, d2) = _reweight_pair(indptr, indices, data,
                                           0, 1, -0.5)
    resp = svc.update_matrix(r0.fingerprint, delta)
    assert resp.drift is not None          # monitor priced the update
    assert resp.fingerprint in svc._monitors
    assert resp.old_fingerprint not in svc._csr
    assert resp.fingerprint in svc._csr and resp.fingerprint in svc._ops
    svc.solve(ip2, ix2, d2, b)

    svc.solve(*B, b)                       # capacity 1: evicts mutated A
    assert resp.fingerprint not in svc._ops
    assert resp.fingerprint not in svc._csr
    assert resp.fingerprint not in svc._monitors
    assert not any(fp == resp.fingerprint for fp, _ in svc._warm)
    assert resp.fingerprint not in svc._jit
    # every auxiliary table only references live operators
    assert set(svc._csr) == set(svc._ops)
    assert set(svc._monitors) <= set(svc._ops)
    assert {fp for fp, _ in svc._warm} <= set(svc._ops)


DELTA_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import scipy.sparse as sp
    from repro.core.replan_policy import DriftPolicy
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import SolverService
    from repro.sparse.replan import EdgeDelta, apply_delta_csr
    from repro.sparse.generators import grid
    from repro.sparse.graph import laplacian_csr

    g = grid((16, 16))
    indptr, indices, data = laplacian_csr(g, shift=0.1)
    n, k = g.n, 8
    part = ((np.arange(n) * k) // n).astype(np.int32)
    mesh = make_test_mesh(8, fanouts=(2, 4))
    repart_calls = []

    def repartition(gs):
        repart_calls.append(gs.n)
        return part

    svc = SolverService(backend="dist_hier", capacity=4, max_iters=400,
                        tol=1e-7, part=part, k=k, mesh=mesh,
                        fanouts=(2, 4),
                        drift=DriftPolicy(max_objective_ratio=1.2),
                        repartition=repartition)
    rng = np.random.default_rng(0)
    b = rng.normal(size=n).astype(np.float32)
    r0 = svc.solve(indptr, indices, data, b)

    # 1) value delta -> O(delta) plan patch, not a rebuild
    dv = EdgeDelta(n, set_rows=[0, 1], set_cols=[1, 0],
                   set_vals=[-0.5, -0.5])
    ip2, ix2, d2 = apply_delta_csr(indptr, indices, data, dv)
    r1 = svc.update_matrix(r0.fingerprint, dv)
    assert r1.patched and not r1.repartitioned
    assert r1.drift is not None and not r1.drift.repartition
    hit = svc.solve(ip2, ix2, d2, b)
    assert hit.cache_hit and hit.fingerprint == r1.fingerprint
    miss = svc.solve(indptr, indices, data, b)
    assert not miss.cache_hit
    A2 = sp.csr_matrix((d2, ix2, ip2), shape=(n, n)).astype(np.float64)
    ref = sp.linalg.spsolve(A2, b.astype(np.float64))
    rel = float(np.abs(np.asarray(hit.x) - ref).max()
                / np.abs(ref).max())

    # 2) heavy cross-partition insertions -> drift trip -> repartition,
    #    with CG state migrated (not restarted)
    plan = svc._ops[r1.fingerprint].plan
    xs = plan.scatter_vec(b)
    u = np.arange(0, 30, dtype=np.int64)
    v = (n - 1 - u)
    ds = EdgeDelta(n, set_rows=np.concatenate([u, v]),
                   set_cols=np.concatenate([v, u]),
                   set_vals=np.full(60, -1.0))
    r2 = svc.update_matrix(r1.fingerprint, ds, state=(xs,))
    assert r2.drift.repartition and "objective" in r2.drift.reason
    assert r2.repartitioned and not r2.patched
    assert len(repart_calls) == 1
    new_plan = svc._ops[r2.fingerprint].plan
    migrated = np.asarray(new_plan.gather_vec(r2.state[0]))
    state_exact = bool(np.array_equal(migrated, b))

    s = svc.stats
    print(json.dumps({
        "rel": rel, "state_exact": state_exact,
        "patches": s.plan_patches, "rebuilds": s.plan_rebuilds,
        "trips": s.drift_trips,
        "fp_moved": r2.fingerprint != r1.fingerprint != r0.fingerprint,
    }))
""")


def test_update_matrix_patches_dist_plan_subprocess():
    """dist_hier serving: a value delta is an O(delta) plan patch; a
    drift trip forces repartition + exact CG-state migration (8 forced
    host devices, set before jax import)."""
    proc = subprocess.run([sys.executable, "-c", DELTA_SCRIPT],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["rel"] < 1e-4
    assert out["state_exact"]
    assert (out["patches"], out["rebuilds"], out["trips"]) == (1, 1, 1)
    assert out["fp_moved"]


# --------------------------------------------------------------------------
# --gen 0 guard
# --------------------------------------------------------------------------

def test_token_serving_gen_zero(capsys):
    args = argparse.Namespace(arch="qwen1.5-0.5b", smoke=True, batch=1,
                              prompt_len=4, gen=0, temperature=0.8)
    _token_serving(args)      # used to raise ZeroDivisionError
    out = capsys.readouterr().out
    assert "decode skipped (--gen 0)" in out
