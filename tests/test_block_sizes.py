"""Property-based tests for Algorithm 1 (the paper's Theorem 1 / Lemma 1,
executed as code)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.block_sizes import (max_load_ratio, saturated_mask,
                                    target_block_sizes,
                                    target_block_sizes_jax)
from repro.core.topology import PU, Topology


def topo_strategy(max_k=12):
    pu = st.tuples(st.floats(0.1, 32.0), st.floats(0.5, 64.0))
    return st.lists(pu, min_size=1, max_size=max_k)


def make_topo(spec):
    return Topology(tuple(PU(s, m, f"p{i}") for i, (s, m) in enumerate(spec)))


def binary_search_optimum(n, speeds, mems, iters=200):
    """Independent oracle: optimal t* = min t s.t. sum min(c_i t, m_i) >= n
    (water-filling KKT condition for minimize max tw_i/c_i)."""
    lo, hi = 0.0, 10.0 * n / speeds.sum() + n / speeds.min()
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if np.sum(np.minimum(speeds * mid, mems)) >= n:
            hi = mid
        else:
            lo = mid
    return hi


@settings(max_examples=200, deadline=None)
@given(topo_strategy(), st.floats(1.0, 1000.0))
def test_algorithm1_optimal(spec, frac):
    """Theorem 1: greedy == water-filling optimum of objective (2)."""
    topo = make_topo(spec)
    n = frac / 1000.0 * topo.total_memory        # always feasible
    tw = target_block_sizes(n, topo)
    # constraint (3): memory respected
    assert np.all(tw <= topo.memories + 1e-9)
    # mass conservation
    assert np.isclose(tw.sum(), n, rtol=1e-9)
    # non-negative
    assert np.all(tw >= -1e-12)
    # optimality vs independent oracle
    t_star = binary_search_optimum(n, topo.speeds, topo.memories)
    assert max_load_ratio(tw, topo) <= t_star * (1 + 1e-6)


@settings(max_examples=100, deadline=None)
@given(topo_strategy(), st.floats(1.0, 999.0))
def test_lemma1_saturated_prefix(spec, frac):
    """Lemma 1: saturated PUs form a prefix of the c_s/m_cap-sorted order."""
    topo = make_topo(spec)
    n = frac / 1000.0 * topo.total_memory
    tw = target_block_sizes(n, topo)
    order = np.argsort(-(topo.speeds / topo.memories), kind="stable")
    sat = np.isclose(tw, topo.memories)[order]
    # once non-saturated, never saturated again
    seen_nonsat = False
    for s in sat:
        if not s:
            seen_nonsat = True
        assert not (s and seen_nonsat), "saturated PU after non-saturated"


@settings(max_examples=100, deadline=None)
@given(topo_strategy())
def test_jax_matches_numpy(spec):
    import jax.numpy as jnp
    topo = make_topo(spec)
    n = 0.9 * topo.total_memory
    tw_np = target_block_sizes(n, topo)
    tw_jx = np.asarray(target_block_sizes_jax(
        jnp.float32(n), jnp.asarray(topo.speeds, jnp.float32),
        jnp.asarray(topo.memories, jnp.float32)))
    assert np.allclose(tw_np, tw_jx, rtol=2e-4, atol=2e-4 * n)


def test_infeasible_raises():
    topo = Topology((PU(1, 1.0), PU(1, 1.0)))
    with pytest.raises(ValueError):
        target_block_sizes(3.0, topo)


def test_integral_rounding():
    topo = Topology((PU(3, 100), PU(1, 100), PU(1, 100)))
    tw = target_block_sizes(101, topo, integral=True)
    assert tw.sum() == 101
    assert np.all(tw == np.round(tw))
    assert np.all(tw <= topo.memories)


def test_homogeneous_is_uniform():
    topo = Topology.homogeneous(8, memory=1000.0)
    tw = target_block_sizes(800, topo)
    assert np.allclose(tw, 100.0)


def test_trivial_case_proportional():
    """Eq. 4: ample memory => proportional to speed."""
    topo = Topology((PU(4, 1e9), PU(1, 1e9), PU(3, 1e9)))
    tw = target_block_sizes(80, topo)
    assert np.allclose(tw, [40, 10, 30])
