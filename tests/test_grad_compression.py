"""int8 cross-pod gradient compression — 8 forced host devices (2,2,2)."""
import json
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models.config import ModelConfig
    from repro.models import transformer
    from repro.compat import use_mesh
    from repro.models.steps import make_train_step, input_specs
    from repro.train.optimizer import AdamWConfig, init_opt_state

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab=256,
                      dtype="float32")
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    B, S = 8, 16
    with use_mesh(mesh):
        params, _ = transformer.init_model(jax.random.PRNGKey(0), cfg,
                                           mesh.axis_names)
        state = {"params": params, "opt": init_opt_state(params)}
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, 256, (B, S)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, 256, (B, S)),
                                       jnp.int32)}
        out = {}
        results = {}
        for tag, comp in (("off", None), ("int8", "int8")):
            step = jax.jit(make_train_step(cfg, AdamWConfig(),
                                           grad_compression=comp))
            lowered = step.lower(state, batch)
            compiled = lowered.compile()
            hlo = compiled.as_text()
            st2, m = compiled(state, batch)
            results[tag] = (float(m["loss"]),
                            jax.tree.leaves(st2["params"]))
            out[f"s8_allgather_{tag}"] = int("s8" in hlo and
                                             "all-gather" in hlo and
                                             hlo.count("s8[") > 0)
        l0, p0 = results["off"]
        l1, p1 = results["int8"]
        out["loss_rel_diff"] = abs(l0 - l1) / max(abs(l0), 1e-9)
        out["param_max_rel"] = max(
            float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9))
            for a, b in zip(p0, p1))
        print(json.dumps(out))
""")


def test_int8_grad_compression():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    # loss is computed pre-reduction — identical
    assert out["loss_rel_diff"] < 1e-5
    # updated params agree to quantization tolerance (one AdamW step)
    assert out["param_max_rel"] < 0.05
    # the compressed program actually moves int8 on the pod axis
    assert out["s8_allgather_int8"] == 1
