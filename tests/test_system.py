"""End-to-end behaviour: the paper's full two-stage pipeline — Algorithm 1
block sizes -> partitioner -> distributed-layout plan -> application metrics
— and the LM-framework integration points."""
import numpy as np
import pytest

from repro.core import (Topology, evaluate, partition, scale_to_load,
                        target_block_sizes)
from repro.core.block_sizes import hetero_batch_split, max_load_ratio
from repro.core.metrics import edge_cut, summarize
from repro.sparse.distributed import build_plan
from repro.sparse.generators import rdg
from repro.sparse.graph import laplacian_csr


@pytest.fixture(scope="module")
def setting():
    g = rdg(3000, seed=9)
    topo = scale_to_load(Topology.topo2(12, 1 / 6, 8.0, 8.5), g.n)
    return g, topo


def test_two_stage_pipeline(setting):
    """LDHT: stage 1 optimal sizes, stage 2 cut minimization, then the
    distributed plan realizes exactly those block sizes (padded)."""
    g, topo = setting
    part, tw = partition(g, topo, "geoRef")
    s = summarize(g, part, topo, tw)
    assert s["mem_violations"] == 0
    assert s["imbalance"] < 1.06
    indptr, indices, data = laplacian_csr(g)
    plan = build_plan(indptr, indices, data, part, topo.k)
    assert plan.B == int(np.bincount(part, minlength=topo.k).max())
    # halo exchange volume == comm volume metric family (same boundary)
    assert plan.S > 0 and plan.n_rounds >= 1


def test_load_ratio_optimality_carries(setting):
    """The realized partition's objective (2) is within 6% of Algorithm 1's
    optimum (stage-2 tools keep the prescribed sizes)."""
    g, topo = setting
    part, tw = partition(g, topo, "geoKM")
    opt = max_load_ratio(tw, topo)
    realized = max_load_ratio(
        np.bincount(part, minlength=topo.k).astype(float), topo)
    assert realized <= opt * 1.06


def test_heterogeneity_improves_over_uniform(setting):
    """Ignoring heterogeneity (uniform blocks) must yield a strictly worse
    load ratio than Algorithm 1 sizes — the paper's core premise."""
    g, topo = setting
    uniform = np.full(topo.k, g.n / topo.k)
    tw = target_block_sizes(g.n, topo)
    assert max_load_ratio(tw, topo) < max_load_ratio(uniform, topo) * 0.999


def test_evaluate_runs_all_methods():
    g = rdg(800, seed=1)
    topo = scale_to_load(Topology.topo1(4, 1 / 4, 4.0, 5.2), g.n)
    res = evaluate(g, topo, methods=("sfc", "geoKM"), verbose=False)
    assert set(res) == {"sfc", "geoKM"}
    assert res["geoKM"]["cut"] <= res["sfc"]["cut"]


def test_hetero_batch_split_framework_hook():
    """LM-framework integration: Algorithm 1 routes the global batch."""
    topo = Topology.topo1(8, 2 / 8, 4.0, 5.2)
    from repro.core.topology import scale_to_load as stl
    shares = hetero_batch_split(256, stl(topo, 256, 1.5))
    assert shares.sum() == 256
    assert shares[0] > shares[-1]
    assert np.all(shares >= 0)
