"""Cost-model layer (ISSUE 9): golden bit-identity for the cut path +
unit coverage for the pluggable objective abstraction.

The golden half replays the exact pre-refactor call sequences captured
in ``tests/golden/cut_mode_golden.json`` — same generators, same rng
draw order, same anc/lams — and requires byte-identical partitions and
float-identical objectives.  This is what locks ``objective="cut"``
(the default everywhere) to the pre-costmodel pipeline: any refactor
that perturbs the cut-mode FM, even by reordering ties, fails here.

Everything is host-only NumPy — no devices, no JAX.
"""
import hashlib
import json
import pathlib

import numpy as np
import pytest

from repro.core import (BottleneckCost, COST_MODELS, CostModel, CutCost,
                        Topology, canonical_ancestors, cost_model_for,
                        partition_tree, scale_to_load)
from repro.core.metrics import (bottleneck_objective, edge_cut,
                                per_pu_model_costs, tree_comm_volumes,
                                tree_cut_split, tree_objective)
from repro.core.refinement import fm_pair_refine, refine_partition
from repro.sparse.generators import aniso_grid, grid, rdg

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" /
     "cut_mode_golden.json").read_text())


def sha(a):
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


# -- golden bit-identity (the cut-mode lock) --------------------------------

def test_golden_tree_objective():
    rng = np.random.default_rng(0)
    anc = canonical_ancestors((2, 2, 2))
    lams = (1.0, 2.5, 7.0)
    for i, want in enumerate(GOLDEN["tree_objective"]):
        g = rdg(200 + 17 * i, seed=i)
        part = rng.integers(0, 8, g.n).astype(np.int32)
        assert tree_objective(g, part, anc, lams) == want["obj"]
        assert tree_objective(g, part, anc) == want["obj_default"]
        assert tree_cut_split(g, part, anc).tolist() == want["cuts"]
        assert sha(tree_comm_volumes(g, part, 8, anc)) == want["vols_sha"]


def _fm_instance():
    g = grid((24, 24))
    part = ((np.arange(g.n) * 8) // g.n).astype(np.int32)
    rng = np.random.default_rng(3)
    noise = rng.choice(g.n, 60, replace=False)
    part[noise] = rng.integers(0, 8, 60)
    return g, part


def test_golden_fm_pair_refine():
    g, part = _fm_instance()
    anc = canonical_ancestors((2, 2, 2))
    caps = np.full(8, np.ceil(g.n / 8 * 1.05))
    gain = fm_pair_refine(g, part, 0, 1, caps, anc=anc,
                          lams=(1.0, 2.0, 4.0))
    assert gain == GOLDEN["fm_pair"]["gain"]
    assert sha(part) == GOLDEN["fm_pair"]["part_sha"]


def test_golden_refine_partition():
    g, part = _fm_instance()
    anc = canonical_ancestors((2, 2, 2))
    out = refine_partition(g, part, np.full(8, g.n / 8), anc=anc,
                           lams=(1.0, 2.0, 4.0))
    assert sha(out) == GOLDEN["refine_partition"]["part_sha"]
    assert tree_objective(g, out, anc, (1.0, 2.0, 4.0)) == \
        GOLDEN["refine_partition"]["obj"]


@pytest.mark.parametrize("case", GOLDEN["partition_tree"],
                         ids=lambda c: f"{c['graph']}-{c['method']}")
def test_golden_partition_tree(case):
    g = (grid((16, 128)) if case["graph"] == "grid16x128"
         else aniso_grid((24, 24), (1.0, 0.05)))
    topo = scale_to_load(Topology.homogeneous(8, fanouts=(2, 2, 2)), g.n)
    res = partition_tree(g, topo, case["method"], seed=0)
    assert res.objective == "cut"            # the default records itself
    assert sha(res.part.astype(np.int32)) == case["part_sha"]
    assert res.tw.tolist() == case["tw"]
    assert sha(res.anc.astype(np.int64)) == case["anc_sha"]
    assert list(res.lams) == case["lams"]
    assert tree_objective(g, res.part, res.anc, res.lams) == case["obj"]


# -- CostModel unit coverage ------------------------------------------------

def _tree_instance(seed=0, k=8):
    rng = np.random.default_rng(seed)
    g = rdg(260, seed=seed)
    part = rng.integers(0, k, g.n).astype(np.int32)
    anc = canonical_ancestors((2, 2, 2))
    return g, part, anc


def test_cut_price_is_tree_objective():
    g, part, anc = _tree_instance()
    lams = (1.0, 3.0, 9.0)
    m = CutCost(lams=lams)
    assert m.price(g, part, anc) == tree_objective(g, part, anc, lams)
    # default lams resolve through the one shared ladder
    assert CutCost().price(g, part, anc) == tree_objective(g, part, anc)


def test_cut_price_flat_is_edge_cut():
    g, part, _ = _tree_instance()
    flat = np.zeros((0, 8), dtype=np.int64)
    assert CutCost(lams=(2.0,)).price(g, part, flat) == \
        pytest.approx(2.0 * edge_cut(g, part))


def test_bottleneck_price_matches_metric_and_breakdown():
    g, part, anc = _tree_instance()
    speeds = (1.0, 2.0, 1.0, 0.5, 1.0, 1.0, 4.0, 1.0)
    m = BottleneckCost(lams=(1.0, 2.0, 4.0), speeds=speeds, c_comp=3.0)
    assert m.price(g, part, anc) == bottleneck_objective(
        g, part, anc, lams=(1.0, 2.0, 4.0),
        speeds=np.asarray(speeds), c_comp=3.0)
    pp = m.per_pu(g, part, anc)
    np.testing.assert_allclose(pp["compute"] + pp["comm"], pp["total"])
    assert m.price(g, part, anc) == pytest.approx(pp["total"].max())
    # the comm split stacks back to the per-level dedup volumes
    vols = tree_comm_volumes(g, part, 8, anc)
    np.testing.assert_allclose(
        pp["comm"], np.asarray((1.0, 2.0, 4.0)) @ vols.astype(float))


def test_summary_schema_and_consistency():
    g, part, anc = _tree_instance()
    for name, cls in COST_MODELS.items():
        s = cls().summary(g, part, anc)
        assert s["objective"] == name == cls.kind
        assert s["makespan"] == pytest.approx(
            BottleneckCost().price(g, part, anc))
        assert len(s["per_pu_compute"]) == len(s["per_pu_comm"]) == 8
        assert len(s["lams"]) == 3 and len(
            s["max_comm_volume_by_level"]) == 3
        json.dumps(s)                        # JSON-friendly contract
    # the bottleneck model's price IS its makespan
    sb = BottleneckCost().summary(g, part, anc)
    assert sb["price"] == sb["makespan"]


def test_cost_model_for_resolution():
    topo = scale_to_load(Topology.homogeneous(8, fanouts=(2, 2, 2)), 512)
    m = cost_model_for("bottleneck", topo=topo, lams=(1, 2, 4), c_comp=5)
    assert isinstance(m, BottleneckCost)
    assert m.lams == (1.0, 2.0, 4.0) and m.c_comp == 5.0
    assert m.speeds == tuple(topo.speeds)
    assert isinstance(cost_model_for("cut"), CutCost)
    # instances pass through unchanged (calibrated models)
    assert cost_model_for(m) is m
    with pytest.raises(ValueError, match="unknown objective"):
        cost_model_for("latency")


def test_partition_tree_bottleneck_not_worse_and_recorded():
    g = grid((24, 24))
    topo = scale_to_load(Topology.homogeneous(8, fanouts=(2, 2, 2)), g.n)
    cut = partition_tree(g, topo, "greedyRef", seed=0)
    bn = partition_tree(g, topo, "greedyRef", seed=0,
                        objective="bottleneck")
    assert cut.objective == "cut" and bn.objective == "bottleneck"
    model = BottleneckCost(lams=cut.lams)
    # stage E starts from the cut result, so it can only improve it
    assert model.price(g, bn.part, bn.anc) <= \
        model.price(g, cut.part, cut.anc) + 1e-9
    with pytest.raises(ValueError, match="unknown objective"):
        partition_tree(g, topo, "greedyRef", seed=0, objective="latency")


def test_base_model_price_abstract():
    g, part, anc = _tree_instance()
    with pytest.raises(NotImplementedError):
        CostModel().price(g, part, anc)


# -- pair-dedup overflow regression (ISSUE 9 satellite) ---------------------
# comm_volumes/tree_comm_volumes deduplicate (receiver, vertex) pairs via
# the linearized key ``recv * n + vert``, which silently wraps int64 once
# k * n approaches 2**63.  Above _PAIR_DEDUP_MAX the dedup switches to a
# lexsort; these lock (a) bit-identical output on the same input and (b)
# correct counts at a vertex count where the product path would wrap.

def test_dedup_lexsort_path_bit_identical():
    from repro.core import metrics as M
    rng = np.random.default_rng(11)
    for trial in range(20):
        k = int(rng.integers(2, 12))
        n = int(rng.integers(10, 5000))
        m = int(rng.integers(0, 400))
        recv = rng.integers(0, k, m)
        vert = rng.integers(0, n, m)
        fast = M._dedup_recv_pairs(recv, vert, n, k)
        slow = M._dedup_recv_pairs(recv, vert,
                                   n * (M._PAIR_DEDUP_MAX // n + 1), k)
        np.testing.assert_array_equal(fast[0], slow[0])
        np.testing.assert_array_equal(fast[1], slow[1])
    # both paths accept empty input
    empty = np.zeros(0, dtype=np.int64)
    for nn in (100, M._PAIR_DEDUP_MAX + 1):
        b, v = M._dedup_recv_pairs(empty, empty, nn, 4)
        assert len(b) == len(v) == 0


def test_dedup_no_int64_wrap_at_huge_n():
    from repro.core.metrics import _dedup_recv_pairs
    n = 2 ** 62                              # recv * n wraps for recv >= 2
    recv = np.array([3, 0, 3, 2, 3, 0], dtype=np.int64)
    vert = np.array([n - 1, 5, n - 1, 7, 2, 5], dtype=np.int64)
    blocks, verts = _dedup_recv_pairs(recv, vert, n, 4)
    np.testing.assert_array_equal(blocks, [0, 2, 3, 3])
    np.testing.assert_array_equal(verts, [5, 7, 2, n - 1])
