"""The ``make bench-diff`` regression gate (ISSUE 9 satellite).

``diff_payloads`` is the pure classifier (no git): >20% increases on
modeled objectives / makespans / round counts fail, wall-clock drift
only warns, agreement/noise bookkeeping never gates, and decreases are
always fine.  The tracked baselines themselves must parse and carry the
structural keys the gate fails on.
"""
import json

import pytest

from benchmarks.common import BASELINES
from benchmarks.diff import THRESHOLD, diff_payloads


def test_fail_on_modeled_objective_and_rounds():
    old = {"modeled_makespan": {"cut": 100.0},
           "tree_objective": {"cut": 50.0},
           "rounds": {"bottleneck": [1, 2, 1]}}
    new = {"modeled_makespan": {"cut": 130.0},       # +30% -> fail
           "tree_objective": {"cut": 55.0},          # +10% -> within band
           "rounds": {"bottleneck": [1, 2, 2]}}      # +100% -> fail
    failures, warnings = diff_payloads(old, new)
    paths = sorted(p for p, *_ in failures)
    assert paths == ["modeled_makespan.cut", "rounds.bottleneck[2]"]
    assert warnings == []


def test_latency_only_warns():
    old = {"per_iter_us": 1000.0, "spmv_us": 500.0, "wall_s": 10.0}
    new = {"per_iter_us": 2000.0, "spmv_us": 540.0, "wall_s": 30.0}
    failures, warnings = diff_payloads(old, new)
    assert failures == []
    assert sorted(p for p, *_ in warnings) == ["per_iter_us", "wall_s"]


def test_noise_keys_and_decreases_never_gate():
    old = {"agreement": {"max_rel_between": 1e-9},
           "modeled_makespan": 100.0, "per_iter_us": 1000.0,
           "win": {"per_iter": True}}
    new = {"agreement": {"max_rel_between": 1e-3},   # skip-classed
           "modeled_makespan": 40.0,                 # improvement
           "per_iter_us": 700.0,
           "win": {"per_iter": False}}               # bool: not numeric
    assert diff_payloads(old, new) == ([], [])


def test_new_and_missing_metrics_are_skipped():
    # a metric only on one side has no baseline to regress against
    failures, warnings = diff_payloads(
        {"modeled_makespan": {"cut": 100.0}},
        {"modeled_makespan": {"bottleneck": 400.0}})
    assert (failures, warnings) == ([], [])


def test_threshold_is_relative_increase():
    old = {"rounds": [10]}
    at = {"rounds": [round(10 * (1 + THRESHOLD), 6)]}   # exactly +20%
    over = {"rounds": [10 * (1 + THRESHOLD) + 0.1]}
    assert diff_payloads(old, at) == ([], [])
    failures, _ = diff_payloads(old, over)
    assert len(failures) == 1


@pytest.mark.parametrize("path", sorted(BASELINES.glob("BENCH_*.json")),
                         ids=lambda p: p.name)
def test_tracked_baselines_parse_and_self_diff_clean(path):
    payload = json.loads(path.read_text())
    assert isinstance(payload, dict) and payload
    # identical payloads never regress against themselves
    assert diff_payloads(payload, payload) == ([], [])
