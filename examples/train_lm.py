"""End-to-end training driver: mamba2-130m (a full assigned architecture,
~129M params) on the synthetic pipeline with checkpointing.

  PYTHONPATH=src python examples/train_lm.py --steps 300 --seq 256 --batch 4
  PYTHONPATH=src python examples/train_lm.py --smoke --steps 50   # CI-sized
"""
import argparse

from repro.configs.registry import get_config
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    print(f"training {cfg.name}: {cfg.param_count / 1e6:.0f}M params")
    tcfg = TrainerConfig(steps=args.steps, seq_len=args.seq,
                         global_batch=args.batch, ckpt_every=50,
                         ckpt_dir=args.ckpt_dir, log_every=10)
    tr = Trainer(cfg, tcfg)
    if tr.maybe_resume():
        print(f"resumed from step {tr.step}")
    losses = tr.run()
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps")


if __name__ == "__main__":
    main()
