"""LDHT expert placement for a MoE LM — the paper's Algorithm 1 + swap
refinement applied to the olmoe-style expert-parallel layer.

Pipeline (mirrors the paper's two-stage LDHT process, Sec. IV):
  1. profile routing on a calibration batch -> expert loads + co-activation,
  2. stage 1: Algorithm 1 computes per-rank load budgets from PU speeds,
  3. stage 2: LPT greedy + pairwise-swap refinement places experts under
     the exact E_loc slot constraint (the 'memory capacity' Eq. 3),
  4. apply the placement: permute stacked expert weights + route via perm,
  5. verify numerics are unchanged and report the Eq. 2 objective.

Run:  PYTHONPATH=src python examples/moe_expert_placement.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.expert_placement import (coactivation_graph, expert_loads,
                                         place_experts,
                                         permute_expert_params)
from repro.core.topology import PU, Topology
from repro.models.common import ParamCollector
from repro.models.mlp import init_moe, moe_forward

B, S, D, E, K, F = 8, 64, 64, 16, 4, 128
EP = 4                                   # expert-parallel ranks


def main():
    rng = jax.random.PRNGKey(0)
    col = ParamCollector(rng, dtype=jnp.float32)
    params, _ = init_moe(col, D, E, F)

    # 1. calibration: run the router, collect top-k statistics
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32)
    logits = x @ params["router"]
    _, topk = jax.lax.top_k(jax.nn.softmax(logits, -1), K)
    ids = np.asarray(topk).reshape(-1, K)
    counts = np.bincount(ids.ravel(), minlength=E)
    loads = expert_loads(counts)
    coact = coactivation_graph(ids, E)
    print(f"expert load spread: min={loads.min():.4f} max={loads.max():.4f}")

    # 2+3. heterogeneous EP ranks: one 2x-speed rank (e.g. a newer chip
    # generation in the serving pool) and three baseline ranks
    topo = Topology(pus=[PU(speed=2.0, memory=1e9)]
                    + [PU(speed=1.0, memory=1e9) for _ in range(EP - 1)])
    res = place_experts(loads, topo, coact=coact)
    print(f"rank loads: {np.round(res.load_per_rank, 4)} "
          f"(speeds {topo.speeds})")
    print(f"Eq.2 max load/speed: {res.max_load_ratio:.4f}  "
          f"(uniform contiguous placement: "
          f"{(loads.reshape(EP, -1).sum(1) / topo.speeds).max():.4f})")
    print(f"Eq.1 co-activation cut: {res.coact_cut:.1f}")

    # 4. apply placement
    y0, _ = moe_forward(params, x, n_experts=E, top_k=K, impl="dense")
    p2 = dict(params)
    p2.update(permute_expert_params(
        {k: params[k] for k in ("w1", "w2", "w3")}, res.perm))
    y1, _ = moe_forward(p2, x, n_experts=E, top_k=K, impl="dense",
                        expert_perm=jnp.asarray(res.perm))

    # 5. verify
    err = float(jnp.abs(y0 - y1).max())
    print(f"placement numerics max|y0-y1| = {err:.2e}")
    assert err < 1e-5
    print("OK — placement is numerics-neutral and load-balanced.")


if __name__ == "__main__":
    main()
