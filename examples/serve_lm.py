"""Batched serving example: prefill a prompt batch, decode with KV/state
caches (works for every assigned architecture family).

  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b --smoke
"""
import subprocess
import sys

if __name__ == "__main__":
    # serve.py is the production entry point; this example simply drives it
    # for a couple of architectures to show family coverage.
    archs = sys.argv[1:] or ["qwen1.5-0.5b", "mamba2-130m",
                             "recurrentgemma-2b"]
    for arch in archs:
        print(f"=== {arch} ===", flush=True)
        subprocess.run([sys.executable, "-m", "repro.launch.serve",
                        "--arch", arch, "--smoke", "--batch", "2",
                        "--prompt-len", "16", "--gen", "8"], check=True)
