"""Quickstart: the paper's two-stage LDHT pipeline in ~30 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Topology, evaluate, partition, scale_to_load, \
    target_block_sizes
from repro.core.metrics import summarize
from repro.sparse.generators import rdg

# 1. an application graph (Delaunay mesh, as in the paper's instances)
g = rdg(8000, seed=0)
print(f"graph: n={g.n} m={g.num_edges}")

# 2. a heterogeneous compute system: 2 fast PUs (GPU-like: 16x speed,
#    limited memory) + 10 slow PUs (TOPO1, Table III exp 5)
topo = scale_to_load(Topology.topo1(12, 1 / 6, 16.0, 13.8), g.n)

# 3. stage 1 — Algorithm 1: optimal target block sizes
tw = target_block_sizes(g.n, topo)
print("target weights:", np.round(tw).astype(int).tolist())
print(f"tw(fast)/tw(slow) = {tw[0] / tw[-1]:.1f}")

# 4. stage 2 — cut-minimizing partition honoring those sizes
part, _ = partition(g, topo, method="geoRef", tw=tw)
print("metrics:", summarize(g, part, topo, tw))

# 5. compare the whole tool zoo (Table IV analogue)
evaluate(g, topo, methods=("sfc", "rcb", "rib", "geoKM", "geoRef"))
