"""End-to-end distributed application: heterogeneous partition -> shard_map
CG solve on 8 (forced host) devices, with edge-colored ppermute halo
exchange overlapped against the interior matvec — through the Operator
protocol, so the same few lines drive the overlapped halo backend, the
Jacobi-preconditioned solve, the allgather baseline, and the single-device
COO reference.  Compares the paper-aware partition against an SFC baseline.

  PYTHONPATH=src python examples/heterogeneous_cg.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.core import Topology, partition, scale_to_load
from repro.core.metrics import max_comm_volume
from repro.sparse import make_operator, cg_solve_global
from repro.sparse.generators import rdg
from repro.sparse.graph import laplacian_csr

g = rdg(6000, seed=1)
topo = scale_to_load(Topology.topo1(8, 2 / 8, 8.0, 8.5), g.n)
indptr, indices, data = laplacian_csr(g, shift=1e-2)
mesh = jax.sharding.Mesh(np.array(jax.devices()[:8]), ("pu",))
rng = np.random.default_rng(0)
b = rng.normal(size=g.n).astype(np.float32)

import scipy.sparse as sp
A = sp.csr_matrix((data, indices, indptr), shape=(g.n, g.n))

for method in ("sfc", "geoRef"):
    part, tw = partition(g, topo, method)
    op = make_operator(indptr, indices, data, "dist_halo",
                       part=part, k=8, mesh=mesh)
    res = op.solve(b, tol=1e-6, max_iters=1000)     # fused whole-CG SPMD
    x = op.gather(res.x)
    rel = np.linalg.norm(A @ x - b) / np.linalg.norm(b)
    plan = op.plan
    interior = int(np.asarray(plan.interior_mask).sum())
    print(f"{method:7s}: maxCommVol={max_comm_volume(g, part, 8):5d} "
          f"halo_slots={plan.S:5d} rounds={plan.n_rounds} "
          f"interior_rows={interior}/{g.n} "
          f"cg_iters={int(res.iters)} rel_res={rel:.2e}")

# Jacobi-preconditioned fused CG off the plan's on-device diagonal
part, _ = partition(g, topo, "geoRef")
op = make_operator(indptr, indices, data, "dist_halo",
                   part=part, k=8, mesh=mesh)
res = op.solve(b, tol=1e-6, max_iters=1000, precondition="jacobi")
x = op.gather(res.x)
rel = np.linalg.norm(A @ x - b) / np.linalg.norm(b)
print(f"jacobi PCG:  cg_iters={int(res.iters)} rel_res={rel:.2e} "
      f"(M = diag(A), extracted at plan build)")

# the partitioner-oblivious baseline: same operator API, allgather comm
op_ag = make_operator(indptr, indices, data, "dist_allgather",
                      part=part, k=8, mesh=mesh)
x, iters, _ = cg_solve_global(op_ag, b, tol=1e-6, max_iters=1000)
rel = np.linalg.norm(A @ x - b) / np.linalg.norm(b)
print(f"allgather baseline: cg_iters={iters} rel_res={rel:.2e} "
      f"(comm volume O(n) vs O(boundary))")

# two-level (multi-pod) schedule: same 8 devices as a (2, 4) ("pod", "pu")
# mesh.  Pod assignment groups Algorithm-1 blocks contiguously (fast PUs
# first -> they share the fast links); only the pod-crossing cut pays the
# slow inter-pod rounds, and the intra-pod boundary accumulation overlaps
# with them.
from repro.launch.mesh import make_test_mesh

mesh_hier = make_test_mesh(8, pods=2)
op_h = make_operator(indptr, indices, data, "dist_hier", part=part, k=8,
                     mesh=mesh_hier, pods=topo.pod_assignment(2))
res = op_h.solve(b, tol=1e-6, max_iters=1000)
x = op_h.gather(res.x)
rel = np.linalg.norm(A @ x - b) / np.linalg.norm(b)
hplan = op_h.plan
print(f"hier (2 pods): rounds intra={hplan.n_rounds_intra} "
      f"inter={hplan.n_rounds_inter} (flat plan: {op.plan.n_rounds} "
      f"rounds, all at inter-pod latency) cg_iters={int(res.iters)} "
      f"rel_res={rel:.2e}")

# block-Jacobi PCG: per-PU diagonal blocks, extracted from the plan
res = op_h.solve(b, tol=1e-6, max_iters=1000, precondition="block_jacobi")
x = op_h.gather(res.x)
rel = np.linalg.norm(A @ x - b) / np.linalg.norm(b)
print(f"hier + block-Jacobi PCG: cg_iters={int(res.iters)} "
      f"rel_res={rel:.2e} (M = blockdiag(A_bb))")
print("note: halo_slots ~ comm volume — the partitioner quality the paper "
      "optimizes maps 1:1 onto ppermute buffer sizes here.  interior rows "
      "(no halo-slot reads) overlap their matvec with the ppermute rounds; "
      "on multi-pod meshes intra-pod boundary rows additionally overlap "
      "the slow inter-pod rounds.")
