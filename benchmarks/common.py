"""Shared benchmark utilities — timing, CSV row emission, and the tracked
JSON baseline writer."""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path
from typing import Callable

BASELINES = Path(__file__).resolve().parent / "baselines"


def write_bench_json(name: str, payload: dict) -> None:
    """``benchmarks/baselines/BENCH_<name>.json`` — the machine-readable
    counterpart of the CSV rows, committed per PR so the perf trajectory
    is diffable across the git history (the repo root's ``BENCH_*.json``
    scratch outputs stay ignored)."""
    BASELINES.mkdir(exist_ok=True)
    path = BASELINES / f"BENCH_{name}.json"
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"# wrote {path}", file=sys.stderr, flush=True)


def time_us(fn: Callable, *args, reps: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(*args)
    return (time.perf_counter() - t0) / reps * 1e6


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"
