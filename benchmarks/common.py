"""Shared benchmark utilities — timing + CSV row emission."""
from __future__ import annotations

import time
from typing import Callable


def time_us(fn: Callable, *args, reps: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(*args)
    return (time.perf_counter() - t0) / reps * 1e6


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"
