"""Table IV analogue: cut / maxCommVolume / partition time per tool, per
graph, per heterogeneous topology.

Topology naming follows the paper's x-axis: t1_f8_fs16 = TOPO1, 8 fast PUs,
fast speed 16 (of 96 PUs total -> |F| = k/12).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import METHODS, Topology, partition, scale_to_load, \
    target_block_sizes
from repro.core.metrics import edge_cut, max_comm_volume
from repro.sparse.generators import grid, rdg, rgg

from .common import row

GRAPHS = {
    "rdg_2d": lambda: rdg(20000, seed=1),
    "rgg_2d": lambda: rgg(20000, dim=2, seed=1),
    "rgg_3d": lambda: rgg(15000, dim=3, seed=1),
    "grid_2d": lambda: grid((140, 140)),
}

# k=24 scaled-down analogue of the paper's 96-PU runs (CPU container)
TOPOS = {
    "t1_f2_fs4": lambda n: scale_to_load(
        Topology.topo1(24, 1 / 12, 4.0, 5.2), n),
    "t1_f2_fs16": lambda n: scale_to_load(
        Topology.topo1(24, 1 / 12, 16.0, 13.8), n),
    "t2_f4_fs16": lambda n: scale_to_load(
        Topology.topo2(24, 1 / 6, 16.0, 13.8), n),
}

BENCH_METHODS = ("sfc", "rcb", "rib", "geoKM", "geoRef", "greedyRef")


def run(methods=BENCH_METHODS, graphs=None, topos=None) -> list[str]:
    rows = []
    for gname, gf in (graphs or GRAPHS).items():
        g = gf()
        for tname, tf in (topos or TOPOS).items():
            topo = tf(g.n)
            tw = target_block_sizes(g.n, topo)
            for m in methods:
                t0 = time.perf_counter()
                part, _ = partition(g, topo, m, tw=tw)
                dt = time.perf_counter() - t0
                cut = edge_cut(g, part)
                mcv = max_comm_volume(g, part, topo.k)
                rows.append(row(f"{gname}__{tname}__{m}", dt * 1e6,
                                f"cut={cut:.0f};maxCV={mcv}"))
    return rows
