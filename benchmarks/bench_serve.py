"""Solver-serving benchmark: multi-RHS batched CG behind the
``SolverService`` cache/admission layer, across the coo / dist_halo /
dist_hier backends.

Per backend (`make bench-serve`):

  * **cold vs warm latency** — the first request for a (matrix, size
    class) pays plan construction + format conversion + the jit trace;
    every repeat is an operator-cache hit landing on the compiled
    program.  ``speedup = cold / warm_p50`` is the serving headline (the
    acceptance bar is >= 5x).
  * **throughput** — solves/sec and p50/p95/max latency over warm
    repeat traffic with fresh RHS batches.
  * **batched vs sequential** — a mixed-difficulty nb=4 batch (hard /
    easy / zero / scaled columns) served in one masked batched solve must
    match the four single-column solves to < 1e-5, with per-column
    iteration counts summing to fewer matvec-equivalents than the naive
    ``nb x max(iters)`` (converged columns freeze instead of riding
    along).

Distributed backends run in a subprocess with 8 forced host devices
(this process keeps the default 1); same caveat as bench_cg — host
devices show schedule overhead, not interconnect wins.  Results land in
CSV rows on stdout and ``benchmarks/baselines/BENCH_serve.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

from .common import row, write_bench_json

WARM_REQUESTS = 12
NB = 4


def _measure(backend: str) -> dict:
    """Runs under whatever device count the process was started with —
    in-process for coo, an 8-device subprocess for dist backends."""
    import jax
    import scipy.sparse as sp

    from repro.launch.serve import SolverService
    from repro.sparse.generators import grid
    from repro.sparse.graph import laplacian_csr

    g = grid((48, 32))
    indptr, indices, data = laplacian_csr(g, shift=0.05)
    n = g.n
    kw = {}
    if backend in ("dist_halo", "dist_hier"):
        part = (np.arange(n) * 8) // n      # locality-preserving stripes
        kw = dict(part=part, k=8)
        if backend == "dist_hier":
            from repro.launch.mesh import make_test_mesh
            kw.update(mesh=make_test_mesh(8, pods=2), pods=2)
        else:
            kw.update(mesh=jax.sharding.Mesh(np.array(jax.devices()),
                                             ("pu",)))
    svc = SolverService(backend=backend, tol=1e-6, max_iters=600, **kw)
    rng = np.random.default_rng(0)

    # standalone plan build + format conversion — the planning-path cost
    # a cache miss pays before any compilation (bench-diff gates it)
    from repro.sparse import make_operator
    t0 = time.perf_counter()
    make_operator(indptr, indices, data, backend, **kw)
    plan_build_s = time.perf_counter() - t0

    def fresh_batch():
        return rng.normal(size=(n, NB)).astype(np.float32)

    t0 = time.perf_counter()
    first = svc.solve(indptr, indices, data, fresh_batch())
    cold_ms = (time.perf_counter() - t0) * 1e3
    assert not first.cache_hit and not first.warm

    lat = []
    t_all = time.perf_counter()
    for _ in range(WARM_REQUESTS):
        t0 = time.perf_counter()
        r = svc.solve(indptr, indices, data, fresh_batch())
        np.asarray(r.x)
        lat.append((time.perf_counter() - t0) * 1e3)
    wall = time.perf_counter() - t_all
    assert r.cache_hit and r.warm
    lat = np.sort(np.array(lat))
    warm_p50 = float(np.percentile(lat, 50))

    # batched vs per-column sequential, mixed difficulty
    A = sp.csr_matrix((data, indices, indptr), shape=(n, n))
    hard = rng.normal(size=n).astype(np.float32)
    e = np.zeros(n, np.float32)
    e[n // 2] = 1.0
    easy = (A @ e).astype(np.float32)
    cols = [hard, easy, np.zeros(n, np.float32),
            (0.1 * hard).astype(np.float32)]
    resp = svc.solve(indptr, indices, data, np.stack(cols, axis=1))
    rel = 0.0
    seq_iters = []
    for j, col in enumerate(cols):
        single = svc.solve(indptr, indices, data, col)
        seq_iters.append(int(single.iters))
        scale = max(float(np.abs(single.x).max()), 1.0)
        rel = max(rel, float(np.abs(resp.x[:, j] - single.x).max()) / scale)
    iters = [int(i) for i in np.asarray(resp.iters)]
    s = svc.stats
    return {
        "n": n, "nb": NB,
        "plan_build_s": plan_build_s,
        "cold_ms": cold_ms,
        "warm_p50_ms": warm_p50,
        "warm_p95_ms": float(np.percentile(lat, 95)),
        "warm_max_ms": float(lat[-1]),
        "speedup_cold_over_warm": cold_ms / warm_p50,
        "solves_per_sec": WARM_REQUESTS / wall,
        "batched_vs_seq_rel": rel,
        "batched_iters": iters,
        "seq_iters": seq_iters,
        "matvec_equiv": int(sum(iters)),
        "matvec_equiv_naive": NB * max(iters),
        "operator_hits": s.operator_hits,
        "operator_misses": s.operator_misses,
        "bucket_hits": s.bucket_hits,
        "bucket_misses": s.bucket_misses,
        "padding_waste": s.padding_waste,
    }


def _subprocess_measure(backend: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_serve",
         "--inner", backend],
        capture_output=True, text=True, timeout=1200, env=env)
    if proc.returncode != 0:
        return {"error": proc.stderr[-2000:]}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", metavar="BACKEND",
                    help="(internal) measure one backend and print JSON")
    ap.add_argument("--backends", default="coo,dist_halo,dist_hier",
                    help="comma-separated backends to bench")
    args = ap.parse_args()
    if args.inner:
        print(json.dumps(_measure(args.inner)))
        return

    rows = ["name,us,derived"]
    payload = {"bench": "serve", "warm_requests": WARM_REQUESTS,
               "backends": {}}
    for backend in args.backends.split(","):
        backend = backend.strip()
        out = (_measure(backend) if backend == "coo"
               else _subprocess_measure(backend))
        payload["backends"][backend] = out
        if "error" in out:
            rows.append(row(f"serve_{backend}__ERROR", 0,
                            out["error"][-200:].replace(",", ";")))
            continue
        rows.append(row(f"serve_{backend}_cold", out["cold_ms"] * 1e3,
                        f"nb={out['nb']} n={out['n']}"))
        rows.append(row(
            f"serve_{backend}_warm_p50", out["warm_p50_ms"] * 1e3,
            f"speedup={out['speedup_cold_over_warm']:.1f}x "
            f"solves/s={out['solves_per_sec']:.1f}"))
        rows.append(row(
            f"serve_{backend}_batched", 0,
            f"rel={out['batched_vs_seq_rel']:.1e} "
            f"matvecs={out['matvec_equiv']}/"
            f"{out['matvec_equiv_naive']} naive"))
    write_bench_json("serve", payload)
    print("\n".join(rows))


if __name__ == "__main__":
    main()
