"""Delta-replanning bench (``make bench-delta``) — O(delta) plan
patching vs a fresh ``build_plan_tree`` on the mutated matrix.

The streaming-graph serving story (ISSUE 10) only holds if patching a
cached plan is much cheaper than rebuilding it.  This bench prices both
on the 256x256 grid Laplacian (k=8, locality-preserving stripes) at <=1%
edge churn, on a depth-2 (2, 4) and a depth-3 (2, 2, 2) mesh:

  * **value-only delta** (1% of entries reweighted) — the headline gated
    number: streaming weight updates are the common case (time-varying
    conductances / edge weights on a fixed mesh), the patch touches no
    structure, and must be **>= 10x** faster than the fresh build.
  * **structural delta** (edge insertions localized to one block's tile)
    — informational: the patch rebuilds every *affected* block, so its
    win is locality-dependent (reported, plan-class in bench-diff, but
    not held to the 10x bar).

Every configuration also re-verifies the contract once: the patched plan
is compared field-by-field (bitwise) against the fresh build, and runs
the PLAN001-010 static verifier.  All host-side NumPy — no devices.

The committed ``benchmarks/baselines/BENCH_delta.json`` carries
``price.patch_vs_fresh_*`` (fail-class in ``make bench-diff``) so a
planning-path regression that erodes the 10x gate is caught at commit
time.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from .common import row, write_bench_json

CHURN = 0.01
REPS = 5
_SKIP_FIELDS = {"_bell", "_bj_inv", "_cols_global", "_replan"}


def _plans_equal(a, b) -> bool:
    """Field-by-field bit equality (same contract as the test suites)."""
    def eq(x, y):
        if x is None or y is None:
            return x is None and y is None
        if isinstance(x, (tuple, list)):
            return (isinstance(y, (tuple, list)) and len(x) == len(y)
                    and all(eq(u, v) for u, v in zip(x, y)))
        if isinstance(x, (int, float, str, bool)):
            return x == y
        xn, yn = np.asarray(x), np.asarray(y)
        return (xn.dtype == yn.dtype and xn.shape == yn.shape
                and bool(np.array_equal(xn, yn)))

    return all(eq(getattr(a, f.name), getattr(b, f.name))
               for f in dataclasses.fields(a)
               if f.name not in _SKIP_FIELDS)


def _grid_laplacian(side: int):
    from repro.sparse.generators import grid
    from repro.sparse.graph import laplacian_csr

    return laplacian_csr(grid((side, side)), shift=1e-2)


def _value_delta(rng, indptr, indices, n):
    """Reweight CHURN of all entries — the streaming-weights case."""
    from repro.sparse.replan import EdgeDelta

    nnz = len(indices)
    pos = rng.choice(nnz, size=int(CHURN * nnz), replace=False)
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    return EdgeDelta(n, set_rows=src[pos], set_cols=np.asarray(indices)[pos],
                     set_vals=rng.uniform(-2.0, 2.0, size=len(pos)))


def _structural_delta(rng, side: int, n: int):
    """Insert diagonal-neighbor edges inside one 16x16 tile of the grid —
    churn localized to a single block, the favorable structural case."""
    from repro.sparse.replan import EdgeDelta

    tile = 16
    ii = rng.integers(0, tile - 1, size=200)
    jj = rng.integers(0, tile - 1, size=200)
    a = ii * side + jj
    b = a + side + 1                      # not in the 5-point stencil
    seen, sr, sc, sv = set(), [], [], []
    for x, y in zip(a.tolist(), b.tolist()):
        if (x, y) in seen:
            continue
        seen.add((x, y))
        w = float(rng.uniform(0.1, 1.0))
        sr += [x, y]
        sc += [y, x]
        sv += [w, w]
    return EdgeDelta(n, set_rows=sr, set_cols=sc, set_vals=sv)


def _min_of(fn, reps: int = REPS) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> int:
    from repro.analysis.verify import verify_plan
    from repro.sparse.distributed import build_plan_tree
    from repro.sparse.replan import apply_delta_csr, apply_edge_delta

    side, k = 256, 8
    indptr, indices, data = _grid_laplacian(side)
    n = len(indptr) - 1
    part = ((np.arange(n) * k) // n).astype(np.int32)
    rng = np.random.default_rng(42)

    rows = ["name,us,derived"]
    payload = {"bench": "delta", "n": n, "nnz": len(indices), "k": k,
               "churn": CHURN, "configs": {}, "price": {}}
    ok_10x = True
    for label, fanouts in (("depth2", (2, 4)), ("depth3", (2, 2, 2))):
        plan = build_plan_tree(indptr, indices, data, part, None, k,
                               fanouts=fanouts)
        # fresh build keeps cache=True: a serving rebuild must re-capture
        # the replan cache too, so that's the honest alternative cost
        fresh_s = _min_of(lambda: build_plan_tree(
            indptr, indices, data, part, None, k, fanouts=fanouts,
            validate=False))
        dv = _value_delta(rng, indptr, indices, n)
        patch_s = _min_of(lambda: apply_edge_delta(plan, dv,
                                                   validate=False))
        ds = _structural_delta(rng, side, n)
        spatch_s = _min_of(lambda: apply_edge_delta(plan, ds,
                                                    validate=False))

        # contract re-check: patched == fresh, and the verifier passes
        equal = True
        for delta in (dv, ds):
            patched = apply_edge_delta(plan, delta, validate=False)
            ip2, ix2, d2 = apply_delta_csr(indptr, indices, data, delta)
            fresh = build_plan_tree(ip2, ix2, d2, part, None, k,
                                    fanouts=fanouts, validate=False)
            equal = equal and _plans_equal(patched, fresh) \
                and verify_plan(patched).ok

        speedup = fresh_s / patch_s
        ok_10x = ok_10x and equal and speedup >= 10.0
        payload["configs"][label] = {
            "fanouts": list(fanouts),
            "fresh_build_s": fresh_s,
            "patch_s": patch_s,
            "speedup": speedup,
            "structural_patch_s": spatch_s,
            "structural_entries": len(ds),
            "structural_speedup": fresh_s / spatch_s,
            "bitwise_equal": equal,
        }
        payload["price"][f"patch_vs_fresh_{label}"] = patch_s / fresh_s
        rows.append(row(f"delta_{label}_fresh_build", fresh_s * 1e6))
        rows.append(row(f"delta_{label}_value_patch", patch_s * 1e6,
                        f"speedup={speedup:.1f}x equal={equal}"))
        rows.append(row(f"delta_{label}_structural_patch", spatch_s * 1e6,
                        f"speedup={fresh_s / spatch_s:.1f}x"))

    payload["meets_10x"] = ok_10x
    print("\n".join(rows))
    write_bench_json("delta", payload)
    if not ok_10x:
        print("bench-delta: FAILED — value-delta patch below the 10x bar "
              "or patched plan not bit-equal")
        return 1
    print("bench-delta: value-delta patch >= 10x fresh build at both "
          "depths, patched plans bit-equal")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
