"""Fig. 1 analogue: flat balanced k-means vs the hierarchical version —
relative edge cut and max comm volume (paper: within ~±1%, hierarchy helps
mapping)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import Topology, scale_to_load, target_block_sizes
from repro.core.balanced_kmeans import (partition_balanced_kmeans,
                                        partition_hierarchical_kmeans)
from repro.core.metrics import edge_cut, max_comm_volume
from repro.sparse.generators import rdg, rgg

from .common import row


def run() -> list[str]:
    rows = []
    for gname, g in (("rdg_2d", rdg(15000, seed=2)),
                     ("rgg_2d", rgg(15000, dim=2, seed=2))):
        topo = scale_to_load(
            Topology.topo3(nodes=4, cores_per_node=6, fast_nodes=2), g.n)
        tw = target_block_sizes(g.n, topo)
        t0 = time.perf_counter()
        flat = partition_balanced_kmeans(g, tw, seed=0)
        t_flat = time.perf_counter() - t0
        t0 = time.perf_counter()
        hier = partition_hierarchical_kmeans(g, tw, topo.fanouts, seed=0)
        t_hier = time.perf_counter() - t0
        cut_f, cut_h = edge_cut(g, flat), edge_cut(g, hier)
        cv_f = max_comm_volume(g, flat, topo.k)
        cv_h = max_comm_volume(g, hier, topo.k)
        rows.append(row(f"hier_vs_flat__{gname}", t_hier * 1e6,
                        f"cut_rel={cut_h / cut_f:.3f};"
                        f"cv_rel={cv_h / max(cv_f, 1):.3f};"
                        f"t_rel={t_hier / t_flat:.2f}"))
    return rows
