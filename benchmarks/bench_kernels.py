"""Pallas kernel microbenchmarks (interpret mode on CPU — numbers reflect
the reference execution; the structural roofline for TPU lives in
EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.flash import flash_attention
from repro.kernels.pdist import pairwise_sqdist_pallas
from repro.kernels.ref import flash_attention_ref, pairwise_sqdist_ref
from repro.kernels.spmv_bell import csr_to_block_ell, spmv_block_ell

from .common import row, time_us


def run() -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4096, 3)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(96, 3)), jnp.float32)
    us_p = time_us(lambda: pairwise_sqdist_pallas(
        x, c, interpret=True).block_until_ready(), reps=3)
    us_r = time_us(lambda: pairwise_sqdist_ref(
        x, c).block_until_ready(), reps=3)
    rows.append(row("pdist_pallas_4096x96", us_p, f"ref_us={us_r:.0f}"))

    from scipy.sparse import random as sprand
    n = 2048
    A = sprand(n, n, density=0.01, random_state=0, format="csr")
    A = (A + A.T).tocsr()
    blocks, cols, meta = csr_to_block_ell(
        A.indptr, A.indices, A.data.astype(np.float32), n)
    xb = jnp.asarray(rng.normal(size=n), jnp.float32)
    bj, cj = jnp.asarray(blocks), jnp.asarray(cols)
    us_s = time_us(lambda: spmv_block_ell(
        bj, cj, xb, interpret=True).block_until_ready(), reps=3)
    rows.append(row("spmv_bell_2048", us_s,
                    f"nnzb={meta['nnzb']};fill={meta['fill']:.2f}"))

    q = jnp.asarray(rng.normal(size=(1, 4, 512, 64)), jnp.float32)
    us_f = time_us(lambda: flash_attention(
        q, q, q, causal=True, interpret=True).block_until_ready(), reps=3)
    us_fr = time_us(lambda: flash_attention_ref(
        q, q, q, causal=True).block_until_ready(), reps=3)
    rows.append(row("flash_attn_512", us_f, f"ref_us={us_fr:.0f}"))
    return rows
