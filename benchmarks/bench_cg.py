"""Fig. 5 analogue: CG time per iteration under different partitions
(TOPO3-style heterogeneity).

Two measurements per partitioner:
  * real: measured single-process SpMV+CG microseconds (CPU; homogeneous);
  * modeled heterogeneous step time, the paper's TOPO3 simulation —
        T_iter = max_i(|b_i| * c_nnz / speed_i) + alpha * maxCommVolume
    with c_nnz the measured per-row SpMV cost and alpha the per-word
    exchange cost (derived from the halo plan, not guessed).

Plus the Operator-era rows:
  * ``build_plan`` vectorization speedup vs the seed per-edge builder
    (256x256 grid Laplacian, k=8, random partition = maximal boundary);
  * cross-backend CG agreement (coo / bell / dist_halo (overlapped) /
    dist_halo_seq / dist_bell / dist_allgather, plus Jacobi-preconditioned
    variants, through the one ``make_operator`` + ``cg_solve_global``
    harness, the distributed ones on 8 forced host devices in a
    subprocess);
  * overlapped vs sequential halo SpMV microseconds.  Caveat: on forced
    host devices a ppermute is a same-process memcpy with no latency to
    hide, so the overlapped schedule's split (two scatter-adds instead of
    one) shows pure overhead here; the win appears on real interconnects
    where the interior matvec runs while the rounds are in flight.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap
import time

import jax.numpy as jnp
import numpy as np

from repro.core import Topology, partition, scale_to_load, \
    target_block_sizes
from repro.core.metrics import block_sizes_of, max_comm_volume
from repro.sparse.cg import cg_solve
from repro.sparse.distributed import build_plan, build_plan_reference
from repro.sparse.generators import grid, rdg
from repro.sparse.graph import laplacian_csr
from repro.sparse.spmv import csr_to_padded_coo, spmv_coo

from .common import row, write_bench_json as _write_bench_json

DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import numpy as np
    import jax
    from repro.sparse.generators import rdg
    from repro.sparse.graph import laplacian_csr
    from repro.sparse import make_operator, cg_solve_global

    g = rdg(512, seed=9)
    indptr, indices, data = laplacian_csr(g, shift=0.1)
    part = np.random.default_rng(0).integers(0, 8, g.n)
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("pu",))
    b = np.random.default_rng(1).normal(size=g.n).astype(np.float32)

    out = {}
    sols = {}
    for name in ("coo", "coo+jacobi", "bell", "dist_halo",
                 "dist_halo+jacobi", "dist_halo_seq", "dist_bell",
                 "dist_allgather"):
        backend, _, variant = name.partition("+")
        kw = (dict(part=part, k=8, mesh=mesh)
              if backend.startswith("dist") else {})
        op = make_operator(indptr, indices, data, backend, **kw)
        t0 = time.perf_counter()
        x, iters, res = cg_solve_global(op, b, tol=1e-7, max_iters=2000,
                                        precondition=variant or None)
        out[name] = {"iters": iters, "res": res,
                     "wall_us": (time.perf_counter() - t0) * 1e6}
        sols[name] = x
    scale = float(np.abs(sols["coo"]).max())
    out["max_pairwise_rel"] = max(
        float(np.abs(sols[a] - sols[b2]).max()) / scale
        for a in sols for b2 in sols if a < b2)

    # overlapped vs sequential halo vs allgather SpMV microseconds.
    # Locality-preserving stripes on a 64x32 grid: interior rows dominate
    # (the regime the overlap targets), unlike the worst-case random
    # partition above where nearly every row is boundary.
    from repro.sparse.generators import grid
    g = grid((64, 32))
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    part = (np.arange(g.n) * 8) // g.n
    xb = None
    for backend in ("dist_halo", "dist_halo_seq", "dist_allgather"):
        op = make_operator(indptr, indices, data, backend,
                           part=part, k=8, mesh=mesh)
        xb = op.scatter(np.random.default_rng(3).normal(
            size=g.n).astype(np.float32))
        op.matvec(xb).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            y = op.matvec(xb)
        y.block_until_ready()
        out[backend + "_spmv_us"] = (time.perf_counter() - t0) / 20 * 1e6
    print(json.dumps(out))
""")


HIER_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import numpy as np
    import jax
    from repro.sparse.generators import grid
    from repro.sparse.graph import laplacian_csr
    from repro.sparse import make_operator, cg_solve_global
    from repro.sparse.distributed import build_plan, build_plan_hier
    from repro.launch.mesh import make_test_mesh

    # locality-preserving stripes on the 2-D grid Laplacian: the partition
    # spans 2 pods, so only the pod-crossing cut pays the slow links
    g = grid((64, 32))
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    part = (np.arange(g.n) * 8) // g.n
    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("pu",))
    mesh_hier = make_test_mesh(8, pods=2)            # ("pod", "pu")
    b = np.random.default_rng(1).normal(size=g.n).astype(np.float32)

    out = {}
    fp = build_plan(indptr, indices, data, part, 8)
    hp = build_plan_hier(indptr, indices, data, part, 2, 8)
    out["rounds_flat"] = fp.n_rounds
    out["rounds_intra"] = hp.n_rounds_intra
    out["rounds_inter"] = hp.n_rounds_inter
    out["halo_slots_intra"] = hp.S_intra
    out["halo_slots_inter"] = hp.S_inter

    sols = {}
    for name, kw in (("dist_halo", dict(mesh=mesh)),
                     ("dist_hier", dict(mesh=mesh_hier, pods=2)),
                     ("dist_hier_bell", dict(mesh=mesh_hier, pods=2)),
                     ("dist_hier+block_jacobi", dict(mesh=mesh_hier,
                                                     pods=2))):
        backend, _, variant = name.partition("+")
        t0 = time.perf_counter()
        op = make_operator(indptr, indices, data, backend,
                           part=part, k=8, **kw)
        plan_build_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        x, iters, res = cg_solve_global(op, b, tol=1e-7, max_iters=2000,
                                        precondition=variant or None)
        out[name] = {"iters": iters, "res": res,
                     "plan_build_s": plan_build_s,
                     "wall_us": (time.perf_counter() - t0) * 1e6}
        sols[name] = x
        xb = op.scatter(np.random.default_rng(3).normal(
            size=g.n).astype(np.float32))
        op.matvec(xb).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            y = op.matvec(xb)
        y.block_until_ready()
        out[name]["spmv_us"] = (time.perf_counter() - t0) / 20 * 1e6
    scale = float(np.abs(sols["dist_halo"]).max())
    out["max_rel_vs_halo"] = max(
        float(np.abs(x - sols["dist_halo"]).max()) / scale
        for x in sols.values())
    print(json.dumps(out))
""")


POD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import numpy as np
    import jax
    from repro.core import (Topology, contiguous_pods, partition_hier,
                            scale_to_load)
    from repro.core.metrics import pod_comm_volumes
    from repro.sparse import make_operator, cg_solve_global
    from repro.sparse.generators import grid
    from repro.sparse.graph import laplacian_csr
    from repro.launch.mesh import make_test_mesh

    # stripes across the long axis: every stripe boundary (and the
    # contiguous-pod cut) is a full 128-wide grid line — the
    # pod-oblivious worst case the pipeline must beat
    g = grid((16, 128))
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    topo = scale_to_load(Topology.homogeneous(8), g.n)
    mesh_hier = make_test_mesh(8, pods=2)            # ("pod", "pu")
    b = np.random.default_rng(1).normal(size=g.n).astype(np.float32)

    part_s = ((np.arange(g.n) * 8) // g.n).astype(np.int32)
    pod_c = contiguous_pods(8, 2)
    res = partition_hier(g, topo, "geoRef", pods=2)

    out = {}
    for name, part, pods in (("oblivious", part_s, pod_c),
                             ("pod_aware", res.part, res.pod_of)):
        _, inter_v = pod_comm_volumes(g, part, 8, pods)
        t0 = time.perf_counter()
        if name == "pod_aware":      # partitioner output drives the runtime
            op = make_operator(indptr, indices, data, "dist_hier",
                               part=res, mesh=mesh_hier)
        else:
            op = make_operator(indptr, indices, data, "dist_hier",
                               part=part, k=8, mesh=mesh_hier, pods=pods)
        plan_build_s = time.perf_counter() - t0
        plan = op.plan               # the HierPlan the runtime executes
        t0 = time.perf_counter()
        x, iters, resid = cg_solve_global(op, b, tol=1e-7, max_iters=2000)
        wall = (time.perf_counter() - t0) * 1e6
        xb = op.scatter(np.random.default_rng(3).normal(
            size=g.n).astype(np.float32))
        op.matvec(xb).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            y = op.matvec(xb)
        y.block_until_ready()
        out[name] = {
            "inter_comm_volume": int(inter_v.sum()),
            "max_inter_comm_volume": int(inter_v.max()),
            "rounds_inter": plan.n_rounds_inter,
            "rounds_intra": plan.n_rounds_intra,
            "plan_build_s": plan_build_s,
            "iters": iters, "res": resid, "cg_wall_us": wall,
            "spmv_us": (time.perf_counter() - t0) / 20 * 1e6,
        }
        out[name + "_x"] = np.asarray(x).tolist()
    xa = np.array(out.pop("oblivious_x"))
    xb_ = np.array(out.pop("pod_aware_x"))
    out["max_rel_between"] = float(
        np.abs(xa - xb_).max() / np.abs(xa).max())
    print(json.dumps(out))
""")


TREE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import numpy as np
    import jax
    from repro.core import (Topology, canonical_ancestors, partition_tree,
                            scale_to_load)
    from repro.core.metrics import tree_comm_volumes
    from repro.sparse import make_operator, cg_solve_global
    from repro.sparse.distributed import build_plan, build_plan_tree
    from repro.sparse.generators import grid
    from repro.sparse.graph import laplacian_csr
    from repro.launch.mesh import make_test_mesh

    # stripes across the long axis on the depth-3 (2, 2, 2) mesh: every
    # stripe boundary costs a full 128-wide grid line, and the flat plan
    # pays every one of its rounds at the slowest-link latency
    g = grid((16, 128))
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    topo = scale_to_load(Topology.homogeneous(8, fanouts=(2, 2, 2)), g.n)
    mesh_tree = make_test_mesh(8, fanouts=(2, 2, 2))  # (pod, host, pu)
    b = np.random.default_rng(1).normal(size=g.n).astype(np.float32)

    part_s = ((np.arange(g.n) * 8) // g.n).astype(np.int32)
    anc_c = canonical_ancestors((2, 2, 2))
    fp = build_plan(indptr, indices, data, part_s, 8)
    res = partition_tree(g, topo, "geoRef")

    out = {"rounds_flat": fp.n_rounds}
    for name, part, tree in (("oblivious", part_s, anc_c),
                             ("tree_aware", res.part, res.anc)):
        vols = tree_comm_volumes(g, part, 8, tree)
        t0 = time.perf_counter()
        if name == "tree_aware":     # partitioner output drives the runtime
            op = make_operator(indptr, indices, data, "dist_hier",
                               part=res, mesh=mesh_tree)
        else:
            op = make_operator(indptr, indices, data, "dist_hier",
                               part=part, k=8, mesh=mesh_tree, tree=tree)
        plan_build_s = time.perf_counter() - t0
        plan = op.plan               # the TreePlan the runtime executes
        t0 = time.perf_counter()
        x, iters, resid = cg_solve_global(op, b, tol=1e-7, max_iters=2000)
        wall = (time.perf_counter() - t0) * 1e6
        xb = op.scatter(np.random.default_rng(3).normal(
            size=g.n).astype(np.float32))
        op.matvec(xb).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            y = op.matvec(xb)
        y.block_until_ready()
        out[name] = {
            "rounds_by_level": list(plan.n_rounds_lvl),
            "volume_by_level": [int(v.sum()) for v in vols],
            "max_volume_by_level": [int(v.max()) for v in vols],
            "plan_build_s": plan_build_s,
            "iters": iters, "res": resid, "cg_wall_us": wall,
            "spmv_us": (time.perf_counter() - t0) / 20 * 1e6,
        }
        out[name + "_x"] = np.asarray(x).tolist()
    xa = np.array(out.pop("oblivious_x"))
    xb_ = np.array(out.pop("tree_aware_x"))
    out["max_rel_between"] = float(
        np.abs(xa - xb_).max() / np.abs(xa).max())
    print(json.dumps(out))
""")


BOTTLENECK_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import numpy as np
    import jax
    from repro.core import Topology, partition_tree, scale_to_load
    from repro.core.costmodel import cost_model_for
    from repro.sparse import make_operator, cg_solve_global
    from repro.sparse.generators import grid
    from repro.sparse.graph import laplacian_csr
    from repro.launch.mesh import make_test_mesh

    # stripes grid on the depth-3 (2, 2, 2) mesh under a loose balance
    # cap (eps=0.5): the cut objective is oblivious to per-PU load below
    # the cap, so cut FM parks the biggest block ~17% over the mean —
    # and the padded SPMD runtime makes EVERY device pay that block as B
    # (plus the max per-level receive volume as S_lvl).  The bottleneck
    # objective prices exactly those maxima; on the measured machine
    # (forced host devices: homogeneous cores, every link a memcpy) the
    # honest model is flat lams=(1,1,1) with a compute-dominant c_comp.
    g = grid((16, 256))
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    topo = scale_to_load(Topology.homogeneous(8, fanouts=(2, 2, 2)), g.n)
    mesh = make_test_mesh(8, fanouts=(2, 2, 2))
    b = np.random.default_rng(1).normal(size=g.n).astype(np.float32)

    out = {}
    ops = {}
    for obj, kw in (("cut", {}),
                    ("bottleneck", dict(lams=(1.0, 1.0, 1.0),
                                        c_comp=8.0))):
        t0 = time.perf_counter()
        res = partition_tree(g, topo, "greedyRef", seed=0, objective=obj,
                             eps=0.5, passes=6, **kw)
        t_part = time.perf_counter() - t0
        t0 = time.perf_counter()
        op = make_operator(indptr, indices, data, "dist_hier",
                           part=res, mesh=mesh)
        plan_build_s = time.perf_counter() - t0
        ops[obj] = op
        plan = op.plan
        sizes = np.bincount(res.part, minlength=8)
        cm = cost_model_for("bottleneck", topo=topo,
                            lams=(1.0, 1.0, 1.0), c_comp=8.0)
        out[obj] = {
            "partition_s": t_part,
            "plan_build_s": plan_build_s,
            "B": int(plan.B),
            "S_lvl": [int(s) for s in plan.S_lvl],
            "rounds_by_level": list(plan.n_rounds_lvl),
            "block_sizes": sorted(int(s) for s in sizes),
            "modeled": cm.summary(g, res.part, res.anc),
            "tree_objective": float(cost_model_for("cut").price(
                g, res.part, np.atleast_2d(res.anc))),
        }
        x, iters, _res = cg_solve_global(op, b, tol=1e-7, max_iters=800)
        out[obj]["iters"] = iters
        out[obj + "_x"] = np.asarray(x).tolist()

    # interleaved min-of-5: host-device collectives jitter by ~10%, the
    # structural B/S_lvl/round gap is what the minima expose
    best = {obj: {"spmv_us": float("inf"), "per_iter_us": float("inf")}
            for obj in ops}
    for _trial in range(5):
        for obj, op in ops.items():
            xb = op.scatter(np.random.default_rng(3).normal(
                size=g.n).astype(np.float32))
            op.matvec(xb).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(50):
                y = op.matvec(xb)
            y.block_until_ready()
            spmv = (time.perf_counter() - t0) / 50 * 1e6
            t0 = time.perf_counter()
            x, iters, _res = cg_solve_global(op, b, tol=1e-7,
                                             max_iters=800)
            per = (time.perf_counter() - t0) * 1e6 / max(iters, 1)
            best[obj]["spmv_us"] = min(best[obj]["spmv_us"], spmv)
            best[obj]["per_iter_us"] = min(best[obj]["per_iter_us"], per)
    for obj in ops:
        out[obj].update(best[obj])
    xa = np.array(out.pop("cut_x"))
    xb_ = np.array(out.pop("bottleneck_x"))
    out["max_rel_between"] = float(np.abs(xa - xb_).max()
                                   / np.abs(xa).max())
    print(json.dumps(out))
""")


def _bench_bottleneck(rows: list[str]) -> None:
    """Bottleneck (makespan) vs cut refinement on the padded tree
    runtime (ISSUE 9).

    The headline numbers are structural — B (max padded block, the rows
    every device computes), S_lvl (max per-level receive volume, the
    halo slots every device pads to) and the per-level round split — and
    the measured per-CG-iteration / SpMV minima they drive.  The
    bottleneck objective prices exactly those maxima (max over PUs of
    modeled compute + per-level dedup receive volume), so its
    refinement must bring B and S_lvl below the cut-refined partition
    and the measured per-iteration time down with them."""
    t0 = time.perf_counter()
    proc = subprocess.run([sys.executable, "-c", BOTTLENECK_SCRIPT],
                          capture_output=True, text=True, timeout=1800)
    wall_s = time.perf_counter() - t0
    if proc.returncode != 0:
        rows.append(row("cg_bottleneck__ERROR", 0,
                        proc.stderr[-200:].replace(",", ";")))
        _write_bench_json("bottleneck", {
            "bench": "bottleneck", "wall_s": wall_s,
            "error": proc.stderr[-2000:]})
        return
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    cut, bn = out["cut"], out["bottleneck"]
    _write_bench_json("bottleneck", {
        "bench": "bottleneck", "wall_s": wall_s,
        "mesh": "grid16x256;k=8;fanouts=(2,2,2);greedyRef;eps=0.5",
        "B": {"cut": cut["B"], "bottleneck": bn["B"]},
        "S_lvl": {"cut": cut["S_lvl"], "bottleneck": bn["S_lvl"]},
        "rounds": {"cut": cut["rounds_by_level"],
                   "bottleneck": bn["rounds_by_level"]},
        "modeled_makespan": {
            "cut": cut["modeled"]["makespan"],
            "bottleneck": bn["modeled"]["makespan"]},
        "tree_objective": {"cut": cut["tree_objective"],
                           "bottleneck": bn["tree_objective"]},
        "per_iter_us": {"cut": cut["per_iter_us"],
                        "bottleneck": bn["per_iter_us"]},
        "spmv_us": {"cut": cut["spmv_us"], "bottleneck": bn["spmv_us"]},
        "iters": {"cut": cut["iters"], "bottleneck": bn["iters"]},
        "win": {
            "per_iter": bool(bn["per_iter_us"] < cut["per_iter_us"]),
            "spmv": bool(bn["spmv_us"] < cut["spmv_us"]),
            "B": bool(bn["B"] < cut["B"]),
            "makespan": bool(bn["modeled"]["makespan"]
                             < cut["modeled"]["makespan"])},
        "agreement": {"max_rel_between": out["max_rel_between"],
                      "pass_1e-5": bool(out["max_rel_between"] < 1e-5)},
        "raw": out,
    })
    for obj in ("cut", "bottleneck"):
        r = out[obj]
        rows.append(row(
            f"cg_bottleneck__{obj}", r["per_iter_us"],
            f"B={r['B']};S0={r['S_lvl'][0]};"
            f"rounds={'/'.join(map(str, r['rounds_by_level']))};"
            f"makespan={r['modeled']['makespan']:.0f};"
            f"spmv_us={r['spmv_us']:.0f};iters={r['iters']}"))
    rows.append(row(
        "cg_bottleneck__per_iter_ratio",
        cut["per_iter_us"] / max(bn["per_iter_us"], 1e-9),
        f"bottleneck_faster="
        f"{int(bn['per_iter_us'] < cut['per_iter_us'])};"
        f"B_ratio={cut['B'] / max(bn['B'], 1):.2f};"
        f"agree_1e-5={int(out['max_rel_between'] < 1e-5)}"))


def _bench_tree(rows: list[str]) -> None:
    """Depth-3 (2, 2, 2) tree schedule: per-level round/volume split,
    tree-aware vs oblivious partition (ISSUE 5).

    The headline numbers are the *per-level* round split (the flat plan
    pays its whole total at the slowest-link latency; the tree plan pays
    only ``rounds_by_level[-1]`` there) and the outermost-level comm
    volume, which the tree-aware pipeline must bring strictly below the
    stripes baseline.  Same forced-host-device caveat as the other
    distributed rows: local memcpy collectives show schedule overhead,
    not the per-level-latency win the splits quantify.
    """
    t0 = time.perf_counter()
    proc = subprocess.run([sys.executable, "-c", TREE_SCRIPT],
                          capture_output=True, text=True, timeout=1200)
    wall_s = time.perf_counter() - t0
    if proc.returncode != 0:
        rows.append(row("cg_tree__ERROR", 0,
                        proc.stderr[-200:].replace(",", ";")))
        _write_bench_json("tree", {"bench": "tree", "wall_s": wall_s,
                                   "error": proc.stderr[-2000:]})
        return
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    _write_bench_json("tree", {
        "bench": "tree", "wall_s": wall_s,
        "rounds": {name: out[name]["rounds_by_level"]
                   for name in ("oblivious", "tree_aware")},
        "rounds_flat": out["rounds_flat"],
        "comm_volumes": {name: out[name]["volume_by_level"]
                         for name in ("oblivious", "tree_aware")},
        "cg_wall_us": {name: out[name]["cg_wall_us"]
                       for name in ("oblivious", "tree_aware")},
        "iters": {name: out[name]["iters"]
                  for name in ("oblivious", "tree_aware")},
        "agreement": {"max_rel_between": out["max_rel_between"],
                      "pass_1e-5": bool(out["max_rel_between"] < 1e-5)},
        "raw": out,
    })
    for name in ("oblivious", "tree_aware"):
        r = out[name]
        lv = ";".join(f"lv{l}={c}" for l, c in
                      enumerate(r["rounds_by_level"]))
        vv = ";".join(f"lv{l}CV={c}" for l, c in
                      enumerate(r["volume_by_level"]))
        rows.append(row(
            f"cg_tree__{name}", r["cg_wall_us"],
            f"{lv};{vv};flat_total={out['rounds_flat']};"
            f"iters={r['iters']};spmv_us={r['spmv_us']:.0f}"))
    ob, ta = out["oblivious"], out["tree_aware"]
    rows.append(row(
        "cg_tree__outer_volume_ratio",
        ob["volume_by_level"][-1] / max(ta["volume_by_level"][-1], 1),
        f"tree_aware_lower="
        f"{int(ta['volume_by_level'][-1] < ob['volume_by_level'][-1])};"
        f"outer_rounds_lt_flat="
        f"{int(ob['rounds_by_level'][-1] < out['rounds_flat'])};"
        f"agree_1e-5={int(out['max_rel_between'] < 1e-5)}"))


def _bench_pod(rows: list[str]) -> None:
    """Pod-aware vs pod-oblivious partitions of the same mesh (ISSUE 4).

    The headline number is ``inter_comm_volume`` — the words the hier
    schedule moves over the slow inter-pod links.  The pod-aware
    pipeline (pods-first geoRef + pod-level sweep + weighted FM) must
    come in strictly below the stripes-with-contiguous-pods baseline at
    <= inter-pod rounds.  Same forced-host-device caveat as the other
    distributed rows: local memcpy collectives show schedule overhead,
    not the slow-link win the volumes quantify.
    """
    t0 = time.perf_counter()
    proc = subprocess.run([sys.executable, "-c", POD_SCRIPT],
                          capture_output=True, text=True, timeout=1200)
    wall_s = time.perf_counter() - t0
    if proc.returncode != 0:
        rows.append(row("cg_pod__ERROR", 0,
                        proc.stderr[-200:].replace(",", ";")))
        _write_bench_json("pod", {"bench": "pod", "wall_s": wall_s,
                                  "error": proc.stderr[-2000:]})
        return
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    _write_bench_json("pod", {
        "bench": "pod", "wall_s": wall_s,
        "rounds": {name: {"inter": out[name]["rounds_inter"],
                          "intra": out[name]["rounds_intra"]}
                   for name in ("oblivious", "pod_aware")},
        "comm_volumes": {name: {
            "inter": out[name]["inter_comm_volume"],
            "max_inter": out[name]["max_inter_comm_volume"]}
            for name in ("oblivious", "pod_aware")},
        "cg_wall_us": {name: out[name]["cg_wall_us"]
                       for name in ("oblivious", "pod_aware")},
        "iters": {name: out[name]["iters"]
                  for name in ("oblivious", "pod_aware")},
        "agreement": {"max_rel_between": out["max_rel_between"],
                      "pass_1e-5": bool(out["max_rel_between"] < 1e-5)},
        "raw": out,
    })
    for name in ("oblivious", "pod_aware"):
        r = out[name]
        rows.append(row(
            f"cg_pod__{name}", r["cg_wall_us"],
            f"interCV={r['inter_comm_volume']};"
            f"maxInterCV={r['max_inter_comm_volume']};"
            f"rounds_inter={r['rounds_inter']};"
            f"rounds_intra={r['rounds_intra']};"
            f"iters={r['iters']};spmv_us={r['spmv_us']:.0f}"))
    ob, pa = out["oblivious"], out["pod_aware"]
    rows.append(row(
        "cg_pod__inter_volume_ratio",
        ob["inter_comm_volume"] / max(pa["inter_comm_volume"], 1),
        f"pod_aware_lower={int(pa['inter_comm_volume'] < ob['inter_comm_volume'])};"
        f"rounds_le={int(pa['rounds_inter'] <= ob['rounds_inter'])};"
        f"agree_1e-5={int(out['max_rel_between'] < 1e-5)}"))


def _bench_hier(rows: list[str]) -> None:
    """Multi-pod (pods=2, k=8) schedule vs the flat plan.

    The headline number is the *round split*: the flat plan pays every one
    of its colored rounds at inter-pod latency on a multi-pod machine,
    while the hier plan pays only ``rounds_inter`` there (the intra rounds
    ride the fast links and overlap the inter exchange).  Same
    forced-host-device caveat as the overlap rows: local memcpy collectives
    show the schedule's overhead, not its win.
    """
    t0 = time.perf_counter()
    proc = subprocess.run([sys.executable, "-c", HIER_SCRIPT],
                          capture_output=True, text=True, timeout=1200)
    wall_s = time.perf_counter() - t0
    if proc.returncode != 0:
        rows.append(row("cg_hier__ERROR", 0,
                        proc.stderr[-200:].replace(",", ";")))
        _write_bench_json("hier", {"bench": "hier", "wall_s": wall_s,
                                   "error": proc.stderr[-2000:]})
        return
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    _write_bench_json("hier", {
        "bench": "hier", "wall_s": wall_s,
        "rounds": {"inter": out["rounds_inter"],
                   "intra": out["rounds_intra"],
                   "flat_total": out["rounds_flat"]},
        "cg_wall_us": {name: out[name]["wall_us"]
                       for name in ("dist_halo", "dist_hier",
                                    "dist_hier_bell",
                                    "dist_hier+block_jacobi")},
        "iters": {name: out[name]["iters"]
                  for name in ("dist_halo", "dist_hier", "dist_hier_bell",
                               "dist_hier+block_jacobi")},
        "agreement": {"max_rel_vs_halo": out["max_rel_vs_halo"],
                      "pass_1e-5": bool(out["max_rel_vs_halo"] < 1e-5)},
        "raw": out,
    })
    rows.append(row(
        "dist_hier_rounds", out["rounds_inter"],
        f"inter={out['rounds_inter']};intra={out['rounds_intra']};"
        f"flat_total={out['rounds_flat']};"
        f"inter_lt_flat={int(out['rounds_inter'] < out['rounds_flat'])}"))
    for name in ("dist_halo", "dist_hier", "dist_hier_bell",
                 "dist_hier+block_jacobi"):
        r = out[name]
        rows.append(row(f"cg_hier__{name.replace('+', '_')}", r["wall_us"],
                        f"iters={r['iters']};spmv_us={r['spmv_us']:.0f}"))
    rows.append(row("cg_hier__max_rel_vs_halo",
                    out["max_rel_vs_halo"] * 1e6,   # in 1e-6 units
                    f"agree_1e-5={int(out['max_rel_vs_halo'] < 1e-5)}"))


def _bench_build_plan(rows: list[str]) -> None:
    g = grid((256, 256))
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    part = np.random.default_rng(0).integers(0, 8, g.n)
    build_plan(indptr, indices, data, part, 8)          # warm
    build_plan_reference(indptr, indices, data, part, 8)
    t_vec = min(_t(build_plan, indptr, indices, data, part) for _ in range(5))
    t_ref = min(_t(build_plan_reference, indptr, indices, data, part)
                for _ in range(3))
    rows.append(row("build_plan_vectorized", t_vec * 1e6,
                    "grid256x256;k=8;random_part"))
    rows.append(row("build_plan_seed_reference", t_ref * 1e6,
                    f"speedup={t_ref / t_vec:.1f}x"))


def _t(fn, *args):
    t0 = time.perf_counter()
    fn(*args, 8)
    return time.perf_counter() - t0


def _bench_operator_backends(rows: list[str]) -> None:
    proc = subprocess.run([sys.executable, "-c", DIST_SCRIPT],
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        rows.append(row("cg_operator_backends__ERROR", 0,
                        proc.stderr[-200:].replace(",", ";")))
        return
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for name in ("coo", "coo+jacobi", "bell", "dist_halo",
                 "dist_halo+jacobi", "dist_halo_seq", "dist_bell",
                 "dist_allgather"):
        r = out[name]
        rows.append(row(f"cg_operator__{name.replace('+', '_')}",
                        r["wall_us"],
                        f"iters={r['iters']};res={r['res']:.2e}"))
    rows.append(row("cg_operator__max_pairwise_rel",
                    out["max_pairwise_rel"] * 1e6,   # in 1e-6 units
                    f"agree_1e-5={int(out['max_pairwise_rel'] < 1e-5)}"))
    rows.append(row("dist_spmv_halo_overlapped", out["dist_halo_spmv_us"],
                    "grid64x32;k=8;stripes"))
    rows.append(row("dist_spmv_halo_sequential",
                    out["dist_halo_seq_spmv_us"],
                    f"overlap_speedup="
                    f"{out['dist_halo_seq_spmv_us'] / out['dist_halo_spmv_us']:.2f}x"))
    rows.append(row("dist_spmv_allgather", out["dist_allgather_spmv_us"],
                    "grid64x32;k=8;stripes"))


def run() -> list[str]:
    rows = []
    _bench_build_plan(rows)
    _bench_operator_backends(rows)
    _bench_hier(rows)
    _bench_pod(rows)
    _bench_tree(rows)
    g = rdg(30000, seed=4)
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    rows_a, cols_a, vals_a = (jnp.asarray(a) for a in
                              csr_to_padded_coo(indptr, indices, data))
    b = jnp.asarray(np.random.default_rng(0).normal(size=g.n), jnp.float32)

    # real single-device SpMV + CG cost
    y = spmv_coo(rows_a, cols_a, vals_a, b)
    y.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        y = spmv_coo(rows_a, cols_a, vals_a, b)
    y.block_until_ready()
    spmv_us = (time.perf_counter() - t0) / 20 * 1e6
    res = cg_solve(lambda x: spmv_coo(rows_a, cols_a, vals_a, x), b,
                   tol=1e-6, max_iters=300)
    res.x.block_until_ready()
    t0 = time.perf_counter()
    res = cg_solve(lambda x: spmv_coo(rows_a, cols_a, vals_a, x), b,
                   tol=1e-6, max_iters=300)
    res.x.block_until_ready()
    cg_total = (time.perf_counter() - t0) * 1e6
    iters = max(int(res.iters), 1)
    rows.append(row("cg_real_per_iter", cg_total / iters,
                    f"iters={iters};spmv_us={spmv_us:.0f}"))

    # modeled heterogeneous per-iteration time (paper's TOPO3 simulation)
    c_row = spmv_us / g.n                     # measured per-row cost, us
    alpha = 4 * c_row                         # per-halo-word exchange cost
    topo = scale_to_load(
        Topology.topo3(nodes=4, cores_per_node=6, fast_nodes=1), g.n)
    tw = target_block_sizes(g.n, topo)
    for m in ("sfc", "rcb", "geoKM", "geoRef"):
        part, _ = partition(g, topo, m, tw=tw)
        sizes = block_sizes_of(part, topo.k)
        t_comp = np.max(sizes / topo.speeds) * c_row
        t_comm = alpha * max_comm_volume(g, part, topo.k)
        rows.append(row(f"cg_model_topo3__{m}", t_comp + t_comm,
                        f"comp={t_comp:.0f};comm={t_comm:.0f}"))
    # uniform blocks (heterogeneity-oblivious) baseline: same model
    uni = np.round(np.full(topo.k, g.n / topo.k)).astype(int)
    part_u, _ = partition(g, topo, "geoKM",
                          tw=np.full(topo.k, g.n / topo.k))
    sizes = block_sizes_of(part_u, topo.k)
    t_comp = np.max(sizes / topo.speeds) * c_row
    t_comm = alpha * max_comm_volume(g, part_u, topo.k)
    rows.append(row("cg_model_topo3__uniform_oblivious", t_comp + t_comm,
                    f"comp={t_comp:.0f};comm={t_comm:.0f}"))
    return rows


def main() -> None:
    """``python -m benchmarks.bench_cg --hier`` (``make bench-hier``):
    only the multi-pod schedule section; ``--pod-aware``
    (``make bench-pod``): only the pod-aware vs pod-oblivious partition
    comparison; ``--tree`` (``make bench-tree``): the depth-3 (2, 2, 2)
    per-level round/volume split.  All on forced host devices."""
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--hier", action="store_true",
                    help="run only the multi-pod (dist_hier) benchmark")
    ap.add_argument("--pod-aware", action="store_true",
                    help="run only the pod-aware vs pod-oblivious "
                         "partition comparison")
    ap.add_argument("--tree", action="store_true",
                    help="run only the depth-3 tree schedule benchmark "
                         "(per-level round split on the (2,2,2) mesh)")
    ap.add_argument("--objective", choices=("cut", "bottleneck"),
                    default=None,
                    help="run only the refinement-objective comparison "
                         "(cut vs bottleneck partitions of the padded "
                         "tree runtime); the value picks the headline "
                         "row, both objectives always run")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    rows: list[str] = []
    if args.hier:
        _bench_hier(rows)
    elif args.pod_aware:
        _bench_pod(rows)
    elif args.tree:
        _bench_tree(rows)
    elif args.objective is not None:
        _bench_bottleneck(rows)
    else:
        rows = run()
    for r in rows:
        print(r, flush=True)


if __name__ == "__main__":
    main()
