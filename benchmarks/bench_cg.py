"""Fig. 5 analogue: CG time per iteration under different partitions
(TOPO3-style heterogeneity).

Two measurements per partitioner:
  * real: measured single-process SpMV+CG microseconds (CPU; homogeneous);
  * modeled heterogeneous step time, the paper's TOPO3 simulation —
        T_iter = max_i(|b_i| * c_nnz / speed_i) + alpha * maxCommVolume
    with c_nnz the measured per-row SpMV cost and alpha the per-word
    exchange cost (derived from the halo plan, not guessed).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import Topology, partition, scale_to_load, \
    target_block_sizes
from repro.core.metrics import block_sizes_of, max_comm_volume
from repro.sparse.cg import cg_solve
from repro.sparse.generators import rdg
from repro.sparse.graph import laplacian_csr
from repro.sparse.spmv import csr_to_padded_coo, spmv_coo

from .common import row


def run() -> list[str]:
    rows = []
    g = rdg(30000, seed=4)
    indptr, indices, data = laplacian_csr(g, shift=1e-2)
    rows_a, cols_a, vals_a = (jnp.asarray(a) for a in
                              csr_to_padded_coo(indptr, indices, data))
    b = jnp.asarray(np.random.default_rng(0).normal(size=g.n), jnp.float32)

    # real single-device SpMV + CG cost
    y = spmv_coo(rows_a, cols_a, vals_a, b)
    y.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        y = spmv_coo(rows_a, cols_a, vals_a, b)
    y.block_until_ready()
    spmv_us = (time.perf_counter() - t0) / 20 * 1e6
    res = cg_solve(lambda x: spmv_coo(rows_a, cols_a, vals_a, x), b,
                   tol=1e-6, max_iters=300)
    res.x.block_until_ready()
    t0 = time.perf_counter()
    res = cg_solve(lambda x: spmv_coo(rows_a, cols_a, vals_a, x), b,
                   tol=1e-6, max_iters=300)
    res.x.block_until_ready()
    cg_total = (time.perf_counter() - t0) * 1e6
    iters = max(int(res.iters), 1)
    rows.append(row("cg_real_per_iter", cg_total / iters,
                    f"iters={iters};spmv_us={spmv_us:.0f}"))

    # modeled heterogeneous per-iteration time (paper's TOPO3 simulation)
    c_row = spmv_us / g.n                     # measured per-row cost, us
    alpha = 4 * c_row                         # per-halo-word exchange cost
    topo = scale_to_load(
        Topology.topo3(nodes=4, cores_per_node=6, fast_nodes=1), g.n)
    tw = target_block_sizes(g.n, topo)
    for m in ("sfc", "rcb", "geoKM", "geoRef"):
        part, _ = partition(g, topo, m, tw=tw)
        sizes = block_sizes_of(part, topo.k)
        t_comp = np.max(sizes / topo.speeds) * c_row
        t_comm = alpha * max_comm_volume(g, part, topo.k)
        rows.append(row(f"cg_model_topo3__{m}", t_comp + t_comm,
                        f"comp={t_comp:.0f};comm={t_comm:.0f}"))
    # uniform blocks (heterogeneity-oblivious) baseline: same model
    uni = np.round(np.full(topo.k, g.n / topo.k)).astype(int)
    part_u, _ = partition(g, topo, "geoKM",
                          tw=np.full(topo.k, g.n / topo.k))
    sizes = block_sizes_of(part_u, topo.k)
    t_comp = np.max(sizes / topo.speeds) * c_row
    t_comm = alpha * max_comm_volume(g, part_u, topo.k)
    rows.append(row("cg_model_topo3__uniform_oblivious", t_comp + t_comm,
                    f"comp={t_comp:.0f};comm={t_comm:.0f}"))
    return rows
