"""Fig. 3/4 analogue: quality and partition time vs number of PUs k
(TOPO2 heterogeneity, rgg graphs)."""
from __future__ import annotations

import time

from repro.core import Topology, partition, scale_to_load, \
    target_block_sizes
from repro.core.metrics import edge_cut, max_comm_volume
from repro.sparse.generators import rgg

from .common import row


def run() -> list[str]:
    rows = []
    g = rgg(30000, dim=2, seed=3)
    for k in (24, 48, 96):
        topo = scale_to_load(Topology.topo2(k, 1 / 6, 16.0, 13.8), g.n)
        tw = target_block_sizes(g.n, topo)
        for m in ("sfc", "geoKM", "geoRef"):
            t0 = time.perf_counter()
            part, _ = partition(g, topo, m, tw=tw)
            dt = time.perf_counter() - t0
            rows.append(row(f"scaling_b{k}__{m}", dt * 1e6,
                            f"cut={edge_cut(g, part):.0f};"
                            f"maxCV={max_comm_volume(g, part, k)}"))
    return rows
