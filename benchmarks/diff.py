"""Benchmark regression gate (``make bench-diff``).

Diffs the *working tree* ``benchmarks/baselines/BENCH_*.json`` against
the *committed* versions (``git show HEAD:...``).  The workflow is: run
the benches (they overwrite the baselines in place), then run this gate
before committing — it classifies every numeric leaf by its key path:

  * **fail** — machine-independent structure: modeled objectives
    (``*objective*``, ``*makespan*``, modeled ``price``) and schedule
    round counts (``rounds*``).  A >20% increase fails the gate; these
    numbers are deterministic per (graph, seed), so a regression is a
    real quality loss, not noise.
  * **plan** — planning-path wall time (``plan_build*``, ``patch*`` /
    ``fresh_build*`` in the delta bench): fails only past a much looser
    threshold (2x), so a real planning-path blow-up gates while ordinary
    host jitter does not.
  * **warn** — wall-clock (``*_us``, ``*_s``, ``wall*``, ``latency*``,
    ``*time*``): printed but never failing, since host timings drift
    with the machine.
  * everything else (agreement flags, shas, sizes) is ignored.

Exit status: number of failing regressions (0 = gate passes).  A
baseline file with no committed counterpart is reported as new and
skipped; a committed file deleted from the working tree fails.
"""
from __future__ import annotations

import json
import re
import subprocess
import sys

from .common import BASELINES

# >20% increase on a fail-class leaf fails the gate
THRESHOLD = 0.20
# planning-path wall time is machine-timed, so it only fails past a much
# looser bar: a doubling is a real planning regression, not host jitter
PLAN_THRESHOLD = 1.0

_FAIL_RE = re.compile(r"objective|makespan|rounds|(^|\.)price($|\.)")
_PLAN_RE = re.compile(r"plan_build|patch_s|fresh_build")
_WARN_RE = re.compile(r"_us($|\.)|_s($|\.)|wall|latency|time")
# measurement noise / bookkeeping that must never gate
_SKIP_RE = re.compile(r"agreement|max_rel|error|fingerprint|sha|raw\.")


def _leaves(node, path=""):
    """Yield (dotted.path, value) for every numeric scalar leaf."""
    if isinstance(node, bool):
        return
    if isinstance(node, (int, float)):
        yield path, float(node)
    elif isinstance(node, dict):
        for k in sorted(node):
            yield from _leaves(node[k], f"{path}.{k}" if path else str(k))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from _leaves(v, f"{path}[{i}]")


def _committed(relpath: str) -> dict | None:
    """The HEAD version of a repo-relative file, or None if untracked."""
    proc = subprocess.run(["git", "show", f"HEAD:{relpath}"],
                          capture_output=True, text=True,
                          cwd=BASELINES.parent.parent)
    if proc.returncode != 0:
        return None
    return json.loads(proc.stdout)


def diff_payloads(old: dict, new: dict, threshold: float = THRESHOLD,
                  plan_threshold: float = PLAN_THRESHOLD) -> tuple[list,
                                                                   list]:
    """(failures, warnings): [(path, old, new, rel_increase), ...].

    Only *increases* regress — objectives and rounds are all
    lower-is-better, and so are the warn-class latencies.  Plan-class
    leaves (planning-path wall time) fail past ``plan_threshold`` and
    warn between ``threshold`` and that.
    """
    old_leaves = dict(_leaves(old))
    failures, warnings = [], []
    for path, val in _leaves(new):
        low = path.lower()
        if _SKIP_RE.search(low):
            continue
        prev = old_leaves.get(path)
        if prev is None:
            continue                      # new metric: no baseline yet
        rel = (val - prev) / max(abs(prev), 1e-12)
        if rel <= threshold:
            continue
        if _PLAN_RE.search(low):
            (failures if rel > plan_threshold
             else warnings).append((path, prev, val, rel))
        elif _FAIL_RE.search(low):
            failures.append((path, prev, val, rel))
        elif _WARN_RE.search(low):
            warnings.append((path, prev, val, rel))
    return failures, warnings


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    n_fail = 0
    files = sorted(BASELINES.glob("BENCH_*.json"))
    if not files:
        print("bench-diff: no baselines in", BASELINES)
        return 0
    for path in files:
        rel = path.relative_to(BASELINES.parent.parent).as_posix()
        new = json.loads(path.read_text())
        old = _committed(rel)
        if old is None:
            print(f"  NEW   {path.name} (no committed baseline; skipped)")
            continue
        failures, warnings = diff_payloads(old, new)
        status = "FAIL" if failures else ("warn" if warnings else "ok")
        print(f"  {status:5s} {path.name}")
        for p, prev, val, r in failures:
            print(f"        FAIL {p}: {prev:g} -> {val:g} (+{r:.0%})")
        for p, prev, val, r in warnings:
            print(f"        warn {p}: {prev:g} -> {val:g} (+{r:.0%})")
        n_fail += len(failures)
    # a committed baseline deleted from the working tree is a regression
    ls = subprocess.run(
        ["git", "ls-tree", "--name-only", "HEAD", "benchmarks/baselines/"],
        capture_output=True, text=True, cwd=BASELINES.parent.parent)
    for line in ls.stdout.splitlines():
        name = line.rsplit("/", 1)[-1]
        if (name.startswith("BENCH_") and name.endswith(".json")
                and not (BASELINES / name).exists()):
            print(f"  FAIL  {name} committed baseline missing from tree")
            n_fail += 1
    if n_fail:
        print(f"bench-diff: {n_fail} regression(s) over "
              f"{THRESHOLD:.0%} threshold")
    else:
        print("bench-diff: gate passes")
    return n_fail


if __name__ == "__main__":
    sys.exit(main())
