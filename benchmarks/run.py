"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.

  python -m benchmarks.run [--only block_sizes,partitioners,...]
"""
from __future__ import annotations

import argparse
import sys
import time

BENCHES = ("block_sizes", "hierarchical", "partitioners", "scaling",
           "cg", "kernels")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help=f"comma list from {BENCHES}")
    args = ap.parse_args()
    want = args.only.split(",") if args.only else BENCHES

    print("name,us_per_call,derived")
    failures = 0
    for name in want:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        try:
            for r in mod.run():
                print(r, flush=True)
        except Exception as e:     # keep the harness going
            failures += 1
            print(f"bench_{name}__ERROR,0,{type(e).__name__}:{e}",
                  flush=True)
        print(f"# bench_{name} done in {time.time() - t0:.1f}s",
              file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
