"""Algorithm 1 runtime scaling — the paper's O(k log k) claim (Thm 1).

Emits one row per k plus the Table III fast/slow target ratios.
"""
from __future__ import annotations

import numpy as np

from repro.core.block_sizes import target_block_sizes
from repro.core.topology import TABLE_III_FAST_SPECS, Topology, scale_to_load

from .common import row, time_us


def run() -> list[str]:
    rows = []
    n = 1e9
    for k in (96, 1536, 24576, 393216):
        topo = scale_to_load(Topology.topo1(k, 1 / 12, 16.0, 13.8), n)
        us = time_us(lambda: target_block_sizes(n, topo), reps=3)
        rows.append(row(f"alg1_k{k}", us, f"n={n:.0e}"))
    # Table III reproduction: tw(fast)/tw(slow) per experiment step
    for i, (spd, mem) in enumerate(TABLE_III_FAST_SPECS, start=1):
        for frac, tag in ((1 / 12, "f8"), (1 / 6, "f16")):
            topo = scale_to_load(Topology.topo1(96, frac, spd, mem), n)
            tw = target_block_sizes(n, topo)
            rows.append(row(f"table3_exp{i}_{tag}", 0.0,
                            f"tw_ratio={tw[0] / tw[-1]:.2f}"))
    return rows
