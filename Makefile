# Repo tooling.  `make test` is the tier-1 gate from ROADMAP.md; run it
# before every commit so "seed tests failing" can never silently regress.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test tier1 deps lint verify-plans trace-audit bench-cg bench \
        bench-hier bench-pod bench-tree bench-serve bench-bottleneck \
        bench-delta bench-diff

deps:
	$(PYTHON) -m pip install -r requirements-dev.txt

# Full suite, no early exit (collection must be clean even without dev deps)
test:
	$(PYTHON) -m pytest -q

# The ROADMAP tier-1 verify command (fail fast)
tier1:
	$(PYTHON) -m pytest -x -q

# AST lint (REPRO001-004, see src/repro/analysis/lint.py): nonzero exit
# with rule ID + file:line on any finding.  Pure ast — no JAX needed.
lint:
	$(PYTHON) -m repro.analysis lint src

# Build flat + tree plans over the generator grid and run the structural
# verifier + mesh/axis checker on each (exit = number of failing plans)
verify-plans:
	$(PYTHON) -m repro.analysis verify

# Jaxpr trace audit (TRACE001-005, see src/repro/analysis/trace.py):
# stage every solver backend's matvec + fused CG on an abstract mesh —
# no devices — and cross-check collectives/dtypes against the plan while
# counting the static per-iteration cost.  Writes the JSON report CI
# uploads as an artifact; nonzero exit on any diagnostic.
trace-audit:
	$(PYTHON) -m repro.analysis trace --fanouts 2,2 --fanouts 2,2,2 \
	    --out trace_audit.json

bench-cg:
	$(PYTHON) -m benchmarks.run --only cg

# Multi-pod (pods=2, k=8) hierarchical schedule vs the flat plan, on
# forced host devices (the subprocess sets the XLA flag itself)
bench-hier:
	$(PYTHON) -m benchmarks.bench_cg --hier

# Pod-aware vs pod-oblivious partitions of the same (pods=2, k=8) mesh:
# inter-pod comm volume / rounds and dist_hier CG time (ISSUE 4)
bench-pod:
	$(PYTHON) -m benchmarks.bench_cg --pod-aware

# Depth-3 (2,2,2) tree schedule: per-level round/comm-volume split and
# tree-aware vs oblivious partitions of the same mesh (ISSUE 5)
bench-tree:
	$(PYTHON) -m benchmarks.bench_cg --tree

# Solver serving: cold vs cache-hit latency, solves/sec, batched-vs-
# sequential agreement across coo/dist_halo/dist_hier (ISSUE 7); writes
# the tracked benchmarks/baselines/BENCH_serve.json
bench-serve:
	$(PYTHON) -m benchmarks.bench_serve

# Bottleneck (makespan) vs cut refinement on the padded tree runtime:
# B / S_lvl / round structure and measured per-CG-iteration minima
# (ISSUE 9); writes the tracked benchmarks/baselines/BENCH_bottleneck.json
bench-bottleneck:
	$(PYTHON) -m benchmarks.bench_cg --objective bottleneck

# Incremental delta replanning: O(delta) plan patch vs fresh
# build_plan_tree at <=1% edge churn (256x256 grid, k=8, depth-2 and
# depth-3 meshes); asserts the value-delta patch is >= 10x faster and
# bit-equal, writes the tracked benchmarks/baselines/BENCH_delta.json
# (ISSUE 10).  Host-side NumPy only — no devices.
bench-delta:
	$(PYTHON) -m benchmarks.bench_delta

# Regression gate: diff fresh BENCH_*.json in the working tree against
# the committed benchmarks/baselines/ (HEAD); >20% regressions on
# modeled objectives / round counts fail, latency drift only warns
bench-diff:
	$(PYTHON) -m benchmarks.diff

bench:
	$(PYTHON) -m benchmarks.run
