"""repro: heterogeneous load distribution (LDHT) framework in JAX."""
__version__ = "1.0.0"
