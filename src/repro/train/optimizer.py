"""In-house AdamW + gradient clipping (no optax dependency).

Optimizer state mirrors the param tree (m, v in f32) so jit in_shardings can
reuse the param PartitionSpecs verbatim — FSDP-sharded optimizer states for
free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 \
        * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init_opt_state(params) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"]
    lr = lr_schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step + 1}, \
        {"grad_norm": gnorm, "lr": lr}
