"""Cross-pod gradient compression (beyond-paper distributed-optimization).

Multi-pod data parallelism reduces gradients across pods over the
(slower) inter-pod links.  XLA inserts that all-reduce implicitly at
bf16/f32 width.  Here the pod axis is made *manual* (shard_map over
'pod' only; 'data'/'model' stay auto-partitioned), so the cross-pod
reduction can be quantized:

  int8 symmetric quantization (per-tensor scale = pmax|g|/127)
  -> int8 all-gather over 'pod' (1 byte/elem on the wire vs 2 for bf16,
     4 for f32) -> local int32 sum -> dequantize.

For pod counts <= 128 the int32 accumulation is exact given int8 inputs,
so the only loss is the quantization itself (~0.4% RMS on typical grad
distributions; the per-tensor pmax scale makes it unbiased in sign).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..compat import SUPPORTS_PARTIAL_MANUAL, shard_map


def compressed_psum(tree, axis: str, bits: int = 8):
    """Quantized sum over a (manual) mesh axis.  bits=8 only for now."""
    assert bits == 8

    def one(g):
        g32 = g.astype(jnp.float32)
        amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis)
        scale = amax / 127.0 + 1e-30
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        # int8 all-gather: 1 byte/elem on the wire; exact int32 local sum
        allq = jax.lax.all_gather(q, axis)              # (npods, ...)
        s = jnp.sum(allq.astype(jnp.int32), axis=0)
        return (s.astype(jnp.float32) * scale).astype(g.dtype)

    return jax.tree.map(one, tree)


def podwise_value_and_grad(loss_fn, mesh, batch_specs, *,
                           compression: str = "int8"):
    """Wrap ``value_and_grad(loss_fn)`` so the cross-pod gradient reduction
    goes through ``compressed_psum`` instead of XLA's implicit all-reduce.

    loss_fn: (params, batch) -> scalar loss.
    batch_specs: dict of PartitionSpecs for the batch *restricted to the
    pod axis* (other axes are auto).  Params are replicated across pods.
    """
    from ..compat import P

    def pod_spec(spec):
        # keep only the 'pod' component of each dim spec
        dims = []
        for d in spec:
            if d == "pod" or (isinstance(d, tuple) and "pod" in d):
                dims.append("pod")
            else:
                dims.append(None)
        return P(*dims)

    b_specs = {k: pod_spec(s) for k, s in batch_specs.items()}

    def local(params, batch):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        g = compressed_psum(g, "pod")
        loss = jax.lax.pmean(loss, "pod")
        return loss, g

    # NOTE (§Perf, measured on jax 0.8.2): in_specs on a partial-auto
    # shard_map can only constrain the manual axis; the measured dry-run
    # shows the auto ('data'/'model') shardings of params/batch do NOT
    # survive the boundary (inner-axis all-reduce x5 on qwen1.5 multi-pod)
    # — so the int8 pod reduction is numerically validated (tests) but
    # kept OFF by default until the boundary preserves auto shardings
    # (jax.sharding.Infer rejects Auto-typed meshes in this version).
    #
    # Compat: where partial-manual is unsupported (see compat), the program
    # is fully manual over every mesh axis — the pod-axis wire traffic
    # (int8 all-gather) is identical, the data/model axes just recompute
    # redundantly inside each pod.
    kw = {"axis_names": {"pod"}} if SUPPORTS_PARTIAL_MANUAL else {}
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(), b_specs),
        out_specs=(P(), P()),
        check_rep=False, **kw)
