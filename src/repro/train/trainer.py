"""Fault-tolerant training loop.

Responsibilities:
  * jit'd train_step over an optional mesh (single-device on this container;
    in_shardings come from the model's spec tree on a real mesh);
  * checkpoint every ``ckpt_every`` steps (atomic, GC'd), auto-resume from
    the latest checkpoint on restart — crash/restart is the fault-tolerance
    primitive (node failure => job reschedules => resume);
  * elastic re-balancing: on a topology change (lost/new PUs) the data
    pipeline shares are recomputed with Algorithm 1 (core.block_sizes) —
    the LDHT technique applied to heterogeneous/degraded data parallelism;
  * straggler mitigation hook: per-step wall times are tracked, and a
    pluggable callback can re-run Algorithm 1 with updated speeds (the
    paper's c_s values measured online instead of given).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.block_sizes import hetero_batch_split, target_block_sizes
from ..core.topology import Topology
from ..data.pipeline import DataConfig, SyntheticLM
from ..models import encdec, transformer
from ..models.config import ModelConfig
from ..models.steps import loss_fn, make_train_step
from .checkpoint import (latest_checkpoint, restore_checkpoint,
                         save_checkpoint)
from .optimizer import AdamWConfig, init_opt_state


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    lr: float = 3e-4
    fail_at_step: int = -1      # fault injection for tests/demos


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 topo: Topology | None = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.topo = topo or Topology.homogeneous(1, memory=1e9)
        mod = encdec if cfg.family == "audio" else transformer
        params, _ = mod.init_model(jax.random.PRNGKey(tcfg.seed), cfg)
        self.state = {"params": params, "opt": init_opt_state(params)}
        opt = AdamWConfig(lr=tcfg.lr, total_steps=tcfg.steps,
                          warmup_steps=max(tcfg.steps // 20, 5))
        self.train_step = jax.jit(make_train_step(cfg, opt),
                                  donate_argnums=(0,))
        self.data = SyntheticLM(DataConfig(
            vocab=cfg.vocab, seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch, seed=tcfg.seed))
        self.step = 0
        self.step_times: list[float] = []
        # Algorithm 1: per-PU batch shares (heterogeneous data parallelism)
        self.shares = hetero_batch_split(tcfg.global_batch, self._scaled())

    def _scaled(self) -> Topology:
        """Topology with memory rescaled to the batch 'load'."""
        from ..core.topology import scale_to_load
        return scale_to_load(self.topo, self.tcfg.global_batch, 1.5)

    # -- fault tolerance -----------------------------------------------------
    def maybe_resume(self) -> bool:
        path = latest_checkpoint(self.tcfg.ckpt_dir)
        if path is None:
            return False
        self.state, manifest = restore_checkpoint(path, self.state)
        self.step = int(manifest["step"])
        return True

    def rebalance(self, surviving: Topology):
        """Elastic scaling: recompute per-PU shares after a topology change.
        O(k log k) — negligible next to a single step."""
        self.topo = surviving
        self.shares = hetero_batch_split(self.tcfg.global_batch,
                                         self._scaled())
        return self.shares

    def measured_speeds_rebalance(self):
        """Straggler mitigation: use observed step times as 1/speed."""
        if not self.step_times:
            return self.shares
        # single-process container: speeds are uniform; the hook exists for
        # multi-host deployments where per-host times differ.
        return self.shares

    # -- loop ------------------------------------------------------------------
    def _batch(self, step: int):
        b = self.data.batch(step)
        if self.cfg.family == "vlm":
            rng = np.random.default_rng(step)
            b["img_embeds"] = rng.normal(scale=0.02, size=(
                self.tcfg.global_batch, self.cfg.n_img_tokens,
                self.cfg.d_model)).astype(np.float32)
        if self.cfg.family == "audio":
            rng = np.random.default_rng(step)
            b["frames"] = rng.normal(scale=0.02, size=(
                self.tcfg.global_batch, self.cfg.n_frames,
                self.cfg.d_model)).astype(np.float32)
        return {k: jnp.asarray(v) for k, v in b.items()}

    def run(self, on_metrics: Callable[[int, dict], None] | None = None):
        losses = []
        while self.step < self.tcfg.steps:
            if self.step == self.tcfg.fail_at_step:
                raise RuntimeError(
                    f"injected fault at step {self.step}")  # demo/testing
            t0 = time.perf_counter()
            batch = self._batch(self.step)
            self.state, metrics = self.train_step(self.state, batch)
            self.step += 1
            loss = float(metrics["loss"])
            losses.append(loss)
            self.step_times.append(time.perf_counter() - t0)
            if on_metrics:
                on_metrics(self.step, metrics)
            if self.step % self.tcfg.log_every == 0:
                print(f"step {self.step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({self.step_times[-1]*1e3:.0f} ms)", flush=True)
            if self.step % self.tcfg.ckpt_every == 0 \
                    or self.step == self.tcfg.steps:
                save_checkpoint(self.tcfg.ckpt_dir, self.state, self.step,
                                extra={"arch": self.cfg.name},
                                keep=self.tcfg.keep)
        return losses
