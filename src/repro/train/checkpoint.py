"""Checkpoint save/restore for fault-tolerant training.

Design (single-host container, multi-host-shaped API):
  * the state pytree is flattened to path-keyed arrays and written as .npz
    plus a JSON manifest (step, config fingerprint, topology);
  * writes are atomic (tmp file + rename) so a crash mid-save never corrupts
    the latest checkpoint;
  * ``keep`` newest checkpoints are retained;
  * restore returns (state, step) and verifies the tree structure matches.

On a real cluster each host writes only its owned shards (jax
process-local addressable shards) — the save path takes arbitrary
``np.asarray``-ables, so plugging in per-shard gathers is a local change.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str | Path, state, step: int,
                    extra: dict | None = None, keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    final = ckpt_dir / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(state)
    np.savez(tmp / "state.npz", **flat)
    manifest = {"step": step, "time": time.time(),
                "keys": sorted(flat), **(extra or {})}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: Path, keep: int):
    ckpts = sorted(p for p in ckpt_dir.iterdir()
                   if re.fullmatch(r"step_\d{8}", p.name))
    for p in ckpts[:-keep]:
        shutil.rmtree(p)


def latest_checkpoint(ckpt_dir: str | Path) -> Path | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    ckpts = sorted(p for p in ckpt_dir.iterdir()
                   if re.fullmatch(r"step_\d{8}", p.name))
    return ckpts[-1] if ckpts else None


def restore_checkpoint(path: str | Path, state_like):
    """Restore into the structure of ``state_like`` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (state, manifest)."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "state.npz")
    leaves_paths = jax.tree_util.tree_flatten_with_path(state_like)[0]
    treedef = jax.tree_util.tree_structure(state_like)
    out = []
    for p, leaf in leaves_paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in p)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        want = getattr(leaf, "shape", None)
        if want is not None and tuple(arr.shape) != tuple(want):
            raise ValueError(f"{key}: checkpoint {arr.shape} != {want}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest
