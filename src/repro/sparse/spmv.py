"""Single-device SpMV and CG building blocks (pure JAX).

Formats:
  * padded-COO  — (rows, cols, vals) each (nnz_pad,); padding rows point at a
    scratch row.  segment_sum based; works for any sparsity.
  * block-ELL   — see kernels/spmv_bell.py (the Pallas TPU kernel).

All converters preserve the input dtype (a float64 CSR yields float64
padded-COO/diagonal arrays — the old hard-coded ``float32`` silently
downcast float64 systems); ``spmv_coo`` additionally carries a trailing
RHS-batch axis through natively (``x`` of shape ``(n, nb)`` yields
``(n, nb)``), which is the single-device half of the multi-RHS batched
CG path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def csr_to_padded_coo(indptr: np.ndarray, indices: np.ndarray,
                      data: np.ndarray, nnz_pad: int | None = None):
    """CSR -> padded COO (rows, cols, vals); padded entries have val 0.
    ``vals`` keeps the dtype of ``data`` (float dtypes pass through;
    anything non-float is promoted to float32)."""
    n = len(indptr) - 1
    nnz = len(indices)
    nnz_pad = nnz_pad or nnz
    data = np.asarray(data)
    vdt = data.dtype if np.issubdtype(data.dtype, np.floating) \
        else np.float32
    rows = np.repeat(np.arange(n, dtype=np.int32), np.diff(indptr))
    out_r = np.zeros(nnz_pad, dtype=np.int32)
    out_c = np.zeros(nnz_pad, dtype=np.int32)
    out_v = np.zeros(nnz_pad, dtype=vdt)
    out_r[:nnz], out_c[:nnz], out_v[:nnz] = rows, indices, data
    return out_r, out_c, out_v


@functools.partial(jax.jit, static_argnames=("n",))
def spmv_coo(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
             x: jnp.ndarray, n: int | None = None) -> jnp.ndarray:
    """y = A @ x for padded COO.  ``n`` (the output size) must be static:
    it shapes the segment-sum target, so it is a ``static_argnames`` entry
    rather than a traced operand.  ``x`` may carry a trailing RHS-batch
    axis (``(n, nb)``); the scatter-add batches natively."""
    n = n if n is not None else x.shape[0]
    contrib = vals.reshape(vals.shape + (1,) * (x.ndim - 1)) * x[cols]
    return jnp.zeros((n,) + x.shape[1:], vals.dtype).at[rows].add(contrib)


def csr_diagonal(indptr: np.ndarray, indices: np.ndarray,
                 data: np.ndarray) -> np.ndarray:
    """(n,) diagonal of a CSR matrix (duplicates summed) — feeds the
    Jacobi preconditioner of ``cg.cg_solve``.  Keeps the dtype of
    ``data``.  Vectorized NumPy."""
    n = len(indptr) - 1
    data = np.asarray(data)
    vdt = data.dtype if np.issubdtype(data.dtype, np.floating) \
        else np.float32
    src = np.repeat(np.arange(n), np.diff(indptr))
    on_diag = src == np.asarray(indices)
    d = np.zeros(n, dtype=vdt)
    np.add.at(d, src[on_diag], data[on_diag])
    return d


def dense_from_coo(rows, cols, vals, n):
    a = np.zeros((n, n), dtype=np.float64)
    np.add.at(a, (rows, cols), vals)
    return a
