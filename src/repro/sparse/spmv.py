"""Single-device SpMV and CG building blocks (pure JAX).

Formats:
  * padded-COO  — (rows, cols, vals) each (nnz_pad,); padding rows point at a
    scratch row.  segment_sum based; works for any sparsity.
  * block-ELL   — see kernels/spmv_bell.py (the Pallas TPU kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def csr_to_padded_coo(indptr: np.ndarray, indices: np.ndarray,
                      data: np.ndarray, nnz_pad: int | None = None):
    """CSR -> padded COO (rows, cols, vals); padded entries have val 0."""
    n = len(indptr) - 1
    nnz = len(indices)
    nnz_pad = nnz_pad or nnz
    rows = np.repeat(np.arange(n, dtype=np.int32), np.diff(indptr))
    out_r = np.zeros(nnz_pad, dtype=np.int32)
    out_c = np.zeros(nnz_pad, dtype=np.int32)
    out_v = np.zeros(nnz_pad, dtype=np.float32)
    out_r[:nnz], out_c[:nnz], out_v[:nnz] = rows, indices, data
    return out_r, out_c, out_v


@functools.partial(jax.jit, static_argnames=("n",))
def spmv_coo(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
             x: jnp.ndarray, n: int | None = None) -> jnp.ndarray:
    """y = A @ x for padded COO.  ``n`` (the output size) must be static:
    it shapes the segment-sum target, so it is a ``static_argnames`` entry
    rather than a traced operand."""
    n = n if n is not None else x.shape[0]
    return jnp.zeros(n, vals.dtype).at[rows].add(vals * x[cols])


def csr_diagonal(indptr: np.ndarray, indices: np.ndarray,
                 data: np.ndarray) -> np.ndarray:
    """(n,) f32 diagonal of a CSR matrix (duplicates summed) — feeds the
    Jacobi preconditioner of ``cg.cg_solve``.  Vectorized NumPy."""
    n = len(indptr) - 1
    src = np.repeat(np.arange(n), np.diff(indptr))
    on_diag = src == np.asarray(indices)
    d = np.zeros(n, dtype=np.float32)
    np.add.at(d, src[on_diag], np.asarray(data)[on_diag])
    return d


def dense_from_coo(rows, cols, vals, n):
    a = np.zeros((n, n), dtype=np.float64)
    np.add.at(a, (rows, cols), vals)
    return a
