"""Incremental delta replanning — O(Δ) patching of tree plans.

Time-stepping simulations and evolving graphs change a small fraction of
matrix entries per step; paying a full :func:`build_plan_tree` (O(nnz)
extraction, per-level Misra-Gries coloring, packing) for every step makes
plan construction the dominant cost of a streaming workload.  This module
patches an existing plan instead:

* the partition (``part``/``order``/``perm``) is reused unchanged, so no
  data movement of the solver state is needed;
* local COO segments are re-extracted only for *affected blocks* (blocks
  that gained or lost entries) — an existing entry's halo level never
  changes (it is a function of the owner/receiver pair only), so
  untouched blocks keep their packed layout byte-for-byte;
* halo slot maps are patched by searchsorted insert/remove over the
  sorted (receiver, vertex) triple keys, with reference counts so a slot
  dies only when its *last* external entry does;
* :func:`repro.sparse.distributed._class_schedule` re-runs only on tree
  levels whose triple set changed — unchanged levels keep their send
  schedules and round permutations *by reference* (no host->device
  transfer).

The contract is bit-level: ``apply_edge_delta(plan, delta)`` must equal
``build_plan_tree`` on the merged CSR field-by-field (locked by the
deterministic sweeps in tests/test_replan.py, the hypothesis suite in
tests/test_replan_properties.py, and ``verify_plan`` under
``REPRO_VALIDATE``).  Everything here is host-side NumPy; the only device
work is uploading the arrays that actually changed.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _as_idx(a) -> np.ndarray:
    return np.ascontiguousarray(np.asarray(a, dtype=np.int64).ravel())


@dataclasses.dataclass(frozen=True)
class EdgeDelta:
    """A batch of CSR entry mutations against an n x n matrix.

    ``set_*`` entries are upserts: an existing (row, col) entry gets the
    new value, a missing one is inserted.  ``drop_*`` entries must exist.
    Entries are stored sorted by ``row * n + col``; duplicate keys within
    a batch, or a key both set and dropped, are rejected — a delta is a
    set of final states, not an event log.
    """
    n: int
    set_rows: np.ndarray
    set_cols: np.ndarray
    set_vals: np.ndarray
    drop_rows: np.ndarray
    drop_cols: np.ndarray

    def __init__(self, n, set_rows=(), set_cols=(), set_vals=(),
                 drop_rows=(), drop_cols=()):
        sr, sc = _as_idx(set_rows), _as_idx(set_cols)
        sv = np.ascontiguousarray(np.asarray(set_vals, dtype=np.float64)
                                  .ravel())
        dr, dc = _as_idx(drop_rows), _as_idx(drop_cols)
        if not (len(sr) == len(sc) == len(sv)):
            raise ValueError("set_rows/set_cols/set_vals length mismatch")
        if len(dr) != len(dc):
            raise ValueError("drop_rows/drop_cols length mismatch")
        for a in (sr, sc, dr, dc):
            if len(a) and (a.min() < 0 or a.max() >= n):
                raise ValueError("entry index out of range [0, n)")
        n = int(n)
        sk = sr * n + sc
        dk = dr * n + dc
        o = np.argsort(sk)
        sk, sr, sc, sv = sk[o], sr[o], sc[o], sv[o]
        o = np.argsort(dk)
        dk, dr, dc = dk[o], dr[o], dc[o]
        if len(sk) > 1 and (np.diff(sk) == 0).any():
            raise ValueError("duplicate (row, col) in set entries")
        if len(dk) > 1 and (np.diff(dk) == 0).any():
            raise ValueError("duplicate (row, col) in drop entries")
        if len(sk) and len(dk) and np.intersect1d(sk, dk).size:
            raise ValueError("(row, col) both set and dropped")
        object.__setattr__(self, "n", n)
        object.__setattr__(self, "set_rows", sr)
        object.__setattr__(self, "set_cols", sc)
        object.__setattr__(self, "set_vals", sv)
        object.__setattr__(self, "drop_rows", dr)
        object.__setattr__(self, "drop_cols", dc)

    @property
    def set_keys(self) -> np.ndarray:
        return self.set_rows * self.n + self.set_cols

    @property
    def drop_keys(self) -> np.ndarray:
        return self.drop_rows * self.n + self.drop_cols

    @property
    def size(self) -> int:
        return len(self.set_rows) + len(self.drop_rows)

    def __len__(self) -> int:
        return self.size

    @classmethod
    def diff(cls, indptr_a, indices_a, data_a,
             indptr_b, indices_b, data_b) -> "EdgeDelta":
        """The delta turning canonical CSR A into canonical CSR B."""
        n = len(indptr_a) - 1
        if len(indptr_b) - 1 != n:
            raise ValueError("CSR shapes differ")
        ka = _csr_keys(indptr_a, indices_a, n)
        kb = _csr_keys(indptr_b, indices_b, n)
        da, db = np.asarray(data_a), np.asarray(data_b)
        pa = np.searchsorted(ka, kb)
        in_a = np.zeros(len(kb), dtype=bool)
        if len(ka):
            hit = pa < len(ka)
            in_a[hit] = ka[np.minimum(pa[hit], len(ka) - 1)] == kb[hit]
        changed = in_a.copy()
        if in_a.any():
            changed[in_a] = da[pa[in_a]] != db[in_a]
        set_m = changed | ~in_a
        pb = np.searchsorted(kb, ka)
        in_b = np.zeros(len(ka), dtype=bool)
        if len(kb):
            hit = pb < len(kb)
            in_b[hit] = kb[np.minimum(pb[hit], len(kb) - 1)] == ka[hit]
        drop_m = ~in_b
        return cls(n, set_rows=kb[set_m] // n, set_cols=kb[set_m] % n,
                   set_vals=db[set_m],
                   drop_rows=ka[drop_m] // n, drop_cols=ka[drop_m] % n)


def _csr_keys(indptr, indices, n: int) -> np.ndarray:
    indptr = np.asarray(indptr)
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    return src * n + np.asarray(indices, dtype=np.int64)


@dataclasses.dataclass
class _Merge:
    """Result of merging an :class:`EdgeDelta` into a canonical CSR.

    Kept entries are moved with boolean-mask compress/expand (``keep`` on
    the old side, ``keep_new`` on the new side) — measurably faster than
    integer fancy indexing at production nnz.
    """
    structural: bool
    indptr2: np.ndarray
    indices2: np.ndarray
    data2: np.ndarray
    keys2: np.ndarray
    rw_pos: np.ndarray       # old-CSR positions of reweighted entries
    rw_vals: np.ndarray      # new values, already cast to data.dtype
    del_pos: np.ndarray      # old-CSR positions removed (sorted)
    ins_keys: np.ndarray     # inserted keys (sorted)
    ins_rows: np.ndarray
    ins_cols: np.ndarray
    keep: np.ndarray | None      # (nnz,) bool: old entries kept
    keep_new: np.ndarray | None  # (nnz2,) bool: new positions of kept
    new_pos_ins: np.ndarray   # inserted entries' positions in the new CSR


def _find_sorted(haystack: np.ndarray, needles: np.ndarray):
    """(positions, found-mask) of ``needles`` in sorted ``haystack``."""
    pos = np.searchsorted(haystack, needles)
    found = np.zeros(len(needles), dtype=bool)
    if len(haystack):
        hit = pos < len(haystack)
        found[hit] = haystack[np.minimum(pos[hit], len(haystack) - 1)] \
            == needles[hit]
    return pos, found


def _merge_csr(indptr, indices, data, keys, delta: EdgeDelta) -> _Merge:
    n = len(indptr) - 1
    nnz = len(keys)
    sk = delta.set_keys
    pos, found = _find_sorted(keys, sk)
    rw_pos = pos[found]
    rw_vals = delta.set_vals[found].astype(data.dtype)
    ins_m = ~found
    ins_keys = sk[ins_m]
    ins_rows = delta.set_rows[ins_m]
    ins_cols = delta.set_cols[ins_m]
    dpos, dfound = _find_sorted(keys, delta.drop_keys)
    if not dfound.all():
        bad = np.flatnonzero(~dfound)[0]
        raise KeyError(
            f"drop entry ({delta.drop_rows[bad]}, {delta.drop_cols[bad]}) "
            "not present in the matrix")
    structural = bool(len(ins_keys) or len(dpos))
    if not structural:
        data2 = data.copy()
        data2[rw_pos] = rw_vals
        return _Merge(False, indptr, indices, data2, keys,
                      rw_pos, rw_vals, dpos, ins_keys, ins_rows, ins_cols,
                      None, None, np.zeros(0, dtype=np.int64))

    keep = np.ones(nnz, dtype=bool)
    keep[dpos] = False
    key_kept = keys[keep]
    new_pos_ins = (np.searchsorted(key_kept, ins_keys)
                   + np.arange(len(ins_keys), dtype=np.int64))
    nnz2 = len(key_kept) + len(ins_keys)
    keep_new = np.ones(nnz2, dtype=bool)
    keep_new[new_pos_ins] = False
    indices2 = np.empty(nnz2, dtype=np.asarray(indices).dtype)
    indices2[keep_new] = np.asarray(indices)[keep]
    indices2[new_pos_ins] = ins_cols.astype(indices2.dtype)
    data2 = np.empty(nnz2, dtype=data.dtype)
    data2[keep_new] = data[keep]
    data2[new_pos_ins] = delta.set_vals[ins_m].astype(data.dtype)
    keys2 = np.empty(nnz2, dtype=np.int64)
    keys2[keep_new] = key_kept
    keys2[new_pos_ins] = ins_keys
    if len(rw_pos):
        data2[np.searchsorted(keys2, keys[rw_pos])] = rw_vals
    deg2 = (np.diff(indptr)
            - np.bincount(keys[dpos] // n, minlength=n)
            + np.bincount(ins_rows, minlength=n))
    indptr2 = np.zeros(n + 1, dtype=np.asarray(indptr).dtype)
    indptr2[1:] = np.cumsum(deg2)
    return _Merge(True, indptr2, indices2, data2, keys2,
                  rw_pos, rw_vals, dpos, ins_keys, ins_rows, ins_cols,
                  keep, keep_new, new_pos_ins)


def apply_delta_csr(indptr, indices, data, delta: EdgeDelta):
    """Apply a delta to a canonical CSR; returns (indptr, indices, data).

    Standalone (no plan needed) — this is what the serving layer uses to
    form the mutated matrix whose fingerprint keys the patched operator.
    """
    n = len(indptr) - 1
    if delta.n != n:
        raise ValueError(f"delta is for n={delta.n}, matrix has n={n}")
    keys = _csr_keys(indptr, indices, n)
    m = _merge_csr(np.asarray(indptr), np.asarray(indices),
                   np.asarray(data), keys, delta)
    return m.indptr2, m.indices2, m.data2


@dataclasses.dataclass
class ReplanCache:
    """Host-side intermediates of one :func:`build_plan_tree` run.

    Everything :func:`apply_edge_delta` needs to rebuild *only* what a
    delta touches.  Arrays are the builder's own (shared, not copied);
    patched caches share unchanged arrays with their predecessor.
    """
    # canonical CSR of the planned matrix + its sorted entry keys
    n: int
    k: int
    B: int
    indptr: np.ndarray
    indices: np.ndarray
    data: np.ndarray
    keys: np.ndarray            # row * n + col, strictly increasing
    # layout (partition is reused across patches)
    part: np.ndarray            # relabeled, tree-major
    order: np.ndarray
    rank_in_block: np.ndarray
    sizes: np.ndarray
    vstarts: np.ndarray         # (k+1,) vertex range of each block in order
    fanouts: tuple
    suffix: tuple
    row_mask: np.ndarray
    # per-CSR-entry packing coordinates
    own: np.ndarray             # owner block (== plan._pack_blk)
    pos_edge: np.ndarray        # packed position (== plan._pack_pos)
    # halo triples in canonical (pair, vertex) order
    t_pair: np.ndarray          # recv * k + own
    t_v: np.ndarray
    t_lvl: np.ndarray
    rel_slot: np.ndarray        # slot within the level (color * S + pos)
    cnt: np.ndarray             # external entries referencing each triple
    rv_keys: np.ndarray         # sorted recv * n + v
    rv_trip: np.ndarray         # sorted position -> triple index
    offs: np.ndarray            # (h+1,) level slot boundaries, offs[0]==B
    # packed local COO (host mirrors of the plan's device arrays)
    rows_a: np.ndarray
    cols_a: np.ndarray
    vals_a: np.ndarray
    per_blk: np.ndarray
    # per-external-entry halo bookkeeping
    ext_blk: np.ndarray
    ext_pos: np.ndarray
    ext_trip: np.ndarray
    # segment bookkeeping (from _derive_tree_fields_np)
    seg_lvl: np.ndarray         # -2 pad, -1 interior, l boundary level
    seg_pos: np.ndarray
    seg_counts: np.ndarray      # (h+1, k)
    row_lvl: np.ndarray
    int_seg: tuple
    lvl_segs: list
    diag: np.ndarray
    diag_b: np.ndarray
    diag_e: np.ndarray
    diag_row: np.ndarray        # rows_a[diag_b, diag_e], precomputed

    @property
    def h(self) -> int:
        return len(self.offs) - 1

    @property
    def nnz(self) -> int:
        return len(self.keys)


def capture_replan_cache(*, indptr, indices, data, src, part, order,
                         rank_in_block, sizes, B, k, n, fanouts, suffix,
                         flat, o2, ext, ext_keys, psrc, t_pair, t_v, t_lvl,
                         slot_of_trip, offs, rows_a, cols_a, vals_a,
                         per_blk, pos_edge, row_mask, host):
    """Build a :class:`ReplanCache` from ``build_plan_tree`` internals.

    Returns None for a non-canonical CSR (unsorted or duplicate entries
    within a row) — such matrices can still be planned, just not patched.
    """
    keys = src.astype(np.int64) * n + np.asarray(indices, dtype=np.int64)
    if len(keys) > 1 and not (np.diff(keys) > 0).all():
        return None
    # triple index at each sorted-(recv, v) position: o2 maps triple t to
    # its pre-sort position, so the inverse permutation is the lookup
    rv_trip = np.empty(len(o2), dtype=np.int64)
    rv_trip[o2] = np.arange(len(o2), dtype=np.int64)
    rv_keys = flat.astype(np.int64)
    p_ext = np.searchsorted(rv_keys, ext_keys.astype(np.int64))
    cnt = np.bincount(p_ext, minlength=len(rv_keys)).astype(np.int64)[o2]
    ext_idx = np.flatnonzero(ext)
    rel_slot = (slot_of_trip - offs[t_lvl]).astype(np.int32)
    vstarts = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(sizes, out=vstarts[1:])
    return ReplanCache(
        n=n, k=k, B=B,
        indptr=np.asarray(indptr), indices=np.asarray(indices),
        data=np.asarray(data), keys=keys,
        part=part, order=order, rank_in_block=rank_in_block,
        sizes=sizes, vstarts=vstarts,
        fanouts=tuple(fanouts), suffix=tuple(suffix), row_mask=row_mask,
        own=psrc, pos_edge=pos_edge,
        t_pair=t_pair, t_v=t_v, t_lvl=t_lvl, rel_slot=rel_slot, cnt=cnt,
        rv_keys=rv_keys, rv_trip=rv_trip, offs=np.asarray(offs),
        rows_a=rows_a, cols_a=cols_a, vals_a=vals_a, per_blk=per_blk,
        ext_blk=psrc[ext_idx], ext_pos=pos_edge[ext_idx],
        ext_trip=rv_trip[p_ext],
        seg_lvl=host["seg_lvl"], seg_pos=host["seg_pos"],
        seg_counts=host["seg_counts"], row_lvl=host["row_lvl"],
        int_seg=host["int_seg"], lvl_segs=list(host["lvl_segs"]),
        diag=host["diag"], diag_b=host["diag_b"], diag_e=host["diag_e"],
        diag_row=rows_a[host["diag_b"], host["diag_e"]],
    )


def _recompute_diag_rows(diag2, cache, blk, row, vals_host):
    """Zero + re-accumulate the diagonal of the given (block, row) pairs
    in the fresh builder's np.add.at order (order matters bit-for-bit
    when a row has several diagonal-hitting entries)."""
    aff = np.zeros(diag2.shape, dtype=bool)
    aff[blk, row] = True
    sel = aff[cache.diag_b, cache.diag_row]
    db, de = cache.diag_b[sel], cache.diag_e[sel]
    diag2[blk, row] = 0.0
    np.add.at(diag2, (db, cache.diag_row[sel]), vals_host[db, de])


def _patch_values(plan, cache: ReplanCache, m: _Merge, validate):
    """Reweight-only fast path: no structure changed, so every packed
    position, slot map, schedule and segment layout is reused; only the
    value arrays (and the diagonal rows hit) are patched."""
    from .distributed import _maybe_verify
    import jax.numpy as jnp

    rw32 = m.rw_vals.astype(np.float32)
    blk = cache.own[m.rw_pos]
    pos = cache.pos_edge[m.rw_pos]
    vals_a = cache.vals_a.copy()
    vals_a[blk, pos] = rw32

    slvl = cache.seg_lvl[blk, pos]
    spos = cache.seg_pos[blk, pos]
    int_r, int_c, int_v = cache.int_seg
    sel = slvl == -1
    if sel.any():
        int_v = int_v.copy()
        int_v[blk[sel], spos[sel]] = rw32[sel]
        vals_int_j = jnp.asarray(int_v)
    else:
        vals_int_j = plan.vals_int
    lvl_segs2, vals_bnd_j = [], []
    for l, (r_, c_, v_) in enumerate(cache.lvl_segs):
        sel = slvl == l
        if sel.any():
            v_ = v_.copy()
            v_[blk[sel], spos[sel]] = rw32[sel]
            vals_bnd_j.append(jnp.asarray(v_))
        else:
            vals_bnd_j.append(plan.vals_bnd_lvl[l])
        lvl_segs2.append((r_, c_, v_))

    diag2 = cache.diag
    diag_j = plan.diag
    is_diag = (cache.keys[m.rw_pos] % cache.n
               == cache.keys[m.rw_pos] // cache.n)
    if is_diag.any():
        diag2 = diag2.copy()
        _recompute_diag_rows(diag2, cache, blk[is_diag],
                             cache.rows_a[blk[is_diag], pos[is_diag]],
                             vals_a)
        diag_j = jnp.asarray(diag2)

    cache2 = dataclasses.replace(
        cache, data=m.data2, vals_a=vals_a,
        int_seg=(int_r, int_c, int_v), lvl_segs=lvl_segs2, diag=diag2)
    return _maybe_verify(dataclasses.replace(
        plan, vals=jnp.asarray(vals_a), vals_int=vals_int_j,
        vals_bnd_lvl=tuple(vals_bnd_j), diag=diag_j,
        _bell={}, _bj_inv=None, _replan=cache2), validate)


def _patch_structure(plan, cache: ReplanCache, m: _Merge, validate):
    """Insert/remove path.  Work scales with the delta plus the size of
    the *affected blocks* (blocks that gained or lost entries) plus a few
    O(nnz) memcpy/scatter passes — never with a full re-extraction."""
    from .distributed import (_class_schedule, _derive_tree_fields_np,
                              _maybe_verify)
    import jax.numpy as jnp

    n, k, B, h = cache.n, cache.k, cache.B, cache.h
    suffix = cache.suffix

    # ---- per-entry owner/position bookkeeping in the new CSR ------------
    nnz2 = len(m.keys2)
    del_own = cache.own[m.del_pos]
    del_dst = cache.indices[m.del_pos]
    ins_own = cache.part[m.ins_rows]
    ins_dst = m.ins_cols
    own2 = np.empty(nnz2, dtype=np.int32)
    own2[m.keep_new] = cache.own[m.keep]
    own2[m.new_pos_ins] = ins_own
    per_blk2 = (cache.per_blk
                - np.bincount(del_own, minlength=k)
                + np.bincount(ins_own, minlength=k))
    aff_mask = np.zeros(k, dtype=bool)
    aff_mask[del_own] = True
    aff_mask[ins_own] = True
    aff = np.flatnonzero(aff_mask)
    pos_edge2 = np.empty(nnz2, dtype=np.int64)
    pos_edge2[m.keep_new] = cache.pos_edge[m.keep]
    pos_edge2[m.new_pos_ins] = 0      # rebuilt below (A blocks only)

    # ---- triple ref-counts: remove / insert external entries ------------
    cnt2 = cache.cnt.copy()
    d_ext = cache.part[del_dst] != del_own
    if d_ext.any():
        dk_rv = del_own[d_ext].astype(np.int64) * n + del_dst[d_ext]
        p_del = np.searchsorted(cache.rv_keys, dk_rv)
        np.subtract.at(cnt2, cache.rv_trip[p_del], 1)
    i_ext = cache.part[ins_dst] != ins_own
    new_rv = np.zeros(0, dtype=np.int64)
    new_rv_cnt = np.zeros(0, dtype=np.int64)
    if i_ext.any():
        ik_rv = ins_own[i_ext].astype(np.int64) * n + ins_dst[i_ext]
        p_ins, found = _find_sorted(cache.rv_keys, ik_rv)
        if found.any():
            np.add.at(cnt2, cache.rv_trip[p_ins[found]], 1)
        new_rv, new_rv_cnt = np.unique(ik_rv[~found], return_counts=True)

    keep_t = cnt2 > 0
    old_idx = np.flatnonzero(keep_t)
    drop_lvls = cache.t_lvl[np.flatnonzero(~keep_t)]

    # ---- merged triple list, canonical (pair, vertex) order -------------
    nv = new_rv % n
    nrecv = new_rv // n
    nown = cache.part[nv].astype(np.int64)
    npair = nrecv * k + nown
    ordn = np.argsort(npair * n + nv, kind="stable")
    nv, nrecv, npair = nv[ordn], nrecv[ordn], npair[ordn]
    ncnt = new_rv_cnt[ordn].astype(np.int64)
    nlvl = np.zeros(len(nv), dtype=np.int64)
    for l in range(h):
        differ = (nrecv // suffix[l]) != (npair % k) // suffix[l]
        nlvl = np.where(differ, l, nlvl)

    old_pv = cache.t_pair[old_idx] * n + cache.t_v[old_idx]
    new_pv = npair * n + nv
    pos_old = (np.arange(len(old_idx), dtype=np.int64)
               + np.searchsorted(new_pv, old_pv))
    pos_new = (np.searchsorted(old_pv, new_pv)
               + np.arange(len(new_pv), dtype=np.int64))
    T2 = len(old_idx) + len(new_pv)

    def merge_t(old_vals, new_vals, dtype):
        out = np.empty(T2, dtype=dtype)
        out[pos_old] = old_vals
        out[pos_new] = new_vals
        return out

    t_pair2 = merge_t(cache.t_pair[old_idx], npair, np.int64)
    t_v2 = merge_t(cache.t_v[old_idx], nv, np.int64)
    t_lvl2 = merge_t(cache.t_lvl[old_idx], nlvl, np.int64)
    cnt3 = merge_t(cnt2[old_idx], ncnt, np.int64)
    old_to_new = np.full(len(cache.t_pair), -1, dtype=np.int64)
    old_to_new[old_idx] = pos_old

    # ---- reschedule only levels whose triple set changed ----------------
    changed_lvls = np.unique(np.concatenate([drop_lvls, nlvl]))
    S_lvl2 = list(plan.S_lvl)
    R_lvl2 = list(plan.n_rounds_lvl)
    si2 = list(plan.send_idx_lvl)
    sm2 = list(plan.send_mask_lvl)
    perms2 = list(plan.round_perms_lvl)
    rel_slot2 = np.empty(T2, dtype=np.int32)
    rel_slot2[pos_old] = cache.rel_slot[old_idx]
    rel_slot2[pos_new] = 0
    dev = np.arange(k, dtype=np.int64)
    for l in changed_lvls.tolist():
        sel = t_lvl2 == l
        sz = suffix[l + 1]
        S_l, R_l, si, sm, perms, slot = _class_schedule(
            t_pair2[sel], t_v2[sel], k, dev % sz, sz, cache.rank_in_block)
        rel_slot2[sel] = slot
        S_lvl2[l], R_lvl2[l] = S_l, R_l
        si2[l], sm2[l] = jnp.asarray(si), jnp.asarray(sm)
        perms2[l] = perms
    offs2 = B + np.concatenate(
        [[0], np.cumsum([r * s for r, s in zip(R_lvl2, S_lvl2)])]).astype(int)
    slot_abs2 = (offs2[t_lvl2] + rel_slot2).astype(np.int32)
    slots_moved = len(changed_lvls) > 0

    # new sorted-(recv, v) lookup
    rvk_all = (t_pair2 // k) * n + t_v2
    ord_rv = np.argsort(rvk_all)
    rv_keys2, rv_trip2 = rvk_all[ord_rv], ord_rv

    # ---- packed COO: copy, zero affected blocks, patch the rest ---------
    nnz_pad2 = max(int(per_blk2.max()) if k else 1, 1)
    w = min(cache.rows_a.shape[1], nnz_pad2)
    rows_a2 = np.zeros((k, nnz_pad2), dtype=np.int32)
    cols_a2 = np.zeros((k, nnz_pad2), dtype=np.int32)
    vals_a2 = np.zeros((k, nnz_pad2), dtype=np.float32)
    rows_a2[:, :w] = cache.rows_a[:, :w]
    cols_a2[:, :w] = cache.cols_a[:, :w]
    vals_a2[:, :w] = cache.vals_a[:, :w]
    rows_a2[aff] = 0
    cols_a2[aff] = 0
    vals_a2[aff] = 0

    rw_blk = cache.own[m.rw_pos]
    rw_p = cache.pos_edge[m.rw_pos]
    rw32 = m.rw_vals.astype(np.float32)
    nm = ~aff_mask[rw_blk]             # reweights in untouched blocks
    vals_a2[rw_blk[nm], rw_p[nm]] = rw32[nm]

    keep_ext = ~aff_mask[cache.ext_blk]
    kext_trip = old_to_new[cache.ext_trip[keep_ext]]
    if slots_moved and keep_ext.any():
        cols_a2[cache.ext_blk[keep_ext], cache.ext_pos[keep_ext]] = \
            slot_abs2[kext_trip]

    # ---- rebuild affected blocks from the new CSR -----------------------
    verts = np.concatenate(
        [cache.order[cache.vstarts[b]:cache.vstarts[b + 1]] for b in aff]
        or [np.zeros(0, dtype=np.int64)])
    deg2 = np.diff(m.indptr2)
    dv = deg2[verts]
    tot = int(dv.sum())
    e_start = np.cumsum(dv) - dv
    e_idx = (np.repeat(np.asarray(m.indptr2, dtype=np.int64)[verts], dv)
             + (np.arange(tot, dtype=np.int64) - np.repeat(e_start, dv)))
    blk_rep = np.repeat(cache.part[verts], dv)
    per_aff = per_blk2[aff]
    blk_e_start = np.cumsum(per_aff) - per_aff
    pos_rep = (np.arange(tot, dtype=np.int64)
               - np.repeat(blk_e_start, per_aff))
    rows_loc = cache.rank_in_block[np.repeat(verts, dv)]
    dst_e = np.asarray(m.indices2)[e_idx]
    cols_loc = cache.rank_in_block[dst_e].astype(np.int32)
    ext_e = cache.part[dst_e] != blk_rep
    trip_e = np.zeros(0, dtype=np.int64)
    if ext_e.any():
        rvk = blk_rep[ext_e].astype(np.int64) * n + dst_e[ext_e]
        trip_e = rv_trip2[np.searchsorted(rv_keys2, rvk)]
        cols_loc[ext_e] = slot_abs2[trip_e]
    rows_a2[blk_rep, pos_rep] = rows_loc
    cols_a2[blk_rep, pos_rep] = cols_loc
    vals_a2[blk_rep, pos_rep] = np.asarray(m.data2)[e_idx]
    pos_edge2[e_idx] = pos_rep

    ext_blk2 = np.concatenate([cache.ext_blk[keep_ext], blk_rep[ext_e]])
    ext_pos2 = np.concatenate([cache.ext_pos[keep_ext], pos_rep[ext_e]])
    ext_trip2 = np.concatenate([kext_trip, trip_e])

    # ---- segments: re-derive affected blocks, merge with the rest -------
    sub = _derive_tree_fields_np(rows_a2[aff], cols_a2[aff], vals_a2[aff],
                                 per_blk2[aff], B, offs2)
    seg_counts2 = cache.seg_counts.copy()
    seg_counts2[:, aff] = sub["seg_counts"]
    pads = np.maximum(seg_counts2.max(axis=1), 1).astype(np.int64)

    sw = cache.seg_lvl.shape[1]
    seg_lvl2 = np.full((k, nnz_pad2), -2, dtype=np.int8)
    seg_lvl2[:, :min(sw, nnz_pad2)] = cache.seg_lvl[:, :min(sw, nnz_pad2)]
    seg_pos2 = np.zeros((k, nnz_pad2), dtype=np.int32)
    seg_pos2[:, :min(sw, nnz_pad2)] = cache.seg_pos[:, :min(sw, nnz_pad2)]
    subw = sub["seg_lvl"].shape[1]
    seg_lvl2[aff] = -2
    seg_lvl2[aff, :subw] = sub["seg_lvl"]
    seg_pos2[aff] = 0
    seg_pos2[aff, :subw] = sub["seg_pos"]
    row_lvl2 = cache.row_lvl.copy()
    row_lvl2[aff] = sub["row_lvl"]

    def merge_seg(old_seg, sub_seg, pad2):
        out = []
        for o_, s_ in zip(old_seg, sub_seg):
            a = np.zeros((k, pad2), dtype=o_.dtype)
            wc = min(o_.shape[1], pad2)
            a[:, :wc] = o_[:, :wc]
            a[aff] = 0
            a[aff, :s_.shape[1]] = s_
            out.append(a)
        return out

    int_seg2 = merge_seg(cache.int_seg, sub["int_seg"], int(pads[0]))
    lvl_segs2 = [merge_seg(cache.lvl_segs[l], sub["lvl_segs"][l],
                           int(pads[l + 1])) for l in range(h)]

    # untouched blocks: patch reweighted values / moved halo slots into
    # the merged segments at their cached (segment, position) coordinates
    def seg_scatter(seg_arrays, which, blk, pos, val):
        s_of = cache.seg_lvl[blk, pos]
        s_pos = cache.seg_pos[blk, pos]
        sel = s_of == -1
        if sel.any():
            int_seg2[which][blk[sel], s_pos[sel]] = val[sel]
        for l in range(h):
            sel = s_of == l
            if sel.any():
                lvl_segs2[l][which][blk[sel], s_pos[sel]] = val[sel]
        del seg_arrays

    if nm.any():
        seg_scatter(None, 2, rw_blk[nm], rw_p[nm], rw32[nm])
    if slots_moved and keep_ext.any():
        seg_scatter(None, 1, cache.ext_blk[keep_ext],
                    cache.ext_pos[keep_ext],
                    slot_abs2[kext_trip])

    # ---- diagonal -------------------------------------------------------
    diag2 = cache.diag.copy()
    diag2[aff] = sub["diag"]
    is_diag = nm & (cache.keys[m.rw_pos] % n == cache.keys[m.rw_pos] // n)
    if is_diag.any():
        _recompute_diag_rows(diag2, cache, rw_blk[is_diag],
                             cache.rows_a[rw_blk[is_diag], rw_p[is_diag]],
                             vals_a2)
    keep_d = ~aff_mask[cache.diag_b]
    db2 = np.concatenate([cache.diag_b[keep_d], aff[sub["diag_b"]]])
    de2 = np.concatenate([cache.diag_e[keep_d], sub["diag_e"]])
    o = np.lexsort((de2, db2))
    db2, de2 = db2[o], de2[o]
    diag_row2 = rows_a2[db2, de2]

    bnd_row2 = row_lvl2 >= 0
    interior_mask2 = cache.row_mask * ~bnd_row2

    cache2 = dataclasses.replace(
        cache,
        indptr=m.indptr2, indices=m.indices2, data=m.data2, keys=m.keys2,
        own=own2, pos_edge=pos_edge2, per_blk=per_blk2,
        t_pair=t_pair2, t_v=t_v2, t_lvl=t_lvl2, rel_slot=rel_slot2,
        cnt=cnt3, rv_keys=rv_keys2, rv_trip=rv_trip2, offs=offs2,
        rows_a=rows_a2, cols_a=cols_a2, vals_a=vals_a2,
        ext_blk=ext_blk2, ext_pos=ext_pos2, ext_trip=ext_trip2,
        seg_lvl=seg_lvl2, seg_pos=seg_pos2, seg_counts=seg_counts2,
        row_lvl=row_lvl2, int_seg=tuple(int_seg2),
        lvl_segs=[tuple(s) for s in lvl_segs2],
        diag=diag2, diag_b=db2, diag_e=de2, diag_row=diag_row2)

    return _maybe_verify(dataclasses.replace(
        plan,
        S=max(S_lvl2), n_rounds=sum(R_lvl2),
        rows=jnp.asarray(rows_a2), cols=jnp.asarray(cols_a2),
        vals=jnp.asarray(vals_a2),
        rows_int=jnp.asarray(int_seg2[0]),
        cols_int=jnp.asarray(int_seg2[1]),
        vals_int=jnp.asarray(int_seg2[2]),
        rows_bnd_lvl=tuple(jnp.asarray(s[0]) for s in lvl_segs2),
        cols_bnd_lvl=tuple(jnp.asarray(s[1]) for s in lvl_segs2),
        vals_bnd_lvl=tuple(jnp.asarray(s[2]) for s in lvl_segs2),
        diag=jnp.asarray(diag2), nnz_blk=per_blk2.copy(),
        interior_mask=jnp.asarray(interior_mask2),
        S_lvl=tuple(S_lvl2), n_rounds_lvl=tuple(R_lvl2),
        send_idx_lvl=tuple(si2), send_mask_lvl=tuple(sm2),
        round_perms_lvl=tuple(perms2),
        _pack_blk=own2, _pack_pos=pos_edge2, _pack_dst=m.indices2,
        _cols_global=None, _bell={}, _bj_inv=None, _replan=cache2),
        validate)


def apply_edge_delta(plan, delta: EdgeDelta, validate=None):
    """Patch ``plan`` (a cached :class:`TreePlan`) for ``delta``.

    Returns a new plan bit-equal to ``build_plan_tree`` on the merged
    CSR with the same partition/tree.  Reweight-only deltas touch O(Δ)
    entries plus a few value-array memcpys; structural deltas re-extract
    only the blocks that gained/lost entries and re-color only the tree
    levels whose halo triple set changed.  ``validate`` as in the
    builders (None -> the ``REPRO_VALIDATE`` env toggle).
    """
    cache = getattr(plan, "_replan", None)
    if cache is None:
        raise ValueError(
            "plan has no replan cache (built with cache=False, from a "
            "non-canonical CSR, or not a tree plan) — rebuild with "
            "build_plan_tree(..., cache=True)")
    if delta.n != cache.n:
        raise ValueError(f"delta n={delta.n} != plan n={cache.n}")
    m = _merge_csr(cache.indptr, cache.indices, cache.data, cache.keys,
                   delta)
    if not m.structural:
        return _patch_values(plan, cache, m, validate)
    return _patch_structure(plan, cache, m, validate)


def migrate_state(old_plan, new_plan, *arrays):
    """Permute solver state between two plans of the same matrix size.

    Gathers each (k, B[, nb]) array to global vertex order under
    ``old_plan`` and re-scatters under ``new_plan`` — the warm-start path
    after a drift-triggered full repartition (CG iterate, residual or
    preconditioner state keep their values; only their layout moves).
    """
    if old_plan.n != new_plan.n:
        raise ValueError(
            f"cannot migrate state: old n={old_plan.n}, new n={new_plan.n}")
    out = tuple(np.asarray(new_plan.scatter_vec(old_plan.gather_vec(a)))
                for a in arrays)
    return out[0] if len(out) == 1 else out
