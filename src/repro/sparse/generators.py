"""Graph generators mirroring the paper's instances (Table II).

  * rgg_2d / rgg_3d — random geometric graphs (KaGen-style): n points uniform
    in the unit square/cube, edge iff dist <= r, r chosen for avg degree ~6.
  * rdg_2d — random Delaunay triangulation graphs.
  * grid_2d / grid_3d — structured meshes (stand-in for the DIMACS hugeX
    triangle meshes, same family: planar, bounded degree).
  * aniso_grid — grid with direction-dependent edge weights (anisotropic
    diffusion; the block-Jacobi preconditioner's model problem).
  * refined_mesh — adaptively refined triangular mesh (refinetrace family):
    start from a coarse Delaunay mesh and refine cells near an attractor
    curve, giving strongly non-uniform density.

All generators are deterministic given seed and return Graph with coords.
"""
from __future__ import annotations

import numpy as np
from scipy.spatial import Delaunay, cKDTree

from .graph import Graph, from_edges


def rgg(n: int, dim: int = 2, avg_degree: float = 6.0,
        seed: int = 0) -> Graph:
    """Random geometric graph in [0,1]^dim with expected avg degree."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, dim)).astype(np.float32)
    # avg_degree = n * V_d(r)  =>  r = (avg_degree / (n c_d))^(1/d)
    c_d = {1: 2.0, 2: np.pi, 3: 4.0 * np.pi / 3.0}[dim]
    r = (avg_degree / (n * c_d)) ** (1.0 / dim)
    tree = cKDTree(pts)
    pairs = tree.query_pairs(r, output_type="ndarray")
    if len(pairs) == 0:
        pairs = np.zeros((0, 2), dtype=np.int64)
    return from_edges(n, pairs[:, 0], pairs[:, 1], symmetrize=True,
                      coords=pts)


def rdg(n: int, seed: int = 0) -> Graph:
    """Random Delaunay graph: Delaunay triangulation of uniform points."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2)).astype(np.float64)
    tri = Delaunay(pts)
    edges = _tri_edges(tri.simplices)
    return from_edges(n, edges[:, 0], edges[:, 1], symmetrize=True,
                      coords=pts.astype(np.float32))


def _tri_edges(simplices: np.ndarray) -> np.ndarray:
    e = np.concatenate([simplices[:, [0, 1]], simplices[:, [1, 2]],
                        simplices[:, [0, 2]]])
    e.sort(axis=1)
    return np.unique(e, axis=0)


def grid(shape: tuple[int, ...]) -> Graph:
    """Structured grid mesh (2D or 3D), 4/6-point stencil."""
    dims = len(shape)
    n = int(np.prod(shape))
    idx = np.arange(n).reshape(shape)
    src, dst = [], []
    for axis in range(dims):
        a = np.take(idx, np.arange(shape[axis] - 1), axis=axis).ravel()
        b = np.take(idx, np.arange(1, shape[axis]), axis=axis).ravel()
        src.append(a)
        dst.append(b)
    src, dst = np.concatenate(src), np.concatenate(dst)
    coords = np.stack(np.unravel_index(np.arange(n), shape),
                      axis=1).astype(np.float32)
    coords /= np.maximum(1, np.array(shape, dtype=np.float32) - 1)
    return from_edges(n, src, dst, symmetrize=True, coords=coords)


def aniso_grid(shape: tuple[int, ...], weights: tuple[float, ...] = None,
               eps: float = 0.01) -> Graph:
    """Structured grid with direction-dependent edge weights — the
    anisotropic-diffusion model problem.  ``weights[d]`` is the coupling
    along axis d (default ``(1, eps, eps, ...)``: strong along axis 0).
    Its shifted Laplacian is the classic case where point-Jacobi stalls
    but per-block preconditioners that keep whole strong lines inside a
    block (e.g. axis-0 stripes + block-Jacobi) stay effective.
    """
    dims = len(shape)
    if weights is None:
        weights = (1.0,) + (eps,) * (dims - 1)
    n = int(np.prod(shape))
    idx = np.arange(n).reshape(shape)
    src, dst, w = [], [], []
    for axis in range(dims):
        a = np.take(idx, np.arange(shape[axis] - 1), axis=axis).ravel()
        b = np.take(idx, np.arange(1, shape[axis]), axis=axis).ravel()
        src.append(a)
        dst.append(b)
        w.append(np.full(len(a), weights[axis], dtype=np.float32))
    src, dst, w = (np.concatenate(src), np.concatenate(dst),
                   np.concatenate(w))
    coords = np.stack(np.unravel_index(np.arange(n), shape),
                      axis=1).astype(np.float32)
    coords /= np.maximum(1, np.array(shape, dtype=np.float32) - 1)
    return from_edges(n, src, dst, w, symmetrize=True, coords=coords)


def refined_mesh(n_coarse: int = 2000, refine_rounds: int = 3,
                 seed: int = 0) -> Graph:
    """Adaptive mesh a la 'refinetrace': density concentrates near a moving
    front (a circle arc), produced by iterative point insertion + re-Delaunay.
    """
    rng = np.random.default_rng(seed)
    pts = rng.random((n_coarse, 2))
    center = np.array([0.5, 0.5])
    for _ in range(refine_rounds):
        d = np.abs(np.linalg.norm(pts - center, axis=1) - 0.3)
        hot = pts[d < 0.08]
        if len(hot) == 0:
            break
        jitter = rng.normal(scale=0.01, size=(len(hot), 2))
        pts = np.concatenate([pts, np.clip(hot + jitter, 0, 1)])
    pts = np.unique(np.round(pts, 7), axis=0)
    tri = Delaunay(pts)
    edges = _tri_edges(tri.simplices)
    return from_edges(len(pts), edges[:, 0], edges[:, 1], symmetrize=True,
                      coords=pts.astype(np.float32))


GENERATORS = {
    "rgg_2d": lambda n, seed=0: rgg(n, 2, seed=seed),
    "rgg_3d": lambda n, seed=0: rgg(n, 3, seed=seed),
    "rdg_2d": lambda n, seed=0: rdg(n, seed=seed),
    "grid_2d": lambda n, seed=0: grid((int(np.sqrt(n)),) * 2),
    "grid_3d": lambda n, seed=0: grid((max(2, round(n ** (1 / 3))),) * 3),
    "refined": lambda n, seed=0: refined_mesh(n, seed=seed),
}
