"""Distributed SpMV / CG over a heterogeneous partition — shard_map version
of the paper's application layer (Sec. VI-a: SpMV and CG on the Laplacian,
distributed according to the partition produced by the respective tool).

MPI-rank-per-PU becomes one mesh index per block.  Because XLA SPMD shards
are uniform, each block is padded to B = max block size; `row_mask` marks
real rows.  The padding waste is exactly the heterogeneity spread: with
Algorithm-1 target sizes the fast PUs own the largest blocks, so B equals
the largest tw and slow PUs carry ghost rows.  (On a real heterogeneous
machine the fast PU also *is* faster, so wall-clock stays balanced — the
simulated-speed benchmark in benchmarks/bench_cg.py models this.)

Halo exchange: the quotient graph of the partition is edge-colored
(core.refinement.vizing_edge_coloring, Misra-Gries: <= Delta+1 rounds on
quotient degree Delta) and each color class becomes one
`lax.ppermute` round — at most one partner per device per round, the exact
communication schedule Geographer-R uses for its pairwise refinement.  The
halo buffer layout is (rounds, S) with stable slots, so column indices are
remapped once on the host.

Three exchange strategies are provided:
  * ``halo``       — ppermute rounds *overlapped* with compute: each
                     block's padded COO is split into interior rows (no
                     halo-slot columns) and boundary rows; the interior
                     matvec is issued before the ppermute rounds, so XLA
                     runs it concurrently with the exchange, and only the
                     boundary accumulation waits on halo data.  [default]
  * ``halo_seq``   — the sequential schedule (all rounds, then one full
                     matvec); same plan, kept as the non-overlapped
                     reference the benchmark compares against.
  * ``allgather``  — all_gather of the whole padded vector, comm volume
                     = O(n); the baseline a partitioner-oblivious system
                     would use.

Orthogonally, ``local_format`` selects the interior matvec kernel:
padded-COO scatter-add (``'coo'``) or the Pallas block-ELL kernel of
kernels/spmv_bell.py (``'bell'``, TPU-compiled, interpreted elsewhere).

Plan construction (:func:`build_plan`) is fully vectorized NumPy —
``searchsorted`` / ``unique`` / fancy-index scatter; the only Python loops
are over quotient-graph edges (O(k^2), k = #PUs), never over vertices or
matrix entries.  The seed's per-edge implementation is preserved as
:func:`build_plan_reference` and serves as the correctness oracle in
tests/test_dist_plan.py and the speedup baseline in benchmarks/bench_cg.py.

Both plan builders produce *identical* plans (bit-equal arrays), so the
ppermute schedule and halo slot layout are stable across the rewrite.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from ..core.refinement import vizing_edge_coloring
from .cg import cg_solve, jacobi_preconditioner


@dataclasses.dataclass
class DistPlan:
    """Host-built plan + device arrays for the distributed operator.

    All arrays carry a leading block axis of size k and are sharded
    one-block-per-device by the shard_map programs below.
    """

    k: int
    B: int                      # padded rows per block
    S: int                      # padded halo slots per round
    n_rounds: int
    n: int                      # true global size
    perm: np.ndarray            # old vertex id -> padded new id (blk*B+rank)
    block_of: np.ndarray        # (k,) first padded id of each block
    sizes: np.ndarray           # (k,) true rows per block
    # device data
    rows: jnp.ndarray           # (k, nnz_pad) int32 local row
    cols: jnp.ndarray           # (k, nnz_pad) int32 local col in [0, B+R*S)
    vals: jnp.ndarray           # (k, nnz_pad) f32
    row_mask: jnp.ndarray       # (k, B) f32
    send_idx: jnp.ndarray       # (k, R, S) int32 local indices to send
    send_mask: jnp.ndarray      # (k, R, S) f32
    round_perms: tuple          # per round: tuple of (src, dst) pairs
    # interior/boundary split of the same nnz set (comm/compute overlap):
    # a row is *boundary* iff any of its edges reads a halo slot; interior
    # rows depend only on x_loc, so their matvec is issued before the
    # ppermute rounds and overlaps with the exchange.  Within each block
    # the packed edge order of rows/cols/vals is preserved, and
    # interior + boundary edges exactly tile the block's true nnz.
    rows_int: jnp.ndarray = None   # (k, nnz_int_pad) int32
    cols_int: jnp.ndarray = None   # (k, nnz_int_pad) int32, all < B
    vals_int: jnp.ndarray = None   # (k, nnz_int_pad) f32
    rows_bnd: jnp.ndarray = None   # (k, nnz_bnd_pad) int32
    cols_bnd: jnp.ndarray = None   # (k, nnz_bnd_pad) int32, in [0, B+R*S)
    vals_bnd: jnp.ndarray = None   # (k, nnz_bnd_pad) f32
    interior_mask: jnp.ndarray = None  # (k, B) f32: real AND interior rows
    diag: jnp.ndarray = None       # (k, B) f32 diagonal of A (Jacobi)
    nnz_blk: np.ndarray = None     # (k,) true nnz per block (host)
    # lazy allgather-mode columns: built on first access from the packing
    # order (only the allgather baseline needs them; halo mode never does)
    _pack_blk: np.ndarray = None      # (nnz,) owning block, packed order
    _pack_pos: np.ndarray = None      # (nnz,) slot within block
    _pack_dst: np.ndarray = None      # (nnz,) global dst vertex, packed order
    _cols_global: jnp.ndarray = None
    _bell: dict = dataclasses.field(default_factory=dict)

    @property
    def cols_global(self) -> jnp.ndarray:
        """(k, nnz_pad) int32 columns in padded global ids (blk*B + rank)."""
        if self._cols_global is None:
            out = np.zeros(self.rows.shape, dtype=np.int32)
            out[self._pack_blk, self._pack_pos] = \
                self.perm[self._pack_dst].astype(np.int32)
            self._cols_global = jnp.asarray(out)
        return self._cols_global

    def scatter_vec(self, x: np.ndarray) -> np.ndarray:
        """(n,) global vector -> (k, B) padded block-major layout."""
        out = np.zeros((self.k, self.B), dtype=np.float32)
        out[self.perm // self.B, self.perm % self.B] = x
        return out

    def gather_vec(self, xb: np.ndarray) -> np.ndarray:
        """(k, B) -> (n,) global order."""
        return np.asarray(xb)[self.perm // self.B, self.perm % self.B]

    def bell_local(self, bm: int = 8, bk: int = 128):
        """Block-ELL form of the *interior* edges, stacked over blocks.

        Returns (blocks, cols): (k, S_b, NNZB, bm, bk) f32 and
        (k, S_b, NNZB) int32 with uniform NNZB = max over blocks, so the
        stack shards cleanly one-block-per-device.  Interior columns are
        all < B, so the local Pallas block-ELL matvec needs no halo data —
        it is the interior half of the overlapped SpMV on TPU.  Cached per
        (bm, bk).
        """
        key = (bm, bk)
        cached = self._bell.get(key)
        if cached is not None:
            return cached
        from ..kernels.spmv_bell import padded_coo_to_block_ell
        ri = np.asarray(self.rows_int)
        ci = np.asarray(self.cols_int)
        vi = np.asarray(self.vals_int)
        per = [padded_coo_to_block_ell(ri[b], ci[b], vi[b], self.B,
                                       bm=bm, bk=bk)
               for b in range(self.k)]
        nnzb = max(blk.shape[1] for blk, _, _ in per)
        Sb = per[0][0].shape[0]
        blocks = np.zeros((self.k, Sb, nnzb, bm, bk), dtype=np.float32)
        cols = np.zeros((self.k, Sb, nnzb), dtype=np.int32)
        for b, (blk, col, _meta) in enumerate(per):
            blocks[b, :, :blk.shape[1]] = blk
            cols[b, :, :col.shape[1]] = col
        cached = (jnp.asarray(blocks), jnp.asarray(cols))
        self._bell[key] = cached
        return cached


def _edge_endpoints(indptr: np.ndarray, indices: np.ndarray):
    src = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
    return src, np.asarray(indices)


def _derive_overlap_fields(rows_a: np.ndarray, cols_a: np.ndarray,
                           vals_a: np.ndarray, per_blk: np.ndarray,
                           B: int) -> dict:
    """Split each block's packed COO into interior/boundary row segments.

    A local row is *boundary* iff any of its edges has a halo-slot column
    (col >= B); every edge of a boundary row — including its local ones —
    goes to the boundary segment, so the interior matvec depends only on
    x_loc and can be issued before (and overlap with) the ppermute rounds.
    Within a block the original packed edge order is preserved in both
    segments, and interior + boundary exactly tile the true nnz set.

    Also extracts the (k, B) diagonal of A (rows == cols can only hold for
    local edges, and local ranks are unique, so rows == cols <=> src == dst)
    for Jacobi preconditioning.  Pure vectorized NumPy; derived only from
    the packed arrays, so both plan builders get bit-identical fields.
    """
    k, nnz_pad = rows_a.shape
    per_blk = np.asarray(per_blk, dtype=np.int64)
    valid = np.arange(nnz_pad)[None, :] < per_blk[:, None]     # (k, nnz_pad)
    halo_edge = valid & (cols_a >= B)
    bnd_row = np.zeros((k, B), dtype=bool)
    bi, ei = np.nonzero(halo_edge)
    bnd_row[bi, rows_a[bi, ei]] = True
    blk_col = np.arange(k)[:, None]
    edge_bnd = valid & bnd_row[blk_col, rows_a]
    edge_int = valid & ~edge_bnd

    def pack(sel):
        counts = sel.sum(axis=1)
        pad = max(int(counts.max()) if k else 0, 1)
        pos = np.cumsum(sel, axis=1) - 1
        b, e = np.nonzero(sel)
        r = np.zeros((k, pad), dtype=np.int32)
        c = np.zeros((k, pad), dtype=np.int32)
        v = np.zeros((k, pad), dtype=np.float32)
        p = pos[b, e]
        r[b, p] = rows_a[b, e]
        c[b, p] = cols_a[b, e]
        v[b, p] = vals_a[b, e]
        return r, c, v

    rows_int, cols_int, vals_int = pack(edge_int)
    rows_bnd, cols_bnd, vals_bnd = pack(edge_bnd)

    diag = np.zeros((k, B), dtype=np.float32)
    on_diag = valid & (rows_a == cols_a)
    db, de = np.nonzero(on_diag)
    np.add.at(diag, (db, rows_a[db, de]), vals_a[db, de])
    return dict(
        rows_int=jnp.asarray(rows_int), cols_int=jnp.asarray(cols_int),
        vals_int=jnp.asarray(vals_int), rows_bnd=jnp.asarray(rows_bnd),
        cols_bnd=jnp.asarray(cols_bnd), vals_bnd=jnp.asarray(vals_bnd),
        diag=jnp.asarray(diag), nnz_blk=per_blk.copy(),
        _bnd_row=bnd_row,
    )


# build_plan uses O(k*n) dense tables (counting sorts) up to this many
# cells, and sort-based extraction beyond.  The widest live table is the
# int32 halo-slot map (4 B/cell; the bool bitmaps are freed before it is
# allocated), so the dense path peaks at ~64 MiB of transient tables at
# this limit.  Module-level so tests can force the fallback path.
DENSE_PLAN_LIMIT = 1 << 24


def build_plan(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
               part: np.ndarray, k: int) -> DistPlan:
    """Build the distributed plan for matrix (CSR) + partition — vectorized.

    O(nnz log nnz) in NumPy kernels (the log from sorts); no Python
    iteration over vertices, edges, or halo slots.
    """
    n = len(indptr) - 1
    part = np.ascontiguousarray(part, dtype=np.int32)
    sizes = np.bincount(part, minlength=k)
    B = int(sizes.max())
    # dense-table mode: O(k*n) bitmaps replace O(x log x) sorts wherever a
    # small-range counting sort suffices; fall back to sorts for huge k*n
    dense = k * n <= DENSE_PLAN_LIMIT
    # block-contiguous reordering: rank of each vertex within its block.
    # order = vertices sorted by (block, id) — a (k, n) one-hot flatnonzero
    # is that counting sort directly; argsort is the general fallback.
    if dense:
        onehot = np.zeros(k * n, dtype=bool)
        onehot[part.astype(np.int64) * n + np.arange(n)] = True
        order = np.flatnonzero(onehot) % n
        del onehot
    else:
        order = np.argsort(part, kind="stable")       # new (unpadded) -> old
    starts = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(sizes, out=starts[1:])
    rank_in_block = np.empty(n, dtype=np.int32)
    rank_in_block[order] = np.arange(n, dtype=np.int64) - starts[part[order]]
    perm = part.astype(np.int64) * B + rank_in_block   # padded new id
    block_of = np.arange(k, dtype=np.int64) * B

    # ---- halo triples: (receiver, owner, vertex), deduped & sorted -------
    # Two equivalent extraction paths (identical triple order — sorted by
    # (receiver, owner, vertex)):
    #   dense  — O(nnz + k*n): dedupe through a (k, n) needed-bitmap, then
    #            one radix argsort over the small-range pair keys.  Used
    #            when the bitmap fits comfortably (k*n <= 2^26 cells).
    #   sorted — O(E_ext log E_ext): np.unique over per-edge triple keys.
    #            Fallback for huge k*n where O(k*n) memory is not ok.
    src, dst = _edge_endpoints(indptr, indices)
    psrc, pdst = part[src], part[dst]
    ext = psrc != pdst
    # receiver = part[src] needs vertex dst owned by part[dst]
    if dense:
        needed = np.zeros(k * n, dtype=bool)
        # k*n <= 2^26 here, so (recv, v) keys always fit int32
        ext_keys = psrc[ext] * np.int32(n) + dst[ext]
        needed[ext_keys] = True
        flat = np.flatnonzero(needed)                  # sorted (recv, v)
        del needed
        t_v = flat % n
        # int16 pair keys: 1-2 radix passes in the stable argsort below
        pair_t = np.int16 if k * k <= np.iinfo(np.int16).max else np.int32
        t_pair = ((flat // n).astype(pair_t) * pair_t(k)
                  + part[t_v].astype(pair_t))          # recv*k + own
        o2 = np.argsort(t_pair, kind="stable")         # radix; keeps v asc
        t_pair, t_v, flat = t_pair[o2], t_v[o2], flat[o2]
        uniq_trip = trip_of_edge = None                # unused on this path
    else:
        key_t = np.int32 if k * k * n < np.iinfo(np.int32).max else np.int64
        pair_key_all = psrc * np.int32(k) + pdst
        trip_key_e = (pair_key_all[ext].astype(key_t) * key_t(n)
                      + dst[ext].astype(key_t))
        uniq_trip, trip_of_edge = np.unique(trip_key_e, return_inverse=True)
        t_pair = (uniq_trip // n).astype(np.int32)     # recv*k + own
        t_v = uniq_trip % n
    # triples sharing a (recv, own) pair are contiguous and sorted by v;
    # halo slot position = rank within the pair group.  t_pair is sorted,
    # so pair groups fall out of the boundary flags — no second unique/sort.
    m = len(t_pair)
    newp = np.empty(m, dtype=bool)
    if m:
        newp[0] = True
        np.not_equal(t_pair[1:], t_pair[:-1], out=newp[1:])
    grp_first = np.flatnonzero(newp)                   # triple idx per pair
    uniq_pairs = t_pair[grp_first]
    pair_counts = np.diff(np.append(grp_first, m))
    pair_of_trip = np.cumsum(newp) - 1
    t_pos = np.arange(m) - grp_first[pair_of_trip]
    S = int(pair_counts.max()) if len(pair_counts) else 1
    S = max(1, S)

    # ---- edge-color the undirected quotient graph ------------------------
    p_recv, p_own = uniq_pairs // k, uniq_pairs % k
    und_key = (np.minimum(p_recv, p_own) * k + np.maximum(p_recv, p_own))
    uniq_und = np.unique(und_key)
    und_a, und_b = uniq_und // k, uniq_und % k
    und_w = np.zeros(len(uniq_und), dtype=np.float64)
    np.add.at(und_w, np.searchsorted(uniq_und, und_key), pair_counts)
    qp = np.stack([und_a, und_b], axis=1).astype(np.int64)
    colors = (vizing_edge_coloring(qp, und_w) if len(qp)
              else np.zeros(0, np.int32))
    n_rounds = int(colors.max() + 1) if len(colors) else 1
    # (k, k) directed-pair -> round lookup (tiny), so per-triple color is a
    # single gather instead of min/max arithmetic over all triples
    color_dir = np.zeros(k * k, dtype=np.int32)
    color_dir[und_a * k + und_b] = colors
    color_dir[und_b * k + und_a] = colors
    t_color = color_dir[t_pair]

    # ---- send schedule (owner side) --------------------------------------
    # each color class is a matching, so an owner serves one receiver per
    # round: the (own, color, pos) scatter below has no collisions.
    send_idx = np.zeros((k, n_rounds, S), dtype=np.int32)
    send_mask = np.zeros((k, n_rounds, S), dtype=np.float32)
    t_own = (uniq_pairs % k)[pair_of_trip]        # owner of each triple
    send_idx[t_own, t_color, t_pos] = rank_in_block[t_v]
    send_mask[t_own, t_color, t_pos] = 1.0
    pair_color = color_dir[und_a * k + und_b]
    round_perms: list[list[tuple[int, int]]] = [[] for _ in range(n_rounds)]
    for a, b, c in zip(und_a.tolist(), und_b.tolist(), pair_color.tolist()):
        # o->r and r->o swap in the same round (bidirectional ppermute)
        round_perms[c].append((a, b))
        round_perms[c].append((b, a))

    # ---- local matrix in padded-COO with remapped columns ----------------
    rows_l = rank_in_block[src]
    # local rank everywhere, then overwrite external edges with halo slots
    cols_l = rank_in_block[dst]
    # halo slot of remote vertex u on receiver r: B + round*S + pos,
    # precomputed per triple so the per-edge remap is one gather
    slot_of_trip = (B + t_color * S + t_pos).astype(np.int32)
    if dense:
        slot_arr = np.empty(k * n, dtype=np.int32)     # (recv, v) -> slot
        slot_arr[flat] = slot_of_trip
        cols_l[ext] = slot_arr[ext_keys]
    else:
        cols_l[ext] = slot_of_trip[trip_of_edge]
    # pack edges per owning block (scatter, no per-block loop).  The slot of
    # edge e is derived from CSR structure in O(nnz) — no argsort: within a
    # block, edges are laid out by (owner rank, CSR order), exactly the
    # order a stable argsort over part[src] would give.
    own = psrc
    per_blk = np.bincount(own, minlength=k)
    nnz_pad = max(int(per_blk.max()) if len(per_blk) else 1, 1)
    deg = np.diff(indptr)
    deg_o = deg[order]
    # edge start of each vertex inside its block's packed segment
    vstart = np.empty(n, dtype=np.int64)
    blk_edge_start = np.cumsum(per_blk) - per_blk
    vstart[order] = (np.cumsum(deg_o) - deg_o) - blk_edge_start[part[order]]
    pos_edge = (vstart[src]
                + (np.arange(len(src)) - np.repeat(indptr[:-1], deg)))
    rows_a = np.zeros((k, nnz_pad), dtype=np.int32)
    cols_a = np.zeros((k, nnz_pad), dtype=np.int32)
    vals_a = np.zeros((k, nnz_pad), dtype=np.float32)
    rows_a[own, pos_edge] = rows_l
    cols_a[own, pos_edge] = cols_l
    vals_a[own, pos_edge] = data

    row_mask = (np.arange(B)[None, :] < sizes[:, None]).astype(np.float32)

    split = _derive_overlap_fields(rows_a, cols_a, vals_a, per_blk, B)
    bnd_row = split.pop("_bnd_row")
    interior_mask = row_mask * ~bnd_row

    return DistPlan(
        k=k, B=B, S=S, n_rounds=n_rounds, n=n, perm=perm, block_of=block_of,
        sizes=sizes,
        rows=jnp.asarray(rows_a), cols=jnp.asarray(cols_a),
        vals=jnp.asarray(vals_a), row_mask=jnp.asarray(row_mask),
        send_idx=jnp.asarray(send_idx), send_mask=jnp.asarray(send_mask),
        round_perms=tuple(tuple(r) for r in round_perms),
        interior_mask=jnp.asarray(interior_mask), **split,
        _pack_blk=own, _pack_pos=pos_edge, _pack_dst=dst,
    )


def build_plan_reference(indptr: np.ndarray, indices: np.ndarray,
                         data: np.ndarray, part: np.ndarray,
                         k: int) -> DistPlan:
    """The seed's per-edge plan builder, kept verbatim (modulo the removed
    dead ``loc`` placeholder) as the oracle for tests and the baseline for
    the vectorization speedup benchmark.  O(|halo|) Python iteration —
    do not use beyond toy meshes."""
    n = len(indptr) - 1
    part = np.asarray(part)
    sizes = np.bincount(part, minlength=k)
    B = int(sizes.max())
    order = np.argsort(part, kind="stable")
    starts = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(sizes, out=starts[1:])
    rank_in_block = np.empty(n, dtype=np.int64)
    rank_in_block[order] = np.arange(n) - starts[part[order]]
    perm = part.astype(np.int64) * B + rank_in_block
    block_of = np.arange(k, dtype=np.int64) * B

    src, dst = _edge_endpoints(indptr, indices)
    ext = part[src] != part[dst]
    recv_blk = part[src][ext].astype(np.int64)
    own_blk = part[dst][ext].astype(np.int64)
    needed = dst[ext].astype(np.int64)
    pair_key = recv_blk * k + own_blk
    uniq_keys, inv = np.unique(pair_key, return_inverse=True)
    need_map: dict[tuple[int, int], np.ndarray] = {}
    for i, key in enumerate(uniq_keys):
        r, o = int(key // k), int(key % k)
        need_map[(r, o)] = np.unique(needed[inv == i])

    und_pairs = sorted({(min(r, o), max(r, o)) for (r, o) in need_map})
    qp = np.array(und_pairs, dtype=np.int64).reshape(-1, 2)
    qw = np.array([len(need_map.get((a, b), ())) +
                   len(need_map.get((b, a), ())) for a, b in und_pairs],
                  dtype=np.float64)
    colors = (vizing_edge_coloring(qp, qw) if len(qp)
              else np.zeros(0, np.int32))
    n_rounds = int(colors.max() + 1) if len(colors) else 1
    S = max(1, max((len(v) for v in need_map.values()), default=1))

    send_idx = np.zeros((k, n_rounds, S), dtype=np.int32)
    send_mask = np.zeros((k, n_rounds, S), dtype=np.float32)
    halo_slot: dict[tuple[int, int], int] = {}
    round_perms: list[list[tuple[int, int]]] = [[] for _ in range(n_rounds)]
    for e, (a, b) in enumerate(und_pairs):
        c = int(colors[e])
        for (o, r) in ((a, b), (b, a)):
            need = need_map.get((r, o))
            if need is None or len(need) == 0:
                continue
            loc = rank_in_block[need].astype(np.int32)
            send_idx[o, c, :len(need)] = loc
            send_mask[o, c, :len(need)] = 1.0
            for p, u in enumerate(need):
                halo_slot[(r, int(u))] = B + c * S + p
        round_perms[c].append((a, b))
        round_perms[c].append((b, a))

    rows_l = rank_in_block[src].astype(np.int32)
    cols_l = np.empty(len(dst), dtype=np.int32)
    same = ~ext
    cols_l[same] = rank_in_block[dst[same]].astype(np.int32)
    for i in np.nonzero(ext)[0]:
        cols_l[i] = halo_slot[(int(part[src[i]]), int(dst[i]))]
    own = part[src]
    per_blk = np.bincount(own, minlength=k)
    nnz_pad = max(int(per_blk.max()) if len(per_blk) else 1, 1)
    rows_a = np.zeros((k, nnz_pad), dtype=np.int32)
    cols_a = np.zeros((k, nnz_pad), dtype=np.int32)
    vals_a = np.zeros((k, nnz_pad), dtype=np.float32)
    ord2 = np.argsort(own, kind="stable")
    off = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(per_blk, out=off[1:])
    for b in range(k):
        sl = ord2[off[b]:off[b + 1]]
        rows_a[b, :len(sl)] = rows_l[sl]
        cols_a[b, :len(sl)] = cols_l[sl]
        vals_a[b, :len(sl)] = data[sl]

    row_mask = np.zeros((k, B), dtype=np.float32)
    for b in range(k):
        row_mask[b, :sizes[b]] = 1.0

    split = _derive_overlap_fields(rows_a, cols_a, vals_a, per_blk, B)
    bnd_row = split.pop("_bnd_row")
    interior_mask = row_mask * ~bnd_row

    blk_e = own[ord2]
    return DistPlan(
        k=k, B=B, S=S, n_rounds=n_rounds, n=n, perm=perm, block_of=block_of,
        sizes=sizes,
        rows=jnp.asarray(rows_a), cols=jnp.asarray(cols_a),
        vals=jnp.asarray(vals_a), row_mask=jnp.asarray(row_mask),
        send_idx=jnp.asarray(send_idx), send_mask=jnp.asarray(send_mask),
        round_perms=tuple(tuple(r) for r in round_perms),
        interior_mask=jnp.asarray(interior_mask), **split,
        _pack_blk=blk_e,
        _pack_pos=np.arange(len(src)) - off[blk_e],
        _pack_dst=dst[ord2],
    )


# --------------------------------------------------------------------------
# shard_map programs
# --------------------------------------------------------------------------

def _halo_exchange(plan: DistPlan, x_loc, send_idx, send_mask, axis: str):
    """x_loc: (B,).  Returns (B + R*S,) extended vector."""
    bufs = []
    for c in range(plan.n_rounds):
        buf = x_loc[send_idx[c]] * send_mask[c]            # (S,)
        perm = plan.round_perms[c]
        if perm:
            buf = jax.lax.ppermute(buf, axis, perm)
        else:
            buf = jnp.zeros_like(buf)
        bufs.append(buf)
    return jnp.concatenate([x_loc] + bufs)


COMM_MODES = ("halo", "halo_seq", "allgather")
LOCAL_FORMATS = ("coo", "bell")


def _local_matvec_builder(plan: DistPlan, comm: str, axis: str,
                          local_format: str = "coo"):
    """Shared per-device matvec for every comm/format combination.

    Returns ``(consts, fn)``: ``consts`` is a tuple of (k, ...) arrays to be
    sharded one-block-per-device, and ``fn(local_consts, x_loc)`` computes
    y_loc = (A @ x)_loc on already-squeezed per-device slices.  Both
    :func:`make_dist_spmv` and the fused :func:`make_dist_cg` build on it.
    ``consts`` always ends with ``plan.row_mask`` so the fused CG can read
    the mask for its psum dots without shipping a duplicate operand.

    ``comm='halo'`` is the *overlapped* schedule: the interior matvec
    (``plan.rows_int`` — rows touching no halo slot) is issued before the
    colored ppermute rounds, so XLA can run it concurrently with the
    exchange; boundary rows accumulate afterward from the extended vector.
    ``comm='halo_seq'`` keeps the PR-1 sequential schedule (exchange all
    rounds, then one full matvec) as the non-overlapped reference.
    ``local_format='bell'`` runs the interior matvec through the Pallas
    block-ELL kernel (kernels/spmv_bell.py) instead of the COO scatter-add
    — ROADMAP's third comm/format combination.
    """
    if comm not in COMM_MODES:
        raise ValueError(f"unknown comm mode {comm!r}; choose {COMM_MODES}")
    if local_format not in LOCAL_FORMATS:
        raise ValueError(f"unknown local format {local_format!r}; "
                         f"choose {LOCAL_FORMATS}")
    if local_format == "bell" and comm != "halo":
        raise ValueError("local_format='bell' requires comm='halo' (the "
                         "interior/boundary split the kernel is built from)")
    B = plan.B

    if comm == "allgather":
        consts = (plan.rows, plan.cols_global, plan.vals, plan.row_mask)

        def fn(c, x):
            rows, cols, vals, row_mask = c
            x_all = jax.lax.all_gather(x, axis).reshape(-1)   # (k*B,)
            y = jnp.zeros(B, jnp.float32).at[rows].add(vals * x_all[cols])
            return y * row_mask

        return consts, fn

    if comm == "halo_seq":
        consts = (plan.rows, plan.cols, plan.vals, plan.send_idx,
                  plan.send_mask, plan.row_mask)

        def fn(c, x):
            rows, cols, vals, send_idx, send_mask, row_mask = c
            x_ext = _halo_exchange(plan, x, send_idx, send_mask, axis)
            y = jnp.zeros(B, jnp.float32).at[rows].add(vals * x_ext[cols])
            return y * row_mask

        return consts, fn

    # comm == "halo": overlapped interior/boundary schedule
    bnd = (plan.rows_bnd, plan.cols_bnd, plan.vals_bnd)
    tail = (plan.send_idx, plan.send_mask, plan.row_mask)
    if local_format == "coo":
        consts = (plan.rows_int, plan.cols_int, plan.vals_int) + bnd + tail

        def fn(c, x):
            ri, ci, vi, rb, cb, vb, send_idx, send_mask, row_mask = c
            # interior first: no halo dependence, overlaps the ppermutes
            y = jnp.zeros(B, jnp.float32).at[ri].add(vi * x[ci])
            x_ext = _halo_exchange(plan, x, send_idx, send_mask, axis)
            y = y.at[rb].add(vb * x_ext[cb])
            return y * row_mask

        return consts, fn

    blocks, bcols = plan.bell_local()

    def fn(c, x):
        from ..kernels.spmv_bell import spmv_block_ell
        blk, bc, rb, cb, vb, send_idx, send_mask, row_mask = c
        y = spmv_block_ell(blk, bc, x)                     # interior rows
        x_ext = _halo_exchange(plan, x, send_idx, send_mask, axis)
        y = y.at[rb].add(vb * x_ext[cb])
        return y * row_mask

    return (blocks, bcols) + bnd + tail, fn


def make_dist_spmv(plan: DistPlan, mesh: Mesh, axis: str = "pu",
                   comm: str = "halo",
                   local_format: str = "coo") -> Callable:
    """Returns jit'd y = A @ x on (k, B) block-major vectors.

    ``comm='halo'`` (default) overlaps the interior matvec with the
    edge-colored ppermute rounds; ``comm='halo_seq'`` is the sequential
    reference schedule; ``comm='allgather'`` gathers the whole padded
    vector (the partitioner-oblivious baseline).  ``local_format='bell'``
    runs the interior matvec through the Pallas block-ELL kernel.
    """
    consts, local_fn = _local_matvec_builder(plan, comm, axis, local_format)

    def prog(*args):
        *cs, x = args
        return local_fn(tuple(c[0] for c in cs), x[0])[None]

    spec = P(axis)
    fn = shard_map(prog, mesh=mesh,
                   in_specs=(spec,) * (len(consts) + 1), out_specs=spec)

    @jax.jit
    def spmv(x):
        return fn(*consts, x)

    return spmv


def make_dist_cg(plan: DistPlan, mesh: Mesh, axis: str = "pu",
                 tol: float = 1e-6, max_iters: int = 500,
                 comm: str = "halo", local_format: str = "coo",
                 precondition: str | None = None) -> Callable:
    """Whole-CG SPMD program: the while_loop runs inside shard_map; dot
    products are psum-reduced local dots; the matvec comes from
    :func:`_local_matvec_builder` — overlapped halo rounds (``'halo'``),
    the sequential schedule (``'halo_seq'``), or the full-vector
    all_gather baseline (``'allgather'``), with the interior matvec in
    padded-COO or Pallas block-ELL (``local_format``).

    ``precondition='jacobi'`` switches the body to preconditioned CG with
    M = diag(A); the diagonal is already on-device in ``plan.diag``,
    extracted when the plan was built.  Convergence is still tested on the
    unpreconditioned residual ||r||^2 <= tol^2 ||b||^2, so preconditioned
    and unpreconditioned solves stop at the same solution quality.

    This is the fused fast path; the composable path is
    ``operator.DistributedOperator`` + the generic ``cg.cg_solve``."""
    if precondition not in (None, "jacobi"):
        raise ValueError(f"unknown precondition {precondition!r}")
    consts, local_fn = _local_matvec_builder(plan, comm, axis, local_format)
    jacobi = precondition == "jacobi"
    all_consts = consts + ((plan.diag,) if jacobi else ())

    def cg_local(*args):
        # one CG implementation for every program shape: the generic
        # cg.cg_solve is pure lax, so tracing it here (with a psum dot and
        # the local matvec) yields the fused whole-CG SPMD program
        *cs, b = args
        cs = tuple(c[0] for c in cs)
        b = b[0]
        prec = None
        if jacobi:
            prec = jacobi_preconditioner(cs[-1])
            cs = cs[:-1]
        row_mask = cs[-1]                 # builder contract: always last

        def dot(u, v):
            return jax.lax.psum(jnp.vdot(u * row_mask, v), axis)

        res = cg_solve(lambda x: local_fn(cs, x), b, tol=tol,
                       max_iters=max_iters, dot=dot, precondition=prec)
        return res.x[None], res.residual[None], res.iters[None]

    spec = P(axis)
    fn = shard_map(cg_local, mesh=mesh,
                   in_specs=(spec,) * (len(all_consts) + 1),
                   out_specs=(spec, spec, spec))

    @jax.jit
    def solve(b):
        x, res, it = fn(*all_consts, b)
        return x, res[0], it[0]

    return solve
