"""Distributed SpMV / CG over a heterogeneous partition — shard_map version
of the paper's application layer (Sec. VI-a: SpMV and CG on the Laplacian,
distributed according to the partition produced by the respective tool).

MPI-rank-per-PU becomes one mesh index per block.  Because XLA SPMD shards
are uniform, each block is padded to B = max block size; `row_mask` marks
real rows.  The padding waste is exactly the heterogeneity spread: with
Algorithm-1 target sizes the fast PUs own the largest blocks, so B equals
the largest tw and slow PUs carry ghost rows.  (On a real heterogeneous
machine the fast PU also *is* faster, so wall-clock stays balanced — the
simulated-speed benchmark in benchmarks/bench_cg.py models this.)

Halo exchange: the quotient graph of the partition is edge-colored
(core.refinement.vizing_edge_coloring, Misra-Gries: <= Delta+1 rounds on
quotient degree Delta) and each color class becomes one
`lax.ppermute` round — at most one partner per device per round, the exact
communication schedule Geographer-R uses for its pairwise refinement.  The
halo buffer layout is (rounds, S) with stable slots, so column indices are
remapped once on the host.

Four exchange strategies are provided:
  * ``halo``       — ppermute rounds *overlapped* with compute: each
                     block's padded COO is split into interior rows (no
                     halo-slot columns) and boundary rows; the interior
                     matvec is issued before the ppermute rounds, so XLA
                     runs it concurrently with the exchange, and only the
                     boundary accumulation waits on halo data.  [default]
  * ``halo_seq``   — the sequential schedule (all rounds, then one full
                     matvec); same plan, kept as the non-overlapped
                     reference the benchmark compares against.
  * ``allgather``  — all_gather of the whole padded vector, comm volume
                     = O(n); the baseline a partitioner-oblivious system
                     would use.
  * ``hier``       — the per-tree-level schedule for hierarchical meshes
                     (:func:`build_plan_tree`; :func:`build_plan_hier` is
                     the two-level instance): halo edges are split by the
                     LCA level of their block pair, one segment per tree
                     level, each with its own Misra-Gries coloring over
                     that level's quotient graph.  The interior matvec is
                     issued first; each level's rounds ppermute over its
                     axis suffix (level 0 = the fast innermost axis,
                     firing in every subtree at once; the outermost level
                     = all axes combined), issued *outermost-level-first*
                     so every slower exchange is in flight while all
                     faster levels' rounds and accumulations run.  A
                     boundary row's class is the highest level it reads,
                     so only root-crossing rows wait on the slowest
                     links.

Orthogonally, ``local_format`` selects the interior matvec kernel:
padded-COO scatter-add (``'coo'``) or the Pallas block-ELL kernel of
kernels/spmv_bell.py (``'bell'``, TPU-compiled, interpreted elsewhere).

Plan construction (:func:`build_plan`) is fully vectorized NumPy —
``searchsorted`` / ``unique`` / fancy-index scatter; the only Python loops
are over quotient-graph edges (O(k^2), k = #PUs), never over vertices or
matrix entries.  The seed's per-edge implementation is preserved as
:func:`build_plan_reference` and serves as the correctness oracle in
tests/test_dist_plan.py and the speedup baseline in benchmarks/bench_cg.py.

Both plan builders produce *identical* plans (bit-equal arrays), so the
ppermute schedule and halo slot layout are stable across the rewrite.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from ..compat import Mesh, P, shard_map
from ..core.refinement import vizing_edge_coloring
from .cg import cg_solve, jacobi_preconditioner


@dataclasses.dataclass
class DistPlan:
    """Host-built plan + device arrays for the distributed operator.

    All arrays carry a leading block axis of size k and are sharded
    one-block-per-device by the shard_map programs below.
    """

    k: int
    B: int                      # padded rows per block
    S: int                      # padded halo slots per round
    n_rounds: int
    n: int                      # true global size
    perm: np.ndarray            # old vertex id -> padded new id (blk*B+rank)
    block_of: np.ndarray        # (k,) first padded id of each block
    sizes: np.ndarray           # (k,) true rows per block
    # device data
    rows: jnp.ndarray           # (k, nnz_pad) int32 local row
    cols: jnp.ndarray           # (k, nnz_pad) int32 local col in [0, B+R*S)
    vals: jnp.ndarray           # (k, nnz_pad) f32
    row_mask: jnp.ndarray       # (k, B) f32
    send_idx: jnp.ndarray       # (k, R, S) int32 local indices to send
    send_mask: jnp.ndarray      # (k, R, S) f32
    round_perms: tuple          # per round: tuple of (src, dst) pairs
    # interior/boundary split of the same nnz set (comm/compute overlap):
    # a row is *boundary* iff any of its edges reads a halo slot; interior
    # rows depend only on x_loc, so their matvec is issued before the
    # ppermute rounds and overlaps with the exchange.  Within each block
    # the packed edge order of rows/cols/vals is preserved, and
    # interior + boundary edges exactly tile the block's true nnz.
    rows_int: jnp.ndarray = None   # (k, nnz_int_pad) int32
    cols_int: jnp.ndarray = None   # (k, nnz_int_pad) int32, all < B
    vals_int: jnp.ndarray = None   # (k, nnz_int_pad) f32
    rows_bnd: jnp.ndarray = None   # (k, nnz_bnd_pad) int32
    cols_bnd: jnp.ndarray = None   # (k, nnz_bnd_pad) int32, in [0, B+R*S)
    vals_bnd: jnp.ndarray = None   # (k, nnz_bnd_pad) f32
    interior_mask: jnp.ndarray = None  # (k, B) f32: real AND interior rows
    diag: jnp.ndarray = None       # (k, B) f32 diagonal of A (Jacobi)
    nnz_blk: np.ndarray = None     # (k,) true nnz per block (host)
    # lazy allgather-mode columns: built on first access from the packing
    # order (only the allgather baseline needs them; halo mode never does)
    _pack_blk: np.ndarray = None      # (nnz,) owning block, packed order
    _pack_pos: np.ndarray = None      # (nnz,) slot within block
    _pack_dst: np.ndarray = None      # (nnz,) global dst vertex, packed order
    _cols_global: jnp.ndarray = None
    _bell: dict = dataclasses.field(default_factory=dict)
    _bj_inv: jnp.ndarray = None       # lazy (k, B, B) block-Jacobi inverses
    # host-side intermediates for O(delta) incremental replanning
    # (:mod:`repro.sparse.replan`); None on plans built without a cache.
    # Never compared by the bit-equality suites — pure bookkeeping.
    _replan: object = None

    @property
    def cols_global(self) -> jnp.ndarray:
        """(k, nnz_pad) int32 columns in padded global ids (blk*B + rank)."""
        if self._cols_global is None:
            out = np.zeros(self.rows.shape, dtype=np.int32)
            out[self._pack_blk, self._pack_pos] = \
                self.perm[self._pack_dst].astype(np.int32)
            self._cols_global = jnp.asarray(out)
        return self._cols_global

    def scatter_vec(self, x: np.ndarray) -> np.ndarray:
        """(n,) global vector -> (k, B) padded block-major layout.  An
        (n, nb) RHS batch scatters to (k, B, nb) — trailing axes ride
        along; padding rows stay zero in every column."""
        x = np.asarray(x)
        dt = x.dtype if np.issubdtype(x.dtype, np.floating) else np.float32
        out = np.zeros((self.k, self.B) + x.shape[1:], dtype=dt)
        out[self.perm // self.B, self.perm % self.B] = x
        return out

    def gather_vec(self, xb: np.ndarray) -> np.ndarray:
        """(k, B[, nb]) -> (n[, nb]) global order."""
        return np.asarray(xb)[self.perm // self.B, self.perm % self.B]

    def bell_local(self, bm: int = 8, bk: int = 128):
        """Block-ELL form of the *interior* edges, stacked over blocks.

        Returns (blocks, cols): (k, S_b, NNZB, bm, bk) f32 and
        (k, S_b, NNZB) int32 with uniform NNZB = max over blocks, so the
        stack shards cleanly one-block-per-device.  Interior columns are
        all < B, so the local Pallas block-ELL matvec needs no halo data —
        it is the interior half of the overlapped SpMV on TPU.  Cached per
        (bm, bk).
        """
        key = (bm, bk)
        cached = self._bell.get(key)
        if cached is not None:
            return cached
        from ..kernels.spmv_bell import padded_coo_to_block_ell
        ri = np.asarray(self.rows_int)
        ci = np.asarray(self.cols_int)
        vi = np.asarray(self.vals_int)
        per = [padded_coo_to_block_ell(ri[b], ci[b], vi[b], self.B,
                                       bm=bm, bk=bk)
               for b in range(self.k)]
        nnzb = max(blk.shape[1] for blk, _, _ in per)
        Sb = per[0][0].shape[0]
        blocks = np.zeros((self.k, Sb, nnzb, bm, bk), dtype=np.float32)
        cols = np.zeros((self.k, Sb, nnzb), dtype=np.int32)
        for b, (blk, col, _meta) in enumerate(per):
            blocks[b, :, :blk.shape[1]] = blk
            cols[b, :, :col.shape[1]] = col
        cached = (jnp.asarray(blocks), jnp.asarray(cols))
        self._bell[key] = cached
        return cached

    def block_jacobi_inv(self) -> jnp.ndarray:
        """(k, B, B) f32 inverses of the per-PU diagonal blocks of A.

        The diagonal block of PU b is assembled from the *local* edges the
        plan already extracted (cols < B — exactly the entries the interior
        + intra-block part of the matvec reads), so no second pass over the
        CSR input is needed.  Rows with no local entries (ghost padding
        rows, fully-halo rows) get an identity diagonal, which keeps their
        zero residuals out of the Krylov space — the same convention as
        :func:`cg.jacobi_preconditioner`.  Lazily computed and cached;
        dense O(k B^3) host inversion, intended for the benchmark/test
        scales this repo runs at (a production variant would sparse-
        Cholesky the local blocks instead).
        """
        if self._bj_inv is None:
            rows = np.asarray(self.rows)
            cols = np.asarray(self.cols)
            vals = np.asarray(self.vals, dtype=np.float64)
            k, nnz_pad = rows.shape
            per = np.asarray(self.nnz_blk, dtype=np.int64)
            valid = np.arange(nnz_pad)[None, :] < per[:, None]
            loc = valid & (cols < self.B)
            M = np.zeros((k, self.B, self.B), dtype=np.float64)
            bi, ei = np.nonzero(loc)
            np.add.at(M, (bi, rows[bi, ei], cols[bi, ei]), vals[bi, ei])
            zero_row = ~M.any(axis=2)                       # ghost + no-local
            zb, zr = np.nonzero(zero_row)
            M[zb, zr, zr] = 1.0
            self._bj_inv = jnp.asarray(np.linalg.inv(M).astype(np.float32))
        return self._bj_inv


def _edge_endpoints(indptr: np.ndarray, indices: np.ndarray):
    src = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
    return src, np.asarray(indices)


def _pack_local_coo(indptr: np.ndarray, src: np.ndarray, data: np.ndarray,
                    part: np.ndarray, order: np.ndarray, k: int,
                    rows_l: np.ndarray, cols_l: np.ndarray,
                    per_blk: np.ndarray):
    """Pack edges per owning block into (k, nnz_pad) padded-COO arrays —
    scatter, no per-block loop.  The slot of edge e is derived from CSR
    structure in O(nnz) — no argsort: within a block, edges are laid out
    by (owner rank, CSR order), exactly the order a stable argsort over
    part[src] would give.  Shared by :func:`build_plan` and
    :func:`build_plan_hier` so the packed edge order (the invariant the
    bit-identity property tests guard) has one definition.

    Returns ``(rows_a, cols_a, vals_a, pos_edge)``.
    """
    n = len(indptr) - 1
    nnz_pad = max(int(per_blk.max()) if len(per_blk) else 1, 1)
    deg = np.diff(indptr)
    deg_o = deg[order]
    # edge start of each vertex inside its block's packed segment
    vstart = np.empty(n, dtype=np.int64)
    blk_edge_start = np.cumsum(per_blk) - per_blk
    vstart[order] = (np.cumsum(deg_o) - deg_o) - blk_edge_start[part[order]]
    pos_edge = (vstart[src]
                + (np.arange(len(src)) - np.repeat(indptr[:-1], deg)))
    own = part[src]
    rows_a = np.zeros((k, nnz_pad), dtype=np.int32)
    cols_a = np.zeros((k, nnz_pad), dtype=np.int32)
    vals_a = np.zeros((k, nnz_pad), dtype=np.float32)
    rows_a[own, pos_edge] = rows_l
    cols_a[own, pos_edge] = cols_l
    vals_a[own, pos_edge] = data
    return rows_a, cols_a, vals_a, pos_edge


def _pack_segment(rows_a: np.ndarray, cols_a: np.ndarray, vals_a: np.ndarray,
                  sel: np.ndarray):
    """Pack the edges selected by boolean mask ``sel`` (k, nnz_pad) into
    fresh (k, pad) arrays, preserving per-block packed edge order."""
    k = rows_a.shape[0]
    counts = sel.sum(axis=1)
    pad = max(int(counts.max()) if k else 0, 1)
    pos = np.cumsum(sel, axis=1) - 1
    b, e = np.nonzero(sel)
    r = np.zeros((k, pad), dtype=np.int32)
    c = np.zeros((k, pad), dtype=np.int32)
    v = np.zeros((k, pad), dtype=np.float32)
    p = pos[b, e]
    r[b, p] = rows_a[b, e]
    c[b, p] = cols_a[b, e]
    v[b, p] = vals_a[b, e]
    return r, c, v


def _derive_overlap_fields(rows_a: np.ndarray, cols_a: np.ndarray,
                           vals_a: np.ndarray, per_blk: np.ndarray,
                           B: int) -> dict:
    """Split each block's packed COO into interior/boundary row segments.

    A local row is *boundary* iff any of its edges has a halo-slot column
    (col >= B); every edge of a boundary row — including its local ones —
    goes to the boundary segment, so the interior matvec depends only on
    x_loc and can be issued before (and overlap with) the ppermute rounds.
    Within a block the original packed edge order is preserved in both
    segments, and interior + boundary exactly tile the true nnz set.

    Also extracts the (k, B) diagonal of A (rows == cols can only hold for
    local edges, and local ranks are unique, so rows == cols <=> src == dst)
    for Jacobi preconditioning.  Pure vectorized NumPy; derived only from
    the packed arrays, so both plan builders get bit-identical fields.
    """
    k, nnz_pad = rows_a.shape
    per_blk = np.asarray(per_blk, dtype=np.int64)
    valid = np.arange(nnz_pad)[None, :] < per_blk[:, None]     # (k, nnz_pad)
    halo_edge = valid & (cols_a >= B)
    bnd_row = np.zeros((k, B), dtype=bool)
    bi, ei = np.nonzero(halo_edge)
    bnd_row[bi, rows_a[bi, ei]] = True
    blk_col = np.arange(k)[:, None]
    edge_bnd = valid & bnd_row[blk_col, rows_a]
    edge_int = valid & ~edge_bnd

    pack = functools.partial(_pack_segment, rows_a, cols_a, vals_a)
    rows_int, cols_int, vals_int = pack(edge_int)
    rows_bnd, cols_bnd, vals_bnd = pack(edge_bnd)

    diag = np.zeros((k, B), dtype=np.float32)
    on_diag = valid & (rows_a == cols_a)
    db, de = np.nonzero(on_diag)
    np.add.at(diag, (db, rows_a[db, de]), vals_a[db, de])
    return dict(
        rows_int=jnp.asarray(rows_int), cols_int=jnp.asarray(cols_int),
        vals_int=jnp.asarray(vals_int), rows_bnd=jnp.asarray(rows_bnd),
        cols_bnd=jnp.asarray(cols_bnd), vals_bnd=jnp.asarray(vals_bnd),
        diag=jnp.asarray(diag), nnz_blk=per_blk.copy(),
        _bnd_row=bnd_row,
    )


# build_plan uses O(k*n) dense tables (counting sorts) up to this many
# cells.  The widest live table is the int32 halo-slot map (4 B/cell; the
# bool bitmaps are freed before it is allocated), so the single-shot dense
# path peaks at ~64 MiB of transient tables at this limit.  Beyond it the
# bitmap is *sharded by vertex range*: the same dedupe runs one
# O(k * chunk) chunk at a time (chunk sized so k * chunk stays at the
# limit), so production-scale k*n keeps the counting-sort extraction
# instead of falling back to O(E log E) comparison sorts.  Module-level so
# tests can force the sharded path.
DENSE_PLAN_LIMIT = 1 << 24


def _block_layout(part: np.ndarray, k: int, dense: bool = False):
    """Block-contiguous vertex layout shared by all plan builders.

    Returns ``(sizes, B, order, rank_in_block, perm, block_of)``.  With
    ``dense`` a (k, n) one-hot flatnonzero replaces the argsort — that is
    the counting sort for the (block, id) key directly, so both paths
    yield the identical ``order``.
    """
    n = len(part)
    sizes = np.bincount(part, minlength=k)
    B = int(sizes.max())
    if dense:
        onehot = np.zeros(k * n, dtype=bool)
        onehot[part.astype(np.int64) * n + np.arange(n)] = True
        order = np.flatnonzero(onehot) % n             # new (unpadded) -> old
        del onehot
    else:
        order = np.argsort(part, kind="stable")
    starts = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(sizes, out=starts[1:])
    rank_in_block = np.empty(n, dtype=np.int32)
    rank_in_block[order] = np.arange(n, dtype=np.int64) - starts[part[order]]
    perm = part.astype(np.int64) * B + rank_in_block   # padded new id
    block_of = np.arange(k, dtype=np.int64) * B
    return sizes, B, order, rank_in_block, perm, block_of


def _ext_col_slots(flat_post: np.ndarray, flat_sorted, o2: np.ndarray,
                   slot_of_trip: np.ndarray, ext_keys: np.ndarray,
                   k: int, n: int, dense: bool) -> np.ndarray:
    """Halo slot per external edge, from the per-triple slots.

    Dense path: scatter the slots into a (k, n) table and gather by edge
    key.  Sharded path: no O(k*n) table — binary-search the sorted
    (recv, v) keys instead (``slot_at[p]`` = slot of the p-th sorted key).
    Shared by :func:`build_plan` and :func:`build_plan_hier`.
    """
    if dense:
        slot_arr = np.empty(k * n, dtype=np.int32)     # (recv, v) -> slot
        slot_arr[flat_post] = slot_of_trip
        return slot_arr[ext_keys]
    slot_at = np.empty(len(flat_sorted), dtype=np.int32)
    slot_at[o2] = slot_of_trip
    return slot_at[np.searchsorted(flat_sorted, ext_keys)]


def _halo_recv_v_pairs(part: np.ndarray, psrc: np.ndarray, dst: np.ndarray,
                       ext: np.ndarray, k: int, n: int, dense: bool):
    """Deduped (receiver, vertex) halo pairs, ascending by ``recv*n + v``.

    Two equivalent bitmap paths (identical output), shared by
    :func:`build_plan` and :func:`build_plan_hier`:

      dense   — O(nnz + k*n): one (k, n) needed-bitmap + flatnonzero.
                Used when the bitmap fits (k*n <= DENSE_PLAN_LIMIT cells).
      sharded — the same dedupe one vertex-range chunk at a time
                (k * chunk <= DENSE_PLAN_LIMIT cells live at once) for
                production-scale k*n; per chunk the flatnonzero gives
                (recv, v) ascending, and chunks partition the v range, so
                one stable radix pass on recv restores global order.

    Returns ``(flat, ext_keys)``: the sorted unique keys and the per-ext-
    edge key (int32 on the dense path — k*n fits — int64 on the sharded).
    """
    if dense:
        needed = np.zeros(k * n, dtype=bool)
        ext_keys = psrc[ext] * np.int32(n) + dst[ext]
        needed[ext_keys] = True
        flat = np.flatnonzero(needed)                  # sorted (recv, v)
        return flat, ext_keys
    e_recv, e_dst = psrc[ext].astype(np.int64), dst[ext].astype(np.int64)
    ext_keys = e_recv * n + e_dst
    cn = max(1, DENSE_PLAN_LIMIT // max(k, 1))
    chunk_of = e_dst // cn
    n_chunks = -(-n // cn)
    ord_c = np.argsort(chunk_of, kind="stable")
    bounds = np.searchsorted(chunk_of[ord_c], np.arange(n_chunks + 1))
    parts_flat = []
    for ci in range(n_chunks):
        sl = ord_c[bounds[ci]:bounds[ci + 1]]
        if not len(sl):
            continue
        v0 = ci * cn
        width = min(cn, n - v0)
        bm = np.zeros(k * width, dtype=bool)
        bm[e_recv[sl] * width + (e_dst[sl] - v0)] = True
        fz = np.flatnonzero(bm)                        # sorted (recv, v_loc)
        parts_flat.append((fz // width) * np.int64(n) + v0 + fz % width)
    flat = (np.concatenate(parts_flat) if parts_flat
            else np.zeros(0, dtype=np.int64))
    return flat[np.argsort(flat // n, kind="stable")], ext_keys


def _maybe_verify(plan, validate):
    """Run the structural verifier on a freshly built plan.

    ``validate=None`` defers to the ``REPRO_VALIDATE`` env var (the test
    suite turns it on via conftest; production builds skip the pass unless
    asked).  Raises ``analysis.PlanVerificationError`` (a ``ValueError``)
    with every violated invariant when the plan is corrupt.
    """
    if validate is None:
        validate = os.environ.get("REPRO_VALIDATE", "0") not in ("", "0")
    if validate:
        from ..analysis import verify_plan      # lazy: keep import acyclic
        verify_plan(plan).raise_for_errors()
    return plan


def build_plan(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
               part: np.ndarray, k: int,
               validate: bool | None = None) -> DistPlan:
    """Build the distributed plan for matrix (CSR) + partition — vectorized.

    O(nnz log nnz) in NumPy kernels (the log from sorts); no Python
    iteration over vertices, edges, or halo slots.  ``validate=`` runs the
    ``repro.analysis`` structural verifier on the result (default: the
    ``REPRO_VALIDATE`` env var).
    """
    n = len(indptr) - 1
    part = np.ascontiguousarray(part, dtype=np.int32)
    # dense-table mode: O(k*n) bitmaps replace O(x log x) sorts wherever a
    # small-range counting sort suffices; vertex-sharded bitmaps beyond
    dense = k * n <= DENSE_PLAN_LIMIT
    sizes, B, order, rank_in_block, perm, block_of = _block_layout(
        part, k, dense=dense)

    # ---- halo triples: (receiver, owner, vertex), deduped & sorted -------
    src, dst = _edge_endpoints(indptr, indices)
    psrc, pdst = part[src], part[dst]
    ext = psrc != pdst
    flat, ext_keys = _halo_recv_v_pairs(part, psrc, dst, ext, k, n, dense)
    flat_sorted = None if dense else flat              # ascending (recv, v)
    t_v = flat % n
    # small-range pair keys: 1-2 radix passes in the stable argsort below
    pair_t = np.int16 if k * k <= np.iinfo(np.int16).max else np.int32
    t_pair = ((flat // n).astype(pair_t) * pair_t(k)
              + part[t_v].astype(pair_t))              # recv*k + own
    o2 = np.argsort(t_pair, kind="stable")             # radix; keeps v asc
    t_pair, t_v, flat = t_pair[o2], t_v[o2], flat[o2]
    # triples sharing a (recv, own) pair are contiguous and sorted by v;
    # halo slot position = rank within the pair group.  t_pair is sorted,
    # so pair groups fall out of the boundary flags — no second unique/sort.
    m = len(t_pair)
    newp = np.empty(m, dtype=bool)
    if m:
        newp[0] = True
        np.not_equal(t_pair[1:], t_pair[:-1], out=newp[1:])
    grp_first = np.flatnonzero(newp)                   # triple idx per pair
    uniq_pairs = t_pair[grp_first]
    pair_counts = np.diff(np.append(grp_first, m))
    pair_of_trip = np.cumsum(newp) - 1
    t_pos = np.arange(m) - grp_first[pair_of_trip]
    S = int(pair_counts.max()) if len(pair_counts) else 1
    S = max(1, S)

    # ---- edge-color the undirected quotient graph ------------------------
    p_recv, p_own = uniq_pairs // k, uniq_pairs % k
    und_key = (np.minimum(p_recv, p_own) * k + np.maximum(p_recv, p_own))
    uniq_und = np.unique(und_key)
    und_a, und_b = uniq_und // k, uniq_und % k
    und_w = np.zeros(len(uniq_und), dtype=np.float64)
    np.add.at(und_w, np.searchsorted(uniq_und, und_key), pair_counts)
    qp = np.stack([und_a, und_b], axis=1).astype(np.int64)
    colors = (vizing_edge_coloring(qp, und_w) if len(qp)
              else np.zeros(0, np.int32))
    n_rounds = int(colors.max() + 1) if len(colors) else 1
    # (k, k) directed-pair -> round lookup (tiny), so per-triple color is a
    # single gather instead of min/max arithmetic over all triples
    color_dir = np.zeros(k * k, dtype=np.int32)
    color_dir[und_a * k + und_b] = colors
    color_dir[und_b * k + und_a] = colors
    t_color = color_dir[t_pair]

    # ---- send schedule (owner side) --------------------------------------
    # each color class is a matching, so an owner serves one receiver per
    # round: the (own, color, pos) scatter below has no collisions.
    send_idx = np.zeros((k, n_rounds, S), dtype=np.int32)
    send_mask = np.zeros((k, n_rounds, S), dtype=np.float32)
    t_own = (uniq_pairs % k)[pair_of_trip]        # owner of each triple
    send_idx[t_own, t_color, t_pos] = rank_in_block[t_v]
    send_mask[t_own, t_color, t_pos] = 1.0
    pair_color = color_dir[und_a * k + und_b]
    round_perms: list[list[tuple[int, int]]] = [[] for _ in range(n_rounds)]
    for a, b, c in zip(und_a.tolist(), und_b.tolist(), pair_color.tolist()):
        # o->r and r->o swap in the same round (bidirectional ppermute)
        round_perms[c].append((a, b))
        round_perms[c].append((b, a))

    # ---- local matrix in padded-COO with remapped columns ----------------
    rows_l = rank_in_block[src]
    # local rank everywhere, then overwrite external edges with halo slots
    cols_l = rank_in_block[dst]
    # halo slot of remote vertex u on receiver r: B + round*S + pos,
    # precomputed per triple so the per-edge remap is one gather
    slot_of_trip = (B + t_color * S + t_pos).astype(np.int32)
    cols_l[ext] = _ext_col_slots(flat, flat_sorted, o2, slot_of_trip,
                                 ext_keys, k, n, dense)
    own = psrc
    per_blk = np.bincount(own, minlength=k)
    rows_a, cols_a, vals_a, pos_edge = _pack_local_coo(
        indptr, src, data, part, order, k, rows_l, cols_l, per_blk)

    row_mask = (np.arange(B)[None, :] < sizes[:, None]).astype(np.float32)

    split = _derive_overlap_fields(rows_a, cols_a, vals_a, per_blk, B)
    bnd_row = split.pop("_bnd_row")
    interior_mask = row_mask * ~bnd_row

    return _maybe_verify(DistPlan(
        k=k, B=B, S=S, n_rounds=n_rounds, n=n, perm=perm, block_of=block_of,
        sizes=sizes,
        rows=jnp.asarray(rows_a), cols=jnp.asarray(cols_a),
        vals=jnp.asarray(vals_a), row_mask=jnp.asarray(row_mask),
        send_idx=jnp.asarray(send_idx), send_mask=jnp.asarray(send_mask),
        round_perms=tuple(tuple(r) for r in round_perms),
        interior_mask=jnp.asarray(interior_mask), **split,
        _pack_blk=own, _pack_pos=pos_edge, _pack_dst=dst,
    ), validate)


def build_plan_reference(indptr: np.ndarray, indices: np.ndarray,
                         data: np.ndarray, part: np.ndarray,
                         k: int) -> DistPlan:
    """The seed's per-edge plan builder, kept verbatim (modulo the removed
    dead ``loc`` placeholder) as the oracle for tests and the baseline for
    the vectorization speedup benchmark.  O(|halo|) Python iteration —
    do not use beyond toy meshes."""
    n = len(indptr) - 1
    part = np.asarray(part)
    sizes = np.bincount(part, minlength=k)
    B = int(sizes.max())
    order = np.argsort(part, kind="stable")
    starts = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(sizes, out=starts[1:])
    rank_in_block = np.empty(n, dtype=np.int64)
    rank_in_block[order] = np.arange(n) - starts[part[order]]
    perm = part.astype(np.int64) * B + rank_in_block
    block_of = np.arange(k, dtype=np.int64) * B

    src, dst = _edge_endpoints(indptr, indices)
    ext = part[src] != part[dst]
    recv_blk = part[src][ext].astype(np.int64)
    own_blk = part[dst][ext].astype(np.int64)
    needed = dst[ext].astype(np.int64)
    pair_key = recv_blk * k + own_blk
    uniq_keys, inv = np.unique(pair_key, return_inverse=True)
    need_map: dict[tuple[int, int], np.ndarray] = {}
    for i, key in enumerate(uniq_keys):
        r, o = int(key // k), int(key % k)
        need_map[(r, o)] = np.unique(needed[inv == i])

    und_pairs = sorted({(min(r, o), max(r, o)) for (r, o) in need_map})
    qp = np.array(und_pairs, dtype=np.int64).reshape(-1, 2)
    qw = np.array([len(need_map.get((a, b), ())) +
                   len(need_map.get((b, a), ())) for a, b in und_pairs],
                  dtype=np.float64)
    colors = (vizing_edge_coloring(qp, qw) if len(qp)
              else np.zeros(0, np.int32))
    n_rounds = int(colors.max() + 1) if len(colors) else 1
    S = max(1, max((len(v) for v in need_map.values()), default=1))

    send_idx = np.zeros((k, n_rounds, S), dtype=np.int32)
    send_mask = np.zeros((k, n_rounds, S), dtype=np.float32)
    halo_slot: dict[tuple[int, int], int] = {}
    round_perms: list[list[tuple[int, int]]] = [[] for _ in range(n_rounds)]
    for e, (a, b) in enumerate(und_pairs):
        c = int(colors[e])
        for (o, r) in ((a, b), (b, a)):
            need = need_map.get((r, o))
            if need is None or len(need) == 0:
                continue
            loc = rank_in_block[need].astype(np.int32)
            send_idx[o, c, :len(need)] = loc
            send_mask[o, c, :len(need)] = 1.0
            for p, u in enumerate(need):
                halo_slot[(r, int(u))] = B + c * S + p
        round_perms[c].append((a, b))
        round_perms[c].append((b, a))

    rows_l = rank_in_block[src].astype(np.int32)
    cols_l = np.empty(len(dst), dtype=np.int32)
    same = ~ext
    cols_l[same] = rank_in_block[dst[same]].astype(np.int32)
    for i in np.nonzero(ext)[0]:
        cols_l[i] = halo_slot[(int(part[src[i]]), int(dst[i]))]
    own = part[src]
    per_blk = np.bincount(own, minlength=k)
    nnz_pad = max(int(per_blk.max()) if len(per_blk) else 1, 1)
    rows_a = np.zeros((k, nnz_pad), dtype=np.int32)
    cols_a = np.zeros((k, nnz_pad), dtype=np.int32)
    vals_a = np.zeros((k, nnz_pad), dtype=np.float32)
    ord2 = np.argsort(own, kind="stable")
    off = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(per_blk, out=off[1:])
    for b in range(k):
        sl = ord2[off[b]:off[b + 1]]
        rows_a[b, :len(sl)] = rows_l[sl]
        cols_a[b, :len(sl)] = cols_l[sl]
        vals_a[b, :len(sl)] = data[sl]

    row_mask = np.zeros((k, B), dtype=np.float32)
    for b in range(k):
        row_mask[b, :sizes[b]] = 1.0

    split = _derive_overlap_fields(rows_a, cols_a, vals_a, per_blk, B)
    bnd_row = split.pop("_bnd_row")
    interior_mask = row_mask * ~bnd_row

    blk_e = own[ord2]
    return DistPlan(
        k=k, B=B, S=S, n_rounds=n_rounds, n=n, perm=perm, block_of=block_of,
        sizes=sizes,
        rows=jnp.asarray(rows_a), cols=jnp.asarray(cols_a),
        vals=jnp.asarray(vals_a), row_mask=jnp.asarray(row_mask),
        send_idx=jnp.asarray(send_idx), send_mask=jnp.asarray(send_mask),
        round_perms=tuple(tuple(r) for r in round_perms),
        interior_mask=jnp.asarray(interior_mask), **split,
        _pack_blk=blk_e,
        _pack_pos=np.arange(len(src)) - off[blk_e],
        _pack_dst=dst[ord2],
    )


# --------------------------------------------------------------------------
# hierarchical (arbitrary-depth tree) plans
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TreePlan(DistPlan):
    """Arbitrary-depth tree plan for hierarchical meshes
    (:func:`build_plan_tree`; the two-level :func:`build_plan_hier` is
    the ``h == 2`` instance).

    Blocks are *tree-major*: device position = the leaf slot of the
    ``fanouts`` mixed radix (outermost digit first), matching a
    ``P((axis_1, ..., axis_h))`` sharding of the leading block axis.
    Halo edges are split by the LCA level of their block pair (level 0 =
    siblings, level h-1 = root-crossing), one segment per level, each
    with its own Misra-Gries coloring over that level's quotient graph —
    nodes are *suffix* indices (the last ``level + 1`` radix digits), so
    one ppermute schedule over the level's axis suffix fires in every
    subtree at once (blocks without a given edge send masked zeros);
    the outermost level linearizes all axes, exactly PR 3's inter-pod
    class.

    The extended vector layout is ``[x_loc | lvl-0 slots | ... |
    lvl-(h-1) slots]``: a boundary row's class is the highest level it
    reads, so each class's accumulation waits only on its own and faster
    levels' exchanges.  The base-class flat schedule fields
    (``send_idx`` / ``send_mask`` / ``round_perms`` / ``rows_bnd``...)
    are *not populated* — a TreePlan only runs under ``comm='hier'``
    (enforced by the matvec builder).  The two-level field names of the
    PR 3-4 API (``S_intra`` / ``n_rounds_inter`` / ``send_idx_intra`` /
    ``rows_bnd_inter`` / ``pods`` / ``k_local`` / ``pod_of``...) remain
    available as read-only views of the level tuples.
    """

    fanouts: tuple = ()                 # (k_1, ..., k_h), prod == k
    anc: np.ndarray = None              # (h-1, k) canonical table, tree-major
    block_map: np.ndarray = None        # (k,) original block id -> device pos
    S_lvl: tuple = ()                   # per-level halo slots per round
    n_rounds_lvl: tuple = ()            # per-level colored round count
    send_idx_lvl: tuple = ()            # per level: (k, R_l, S_l) int32
    send_mask_lvl: tuple = ()           # per level: (k, R_l, S_l) f32
    round_perms_lvl: tuple = ()         # per level, per round:
    #                                     suffix-linearized (src, dst) pairs
    rows_bnd_lvl: tuple = ()            # per level: rows whose highest
    cols_bnd_lvl: tuple = ()            #   read is that level's slot range
    vals_bnd_lvl: tuple = ()

    # -- tree structure -----------------------------------------------------
    @property
    def h(self) -> int:
        return len(self.fanouts)

    def level_offsets(self) -> np.ndarray:
        """(h+1,) slot-range boundaries of the extended vector: level l
        slots live in ``[offs[l], offs[l+1])``; ``offs[0] == B``."""
        sizes = [r * s for r, s in zip(self.n_rounds_lvl, self.S_lvl)]
        return self.B + np.concatenate([[0], np.cumsum(sizes)]).astype(int)

    # -- two-level views (the PR 3-4 HierPlan API) --------------------------
    @property
    def pods(self) -> int:
        return self.fanouts[0] if self.h >= 2 else 1

    @property
    def k_local(self) -> int:
        return self.k // self.pods

    @property
    def pod_of(self) -> np.ndarray:
        """(k,) top-level group of each tree-major block."""
        return np.arange(self.k, dtype=np.int64) // self.k_local

    def _two_level(self, name: str, idx: int):
        if self.h > 2:
            raise AttributeError(
                f"{name} is the two-level view; this plan is depth "
                f"{self.h} — use the *_lvl tuples")
        return idx

    @property
    def S_intra(self) -> int:
        return self.S_lvl[self._two_level("S_intra", 0)]

    @property
    def S_inter(self) -> int:
        self._two_level("S_inter", 1)
        return self.S_lvl[1] if self.h >= 2 else 1

    @property
    def n_rounds_intra(self) -> int:
        return self.n_rounds_lvl[self._two_level("n_rounds_intra", 0)]

    @property
    def n_rounds_inter(self) -> int:
        self._two_level("n_rounds_inter", 1)
        return self.n_rounds_lvl[1] if self.h >= 2 else 0

    @property
    def send_idx_intra(self):
        return self.send_idx_lvl[self._two_level("send_idx_intra", 0)]

    @property
    def send_mask_intra(self):
        return self.send_mask_lvl[self._two_level("send_mask_intra", 0)]

    @property
    def send_idx_inter(self):
        return self.send_idx_lvl[self._two_level("send_idx_inter", 1)]

    @property
    def send_mask_inter(self):
        return self.send_mask_lvl[self._two_level("send_mask_inter", 1)]

    @property
    def round_perms_intra(self) -> tuple:
        return self.round_perms_lvl[self._two_level("round_perms_intra", 0)]

    @property
    def round_perms_inter(self) -> tuple:
        return self.round_perms_lvl[self._two_level("round_perms_inter", 1)]

    @property
    def rows_bnd_intra(self):
        return self.rows_bnd_lvl[self._two_level("rows_bnd_intra", 0)]

    @property
    def cols_bnd_intra(self):
        return self.cols_bnd_lvl[self._two_level("cols_bnd_intra", 0)]

    @property
    def vals_bnd_intra(self):
        return self.vals_bnd_lvl[self._two_level("vals_bnd_intra", 0)]

    @property
    def rows_bnd_inter(self):
        return self.rows_bnd_lvl[self._two_level("rows_bnd_inter", 1)]

    @property
    def cols_bnd_inter(self):
        return self.cols_bnd_lvl[self._two_level("cols_bnd_inter", 1)]

    @property
    def vals_bnd_inter(self):
        return self.vals_bnd_lvl[self._two_level("vals_bnd_inter", 1)]


# The two-level plan is the h == 2 TreePlan; the name is kept as the
# PR 3-4 API (isinstance checks and imports continue to work).
HierPlan = TreePlan


def _class_schedule(t_pair: np.ndarray, t_v: np.ndarray, k: int,
                    q_of: np.ndarray, nq: int, rank_in_block: np.ndarray):
    """Schedule one halo class (intra- or inter-pod) of directed-pair
    triples.

    ``t_pair`` (sorted ``recv*k + own`` keys; triples within a pair sorted
    by vertex) is grouped into pair runs; the class's quotient graph —
    nodes ``q_of[block]`` (local pu index for intra, global block id for
    inter), so intra edges from *different pods* with the same local
    endpoints merge into one colored edge and share a ppermute pair — is
    Misra-Gries edge-colored; the owner-side send schedule and per-triple
    halo slots fall out of (color, position-in-pair).

    Returns ``(S, n_rounds, send_idx, send_mask, round_pairs, slot)`` with
    ``slot`` the *relative* slot ``color * S + pos`` per triple and
    ``round_pairs[c]`` the bidirectional quotient-node pairs of round c.
    """
    m = len(t_pair)
    newp = np.empty(m, dtype=bool)
    if m:
        newp[0] = True
        np.not_equal(t_pair[1:], t_pair[:-1], out=newp[1:])
    grp_first = np.flatnonzero(newp)
    uniq_pairs = t_pair[grp_first].astype(np.int64)
    pair_counts = np.diff(np.append(grp_first, m))
    pair_of_trip = np.cumsum(newp) - 1
    t_pos = np.arange(m) - grp_first[pair_of_trip] if m else np.zeros(0, int)
    S = max(1, int(pair_counts.max()) if len(pair_counts) else 1)

    p_recv, p_own = uniq_pairs // k, uniq_pairs % k
    q_r, q_o = q_of[p_recv], q_of[p_own]
    und_key = np.minimum(q_r, q_o) * nq + np.maximum(q_r, q_o)
    uniq_und, und_inv = np.unique(und_key, return_inverse=True)
    und_a, und_b = uniq_und // nq, uniq_und % nq
    und_w = np.zeros(len(uniq_und), dtype=np.float64)
    np.add.at(und_w, und_inv, pair_counts)
    qp = np.stack([und_a, und_b], axis=1).astype(np.int64)
    colors = (vizing_edge_coloring(qp, und_w) if len(qp)
              else np.zeros(0, np.int32))
    n_rounds = int(colors.max() + 1) if len(colors) else 0
    color_dir = np.zeros(nq * nq, dtype=np.int32)
    color_dir[und_a * nq + und_b] = colors
    color_dir[und_b * nq + und_a] = colors
    t_color = (color_dir[q_of[(t_pair.astype(np.int64)) // k] * nq
                         + q_of[t_pair.astype(np.int64) % k]]
               if m else np.zeros(0, np.int32))

    send_idx = np.zeros((k, n_rounds, S), dtype=np.int32)
    send_mask = np.zeros((k, n_rounds, S), dtype=np.float32)
    t_own = (uniq_pairs % k)[pair_of_trip] if m else np.zeros(0, int)
    send_idx[t_own, t_color, t_pos] = rank_in_block[t_v]
    send_mask[t_own, t_color, t_pos] = 1.0
    round_pairs: list[list[tuple[int, int]]] = [[] for _ in range(n_rounds)]
    pair_color = color_dir[und_a * nq + und_b]
    for a, b, c in zip(und_a.tolist(), und_b.tolist(), pair_color.tolist()):
        round_pairs[c].append((a, b))
        round_pairs[c].append((b, a))
    slot = (t_color * S + t_pos).astype(np.int32)
    return (S, n_rounds, send_idx, send_mask,
            tuple(tuple(r) for r in round_pairs), slot)


def _derive_tree_fields_np(rows_a: np.ndarray, cols_a: np.ndarray,
                           vals_a: np.ndarray, per_blk: np.ndarray,
                           B: int, offs: np.ndarray) -> dict:
    """NumPy core of :func:`_derive_tree_fields` — host arrays only.

    Besides the packed segments it returns the per-edge segment
    bookkeeping (``seg_lvl``/``seg_pos``/``seg_counts``, ``row_lvl`` and
    the diagonal entry positions) that :mod:`repro.sparse.replan` uses to
    patch segments in place instead of re-deriving all blocks.
    """
    k, nnz_pad = rows_a.shape
    h = len(offs) - 1
    per_blk = np.asarray(per_blk, dtype=np.int64)
    valid = np.arange(nnz_pad)[None, :] < per_blk[:, None]
    # per-edge slot level: -1 local, l for cols in [offs[l], offs[l+1])
    edge_lvl = np.searchsorted(np.asarray(offs), cols_a, side="right") - 1
    edge_lvl = np.where(valid, edge_lvl, -1)
    # per-row highest level read
    row_lvl = np.full((k, B), -1, dtype=np.int64)
    bi, ei = np.nonzero(valid)
    np.maximum.at(row_lvl, (bi, rows_a[bi, ei]), edge_lvl[bi, ei])

    blk_col = np.arange(k)[:, None]
    row_lvl_of_edge = row_lvl[blk_col, rows_a]
    # per-edge segment (-2 padding, -1 interior, l = boundary level) and
    # the edge's packed position inside that segment
    seg_lvl = np.where(valid, row_lvl_of_edge, -2).astype(np.int8)
    seg_pos = np.zeros((k, nnz_pad), dtype=np.int32)
    seg_counts = np.zeros((h + 1, k), dtype=np.int64)
    segs = []
    for s in range(-1, h):
        sel = valid & (row_lvl_of_edge == s)
        counts = sel.sum(axis=1)
        seg_counts[s + 1] = counts
        pad = max(int(counts.max()) if k else 0, 1)
        pos = np.cumsum(sel, axis=1) - 1
        b, e = np.nonzero(sel)
        p = pos[b, e]
        seg_pos[b, e] = p.astype(np.int32)
        r = np.zeros((k, pad), dtype=np.int32)
        c = np.zeros((k, pad), dtype=np.int32)
        v = np.zeros((k, pad), dtype=np.float32)
        r[b, p] = rows_a[b, e]
        c[b, p] = cols_a[b, e]
        v[b, p] = vals_a[b, e]
        segs.append((r, c, v))

    diag = np.zeros((k, B), dtype=np.float32)
    on_diag = valid & (rows_a == cols_a)
    db, de = np.nonzero(on_diag)
    np.add.at(diag, (db, rows_a[db, de]), vals_a[db, de])
    return dict(
        int_seg=segs[0], lvl_segs=segs[1:], diag=diag,
        nnz_blk=per_blk.copy(), row_lvl=row_lvl,
        seg_lvl=seg_lvl, seg_pos=seg_pos, seg_counts=seg_counts,
        diag_b=db, diag_e=de,
    )


def _derive_tree_fields(rows_a: np.ndarray, cols_a: np.ndarray,
                        vals_a: np.ndarray, per_blk: np.ndarray,
                        B: int, offs: np.ndarray) -> dict:
    """(h+1)-way interior / per-level boundary split.

    A row's class is the *highest* slot level any of its edges reads
    (``offs`` are the level-range boundaries, ``offs[0] == B``; reads
    below B are local).  Every edge of a row goes to the row's segment,
    so the h+1 segments exactly tile the true nnz set and the PR 2
    boundary set is the union of the level segments.  The interior
    criterion (no halo reads at all) is identical to the flat plan's, so
    the interior segment is bit-equal to :func:`build_plan`'s on the
    same partition; at ``h == 2`` the level segments are exactly PR 3's
    intra-/inter-pod split.  The ``_host`` entry carries the NumPy core's
    raw output for the replan cache (popped by :func:`build_plan_tree`).
    """
    host = _derive_tree_fields_np(rows_a, cols_a, vals_a, per_blk, B, offs)
    rows_int, cols_int, vals_int = host["int_seg"]
    lvl_seg = host["lvl_segs"]
    return dict(
        rows_int=jnp.asarray(rows_int), cols_int=jnp.asarray(cols_int),
        vals_int=jnp.asarray(vals_int),
        rows_bnd_lvl=tuple(jnp.asarray(r) for r, _, _ in lvl_seg),
        cols_bnd_lvl=tuple(jnp.asarray(c) for _, c, _ in lvl_seg),
        vals_bnd_lvl=tuple(jnp.asarray(v) for _, _, v in lvl_seg),
        diag=jnp.asarray(host["diag"]), nnz_blk=host["nnz_blk"],
        _bnd_row=host["row_lvl"] >= 0,
        _host=host,
    )


def build_plan_tree(indptr: np.ndarray, indices: np.ndarray,
                    data: np.ndarray, part: np.ndarray,
                    tree, k: int, fanouts=None,
                    validate: bool | None = None,
                    cache: bool = True) -> TreePlan:
    """Build the arbitrary-depth distributed plan for a tree mesh.

    ``tree`` is anything ``core.topology.normalize_tree_of`` accepts: a
    pod count or (k,) pod array (the two-level instance), an explicit
    (h-1, k) ancestor table — e.g. the partition-derived table of
    ``core.api.partition_tree`` / ``tree_assignment_for`` (generally
    non-contiguous after the per-level sweeps) — or ``None`` with
    ``fanouts`` for the canonical contiguous grouping.  Every level must
    group blocks equally (the tree meshes are rectangular).  Blocks are
    relabeled tree-major (lexicographic by ancestor path); ``block_map``
    maps the caller's block ids to device positions (scatter/gather are
    unaffected — they go through ``perm``).

    Each tree level gets its own Misra-Gries coloring of its quotient
    graph over *suffix* indices (the last ``level + 1`` mixed-radix
    digits), so one ppermute schedule over the level's axis suffix fires
    in every subtree at once; the outermost level linearizes the full
    axis tuple.  Vectorized NumPy throughout; the only Python loops are
    over tree levels, quotient edges and chunks, as in
    :func:`build_plan`.
    """
    from ..core.topology import normalize_tree_of

    n = len(indptr) - 1
    part = np.ascontiguousarray(part, dtype=np.int32)
    # one validation definition shared with the partitioner side
    # (core.api.partition_tree produces what this consumes)
    anc_in = normalize_tree_of(tree, k, fanouts)
    h = anc_in.shape[0] + 1
    # tree-major relabeling: device position = leaf slot of the mixed
    # radix — stable lexicographic by ancestor path (top row primary),
    # the depth-h generalization of build_plan_hier's pod-major argsort
    order_blocks = (np.lexsort(tuple(anc_in[::-1])) if h > 1
                    else np.arange(k, dtype=np.int64))
    block_map = np.empty(k, dtype=np.int64)
    block_map[order_blocks] = np.arange(k)
    part = block_map[part].astype(np.int32)
    # canonical table / fanouts of the relabeled (device-position) blocks
    counts = [int(anc_in[t].max()) + 1 for t in range(h - 1)] + [k]
    fanouts_out, prev = [], 1
    for c in counts:
        fanouts_out.append(c // prev)
        prev = c
    fanouts_out = tuple(fanouts_out)
    # suffix size of level l = prod(fanouts[h-1-l:]): the range its
    # quotient nodes (and ppermute indices) live in
    suffix = [1] * (h + 1)
    for t in range(h - 1, -1, -1):
        suffix[h - 1 - t + 1] = suffix[h - 1 - t] * fanouts_out[t]
    dev = np.arange(k, dtype=np.int64)
    anc_dev = np.stack([dev // suffix[h - 1 - t]
                        for t in range(h - 1)]) if h > 1 else \
        np.zeros((0, k), dtype=np.int64)

    dense = k * n <= DENSE_PLAN_LIMIT
    sizes, B, order, rank_in_block, perm, block_of = _block_layout(
        part, k, dense=dense)

    # ---- halo triples, split by LCA level -------------------------------
    # same dense/vertex-sharded bitmap extraction as build_plan (one
    # definition, DENSE_PLAN_LIMIT respected), then triples ordered by
    # (directed pair, vertex) via the stable radix pass
    src, dst = _edge_endpoints(indptr, indices)
    psrc, pdst = part[src], part[dst]
    ext = psrc != pdst
    flat, ext_keys = _halo_recv_v_pairs(part, psrc, dst, ext, k, n, dense)
    flat_sorted = None if dense else flat              # ascending (recv, v)
    t_v_pre = flat % n
    t_pair_pre = ((flat // n).astype(np.int64) * k
                  + part[t_v_pre].astype(np.int64))    # recv*k + own
    o2 = np.argsort(t_pair_pre, kind="stable")         # keeps v ascending
    t_pair_all = t_pair_pre[o2]
    t_v_all = t_v_pre[o2]
    flat_post = flat[o2]
    # LCA level per triple: highest level whose suffix indices differ
    t_recv, t_own = t_pair_all // k, t_pair_all % k
    t_lvl = np.zeros(len(t_pair_all), dtype=np.int64)
    for l in range(h):
        differ = (t_recv // suffix[l]) != (t_own // suffix[l])
        t_lvl = np.where(differ, l, t_lvl)

    S_lvl, R_lvl, si_lvl, sm_lvl, perms_lvl = [], [], [], [], []
    slot_of_trip = np.empty(len(t_pair_all), dtype=np.int32)
    off = B
    for l in range(h):
        sel = t_lvl == l
        sz = suffix[l + 1]
        S_l, R_l, si, sm, perms, slot = _class_schedule(
            t_pair_all[sel], t_v_all[sel], k, dev % sz, sz, rank_in_block)
        slot_of_trip[sel] = off + slot
        off += R_l * S_l
        S_lvl.append(S_l)
        R_lvl.append(R_l)
        si_lvl.append(si)
        sm_lvl.append(sm)
        perms_lvl.append(perms)
    offs = B + np.concatenate(
        [[0], np.cumsum([r * s for r, s in zip(R_lvl, S_lvl)])]).astype(int)

    # ---- local matrix in padded-COO (same packing as build_plan) --------
    rows_l = rank_in_block[src]
    cols_l = rank_in_block[dst]
    cols_l[ext] = _ext_col_slots(flat_post, flat_sorted, o2, slot_of_trip,
                                 ext_keys, k, n, dense)
    own = psrc
    per_blk = np.bincount(own, minlength=k)
    rows_a, cols_a, vals_a, pos_edge = _pack_local_coo(
        indptr, src, data, part, order, k, rows_l, cols_l, per_blk)

    row_mask = (np.arange(B)[None, :] < sizes[:, None]).astype(np.float32)

    split = _derive_tree_fields(rows_a, cols_a, vals_a, per_blk, B, offs)
    bnd_row = split.pop("_bnd_row")
    host_split = split.pop("_host")
    interior_mask = row_mask * ~bnd_row

    # host-side intermediates for O(delta) patching (sparse/replan.py).
    # ``cache=False`` drops them (saves ~2x host memory for static
    # matrices); a canonical sorted CSR is required for patching, so a
    # non-canonical input simply gets no cache instead of failing.
    replan_cache = None
    if cache:
        from .replan import capture_replan_cache
        replan_cache = capture_replan_cache(
            indptr=np.asarray(indptr), indices=dst,
            data=np.asarray(data), src=src,
            part=part, order=order, rank_in_block=rank_in_block,
            sizes=sizes, B=B, k=k, n=n, fanouts=fanouts_out,
            suffix=tuple(suffix), flat=flat, o2=o2, ext=ext,
            ext_keys=ext_keys, psrc=psrc,
            t_pair=t_pair_all, t_v=t_v_all, t_lvl=t_lvl,
            slot_of_trip=slot_of_trip, offs=offs,
            rows_a=rows_a, cols_a=cols_a, vals_a=vals_a,
            per_blk=per_blk, pos_edge=pos_edge,
            row_mask=row_mask, host=host_split)

    return _maybe_verify(TreePlan(
        k=k, B=B, S=max(S_lvl), n_rounds=sum(R_lvl), n=n, perm=perm,
        block_of=block_of, sizes=sizes,
        rows=jnp.asarray(rows_a), cols=jnp.asarray(cols_a),
        vals=jnp.asarray(vals_a), row_mask=jnp.asarray(row_mask),
        send_idx=None, send_mask=None, round_perms=(),
        interior_mask=jnp.asarray(interior_mask), **split,
        fanouts=fanouts_out, anc=anc_dev, block_map=block_map,
        S_lvl=tuple(S_lvl), n_rounds_lvl=tuple(R_lvl),
        send_idx_lvl=tuple(jnp.asarray(a) for a in si_lvl),
        send_mask_lvl=tuple(jnp.asarray(a) for a in sm_lvl),
        round_perms_lvl=tuple(perms_lvl),
        _pack_blk=own, _pack_pos=pos_edge, _pack_dst=dst,
        _replan=replan_cache,
    ), validate)


def build_plan_hier(indptr: np.ndarray, indices: np.ndarray,
                    data: np.ndarray, part: np.ndarray,
                    pods, k: int, validate: bool | None = None) -> TreePlan:
    """Build the two-level distributed plan for a multi-pod mesh — the
    ``h == 2`` instance of :func:`build_plan_tree` (kept as the PR 3-4
    API).

    ``pods`` is either the pod count (blocks are grouped contiguously —
    block b goes to pod ``b // (k // pods)``, matching
    ``core.topology.Topology.pod_assignment``: Algorithm-1 orders fast PUs
    first, so the fast PUs that share the heaviest cut land in one pod) or
    an explicit (k,) pod id per block — e.g. the partition-derived
    assignment of ``core.api.partition_hier`` / ``pod_assignment_for``
    (generally non-contiguous after the pod-level sweep).  Pods must be
    equal-sized (the mesh is rectangular).
    """
    from ..core.topology import normalize_pod_of

    # one validation definition shared with the partitioner side
    pod_of_block = normalize_pod_of(pods, k)
    return build_plan_tree(indptr, indices, data, part,
                           pod_of_block[None, :], k, validate=validate)


# --------------------------------------------------------------------------
# shard_map programs
# --------------------------------------------------------------------------
#
# Every per-device function below is *rank-polymorphic* over a trailing
# RHS-batch axis: x_loc may be (B,) or (B, nb) and the same gather /
# scatter-add / ppermute schedule carries the extra axis through (vmap
# cannot cross the ppermute rounds on every supported JAX, so the batch
# axis is threaded natively instead).  Per-row weights ((S,) send masks,
# (nnz,) values, (B,) row masks) are aligned with a batched operand via
# :func:`_bcol`.


def _bcol(m, x):
    """Align a per-row weight/mask with ``x``'s trailing RHS-batch axes:
    (s,) against (s, nb) -> (s, 1) so NumPy broadcasting applies the
    weight to every column."""
    return m.reshape(m.shape + (1,) * (x.ndim - m.ndim))


def _halo_exchange(plan: DistPlan, x_loc, send_idx, send_mask, axis: str):
    """x_loc: (B,) or (B, nb).  Returns the (B + R*S[, nb]) extended
    vector."""
    bufs = []
    for c in range(plan.n_rounds):
        buf = x_loc[send_idx[c]] * _bcol(send_mask[c], x_loc)  # (S[, nb])
        perm = plan.round_perms[c]
        if perm:
            buf = jax.lax.ppermute(buf, axis, perm)
        else:
            buf = jnp.zeros_like(buf)
        bufs.append(buf)
    return jnp.concatenate([x_loc] + bufs)


def _hier_exchange(plan: HierPlan, x_loc, send_idx, send_mask, axes,
                   perms, n_rounds):
    """One class of hier rounds: returns the per-round (S[, nb]) buffers.

    ``axes`` is the ppermute axis spec — the intra-pod axes (fast links;
    the shared local-index schedule fires in every pod, masked zeros where
    a pod lacks the edge) or the full (pod, *intra) tuple with linearized
    device indices (inter-pod, slow links).
    """
    bufs = []
    for c in range(n_rounds):
        buf = x_loc[send_idx[c]] * _bcol(send_mask[c], x_loc)
        perm = perms[c]
        if perm:
            buf = jax.lax.ppermute(buf, axes, perm)
        else:
            buf = jnp.zeros_like(buf)
        bufs.append(buf)
    return bufs


COMM_MODES = ("halo", "halo_seq", "allgather", "hier")
LOCAL_FORMATS = ("coo", "bell")


def _validate_tree_axes(plan: "TreePlan", mesh: Mesh, axis) -> None:
    """Check that the mesh's trailing axes actually hold the plan's tree:
    level ``l`` ppermutes over ``axes[h-1-l:]`` with suffix-linearized
    indices, so the *product of those axis sizes* must equal the plan's
    level-``l`` suffix size ``prod(fanouts[h-1-l:])`` — an axis tuple
    that merely has enough entries but the wrong shape would deliver
    halo words to the wrong devices silently.

    Delegates to the reusable ``repro.analysis.check_mesh_axes`` pass
    (MESH0xx diagnostics) and raises ``ValueError`` on any violation, the
    historical contract of this hook.
    """
    from ..analysis import check_mesh_axes      # lazy: keep import acyclic
    check_mesh_axes(plan, mesh, tuple(axis)).raise_for_errors()


def abstract_mesh_for(plan: DistPlan, axis: str | tuple = "pu"):
    """Device-free mesh shaped for ``plan``'s schedule (trace entry hook).

    Returns a ``compat.abstract_mesh`` whose axis names/sizes match what
    :func:`make_dist_spmv` / :func:`make_dist_cg` expect for this plan, so
    the solver programs can be traced (``jax.make_jaxpr``) and audited on
    a machine with no devices — the entry point used by
    ``repro.analysis.trace``.

    Flat plans get a single ``axis`` of size ``k``.  Tree plans get one
    axis per level (``launch.mesh.tree_axis_names`` by default, or the
    explicit ``axis`` tuple), outermost first; when more axes than levels
    are named, the extra leading axes get size 1 — they fold into the
    outermost level exactly as on a concrete mesh.
    """
    from .. import compat
    if isinstance(plan, TreePlan):
        if axis == "pu":
            from ..launch.mesh import tree_axis_names
            names = tree_axis_names(max(plan.h, 2))
        else:
            names = tuple(axis)
        fanouts = plan.fanouts
        if len(names) > len(fanouts):
            fanouts = (1,) * (len(names) - len(fanouts)) + tuple(fanouts)
        return compat.abstract_mesh(dict(zip(names, fanouts)))
    name = axis if isinstance(axis, str) else tuple(axis)[0]
    return compat.abstract_mesh({name: plan.k})


def _local_matvec_builder(plan: DistPlan, comm: str, axis: str,
                          local_format: str = "coo"):
    """Shared per-device matvec for every comm/format combination.

    Returns ``(consts, fn)``: ``consts`` is a tuple of (k, ...) arrays to be
    sharded one-block-per-device, and ``fn(local_consts, x_loc)`` computes
    y_loc = (A @ x)_loc on already-squeezed per-device slices.  Both
    :func:`make_dist_spmv` and the fused :func:`make_dist_cg` build on it.
    ``consts`` always ends with ``plan.row_mask`` so the fused CG can read
    the mask for its psum dots without shipping a duplicate operand.

    ``comm='halo'`` is the *overlapped* schedule: the interior matvec
    (``plan.rows_int`` — rows touching no halo slot) is issued before the
    colored ppermute rounds, so XLA can run it concurrently with the
    exchange; boundary rows accumulate afterward from the extended vector.
    ``comm='halo_seq'`` keeps the PR-1 sequential schedule (exchange all
    rounds, then one full matvec) as the non-overlapped reference.
    ``local_format='bell'`` runs the interior matvec through the Pallas
    block-ELL kernel (kernels/spmv_bell.py) instead of the COO scatter-add
    — ROADMAP's third comm/format combination.

    ``comm='hier'`` is the three-stage multi-pod schedule and requires a
    :class:`HierPlan` plus a *tuple* ``axis`` ``(pod_axis, *intra_axes)``:
    interior matvec first, then intra-pod ppermute rounds over the fast
    intra axes and inter-pod rounds over the combined axes — the
    intra-pod boundary accumulation depends only on the fast rounds, so
    it overlaps with the slow inter-pod exchange.
    """
    if comm not in COMM_MODES:
        raise ValueError(f"unknown comm mode {comm!r}; choose {COMM_MODES}")
    if local_format not in LOCAL_FORMATS:
        raise ValueError(f"unknown local format {local_format!r}; "
                         f"choose {LOCAL_FORMATS}")
    if local_format == "bell" and comm not in ("halo", "hier"):
        raise ValueError("local_format='bell' requires comm='halo' or "
                         "'hier' (the interior/boundary split the kernel "
                         "is built from)")
    if isinstance(plan, TreePlan) != (comm == "hier"):
        raise ValueError(
            "comm='hier' requires a TreePlan (build_plan_tree / "
            "build_plan_hier) and a TreePlan only runs under comm='hier' "
            "— its halo layout has separate per-level slot ranges that "
            f"the flat schedules cannot address (got comm={comm!r}, "
            f"plan={type(plan).__name__})")
    B = plan.B

    if comm == "hier":
        h = plan.h
        if isinstance(axis, str) or len(tuple(axis)) < max(h, 2):
            raise ValueError(f"comm='hier' on a depth-{h} plan needs "
                             f"axis=(outer_axis, ..., inner_axis) with "
                             f">= {max(h, 2)} mesh axes; got {axis!r}")
        axes = tuple(axis)

        def level_axes(l: int):
            # level l ppermutes over the axis suffix holding its
            # mixed-radix digits; extra leading mesh axes fold into the
            # outermost level (axes[0:] for l == h-1)
            sub = axes[h - 1 - l:]
            return sub[0] if len(sub) == 1 else sub

        if local_format == "bell":
            head = plan.bell_local()
        else:
            head = (plan.rows_int, plan.cols_int, plan.vals_int)
        consts = head
        for l in range(h):
            consts = consts + (plan.rows_bnd_lvl[l], plan.cols_bnd_lvl[l],
                               plan.vals_bnd_lvl[l])
        for l in range(h):
            consts = consts + (plan.send_idx_lvl[l], plan.send_mask_lvl[l])
        consts = consts + (plan.row_mask,)

        n_head = len(head)

        def fn(c, x):
            bnd = c[n_head:n_head + 3 * h]
            sends = c[n_head + 3 * h:n_head + 5 * h]
            row_mask = c[-1]
            # stage 1: interior matvec — no halo dependence at all
            if local_format == "bell":
                if x.ndim > 1:
                    raise ValueError(
                        "local_format='bell' is single-RHS (the Pallas "
                        "block-ELL kernel is a vector kernel); use "
                        "local_format='coo' for batched solves")
                from ..kernels.spmv_bell import spmv_block_ell
                y = spmv_block_ell(c[0], c[1], x)
            else:
                ri, ci, vi = c[:3]
                y = jnp.zeros((B,) + x.shape[1:], x.dtype).at[ri].add(
                    _bcol(vi, x) * x[ci])
            # stage 2: issue every level's rounds, *outermost first* —
            # each slower exchange is in flight while all faster levels'
            # rounds and accumulations (and the interior matvec) run
            bufs: list = [None] * h
            for l in range(h - 1, -1, -1):
                bufs[l] = _hier_exchange(plan, x, sends[2 * l],
                                         sends[2 * l + 1], level_axes(l),
                                         plan.round_perms_lvl[l],
                                         plan.n_rounds_lvl[l])
            # stage 3: accumulate innermost first — a level's rows read
            # only its own and faster levels' slots, so each
            # accumulation waits on nothing slower than itself
            x_ext = x
            for l in range(h):
                if bufs[l]:
                    x_ext = jnp.concatenate([x_ext] + bufs[l])
                rl, cl, vl = bnd[3 * l:3 * l + 3]
                y = y.at[rl].add(_bcol(vl, x) * x_ext[cl])
            return y * _bcol(row_mask, y)

        return consts, fn

    if comm == "allgather":
        consts = (plan.rows, plan.cols_global, plan.vals, plan.row_mask)

        def fn(c, x):
            rows, cols, vals, row_mask = c
            x_all = jax.lax.all_gather(x, axis)               # (k, B[, nb])
            x_all = x_all.reshape((-1,) + x.shape[1:])        # (k*B[, nb])
            y = jnp.zeros((B,) + x.shape[1:], x.dtype).at[rows].add(
                _bcol(vals, x) * x_all[cols])
            return y * _bcol(row_mask, y)

        return consts, fn

    if comm == "halo_seq":
        consts = (plan.rows, plan.cols, plan.vals, plan.send_idx,
                  plan.send_mask, plan.row_mask)

        def fn(c, x):
            rows, cols, vals, send_idx, send_mask, row_mask = c
            x_ext = _halo_exchange(plan, x, send_idx, send_mask, axis)
            y = jnp.zeros((B,) + x.shape[1:], x.dtype).at[rows].add(
                _bcol(vals, x) * x_ext[cols])
            return y * _bcol(row_mask, y)

        return consts, fn

    # comm == "halo": overlapped interior/boundary schedule
    bnd = (plan.rows_bnd, plan.cols_bnd, plan.vals_bnd)
    tail = (plan.send_idx, plan.send_mask, plan.row_mask)
    if local_format == "coo":
        consts = (plan.rows_int, plan.cols_int, plan.vals_int) + bnd + tail

        def fn(c, x):
            ri, ci, vi, rb, cb, vb, send_idx, send_mask, row_mask = c
            # interior first: no halo dependence, overlaps the ppermutes
            y = jnp.zeros((B,) + x.shape[1:], x.dtype).at[ri].add(
                _bcol(vi, x) * x[ci])
            x_ext = _halo_exchange(plan, x, send_idx, send_mask, axis)
            y = y.at[rb].add(_bcol(vb, x) * x_ext[cb])
            return y * _bcol(row_mask, y)

        return consts, fn

    blocks, bcols = plan.bell_local()

    def fn(c, x):
        from ..kernels.spmv_bell import spmv_block_ell
        if x.ndim > 1:
            raise ValueError(
                "local_format='bell' is single-RHS (the Pallas block-ELL "
                "kernel is a vector kernel); use local_format='coo' for "
                "batched solves")
        blk, bc, rb, cb, vb, send_idx, send_mask, row_mask = c
        y = spmv_block_ell(blk, bc, x)                     # interior rows
        x_ext = _halo_exchange(plan, x, send_idx, send_mask, axis)
        y = y.at[rb].add(vb * x_ext[cb])
        return y * row_mask

    return (blocks, bcols) + bnd + tail, fn


def make_dist_spmv(plan: DistPlan, mesh: Mesh, axis: str = "pu",
                   comm: str = "halo",
                   local_format: str = "coo") -> Callable:
    """Returns jit'd y = A @ x on (k, B) block-major vectors.

    ``comm='halo'`` (default) overlaps the interior matvec with the
    edge-colored ppermute rounds; ``comm='halo_seq'`` is the sequential
    reference schedule; ``comm='allgather'`` gathers the whole padded
    vector (the partitioner-oblivious baseline); ``comm='hier'`` is the
    per-tree-level schedule (needs a :class:`TreePlan` and
    ``axis=(outer_axis, ..., inner_axis)`` whose trailing-axis products
    match the plan's fanouts suffixes).  ``local_format='bell'`` runs
    the interior matvec through the Pallas block-ELL kernel.
    """
    consts, local_fn = _local_matvec_builder(plan, comm, axis, local_format)
    if comm == "hier":
        _validate_tree_axes(plan, mesh, axis)

    def prog(*args):
        *cs, x = args
        return local_fn(tuple(c[0] for c in cs), x[0])[None]

    spec = P(axis if isinstance(axis, str) else tuple(axis))
    fn = shard_map(prog, mesh=mesh,
                   in_specs=(spec,) * (len(consts) + 1), out_specs=spec)

    @jax.jit
    def spmv(x):
        return fn(*consts, x)

    return spmv


def make_dist_cg(plan: DistPlan, mesh: Mesh, axis: str = "pu",
                 tol: float = 1e-6, max_iters: int = 500,
                 comm: str = "halo", local_format: str = "coo",
                 precondition: str | None = None) -> Callable:
    """Whole-CG SPMD program: the while_loop runs inside shard_map; dot
    products are psum-reduced local dots; the matvec comes from
    :func:`_local_matvec_builder` — overlapped halo rounds (``'halo'``),
    the sequential schedule (``'halo_seq'``), or the full-vector
    all_gather baseline (``'allgather'``), with the interior matvec in
    padded-COO or Pallas block-ELL (``local_format``).

    ``precondition='jacobi'`` switches the body to preconditioned CG with
    M = diag(A); the diagonal is already on-device in ``plan.diag``,
    extracted when the plan was built.  ``precondition='block_jacobi'``
    uses the per-PU diagonal blocks instead (M = blockdiag(A_bb), applied
    as one dense (B, B) matmul per device from the plan's cached
    inverses).  Convergence is still tested on the unpreconditioned
    residual ||r||^2 <= tol^2 ||b||^2, so preconditioned and
    unpreconditioned solves stop at the same solution quality.

    This is the fused fast path; the composable path is
    ``operator.DistributedOperator`` + the generic ``cg.cg_solve``."""
    if precondition not in (None, "jacobi", "block_jacobi"):
        raise ValueError(f"unknown precondition {precondition!r}")
    consts, local_fn = _local_matvec_builder(plan, comm, axis, local_format)
    if comm == "hier":
        _validate_tree_axes(plan, mesh, axis)
    prec_tail = ()
    if precondition == "jacobi":
        prec_tail = (plan.diag,)
    elif precondition == "block_jacobi":
        prec_tail = (plan.block_jacobi_inv(),)
    all_consts = consts + prec_tail

    def cg_local(*args):
        # one CG implementation for every program shape: the generic
        # cg.cg_solve is pure lax, so tracing it here (with a psum dot and
        # the local matvec) yields the fused whole-CG SPMD program.  A 2-D
        # per-device b carries the trailing RHS-batch axis — the local
        # matvec is batch-native (rank-polymorphic schedule), the psum dot
        # stays single-column (cg_solve vmaps it over columns), and the
        # whole multi-RHS masked loop runs inside this one shard_map body.
        *cs, b = args
        cs = tuple(c[0] for c in cs)
        b = b[0]
        prec = None
        if precondition == "jacobi":
            prec = jacobi_preconditioner(cs[-1])
            cs = cs[:-1]
        elif precondition == "block_jacobi":
            minv = cs[-1]                 # (B, B); ghost rows identity, and
            cs = cs[:-1]                  # ghost residuals are exactly zero
            prec = lambda r: minv @ r
        row_mask = cs[-1]                 # builder contract: always last

        def dot(u, v):
            return jax.lax.psum(jnp.vdot(u * row_mask, v), axis)

        mv = lambda x: local_fn(cs, x)
        mv.batch_native = True
        res = cg_solve(mv, b, tol=tol,
                       max_iters=max_iters, dot=dot, precondition=prec,
                       batched=b.ndim == 2)
        return res.x[None], res.residual[None], res.iters[None]

    spec = P(axis if isinstance(axis, str) else tuple(axis))
    fn = shard_map(cg_local, mesh=mesh,
                   in_specs=(spec,) * (len(all_consts) + 1),
                   out_specs=(spec, spec, spec))

    @jax.jit
    def solve(b):
        x, res, it = fn(*all_consts, b)
        return x, res[0], it[0]

    return solve
