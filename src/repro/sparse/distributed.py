"""Distributed SpMV / CG over a heterogeneous partition — shard_map version
of the paper's application layer (Sec. VI-a: SpMV and CG on the Laplacian,
distributed according to the partition produced by the respective tool).

MPI-rank-per-PU becomes one mesh index per block.  Because XLA SPMD shards
are uniform, each block is padded to B = max block size; `row_mask` marks
real rows.  The padding waste is exactly the heterogeneity spread: with
Algorithm-1 target sizes the fast PUs own the largest blocks, so B equals
the largest tw and slow PUs carry ghost rows.  (On a real heterogeneous
machine the fast PU also *is* faster, so wall-clock stays balanced — the
simulated-speed benchmark in benchmarks/bench_cg.py models this.)

Halo exchange: the quotient graph of the partition is edge-colored
(core.refinement.vizing_edge_coloring, Misra-Gries: <= Delta+1 rounds on
quotient degree Delta) and each color class becomes one
`lax.ppermute` round — at most one partner per device per round, the exact
communication schedule Geographer-R uses for its pairwise refinement.  The
halo buffer layout is (rounds, S) with stable slots, so column indices are
remapped once on the host.

Both exchange strategies are provided:
  * ``halo``       — ppermute rounds, comm volume = O(boundary)  [default]
  * ``allgather``  — all_gather of the whole padded vector, comm volume
                     = O(n); the baseline a partitioner-oblivious system
                     would use.  The benchmark compares the two.

Plan construction (:func:`build_plan`) is fully vectorized NumPy —
``searchsorted`` / ``unique`` / fancy-index scatter; the only Python loops
are over quotient-graph edges (O(k^2), k = #PUs), never over vertices or
matrix entries.  The seed's per-edge implementation is preserved as
:func:`build_plan_reference` and serves as the correctness oracle in
tests/test_dist_plan.py and the speedup baseline in benchmarks/bench_cg.py.

Both plan builders produce *identical* plans (bit-equal arrays), so the
ppermute schedule and halo slot layout are stable across the rewrite.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from ..core.refinement import vizing_edge_coloring


@dataclasses.dataclass
class DistPlan:
    """Host-built plan + device arrays for the distributed operator.

    All arrays carry a leading block axis of size k and are sharded
    one-block-per-device by the shard_map programs below.
    """

    k: int
    B: int                      # padded rows per block
    S: int                      # padded halo slots per round
    n_rounds: int
    n: int                      # true global size
    perm: np.ndarray            # old vertex id -> padded new id (blk*B+rank)
    block_of: np.ndarray        # (k,) first padded id of each block
    sizes: np.ndarray           # (k,) true rows per block
    # device data
    rows: jnp.ndarray           # (k, nnz_pad) int32 local row
    cols: jnp.ndarray           # (k, nnz_pad) int32 local col in [0, B+R*S)
    vals: jnp.ndarray           # (k, nnz_pad) f32
    row_mask: jnp.ndarray       # (k, B) f32
    send_idx: jnp.ndarray       # (k, R, S) int32 local indices to send
    send_mask: jnp.ndarray      # (k, R, S) f32
    round_perms: tuple          # per round: tuple of (src, dst) pairs
    # lazy allgather-mode columns: built on first access from the packing
    # order (only the allgather baseline needs them; halo mode never does)
    _pack_blk: np.ndarray = None      # (nnz,) owning block, packed order
    _pack_pos: np.ndarray = None      # (nnz,) slot within block
    _pack_dst: np.ndarray = None      # (nnz,) global dst vertex, packed order
    _cols_global: jnp.ndarray = None

    @property
    def cols_global(self) -> jnp.ndarray:
        """(k, nnz_pad) int32 columns in padded global ids (blk*B + rank)."""
        if self._cols_global is None:
            out = np.zeros(self.rows.shape, dtype=np.int32)
            out[self._pack_blk, self._pack_pos] = \
                self.perm[self._pack_dst].astype(np.int32)
            self._cols_global = jnp.asarray(out)
        return self._cols_global

    def scatter_vec(self, x: np.ndarray) -> np.ndarray:
        """(n,) global vector -> (k, B) padded block-major layout."""
        out = np.zeros((self.k, self.B), dtype=np.float32)
        out[self.perm // self.B, self.perm % self.B] = x
        return out

    def gather_vec(self, xb: np.ndarray) -> np.ndarray:
        """(k, B) -> (n,) global order."""
        return np.asarray(xb)[self.perm // self.B, self.perm % self.B]


def _edge_endpoints(indptr: np.ndarray, indices: np.ndarray):
    src = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
    return src, np.asarray(indices)


# build_plan uses O(k*n) dense tables (counting sorts) up to this many
# cells, and sort-based extraction beyond.  The widest live table is the
# int32 halo-slot map (4 B/cell; the bool bitmaps are freed before it is
# allocated), so the dense path peaks at ~64 MiB of transient tables at
# this limit.  Module-level so tests can force the fallback path.
DENSE_PLAN_LIMIT = 1 << 24


def build_plan(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
               part: np.ndarray, k: int) -> DistPlan:
    """Build the distributed plan for matrix (CSR) + partition — vectorized.

    O(nnz log nnz) in NumPy kernels (the log from sorts); no Python
    iteration over vertices, edges, or halo slots.
    """
    n = len(indptr) - 1
    part = np.ascontiguousarray(part, dtype=np.int32)
    sizes = np.bincount(part, minlength=k)
    B = int(sizes.max())
    # dense-table mode: O(k*n) bitmaps replace O(x log x) sorts wherever a
    # small-range counting sort suffices; fall back to sorts for huge k*n
    dense = k * n <= DENSE_PLAN_LIMIT
    # block-contiguous reordering: rank of each vertex within its block.
    # order = vertices sorted by (block, id) — a (k, n) one-hot flatnonzero
    # is that counting sort directly; argsort is the general fallback.
    if dense:
        onehot = np.zeros(k * n, dtype=bool)
        onehot[part.astype(np.int64) * n + np.arange(n)] = True
        order = np.flatnonzero(onehot) % n
        del onehot
    else:
        order = np.argsort(part, kind="stable")       # new (unpadded) -> old
    starts = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(sizes, out=starts[1:])
    rank_in_block = np.empty(n, dtype=np.int32)
    rank_in_block[order] = np.arange(n, dtype=np.int64) - starts[part[order]]
    perm = part.astype(np.int64) * B + rank_in_block   # padded new id
    block_of = np.arange(k, dtype=np.int64) * B

    # ---- halo triples: (receiver, owner, vertex), deduped & sorted -------
    # Two equivalent extraction paths (identical triple order — sorted by
    # (receiver, owner, vertex)):
    #   dense  — O(nnz + k*n): dedupe through a (k, n) needed-bitmap, then
    #            one radix argsort over the small-range pair keys.  Used
    #            when the bitmap fits comfortably (k*n <= 2^26 cells).
    #   sorted — O(E_ext log E_ext): np.unique over per-edge triple keys.
    #            Fallback for huge k*n where O(k*n) memory is not ok.
    src, dst = _edge_endpoints(indptr, indices)
    psrc, pdst = part[src], part[dst]
    ext = psrc != pdst
    # receiver = part[src] needs vertex dst owned by part[dst]
    if dense:
        needed = np.zeros(k * n, dtype=bool)
        # k*n <= 2^26 here, so (recv, v) keys always fit int32
        ext_keys = psrc[ext] * np.int32(n) + dst[ext]
        needed[ext_keys] = True
        flat = np.flatnonzero(needed)                  # sorted (recv, v)
        del needed
        t_v = flat % n
        # int16 pair keys: 1-2 radix passes in the stable argsort below
        pair_t = np.int16 if k * k <= np.iinfo(np.int16).max else np.int32
        t_pair = ((flat // n).astype(pair_t) * pair_t(k)
                  + part[t_v].astype(pair_t))          # recv*k + own
        o2 = np.argsort(t_pair, kind="stable")         # radix; keeps v asc
        t_pair, t_v, flat = t_pair[o2], t_v[o2], flat[o2]
        uniq_trip = trip_of_edge = None                # unused on this path
    else:
        key_t = np.int32 if k * k * n < np.iinfo(np.int32).max else np.int64
        pair_key_all = psrc * np.int32(k) + pdst
        trip_key_e = (pair_key_all[ext].astype(key_t) * key_t(n)
                      + dst[ext].astype(key_t))
        uniq_trip, trip_of_edge = np.unique(trip_key_e, return_inverse=True)
        t_pair = (uniq_trip // n).astype(np.int32)     # recv*k + own
        t_v = uniq_trip % n
    # triples sharing a (recv, own) pair are contiguous and sorted by v;
    # halo slot position = rank within the pair group.  t_pair is sorted,
    # so pair groups fall out of the boundary flags — no second unique/sort.
    m = len(t_pair)
    newp = np.empty(m, dtype=bool)
    if m:
        newp[0] = True
        np.not_equal(t_pair[1:], t_pair[:-1], out=newp[1:])
    grp_first = np.flatnonzero(newp)                   # triple idx per pair
    uniq_pairs = t_pair[grp_first]
    pair_counts = np.diff(np.append(grp_first, m))
    pair_of_trip = np.cumsum(newp) - 1
    t_pos = np.arange(m) - grp_first[pair_of_trip]
    S = int(pair_counts.max()) if len(pair_counts) else 1
    S = max(1, S)

    # ---- edge-color the undirected quotient graph ------------------------
    p_recv, p_own = uniq_pairs // k, uniq_pairs % k
    und_key = (np.minimum(p_recv, p_own) * k + np.maximum(p_recv, p_own))
    uniq_und = np.unique(und_key)
    und_a, und_b = uniq_und // k, uniq_und % k
    und_w = np.zeros(len(uniq_und), dtype=np.float64)
    np.add.at(und_w, np.searchsorted(uniq_und, und_key), pair_counts)
    qp = np.stack([und_a, und_b], axis=1).astype(np.int64)
    colors = (vizing_edge_coloring(qp, und_w) if len(qp)
              else np.zeros(0, np.int32))
    n_rounds = int(colors.max() + 1) if len(colors) else 1
    # (k, k) directed-pair -> round lookup (tiny), so per-triple color is a
    # single gather instead of min/max arithmetic over all triples
    color_dir = np.zeros(k * k, dtype=np.int32)
    color_dir[und_a * k + und_b] = colors
    color_dir[und_b * k + und_a] = colors
    t_color = color_dir[t_pair]

    # ---- send schedule (owner side) --------------------------------------
    # each color class is a matching, so an owner serves one receiver per
    # round: the (own, color, pos) scatter below has no collisions.
    send_idx = np.zeros((k, n_rounds, S), dtype=np.int32)
    send_mask = np.zeros((k, n_rounds, S), dtype=np.float32)
    t_own = (uniq_pairs % k)[pair_of_trip]        # owner of each triple
    send_idx[t_own, t_color, t_pos] = rank_in_block[t_v]
    send_mask[t_own, t_color, t_pos] = 1.0
    pair_color = color_dir[und_a * k + und_b]
    round_perms: list[list[tuple[int, int]]] = [[] for _ in range(n_rounds)]
    for a, b, c in zip(und_a.tolist(), und_b.tolist(), pair_color.tolist()):
        # o->r and r->o swap in the same round (bidirectional ppermute)
        round_perms[c].append((a, b))
        round_perms[c].append((b, a))

    # ---- local matrix in padded-COO with remapped columns ----------------
    rows_l = rank_in_block[src]
    # local rank everywhere, then overwrite external edges with halo slots
    cols_l = rank_in_block[dst]
    # halo slot of remote vertex u on receiver r: B + round*S + pos,
    # precomputed per triple so the per-edge remap is one gather
    slot_of_trip = (B + t_color * S + t_pos).astype(np.int32)
    if dense:
        slot_arr = np.empty(k * n, dtype=np.int32)     # (recv, v) -> slot
        slot_arr[flat] = slot_of_trip
        cols_l[ext] = slot_arr[ext_keys]
    else:
        cols_l[ext] = slot_of_trip[trip_of_edge]
    # pack edges per owning block (scatter, no per-block loop).  The slot of
    # edge e is derived from CSR structure in O(nnz) — no argsort: within a
    # block, edges are laid out by (owner rank, CSR order), exactly the
    # order a stable argsort over part[src] would give.
    own = psrc
    per_blk = np.bincount(own, minlength=k)
    nnz_pad = max(int(per_blk.max()) if len(per_blk) else 1, 1)
    deg = np.diff(indptr)
    deg_o = deg[order]
    # edge start of each vertex inside its block's packed segment
    vstart = np.empty(n, dtype=np.int64)
    blk_edge_start = np.cumsum(per_blk) - per_blk
    vstart[order] = (np.cumsum(deg_o) - deg_o) - blk_edge_start[part[order]]
    pos_edge = (vstart[src]
                + (np.arange(len(src)) - np.repeat(indptr[:-1], deg)))
    rows_a = np.zeros((k, nnz_pad), dtype=np.int32)
    cols_a = np.zeros((k, nnz_pad), dtype=np.int32)
    vals_a = np.zeros((k, nnz_pad), dtype=np.float32)
    rows_a[own, pos_edge] = rows_l
    cols_a[own, pos_edge] = cols_l
    vals_a[own, pos_edge] = data

    row_mask = (np.arange(B)[None, :] < sizes[:, None]).astype(np.float32)

    return DistPlan(
        k=k, B=B, S=S, n_rounds=n_rounds, n=n, perm=perm, block_of=block_of,
        sizes=sizes,
        rows=jnp.asarray(rows_a), cols=jnp.asarray(cols_a),
        vals=jnp.asarray(vals_a), row_mask=jnp.asarray(row_mask),
        send_idx=jnp.asarray(send_idx), send_mask=jnp.asarray(send_mask),
        round_perms=tuple(tuple(r) for r in round_perms),
        _pack_blk=own, _pack_pos=pos_edge, _pack_dst=dst,
    )


def build_plan_reference(indptr: np.ndarray, indices: np.ndarray,
                         data: np.ndarray, part: np.ndarray,
                         k: int) -> DistPlan:
    """The seed's per-edge plan builder, kept verbatim (modulo the removed
    dead ``loc`` placeholder) as the oracle for tests and the baseline for
    the vectorization speedup benchmark.  O(|halo|) Python iteration —
    do not use beyond toy meshes."""
    n = len(indptr) - 1
    part = np.asarray(part)
    sizes = np.bincount(part, minlength=k)
    B = int(sizes.max())
    order = np.argsort(part, kind="stable")
    starts = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(sizes, out=starts[1:])
    rank_in_block = np.empty(n, dtype=np.int64)
    rank_in_block[order] = np.arange(n) - starts[part[order]]
    perm = part.astype(np.int64) * B + rank_in_block
    block_of = np.arange(k, dtype=np.int64) * B

    src, dst = _edge_endpoints(indptr, indices)
    ext = part[src] != part[dst]
    recv_blk = part[src][ext].astype(np.int64)
    own_blk = part[dst][ext].astype(np.int64)
    needed = dst[ext].astype(np.int64)
    pair_key = recv_blk * k + own_blk
    uniq_keys, inv = np.unique(pair_key, return_inverse=True)
    need_map: dict[tuple[int, int], np.ndarray] = {}
    for i, key in enumerate(uniq_keys):
        r, o = int(key // k), int(key % k)
        need_map[(r, o)] = np.unique(needed[inv == i])

    und_pairs = sorted({(min(r, o), max(r, o)) for (r, o) in need_map})
    qp = np.array(und_pairs, dtype=np.int64).reshape(-1, 2)
    qw = np.array([len(need_map.get((a, b), ())) +
                   len(need_map.get((b, a), ())) for a, b in und_pairs],
                  dtype=np.float64)
    colors = (vizing_edge_coloring(qp, qw) if len(qp)
              else np.zeros(0, np.int32))
    n_rounds = int(colors.max() + 1) if len(colors) else 1
    S = max(1, max((len(v) for v in need_map.values()), default=1))

    send_idx = np.zeros((k, n_rounds, S), dtype=np.int32)
    send_mask = np.zeros((k, n_rounds, S), dtype=np.float32)
    halo_slot: dict[tuple[int, int], int] = {}
    round_perms: list[list[tuple[int, int]]] = [[] for _ in range(n_rounds)]
    for e, (a, b) in enumerate(und_pairs):
        c = int(colors[e])
        for (o, r) in ((a, b), (b, a)):
            need = need_map.get((r, o))
            if need is None or len(need) == 0:
                continue
            loc = rank_in_block[need].astype(np.int32)
            send_idx[o, c, :len(need)] = loc
            send_mask[o, c, :len(need)] = 1.0
            for p, u in enumerate(need):
                halo_slot[(r, int(u))] = B + c * S + p
        round_perms[c].append((a, b))
        round_perms[c].append((b, a))

    rows_l = rank_in_block[src].astype(np.int32)
    cols_l = np.empty(len(dst), dtype=np.int32)
    same = ~ext
    cols_l[same] = rank_in_block[dst[same]].astype(np.int32)
    for i in np.nonzero(ext)[0]:
        cols_l[i] = halo_slot[(int(part[src[i]]), int(dst[i]))]
    own = part[src]
    per_blk = np.bincount(own, minlength=k)
    nnz_pad = int(per_blk.max()) if len(per_blk) else 1
    rows_a = np.zeros((k, nnz_pad), dtype=np.int32)
    cols_a = np.zeros((k, nnz_pad), dtype=np.int32)
    vals_a = np.zeros((k, nnz_pad), dtype=np.float32)
    ord2 = np.argsort(own, kind="stable")
    off = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(per_blk, out=off[1:])
    for b in range(k):
        sl = ord2[off[b]:off[b + 1]]
        rows_a[b, :len(sl)] = rows_l[sl]
        cols_a[b, :len(sl)] = cols_l[sl]
        vals_a[b, :len(sl)] = data[sl]

    row_mask = np.zeros((k, B), dtype=np.float32)
    for b in range(k):
        row_mask[b, :sizes[b]] = 1.0

    blk_e = own[ord2]
    return DistPlan(
        k=k, B=B, S=S, n_rounds=n_rounds, n=n, perm=perm, block_of=block_of,
        sizes=sizes,
        rows=jnp.asarray(rows_a), cols=jnp.asarray(cols_a),
        vals=jnp.asarray(vals_a), row_mask=jnp.asarray(row_mask),
        send_idx=jnp.asarray(send_idx), send_mask=jnp.asarray(send_mask),
        round_perms=tuple(tuple(r) for r in round_perms),
        _pack_blk=blk_e,
        _pack_pos=np.arange(len(src)) - off[blk_e],
        _pack_dst=dst[ord2],
    )


# --------------------------------------------------------------------------
# shard_map programs
# --------------------------------------------------------------------------

def _halo_exchange(plan: DistPlan, x_loc, send_idx, send_mask, axis: str):
    """x_loc: (B,).  Returns (B + R*S,) extended vector."""
    bufs = []
    for c in range(plan.n_rounds):
        buf = x_loc[send_idx[c]] * send_mask[c]            # (S,)
        perm = plan.round_perms[c]
        if perm:
            buf = jax.lax.ppermute(buf, axis, perm)
        else:
            buf = jnp.zeros_like(buf)
        bufs.append(buf)
    return jnp.concatenate([x_loc] + bufs)


def make_dist_spmv(plan: DistPlan, mesh: Mesh, axis: str = "pu",
                   comm: str = "halo") -> Callable:
    """Returns jit'd y = A @ x on (k, B) block-major vectors.

    ``comm='halo'`` exchanges only the boundary via edge-colored ppermute
    rounds; ``comm='allgather'`` gathers the whole padded vector (the
    partitioner-oblivious baseline) using ``plan.cols_global``.
    """
    if comm == "allgather":
        return make_dist_spmv_allgather(plan, plan.cols_global, mesh, axis)
    if comm != "halo":
        raise ValueError(f"unknown comm mode {comm!r}")

    def local_matvec(rows, cols, vals, row_mask, send_idx, send_mask, x):
        x = x[0]                                            # (B,)
        x_ext = _halo_exchange(plan, x, send_idx[0], send_mask[0], axis)
        y = jnp.zeros(plan.B, jnp.float32).at[rows[0]].add(
            vals[0] * x_ext[cols[0]])
        return (y * row_mask[0])[None]

    spec = P(axis)
    fn = shard_map(
        local_matvec, mesh=mesh,
        in_specs=(spec,) * 6 + (spec,), out_specs=spec)

    @jax.jit
    def spmv(x):
        return fn(plan.rows, plan.cols, plan.vals, plan.row_mask,
                  plan.send_idx, plan.send_mask, x)

    return spmv


def make_dist_spmv_allgather(plan: DistPlan, cols_global: jnp.ndarray,
                             mesh: Mesh, axis: str = "pu") -> Callable:
    def local_matvec(rows, cols, vals, row_mask, x):
        x_all = jax.lax.all_gather(x[0], axis).reshape(-1)   # (k*B,)
        y = jnp.zeros(plan.B, jnp.float32).at[rows[0]].add(
            vals[0] * x_all[cols[0]])
        return (y * row_mask[0])[None]

    spec = P(axis)
    fn = shard_map(local_matvec, mesh=mesh,
                   in_specs=(spec,) * 5, out_specs=spec)

    @jax.jit
    def spmv(x):
        return fn(plan.rows, cols_global, plan.vals, plan.row_mask, x)

    return spmv


def make_dist_cg(plan: DistPlan, mesh: Mesh, axis: str = "pu",
                 tol: float = 1e-6, max_iters: int = 500,
                 comm: str = "halo") -> Callable:
    """Whole-CG SPMD program: the while_loop runs inside shard_map; dot
    products are psum-reduced local dots; the matvec uses the edge-colored
    halo rounds (``comm='halo'``) or the full-vector all_gather baseline
    (``comm='allgather'``).

    This is the fused fast path; the composable path is
    ``operator.DistributedOperator`` + the generic ``cg.cg_solve``."""
    if comm not in ("halo", "allgather"):
        raise ValueError(f"unknown comm mode {comm!r}")
    cols_dev = plan.cols if comm == "halo" else plan.cols_global

    def cg_local(rows, cols, vals, row_mask, send_idx, send_mask, b):
        rows, cols, vals, row_mask = rows[0], cols[0], vals[0], row_mask[0]
        send_idx, send_mask, b = send_idx[0], send_mask[0], b[0]

        def matvec(x):
            if comm == "halo":
                x_ext = _halo_exchange(plan, x, send_idx, send_mask, axis)
            else:
                x_ext = jax.lax.all_gather(x, axis).reshape(-1)  # (k*B,)
            y = jnp.zeros(plan.B, jnp.float32).at[rows].add(
                vals * x_ext[cols])
            return y * row_mask

        def dot(u, v):
            return jax.lax.psum(jnp.vdot(u * row_mask, v), axis)

        x = jnp.zeros_like(b)
        r = b - matvec(x)
        p = r
        rs = dot(r, r)
        tol2 = tol * tol * jnp.maximum(dot(b, b), 1e-30)

        def cond(s):
            return (s[3] > tol2) & (s[4] < max_iters)

        def body(s):
            x, r, p, rs, it = s
            ap = matvec(p)
            alpha = rs / (dot(p, ap) + 1e-30)
            x = x + alpha * p
            r = r - alpha * ap
            rs2 = dot(r, r)
            p = r + (rs2 / (rs + 1e-30)) * p
            return x, r, p, rs2, it + 1

        x, r, p, rs, it = jax.lax.while_loop(
            cond, body, (x, r, p, rs, jnp.zeros((), jnp.int32)))
        return x[None], rs[None], it[None]

    spec = P(axis)
    fn = shard_map(cg_local, mesh=mesh, in_specs=(spec,) * 7,
                   out_specs=(spec, spec, spec))

    @jax.jit
    def solve(b):
        x, rs, it = fn(plan.rows, cols_dev, plan.vals, plan.row_mask,
                       plan.send_idx, plan.send_mask, b)
        return x, jnp.sqrt(rs[0]), it[0]

    return solve
