"""Distributed SpMV / CG over a heterogeneous partition — shard_map version
of the paper's application layer (Sec. VI-a: SpMV and CG on the Laplacian,
distributed according to the partition produced by the respective tool).

MPI-rank-per-PU becomes one mesh index per block.  Because XLA SPMD shards
are uniform, each block is padded to B = max block size; `row_mask` marks
real rows.  The padding waste is exactly the heterogeneity spread: with
Algorithm-1 target sizes the fast PUs own the largest blocks, so B equals
the largest tw and slow PUs carry ghost rows.  (On a real heterogeneous
machine the fast PU also *is* faster, so wall-clock stays balanced — the
simulated-speed benchmark in benchmarks/bench_cg.py models this.)

Halo exchange: the quotient graph of the partition is edge-colored
(core.refinement.greedy_edge_coloring) and each color class becomes one
`lax.ppermute` round — at most one partner per device per round, the exact
communication schedule Geographer-R uses for its pairwise refinement.  The
halo buffer layout is (rounds, S) with stable slots, so column indices are
remapped once on the host.

Both exchange strategies are provided:
  * ``halo``       — ppermute rounds, comm volume = O(boundary)  [default]
  * ``allgather``  — all_gather of the whole padded vector, comm volume
                     = O(n); the baseline a partitioner-oblivious system
                     would use.  The benchmark compares the two.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.refinement import greedy_edge_coloring, quotient_graph
from .graph import Graph


@dataclasses.dataclass
class DistPlan:
    """Host-built plan + device arrays for the distributed operator.

    All arrays carry a leading block axis of size k and are sharded
    one-block-per-device by ``shard``.
    """

    k: int
    B: int                      # padded rows per block
    S: int                      # padded halo slots per round
    n_rounds: int
    n: int                      # true global size
    perm: np.ndarray            # old vertex id -> new (block-contiguous) id
    block_of: np.ndarray        # (k,) first new id of each block
    # device data
    rows: jnp.ndarray           # (k, nnz_pad) int32 local row
    cols: jnp.ndarray           # (k, nnz_pad) int32 local col in [0, B+R*S)
    vals: jnp.ndarray           # (k, nnz_pad) f32
    row_mask: jnp.ndarray       # (k, B) f32
    send_idx: jnp.ndarray       # (k, R, S) int32 local indices to send
    send_mask: jnp.ndarray      # (k, R, S) f32
    round_perms: tuple          # per round: tuple of (src, dst) pairs

    def scatter_vec(self, x: np.ndarray) -> np.ndarray:
        """(n,) global vector -> (k, B) padded block-major layout."""
        out = np.zeros((self.k, self.B), dtype=np.float32)
        new = self.perm
        blk = np.searchsorted(self.block_of, new, side="right") - 1
        out[blk, new - self.block_of[blk]] = x
        return out

    def gather_vec(self, xb: np.ndarray) -> np.ndarray:
        """(k, B) -> (n,) global order."""
        new = self.perm
        blk = np.searchsorted(self.block_of, new, side="right") - 1
        return np.asarray(xb)[blk, new - self.block_of[blk]]


def build_plan(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
               part: np.ndarray, k: int) -> DistPlan:
    """Build the distributed plan for matrix (CSR) + partition."""
    n = len(indptr) - 1
    part = np.asarray(part)
    sizes = np.bincount(part, minlength=k)
    B = int(sizes.max())
    # block-contiguous reordering
    order = np.argsort(part, kind="stable")       # new -> old
    perm = np.empty(n, dtype=np.int64)            # old -> new (within-global)
    starts = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(sizes, out=starts[1:])
    # pad blocks: new id of old vertex v = pad_start[part[v]] + rank within block
    rank_in_block = np.empty(n, dtype=np.int64)
    rank_in_block[order] = np.arange(n) - starts[part[order]]
    perm = part.astype(np.int64) * B + rank_in_block   # padded new id
    block_of = np.arange(k, dtype=np.int64) * B

    # halo plan: for each ordered pair (owner -> receiver), vertices needed
    src = np.repeat(np.arange(n), np.diff(indptr))
    dst = indices
    ext = part[src] != part[dst]
    # receiver = part[src] needs vertex dst owned by part[dst]
    recv_blk = part[src][ext].astype(np.int64)
    own_blk = part[dst][ext].astype(np.int64)
    needed = dst[ext].astype(np.int64)
    pair_key = recv_blk * k + own_blk
    uniq_keys, inv = np.unique(pair_key, return_inverse=True)
    # per (receiver, owner): sorted unique needed vertices
    need_map: dict[tuple[int, int], np.ndarray] = {}
    for i, key in enumerate(uniq_keys):
        r, o = int(key // k), int(key % k)
        need_map[(r, o)] = np.unique(needed[inv == i])

    # color the undirected quotient graph
    und_pairs = sorted({(min(r, o), max(r, o)) for (r, o) in need_map})
    qp = np.array(und_pairs, dtype=np.int64).reshape(-1, 2)
    qw = np.array([len(need_map.get((a, b), ())) +
                   len(need_map.get((b, a), ())) for a, b in und_pairs],
                  dtype=np.float64)
    colors = (greedy_edge_coloring(qp, qw) if len(qp)
              else np.zeros(0, np.int32))
    n_rounds = int(colors.max() + 1) if len(colors) else 1
    S = max(1, max((len(v) for v in need_map.values()), default=1))

    send_idx = np.zeros((k, n_rounds, S), dtype=np.int32)
    send_mask = np.zeros((k, n_rounds, S), dtype=np.float32)
    # halo slot of remote vertex u on receiver r: B + c*S + pos
    halo_slot: dict[tuple[int, int], int] = {}
    round_perms: list[list[tuple[int, int]]] = [[] for _ in range(n_rounds)]
    for e, (a, b) in enumerate(und_pairs):
        c = int(colors[e])
        for (o, r) in ((a, b), (b, a)):              # both directions
            need = need_map.get((r, o))
            if need is None or len(need) == 0:
                continue
            loc = (need - block_of[part[need]] * 0   # local index on owner
                   ) % B  # placeholder, fixed below
            loc = rank_in_block[need].astype(np.int32)
            send_idx[o, c, :len(need)] = loc
            send_mask[o, c, :len(need)] = 1.0
            for p, u in enumerate(need):
                halo_slot[(r, int(u))] = B + c * S + p
        # schedule: o->r and r->o in the same round (bidirectional swap)
        round_perms[c].append((a, b))
        round_perms[c].append((b, a))

    # local matrix in padded-COO with remapped columns
    rows_l = rank_in_block[src].astype(np.int32)
    cols_l = np.empty(len(dst), dtype=np.int32)
    same = ~ext
    cols_l[same] = rank_in_block[dst[same]].astype(np.int32)
    ext_ids = np.nonzero(ext)[0]
    for i in ext_ids:
        cols_l[i] = halo_slot[(int(part[src[i]]), int(dst[i]))]
    own = part[src]
    per_blk = np.bincount(own, minlength=k)
    nnz_pad = int(per_blk.max()) if len(per_blk) else 1
    rows_a = np.zeros((k, nnz_pad), dtype=np.int32)
    cols_a = np.zeros((k, nnz_pad), dtype=np.int32)
    vals_a = np.zeros((k, nnz_pad), dtype=np.float32)
    fill = np.zeros(k, dtype=np.int64)
    ord2 = np.argsort(own, kind="stable")
    off = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(per_blk, out=off[1:])
    for b in range(k):
        sl = ord2[off[b]:off[b + 1]]
        rows_a[b, :len(sl)] = rows_l[sl]
        cols_a[b, :len(sl)] = cols_l[sl]
        vals_a[b, :len(sl)] = data[sl]

    row_mask = np.zeros((k, B), dtype=np.float32)
    for b in range(k):
        row_mask[b, :sizes[b]] = 1.0

    return DistPlan(
        k=k, B=B, S=S, n_rounds=n_rounds, n=n, perm=perm, block_of=block_of,
        rows=jnp.asarray(rows_a), cols=jnp.asarray(cols_a),
        vals=jnp.asarray(vals_a), row_mask=jnp.asarray(row_mask),
        send_idx=jnp.asarray(send_idx), send_mask=jnp.asarray(send_mask),
        round_perms=tuple(tuple(r) for r in round_perms),
    )


# --------------------------------------------------------------------------
# shard_map programs
# --------------------------------------------------------------------------

def _halo_exchange(plan: DistPlan, x_loc, send_idx, send_mask, axis: str):
    """x_loc: (B,).  Returns (B + R*S,) extended vector."""
    bufs = []
    for c in range(plan.n_rounds):
        buf = x_loc[send_idx[c]] * send_mask[c]            # (S,)
        perm = plan.round_perms[c]
        if perm:
            buf = jax.lax.ppermute(buf, axis, perm)
        else:
            buf = jnp.zeros_like(buf)
        bufs.append(buf)
    return jnp.concatenate([x_loc] + bufs)


def make_dist_spmv(plan: DistPlan, mesh: Mesh, axis: str = "pu",
                   comm: str = "halo") -> Callable:
    """Returns jit'd y = A @ x on (k, B) block-major vectors."""

    def local_matvec(rows, cols, vals, row_mask, send_idx, send_mask, x):
        x = x[0]                                            # (B,)
        if comm == "halo":
            x_ext = _halo_exchange(plan, x, send_idx[0], send_mask[0], axis)
        elif comm == "allgather":
            x_all = jax.lax.all_gather(x, axis)             # (k, B)
            # columns for remote entries index halo slots; rebuild them from
            # the halo layout is halo-specific, so allgather mode instead
            # uses global padded ids: col_global = blk*B + loc.  We pass the
            # same cols but they are remapped by the caller (see
            # make_dist_spmv_allgather).
            raise RuntimeError("use make_dist_spmv_allgather")
        y = jnp.zeros(plan.B, jnp.float32).at[rows[0]].add(
            vals[0] * x_ext[cols[0]])
        return (y * row_mask[0])[None]

    spec = P(axis)
    fn = jax.shard_map(
        local_matvec, mesh=mesh,
        in_specs=(spec,) * 6 + (spec,), out_specs=spec)

    @jax.jit
    def spmv(x):
        return fn(plan.rows, plan.cols, plan.vals, plan.row_mask,
                  plan.send_idx, plan.send_mask, x)

    return spmv


def build_allgather_cols(plan: DistPlan, indptr, indices, part) -> jnp.ndarray:
    """Column ids in global padded space (blk*B + rank) for allgather mode."""
    n = len(indptr) - 1
    src = np.repeat(np.arange(n), np.diff(indptr))
    own = part[src]
    k, B = plan.k, plan.B
    new_id = plan.perm[indices]                     # padded global id
    per_blk = np.bincount(own, minlength=k)
    nnz_pad = plan.rows.shape[1]
    cols_a = np.zeros((k, nnz_pad), dtype=np.int32)
    ord2 = np.argsort(own, kind="stable")
    off = np.zeros(k + 1, dtype=np.int64)
    np.cumsum(per_blk, out=off[1:])
    for b in range(k):
        sl = ord2[off[b]:off[b + 1]]
        cols_a[b, :len(sl)] = new_id[sl]
    return jnp.asarray(cols_a)


def make_dist_spmv_allgather(plan: DistPlan, cols_global: jnp.ndarray,
                             mesh: Mesh, axis: str = "pu") -> Callable:
    def local_matvec(rows, cols, vals, row_mask, x):
        x_all = jax.lax.all_gather(x[0], axis).reshape(-1)   # (k*B,)
        y = jnp.zeros(plan.B, jnp.float32).at[rows[0]].add(
            vals[0] * x_all[cols[0]])
        return (y * row_mask[0])[None]

    spec = P(axis)
    fn = jax.shard_map(local_matvec, mesh=mesh,
                       in_specs=(spec,) * 5, out_specs=spec)

    @jax.jit
    def spmv(x):
        return fn(plan.rows, cols_global, plan.vals, plan.row_mask, x)

    return spmv


def make_dist_cg(plan: DistPlan, mesh: Mesh, axis: str = "pu",
                 tol: float = 1e-6, max_iters: int = 500) -> Callable:
    """Whole-CG SPMD program: the while_loop runs inside shard_map; dot
    products are psum-reduced local dots; the matvec uses the halo rounds."""

    def cg_local(rows, cols, vals, row_mask, send_idx, send_mask, b):
        rows, cols, vals, row_mask = rows[0], cols[0], vals[0], row_mask[0]
        send_idx, send_mask, b = send_idx[0], send_mask[0], b[0]

        def matvec(x):
            x_ext = _halo_exchange(plan, x, send_idx, send_mask, axis)
            y = jnp.zeros(plan.B, jnp.float32).at[rows].add(
                vals * x_ext[cols])
            return y * row_mask

        def dot(u, v):
            return jax.lax.psum(jnp.vdot(u * row_mask, v), axis)

        x = jnp.zeros_like(b)
        r = b - matvec(x)
        p = r
        rs = dot(r, r)
        tol2 = tol * tol * jnp.maximum(dot(b, b), 1e-30)

        def cond(s):
            return (s[3] > tol2) & (s[4] < max_iters)

        def body(s):
            x, r, p, rs, it = s
            ap = matvec(p)
            alpha = rs / (dot(p, ap) + 1e-30)
            x = x + alpha * p
            r = r - alpha * ap
            rs2 = dot(r, r)
            p = r + (rs2 / (rs + 1e-30)) * p
            return x, r, p, rs2, it + 1

        x, r, p, rs, it = jax.lax.while_loop(
            cond, body, (x, r, p, rs, jnp.zeros((), jnp.int32)))
        return x[None], rs[None], it[None]

    spec = P(axis)
    fn = jax.shard_map(cg_local, mesh=mesh, in_specs=(spec,) * 7,
                       out_specs=(spec, spec, spec))

    @jax.jit
    def solve(b):
        x, rs, it = fn(plan.rows, plan.cols, plan.vals, plan.row_mask,
                       plan.send_idx, plan.send_mask, b)
        return x, jnp.sqrt(rs[0]), it[0]

    return solve
