"""The Operator protocol — one interface over every SpMV backend.

The paper's phase-2 evaluation (Sec. VI-a) runs the *same* SpMV/CG
application against matrices distributed by different partitioners; this
module is the code shape of that idea: a backend-agnostic linear-operator
interface so that one ``cg_solve`` and one benchmark harness drive

  * ``coo``            — single-device padded-COO segment-sum (spmv.py);
  * ``bell``           — the Pallas block-ELL TPU kernel
                         (kernels/spmv_bell.py), compiled on TPU and
                         interpreted elsewhere (backend auto-detection);
  * ``dist_halo``      — shard_map, edge-colored ppermute halo exchange,
                         *overlapped*: the interior matvec (rows touching
                         no halo slot) is issued before the ppermute
                         rounds so compute hides communication;
  * ``dist_halo_seq``  — the sequential halo schedule (exchange all
                         rounds, then one full matvec) — the
                         non-overlapped reference;
  * ``dist_bell``      — overlapped halo exchange with the interior
                         matvec in the Pallas block-ELL kernel (ROADMAP's
                         third comm/format combination);
  * ``dist_allgather`` — shard_map, all_gather baseline;
  * ``dist_hier``      — the per-tree-level hierarchical schedule
                         (``build_plan_tree``; two-level multi-pod is the
                         ``h == 2`` instance): interior matvec, then one
                         ppermute round class per tree level over that
                         level's axis suffix, issued outermost-first so
                         every slower exchange overlaps all faster-level
                         work.  Needs ``pods=`` / ``fanouts=`` / ``tree=``
                         and a hierarchical mesh
                         (``launch.mesh.make_test_mesh(k, pods=...)`` /
                         ``make_test_mesh(k, fanouts=...)`` or
                         ``make_production_mesh(multi_pod=True)``);
  * ``dist_hier_bell`` — the same tree schedule with the interior matvec
                         in the Pallas block-ELL kernel (the hier
                         counterpart of ``dist_bell``).

Protocol
--------
An Operator is any object with

  ``n``             — true global dimension;
  ``matvec(x)``     — y = A @ x in *operator space* (the backend's native
                      layout: (n,) for single-device, (k, B) padded
                      block-major for distributed);
  ``dot(u, v)``     — inner product in operator space (plain vdot is exact
                      for the distributed layout because padding rows stay
                      zero under matvec and scatter);
  ``diag()``        — diagonal of A in operator space (on-device; feeds
                      the Jacobi preconditioner in ``cg_solve``);
  ``scatter(x)``    — (n,) global numpy vector -> operator space;
  ``gather(y)``     — operator space -> (n,) global numpy vector.

``cg.cg_solve`` accepts an Operator directly; :func:`cg_solve_global` adds the
scatter/solve/gather round trip so callers never touch layouts.  Both take
``precondition='jacobi'`` to run preconditioned CG off the operator's
diagonal.  ``make_operator`` is the single factory the benchmark harness
uses.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from .cg import CGResult, cg_solve
from .distributed import (DistPlan, build_plan, build_plan_tree,
                          make_dist_cg, make_dist_spmv)
from .spmv import csr_diagonal, csr_to_padded_coo, spmv_coo


@runtime_checkable
class Operator(Protocol):
    """Structural protocol — see module docstring for the contract."""

    n: int

    def matvec(self, x): ...

    def dot(self, u, v): ...

    def diag(self): ...

    def scatter(self, x): ...

    def gather(self, y): ...


# --------------------------------------------------------------------------
# Single-device backends
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CooOperator:
    """Padded-COO segment-sum SpMV (any backend, any sparsity).

    ``batch_native``: the scatter-add matvec carries a trailing RHS-batch
    axis through natively, so the batched CG path needs no vmap."""

    n: int
    rows: jnp.ndarray
    cols: jnp.ndarray
    vals: jnp.ndarray

    batch_native = True

    @classmethod
    def from_csr(cls, indptr, indices, data, nnz_pad: int | None = None):
        rows, cols, vals = csr_to_padded_coo(indptr, indices, data,
                                             nnz_pad=nnz_pad)
        return cls(n=len(indptr) - 1, rows=jnp.asarray(rows),
                   cols=jnp.asarray(cols), vals=jnp.asarray(vals))

    def matvec(self, x):
        return spmv_coo(self.rows, self.cols, self.vals, x, n=self.n)

    def operand_spec(self, nb: int | None = None):
        """``ShapeDtypeStruct`` of the matvec operand — the abstract input
        the trace auditor (``repro.analysis.trace``) feeds to
        ``jax.make_jaxpr``; ``nb`` adds the trailing RHS-batch axis."""
        shape = (self.n,) if nb is None else (self.n, nb)
        return jax.ShapeDtypeStruct(shape, self.vals.dtype)

    def dot(self, u, v):
        return jnp.vdot(u, v)

    def diag(self):
        """On-device diagonal extraction from the padded-COO triples."""
        on_diag = jnp.where(self.rows == self.cols, self.vals, 0.0)
        return jnp.zeros(self.n, self.vals.dtype).at[self.rows].add(on_diag)

    def scatter(self, x):
        return jnp.asarray(_as_float(x))

    def gather(self, y):
        return np.asarray(y)


def _as_float(x):
    """Host vector -> float ndarray, preserving float dtypes (float64
    systems stay float64 under JAX_ENABLE_X64; the old hard-coded
    ``astype(np.float32)`` silently downcast them)."""
    x = np.asarray(x)
    if not np.issubdtype(x.dtype, np.floating):
        x = x.astype(np.float32)
    return x


@dataclasses.dataclass
class BlockEllOperator:
    """Pallas block-ELL SpMV (TPU-compiled; interpreted off-TPU)."""

    n: int
    blocks: jnp.ndarray
    cols: jnp.ndarray
    interpret: bool | None = None
    diag_: jnp.ndarray | None = None

    @classmethod
    def from_csr(cls, indptr, indices, data, bm: int = 8, bk: int = 128,
                 nnzb: int | None = None, interpret: bool | None = None):
        from ..kernels.spmv_bell import csr_to_block_ell
        n = len(indptr) - 1
        blocks, cols, _meta = csr_to_block_ell(indptr, indices, data, n,
                                               bm=bm, bk=bk, nnzb=nnzb)
        return cls(n=n, blocks=jnp.asarray(blocks), cols=jnp.asarray(cols),
                   interpret=interpret,
                   diag_=jnp.asarray(csr_diagonal(indptr, indices, data)))

    def matvec(self, x):
        from ..kernels.spmv_bell import spmv_block_ell
        return spmv_block_ell(self.blocks, self.cols, x,
                              interpret=self.interpret)

    def operand_spec(self, nb: int | None = None):
        """Abstract matvec operand for device-free tracing (the Pallas
        kernel is single-RHS, so ``nb`` is rejected like in matvec)."""
        if nb is not None:
            raise ValueError("BlockEllOperator is single-RHS")
        return jax.ShapeDtypeStruct((self.n,), self.blocks.dtype)

    def dot(self, u, v):
        return jnp.vdot(u, v)

    def diag(self):
        if self.diag_ is None:
            raise ValueError("BlockEllOperator built without a diagonal; "
                             "construct via from_csr for Jacobi support")
        return self.diag_

    def scatter(self, x):
        return jnp.asarray(_as_float(x))

    def gather(self, y):
        return np.asarray(y)


# --------------------------------------------------------------------------
# Distributed backend
# --------------------------------------------------------------------------

@dataclasses.dataclass
class DistributedOperator:
    """shard_map SpMV over a partition plan.

    ``comm`` picks the exchange schedule — ``'halo'`` (overlapped
    interior/boundary, the default), ``'halo_seq'`` (sequential
    reference), ``'allgather'`` (partitioner-oblivious baseline) or
    ``'hier'`` (the three-stage multi-pod schedule; needs a ``HierPlan``
    and a tuple ``axis``, see :meth:`from_csr`); ``local_format`` picks
    the interior matvec kernel — ``'coo'`` scatter-add or ``'bell'``
    (Pallas block-ELL, comm='halo' or 'hier').

    Operator space is the (k, B) padded block-major layout; ``dot`` is a
    plain vdot because ghost rows are zero in both vectors.  ``solve``
    exposes the fused whole-CG-in-shard_map program (one dispatch total)
    next to the composable ``cg_solve(op, ...)`` path (one dispatch per
    matvec) — both converge identically; the fused one is faster when
    dispatch overhead dominates.

    ``batch_native``: the halo/hier exchange schedules carry a trailing
    RHS-batch axis through natively (vmap cannot cross their ppermute
    rounds on every supported JAX), so batched CG hands them the full
    (k, B, nb) operand.  ``local_format='bell'`` stays single-RHS (the
    Pallas kernel is a vector kernel) and raises on a batched operand.
    """

    plan: DistPlan
    mesh: object
    axis: str | tuple = "pu"
    comm: str = "halo"
    local_format: str = "coo"

    batch_native = True

    def __post_init__(self):
        self.n = self.plan.n
        self._spmv = make_dist_spmv(self.plan, self.mesh, axis=self.axis,
                                    comm=self.comm,
                                    local_format=self.local_format)
        self._fused = {}   # (tol, max_iters, precondition) -> compiled CG

    @classmethod
    def from_csr(cls, indptr, indices, data, part, k, mesh,
                 axis: str | tuple = "pu", comm: str = "halo",
                 local_format: str = "coo", pods=None, fanouts=None,
                 tree=None, validate: bool | None = None):
        """``comm='hier'`` builds the hierarchical plan — ``pods`` (pod
        count or explicit (k,) pod-of-block array) for the two-level
        instance, ``fanouts``/``tree`` ((k_1, ..., k_h) tuple / explicit
        (h-1, k) ancestor table) for arbitrary depth — and defaults
        ``axis`` to the mesh's full axis tuple, outermost level first —
        e.g. ``('pod', 'pu')`` on ``make_test_mesh(k, pods=...)``,
        ``('pod', 'host', 'pu')`` on ``make_test_mesh(k,
        fanouts=(2, 2, 2))`` and ``('pod', 'data', 'model')`` on
        ``make_production_mesh(multi_pod=True)``."""
        if comm == "hier":
            if pods is None and fanouts is None and tree is None:
                raise ValueError(
                    "comm='hier' needs pods= (pod count or (k,) "
                    "pod-of-block array), fanouts= ((k_1, ..., k_h) "
                    "tree shape) or tree= ((h-1, k) ancestor table)")
            if pods is not None and tree is not None:
                raise ValueError("pass either pods= or tree=, not both")
            plan = build_plan_tree(indptr, indices, data, part,
                                   pods if pods is not None else tree,
                                   k, fanouts=fanouts, validate=validate)
            if axis == "pu":                    # default -> full mesh tuple
                axis = tuple(mesh.axis_names)
        else:
            if pods is not None or fanouts is not None or tree is not None:
                raise ValueError("pods=/fanouts=/tree= only apply to "
                                 "comm='hier'")
            plan = build_plan(indptr, indices, data, part, k,
                              validate=validate)
        return cls(plan=plan, mesh=mesh, axis=axis, comm=comm,
                   local_format=local_format)

    def matvec(self, x):
        return self._spmv(x)

    def operand_spec(self, nb: int | None = None):
        """Abstract (k, B[, nb]) operator-space operand for device-free
        tracing: together with :func:`distributed.abstract_mesh_for` this
        lets ``repro.analysis.trace`` audit the staged program without
        any of the target topology present."""
        shape = (self.plan.k, self.plan.B)
        if nb is not None:
            shape = shape + (nb,)
        return jax.ShapeDtypeStruct(shape, self.plan.vals.dtype)

    def fused_solver(self, tol: float = 1e-6, max_iters: int = 500,
                     precondition: str | None = None):
        """The cached fused whole-CG program on *operator-space* operands
        ((k, B[, nb]) -> (x, res, iters)) — what :meth:`solve` runs after
        scattering, exposed so the trace auditor can ``make_jaxpr`` it."""
        key = (tol, max_iters, precondition)
        fused = self._fused.get(key)
        if fused is None:
            fused = self._fused[key] = make_dist_cg(
                self.plan, self.mesh, axis=self.axis,
                tol=tol, max_iters=max_iters, comm=self.comm,
                local_format=self.local_format, precondition=precondition)
        return fused

    def dot(self, u, v):
        return jnp.vdot(u, v)

    def diag(self):
        """(k, B) diagonal of A — extracted at plan build, already on
        device; ghost rows carry zero (handled by the preconditioner)."""
        return self.plan.diag

    def block_jacobi_preconditioner(self):
        """z = M^-1 r with M = blockdiag(A_bb), the per-PU diagonal blocks
        the plan already extracted (``plan.block_jacobi_inv``).  Operator-
        space application: one batched (B, B) matmul per block; ghost rows
        are identity in M^-1 and their residuals exactly zero, so padding
        stays out of the Krylov space."""
        minv = self.plan.block_jacobi_inv()          # (k, B, B)

        def apply(r):
            return jnp.einsum("kij,kj->ki", minv, r)

        return apply

    def scatter(self, x):
        return jnp.asarray(self.plan.scatter_vec(np.asarray(x)))

    def gather(self, y):
        return self.plan.gather_vec(np.asarray(y))

    def solve(self, b, tol: float = 1e-6, max_iters: int = 500,
              precondition: str | None = None) -> CGResult:
        """Fused distributed CG on a (n,) global right-hand side — or an
        (n, nb) RHS batch, which runs the multi-RHS masked loop inside the
        same shard_map program and returns per-column iters/residual.  The
        traced program is cached per (tol, max_iters, precondition);
        ``jax.jit`` retraces per operand shape under one cache entry, so
        repeated solves with new right-hand sides (same batch width) pay
        no re-trace."""
        fused = self.fused_solver(tol, max_iters, precondition)
        x, res, it = fused(self.scatter(b))
        return CGResult(x=x, iters=it, residual=res)


# --------------------------------------------------------------------------
# Factory + harness entry point
# --------------------------------------------------------------------------

BACKENDS = ("coo", "bell", "dist_halo", "dist_halo_seq", "dist_bell",
            "dist_allgather", "dist_hier", "dist_hier_bell")

_DIST_MODES = {
    "dist_halo": ("halo", "coo"),
    "dist_halo_seq": ("halo_seq", "coo"),
    "dist_bell": ("halo", "bell"),
    "dist_allgather": ("allgather", "coo"),
    "dist_hier": ("hier", "coo"),
    "dist_hier_bell": ("hier", "bell"),
}

_HIER_BACKENDS = ("dist_hier", "dist_hier_bell")


def make_operator(indptr, indices, data, backend: str = "coo", *,
                  part=None, k: int | None = None, mesh=None,
                  axis: str | tuple = "pu", **kw) -> Operator:
    """One factory for every SpMV backend (see BACKENDS).

    ``dist_hier`` / ``dist_hier_bell`` additionally need ``pods=`` (pod
    count or explicit (k,) pod-of-block array, e.g.
    ``core.topology.Topology.pod_assignment``), ``fanouts=`` or
    ``tree=`` (the arbitrary-depth forms) and a hierarchical mesh;
    ``axis`` defaults to the mesh's full axis tuple, outermost level
    first.

    ``part`` may also be a ``core.api.HierPartition`` (the tree-aware
    pipeline's output, duck-typed on ``.part``/``.pod_of``): the block
    partition, ``k``, and — for the hier backends — the
    partition-derived ancestor table are unpacked from it, so the
    partitioner output drives the runtime directly."""
    if part is not None and hasattr(part, "part") and hasattr(part,
                                                              "pod_of"):
        hp = part
        part = np.asarray(hp.part)
        if k is None:
            k = hp.k
        if backend in _HIER_BACKENDS and "pods" not in kw:
            kw.setdefault("tree", np.asarray(hp.anc)
                          if getattr(hp, "anc", None) is not None
                          else np.asarray(hp.pod_of))
    if backend == "coo":
        return CooOperator.from_csr(indptr, indices, data, **kw)
    if backend == "bell":
        return BlockEllOperator.from_csr(indptr, indices, data, **kw)
    if backend in _DIST_MODES:
        if part is None or k is None or mesh is None:
            raise ValueError(f"{backend} needs part=, k=, mesh=")
        comm, local_format = _DIST_MODES[backend]
        return DistributedOperator.from_csr(indptr, indices, data, part, k,
                                            mesh, axis=axis, comm=comm,
                                            local_format=local_format, **kw)
    raise ValueError(f"unknown backend {backend!r}; choose from {BACKENDS}")


def cg_solve_global(op: Operator, b: np.ndarray, tol: float = 1e-6,
             max_iters: int = 500,
             precondition: str | None = None) -> tuple[np.ndarray, int,
                                                       float]:
    """Scatter -> generic CG -> gather.  Returns (x_global, iters, res).

    A 2-D ``b`` of shape (n, nb) is an RHS batch: the multi-RHS masked
    loop runs all columns in one program and the returned iters/res are
    (nb,) arrays (the global vector is unambiguously 1-D, so the batch
    is inferred from ndim here — operator space needs the explicit
    ``batched=`` flag because a distributed single-RHS operand is
    already 2-D)."""
    batched = np.ndim(b) == 2
    res = cg_solve(op, op.scatter(b), tol=tol, max_iters=max_iters,
                   precondition=precondition, batched=batched)
    if batched:
        return (op.gather(res.x), np.asarray(res.iters),
                np.asarray(res.residual))
    return op.gather(res.x), int(res.iters), float(res.residual)
