"""CSR graph / sparse-matrix container.

The paper exploits the correspondence between a symmetric n x n matrix A and
an undirected graph G (Sec. II).  We store graphs in CSR with both edge
directions present (as ParMetis/Metis do), plus optional vertex coordinates
for the geometric partitioners.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Graph:
    """Undirected graph in symmetric CSR.

    indptr:  (n+1,) int64
    indices: (m2,) int32   — column indices; m2 = 2 * #undirected-edges
    weights: (m2,) float32 — edge weights (1.0 for unweighted)
    coords:  (n, d) float32 or None — vertex coordinates for geometric methods
    """

    indptr: np.ndarray
    indices: np.ndarray
    weights: np.ndarray
    coords: np.ndarray | None = None

    @property
    def n(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        """Number of undirected edges."""
        return len(self.indices) // 2

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @property
    def dim(self) -> int:
        return 0 if self.coords is None else self.coords.shape[1]

    def validate(self) -> None:
        n = self.n
        assert self.indptr[0] == 0 and self.indptr[-1] == len(self.indices)
        assert np.all(np.diff(self.indptr) >= 0)
        assert self.indices.min(initial=0) >= 0
        assert self.indices.max(initial=-1) < n
        # symmetry: edge multiset must be symmetric
        src = np.repeat(np.arange(n), self.degrees)
        fwd = set(zip(src.tolist(), self.indices.tolist()))
        assert all((v, u) in fwd for (u, v) in fwd), "graph is not symmetric"

    def edge_list(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (src, dst, w) with both directions."""
        src = np.repeat(np.arange(self.n, dtype=np.int32), self.degrees)
        return src, self.indices, self.weights

    def _edge_positions(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        """CSR positions of directed edges (u, v); raises on a missing
        edge.  Requires canonical (sorted-within-row) indices, which
        :func:`from_edges` guarantees."""
        u = np.asarray(u, dtype=np.int64)
        v = np.asarray(v, dtype=np.int64)
        src = np.repeat(np.arange(self.n, dtype=np.int64), self.degrees)
        keys = src * self.n + self.indices
        want = u * self.n + v
        pos = np.searchsorted(keys, want)
        ok = (pos < len(keys)) & (keys[np.minimum(pos, len(keys) - 1)]
                                  == want) if len(keys) else \
            np.zeros(len(want), dtype=bool)
        if not np.all(ok):
            bad = np.flatnonzero(~ok)[0]
            raise KeyError(f"edge ({u[bad]}, {v[bad]}) not in graph")
        return pos

    def add_edges(self, u: np.ndarray, v: np.ndarray,
                  w: np.ndarray | None = None) -> "Graph":
        """New graph with undirected edges (u, v) added.

        Follows :func:`from_edges` semantics: self-loops are dropped and
        an edge that already exists gets the weights *summed*.
        """
        u = np.asarray(u, dtype=np.int64)
        v = np.asarray(v, dtype=np.int64)
        w = (np.ones(len(u), dtype=np.float32) if w is None
             else np.asarray(w, dtype=np.float32))
        s0, d0, w0 = self.edge_list()
        return from_edges(self.n,
                          np.concatenate([s0, u, v]),
                          np.concatenate([d0, v, u]),
                          np.concatenate([w0, w, w]), coords=self.coords)

    def remove_edges(self, u: np.ndarray, v: np.ndarray) -> "Graph":
        """New graph with undirected edges (u, v) removed (must exist)."""
        u = np.asarray(u, dtype=np.int64)
        v = np.asarray(v, dtype=np.int64)
        kill = np.concatenate([self._edge_positions(u, v),
                               self._edge_positions(v, u)])
        keep = np.ones(len(self.indices), dtype=bool)
        keep[kill] = False
        s0, d0, w0 = self.edge_list()
        return from_edges(self.n, s0[keep], d0[keep], w0[keep],
                          coords=self.coords)

    def reweight_edges(self, u: np.ndarray, v: np.ndarray,
                       w: np.ndarray) -> "Graph":
        """New graph with undirected edges (u, v) set to weight w (both
        CSR directions; edges must exist).  Structure is shared — only
        the weight array is copied."""
        w = np.asarray(w, dtype=np.float32)
        weights = self.weights.copy()
        weights[self._edge_positions(u, v)] = w
        weights[self._edge_positions(v, u)] = w
        return Graph(indptr=self.indptr, indices=self.indices,
                     weights=weights, coords=self.coords)

    def subgraph(self, mask: np.ndarray) -> tuple["Graph", np.ndarray]:
        """Vertex-induced subgraph.  Returns (sub, old_ids)."""
        old_ids = np.nonzero(mask)[0]
        remap = -np.ones(self.n, dtype=np.int64)
        remap[old_ids] = np.arange(len(old_ids))
        src, dst, w = self.edge_list()
        keep = mask[src] & mask[dst]
        s2, d2, w2 = remap[src[keep]], remap[dst[keep]], w[keep]
        sub = from_edges(len(old_ids), s2, d2, w2,
                         coords=None if self.coords is None
                         else self.coords[old_ids])
        return sub, old_ids


def from_edges(n: int, src: np.ndarray, dst: np.ndarray,
               w: np.ndarray | None = None,
               coords: np.ndarray | None = None,
               symmetrize: bool = False) -> Graph:
    """Build CSR from an edge list.

    If ``symmetrize``, (u,v) implies (v,u); duplicate edges get their weights
    summed; self-loops are dropped.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if w is None:
        w = np.ones(len(src), dtype=np.float32)
    w = np.asarray(w, dtype=np.float32)
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        w = np.concatenate([w, w])
    keep = src != dst
    src, dst, w = src[keep], dst[keep], w[keep]
    # dedupe
    key = src * n + dst
    order = np.argsort(key, kind="stable")
    key, src, dst, w = key[order], src[order], dst[order], w[order]
    uniq, start = np.unique(key, return_index=True)
    w = np.add.reduceat(w, start) if len(w) else w
    src, dst = src[start], dst[start]

    counts = np.bincount(src, minlength=n)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return Graph(indptr=indptr, indices=dst.astype(np.int32),
                 weights=w.astype(np.float32), coords=coords)


def structure_graph(indptr, indices, data=None) -> Graph:
    """Off-diagonal structure of a canonical CSR matrix as a :class:`Graph`.

    Edge weights are |data| (or 1.0 when ``data`` is None).  Assumes a
    structurally symmetric matrix with sorted rows — e.g. the Laplacians
    this repo plans — so the CSR order can be reused directly, skipping
    the O(m log m) sort of :func:`from_edges`.  This is how the drift
    monitor prices a mutated matrix after every delta without paying a
    graph rebuild.
    """
    indptr = np.asarray(indptr)
    n = len(indptr) - 1
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    ind = np.asarray(indices)
    off = src != ind
    counts = np.bincount(src[off], minlength=n)
    new_indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=new_indptr[1:])
    w = (np.ones(int(off.sum()), dtype=np.float32) if data is None
         else np.abs(np.asarray(data)[off]).astype(np.float32))
    return Graph(indptr=new_indptr, indices=ind[off].astype(np.int32),
                 weights=w)


def laplacian_csr(g: Graph, shift: float = 1e-3):
    """Graph Laplacian L = D - A, diagonal shifted to be positive definite
    (Sec. VI-a: 'we shift the diagonal of the Laplacian slightly').

    Returns CSR arrays (indptr, indices, data) including the diagonal.
    """
    n = g.n
    src, dst, w = g.edge_list()
    deg_w = np.zeros(n, dtype=np.float64)
    np.add.at(deg_w, src, w)
    # rows: off-diagonal -w, diagonal deg + shift
    all_src = np.concatenate([src, np.arange(n)])
    all_dst = np.concatenate([dst, np.arange(n)])
    all_val = np.concatenate([-w.astype(np.float64), deg_w + shift])
    order = np.lexsort((all_dst, all_src))
    all_src, all_dst, all_val = (all_src[order], all_dst[order],
                                 all_val[order])
    counts = np.bincount(all_src, minlength=n)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, all_dst.astype(np.int32), all_val.astype(np.float32)
