"""Conjugate-gradient solver (Sec. VI-a: 'CG solver from LAMA ... applied to
systems derived from the graph's Laplacian') — JAX, lax.while_loop.

The operator is passed either as a bare matvec closure or as an
``operator.Operator`` (anything with ``matvec`` / ``dot``), so the same
solver drives the single-device padded-COO SpMV, the Pallas block-ELL
kernel, and the distributed shard_map SpMV — one solver, one benchmark
harness, every backend.

Multi-RHS batching (``batched=True``): ``b`` carries a trailing RHS-batch
axis (``(n, nb)`` single-device, ``(k, B, nb)`` distributed operator
space) and the loop runs all columns in one program with *per-column
convergence masks* — a finished column's alpha/beta are masked to zero,
so its x/r/p freeze while stragglers converge, and ``CGResult`` carries
per-column ``iters``/``residual``.  The total work is
``sum(iters)`` column-iterations, not ``nb * max(iters)``.

All epsilon guards are dtype-aware (``jnp.finfo(b.dtype)``): near-zero
alpha/beta denominators produce a zero step instead of an overflow (the
float32 failure mode of the old hard-coded ``1e-30``), and the ``tol2``
floor never demands a sub-denormal residual.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class CGResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray          # scalar, or (nb,) per column when batched
    residual: jnp.ndarray       # scalar, or (nb,) per column when batched


def jacobi_preconditioner(diag: jnp.ndarray) -> Callable:
    """M^-1 r = r / diag(A), with zero diagonal entries (padded ghost rows
    in the distributed layout) passed through as zero — ghost residuals are
    exactly zero, so this keeps them out of the Krylov space."""
    one = jnp.ones((), diag.dtype)
    safe = jnp.where(diag != 0, diag, one)
    inv = jnp.where(diag != 0, one / safe, 0)

    def apply(r):
        return r * inv

    return apply


def _safe_div(num, den):
    """num / den with a dtype-aware zero guard: a denominator at or below
    the smallest normal of its dtype yields a zero step instead of an
    overflow.  The old ``num / (den + 1e-30)`` was float64-centric — at
    float32 a denominator that underflows still divides by the 1e-30
    guard itself, so alpha could be off by orders of magnitude (or
    overflow to inf for large numerators)."""
    tiny = jnp.finfo(den.dtype).tiny
    ok = jnp.abs(den) > tiny
    return jnp.where(ok, num / jnp.where(ok, den, 1), 0)


def _tol2_floor(tol, b2):
    """Squared absolute tolerance ``tol^2 ||b||^2`` with dtype-aware
    floors: ``b2`` is floored to the smallest normal (a zero RHS converges
    immediately) and the product is floored to it too, so the stop test
    never demands a residual the dtype cannot even represent."""
    tiny = jnp.finfo(b2.dtype).tiny
    return jnp.maximum(tol * tol * jnp.maximum(b2, tiny), tiny)


def _resolve_operator(matvec, dot, precondition):
    """Unpack an Operator (matvec/dot/preconditioner resolution) — shared
    by the single-RHS and batched paths.  Returns
    ``(matvec, dot, precondition, batch_native)``."""
    batch_native = False
    if hasattr(matvec, "matvec"):
        op = matvec
        matvec = op.matvec
        dot = dot or getattr(op, "dot", None)
        batch_native = bool(getattr(op, "batch_native", False))
        if precondition == "jacobi":
            precondition = jacobi_preconditioner(op.diag())
        elif precondition == "block_jacobi":
            bj = getattr(op, "block_jacobi_preconditioner", None)
            if bj is None:
                raise ValueError(
                    "precondition='block_jacobi' needs an Operator with "
                    "per-PU blocks (DistributedOperator); "
                    f"{type(op).__name__} has none")
            precondition = bj()
    else:
        batch_native = bool(getattr(matvec, "batch_native", False))
    if isinstance(precondition, str):
        raise ValueError(f"precondition={precondition!r} needs an Operator "
                         "(jacobi: any backend with diag(); block_jacobi: "
                         "distributed backends); pass a callable M^-1 "
                         "instead")
    return matvec, dot, precondition, batch_native


def _cg_solve_batched(matvec, b, x0, tol, max_iters, dot, M,
                      batch_native) -> CGResult:
    """Multi-RHS CG: all columns advance in one loop; converged columns
    freeze (alpha/beta masked to zero) while stragglers iterate.

    ``matvec``/``M`` are single-column callables unless ``batch_native``
    (operators whose matvec carries the trailing batch axis through
    natively, e.g. the distributed halo schedules — vmap cannot cross
    their ppermute rounds on every supported JAX); ``dot`` is the
    single-column inner product and is vmapped over columns, so the
    distributed psum-reduced dot batches without modification.
    """
    nb = b.shape[-1]
    mv = matvec if batch_native else jax.vmap(matvec, in_axes=-1,
                                              out_axes=-1)
    dot = dot or (lambda u, v: jnp.vdot(u, v))
    dotb = jax.vmap(dot, in_axes=-1, out_axes=0)       # (..., nb) -> (nb,)
    Mb = None
    if M is not None:
        Mb = M if batch_native and getattr(M, "batch_native", False) \
            else jax.vmap(M, in_axes=-1, out_axes=-1)

    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - mv(x)
    tol2 = _tol2_floor(tol, dotb(b, b))                # (nb,)
    z = Mb(r) if Mb is not None else r
    p = z
    rz = dotb(r, z)
    rr = dotb(r, r)
    it = jnp.zeros((nb,), jnp.int32)

    def active(rr, it):
        return (rr > tol2) & (it < max_iters)

    def cond(state):
        _, _, _, _, rr, it = state
        return jnp.any(active(rr, it))

    def body(state):
        x, r, p, rz, rr, it = state
        act = active(rr, it)                           # (nb,) column masks
        ap = mv(p)
        # masked alpha: converged columns take a zero step, so their
        # x/r stay frozen while active columns advance (trailing-axis
        # broadcasting aligns the (nb,) scalars with (..., nb) vectors)
        alpha = jnp.where(act, _safe_div(rz, dotb(p, ap)), 0)
        x = x + alpha * p
        r = r - alpha * ap
        z = Mb(r) if Mb is not None else r
        rz_new = dotb(r, z)
        beta = jnp.where(act, _safe_div(rz_new, rz), 0)
        p = jnp.where(act, z + beta * p, p)
        rz = jnp.where(act, rz_new, rz)
        rr = jnp.where(act, dotb(r, r), rr)
        return x, r, p, rz, rr, it + act.astype(jnp.int32)

    x, r, p, rz, rr, it = jax.lax.while_loop(
        cond, body, (x, r, p, rz, rr, it))
    return CGResult(x=x, iters=it, residual=jnp.sqrt(rr))


def cg_solve(matvec: Callable[[jnp.ndarray], jnp.ndarray], b: jnp.ndarray,
             x0: jnp.ndarray | None = None, tol: float = 1e-6,
             max_iters: int = 500,
             dot: Callable | None = None,
             precondition: str | Callable | None = None,
             batched: bool = False) -> CGResult:
    """CG / preconditioned CG.  ``matvec`` is either a callable or an
    Operator (``matvec``/``dot`` attributes); ``dot`` may be overridden
    for distributed use (e.g. a psum-reduced local dot inside shard_map).

    ``precondition`` is ``None`` (plain CG), a callable ``z = M^-1(r)``,
    or a string — ``'jacobi'`` resolves through the Operator's ``diag()``
    (every backend carries its diagonal on-device) and ``'block_jacobi'``
    through the Operator's ``block_jacobi_preconditioner()`` (per-PU
    diagonal blocks; distributed backends only).  Convergence is always
    tested on the *unpreconditioned* residual ||r||^2 <= tol^2 ||b||^2, so
    preconditioning changes the iteration count, never the stop quality.

    ``batched=True`` treats the *last* axis of ``b`` as an RHS batch and
    runs the multi-RHS loop with per-column convergence masks (see module
    docstring); ``matvec``/``dot``/``precondition`` stay single-column —
    they are vmapped over the batch axis unless the operator declares
    ``batch_native`` (the distributed backends, whose schedules carry the
    batch axis through natively).
    """
    matvec, dot, precondition, batch_native = _resolve_operator(
        matvec, dot, precondition)
    if batched:
        return _cg_solve_batched(matvec, b, x0, tol, max_iters, dot,
                                 precondition, batch_native)
    dot = dot or (lambda u, v: jnp.vdot(u, v))
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - matvec(x)
    tol2 = _tol2_floor(tol, dot(b, b))

    if precondition is not None:
        M = precondition
        z = M(r)
        p = z
        rz = dot(r, z)
        rr = dot(r, r)

        def cond(state):
            return (state[4] > tol2) & (state[5] < max_iters)

        def body(state):
            x, r, p, rz, rr, it = state
            ap = matvec(p)
            alpha = _safe_div(rz, dot(p, ap))
            x = x + alpha * p
            r = r - alpha * ap
            z = M(r)
            rz_new = dot(r, z)
            p = z + _safe_div(rz_new, rz) * p
            return x, r, p, rz_new, dot(r, r), it + 1

        x, r, p, rz, rr, it = jax.lax.while_loop(
            cond, body, (x, r, p, rz, rr, jnp.zeros((), jnp.int32)))
        return CGResult(x=x, iters=it, residual=jnp.sqrt(rr))

    p = r
    rs = dot(r, r)

    def cond(state):
        _, _, _, rs, it = state
        return (rs > tol2) & (it < max_iters)

    def body(state):
        x, r, p, rs, it = state
        ap = matvec(p)
        alpha = _safe_div(rs, dot(p, ap))
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = dot(r, r)
        p = r + _safe_div(rs_new, rs) * p
        return x, r, p, rs_new, it + 1

    x, r, p, rs, it = jax.lax.while_loop(
        cond, body, (x, r, p, rs, jnp.zeros((), jnp.int32)))
    return CGResult(x=x, iters=it, residual=jnp.sqrt(rs))
