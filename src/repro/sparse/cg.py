"""Conjugate-gradient solver (Sec. VI-a: 'CG solver from LAMA ... applied to
systems derived from the graph's Laplacian') — JAX, lax.while_loop.

The operator is passed either as a bare matvec closure or as an
``operator.Operator`` (anything with ``matvec`` / ``dot``), so the same
solver drives the single-device padded-COO SpMV, the Pallas block-ELL
kernel, and the distributed shard_map SpMV — one solver, one benchmark
harness, every backend.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class CGResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray
    residual: jnp.ndarray


def jacobi_preconditioner(diag: jnp.ndarray) -> Callable:
    """M^-1 r = r / diag(A), with zero diagonal entries (padded ghost rows
    in the distributed layout) passed through as zero — ghost residuals are
    exactly zero, so this keeps them out of the Krylov space."""
    safe = jnp.where(diag != 0, diag, 1.0)
    inv = jnp.where(diag != 0, 1.0 / safe, 0.0)

    def apply(r):
        return r * inv

    return apply


def cg_solve(matvec: Callable[[jnp.ndarray], jnp.ndarray], b: jnp.ndarray,
             x0: jnp.ndarray | None = None, tol: float = 1e-6,
             max_iters: int = 500,
             dot: Callable | None = None,
             precondition: str | Callable | None = None) -> CGResult:
    """CG / preconditioned CG.  ``matvec`` is either a callable or an
    Operator (``matvec``/``dot`` attributes); ``dot`` may be overridden
    for distributed use (e.g. a psum-reduced local dot inside shard_map).

    ``precondition`` is ``None`` (plain CG), a callable ``z = M^-1(r)``,
    or a string — ``'jacobi'`` resolves through the Operator's ``diag()``
    (every backend carries its diagonal on-device) and ``'block_jacobi'``
    through the Operator's ``block_jacobi_preconditioner()`` (per-PU
    diagonal blocks; distributed backends only).  Convergence is always
    tested on the *unpreconditioned* residual ||r||^2 <= tol^2 ||b||^2, so
    preconditioning changes the iteration count, never the stop quality.
    """
    if hasattr(matvec, "matvec"):
        op = matvec
        matvec = op.matvec
        dot = dot or getattr(op, "dot", None)
        if precondition == "jacobi":
            precondition = jacobi_preconditioner(op.diag())
        elif precondition == "block_jacobi":
            bj = getattr(op, "block_jacobi_preconditioner", None)
            if bj is None:
                raise ValueError(
                    "precondition='block_jacobi' needs an Operator with "
                    "per-PU blocks (DistributedOperator); "
                    f"{type(op).__name__} has none")
            precondition = bj()
    if isinstance(precondition, str):
        raise ValueError(f"precondition={precondition!r} needs an Operator "
                         "(jacobi: any backend with diag(); block_jacobi: "
                         "distributed backends); pass a callable M^-1 "
                         "instead")
    dot = dot or (lambda u, v: jnp.vdot(u, v))
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - matvec(x)
    b2 = dot(b, b)
    tol2 = tol * tol * jnp.maximum(b2, 1e-30)

    if precondition is not None:
        M = precondition
        z = M(r)
        p = z
        rz = dot(r, z)
        rr = dot(r, r)

        def cond(state):
            return (state[4] > tol2) & (state[5] < max_iters)

        def body(state):
            x, r, p, rz, rr, it = state
            ap = matvec(p)
            alpha = rz / (dot(p, ap) + 1e-30)
            x = x + alpha * p
            r = r - alpha * ap
            z = M(r)
            rz_new = dot(r, z)
            p = z + (rz_new / (rz + 1e-30)) * p
            return x, r, p, rz_new, dot(r, r), it + 1

        x, r, p, rz, rr, it = jax.lax.while_loop(
            cond, body, (x, r, p, rz, rr, jnp.zeros((), jnp.int32)))
        return CGResult(x=x, iters=it, residual=jnp.sqrt(rr))

    p = r
    rs = dot(r, r)

    def cond(state):
        _, _, _, rs, it = state
        return (rs > tol2) & (it < max_iters)

    def body(state):
        x, r, p, rs, it = state
        ap = matvec(p)
        alpha = rs / (dot(p, ap) + 1e-30)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = dot(r, r)
        p = r + (rs_new / (rs + 1e-30)) * p
        return x, r, p, rs_new, it + 1

    x, r, p, rs, it = jax.lax.while_loop(
        cond, body, (x, r, p, rs, jnp.zeros((), jnp.int32)))
    return CGResult(x=x, iters=it, residual=jnp.sqrt(rs))
