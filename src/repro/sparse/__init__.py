"""Sparse matrix / graph substrate.

Public surface:

  * ``graph`` / ``generators`` — CSR graphs and the paper's instance
    families (Table II);
  * ``spmv`` / ``kernels.spmv_bell`` — single-device SpMV backends;
  * ``distributed`` — partition-aware shard_map SpMV/CG (halo exchange);
  * ``operator``   — the Operator protocol unifying every backend behind
    ``make_operator`` + ``cg_solve_global`` (see its module docstring);
  * ``cg``         — the one CG solver all backends share;
  * ``replan``     — O(delta) incremental plan patching for streaming
    graphs (``EdgeDelta`` / ``apply_edge_delta`` / ``migrate_state``).
"""
from .cg import CGResult, cg_solve, jacobi_preconditioner
from .distributed import (DistPlan, HierPlan, TreePlan, build_plan,
                          build_plan_hier, build_plan_tree)
from .operator import (BACKENDS, BlockEllOperator, CooOperator,
                       DistributedOperator, Operator, make_operator,
                       cg_solve_global)
from .replan import (EdgeDelta, apply_delta_csr, apply_edge_delta,
                     migrate_state)

__all__ = ["CGResult", "cg_solve", "jacobi_preconditioner", "BACKENDS",
           "Operator", "CooOperator", "BlockEllOperator",
           "DistributedOperator", "make_operator", "cg_solve_global",
           "DistPlan", "HierPlan", "TreePlan", "build_plan",
           "build_plan_hier", "build_plan_tree", "EdgeDelta",
           "apply_delta_csr", "apply_edge_delta", "migrate_state"]
