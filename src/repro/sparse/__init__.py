"""Sparse matrix / graph substrate.

Public surface:

  * ``graph`` / ``generators`` — CSR graphs and the paper's instance
    families (Table II);
  * ``spmv`` / ``kernels.spmv_bell`` — single-device SpMV backends;
  * ``distributed`` — partition-aware shard_map SpMV/CG (halo exchange);
  * ``operator``   — the Operator protocol unifying every backend behind
    ``make_operator`` + ``cg_solve_global`` (see its module docstring);
  * ``cg``         — the one CG solver all backends share.
"""
from .cg import CGResult, cg_solve, jacobi_preconditioner
from .distributed import (DistPlan, HierPlan, TreePlan, build_plan,
                          build_plan_hier, build_plan_tree)
from .operator import (BACKENDS, BlockEllOperator, CooOperator,
                       DistributedOperator, Operator, make_operator,
                       cg_solve_global)

__all__ = ["CGResult", "cg_solve", "jacobi_preconditioner", "BACKENDS",
           "Operator", "CooOperator", "BlockEllOperator",
           "DistributedOperator", "make_operator", "cg_solve_global",
           "DistPlan", "HierPlan", "TreePlan", "build_plan",
           "build_plan_hier", "build_plan_tree"]
