"""Sparse matrix / graph substrate."""
