"""stablelm-3b [dense] — hf:stabilityai/stablelm family.
32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense", n_layers=32, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=6912, vocab=50304,
    norm="layernorm",
)

SMOKE = ModelConfig(
    name="stablelm-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=176, vocab=256, norm="layernorm",
    dtype="float32",
)
