"""recurrentgemma-2b [hybrid] — RG-LRU + local attention 1:2,
arXiv:2402.19427.  26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000, window=2048."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_ff=7680, vocab=256000, head_dim=256,
    pattern=("rec", "rec", "attn"), window=2048, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="rgemma-smoke", family="hybrid", n_layers=5, d_model=64,
    n_heads=4, n_kv_heads=1, d_ff=176, vocab=256, head_dim=16,
    pattern=("rec", "rec", "attn"), window=16, tie_embeddings=True,
    dtype="float32",
)
