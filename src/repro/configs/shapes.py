"""Assigned input shapes (LM-family): seq_len x global_batch per mode.

``decode_*`` / ``long_*`` lower serve_step (one new token against a KV cache
of seq_len), NOT train_step.  ``long_500k`` requires sub-quadratic decode
state and is only run for SSM/hybrid archs (cfg.sub_quadratic).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    mode: str            # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg, shape: Shape) -> bool:
    """long_500k only for sub-quadratic archs (see DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True
