"""qwen2.5-14b [dense] — hf:Qwen/Qwen2.5-14B family.  GQA, QKV bias.
48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=13824, vocab=152064, qkv_bias=True,
    head_dim=128, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke", family="dense", n_layers=3, d_model=80,
    n_heads=5, n_kv_heads=1, d_ff=216, vocab=256, qkv_bias=True,
    head_dim=16, dtype="float32",
)
