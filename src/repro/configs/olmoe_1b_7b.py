"""olmoe-1b-7b [moe] — arXiv:2409.02060.  64 experts top-8.
16L d_model=2048 16H (GQA kv=16) d_expert=1024 vocab=50304."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=0, vocab=50304,
    n_experts=64, top_k=8, d_expert=1024,
)

SMOKE = ModelConfig(
    name="olmoe-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=256,
    n_experts=8, top_k=2, d_expert=32, dtype="float32",
)
