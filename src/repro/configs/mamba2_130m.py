"""mamba2-130m [ssm] — SSD (state-space duality), arXiv:2405.21060.
24L d_model=768, attention-free, vocab=50280, ssm_state=128."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
    n_heads=24, n_kv_heads=24, d_ff=0, vocab=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, conv_kernel=4,
    tie_embeddings=True, norm="rmsnorm",
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm", n_layers=2, d_model=64,
    n_heads=2, n_kv_heads=2, d_ff=0, vocab=128,
    ssm_state=16, ssm_headdim=16, tie_embeddings=True, dtype="float32",
)
