"""internvl2-76b [vlm] — InternViT + InternLM2 backbone, arXiv:2404.16821.
80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (n_img_tokens, d_model) that replace the sequence prefix."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=28672, vocab=128256, head_dim=128,
    n_img_tokens=256,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=176, vocab=256, head_dim=16,
    n_img_tokens=8, dtype="float32",
)
