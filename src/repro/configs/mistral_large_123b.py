"""mistral-large-123b [dense] — hf:mistralai/Mistral-Large-Instruct-2407.
88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense", n_layers=88, d_model=12288,
    n_heads=96, n_kv_heads=8, d_ff=28672, vocab=32768, head_dim=128,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="mistral-smoke", family="dense", n_layers=3, d_model=96,
    n_heads=6, n_kv_heads=2, d_ff=224, vocab=128, head_dim=16,
    dtype="float32",
)
