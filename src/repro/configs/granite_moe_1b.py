"""granite-moe-1b-a400m [moe] — hf:ibm-granite/granite-3.0-1b-a400m-base.
32 experts top-8.  24L d_model=1024 16H (GQA kv=8) d_expert=512
vocab=49155."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=8, d_ff=0, vocab=49155,
    n_experts=32, top_k=8, d_expert=512, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=0, vocab=256,
    n_experts=4, top_k=2, d_expert=32, tie_embeddings=True,
    dtype="float32",
)
