"""Architecture registry: --arch <id> -> (full config, smoke config)."""
from __future__ import annotations

import importlib

_MODULES = {
    "mamba2-130m": "mamba2_130m",
    "mistral-large-123b": "mistral_large_123b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "qwen2.5-14b": "qwen2_5_14b",
    "stablelm-3b": "stablelm_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "internvl2-76b": "internvl2_76b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "whisper-tiny": "whisper_tiny",
}

ARCHS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.SMOKE if smoke else mod.CONFIG
