"""whisper-tiny [audio] — arXiv:2212.04356.  Enc-dec; conv frontend is a
STUB (input_specs() provides precomputed frame embeddings).
4L d_model=384 6H d_ff=1536 vocab=51865.

Fidelity note: real whisper-tiny caps the decoder context at 448; max_seq is
raised here so the assigned decode_32k cache shape is exercised (DESIGN.md).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio", n_layers=4, d_model=384,
    n_heads=6, n_kv_heads=6, d_ff=1536, vocab=51865, enc_layers=4,
    n_frames=1500, norm="layernorm", activation="gelu",
    tie_embeddings=True, max_seq=32768,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=176, vocab=256, enc_layers=2,
    n_frames=32, norm="layernorm", activation="gelu",
    tie_embeddings=True, max_seq=64, dtype="float32",
)
