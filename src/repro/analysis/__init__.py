"""Static analysis for the distributed-plan pipeline.

Three passes, all runnable without devices (NumPy + ``ast`` only at
verification time; no mesh, no jit):

  * ``verify``      — structural verifier over ``DistPlan`` / ``TreePlan``
                      invariants (proper colorings, permutation rounds,
                      slot routing, interior/boundary tiling, and an
                      abstract replay of the round schedule that proves
                      every halo slot is written exactly once before any
                      boundary row reads it);
  * ``verify.check_mesh_axes`` — plan-vs-mesh shape checking for the
                      ``comm='hier'`` axis folding plus the per-level
                      ppermute partner table, without real devices;
  * ``lint``        — custom AST lint (rule ids REPRO001+) for the
                      API-drift / determinism / host-sync bug classes
                      that produced earlier PRs' bugfixes;
  * ``trace``       — jaxpr-level auditor (rule ids TRACE001+): every
                      solver program is traced abstractly (no devices,
                      ``compat.abstract_mesh``) and its staged
                      collectives/dtypes are cross-checked against the
                      plan schedule, plus a static per-CG-iteration
                      cost model (:class:`~.trace.TraceCost`) consumed
                      by ``launch.roofline.static_roofline``.

``python -m repro.analysis`` is the CLI (``lint`` / ``verify`` /
``partners`` / ``trace`` subcommands, ``--format=json|github`` for
machine-readable output); ``make lint``, ``make verify-plans`` and
``make trace-audit`` wrap it.  Plan builders run the verifier at build
time under ``REPRO_VALIDATE=1`` (on by default in the test suite via
conftest).
"""
from .diagnostics import Diagnostic, PlanVerificationError, Report
from .lint import LINT_RULES, lint_paths
from .trace import (TRACE_RULES, TraceCost, audit_backend, audit_jaxpr,
                    audit_operator)
from .verify import (check_mesh_axes, partner_table, verify_partition,
                     verify_plan)

__all__ = [
    "Diagnostic", "PlanVerificationError", "Report",
    "verify_plan", "verify_partition", "check_mesh_axes", "partner_table",
    "lint_paths", "LINT_RULES",
    "audit_jaxpr", "audit_operator", "audit_backend",
    "TRACE_RULES", "TraceCost",
]
