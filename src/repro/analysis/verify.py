"""Static plan/schedule verifier — structural checks over ``DistPlan`` /
``TreePlan`` invariants, runnable without devices.

The paper's pipeline stands or falls on plan correctness: a mis-colored
round or a mis-routed halo slot silently produces wrong numerics.  What
matters is the schedule *actually executed per PU* (Langguth/Schlag/
Schulz), so this pass proves structural properties of the built plan
itself, not the modeled objective:

  ==========  ============================================================
  code        invariant
  ==========  ============================================================
  PLAN001     metadata: sizes/B/n consistency, ``perm`` is a permutation
              of padded ids, ``row_mask`` matches ``sizes``, packed nnz
              bookkeeping agrees with ``nnz_blk``
  PLAN002     level structure: ``fanouts`` multiply to k, per-level
              schedule tuples are mutually sized, ``level_offsets`` tile
              the extended vector, the ancestor table matches the
              tree-major mixed radix
  PLAN003     proper coloring: each round of each level's quotient
              schedule is a matching (no node talks to two partners in
              one round) and is bidirectional
  PLAN004     permutation rounds: every ``round_perms*`` entry has
              distinct sources, distinct destinations, in-range nodes
  PLAN005     send schedule: masked ``send_idx`` entries address real
              (non-ghost) local rows
  PLAN006     write-write race: abstract replay of the comm schedule
              delivers every halo slot at most once
  PLAN007     read-before-write: every halo slot read by a real edge was
              written by the replay, reads stay inside the extended
              vector, level-l boundary rows never read a slower level's
              slot range, local reads never address ghost rows
  PLAN008     tiling: interior + per-level boundary segments exactly
              tile the flat packed nnz set per block (multiset-exact),
              segment padding is zero, ``interior_mask`` agrees
  PLAN009     routing: the replayed content of every halo slot is
              exactly the vertex each packed edge expects (catches slot
              aliasing that is self-consistent enough to pass PLAN006/7)
  PLAN010     replan cache (plans built with ``cache=True``): the cached
              host CSR/bookkeeping agrees with the plan it claims to
              patch — same n/k/B, per-block nnz, sorted CSR keys, level
              offsets (a stale cache makes the *next*
              ``apply_edge_delta`` wrong, not this plan)
  ==========  ============================================================

All checks are vectorized NumPy — O(nnz + rounds) plus sorts — and never
index out of bounds on corrupted inputs (range guards first, dependent
checks skipped).  ``check_mesh_axes`` is the mesh/axis companion pass
(MESH0xx): given a plan plus mesh *shape* and axis names (no devices) it
verifies the ``comm='hier'`` axis folding and reports the per-level
ppermute partner table.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .diagnostics import Report


# --------------------------------------------------------------------------
# plan normalization: flat DistPlan and TreePlan as one per-level view
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _Level:
    nq: int                 # quotient node count (suffix size for trees)
    S: int                  # halo slots per round
    R: int                  # colored rounds
    send_idx: np.ndarray    # (k, R, S)
    send_mask: np.ndarray   # (k, R, S)
    perms: tuple            # per round: tuple of (src, dst) quotient pairs


def _is_tree(plan) -> bool:
    return bool(getattr(plan, "fanouts", ()))


def _tree_suffix(fanouts) -> list[int]:
    """suffix[l+1] = prod(fanouts[h-1-l:]) — level l's quotient range."""
    h = len(fanouts)
    suffix = [1] * (h + 1)
    for t in range(h - 1, -1, -1):
        suffix[h - 1 - t + 1] = suffix[h - 1 - t] * int(fanouts[t])
    return suffix


def _levels_of(plan, rep: Report) -> list[_Level] | None:
    """Per-level schedule views, or None when the schedule tuples are too
    malformed to interpret (the shape diagnostics are already in ``rep``)."""
    k = int(plan.k)
    if _is_tree(plan):
        fanouts = tuple(int(f) for f in plan.fanouts)
        h = len(fanouts)
        if int(np.prod(fanouts)) != k:
            rep.add("PLAN002", f"prod(fanouts)={int(np.prod(fanouts))} != "
                               f"k={k}", where="fanouts", fanouts=fanouts)
            return None
        tups = (plan.S_lvl, plan.n_rounds_lvl, plan.send_idx_lvl,
                plan.send_mask_lvl, plan.round_perms_lvl)
        if any(len(t) != h for t in tups):
            rep.add("PLAN002",
                    f"per-level tuples must all have h={h} entries; got "
                    f"lengths {tuple(len(t) for t in tups)} for (S_lvl, "
                    f"n_rounds_lvl, send_idx_lvl, send_mask_lvl, "
                    f"round_perms_lvl)", where="levels")
            return None
        suffix = _tree_suffix(fanouts)
        levels = []
        for l in range(h):
            levels.append(_Level(
                nq=suffix[l + 1], S=int(plan.S_lvl[l]),
                R=int(plan.n_rounds_lvl[l]),
                send_idx=np.asarray(plan.send_idx_lvl[l]),
                send_mask=np.asarray(plan.send_mask_lvl[l]),
                perms=tuple(plan.round_perms_lvl[l])))
        return levels
    return [_Level(nq=k, S=int(plan.S), R=int(plan.n_rounds),
                   send_idx=np.asarray(plan.send_idx),
                   send_mask=np.asarray(plan.send_mask),
                   perms=tuple(plan.round_perms))]


def _level_offsets(plan, levels: list[_Level]) -> np.ndarray:
    """(h+1,) slot-range boundaries; ``offs[0] == B`` (flat and tree)."""
    sizes = [lv.R * lv.S for lv in levels]
    return int(plan.B) + np.concatenate(
        [[0], np.cumsum(sizes)]).astype(np.int64)


# --------------------------------------------------------------------------
# individual passes
# --------------------------------------------------------------------------

def _check_metadata(plan, rep: Report) -> bool:
    k, B, n = int(plan.k), int(plan.B), int(plan.n)
    ok = True
    if k <= 0 or B <= 0 or n <= 0:
        rep.add("PLAN001", f"k={k}, B={B}, n={n} must be positive")
        return False
    sizes = np.asarray(plan.sizes)
    if sizes.shape != (k,):
        rep.add("PLAN001", f"sizes has shape {sizes.shape}, want ({k},)")
        return False
    if int(sizes.sum()) != n:
        rep.add("PLAN001", f"sizes sum to {int(sizes.sum())} != n={n}")
        ok = False
    if sizes.max(initial=0) > B:
        rep.add("PLAN001", f"max block size {int(sizes.max())} exceeds "
                           f"B={B}")
        ok = False
    perm = np.asarray(plan.perm)
    if perm.shape != (n,):
        rep.add("PLAN001", f"perm has shape {perm.shape}, want ({n},)")
        return ok and False
    blk, rank = perm // B, perm % B
    if perm.min(initial=0) < 0 or (blk >= k).any():
        rep.add("PLAN001", "perm contains padded ids outside [0, k*B)")
        ok = False
    elif (rank >= sizes[blk]).any():
        bad = int(np.flatnonzero(rank >= sizes[blk])[0])
        rep.add("PLAN001", f"perm[{bad}] addresses ghost row "
                           f"{int(rank[bad])} of block {int(blk[bad])} "
                           f"(size {int(sizes[blk[bad]])})")
        ok = False
    if len(np.unique(perm)) != n:
        rep.add("PLAN001", "perm is not injective (two vertices share a "
                           "padded id)")
        ok = False
    row_mask = np.asarray(plan.row_mask)
    want = (np.arange(B)[None, :] < sizes[:, None]).astype(row_mask.dtype)
    if row_mask.shape != (k, B) or not np.array_equal(row_mask, want):
        rep.add("PLAN001", "row_mask does not mark exactly the first "
                           "sizes[b] rows of each block")
        ok = False
    nnz_blk = getattr(plan, "nnz_blk", None)
    pack_blk = getattr(plan, "_pack_blk", None)
    if nnz_blk is not None and pack_blk is not None:
        have = np.bincount(np.asarray(pack_blk), minlength=k)
        if not np.array_equal(have, np.asarray(nnz_blk)):
            rep.add("PLAN001", "nnz_blk disagrees with the packed edge "
                               "ownership (_pack_blk)")
            ok = False
    return ok


def _check_level_structure(plan, levels: list[_Level],
                           rep: Report) -> bool:
    k = int(plan.k)
    ok = True
    for l, lv in enumerate(levels):
        where = f"level {l}"
        if lv.S < 1 or lv.R < 0:
            rep.add("PLAN002", f"S={lv.S} (want >= 1), R={lv.R} "
                               f"(want >= 0)", where=where)
            ok = False
            continue
        for name, arr in (("send_idx", lv.send_idx),
                          ("send_mask", lv.send_mask)):
            if arr.shape != (k, lv.R, lv.S):
                rep.add("PLAN002",
                        f"{name} has shape {arr.shape}, want "
                        f"({k}, {lv.R}, {lv.S})", where=where)
                ok = False
        if len(lv.perms) != lv.R:
            rep.add("PLAN002", f"round_perms has {len(lv.perms)} rounds, "
                               f"want R={lv.R}", where=where)
            ok = False
        if k % lv.nq:
            rep.add("PLAN002", f"quotient size {lv.nq} does not divide "
                               f"k={k}", where=where)
            ok = False
    if _is_tree(plan):
        anc = getattr(plan, "anc", None)
        h = len(levels)
        if anc is not None:
            anc = np.asarray(anc)
            suffix = _tree_suffix(plan.fanouts)
            dev = np.arange(k, dtype=np.int64)
            want = (np.stack([dev // suffix[h - 1 - t]
                              for t in range(h - 1)])
                    if h > 1 else np.zeros((0, k), np.int64))
            if anc.shape != want.shape or not np.array_equal(anc, want):
                rep.add("PLAN002", "ancestor table does not match the "
                                   "tree-major mixed radix of fanouts "
                                   f"{tuple(plan.fanouts)}", where="anc")
                ok = False
    return ok


def _check_rounds(levels: list[_Level], rep: Report) -> None:
    for l, lv in enumerate(levels):
        for c, pairs in enumerate(lv.perms[:lv.R]):
            where = f"level {l} round {c}"
            srcs = [a for a, _ in pairs]
            dsts = [b for _, b in pairs]
            bad = [p for p in pairs
                   if not (0 <= p[0] < lv.nq and 0 <= p[1] < lv.nq)]
            if bad:
                rep.add("PLAN004", f"pairs {bad} outside quotient range "
                                   f"[0, {lv.nq})", where=where)
            if any(a == b for a, b in pairs):
                rep.add("PLAN004", "self-pair (a, a) in ppermute round",
                        where=where)
            if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
                rep.add("PLAN004",
                        "round is not a permutation: duplicate source or "
                        "destination node (ppermute delivery is undefined)",
                        where=where, pairs=tuple(pairs))
                continue
            # matching on the undirected quotient graph = proper coloring
            und = {(min(a, b), max(a, b)) for a, b in pairs}
            touched: dict[int, tuple] = {}
            for e in und:
                for node in e:
                    if node in touched and touched[node] != e:
                        rep.add("PLAN003",
                                f"node {node} talks to two partners in one "
                                f"round ({touched[node]} and {e}) — the "
                                "edge coloring is not proper on the "
                                "quotient graph", where=where)
                        break
                    touched[node] = e
            asym = [(a, b) for a, b in pairs if (b, a) not in set(pairs)]
            if asym:
                rep.add("PLAN003", f"one-directional pairs {asym}: the "
                                   "exchange schedule must be "
                                   "bidirectional", where=where)


def _check_send_schedule(plan, levels: list[_Level], rep: Report) -> None:
    sizes = np.asarray(plan.sizes)
    for l, lv in enumerate(levels):
        if lv.send_idx.shape != (plan.k, lv.R, lv.S):
            continue                       # shape already diagnosed
        live = lv.send_mask > 0
        idx = lv.send_idx
        bad = live & ((idx < 0) | (idx >= sizes[:, None, None]))
        if bad.any():
            b, c, s = (int(x[0]) for x in np.nonzero(bad))
            rep.add("PLAN005",
                    f"block {b} sends local row {int(idx[b, c, s])} in "
                    f"round {c} slot {s}, but only {int(sizes[b])} rows "
                    "are real (ghost-row send)", where=f"level {l}",
                    count=int(bad.sum()))


def _replay(plan, levels: list[_Level], offs: np.ndarray, rep: Report):
    """Abstract replay of the comm schedule.

    Returns ``(content, writes)``: ``content[b, j]`` is the padded global
    id (blk*B + rank) of the vertex whose value position ``j`` of block
    ``b``'s extended vector holds after all rounds (-1 = never written),
    ``writes[b, j]`` how many masked sends were delivered there — the
    write-write race detector (PLAN006).
    """
    k, B = int(plan.k), int(plan.B)
    ext_len = int(offs[-1])
    content = np.full((k, ext_len), -1, dtype=np.int64)
    content[:, :B] = np.arange(k, dtype=np.int64)[:, None] * B + np.arange(B)
    writes = np.zeros((k, ext_len), dtype=np.int32)
    dev_base = np.arange(k, dtype=np.int64)[:, None] * B
    for l, lv in enumerate(levels):
        if (lv.send_idx.shape != (k, lv.R, lv.S) or k % lv.nq
                or len(lv.perms) < lv.R):
            continue                       # shape already diagnosed
        n_sub = k // lv.nq
        for c in range(lv.R):
            send_val = np.where(lv.send_mask[:, c] > 0,
                                dev_base + lv.send_idx[:, c], -1)
            lo = int(offs[l]) + c * lv.S
            for a, b in lv.perms[c]:
                if not (0 <= a < lv.nq and 0 <= b < lv.nq):
                    continue               # PLAN004 already flagged
                for p in range(n_sub):
                    src, dst = p * lv.nq + a, p * lv.nq + b
                    sv = send_val[src]
                    live = sv >= 0
                    writes[dst, lo:lo + lv.S] += live
                    content[dst, lo:lo + lv.S] = np.where(
                        live, sv, content[dst, lo:lo + lv.S])
    races = writes > 1
    if races.any():
        b, j = (int(x[0]) for x in np.nonzero(races))
        rep.add("PLAN006",
                f"halo slot {j} of block {b} is written "
                f"{int(writes[b, j])} times — write-write race on the "
                "comm schedule", count=int(races.sum()))
    return content, writes


def _check_reads(plan, offs: np.ndarray, writes: np.ndarray,
                 rep: Report) -> None:
    k, B = int(plan.k), int(plan.B)
    ext_len = int(offs[-1])
    cols = np.asarray(plan.cols)
    nnz_blk = np.asarray(plan.nnz_blk)
    valid = np.arange(cols.shape[1])[None, :] < nnz_blk[:, None]
    out = valid & ((cols < 0) | (cols >= ext_len))
    if out.any():
        b, e = (int(x[0]) for x in np.nonzero(out))
        rep.add("PLAN007", f"edge {e} of block {b} reads column "
                           f"{int(cols[b, e])}, outside the extended "
                           f"vector [0, {ext_len})", count=int(out.sum()))
    sizes = np.asarray(plan.sizes)
    ghost = valid & (cols >= 0) & (cols < B) & (cols >= sizes[:, None])
    if ghost.any():
        b, e = (int(x[0]) for x in np.nonzero(ghost))
        rep.add("PLAN007", f"edge {e} of block {b} reads local ghost row "
                           f"{int(cols[b, e])} (block has "
                           f"{int(sizes[b])} real rows)",
                count=int(ghost.sum()))
    halo = valid & (cols >= B) & (cols < ext_len)
    wr = writes[np.arange(k)[:, None], np.clip(cols, 0, ext_len - 1)]
    unread = halo & (wr == 0)
    if unread.any():
        b, e = (int(x[0]) for x in np.nonzero(unread))
        rep.add("PLAN007",
                f"edge {e} of block {b} reads halo slot "
                f"{int(cols[b, e])} which no round ever writes "
                "(read-before-write)", count=int(unread.sum()))


def _segments_of(plan):
    """(label, rows, cols, vals, class) per accumulation segment, where
    ``class`` is -1 for interior and the level index for boundary."""
    segs = [("interior", plan.rows_int, plan.cols_int, plan.vals_int, -1)]
    if _is_tree(plan):
        for l in range(len(plan.fanouts)):
            segs.append((f"boundary level {l}", plan.rows_bnd_lvl[l],
                         plan.cols_bnd_lvl[l], plan.vals_bnd_lvl[l], l))
    else:
        segs.append(("boundary", plan.rows_bnd, plan.cols_bnd,
                     plan.vals_bnd, 0))
    return segs


def _check_tiling(plan, offs: np.ndarray, rep: Report) -> None:
    """Interior + per-level boundary segments exactly tile the flat packed
    nnz set (PLAN008), and each segment reads only its own and faster
    levels' slot ranges (the read-ordering half of PLAN007)."""
    k, B = int(plan.k), int(plan.B)
    ext_len = int(offs[-1])
    rows_a = np.asarray(plan.rows)
    cols_a = np.asarray(plan.cols)
    vals_a = np.asarray(plan.vals)
    nnz_blk = np.asarray(plan.nnz_blk)
    valid = np.arange(rows_a.shape[1])[None, :] < nnz_blk[:, None]
    if (valid & ((rows_a < 0) | (rows_a >= B))).any():
        rep.add("PLAN008", "flat packed rows outside [0, B); skipping "
                           "segment tiling")
        return
    # per-edge slot level from the flat plan (-1 local), per-row class =
    # highest level read — the independent reconstruction the segments
    # are compared against
    edge_lvl = np.searchsorted(offs, np.clip(cols_a, 0, ext_len - 1),
                               side="right") - 1
    row_lvl = np.full((k, B), -1, dtype=np.int64)
    bi, ei = np.nonzero(valid)
    np.maximum.at(row_lvl, (bi, rows_a[bi, ei]), edge_lvl[bi, ei])
    row_lvl_of_edge = row_lvl[np.arange(k)[:, None], rows_a]

    segs = _segments_of(plan)
    for label, r, c, v, cls in segs:
        r, c, v = np.asarray(r), np.asarray(c), np.asarray(v)
        if r.shape[0] != k or c.shape != r.shape or v.shape != r.shape:
            rep.add("PLAN008", f"{label} segment arrays are mis-shaped "
                               f"({r.shape}, {c.shape}, {v.shape})")
            continue
        sel = valid & (row_lvl_of_edge == cls)
        counts = sel.sum(axis=1)
        if int(counts.max(initial=0)) > r.shape[1]:
            rep.add("PLAN008", f"{label} segment is narrower than its "
                               f"class ({r.shape[1]} < "
                               f"{int(counts.max())})")
            continue
        for b in range(k):
            cnt = int(counts[b])
            exp = np.stack([rows_a[b, sel[b]], cols_a[b, sel[b]],
                            vals_a[b, sel[b]].view(np.int32)])
            got = np.stack([r[b, :cnt], c[b, :cnt],
                            v[b, :cnt].view(np.int32)])
            exp = exp[:, np.lexsort(exp)]
            got = got[:, np.lexsort(got)]
            if not np.array_equal(exp, got):
                rep.add("PLAN008",
                        f"{label} segment of block {b} is not the "
                        "(row, col, val) multiset of the flat edges in "
                        "its class", where=f"block {b}")
                break
            if (r[b, cnt:].any() or c[b, cnt:].any() or v[b, cnt:].any()):
                rep.add("PLAN008", f"{label} segment of block {b} has "
                                   "nonzero padding beyond its class "
                                   f"count {cnt}", where=f"block {b}")
        # read-ordering: a class-`cls` row waits only on levels <= cls,
        # so any real read past offs[cls+1] races the slower exchange
        limit = int(offs[cls + 1])
        pos = np.arange(r.shape[1])[None, :] < counts[:, None]
        late = pos & (c >= limit)
        if late.any():
            b, e = (int(x[0]) for x in np.nonzero(late))
            rep.add("PLAN007",
                    f"{label} segment of block {b} reads column "
                    f"{int(c[b, e])} >= {limit}: the accumulation does "
                    "not wait for that level's exchange "
                    "(read-before-write)", count=int(late.sum()))
    interior_mask = np.asarray(plan.interior_mask)
    sizes = np.asarray(plan.sizes)
    want = ((np.arange(B)[None, :] < sizes[:, None]) & (row_lvl < 0))
    if not np.array_equal(interior_mask.astype(bool), want):
        rep.add("PLAN008", "interior_mask does not equal "
                           "row_mask AND (row reads no halo slot)")


def _check_routing(plan, offs: np.ndarray, content: np.ndarray,
                   rep: Report) -> None:
    pb = getattr(plan, "_pack_blk", None)
    pp = getattr(plan, "_pack_pos", None)
    pd = getattr(plan, "_pack_dst", None)
    if pb is None or pp is None or pd is None:
        rep.info["routing"] = ("skipped: plan carries no packed-edge "
                               "provenance (_pack_blk/_pack_pos/_pack_dst)")
        return
    k, B = int(plan.k), int(plan.B)
    ext_len = int(offs[-1])
    cols_a = np.asarray(plan.cols)
    pb, pp, pd = (np.asarray(a) for a in (pb, pp, pd))
    if (pb < 0).any() or (pb >= k).any() or (pp < 0).any() \
            or (pp >= cols_a.shape[1]).any():
        rep.add("PLAN001", "_pack_blk/_pack_pos address cells outside the "
                           "packed arrays")
        return
    col = cols_a[pb, pp]
    expect = np.asarray(plan.perm)[pd]
    local = (col >= 0) & (col < B)
    got = np.where(local, pb * B + col,
                   content[pb, np.clip(col, 0, ext_len - 1)])
    got = np.where((col < 0) | (col >= ext_len), -1, got)
    bad = got != expect
    if bad.any():
        i = int(np.flatnonzero(bad)[0])
        rep.add("PLAN009",
                f"edge {i} (block {int(pb[i])}, col {int(col[i])}) reads "
                f"padded id {int(got[i])} but its destination vertex "
                f"{int(pd[i])} lives at padded id {int(expect[i])} — "
                "mis-routed or aliased halo slot", count=int(bad.sum()))


def _check_replan_cache(plan, offs: np.ndarray, rep: Report) -> None:
    """Consistency of the incremental-replanning cache carried by plans
    built with ``cache=True`` (PLAN010).  The cache is host bookkeeping
    for :func:`repro.sparse.replan.apply_edge_delta`; a mismatch would
    not make *this* plan wrong, but would corrupt the next patch."""
    cache = getattr(plan, "_replan", None)
    if cache is None:
        return
    n, k, B = int(plan.n), int(plan.k), int(plan.B)
    if (int(cache.n), int(cache.k), int(cache.B)) != (n, k, B):
        rep.add("PLAN010", f"cache (n, k, B)=({cache.n}, {cache.k}, "
                           f"{cache.B}) != plan ({n}, {k}, {B})",
                where="_replan")
        return
    if cache.nnz != int(np.asarray(plan.nnz_blk).sum()):
        rep.add("PLAN010", f"cache holds {cache.nnz} CSR entries; plan's "
                           f"nnz_blk sums to "
                           f"{int(np.asarray(plan.nnz_blk).sum())}",
                where="_replan")
    if not np.array_equal(cache.per_blk, np.asarray(plan.nnz_blk)):
        rep.add("PLAN010", "cache per_blk disagrees with plan nnz_blk",
                where="_replan")
    if cache.part.shape != (n,) or (cache.part.size and (
            cache.part.min() < 0 or cache.part.max() >= k)):
        rep.add("PLAN010", f"cache part shape {cache.part.shape} / values "
                           f"not a valid (n,) block map", where="_replan")
    if len(cache.keys) > 1 and not bool(np.all(np.diff(cache.keys) > 0)):
        rep.add("PLAN010", "cache CSR keys are not strictly increasing "
                           "(non-canonical CSR)", where="_replan")
    if not np.array_equal(cache.offs, offs):
        rep.add("PLAN010", f"cache level offsets {cache.offs.tolist()} != "
                           f"plan level offsets {offs.tolist()}",
                where="_replan")


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------

def verify_plan(plan) -> Report:
    """Run every structural pass over a ``DistPlan`` or ``TreePlan``.

    Pure host-side NumPy; accepts any object with the plan field contract
    (duck-typed — the reference builder's plans verify identically).
    Returns a :class:`Report`; call ``.raise_for_errors()`` to turn
    violations into a :class:`PlanVerificationError`.
    """
    kind = "TreePlan" if _is_tree(plan) else "DistPlan"
    extra = (f", fanouts={tuple(plan.fanouts)}" if _is_tree(plan) else
             f", rounds={int(plan.n_rounds)}")
    rep = Report(subject=f"{kind}(k={plan.k}, B={plan.B}, n={plan.n}"
                         f"{extra})")
    if not _check_metadata(plan, rep):
        return rep
    levels = _levels_of(plan, rep)
    if levels is None:
        return rep
    structure_ok = _check_level_structure(plan, levels, rep)
    _check_rounds(levels, rep)
    _check_send_schedule(plan, levels, rep)
    offs = _level_offsets(plan, levels)
    content, writes = _replay(plan, levels, offs, rep)
    if structure_ok:
        _check_reads(plan, offs, writes, rep)
        _check_tiling(plan, offs, rep)
        _check_routing(plan, offs, content, rep)
    _check_replan_cache(plan, offs, rep)
    return rep


def verify_partition(res, n: int | None = None) -> Report:
    """Structural checks over a ``core.api.HierPartition`` (PART0xx):
    the vertex map is in range, the ancestor table is nested and
    rectangular, and ``fanouts``/``lams`` are mutually consistent."""
    part = np.asarray(res.part)
    k = int(res.k)
    rep = Report(subject=f"HierPartition(k={k}, "
                         f"fanouts={tuple(res.fanouts)})")
    if n is not None and part.shape != (n,):
        rep.add("PART001", f"part has shape {part.shape}, want ({n},)")
    if part.size and (part.min() < 0 or part.max() >= k):
        rep.add("PART001", f"part values outside [0, {k})")
    anc = np.asarray(res.anc)
    if anc.ndim != 2 or anc.shape[1] != k:
        rep.add("PART002", f"ancestor table has shape {anc.shape}, want "
                           f"(h-1, {k})")
        return rep
    fanouts = tuple(int(f) for f in res.fanouts)
    if int(np.prod(fanouts)) != k:
        rep.add("PART002", f"prod(fanouts)={int(np.prod(fanouts))} != "
                           f"k={k}")
    prev = np.zeros(k, dtype=np.int64)
    prev_c = 1
    for t in range(anc.shape[0]):
        row = anc[t]
        c = int(row.max()) + 1 if row.size else 1
        # nested: a level-t group has exactly one parent group
        parent_of = {}
        for g, p in zip(row.tolist(), prev.tolist()):
            if parent_of.setdefault(g, p) != p:
                rep.add("PART002", f"level row {t} is not nested under "
                                   f"row {t - 1} (group {g} has two "
                                   "parents)")
                break
        counts = np.bincount(row, minlength=c)
        if row.size and counts.min() != counts.max():
            rep.add("PART002", f"level row {t} groups blocks unequally "
                               f"({counts.min()}..{counts.max()}) — tree "
                               "meshes are rectangular")
        if c % prev_c:
            rep.add("PART002", f"level row {t} has {c} groups, not a "
                               f"multiple of the parent's {prev_c}")
        prev, prev_c = row, c
    lams = getattr(res, "lams", None)
    if lams is not None and len(lams) != len(fanouts):
        rep.add("PART003", f"{len(lams)} objective weights for a depth-"
                           f"{len(fanouts)} tree")
    return rep


def partner_table(plan) -> dict[int, list[list[tuple[int, int]]]]:
    """Per-level ppermute partner table in *device* (leaf-linear) indices:
    ``table[level][round]`` lists every (src_dev, dst_dev) delivery,
    expanded over all subtrees sharing the suffix schedule."""
    rep = Report(subject="partner_table")
    levels = _levels_of(plan, rep)
    if levels is None:
        raise ValueError(str(rep))
    k = int(plan.k)
    table: dict[int, list[list[tuple[int, int]]]] = {}
    for l, lv in enumerate(levels):
        n_sub = max(k // lv.nq, 1)
        rounds = []
        for c in range(lv.R):
            pairs = []
            for a, b in lv.perms[c] if c < len(lv.perms) else ():
                for p in range(n_sub):
                    pairs.append((p * lv.nq + a, p * lv.nq + b))
            rounds.append(pairs)
        table[l] = rounds
    return table


def check_mesh_axes(plan, mesh, axis=None) -> Report:
    """Statically verify the ``comm='hier'`` mesh/axis folding — no
    devices needed.

    ``mesh`` is either a ``Mesh``-like object (``.shape`` mapping +
    ``.axis_names``) or a plain ``{axis_name: size}`` mapping; ``axis``
    is the axis tuple the shard_map program would use (default: all of
    the mesh's axes, outermost first).  Checks (MESH0xx):

      MESH001  axis names missing from the mesh
      MESH002  tree level l ppermutes over ``axes[h-1-l:]`` whose size
               product must equal ``prod(fanouts[h-1-l:])`` — a mesh that
               merely has enough devices but the wrong shape would
               deliver halo words to the wrong devices silently
      MESH003  a flat plan's single axis must span exactly k devices
      MESH004  too few axes for the plan depth

    ``report.info['partner_table']`` carries the per-level ppermute
    partner table (:func:`partner_table`).
    """
    if hasattr(mesh, "shape"):
        sizes = dict(mesh.shape)
    else:
        sizes = dict(mesh)
    if axis is None:
        axes = tuple(sizes)
    else:
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
    rep = Report(subject=f"mesh axes {axes} vs "
                         f"{'tree' if _is_tree(plan) else 'flat'} plan")
    missing = [a for a in axes if a not in sizes]
    if missing:
        rep.add("MESH001", f"axis names {missing} not in mesh axes "
                           f"{tuple(sizes)}")
        return rep
    if not _is_tree(plan):
        span = int(np.prod([sizes[a] for a in axes])) if axes else 0
        if span != int(plan.k):
            rep.add("MESH003", f"axes {axes} span {span} devices but the "
                               f"flat plan has k={int(plan.k)} blocks")
        rep.info["partner_table"] = partner_table(plan)
        return rep
    h = len(plan.fanouts)
    if len(axes) < h:
        rep.add("MESH004", f"comm='hier' on a depth-{h} plan needs "
                           f">= {h} mesh axes; got {axes!r}")
        return rep
    suffix = 1
    for l in range(h):
        suffix *= int(plan.fanouts[h - 1 - l])
        mesh_suffix = int(np.prod([sizes[a] for a in axes[h - 1 - l:]]))
        if mesh_suffix != suffix:
            rep.add("MESH002",
                    f"mesh axes {axes[h - 1 - l:]} have {mesh_suffix} "
                    f"devices but tree level {l} of the "
                    f"{tuple(plan.fanouts)} plan spans {suffix} — the "
                    "mesh shape must match the plan's fanouts suffix per "
                    "level (extra leading axes fold into the outermost "
                    "level only)", where=f"level {l}")
    if rep.ok:
        rep.info["partner_table"] = partner_table(plan)
    return rep
