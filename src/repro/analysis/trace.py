"""Jaxpr-level trace auditor + static cost model for the solver programs.

PR 6's plan verifier checks ``DistPlan``/``TreePlan`` metadata against
itself; nothing checked that the *staged program* actually implements the
plan (the JAX-0.4.x sharding-constraint no-op shipped in exactly that
gap).  This pass closes it without devices: every solver program (matvec
and fused CG, every backend) is traced abstractly —
``jax.make_jaxpr`` under ``ShapeDtypeStruct`` inputs on a
``compat.abstract_mesh`` — and the closed jaxpr is walked (recursing
through ``pjit`` / ``shard_map`` / ``while`` / ``scan`` / ``cond``
sub-jaxprs) to extract every collective and every dtype transition.

Three products, all on the shared :class:`~.diagnostics.Report` model:

  ========  ===========================================================
  rule      what
  ========  ===========================================================
  TRACE001  ppermute round count on a level's axis tuple differs from
            the plan's non-empty ``round_perms[_lvl]`` schedule
            (dropped/extra round, rounds staged on the wrong axes)
  TRACE002  a staged round's permutation pairs differ from the plan's
            round (compared as sets — pair order within a round is
            semantically free)
  TRACE003  a collective the plan cannot account for (ppermute on an
            unknown axis tuple, all_gather in a halo program, any
            collective in a single-device program)
  TRACE004  float-width conversion on the traced dataflow (silent
            promotion/demotion, e.g. an f32 upcast or a bf16 downcast)
  TRACE005  float value wider than the program dtype (f64 constants /
            results leaking in under ``JAX_ENABLE_X64``)
  ========  ===========================================================

plus a :class:`TraceCost` — per-CG-iteration FLOPs, HBM bytes, and
per-level communication bytes counted from the jaxpr ops — consumable by
``launch.roofline.static_roofline`` and by ``SolverService`` to price
bucket size-classes at admission.

Communication is reported two ways: *wire* bytes are what the staged
ppermutes move (padded ``rounds x S x k x itemsize``, counted from the
jaxpr operand shapes), *payload* bytes are the live (mask-selected) halo
words from the plan — by construction each live slot is one (receiver,
vertex) pair, so per level they equal
``metrics.tree_comm_volumes(...)[level].sum() * itemsize`` exactly (the
acceptance oracle; ``tests/test_analysis_trace.py`` asserts it).

Primitive names drift across JAX versions (``psum`` vs ``psum2``), so
collectives are matched by name prefix and the walker recurses into *any*
jaxpr-valued equation param rather than a fixed list of HOPs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from .diagnostics import Report

TRACE_RULES: dict[str, str] = {
    "TRACE001": "staged collective round count differs from the plan",
    "TRACE002": "staged ppermute permutation differs from the plan round",
    "TRACE003": "collective not derivable from the plan",
    "TRACE004": "float-width conversion on the solver dataflow",
    "TRACE005": "float wider than the program dtype (x64 leak)",
}

# collective primitive families, matched by prefix: psum is psum2 on
# 0.4.x, and pbroadcast/reduce_scatter spellings vary.
_COLL_KINDS = ("ppermute", "psum", "all_gather", "all_to_all",
               "reduce_scatter", "pbroadcast")

# collectives a comm mode may stage (ppermute levels are checked
# separately against the plan's round schedule)
_ALLOWED_KINDS = {
    "halo": frozenset({"ppermute", "psum"}),
    "halo_seq": frozenset({"ppermute", "psum"}),
    "hier": frozenset({"ppermute", "psum"}),
    "allgather": frozenset({"all_gather", "psum"}),
    None: frozenset(),                       # single-device program
}


@dataclasses.dataclass(frozen=True)
class Collective:
    """One collective op extracted from the jaxpr, in program order."""

    kind: str                    # 'ppermute' / 'psum' / 'all_gather' / ...
    axes: tuple                  # mesh axis names it runs over
    perm: tuple | None           # ppermute (src, dst) pairs
    shape: tuple                 # per-device payload shape
    dtype: str
    nbytes: float                # per-device wire bytes
    devices: int                 # mesh size at this nesting depth
    in_loop: bool                # inside a while/scan body


class _Acc:
    """Mutable walk state: collectives + flop/byte counters, split into
    outside-loop and per-iteration (while/scan body) buckets."""

    __slots__ = ("colls", "flops", "flops_loop", "bytes", "bytes_loop")

    def __init__(self):
        self.colls: list[Collective] = []
        self.flops = self.flops_loop = 0.0
        self.bytes = self.bytes_loop = 0.0


def _sub_jaxprs(val) -> list:
    """Every Jaxpr reachable from an eqn param value (ClosedJaxpr, bare
    Jaxpr, or tuples/lists of either) — the version-proof way to recurse
    through pjit/shard_map/while/scan/cond/pallas_call params."""
    if hasattr(val, "eqns"):                       # bare Jaxpr
        return [val]
    if hasattr(val, "jaxpr"):                      # ClosedJaxpr
        return [val.jaxpr]
    if isinstance(val, (tuple, list)):
        out = []
        for v in val:
            out.extend(_sub_jaxprs(v))
        return out
    return []


def _aval(v):
    av = getattr(v, "aval", None)
    if av is not None and hasattr(av, "shape") and hasattr(av, "dtype"):
        return av
    return None


def _size(av) -> float:
    return float(np.prod(av.shape)) if av.shape else 1.0


def _nbytes(av) -> float:
    return _size(av) * np.dtype(av.dtype).itemsize


def _float_dtype(dt) -> bool:
    """Float-family test that also covers the ml_dtypes extended floats
    (bf16/f8): plain ``np.issubdtype`` reports those as non-inexact, which
    would hide exactly the bf16 up/downcasts TRACE004 exists to catch."""
    try:
        return bool(jax.dtypes.issubdtype(dt, np.inexact))
    except TypeError:
        return bool(np.issubdtype(dt, np.inexact))


def _inexact(av) -> bool:
    return av is not None and _float_dtype(av.dtype)


def _coll_kind(prim: str) -> str | None:
    for kind in _COLL_KINDS:
        if prim == kind or prim.startswith(kind):
            return kind
    return None


def _axes_param(params: dict) -> tuple:
    ax = params.get("axis_name", params.get("axes", ()))
    if isinstance(ax, str):
        return (ax,)
    return tuple(ax)


def _collective(kind: str, eqn, in_loop: bool, devices: int) -> Collective:
    perm = eqn.params.get("perm")
    if perm is not None:
        perm = tuple((int(a), int(b)) for a, b in perm)
    # wire convention: ppermute/psum/all_to_all move their operand,
    # all_gather-style ops deliver their (replicated) output
    src = (eqn.outvars if kind in ("all_gather", "pbroadcast")
           else eqn.invars)
    avs = [a for a in map(_aval, src) if a is not None]
    nbytes = sum(map(_nbytes, avs))
    shape = avs[0].shape if avs else ()
    dtype = str(avs[0].dtype) if avs else "?"
    return Collective(kind=kind, axes=_axes_param(eqn.params), perm=perm,
                      shape=tuple(shape), dtype=dtype, nbytes=nbytes,
                      devices=devices, in_loop=in_loop)


# elementwise float primitives counted at one FLOP per output element
_EW = frozenset((
    "add", "sub", "mul", "div", "max", "min", "pow", "atan2", "rem",
    "neg", "abs", "sign", "floor", "ceil", "round", "exp", "log",
    "expm1", "log1p", "sqrt", "rsqrt", "cbrt", "square", "integer_pow",
    "sin", "cos", "tan", "tanh", "erf", "erf_inv", "logistic",
    "add_any", "nextafter",
))

# shape/layout plumbing that costs no HBM round-trip of its own (XLA
# fuses these; counting them would double every operand)
_STRUCTURAL = frozenset((
    "reshape", "squeeze", "expand_dims", "broadcast_in_dim", "transpose",
    "convert_element_type", "copy", "iota", "stop_gradient",
    "bitcast_convert_type", "rev", "slice",
))


def _flops_of(prim: str, eqn) -> float:
    out = _aval(eqn.outvars[0]) if eqn.outvars else None
    if prim == "dot_general":
        (lc, _rc), _ = eqn.params["dimension_numbers"]
        lhs = _aval(eqn.invars[0])
        csz = float(np.prod([lhs.shape[i] for i in lc])) if lc else 1.0
        return 2.0 * _size(out) * csz
    if prim.startswith("scatter"):
        upd = _aval(eqn.invars[2]) if len(eqn.invars) > 2 else None
        return _size(upd) if _inexact(upd) else 0.0
    if prim.startswith("reduce_") and prim != "reduce_precision":
        op0 = _aval(eqn.invars[0])
        return _size(op0) if _inexact(op0) else 0.0
    if prim in _EW:
        return _size(out) if _inexact(out) else 0.0
    return 0.0


def _bytes_of(prim: str, eqn) -> float:
    if prim in _STRUCTURAL:
        return 0.0
    total = 0.0
    for v in eqn.invars:
        if hasattr(v, "val"):                      # literal
            continue
        av = _aval(v)
        if av is not None:
            total += _nbytes(av)
    for v in eqn.outvars:
        av = _aval(v)
        if av is not None:
            total += _nbytes(av)
    return total


def _mesh_size(params: dict) -> int | None:
    mesh = params.get("mesh")
    shape = getattr(mesh, "shape", None)
    if shape is None:
        return None
    return int(np.prod(list(dict(shape).values()))) or 1


def _walk(jaxpr, acc: _Acc, in_loop: bool, devices: int) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        kind = _coll_kind(prim)
        if kind is not None:
            acc.colls.append(_collective(kind, eqn, in_loop, devices))
            continue
        subs = _sub_jaxprs(list(eqn.params.values()))
        if subs:
            loop = in_loop or prim in ("while", "scan")
            dev = _mesh_size(eqn.params) if prim.startswith("shard_map") \
                else None
            for sub in subs:
                _walk(sub, acc, loop, dev or devices)
            continue
        f = _flops_of(prim, eqn) * devices
        b = _bytes_of(prim, eqn) * devices
        if in_loop:
            acc.flops_loop += f
            acc.bytes_loop += b
        else:
            acc.flops += f
            acc.bytes += b


# --------------------------------------------------------------------------
# dtype-flow audit (TRACE004/005)
# --------------------------------------------------------------------------

def _dtype_audit(jaxpr, consts, base: np.dtype, rep: Report) -> None:
    seen4: set = set()
    seen5: set = set()

    def flag5(dtype, what: str) -> None:
        d = np.dtype(dtype)
        if _float_dtype(d) and d.itemsize > base.itemsize \
                and d not in seen5:
            seen5.add(d)
            rep.add("TRACE005",
                    f"{what} of dtype {d.name} is wider than the "
                    f"{base.name} program dtype — an x64/f64 leak that "
                    "silently promotes the whole dataflow",
                    where="dtype-flow", dtype=d.name, base=base.name)

    for c in consts:
        if hasattr(c, "dtype"):
            flag5(c.dtype, "trace constant")

    def visit(jx) -> None:
        for eqn in jx.eqns:
            if eqn.primitive.name == "convert_element_type":
                a, b = _aval(eqn.invars[0]), _aval(eqn.outvars[0])
                if _inexact(a) and _inexact(b) and a.dtype != b.dtype \
                        and (a.dtype, b.dtype) not in seen4:
                    seen4.add((a.dtype, b.dtype))
                    verb = ("promotion" if np.dtype(b.dtype).itemsize
                            >= np.dtype(a.dtype).itemsize else "demotion")
                    rep.add("TRACE004",
                            f"silent float {verb} "
                            f"{np.dtype(a.dtype).name} -> "
                            f"{np.dtype(b.dtype).name} on the traced "
                            "dataflow",
                            where="dtype-flow",
                            src=np.dtype(a.dtype).name,
                            dst=np.dtype(b.dtype).name)
            for v in eqn.invars:
                if hasattr(v, "val"):
                    av = _aval(v)
                    if av is not None:
                        flag5(av.dtype, "literal")
            for v in eqn.outvars:
                av = _aval(v)
                if av is not None:
                    flag5(av.dtype, "result")
            for sub in _sub_jaxprs(list(eqn.params.values())):
                visit(sub)

    visit(jaxpr)


# --------------------------------------------------------------------------
# schedule conformance (TRACE001/002/003)
# --------------------------------------------------------------------------

def _expected_schedule(plan, axis):
    """Per level: (level, axes-key, [(round_index, perm), ...] for the
    non-empty rounds, in round order) — mirrors exactly what
    ``_halo_exchange`` / ``_hier_exchange`` stage."""
    from ..sparse.distributed import TreePlan
    if isinstance(plan, TreePlan):
        axes = tuple(axis) if not isinstance(axis, str) else (axis,)
        h = plan.h
        out = []
        for lvl in range(h):
            key = tuple(axes[h - 1 - lvl:])
            rounds = [(c, tuple(map(tuple, p)))
                      for c, p in enumerate(plan.round_perms_lvl[lvl]) if p]
            out.append((lvl, key, rounds))
        return out
    key = (axis,) if isinstance(axis, str) else tuple(axis)
    rounds = [(c, tuple(map(tuple, p)))
              for c, p in enumerate(plan.round_perms) if p]
    return [(0, key, rounds)]


def _check_schedule(colls: list[Collective], plan, axis, comm: str | None,
                    rep: Report) -> dict[tuple, int]:
    """Cross-check staged collectives against the plan schedule.  Returns
    ``{axes-key: level}`` for per-level cost attribution."""
    groups: dict[tuple, list] = {}
    for c in colls:
        if c.kind == "ppermute":
            groups.setdefault(c.axes, []).append(c.perm)

    key_level: dict[tuple, int] = {}
    if plan is not None and comm == "allgather":
        # the gather baseline stages no ppermute rounds at all — any that
        # appear are not derivable from this schedule (flagged below via
        # the leftover groups), and each all_gather must run over the
        # program's own axis
        exp_axes = {axis} if isinstance(axis, str) else set(axis)
        for c in colls:
            if c.kind == "all_gather" and set(c.axes) != exp_axes:
                rep.add("TRACE003",
                        f"all_gather over axes {c.axes}; this program "
                        f"gathers over {tuple(sorted(exp_axes))}",
                        where=f"axes {c.axes}", kind=c.kind)
    elif plan is not None:
        for lvl, key, rounds in _expected_schedule(plan, axis):
            key_level[key] = lvl
            got = groups.pop(key, [])
            if not rounds:
                if got:
                    rep.add("TRACE001",
                            f"level {lvl}: plan schedules no rounds on "
                            f"axes {key} but the program stages "
                            f"{len(got)} ppermute(s)",
                            where=f"level {lvl}",
                            staged=len(got), planned=0)
                continue
            # the program may apply the matvec m times (e.g. the CG
            # initial residual + the loop body) — each application must
            # replay the full round schedule in order
            if not got or len(got) % len(rounds):
                rep.add("TRACE001",
                        f"level {lvl}: program stages {len(got)} "
                        f"ppermute round(s) on axes {key}, plan "
                        f"schedules {len(rounds)} — dropped or extra "
                        "rounds (or rounds staged on the wrong axes)",
                        where=f"level {lvl}",
                        staged=len(got), planned=len(rounds))
                continue
            m = len(got) // len(rounds)
            bad: set[int] = set()
            for a in range(m):
                block = got[a * len(rounds):(a + 1) * len(rounds)]
                for (c_idx, eperm), gperm in zip(rounds, block):
                    if c_idx in bad:
                        continue
                    if set(gperm) != set(eperm) or len(gperm) != len(eperm):
                        bad.add(c_idx)
                        rep.add("TRACE002",
                                f"level {lvl} round {c_idx}: staged "
                                "permutation differs from the plan's "
                                "round_perms — halo words would land on "
                                "the wrong devices",
                                where=f"level {lvl} round {c_idx}",
                                staged=sorted(gperm),
                                planned=sorted(eperm))
    for key, got in sorted(groups.items()):
        rep.add("TRACE003",
                f"{len(got)} ppermute(s) over axes {key} not derivable "
                "from the plan schedule",
                where=f"axes {key}", staged=len(got))

    allowed = _ALLOWED_KINDS.get(comm, _ALLOWED_KINDS[None])
    flagged: set[str] = set()
    for c in colls:
        if c.kind == "ppermute" or c.kind in allowed or c.kind in flagged:
            continue
        flagged.add(c.kind)
        what = (f"comm={comm!r} programs" if comm is not None
                else "a single-device program")
        rep.add("TRACE003",
                f"{c.kind} over axes {c.axes} staged in {what}",
                where=f"axes {c.axes}", kind=c.kind)
    return key_level


# --------------------------------------------------------------------------
# static cost model
# --------------------------------------------------------------------------

_ROOFLINE_KIND = {
    "ppermute": "collective-permute",
    "psum": "all-reduce",
    "all_gather": "all-gather",
    "pbroadcast": "all-gather",
    "reduce_scatter": "reduce-scatter",
    "all_to_all": "all-to-all",
}


@dataclasses.dataclass
class TraceCost:
    """Static per-program cost counted from the jaxpr (global totals
    across all devices).  ``*_per_iter`` is the while/scan body (one CG
    iteration); for a loop-free program (a matvec) it equals the whole
    program.  ``comm_wire_bytes_lvl`` is the staged (padded) ppermute
    traffic per tree level and iteration; ``comm_payload_bytes_lvl`` is
    the live mask-selected halo words from the plan — the quantity that
    matches ``metrics.tree_comm_volumes`` exactly."""

    dtype: str
    n_devices: int
    flops: float
    flops_per_iter: float
    hbm_bytes: float
    hbm_bytes_per_iter: float
    rounds_lvl: tuple = ()
    comm_wire_bytes_lvl: tuple = ()
    comm_payload_bytes_lvl: tuple = ()
    collective_bytes: dict[str, float] = dataclasses.field(
        default_factory=dict)          # kind -> wire bytes per iteration

    def collectives(self) -> dict[str, float]:
        """Per-iteration wire bytes keyed by HLO collective name — the
        shape ``launch.roofline.roofline_terms`` consumes (all-reduce is
        doubled there, so psum bytes are reported once here)."""
        out: dict[str, float] = {}
        for kind, b in self.collective_bytes.items():
            name = _ROOFLINE_KIND.get(kind, kind)
            out[name] = out.get(name, 0.0) + b
        return out

    def roofline(self) -> dict[str, Any]:
        from ..launch.roofline import static_roofline
        return static_roofline(self)

    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        for key in ("rounds_lvl", "comm_wire_bytes_lvl",
                    "comm_payload_bytes_lvl"):
            d[key] = list(d[key])
        return d


def _payload_bytes_lvl(plan, itemsize: int, nb: int) -> tuple:
    """Live halo words per level x itemsize x RHS width — each non-zero
    send_mask slot is one (receiver, vertex) delivery, so this equals the
    metrics-side deduplicated volume exactly."""
    from ..sparse.distributed import TreePlan
    if isinstance(plan, TreePlan):
        masks = plan.send_mask_lvl
    else:
        masks = (plan.send_mask,)
    return tuple(float(np.asarray(m).sum()) * itemsize * nb for m in masks)


def _build_cost(acc: _Acc, plan, key_level: dict[tuple, int],
                base: np.dtype, nb: int | None,
                n_devices: int) -> TraceCost:
    has_loop = bool(acc.flops_loop or acc.bytes_loop
                    or any(c.in_loop for c in acc.colls))
    iter_colls = [c for c in acc.colls if c.in_loop] if has_loop \
        else acc.colls

    by_kind: dict[str, float] = {}
    n_lvls = len({lvl for lvl in key_level.values()}) if key_level else 0
    wire_lvl = [0.0] * n_lvls
    rounds_lvl = [0] * n_lvls
    for c in iter_colls:
        by_kind[c.kind] = by_kind.get(c.kind, 0.0) + c.nbytes * c.devices
        if c.kind == "ppermute" and c.axes in key_level:
            lvl = key_level[c.axes]
            wire_lvl[lvl] += c.nbytes * c.devices
            rounds_lvl[lvl] += 1

    payload = ()
    if plan is not None:
        payload = _payload_bytes_lvl(plan, base.itemsize, nb or 1)
    return TraceCost(
        dtype=base.name, n_devices=n_devices,
        flops=acc.flops, flops_per_iter=(acc.flops_loop if has_loop
                                         else acc.flops),
        hbm_bytes=acc.bytes,
        hbm_bytes_per_iter=(acc.bytes_loop if has_loop else acc.bytes),
        rounds_lvl=tuple(rounds_lvl),
        comm_wire_bytes_lvl=tuple(wire_lvl),
        comm_payload_bytes_lvl=payload,
        collective_bytes=by_kind)


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------

def audit_jaxpr(closed, *, subject: str = "program", plan=None,
                axis="pu", comm: str | None = None,
                base_dtype=None, nb: int | None = None) -> Report:
    """Audit one closed jaxpr against ``plan``'s schedule; the report
    carries the :class:`TraceCost` in ``info['cost']``."""
    jaxpr = getattr(closed, "jaxpr", closed)
    consts = getattr(closed, "consts", ())
    if base_dtype is None:
        inexact = [v.aval.dtype for v in jaxpr.invars
                   if _inexact(_aval(v))]
        base_dtype = inexact[0] if inexact else np.float32
    base = np.dtype(base_dtype)

    rep = Report(subject=subject)
    acc = _Acc()
    _walk(jaxpr, acc, in_loop=False, devices=1)
    key_level = _check_schedule(acc.colls, plan, axis, comm, rep)
    _dtype_audit(jaxpr, consts, base, rep)
    n_dev = max((c.devices for c in acc.colls), default=1)
    if plan is not None:
        n_dev = max(n_dev, plan.k)
    rep.info["cost"] = _build_cost(acc, plan, key_level, base, nb, n_dev)
    rep.info["n_collectives"] = len(acc.colls)
    return rep


def _merge(rep: Report, sub: Report, tag: str) -> None:
    for d in sub.diagnostics:
        where = f"{tag}: {d.where}" if d.where else tag
        rep.diagnostics.append(dataclasses.replace(d, where=where))
    rep.info[f"cost_{tag}"] = sub.info["cost"]


def audit_operator(op, *, nb: int | None = None, solver: bool = True,
                   tol: float = 1e-6, max_iters: int = 100,
                   precondition: str | None = None,
                   subject: str | None = None) -> Report:
    """Trace + audit an operator's matvec and (optionally) its CG solve.

    Works on any backend from ``operator.make_operator``; distributed
    operators built over ``distributed.abstract_mesh_for(plan)`` trace
    without devices.  ``info`` carries ``cost_matvec`` / ``cost_cg``.
    """
    plan = getattr(op, "plan", None)
    comm = getattr(op, "comm", None)
    axis = getattr(op, "axis", "pu")
    spec = op.operand_spec(nb)
    rep = Report(subject=subject or type(op).__name__)

    mv = jax.make_jaxpr(op.matvec)(spec)
    _merge(rep, audit_jaxpr(mv, plan=plan, axis=axis, comm=comm, nb=nb),
           "matvec")
    if solver:
        if hasattr(op, "fused_solver"):
            fn: Callable = op.fused_solver(tol, max_iters, precondition)
        else:
            from ..sparse.cg import cg_solve

            def fn(b):
                return cg_solve(op, b, tol=tol, max_iters=max_iters,
                                precondition=precondition,
                                batched=nb is not None)
        cg = jax.make_jaxpr(fn)(spec)
        _merge(rep, audit_jaxpr(cg, plan=plan, axis=axis, comm=comm,
                                nb=nb), "cg")
    return rep


def audit_backend(backend: str, *, n: int = 144,
                  fanouts: tuple[int, ...] = (2, 2),
                  generator: str = "grid_2d", seed: int = 0,
                  nb: int | None = None, part=None,
                  tol: float = 1e-6, max_iters: int = 100,
                  precondition: str | None = None) -> Report:
    """Build a small fixture system + operator on an abstract mesh and
    audit it — the ``make trace-audit`` / CLI entry point.  The default
    partition is the benchmark's locality-preserving stripes."""
    from .. import compat
    from ..launch.mesh import tree_axis_names
    from ..sparse.generators import GENERATORS
    from ..sparse.graph import laplacian_csr
    from ..sparse.operator import _HIER_BACKENDS, make_operator

    g = GENERATORS[generator](n, seed=seed)
    nv = len(g.indptr) - 1
    indptr, indices, data = laplacian_csr(g, shift=0.1)
    k = int(np.prod(fanouts))
    if part is None:
        part = (np.arange(nv) * k) // nv
    subject = (f"{backend} {generator} n={nv} fanouts="
               + "x".join(map(str, fanouts))
               + (f" nb={nb}" if nb else "")
               + (f" prec={precondition}" if precondition else ""))

    kw: dict[str, Any] = {}
    if backend in ("coo", "bell"):
        op = make_operator(indptr, indices, data, backend)
    else:
        if backend in _HIER_BACKENDS:
            if len(fanouts) < 2:
                raise ValueError(f"{backend} needs >= 2 tree levels; got "
                                 f"fanouts={fanouts}")
            names = tree_axis_names(len(fanouts))
            mesh = compat.abstract_mesh(dict(zip(names, fanouts)))
            kw["fanouts"] = tuple(fanouts)
        else:
            mesh = compat.abstract_mesh({"pu": k})
        op = make_operator(indptr, indices, data, backend, part=part, k=k,
                           mesh=mesh, **kw)
    return audit_operator(op, nb=nb, tol=tol, max_iters=max_iters,
                          precondition=precondition, subject=subject)
