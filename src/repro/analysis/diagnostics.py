"""Structured diagnostics shared by the analysis passes.

Every check emits :class:`Diagnostic` records (a stable ``code``, a
human-readable message, and a machine-readable ``details`` dict) into a
:class:`Report` instead of raising at the first failure, so one verifier
run over a corrupted plan names *every* violated invariant — the mutation
suite asserts on codes, the CLI prints them, and the build-time
``validate=`` hook raises :class:`PlanVerificationError` carrying the
whole report.
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One violated invariant (or lint finding).

    ``code`` is the stable identifier (``PLAN0xx`` for the plan verifier,
    ``MESH0xx`` for the mesh/axis checker, ``REPRO0xx`` for the lint);
    ``where`` locates it (a plan context like ``level 1 round 2`` or a
    ``path:line`` for lint findings); ``details`` carries whatever small
    arrays/scalars made the check fail, for programmatic consumers.
    """

    code: str
    message: str
    where: str = ""
    details: dict[str, Any] = dataclasses.field(default_factory=dict)

    def __str__(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        return f"{self.code}{loc}: {self.message}"

    def to_dict(self) -> dict[str, Any]:
        return {"code": self.code, "message": self.message,
                "where": self.where, "details": jsonable(self.details)}


@dataclasses.dataclass
class Report:
    """Outcome of one analysis pass over one subject."""

    subject: str
    diagnostics: list[Diagnostic] = dataclasses.field(default_factory=list)
    # side-channel results (e.g. the per-level ppermute partner table)
    info: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.diagnostics

    def add(self, code: str, message: str, where: str = "",
            **details: Any) -> None:
        self.diagnostics.append(Diagnostic(code=code, message=message,
                                           where=where, details=details))

    def codes(self) -> set[str]:
        return {d.code for d in self.diagnostics}

    def raise_for_errors(self) -> None:
        if self.diagnostics:
            raise PlanVerificationError(self)

    def __str__(self) -> str:
        if self.ok:
            return f"{self.subject}: OK"
        lines = [f"{self.subject}: {len(self.diagnostics)} violation(s)"]
        lines += [f"  {d}" for d in self.diagnostics]
        return "\n".join(lines)

    def to_dict(self) -> dict[str, Any]:
        """JSON-ready form — the ``--format=json`` CLI payload and the CI
        artifact schema."""
        return {"subject": self.subject, "ok": self.ok,
                "diagnostics": [d.to_dict() for d in self.diagnostics],
                "info": jsonable(self.info)}


def jsonable(x: Any) -> Any:
    """Best-effort conversion to JSON-serializable types: numpy scalars
    and arrays, tuples, non-string dict keys, and result dataclasses that
    expose ``to_dict`` (e.g. ``trace.TraceCost``) all flatten; anything
    unknown falls back to ``repr`` rather than failing the dump."""
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    if hasattr(x, "to_dict"):
        return jsonable(x.to_dict())
    if hasattr(x, "item") and not hasattr(x, "__len__"):    # numpy scalar
        return x.item()
    if hasattr(x, "tolist"):                                # numpy array
        return x.tolist()
    if isinstance(x, dict):
        return {str(k): jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple, set, frozenset)):
        return [jsonable(v) for v in x]
    return repr(x)


class PlanVerificationError(ValueError):
    """A plan (or partition) failed structural verification.

    Subclasses ``ValueError`` so existing callers treating bad plan inputs
    as value errors keep working; ``.report`` carries the diagnostics.
    """

    def __init__(self, report: Report):
        self.report = report
        super().__init__(str(report))
