"""Custom AST lint for the repo's recurring bug classes.

Each rule targets a failure mode that produced (or would have prevented)
an actual bugfix in the PR history:

  ========  ==============================================================
  rule      what / why
  ========  ==============================================================
  REPRO001  ``jax.sharding`` / ``shard_map`` imported or referenced
            outside ``compat.py``.  JAX moved ``shard_map`` and the
            sharding API across 0.4.x; direct imports are the API-drift
            class behind the PR 3 sharding-constraint no-op.  All access
            goes through ``repro.compat``.
  REPRO002  blanket ``except Exception: pass`` (or bare ``except:``).
            Swallowing everything hid the PR 3 constraint no-op; catch
            the concrete types and record or re-raise.
  REPRO003  unseeded global-RNG calls (``np.random.rand`` etc. /
            ``from numpy.random import shuffle``) in ``core/`` +
            ``sparse/`` schedule-building code.  Plans must be
            deterministic — use ``np.random.default_rng(seed)``.
  REPRO004  host-sync idioms in solver paths: ``.item()`` and
            ``jax.device_get(...)`` in ``core/`` + ``sparse/``, and
            ``float()``/``int()``/``bool()`` /
            ``np.asarray(...)``/``np.array(...)`` on traced values
            inside explicitly ``@jit``-decorated functions.  Each
            forces a device round-trip per CG iteration (the numpy
            coercions additionally fail with a ConcretizationError on
            abstract values — host plan-building is where they belong,
            and that code is never jitted).
  ========  ==============================================================

Pure ``ast`` — no imports of the linted code, so it runs identically on
both CI matrix entries.  ``ALLOWLIST`` maps path suffixes to the rule
codes permitted there (``compat.py`` is the single sanctioned home of
the sharding imports).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable

from .diagnostics import Report

LINT_RULES: dict[str, str] = {
    "REPRO001": "jax.sharding/shard_map used outside compat.py",
    "REPRO002": "blanket 'except Exception: pass' swallows errors",
    "REPRO003": "unseeded global RNG in schedule-building code",
    "REPRO004": "host-sync (.item()/float()/np.asarray/device_get) in "
                "jitted solver paths",
}

# path-suffix -> codes sanctioned there.  Keep this near-empty: compat.py
# exists precisely so nothing else needs an entry.
ALLOWLIST: dict[str, frozenset[str]] = {
    "repro/compat.py": frozenset({"REPRO001"}),
}

_SEEDED_RNG = {"default_rng", "Generator", "SeedSequence", "RandomState",
               "Philox", "PCG64", "MT19937", "bit_generator"}
_JIT_NAMES = {"jit"}          # matches jit, jax.jit, partial(jax.jit, ...)
_HOST_COERCE = {"float", "int", "bool"}
# numpy materializations: legitimate all over host plan-building, a host
# sync (or ConcretizationError) on traced values — flagged inside jit only
_NP_COERCE = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}


def _dotted(node: ast.AST) -> str:
    """'jax.sharding.Mesh' for an Attribute/Name chain, '' otherwise."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_sharding_module(mod: str) -> bool:
    return (mod == "jax.sharding" or mod.startswith("jax.sharding.")
            or mod == "jax.experimental.shard_map"
            or mod.startswith("jax.experimental.shard_map."))


def _is_jit_decorator(dec: ast.AST) -> bool:
    name = _dotted(dec)
    if name.split(".")[-1] in _JIT_NAMES:
        return True
    if isinstance(dec, ast.Call):              # partial(jax.jit, ...) /
        if _is_jit_decorator(dec.func):        # jax.jit(static_argnums=..)
            return True
        return any(_is_jit_decorator(a) for a in dec.args)
    return False


class _Linter(ast.NodeVisitor):
    def __init__(self, path: Path, rel: str, rep: Report,
                 allowed: frozenset[str]):
        self.path, self.rel, self.rep, self.allowed = path, rel, rep, allowed
        parts = Path(rel).parts
        self.solver_scope = "core" in parts or "sparse" in parts
        self.jit_depth = 0

    def _add(self, code: str, node: ast.AST, message: str) -> None:
        if code in self.allowed:
            return
        self.rep.add(code, message,
                     where=f"{self.rel}:{getattr(node, 'lineno', 0)}")

    # -- REPRO001 -----------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            if _is_sharding_module(alias.name):
                self._add("REPRO001", node,
                          f"import {alias.name}: use repro.compat instead "
                          "of importing jax.sharding/shard_map directly")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        if _is_sharding_module(mod) or (
                mod in ("jax.experimental", "jax")
                and any(a.name in ("shard_map", "sharding")
                        for a in node.names)):
            self._add("REPRO001", node,
                      f"from {mod} import "
                      f"{', '.join(a.name for a in node.names)}: use "
                      "repro.compat instead")
        if mod == "numpy.random" or mod.startswith("numpy.random."):
            bad = [a.name for a in node.names
                   if a.name not in _SEEDED_RNG]
            if bad and self.solver_scope:
                self._add("REPRO003", node,
                          f"from numpy.random import {', '.join(bad)}: "
                          "global-RNG functions are unseeded; use "
                          "np.random.default_rng(seed)")
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        name = _dotted(node)
        if name.startswith("jax.sharding.") or name == "jax.sharding":
            self._add("REPRO001", node,
                      f"{name}: use repro.compat instead of the "
                      "jax.sharding namespace")
            return          # don't re-flag the nested jax.sharding chain
        self.generic_visit(node)

    # -- REPRO002 -----------------------------------------------------
    def visit_ExceptHandler(self, node: ast.ExceptHandler) -> None:
        broad = node.type is None or (
            isinstance(node.type, ast.Name)
            and node.type.id in ("Exception", "BaseException"))

        def _noop(s: ast.stmt) -> bool:   # `pass` or a bare `...`
            return isinstance(s, ast.Pass) or (
                isinstance(s, ast.Expr)
                and isinstance(s.value, ast.Constant)
                and s.value.value is Ellipsis)

        only_pass = all(_noop(s) for s in node.body)
        if broad and only_pass:
            what = ("bare except" if node.type is None
                    else f"except {node.type.id}")
            self._add("REPRO002", node,
                      f"{what}: pass — swallows every error; catch the "
                      "concrete exception types and record or re-raise")
        self.generic_visit(node)

    # -- REPRO003 / REPRO004 ------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        if self.solver_scope and name:
            parts = name.split(".")
            if len(parts) >= 3 and parts[-2] == "random" \
                    and parts[0] in ("np", "numpy") \
                    and parts[-1] not in _SEEDED_RNG:
                self._add("REPRO003", node,
                          f"{name}(): unseeded global RNG makes plan "
                          "construction nondeterministic; use "
                          "np.random.default_rng(seed)")
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr == "item" and not node.args \
                and self.solver_scope:
            self._add("REPRO004", node,
                      ".item(): host sync — forces a device round-trip "
                      "in the solver path; keep reductions on device")
        if self.jit_depth and isinstance(node.func, ast.Name) \
                and node.func.id in _HOST_COERCE and node.args:
            self._add("REPRO004", node,
                      f"{node.func.id}() on a traced value inside a "
                      "jitted function: host sync (ConcretizationError "
                      "at best, per-step round-trip at worst)")
        if self.jit_depth and name in _NP_COERCE and node.args:
            self._add("REPRO004", node,
                      f"{name}() inside a jitted function materializes "
                      "the traced value on host; use jnp for on-device "
                      "work and keep numpy in plan construction")
        if name.split(".")[-1] == "device_get" \
                and (self.solver_scope or self.jit_depth):
            self._add("REPRO004", node,
                      f"{name}(): explicit device->host transfer in the "
                      "solver path; keep reductions on device and fetch "
                      "results once after the solve")
        self.generic_visit(node)

    def _visit_func(self, node) -> None:
        jitted = any(_is_jit_decorator(d) for d in node.decorator_list)
        self.jit_depth += jitted
        self.generic_visit(node)
        self.jit_depth -= jitted

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func


def _iter_py(paths: Iterable[str | Path]):
    for p in paths:
        p = Path(p)
        if p.is_dir():
            yield from sorted(q for q in p.rglob("*.py")
                              if not any(part.startswith(".")
                                         for part in q.parts))
        elif p.suffix == ".py":
            yield p


def lint_paths(paths: Iterable[str | Path], *,
               allowlist: dict[str, frozenset[str]] | None = None,
               root: str | Path | None = None) -> Report:
    """Lint every ``.py`` file under ``paths``; returns a :class:`Report`
    whose diagnostics carry ``rule [path:line]: message``."""
    allow = ALLOWLIST if allowlist is None else allowlist
    root = Path(root) if root is not None else Path.cwd()
    rep = Report(subject="lint")
    n = 0
    for path in _iter_py(paths):
        n += 1
        try:
            rel = str(path.resolve().relative_to(root.resolve()))
        except ValueError:
            rel = str(path)
        rel = rel.replace("\\", "/")
        allowed = frozenset().union(
            *(codes for suffix, codes in allow.items()
              if rel.endswith(suffix)))
        try:
            tree = ast.parse(path.read_text(), filename=str(path))
        except SyntaxError as e:
            rep.add("REPRO000", f"syntax error: {e.msg}",
                    where=f"{rel}:{e.lineno}")
            continue
        _Linter(path, rel, rep, allowed).visit(tree)
    rep.info["files"] = n
    return rep
