"""CLI for the static-analysis passes.

  python -m repro.analysis lint src            # AST lint (REPRO0xx)
  python -m repro.analysis verify              # plan verifier sweep
  python -m repro.analysis verify --fanouts 2,2,2 --generator rgg_2d
  python -m repro.analysis partners --fanouts 2,2   # ppermute table
  python -m repro.analysis trace               # jaxpr audit (TRACE0xx)
  python -m repro.analysis trace --backend dist_hier --fanouts 2,2,2

``verify`` builds real plans (flat, pod, and tree at each requested
fanouts) over paper-family generators with a seeded random partition and
runs every PLAN0xx/MESH0xx pass on them — no devices are touched; plan
construction and verification are host-side NumPy.  ``trace`` goes one
layer deeper: it stages each solver backend's matvec + fused CG on an
*abstract* mesh (still no devices), walks the jaxpr, and cross-checks
the staged collectives/dtypes against the plan (TRACE0xx) while counting
the static per-iteration cost consumed by ``launch.roofline``.

Every subcommand exits 0 iff no pass reported a diagnostic and 1
otherwise, so Make/CI gate uniformly.  ``--format=json`` dumps the full
report list; ``--format=github`` emits GitHub Actions ``::error``
annotations (inline on the PR for lint findings, which carry file:line).
``trace --out FILE`` additionally writes the JSON report to a file — the
CI artifact — independent of the console format.
"""
from __future__ import annotations

import argparse
import json
import re
import sys

import numpy as np

from .diagnostics import Report


def _parse_fanouts(s: str) -> tuple[int, ...]:
    return tuple(int(x) for x in s.replace("x", ",").split(",") if x)


def _build_subjects(gen_names, n, fanouts_list, seed):
    """Yield (label, plan, mesh_sizes, axes) over the verify matrix."""
    from repro.core.topology import canonical_ancestors
    from repro.launch.mesh import tree_axis_names
    from repro.sparse.distributed import build_plan, build_plan_tree
    from repro.sparse.generators import GENERATORS

    rng = np.random.default_rng(seed)
    for gname in gen_names:
        g = GENERATORS[gname](n, seed=seed)
        nv = len(g.indptr) - 1
        data = np.asarray(g.weights, dtype=np.float32)
        for fanouts in fanouts_list:
            k = int(np.prod(fanouts))
            part = rng.integers(0, k, size=nv).astype(np.int64)
            flat = build_plan(g.indptr, g.indices, data, part, k)
            yield (f"{gname}/flat k={k}", flat, {"data": k}, ("data",))
            if len(fanouts) > 1:
                anc = canonical_ancestors(fanouts)
                tree = build_plan_tree(g.indptr, g.indices, data, part,
                                       anc, k)
                axes = tree_axis_names(len(fanouts))
                sizes = dict(zip(axes, fanouts))
                yield (f"{gname}/tree {fanouts}", tree, sizes, axes)


def _cmd_verify(args) -> list[Report]:
    from . import check_mesh_axes, verify_plan

    fanouts_list = ([_parse_fanouts(s) for s in args.fanouts]
                    or [(4,), (2, 2), (2, 2, 2)])
    reports = []
    for label, plan, sizes, axes in _build_subjects(
            args.generator, args.n, fanouts_list, args.seed):
        rep = verify_plan(plan)
        mesh_rep = check_mesh_axes(plan, sizes, axes)
        merged = Report(subject=f"{label}: {rep.subject}",
                        diagnostics=rep.diagnostics + mesh_rep.diagnostics,
                        info={**rep.info, **mesh_rep.info})
        reports.append(merged)
    return reports


def _cmd_partners(args) -> list[Report]:
    from . import partner_table
    reports = []
    for label, plan, _, _ in _build_subjects(
            args.generator[:1], args.n,
            [_parse_fanouts(args.fanouts)], args.seed):
        reports.append(Report(subject=label,
                              info={"partners": partner_table(plan)}))
    return reports


def _cmd_lint(args) -> list[Report]:
    from .lint import lint_paths
    return [lint_paths(args.paths)]


def _cmd_trace(args) -> list[Report]:
    from .trace import audit_backend
    from repro.sparse.operator import _HIER_BACKENDS, BACKENDS

    backends = args.backend or list(BACKENDS)
    fanouts_list = ([_parse_fanouts(s) for s in args.fanouts]
                    or [(2, 2)])
    reports = []
    for fanouts in fanouts_list:
        for backend in backends:
            if backend in _HIER_BACKENDS and len(fanouts) < 2:
                continue
            if backend not in _HIER_BACKENDS and fanouts != fanouts_list[0]:
                continue        # flat backends only vary with k, not shape
            reports.append(audit_backend(
                backend, n=args.n, fanouts=fanouts,
                generator=args.generator[0], seed=args.seed, nb=args.nb))
    return reports


# --------------------------------------------------------------------------
# output formatting
# --------------------------------------------------------------------------

def _print_text(reports: list[Report]) -> None:
    for rep in reports:
        status = "OK" if rep.ok else "FAIL"
        print(f"[{status}] {rep.subject}")
        for d in rep.diagnostics:
            print(f"    {d}")
        for tag in ("cost_matvec", "cost_cg"):
            cost = rep.info.get(tag)
            if cost is None:
                continue
            lvl = " ".join(
                f"L{i}:{int(w)}B/{int(p)}B live"
                for i, (w, p) in enumerate(
                    zip(cost.comm_wire_bytes_lvl,
                        cost.comm_payload_bytes_lvl)))
            print(f"    {tag[5:]}: {cost.flops_per_iter:.3g} flop/it "
                  f"{cost.hbm_bytes_per_iter:.3g} B/it"
                  + (f"  comm {lvl}" if lvl else ""))
        partners = rep.info.get("partners")
        if partners is not None:
            for lvl, rounds in partners.items():
                for c, pairs in enumerate(rounds):
                    print(f"    level {lvl} round {c}: "
                          + " ".join(f"{a}->{b}" for a, b in pairs))
    bad = sum(not r.ok for r in reports)
    print(f"{len(reports)} subject(s), {bad} failing")


_WHERE_RE = re.compile(r"^(?:\w+: )?([\w./-]+\.py):(\d+)$")


def _print_github(reports: list[Report]) -> None:
    """GitHub Actions annotations: findings that carry a file:line (the
    lint) annotate inline on the PR; everything else is a plain error."""
    for rep in reports:
        for d in rep.diagnostics:
            msg = f"{d.code}: {d.message}"
            m = _WHERE_RE.match(d.where)
            if m:
                print(f"::error file={m.group(1)},line={m.group(2)}::{msg}")
            else:
                loc = f" [{d.where}]" if d.where else ""
                print(f"::error::{rep.subject}{loc}: {msg}")


def _emit(reports: list[Report], fmt: str) -> None:
    if fmt == "json":
        print(json.dumps([r.to_dict() for r in reports], indent=1))
    elif fmt == "github":
        _print_github(reports)
    else:
        _print_text(reports)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    def _common(p):
        p.add_argument("--format", choices=("text", "json", "github"),
                       default="text",
                       help="console output: human text, a JSON report "
                            "list, or GitHub Actions ::error annotations")

    p_lint = sub.add_parser("lint", help="AST lint (REPRO0xx rules)")
    p_lint.add_argument("paths", nargs="+",
                        help="files or directories to lint")
    _common(p_lint)
    p_lint.set_defaults(fn=_cmd_lint)

    p_ver = sub.add_parser("verify",
                           help="build + verify plans (PLAN/MESH0xx)")
    p_ver.add_argument("--generator", action="append", default=None,
                       help="generator name(s); default grid_2d + rgg_2d")
    p_ver.add_argument("--n", type=int, default=196,
                       help="approximate vertex count (default 196)")
    p_ver.add_argument("--fanouts", action="append", default=[],
                       help="fanouts like 2,2,2 (repeatable); default "
                            "4 / 2,2 / 2,2,2")
    p_ver.add_argument("--seed", type=int, default=0)
    _common(p_ver)
    p_ver.set_defaults(fn=_cmd_verify)

    p_par = sub.add_parser("partners",
                           help="print the per-level ppermute partner "
                                "table of a built plan")
    p_par.add_argument("--generator", action="append", default=None)
    p_par.add_argument("--n", type=int, default=64)
    p_par.add_argument("--fanouts", default="2,2")
    p_par.add_argument("--seed", type=int, default=0)
    _common(p_par)
    p_par.set_defaults(fn=_cmd_partners)

    p_tr = sub.add_parser("trace",
                          help="jaxpr trace audit (TRACE0xx) + static "
                               "cost model, on an abstract mesh")
    p_tr.add_argument("--backend", action="append", default=None,
                      help="backend name(s) (operator.BACKENDS); "
                           "default: all")
    p_tr.add_argument("--generator", action="append", default=None)
    p_tr.add_argument("--n", type=int, default=144,
                      help="approximate vertex count (default 144)")
    p_tr.add_argument("--fanouts", action="append", default=[],
                      help="tree shapes like 2,2 (repeatable; hier "
                           "backends re-audit per shape); default 2,2")
    p_tr.add_argument("--nb", type=int, default=None,
                      help="trace the batched (multi-RHS) programs")
    p_tr.add_argument("--seed", type=int, default=0)
    p_tr.add_argument("--out", default=None,
                      help="also write the JSON report list to this file "
                           "(the CI artifact), regardless of --format")
    _common(p_tr)
    p_tr.set_defaults(fn=_cmd_trace)

    args = ap.parse_args(argv)
    if getattr(args, "generator", None) is None and args.cmd != "lint":
        args.generator = (["grid_2d"] if args.cmd == "trace"
                          else ["grid_2d", "rgg_2d"])
    reports = args.fn(args)
    if getattr(args, "out", None):
        with open(args.out, "w") as f:
            json.dump([r.to_dict() for r in reports], f, indent=1)
    _emit(reports, args.format)
    # uniform contract (ISSUE 8): nonzero iff any pass reported anything
    return 1 if any(r.diagnostics for r in reports) else 0


if __name__ == "__main__":
    sys.exit(main())
