"""CLI for the static-analysis passes.

  python -m repro.analysis lint src            # AST lint (REPRO0xx)
  python -m repro.analysis verify              # plan verifier sweep
  python -m repro.analysis verify --fanouts 2,2,2 --generator rgg_2d
  python -m repro.analysis partners --fanouts 2,2   # ppermute table

``verify`` builds real plans (flat, pod, and tree at each requested
fanouts) over paper-family generators with a seeded random partition and
runs every PLAN0xx/MESH0xx pass on them — no devices are touched; plan
construction and verification are host-side NumPy.  Exit status is the
number of violating subjects (0 = clean), so Make/CI can gate on it.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def _parse_fanouts(s: str) -> tuple[int, ...]:
    return tuple(int(x) for x in s.replace("x", ",").split(",") if x)


def _build_subjects(gen_names, n, fanouts_list, seed):
    """Yield (label, plan, mesh_sizes, axes) over the verify matrix."""
    from repro.core.topology import canonical_ancestors
    from repro.launch.mesh import tree_axis_names
    from repro.sparse.distributed import build_plan, build_plan_tree
    from repro.sparse.generators import GENERATORS

    rng = np.random.default_rng(seed)
    for gname in gen_names:
        g = GENERATORS[gname](n, seed=seed)
        nv = len(g.indptr) - 1
        data = np.asarray(g.weights, dtype=np.float32)
        for fanouts in fanouts_list:
            k = int(np.prod(fanouts))
            part = rng.integers(0, k, size=nv).astype(np.int64)
            flat = build_plan(g.indptr, g.indices, data, part, k)
            yield (f"{gname}/flat k={k}", flat, {"data": k}, ("data",))
            if len(fanouts) > 1:
                anc = canonical_ancestors(fanouts)
                tree = build_plan_tree(g.indptr, g.indices, data, part,
                                       anc, k)
                axes = tree_axis_names(len(fanouts))
                sizes = dict(zip(axes, fanouts))
                yield (f"{gname}/tree {fanouts}", tree, sizes, axes)


def _cmd_verify(args) -> int:
    from . import check_mesh_axes, verify_plan

    fanouts_list = ([_parse_fanouts(s) for s in args.fanouts]
                    or [(4,), (2, 2), (2, 2, 2)])
    failures = 0
    for label, plan, sizes, axes in _build_subjects(
            args.generator, args.n, fanouts_list, args.seed):
        rep = verify_plan(plan)
        mesh_rep = check_mesh_axes(plan, sizes, axes)
        ok = rep.ok and mesh_rep.ok
        failures += not ok
        status = "OK" if ok else "FAIL"
        print(f"[{status}] {label}: {rep.subject}")
        for d in rep.diagnostics + mesh_rep.diagnostics:
            print(f"    {d}")
    print(f"verify: {failures} failing subject(s)")
    return failures


def _cmd_partners(args) -> int:
    from . import partner_table
    subjects = _build_subjects(args.generator[:1], args.n,
                               [_parse_fanouts(args.fanouts)], args.seed)
    for label, plan, _, _ in subjects:
        table = partner_table(plan)
        print(f"{label}:")
        for lvl, rounds in table.items():
            for c, pairs in enumerate(rounds):
                print(f"  level {lvl} round {c}: "
                      + " ".join(f"{a}->{b}" for a, b in pairs))
    return 0


def _cmd_lint(args) -> int:
    from .lint import lint_paths
    rep = lint_paths(args.paths)
    for d in rep.diagnostics:
        print(d)
    print(f"lint: {len(rep.diagnostics)} finding(s) in "
          f"{rep.info.get('files', 0)} file(s)")
    return 1 if rep.diagnostics else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_lint = sub.add_parser("lint", help="AST lint (REPRO0xx rules)")
    p_lint.add_argument("paths", nargs="+",
                        help="files or directories to lint")
    p_lint.set_defaults(fn=_cmd_lint)

    p_ver = sub.add_parser("verify",
                           help="build + verify plans (PLAN/MESH0xx)")
    p_ver.add_argument("--generator", action="append", default=None,
                       help="generator name(s); default grid_2d + rgg_2d")
    p_ver.add_argument("--n", type=int, default=196,
                       help="approximate vertex count (default 196)")
    p_ver.add_argument("--fanouts", action="append", default=[],
                       help="fanouts like 2,2,2 (repeatable); default "
                            "4 / 2,2 / 2,2,2")
    p_ver.add_argument("--seed", type=int, default=0)
    p_ver.set_defaults(fn=_cmd_verify)

    p_par = sub.add_parser("partners",
                           help="print the per-level ppermute partner "
                                "table of a built plan")
    p_par.add_argument("--generator", action="append", default=None)
    p_par.add_argument("--n", type=int, default=64)
    p_par.add_argument("--fanouts", default="2,2")
    p_par.add_argument("--seed", type=int, default=0)
    p_par.set_defaults(fn=_cmd_partners)

    args = ap.parse_args(argv)
    if getattr(args, "generator", None) is None and args.cmd != "lint":
        args.generator = ["grid_2d", "rgg_2d"]
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
