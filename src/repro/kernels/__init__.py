"""Pallas TPU kernels (validated with interpret=True on CPU).

  pdist.py     — pairwise squared distance (balanced k-means hot loop)
  spmv_bell.py — block-ELL SpMV (the paper's HPC kernel, TPU-native re-tile)
  flash.py     — flash attention (LM stack hot loop)
  ops.py       — jit'd wrappers;  ref.py — pure-jnp oracles
"""
