"""Pallas TPU kernel: block-ELL SpMV — the paper's HPC kernel (Sec. VI-a)
re-thought for the TPU memory hierarchy.

GPU SpMV is gather-heavy CSR; TPUs have no efficient per-lane gather, but an
MXU that eats dense (8x128-aligned) tiles.  We therefore re-tile the sparse
matrix into a *block-ELL* format:

  * rows grouped into stripes of BM rows,
  * columns grouped into panels of BK columns,
  * each stripe stores exactly NNZB dense (BM, BK) blocks (the densest
    panels; zero-padded if the stripe has fewer) plus their panel indices.

y[stripe] = sum_b  A_blocks[stripe, b] @ x[cols[stripe, b]]

The kernel walks grid (stripes, NNZB); the x panel for each step is selected
with a data-dependent BlockSpec index_map fed by scalar prefetch
(PrefetchScalarGridSpec), so the right (BK,) slice of x is already in VMEM
when the MXU needs it.  Output accumulates across the NNZB grid dimension.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------------
# Format conversion (host-side, NumPy): CSR -> block-ELL
# --------------------------------------------------------------------------

def csr_to_block_ell(indptr: np.ndarray, indices: np.ndarray,
                     data: np.ndarray, n: int, bm: int = 8, bk: int = 128,
                     nnzb: int | None = None):
    """Convert CSR to block-ELL.

    Returns (blocks, cols, meta) where
      blocks: (S, NNZB, BM, BK) — dense blocks per stripe, in the dtype
              of ``data`` (float dtypes preserved, else float32)
      cols:   (S, NNZB) int32 — column-panel index of each block
      meta:   dict(n=n, bm=bm, bk=bk, fill=fraction of nonzero cells kept)
    If nnzb is None it is set to the max #panels touched by any stripe
    (lossless).  Smaller nnzb drops the sparsest panels (lossy — for
    preconditioner-style use; tests use lossless).
    """
    data = np.asarray(data)
    vdt = data.dtype if np.issubdtype(data.dtype, np.floating) \
        else np.float32
    S = -(-n // bm)
    P = -(-n // bk)
    per_stripe: list[dict[int, np.ndarray]] = [dict() for _ in range(S)]
    for i in range(n):
        s = i // bm
        row = slice(indptr[i], indptr[i + 1])
        for j, v in zip(indices[row], data[row]):
            p = int(j) // bk
            blk = per_stripe[s].get(p)
            if blk is None:
                blk = np.zeros((bm, bk), dtype=vdt)
                per_stripe[s][p] = blk
            blk[i % bm, int(j) % bk] += v
    max_panels = max((len(d) for d in per_stripe), default=1) or 1
    if nnzb is None:
        nnzb = max_panels
    blocks = np.zeros((S, nnzb, bm, bk), dtype=vdt)
    cols = np.zeros((S, nnzb), dtype=np.int32)
    kept = total = 0
    for s, panels in enumerate(per_stripe):
        items = sorted(panels.items(),
                       key=lambda kv: -np.count_nonzero(kv[1]))
        total += sum(np.count_nonzero(b) for _, b in items)
        for b, (p, blk) in enumerate(items[:nnzb]):
            blocks[s, b] = blk
            cols[s, b] = p
            kept += np.count_nonzero(blk)
    meta = dict(n=n, bm=bm, bk=bk, nnzb=nnzb,
                fill=kept / max(total, 1))
    return blocks, cols, meta


def padded_coo_to_block_ell(rows: np.ndarray, cols: np.ndarray,
                            vals: np.ndarray, n: int, bm: int = 8,
                            bk: int = 128, nnzb: int | None = None):
    """Convert padded COO (one device's local block) to block-ELL.

    Unlike :func:`csr_to_block_ell` this is fully vectorized NumPy — no
    per-row Python — so the distributed operator can convert every local
    block at plan-build time.  Zero-valued entries (the padding convention
    of the packed layouts in ``sparse.distributed``) are dropped before
    blocking, so padded slots never allocate a panel.

    Returns (blocks, cols, meta) with the same shapes/semantics as
    :func:`csr_to_block_ell`: blocks (S, NNZB, BM, BK) f32, cols (S, NNZB)
    int32, NNZB defaulting to the max #panels touched by any stripe
    (lossless).  Panels within a stripe are ordered by column-panel index
    (not by density): block-ELL SpMV is order-invariant, and the sorted
    order falls out of the radix sort for free.
    """
    rows = np.asarray(rows).ravel()
    cols = np.asarray(cols).ravel()
    vals = np.asarray(vals).ravel()
    if not np.issubdtype(vals.dtype, np.floating):
        vals = vals.astype(np.float32)
    live = vals != 0
    rows, cols, vals = rows[live], cols[live], vals[live]
    S = max(-(-n // bm), 1)
    stripe = rows // bm
    panel = cols // bk
    Pn = max(-(-int(cols.max() + 1) // bk), 1) if len(cols) else 1
    key = stripe.astype(np.int64) * Pn + panel
    uniq, inv = np.unique(key, return_inverse=True)
    u_stripe = (uniq // Pn).astype(np.int64)
    u_panel = (uniq % Pn).astype(np.int32)
    per_stripe = np.bincount(u_stripe, minlength=S)
    max_panels = max(int(per_stripe.max()) if len(per_stripe) else 0, 1)
    if nnzb is None:
        nnzb = max_panels
    # slot of each unique (stripe, panel) within its stripe: uniq is sorted
    # by (stripe, panel), so the slot is the rank inside the stripe group
    grp_start = np.repeat(np.cumsum(per_stripe) - per_stripe, per_stripe)
    slot = (np.arange(len(uniq)) - grp_start).astype(np.int64)
    blocks = np.zeros((S, nnzb, bm, bk), dtype=vals.dtype)
    colsb = np.zeros((S, nnzb), dtype=np.int32)
    u_keep = slot < nnzb
    colsb[u_stripe[u_keep], slot[u_keep]] = u_panel[u_keep]
    e_slot = slot[inv]
    keep = e_slot < nnzb
    np.add.at(blocks, (stripe[keep], e_slot[keep],
                       rows[keep] % bm, cols[keep] % bk), vals[keep])
    kept = int(keep.sum())
    meta = dict(n=n, bm=bm, bk=bk, nnzb=nnzb,
                fill=kept / max(len(vals), 1))
    return blocks, colsb, meta


# --------------------------------------------------------------------------
# Kernel
# --------------------------------------------------------------------------

def default_interpret() -> bool:
    """Backend detection for the kernel path: the block-ELL kernel uses
    TPU-only Pallas features (PrefetchScalarGridSpec), so it compiles for
    real on TPU and falls back to the Pallas interpreter elsewhere (CPU
    dry-runs, CI).  ``REPRO_PALLAS_INTERPRET=0/1`` overrides detection."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env != "0"
    return jax.default_backend() != "tpu"


def spmv_block_ell(blocks: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray,
                   interpret: bool | None = None) -> jnp.ndarray:
    """y = A @ x with A in block-ELL.  x: (n,); returns (n,) in the
    blocks' dtype (the kernel computes in the blocks' dtype — float64
    blocks keep float64 accumulation under the interpreter/CPU path).

    ``interpret=None`` resolves via :func:`default_interpret` — compiled
    Mosaic on TPU, interpreter elsewhere."""
    if interpret is None:
        interpret = default_interpret()
    return _spmv_block_ell(blocks, cols, x, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _spmv_block_ell(blocks: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray,
                    interpret: bool) -> jnp.ndarray:
    S, NNZB, BM, BK = blocks.shape
    dt = blocks.dtype
    n = x.shape[0]
    P = -(-n // BK)
    xp = jnp.zeros((P, BK), dt).at[
        jnp.arange(n) // BK, jnp.arange(n) % BK].set(x.astype(dt))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(S, NNZB),
        in_specs=[
            pl.BlockSpec((1, 1, BM, BK), lambda s, b, cols: (s, b, 0, 0)),
            pl.BlockSpec((1, BK), lambda s, b, cols: (cols[s, b], 0)),
        ],
        out_specs=pl.BlockSpec((1, BM), lambda s, b, cols: (s, 0)),
    )

    def kernel(cols_ref, blocks_ref, x_ref, y_ref):
        b = pl.program_id(1)

        @pl.when(b == 0)
        def _init():
            y_ref[...] = jnp.zeros_like(y_ref)

        a = blocks_ref[0, 0]                  # (BM, BK)
        xv = x_ref[...]                       # (1, BK)
        y_ref[...] += jax.lax.dot_general(
            xv, a, (((1,), (1,)), ((), ())),
            preferred_element_type=dt)        # (1, BM)

    y = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, BM), dt),
        interpret=interpret,
    )(cols, blocks, xp)
    return y.reshape(-1)[:n]
