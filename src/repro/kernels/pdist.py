"""Pallas TPU kernel: pairwise squared distances, the hot loop of balanced
k-means (geoKM).  D[i, j] = ||X[i] - C[j]||^2.

TPU adaptation: `||x-c||^2 = ||x||^2 - 2 x.c + ||c||^2` turns the distance
computation into a matmul that runs on the MXU.  We tile X into (BN, D) and C
into (BK, D) VMEM blocks; D (the coordinate dim, 2 or 3 for meshes) is padded
to the 128-lane width once at the wrapper level so the MXU contraction is
aligned.  Grid is (n/BN, k/BK); each program computes one (BN, BK) output
tile entirely in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pdist_kernel(x_ref, c_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)          # (BN, D)
    c = c_ref[...].astype(jnp.float32)          # (BK, D)
    xx = jnp.sum(x * x, axis=1, keepdims=True)  # (BN, 1)
    cc = jnp.sum(c * c, axis=1)[None, :]        # (1, BK)
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    out_ref[...] = xx - 2.0 * xc + cc


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def pairwise_sqdist_pallas(x: jnp.ndarray, c: jnp.ndarray, bn: int = 256,
                           bk: int = 128, interpret: bool = True):
    """(n, d) x (k, d) -> (n, k) squared distances.

    interpret=True on CPU (this container); False on real TPU.
    """
    n, d = x.shape
    k, _ = c.shape
    # pad: lanes want multiples of 128 in the minor dim, sublanes 8.
    dp = max(8, -(-d // 8) * 8)
    npad = -(-n // bn) * bn
    kpad = -(-k // bk) * bk
    xp = jnp.zeros((npad, dp), x.dtype).at[:n, :d].set(x)
    cp = jnp.zeros((kpad, dp), c.dtype).at[:k, :d].set(c)

    out = pl.pallas_call(
        _pdist_kernel,
        grid=(npad // bn, kpad // bk),
        in_specs=[
            pl.BlockSpec((bn, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((npad, kpad), jnp.float32),
        interpret=interpret,
    )(xp, cp)
    return out[:n, :k]
