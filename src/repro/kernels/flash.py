"""Pallas TPU kernel: flash attention (online softmax), the LM stack's
perf-critical hot spot.

Tiling: grid (B*H, Sq/BQ, Sk/BK).  Each (bh, qi) owns a (BQ, D) query tile
resident in VMEM; the innermost grid dimension walks key/value tiles of
shape (BK, D), maintaining the running max m, normalizer l and accumulator
acc in VMEM scratch (the classic FlashAttention-2 schedule).  The MXU sees
(BQ, D) x (D, BK) and (BQ, BK) x (BK, D) matmuls — both 128-aligned when
D, BQ, BK are multiples of 128 (D=64 also lowers fine: 8x128 tiles pack 2
rows).  Causal masking is applied in-kernel via block-local iota; fully
masked tiles short-circuit with @pl.when.

jnp oracle: kernels/ref.py::flash_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, bq: int, bk: int, scale: float,
                  n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    run = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)              # (BQ, D)
        k = k_ref[0].astype(jnp.float32)              # (BK, D)
        v = v_ref[0].astype(jnp.float32)              # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, _NEG)
        m_prev = m_ref[...]                           # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q, k, v: (B, H, S, D) -> (B, H, S, D).  Softmax scale 1/sqrt(D)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    scale = D ** -0.5
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, "pad sequence to tile multiples"
    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * H, Sk, D)
    vf = v.reshape(B * H, Sk, D)
    n_k = Sk // bk

    out = pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, bq=bq, bk=bk,
                          scale=scale, n_k=n_k),
        grid=(B * H, Sq // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            # (BQ, 1) running max / normalizer, (BQ, D) accumulator — VMEM
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D)
