"""jit'd public wrappers for the Pallas kernels.

On this CPU container every kernel runs with interpret=True (the kernel body
executes in Python/XLA-CPU for correctness validation); on a real TPU set
``REPRO_PALLAS_INTERPRET=0`` to compile to Mosaic.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from .pdist import pairwise_sqdist_pallas
from .spmv_bell import csr_to_block_ell, spmv_block_ell

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def pairwise_sqdist(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """(n, d) x (k, d) -> (n, k) squared Euclidean distances (Pallas)."""
    return pairwise_sqdist_pallas(x, c, interpret=_INTERPRET)


def spmv(blocks: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray):
    """Block-ELL SpMV y = A @ x (Pallas)."""
    return spmv_block_ell(blocks, cols, x, interpret=_INTERPRET)


__all__ = ["pairwise_sqdist", "spmv", "csr_to_block_ell"]
