"""jit'd public wrappers for the Pallas kernels.

Kernel-path selection lives in ``spmv_bell.default_interpret``: compiled
Mosaic on TPU, the Pallas interpreter elsewhere (CPU containers, CI);
``REPRO_PALLAS_INTERPRET=0/1`` overrides the detection either way.
"""
from __future__ import annotations

import jax.numpy as jnp

from .pdist import pairwise_sqdist_pallas
from .spmv_bell import csr_to_block_ell, default_interpret, spmv_block_ell


def pairwise_sqdist(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """(n, d) x (k, d) -> (n, k) squared Euclidean distances (Pallas)."""
    return pairwise_sqdist_pallas(x, c, interpret=default_interpret())


def spmv(blocks: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray):
    """Block-ELL SpMV y = A @ x (Pallas)."""
    return spmv_block_ell(blocks, cols, x)


__all__ = ["pairwise_sqdist", "spmv", "csr_to_block_ell"]
