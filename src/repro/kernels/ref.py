"""Pure-jnp oracles for every Pallas kernel (allclose targets for tests)."""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_sqdist_ref(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """(n, d), (k, d) -> (n, k): ||x - c||^2, computed directly."""
    diff = x[:, None, :].astype(jnp.float32) - c[None, :, :].astype(jnp.float32)
    return jnp.sum(diff * diff, axis=-1)


def spmv_block_ell_ref(blocks: jnp.ndarray, cols: jnp.ndarray,
                       x: jnp.ndarray) -> jnp.ndarray:
    """Dense oracle for the block-ELL SpMV."""
    S, NNZB, BM, BK = blocks.shape
    n = x.shape[0]
    P = -(-n // BK)
    xp = jnp.zeros((P * BK,), jnp.float32).at[:n].set(x.astype(jnp.float32))
    xp = xp.reshape(P, BK)
    # y[s] = sum_b blocks[s, b] @ xp[cols[s, b]]
    xg = xp[cols]                              # (S, NNZB, BK)
    y = jnp.einsum("sbmk,sbk->sm", blocks, xg)
    return y.reshape(-1)[:n]


def flash_attention_ref(q, k, v, causal: bool = True, scale: float | None = None):
    """Plain softmax attention oracle. q,k,v: (B, H, S, D) (H may be kv-expanded)."""
    import jax
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
