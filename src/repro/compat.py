"""JAX version compatibility shims.

Compat policy
-------------
The repo targets the *current* JAX API surface (``jax.shard_map``,
``jax.sharding.use_mesh`` / ``set_mesh``, ``jax.sharding.get_abstract_mesh``)
but must keep running on the previous generation (0.4.x), where

  * ``shard_map`` lives in ``jax.experimental.shard_map``;
  * there is no ``set_mesh`` / ``use_mesh`` — the ambient mesh is the
    thread-resident *physical* mesh set by ``with mesh:``;
  * there is no ``get_abstract_mesh`` — the ambient mesh is read from
    ``jax.interpreters.pxla.thread_resources``.

Every call site in this repo goes through this module instead of touching
the moving pieces directly.  Rules for new code:

  1. Never call ``jax.sharding.set_mesh`` / ``use_mesh`` directly — use
     :func:`use_mesh` (a context manager on every version).
  2. Never call ``jax.shard_map`` / ``jax.experimental.shard_map.shard_map``
     directly — use :func:`shard_map`.
  3. Never call ``jax.sharding.get_abstract_mesh`` directly — use
     :func:`get_ambient_mesh` (returns ``None`` when no mesh is ambient).
  4. Never import from ``jax.sharding`` at all outside this module — the
     stable names (``Mesh``, ``PartitionSpec``/``P``, ``NamedSharding``)
     are re-exported here so every sharding symbol has one import path.
     Lint rule REPRO001 (``repro.analysis.lint``) enforces this; this
     module is the single allowlisted file.

The shims are resolved once at import time; there is no per-call overhead
beyond one extra Python frame.
"""
from __future__ import annotations

import contextlib
import inspect
from typing import Any, Callable

import jax

JAX_VERSION: tuple[int, ...] = tuple(
    int(p) for p in jax.__version__.split(".")[:3] if p.isdigit())

# Stable re-exports: these classes have kept their names across the
# supported versions, but importing them from one place keeps the rest of
# the tree free of `jax.sharding` (REPRO001) so the next rename lands here.
Mesh = jax.sharding.Mesh
PartitionSpec = jax.sharding.PartitionSpec
P = PartitionSpec
NamedSharding = jax.sharding.NamedSharding

# AbstractMesh: a mesh that carries axis names/sizes but no devices, so
# shard_map programs can be traced (jax.make_jaxpr / eval_shape) on a
# machine with none of the target topology.  The constructor changed
# shape across releases: 0.4.x/0.5.x take a shape tuple of (name, size)
# pairs, current JAX takes (axis_sizes, axis_names).
_AbstractMesh = getattr(jax.sharding, "AbstractMesh", None)
HAS_ABSTRACT_MESH: bool = _AbstractMesh is not None


def abstract_mesh(shape) -> Any:
    """Device-free mesh from ``{axis_name: size}`` (or (name, size) pairs).

    The result carries ``axis_names`` / ``shape`` like a concrete
    ``Mesh`` and is accepted by :func:`shard_map`, so solver programs can
    be abstractly traced for the jaxpr-level audit
    (``repro.analysis.trace``) without any devices.
    """
    if _AbstractMesh is None:
        raise NotImplementedError(
            "jax.sharding.AbstractMesh is unavailable on this JAX version; "
            "device-free tracing needs jax >= 0.4.34")
    pairs = tuple(shape.items()) if hasattr(shape, "items") else tuple(shape)
    try:
        return _AbstractMesh(pairs)
    except TypeError:
        return _AbstractMesh(tuple(s for _, s in pairs),
                             tuple(n for n, _ in pairs))


# Partial-manual shard_map (manual over a subset of mesh axes) only works
# where it is a first-class API (jax.shard_map with axis_names); the 0.4.x
# `auto=` spelling trips an XLA CHECK (IsManualSubgroup) when lowered under
# jit.  Call sites that *optionally* go partial-manual gate on this flag.
SUPPORTS_PARTIAL_MANUAL: bool = hasattr(jax, "shard_map")


# --------------------------------------------------------------------------
# shard_map
# --------------------------------------------------------------------------

if hasattr(jax, "shard_map"):                               # jax >= 0.6
    _shard_map_impl = jax.shard_map
else:                                                       # jax 0.4.x/0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

# the replication-check kwarg was renamed check_rep -> check_vma upstream;
# resolve the name once here so call-time errors surface undisturbed
try:
    _SM_PARAMS = frozenset(
        inspect.signature(_shard_map_impl).parameters)
except (TypeError, ValueError):                             # C-level callable
    _SM_PARAMS = frozenset()
_CHECK_KW = ("check_rep" if "check_rep" in _SM_PARAMS
             else "check_vma" if "check_vma" in _SM_PARAMS else None)


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_rep: bool = False,
              axis_names: frozenset | set | None = None) -> Callable:
    """Version-stable ``shard_map``.

    ``check_rep`` (renamed ``check_vma`` upstream) defaults to False: the
    halo-exchange programs in ``sparse.distributed`` use ``ppermute``,
    whose replication rules differ across versions.

    ``axis_names`` is the current partial-manual spelling (the set of mesh
    axes the body is *manual* over); on 0.4.x it is translated to the
    complementary ``auto=`` frozenset.
    """
    kwargs: dict[str, Any] = {}
    if axis_names is not None:
        if not SUPPORTS_PARTIAL_MANUAL:
            # the 0.4.x `auto=` spelling of partial-manual is a known hard
            # XLA CHECK crash under jit (see SUPPORTS_PARTIAL_MANUAL above)
            # — fail loudly in Python instead of aborting the process
            raise NotImplementedError(
                "partial-manual shard_map (axis_names=...) is not supported "
                "on this JAX version; gate on compat.SUPPORTS_PARTIAL_MANUAL "
                "and fall back to a fully-manual program")
        kwargs["axis_names"] = set(axis_names)
    if _CHECK_KW is not None:
        kwargs[_CHECK_KW] = check_rep
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


# --------------------------------------------------------------------------
# manual-region detection + sharding constraints
# --------------------------------------------------------------------------

def _manual_axes_from_abstract_mesh() -> set:
    """Axis names the ambient *abstract* mesh marks Manual (current JAX).

    Inside a ``shard_map`` body on current JAX the ambient abstract mesh
    carries per-axis types; Manual axes are exactly the ones the body is
    manual over.  ``axis_types`` has been both a tuple (one entry per axis)
    and a dict (type -> names) across releases — handle either shape.
    """
    get_abs = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abs is None:
        return set()
    try:
        mesh = get_abs()
    except Exception:
        return set()
    axis_types = getattr(mesh, "axis_types", None)
    if mesh is None or axis_types is None:
        return set()
    names = tuple(getattr(mesh, "axis_names", ()))
    out: set = set()
    if isinstance(axis_types, dict):                        # type -> name(s)
        for t, ax in axis_types.items():
            if "anual" in str(t):
                out.update(ax if isinstance(ax, (tuple, list, set, frozenset))
                           else (ax,))
    else:                                                   # tuple per axis
        for name, t in zip(names, tuple(axis_types)):
            if "anual" in str(t):
                out.add(name)
    return out


def _bound_axis_names() -> set:
    """Axis names bound in the current trace's axis env (0.4.x/0.5.x).

    Inside a fully-manual ``shard_map`` body the mesh axes are bound as
    named axes (same mechanism as ``psum`` resolution), so this detects
    manual regions on versions without abstract-mesh axis types.  (vmap
    ``axis_name=`` also binds names — callers intersect with the mesh's
    axis names, and constraining over a vmapped axis name would be just as
    illegal, so the over-approximation is safe.)
    """
    fn = getattr(jax.core, "unsafe_get_axis_names_DO_NOT_USE", None)
    if fn is None:
        return set()
    try:
        return set(fn())
    except Exception:
        return set()


def manual_axis_names() -> frozenset:
    """Mesh axis names the *current trace* is manual over.

    Empty outside ``shard_map``; inside a (fully or partially) manual
    region it contains the manual axes, on every supported JAX version.
    Used by ``models.common.maybe_constrain`` to drop manual axes from
    sharding constraints (constraining over a manual axis is an error).
    """
    return frozenset(_manual_axes_from_abstract_mesh() | _bound_axis_names())


def constrain_to_mesh(x, mesh, spec):
    """``with_sharding_constraint`` against an ambient mesh of either kind.

    A concrete ``Mesh`` (the 0.4.x ``with mesh:`` ambient) needs the spec
    wrapped in a ``NamedSharding``; the current-JAX abstract ambient mesh
    accepts the bare ``PartitionSpec``.  Deliberately *not* wrapped in a
    try/except: spec errors (rank mismatch, unknown axis) must surface.
    """
    if isinstance(mesh, jax.sharding.Mesh):
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------------------
# ambient mesh
# --------------------------------------------------------------------------

def use_mesh(mesh) -> contextlib.AbstractContextManager:
    """Context manager making ``mesh`` ambient for sharding decisions.

    Prefers ``jax.sharding.use_mesh`` / ``set_mesh`` (current API); falls
    back to the legacy global-mesh context (``with mesh:``) on 0.4.x.
    """
    for name in ("use_mesh", "set_mesh"):
        fn = getattr(jax.sharding, name, None)
        if fn is not None:
            return fn(mesh)
    return mesh                                  # Mesh.__enter__ (legacy)


def get_ambient_mesh() -> Any | None:
    """The ambient mesh set by :func:`use_mesh`, or ``None``.

    On current JAX this is the abstract mesh; on 0.4.x it is the concrete
    thread-resident physical mesh.  Either carries ``axis_names`` /
    ``shape`` and is accepted by :func:`shard_map`.
    """
    get_abs = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abs is not None:
        try:
            mesh = get_abs()
        except Exception:
            return None
        if mesh is None or not getattr(mesh, "axis_names", ()):
            return None
        return mesh
    try:
        from jax.interpreters.pxla import thread_resources
        mesh = thread_resources.env.physical_mesh
    except Exception:
        return None
    if mesh is None or getattr(mesh, "empty", False):
        return None
    return mesh


__all__ = ["JAX_VERSION", "Mesh", "PartitionSpec", "P", "NamedSharding",
           "shard_map", "use_mesh", "get_ambient_mesh",
           "manual_axis_names", "constrain_to_mesh",
           "abstract_mesh", "HAS_ABSTRACT_MESH"]
