import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape x
mesh) combination against 512 placeholder host devices — proving the
distribution config is coherent without hardware.

Per cell, TWO lowerings happen:
  1. deployable — scanned layers + chunked attention.  Proves compilation,
     yields memory_analysis() (fits-in-HBM evidence) and the collective
     schedule.
  2. cost-faithful — COST_MODE unrolled variants with 1 and 2 layer-groups;
     FLOPs/bytes/collective-bytes are linearly extrapolated to the full
     depth (exact for homogeneous stacks; XLA cost_analysis counts scan
     bodies once, see models/costmode.py).

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from ..compat import NamedSharding, P, use_mesh
from ..configs.registry import ARCHS, get_config
from ..configs.shapes import SHAPES, applicable
from ..models import encdec, transformer
from ..models.config import ModelConfig
from ..models.costmode import cost_mode
from ..models.steps import (batch_specs_sharding, input_specs,
                            make_decode_step, make_prefill, make_train_step)
from ..train.optimizer import AdamWConfig
from .mesh import make_production_mesh
from .roofline import analyze_compiled, roofline_terms

# Gradient-accumulation microbatches per train step, sized so per-device
# residual activations (n_layers x B_loc/accum x S x d_model bf16, kept by
# per-group remat) fit the 16 GB v5e HBM next to params + optimizer state.
ACCUM_STEPS = {
    "mistral-large-123b": 16,
    "internvl2-76b": 16,
    "qwen2.5-14b": 8,
    "stablelm-3b": 4,
    "recurrentgemma-2b": 4,
    # MoE: the shard_map dispatch (§Perf) removed the dispatch blow-up, so
    # accumulation drops 4->2 — fewer FSDP weight re-gathers per step while
    # the dots_nb live set stays under HBM (olmoe 13.6 GiB measured).
    "olmoe-1b-7b": 2,
    "granite-moe-1b-a400m": 2,
    "qwen1.5-0.5b": 2,
    "mamba2-130m": 1,
    "whisper-tiny": 1,
}

# Per-arch remat policy for the layer-group scan (§Perf): 'dots_nb' saves
# projection outputs but recomputes the batched S^2 attention einsums —
# less recompute traffic than 'full' without the HBM blow-up of 'dots'
# (dots saved the S^2 score matrices: olmoe 51 GiB/device, an OOM).
REMAT_POLICY = {
    "internvl2-76b": "dots_nb",   # bound 60.3->54.9 s; fits (13.0 GiB)
    "olmoe-1b-7b": "dots_nb",
    "granite-moe-1b-a400m": "dots_nb",
    "mamba2-130m": "dots_nb",
    "qwen1.5-0.5b": "dots_nb",
    "whisper-tiny": "dots_nb",
}

# Two-level (sqrt-N) remat for the deep stacks whose flat boundary stash
# (n_groups x |x| per device) exceeds HBM even at accum=16 (§Perf):
# mistral 88 groups x 100 MB = 8.8 GiB, internvl2 80 x ~70 MB.
REMAT_CHUNKS = {
    "mistral-large-123b": 8,     # 8 outer x 11 inner
    "internvl2-76b": 8,          # 8 outer x 10 inner
}


def _model_mod(cfg):
    return encdec if cfg.family == "audio" else transformer


def param_structs_and_specs(cfg: ModelConfig, mesh_axes):
    """Abstract param tree + PartitionSpecs without allocating anything."""
    mod = _model_mod(cfg)
    captured = {}

    def f():
        p, s = mod.init_model(jax.random.PRNGKey(0), cfg, mesh_axes)
        captured["specs"] = s
        return p

    sds = jax.eval_shape(f)
    return sds, captured["specs"]


def _shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# §Perf A/B toggle: set False to lower serving cells with the training
# (FSDP x TP) weight layout instead of serving_weight_rules.
SERVING_RULES_ENABLED = True

# Cross-pod gradient compression ("int8" | None) for multi-pod train cells
# — see train/compression.py.  Default off (the baseline reduction is the
# reference; flip for the §Perf A/B).
GRAD_COMPRESSION = None


def serving_weight_rules(cfg: ModelConfig, mesh, batch: int = 0) -> dict:
    """Inference param-sharding policy (§Perf: 'serving sharding != training
    sharding').  Training uses FSDP ('embed' axis over 'data'), which makes
    every decode step all-gather layer weights — pure overhead when weights
    are read-only.  If the TP-only footprint fits comfortably in HBM *and*
    the request batch actually shards over the data axis, replicate the
    'embed' axis (weights stationary, sharded over 'model' only).

    Measured counter-case (mamba2-130m long_500k, B=1): with the batch
    unsharded every device repeats the same compute, so FSDP's weight
    *split* + gather (9.7 MB/step) beats stationary replicated reads
    (bound 196us vs 267us/step) — keep the 2D layout there.
    """
    tp = mesh.shape.get("model", 1)
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            dp *= mesh.shape[ax]
    param_bytes = cfg.param_count * 2          # bf16
    if param_bytes / tp <= 6e9 and batch % dp == 0:
        return {"embed": None}
    return {}


def _lower(cfg: ModelConfig, mode: str, B: int, S: int, mesh,
           donate: bool = True, accum_steps: int = 1):
    """Lower + compile one program.  Returns (lowered, compiled)."""
    from ..models.common import rules_override
    mesh_axes = mesh.axis_names
    dp_total = 1
    for ax in ("pod", "data"):
        if ax in mesh_axes:
            dp_total *= mesh.shape[ax]
    rules = {} if B % dp_total == 0 else {"batch": None}
    if mode in ("prefill", "decode") and SERVING_RULES_ENABLED:
        rules.update(serving_weight_rules(cfg, mesh, batch=B))
    with rules_override(**rules):
        return _lower_inner(cfg, mode, B, S, mesh, donate, accum_steps)


def _lower_inner(cfg, mode, B, S, mesh, donate, accum_steps):
    from ..models.common import logical_to_spec as l2s
    mesh_axes = mesh.axis_names
    params_sds, pspecs = param_structs_and_specs(cfg, mesh_axes)
    p_shard = _shardings(mesh, pspecs)
    dp = tuple(a for a in ("pod", "data") if a in mesh_axes)

    with use_mesh(mesh):
        if mode == "train":
            f32sds = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
            state_sds = {"params": params_sds,
                         "opt": {"m": jax.tree.map(f32sds, params_sds),
                                 "v": jax.tree.map(f32sds, params_sds),
                                 "step": jax.ShapeDtypeStruct((),
                                                              jnp.int32)}}
            state_shard = {"params": p_shard,
                           "opt": {"m": p_shard, "v": p_shard,
                                   "step": NamedSharding(mesh, P())}}
            bspecs = batch_specs_sharding(cfg, mesh_axes)
            batch_sds = input_specs(cfg, B, S, "train")
            b_shard = {k: NamedSharding(mesh, bspecs[k]) for k in batch_sds}
            rep = NamedSharding(mesh, P())
            jitted = jax.jit(
                make_train_step(cfg, AdamWConfig(),
                                accum_steps=accum_steps,
                                grad_compression=GRAD_COMPRESSION),
                in_shardings=(state_shard, b_shard),
                out_shardings=(state_shard,
                               {"loss": rep, "grad_norm": rep, "lr": rep}),
                donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state_sds, batch_sds)
        elif mode == "prefill":
            bspecs = batch_specs_sharding(cfg, mesh_axes)
            batch_sds = input_specs(cfg, B, S, "prefill")
            b_shard = {k: NamedSharding(mesh, bspecs[k]) for k in batch_sds}
            cspecs = (encdec.cache_specs(cfg, mesh_axes)
                      if cfg.family == "audio"
                      else transformer.cache_specs(cfg, mesh_axes))
            out_shard = (NamedSharding(
                mesh, l2s(("batch", None, "act_vocab"),
                          mesh_axes=mesh_axes)),
                         _shardings(mesh, cspecs))
            jitted = jax.jit(make_prefill(cfg),
                             in_shardings=(p_shard, b_shard),
                             out_shardings=out_shard)
            lowered = jitted.lower(params_sds, batch_sds)
        elif mode == "decode":
            if cfg.family == "audio":
                cache_sds = encdec.cache_shape(cfg, B, S)
                cspecs = encdec.cache_specs(cfg, mesh_axes)
            else:
                cache_sds = jax.eval_shape(
                    lambda: transformer.init_cache(cfg, B, S))
                cspecs = transformer.cache_specs(cfg, mesh_axes)
            c_shard = _shardings(mesh, cspecs)
            out_shard = (NamedSharding(
                mesh, l2s(("batch", None, "act_vocab"),
                          mesh_axes=mesh_axes)), c_shard)
            jitted = jax.jit(
                make_decode_step(cfg),
                in_shardings=(p_shard, c_shard,
                              NamedSharding(
                                  mesh, l2s(("batch", "seq"),
                                            mesh_axes=mesh_axes)),
                              NamedSharding(mesh, P())),
                out_shardings=out_shard,
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(
                params_sds, cache_sds,
                jax.ShapeDtypeStruct((B, 1), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
        else:
            raise ValueError(mode)
        compiled = lowered.compile()
    return lowered, compiled


def _cost_cfg(cfg: ModelConfig, k: int) -> ModelConfig:
    """Config with k layer-groups (remainder preserved)."""
    u = len(cfg.unit)
    rem = cfg.n_layers % u
    kw = {"n_layers": k * u + rem}
    if cfg.family == "audio":
        kw["enc_layers"] = k          # enc/dec trip counts move together
    return dataclasses.replace(cfg, **kw)


def _extrapolate(c1: dict, c2: dict, g_full: int) -> dict:
    """cost(G) = a + b*G; b = c2 - c1; return cost(g_full)."""
    out = {}
    for key in ("hlo_flops", "hlo_bytes", "hlo_bytes_structural",
                "hlo_bytes_attn_s2"):
        if key not in c1:
            continue
        b = c2[key] - c1[key]
        out[key] = c1[key] + (g_full - 1) * b
    for ckey in ("collectives", "collectives_raw_f32promoted"):
        if ckey not in c1:
            continue
        coll = {}
        for kind in c1[ckey]:
            b = c2[ckey][kind] - c1[ckey][kind]
            coll[kind] = int(c1[ckey][kind] + (g_full - 1) * b)
        out[ckey] = coll
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               cfg: ModelConfig | None = None, extra_tag: str = "",
               skip_cost: bool = False):
    """Lower + compile one cell (deployable + cost passes)."""
    if cfg is None:
        cfg = get_config(arch)
        if arch in REMAT_POLICY and cfg.remat == "full":
            cfg = dataclasses.replace(cfg, remat=REMAT_POLICY[arch])
        if arch in REMAT_CHUNKS and cfg.remat_chunks == 0:
            cfg = dataclasses.replace(cfg, remat_chunks=REMAT_CHUNKS[arch])
    shape = SHAPES[shape_name]
    if not applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name,
                "skipped": "long_500k needs sub-quadratic decode "
                           "(see DESIGN.md §Arch-applicability)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    B, S = shape.global_batch, shape.seq_len
    accum = ACCUM_STEPS.get(arch, 1) if shape.mode == "train" else 1

    t0 = time.time()
    lowered, compiled = _lower(cfg, shape.mode, B, S, mesh,
                               accum_steps=accum)
    t_deploy = time.time() - t0
    rec = analyze_compiled(lowered, compiled, seq_len=S)
    rec["counted_once"] = {"hlo_flops": rec.pop("hlo_flops"),
                           "hlo_bytes": rec.pop("hlo_bytes"),
                           "collectives": rec.pop("collectives")}

    if not skip_cost:
        t0 = time.time()
        with cost_mode():
            _, comp1 = _lower(_cost_cfg(cfg, 1), shape.mode, B, S, mesh,
                              accum_steps=accum)
            c1 = analyze_compiled(None, comp1, seq_len=S)
            _, comp2 = _lower(_cost_cfg(cfg, 2), shape.mode, B, S, mesh,
                              accum_steps=accum)
            c2 = analyze_compiled(None, comp2, seq_len=S)
        g_full = (cfg.n_layers if cfg.family == "audio" else cfg.n_groups)
        ext = _extrapolate(c1, c2, g_full)
        rec.update(ext)
        rec.update(roofline_terms(ext["hlo_flops"], ext["hlo_bytes"],
                                  ext["collectives"]))
        if "hlo_bytes_structural" in ext:
            from .mesh import HW
            rec["memory_s_structural"] = (ext["hlo_bytes_structural"]
                                          / HW["hbm_bw"])
            rec["memory_s_structural_flash"] = (
                (ext["hlo_bytes_structural"]
                 - ext.get("hlo_bytes_attn_s2", 0.0)) / HW["hbm_bw"])
        rec["cost_pass_s"] = round(time.time() - t0, 2)

    rec["accum_steps"] = accum
    rec.update(arch=arch, shape=shape_name, mode=shape.mode,
               mesh="2x16x16" if multi_pod else "16x16",
               seq_len=S, global_batch=B,
               deploy_compile_s=round(t_deploy, 2),
               model_params=cfg.param_count,
               model_params_active=cfg.active_param_count)
    if extra_tag:
        rec["tag"] = extra_tag
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-cost", action="store_true",
                    help="deployable compile only (no roofline extrapolation)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = ([(a, s) for a in ARCHS for s in SHAPES]
             if args.all else [(args.arch, args.shape)])
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        tag = f"{arch}__{shape}__{'2x16x16' if args.multi_pod else '16x16'}"
        path = outdir / f"{tag}.json"
        if path.exists():
            print(f"[skip cached] {tag}")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            rec = lower_cell(arch, shape, multi_pod=args.multi_pod,
                             skip_cost=args.skip_cost)
        # what lower_cell can actually raise: bad arch/shape config keys
        # (KeyError/ValueError), spec/rank mismatches in the model code
        # (TypeError/ValueError), partial-manual shard_map gaps on old JAX
        # (NotImplementedError), and XLA lowering/compile failures
        # (XlaRuntimeError subclasses RuntimeError on all supported
        # versions).  Anything else — MemoryError, KeyboardInterrupt,
        # genuine bugs — should crash the sweep, not be recorded as a
        # per-cell failure (REPRO002).
        except (KeyError, ValueError, TypeError, NotImplementedError,
                RuntimeError):
            failures += 1
            rec = {"arch": arch, "shape": shape,
                   "error": traceback.format_exc()}
            print(rec["error"])
        path.write_text(json.dumps(rec, indent=2, default=str))
        if "error" not in rec and "skipped" not in rec:
            if "compute_s" in rec:
                print(f"  compute={rec['compute_s']:.4f}s "
                      f"memory={rec['memory_s']:.4f}s "
                      f"collective={rec['collective_s']:.4f}s "
                      f"dominant={rec['dominant']}")
            print(f"  memory_analysis: {rec['memory']} "
                  f"(deploy compile {rec['deploy_compile_s']}s)")
        elif "skipped" in rec:
            print(f"  skipped: {rec['skipped']}")
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
