import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Dry-run 'profiler': dump the largest collectives (with source context)
from the compiled HLO of one (arch, shape, mesh) cell.

This is the §Perf iteration tool — no wall-clock on CPU, so the profile is
the post-SPMD HLO itself: what gets all-gathered/all-reduced, how big, and
from which source line (XLA keeps `metadata.op_name` / source hints).

Usage:
  PYTHONPATH=src python -m repro.launch.profile_hlo --arch olmoe-1b-7b \
      --shape train_4k [--groups 1] [--top 25] [--dump-hlo /tmp/x.hlo]
"""
import argparse
import re

from .roofline import _SHAPE_RE, _DTYPE_BYTES


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.M)

_META_RE = re.compile(r'metadata=\{([^}]*)\}')


def top_collectives(hlo_text: str, top: int = 25):
    rows = []
    for m in _LINE_RE.finditer(hlo_text):
        name, shape_str, kind, start = m.groups()
        nbytes = _shape_bytes(shape_str)
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start():line_end]
        meta = _META_RE.search(line)
        op_name = ""
        if meta:
            mm = re.search(r'op_name="([^"]*)"', meta.group(1))
            if mm:
                op_name = mm.group(1)
        dims = re.search(r'(replica_groups=\S+|source_target_pairs=\S+)', line)
        rows.append((nbytes, kind, name, shape_str[:60], op_name[:110],
                     (dims.group(1)[:60] if dims else "")))
    rows.sort(reverse=True)
    return rows[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--groups", type=int, default=1,
                    help="layer-groups in cost mode (0 = deployable program)")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--dump-hlo", default="")
    args = ap.parse_args()

    from ..configs.registry import get_config
    from ..configs.shapes import SHAPES
    from ..models.costmode import cost_mode
    from .mesh import make_production_mesh
    from .dryrun import ACCUM_STEPS, _cost_cfg, _lower

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    accum = ACCUM_STEPS.get(args.arch, 1) if shape.mode == "train" else 1

    if args.groups > 0:
        with cost_mode():
            _, compiled = _lower(_cost_cfg(cfg, args.groups), shape.mode,
                                 shape.global_batch, shape.seq_len, mesh,
                                 accum_steps=accum)
    else:
        _, compiled = _lower(cfg, shape.mode, shape.global_batch,
                             shape.seq_len, mesh, accum_steps=accum)
    hlo = compiled.as_text()
    if args.dump_hlo:
        with open(args.dump_hlo, "w") as f:
            f.write(hlo)
        print(f"[dumped {len(hlo)} chars to {args.dump_hlo}]")

    rows = top_collectives(hlo, args.top)
    total = {}
    for nbytes, kind, *_ in rows:
        total[kind] = total.get(kind, 0) + nbytes
    print(f"{'bytes':>14s}  {'kind':18s} {'shape':60s} op_name")
    for nbytes, kind, name, shape_str, op_name, dims in rows:
        print(f"{nbytes:14,d}  {kind:18s} {shape_str:60s} {op_name}")
        if dims:
            print(f"{'':14s}  {'':18s} {dims}")
    print("\n[top-N subtotal by kind]")
    for k, v in sorted(total.items(), key=lambda kv: -kv[1]):
        print(f"  {k:20s} {v:15,d}")


if __name__ == "__main__":
    main()
