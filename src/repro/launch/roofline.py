"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

cost_analysis() of the SPMD-partitioned executable reports the *per-device*
program, so dividing by per-chip peaks gives the same number as the global
formulation (global = per_device * chips; chips cancel).

collective_bytes is NOT in cost_analysis: we parse the post-SPMD HLO and sum
the output-tensor sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.  all-reduce counts x2 (it moves the data
twice: reduce-scatter + all-gather on a ring).
"""
from __future__ import annotations

import re
from typing import Any

import numpy as np

from .mesh import HW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %ag = bf16[2,1024,128]{2,1,0} all-gather(...)
#        ROOT %t = (f32[8]{0}, f32[8]{0}) tuple(...)
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))\S*\s+"
    r"([\w-]+)\(([^)]*)", re.M)

_OPERAND_RE = re.compile(r"%([\w.-]+)")


def collective_bytes(hlo_text: str,
                     resolve_promotion: bool = True) -> dict[str, int]:
    """Sum output bytes of each collective kind (skipping -done duplicates).

    resolve_promotion: the CPU backend's float-normalization pass promotes
    every bf16 collective to f32 (convert -> collective -> convert back);
    on the TPU target these run in bf16.  When enabled, a collective whose
    payload is traced to a bf16 producer (operand is a convert / convert-
    fusion of a bf16 value, or the reducer is a '_promoted' clone) is
    counted at bf16 width — i.e. half its f32 wire size.
    """
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    dtype_of: dict[str, str] = {}
    kind_of: dict[str, tuple[str, list[str]]] = {}
    if resolve_promotion:
        for m in _DEF_RE.finditer(hlo_text):
            name, shape_str, opkind, ops = m.groups()
            dt = _SHAPE_RE.match(shape_str.lstrip("("))
            dtype_of[name] = dt.group(1) if dt else "?"
            kind_of[name] = (opkind, _OPERAND_RE.findall(ops or ""))

    def _payload_is_bf16(operand: str | None, line: str) -> bool:
        """True iff the wire payload is a promoted bf16 value.  Signatures:
        a '_promoted' cloned reducer, a convert-of-bf16 operand, or a
        convert/copy/bitcast fusion with a bf16 direct operand."""
        if "_promoted" in line:           # cloned bf16 reducer signature
            return True
        if operand is None:
            return False
        opkind, inner = kind_of.get(operand, ("", []))
        if opkind == "convert":
            return bool(inner) and dtype_of.get(inner[0]) == "bf16"
        if opkind == "fusion":
            return any(dtype_of.get(i) == "bf16" for i in inner)
        return False

    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # async pairs appear as -start/-done; the regex above strips the
        # suffix, but -done would double count.  Check the raw text window.
        tail = hlo_text[m.start():m.end()]
        if f"{kind}-done(" in tail:
            continue
        nbytes = _shape_bytes(shape_str)
        if resolve_promotion and "f32" in shape_str:
            line_end = hlo_text.find("\n", m.end())
            line = hlo_text[m.start():line_end]
            oper = re.search(r"\(%([\w.-]+)", line)
            if _payload_is_bf16(oper.group(1) if oper else None, line):
                nbytes //= 2
        out[kind] += nbytes
    return out


# Ops whose bytes are CPU-backend artifacts (bf16->f32 promotion inserts
# convert/copy pairs around every bf16 arithmetic op; TPU executes bf16
# natively) or that never touch HBM as standalone ops on TPU (layout
# bitcasts, broadcasts of scalars, tuple plumbing).
_STRUCTURAL_SKIP = frozenset((
    "parameter", "constant", "iota", "tuple", "get-tuple-element",
    "bitcast", "convert", "copy", "reduce-precision", "broadcast",
    "after-all", "partition-id",
))

_ENTRY_RE = re.compile(r"^ENTRY [^\{]*\{(.*?)^\}", re.M | re.S)
_SOP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.-]+\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))\S*\s+([\w-]+)\(", re.M)


def structural_bytes(hlo_text: str,
                     s2_dim: int | None = None) -> tuple[float, float]:
    """TPU-adjusted HBM-traffic estimate: 2x the output bytes (write + read
    by consumer) of every entry-computation op that would exist on the TPU
    backend.  cost_analysis() on the CPU backend counts the f32-promotion
    converts the CPU inserts around every bf16 op — measured at >10x the
    real traffic for bf16 models — so the §Roofline memory term reports
    both the raw and this structural figure.

    Returns (total_bytes, s2_bytes): s2_bytes is the subtotal of ops whose
    shape contains the (S, S) attention-score pair — traffic the Pallas
    flash kernel (kernels/flash.py) keeps in VMEM on the TPU target.
    """
    m = _ENTRY_RE.search(hlo_text)
    body = m.group(1) if m else hlo_text
    total = 0
    s2 = 0
    for om in _SOP_RE.finditer(body):
        shape_str, kind = om.groups()
        if kind in _STRUCTURAL_SKIP:
            continue
        b = 2 * _shape_bytes(shape_str)
        total += b
        if s2_dim is not None:
            for _, dims in _SHAPE_RE.findall(shape_str):
                dd = [int(d) for d in dims.split(",") if d]
                if dd.count(s2_dim) >= 2:
                    s2 += b
                    break
    return float(total), float(s2)


def roofline_terms(flops: float, bytes_accessed: float,
                   coll: dict[str, int]) -> dict[str, Any]:
    """Three per-device roofline terms in seconds + the dominant one."""
    comm_bytes = sum(v * (2 if k == "all-reduce" else 1)
                     for k, v in coll.items())
    t_compute = flops / HW["peak_flops"]
    t_memory = bytes_accessed / HW["hbm_bw"]
    t_coll = comm_bytes / HW["link_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(t_compute, t_memory, t_coll)
    return {
        **terms,
        "dominant": dom,
        "collective_bytes": comm_bytes,
        "roofline_fraction": t_compute / bound if bound > 0 else 0.0,
        # fraction of the bound spent doing useful math: 1.0 = compute-bound
    }


def static_roofline(cost) -> dict[str, Any]:
    """Roofline terms from a static ``analysis.trace.TraceCost`` — the
    device-free counterpart of :func:`analyze_compiled`: no compilation,
    no HLO, just the jaxpr-counted per-CG-iteration FLOPs/bytes.

    ``TraceCost`` totals are global (summed over all devices); the
    roofline terms are per-device, so everything is divided by
    ``n_devices`` first.  ``cost.collectives()`` already uses the HLO
    collective names :func:`roofline_terms` expects (psum bytes arrive
    once and get the all-reduce x2 there).
    """
    k = max(int(cost.n_devices), 1)
    coll = {name: b / k for name, b in cost.collectives().items()}
    out = roofline_terms(cost.flops_per_iter / k,
                         cost.hbm_bytes_per_iter / k, coll)
    out["static_flops_per_iter"] = cost.flops_per_iter
    out["static_bytes_per_iter"] = cost.hbm_bytes_per_iter
    out["n_devices"] = k
    out["per_iteration"] = True
    return out


def modeled_makespan(g, part, anc=None, lams=None, speeds=None,
                     c_comp: float = 1.0) -> dict[str, Any]:
    """Partition-level modeled makespan (``core.costmodel``) — the
    machine-model counterpart of the jaxpr-counted :func:`static_roofline`:
    the roofline prices the *compiled program* (FLOPs/bytes/collective
    bytes of the padded SPMD executable), this prices the *partition*
    (per-PU Algorithm-1 compute + per-level deduplicated halo words).
    The two should rank partitions the same way — the padded program pays
    max block size as B and max per-level receive volume as S_lvl, which
    is exactly what the bottleneck model bounds.

    ``g`` is the adjacency :class:`repro.sparse.graph.Graph`; ``part`` a
    (n,) block array or a ``core.api.HierPartition`` (its ``anc``/
    ``lams`` are used unless overridden).  Returns the
    ``BottleneckCost.summary`` dict plus the summed-cut price under the
    same weights (``cut_price``) for side-by-side reporting.
    """
    from ..core.costmodel import BottleneckCost, CutCost

    if hasattr(part, "part"):              # HierPartition duck-type
        hp = part
        part = hp.part
        if anc is None:
            anc = hp.anc
        if lams is None:
            lams = hp.lams
    part = np.asarray(part)
    if anc is None:
        anc = np.zeros((0, int(part.max(initial=0)) + 1), dtype=np.int64)
    kw = dict(lams=None if lams is None else tuple(map(float, lams)),
              speeds=None if speeds is None else tuple(map(float, speeds)),
              c_comp=float(c_comp))
    out = BottleneckCost(**kw).summary(g, part, anc)
    out["cut_price"] = CutCost(**kw).price(g, part, np.atleast_2d(anc))
    return out


def analyze_compiled(lowered, compiled,
                     seq_len: int | None = None) -> dict[str, Any]:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):        # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    out = roofline_terms(flops, byts, coll)
    out["hlo_flops"] = flops
    out["hlo_bytes"] = byts
    out["collectives"] = coll
    out["collectives_raw_f32promoted"] = collective_bytes(
        hlo, resolve_promotion=False)
    sb, s2b = structural_bytes(hlo, s2_dim=seq_len)
    out["hlo_bytes_structural"] = sb
    out["hlo_bytes_attn_s2"] = s2b
    out["memory_s_structural"] = sb / HW["hbm_bw"]
    out["memory_s_structural_flash"] = (sb - s2b) / HW["hbm_bw"]
    try:
        mem = compiled.memory_analysis()
        out["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    # memory_analysis() is optional on CPU/interpret backends: it raises
    # NotImplementedError/RuntimeError (XlaRuntimeError) where the backend
    # has no cost model, and AttributeError on executables that don't
    # expose it at all.  Anything else is a real bug and should surface.
    except (RuntimeError, NotImplementedError, AttributeError) as e:
        out["memory"] = {"error": str(e)}
    return out
