"""Production mesh construction.

Kept as FUNCTIONS so importing this module never touches jax device state
(device count is frozen at first jax init; the dry-run sets
xla_force_host_platform_device_count=512 before importing anything).
"""
from __future__ import annotations

import jax
import numpy as np

from ..compat import Mesh


def tree_axis_names(h: int) -> tuple[str, ...]:
    """Axis names for a depth-``h`` tree mesh, outermost first: the
    two-level ``("pod", "pu")`` of PR 3, ``("pod", "host", "pu")`` at
    depth 3 (the paper's chip < host < pod nesting), generic ``lv{i}``
    prefixes beyond."""
    if h == 1:
        return ("pu",)
    if h == 2:
        return ("pod", "pu")
    if h == 3:
        return ("pod", "host", "pu")
    return tuple(f"lv{i}" for i in range(h - 1)) + ("pu",)


def make_production_mesh(*, multi_pod: bool = False, fanouts=None):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    ``fanouts=(k_1, ..., k_h)`` overrides the shape with an arbitrary-
    depth tree mesh (one axis per tree level, outermost first) for the
    ``comm='hier'`` tree plans; a 3-tuple keeps the multi-pod
    ``("pod", "data", "model")`` axis names so existing specs map on."""
    if fanouts is not None:
        fanouts = tuple(int(f) for f in fanouts)
        axes = (("pod", "data", "model") if len(fanouts) == 3
                else tree_axis_names(len(fanouts)))
        return jax.make_mesh(fanouts, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(k: int = 8, axes: tuple[str, ...] = ("data",),
                   pods: int | None = None, fanouts=None):
    """Small mesh for subprocess tests (host platform devices).

    ``pods=p`` builds the two-level ``(p, k // p)`` mesh with axes
    ``("pod", "pu")`` — the test-scale analogue of
    ``make_production_mesh(multi_pod=True)``'s ``("pod", "data", "model")``
    — for the hierarchical SpMV/CG plans (``sparse.distributed.
    build_plan_hier`` / backend ``dist_hier``).  ``fanouts=(k_1, ...,
    k_h)`` builds the arbitrary-depth tree mesh (one axis per level,
    outermost first — e.g. ``(2, 2, 2)`` is the depth-3
    ``("pod", "host", "pu")`` mesh of ``build_plan_tree``).
    """
    devs = jax.devices()[:k]
    if fanouts is not None:
        if pods is not None or axes != ("data",):
            raise ValueError("fanouts= fixes the axes to the tree levels; "
                             f"drop pods={pods!r} / axes={axes!r}")
        fanouts = tuple(int(f) for f in fanouts)
        if int(np.prod(fanouts)) != k:
            raise ValueError(f"prod(fanouts)={np.prod(fanouts)} != k={k}")
        return Mesh(np.array(devs).reshape(fanouts),
                                 tree_axis_names(len(fanouts)))
    if pods is not None:
        if axes != ("data",):
            raise ValueError("pods= fixes the axes to ('pod', 'pu'); "
                             f"drop axes={axes!r}")
        if pods <= 0 or k % pods:
            raise ValueError(f"pods={pods} must divide k={k}")
        return Mesh(np.array(devs).reshape(pods, k // pods),
                                 ("pod", "pu"))
    shape = (k,) if len(axes) == 1 else None
    return Mesh(np.array(devs).reshape(
        shape or (k // 2, 2)), axes)


# TPU v5e-class hardware constants (per chip) for the roofline analysis.
HW = dict(
    peak_flops=197e12,      # bf16 FLOP/s
    hbm_bw=819e9,           # B/s
    link_bw=50e9,           # B/s per ICI link
)

# Deployment flags for real TPU pods: compute/communication overlap is
# XLA's latency-hiding scheduler — the collective schedule this framework
# emits (weight all-gathers ahead of their dots, grad reduce-scatters
# behind the backward) is what the scheduler overlaps.  The CPU dry-run
# backend runs collectives synchronously, so these are set at launch, not
# measured here.
TPU_XLA_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true "
)
