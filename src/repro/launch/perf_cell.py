import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb runner: lower one (arch, shape) cell under a named
variant (set of optimization toggles) and record the roofline terms to
experiments/perf/<arch>__<shape>__<mesh>__<variant>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.perf_cell --arch olmoe-1b-7b \
      --shape train_4k --variant baseline --moe-impl dense --seq-sp off
"""
import argparse
import dataclasses
import json
from pathlib import Path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--moe-impl", default=None,
                    choices=("dense", "shard_map", "auto"))
    ap.add_argument("--seq-sp", default=None, choices=("on", "off", "auto"))
    ap.add_argument("--remat", default=None,
                    choices=("full", "dots", "dots_nb", "none"))
    ap.add_argument("--no-serve-rules", action="store_true",
                    help="serve cells with the training FSDPxTP layout")
    ap.add_argument("--remat-chunks", type=int, default=None)
    ap.add_argument("--grad-compression", default=None, choices=("int8",))
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    from ..configs.registry import get_config
    from . import dryrun

    cfg = get_config(args.arch)
    repl = {}
    if args.moe_impl is not None:
        repl["moe_impl"] = args.moe_impl
    if args.seq_sp is not None:
        repl["seq_sp"] = args.seq_sp
    if args.remat is not None:
        repl["remat"] = args.remat
    if args.remat_chunks is not None:
        repl["remat_chunks"] = args.remat_chunks
    if repl:
        cfg = dataclasses.replace(cfg, **repl)
    if args.accum is not None:
        dryrun.ACCUM_STEPS[args.arch] = args.accum
    if args.no_serve_rules:
        dryrun.SERVING_RULES_ENABLED = False
    if args.grad_compression:
        dryrun.GRAD_COMPRESSION = args.grad_compression

    rec = dryrun.lower_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                            cfg=cfg, extra_tag=args.variant)
    mesh = "2x16x16" if args.multi_pod else "16x16"
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    path = outdir / f"{args.arch}__{args.shape}__{mesh}__{args.variant}.json"
    path.write_text(json.dumps(rec, indent=2, default=str))
    for k in ("compute_s", "memory_s", "collective_s", "dominant",
              "roofline_fraction"):
        if k in rec:
            print(f"{k}: {rec[k]}")
    if "collectives" in rec:
        print("collectives:", rec["collectives"])
    print(f"[saved {path}]")


if __name__ == "__main__":
    main()
