"""Serving launcher: solver-as-a-service for sparse systems, plus the
token-serving scaffold (batched prefill + decode with a KV/state cache).

Solver serving (the paper's workload at traffic scale — many small/medium
CG solves against a pool of matrices, ROADMAP's solver-as-a-service item):

  PYTHONPATH=src python -m repro.launch.serve --solver --requests 64

:class:`SolverService` is the serving layer the bench and tests drive:

  * **operator cache** — LRU keyed by :func:`matrix_fingerprint` (shape +
    nnz + a blake2b content hash of indptr/indices/data), so repeat
    traffic skips ``build_plan`` / ``build_plan_tree`` / format
    conversion entirely and lands on the cached operator's jitted solve
    (the ``DistributedOperator._fused`` per-``(tol, max_iters,
    precondition)`` trace cache compounds with this: cache-hit requests
    re-enter an already-compiled program).
  * **bucketed admission** — each request's RHS batch is padded up to a
    size class from ``buckets`` (the MaxText ``offline_inference``
    pattern), so one compiled multi-RHS program per (matrix, class)
    serves every batch width in the class.  Padding columns are
    all-zero, and a zero column is *free* under the masked batched CG:
    ``||b||^2 = 0`` keeps it inactive from iteration 0.
  * **counters** — :class:`ServeStats` tracks operator/bucket hits and
    misses, evictions, and real vs padded columns (padding waste).
  * **streaming updates** — :meth:`SolverService.update_matrix` applies an
    :class:`repro.sparse.replan.EdgeDelta` to a cached matrix: the plan is
    patched in O(delta) when it carries a replan cache, the old
    fingerprint is retired (no stale hits), and an optional
    :class:`repro.core.replan_policy.DriftPolicy` prices every update so
    a drifted partition triggers a full repartition with solver-state
    migration instead of unbounded quality decay.

Token serving (unchanged scaffold):

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import time
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from ..core.replan_policy import DriftDecision, DriftMonitor, DriftPolicy
from ..sparse import cg_solve, make_operator
from ..sparse.cg import CGResult
from ..sparse.graph import structure_graph
from ..sparse.replan import (EdgeDelta, apply_delta_csr, apply_edge_delta,
                             migrate_state)


# --------------------------------------------------------------------------
# Solver serving
# --------------------------------------------------------------------------

def matrix_fingerprint(indptr, indices, data) -> str:
    """Cache key for a CSR matrix: ``<n>:<nnz>:<blake2b>`` over the dtype,
    shape and bytes of all three arrays.  Content-hashed — two structurally
    identical matrices with different values never collide."""
    h = hashlib.blake2b(digest_size=16)
    for a in (indptr, indices, data):
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(np.int64(a.size).tobytes())
        h.update(a.tobytes())
    return f"{len(indptr) - 1}:{len(indices)}:{h.hexdigest()}"


@dataclasses.dataclass
class ServeStats:
    """Admission/cache counters, reported by the bench and asserted in
    tests.  ``padding_waste`` is the fraction of solved columns that were
    admission padding (cheap — padded columns converge in 0 iterations —
    but still traced/allocated work worth watching)."""

    operator_hits: int = 0
    operator_misses: int = 0
    operator_evictions: int = 0
    bucket_hits: int = 0            # (matrix, size-class) already warmed
    bucket_misses: int = 0          # first solve of the class: traces
    real_cols: int = 0
    padded_cols: int = 0
    solves: int = 0
    plan_patches: int = 0           # update_matrix served by O(delta) patch
    plan_rebuilds: int = 0          # update_matrix paid a full plan build
    drift_trips: int = 0            # rebuilds forced by the drift monitor

    @property
    def padding_waste(self) -> float:
        total = self.real_cols + self.padded_cols
        return self.padded_cols / total if total else 0.0


@dataclasses.dataclass
class UpdateResponse:
    """One served :meth:`SolverService.update_matrix`: the matrix moved to
    a new fingerprint, either by an O(delta) plan patch or by a full
    rebuild (drift trip / no replan cache)."""

    fingerprint: str                # fingerprint of the mutated matrix
    old_fingerprint: str
    patched: bool                   # True: O(delta) patch; False: rebuild
    repartitioned: bool             # rebuild used a fresh partition
    drift: DriftDecision | None     # None when no drift policy is set
    state: tuple | None             # migrated solver state (if passed in)


@dataclasses.dataclass
class SolveResponse:
    """One served solve: gathered solution plus per-column convergence
    info (padding columns already stripped)."""

    x: np.ndarray                   # (n,) or (n, nb)
    iters: np.ndarray               # () or (nb,) int
    residual: np.ndarray            # () or (nb,)
    fingerprint: str = ""
    bucket: int = 0
    cache_hit: bool = False         # operator came from the cache
    warm: bool = False              # (matrix, bucket) class already traced


class SolverService:
    """Multi-RHS CG serving over a pool of matrices (see module docstring).

    ``backend`` / ``op_kw`` go to :func:`repro.sparse.make_operator`
    verbatim (e.g. ``backend='dist_hier', part=..., k=8, mesh=...,
    pods=2``), so one service class fronts every SpMV backend; the
    solver parameters are fixed per service (one compiled program per
    matrix x size class).  ``capacity`` bounds the operator cache
    (least-recently-used eviction drops the operator *and all its
    compiled solves*)."""

    def __init__(self, backend: str = "coo",
                 buckets: tuple[int, ...] = (1, 2, 4, 8, 16),
                 capacity: int = 8, tol: float = 1e-6,
                 max_iters: int = 500, precondition: str | None = None,
                 drift: DriftPolicy | None = None, repartition=None,
                 **op_kw):
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError(f"buckets must be sorted unique size classes; "
                             f"got {buckets!r}")
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.backend = backend
        self.buckets = tuple(int(b) for b in buckets)
        self.capacity = capacity
        self.tol = tol
        self.max_iters = max_iters
        self.precondition = precondition
        self.op_kw = op_kw
        self.stats = ServeStats()
        self._ops: OrderedDict[str, object] = OrderedDict()
        self._warm: set[tuple[str, int]] = set()
        # fingerprint -> jitted batched cg_solve for operators without a
        # fused .solve (the single-device backends): without this every
        # warm request would re-trace the while_loop body, and the cache
        # hit would only skip format conversion, not compilation
        self._jit: dict[str, object] = {}
        # (fingerprint, bucket) -> static price (trace audit + roofline)
        self._cost: dict[tuple[str, int], dict] = {}
        # streaming updates (update_matrix): host CSR per cached matrix,
        # drift monitor per matrix, per-matrix partition overrides from
        # drift-tripped repartitions
        self.drift = drift
        self.repartition = repartition
        self._csr: dict[str, tuple] = {}
        self._monitors: dict[str, DriftMonitor] = {}
        self._parts: dict[str, np.ndarray] = {}

    def bucket_for(self, nb: int) -> int:
        """Smallest admission class holding ``nb`` columns; oversize
        requests become their own exact-width class (served, but each
        distinct width traces its own program)."""
        for b in self.buckets:
            if nb <= b:
                return b
        return nb

    def operator_for(self, indptr, indices, data,
                     fingerprint: str | None = None):
        """``(fingerprint, operator, hit)`` with LRU admission: a cached
        matrix skips plan construction / format conversion entirely."""
        fp = fingerprint or matrix_fingerprint(indptr, indices, data)
        op = self._ops.get(fp)
        if op is not None:
            self._ops.move_to_end(fp)
            self.stats.operator_hits += 1
            return fp, op, True
        self.stats.operator_misses += 1
        op = make_operator(indptr, indices, data, self.backend,
                           **self.op_kw)
        self._install(fp, op, (np.asarray(indptr), np.asarray(indices),
                               np.asarray(data)))
        return fp, op, False

    def _install(self, fp: str, op, csr: tuple) -> None:
        """Admit (fp, op) into the LRU, keeping the host CSR for
        :meth:`update_matrix`; evicts down to capacity."""
        self._ops[fp] = op
        self._csr[fp] = csr
        while len(self._ops) > self.capacity:
            old_fp, _ = self._ops.popitem(last=False)
            self._retire(old_fp)
            self.stats.operator_evictions += 1

    def _retire(self, fp: str) -> None:
        """Drop every per-matrix cache keyed by ``fp`` — compiled solves,
        warm size classes, static prices, host CSR, drift state."""
        self._warm = {w for w in self._warm if w[0] != fp}
        self._jit.pop(fp, None)
        self._cost = {key: v for key, v in self._cost.items()
                      if key[0] != fp}
        self._csr.pop(fp, None)
        self._monitors.pop(fp, None)
        self._parts.pop(fp, None)

    def update_matrix(self, fingerprint: str, delta: EdgeDelta,
                      state=None) -> UpdateResponse:
        """Apply an :class:`EdgeDelta` to a cached matrix in place of a
        full re-admission: the operator moves to the mutated matrix's
        fingerprint via an O(delta) plan patch
        (:func:`repro.sparse.replan.apply_edge_delta`) when its plan
        carries a replan cache, and via a full rebuild otherwise.

        With a :class:`DriftPolicy` (``drift=`` at construction) every
        update is priced against the last full plan's baseline; a
        threshold trip forces a rebuild on a fresh partition from the
        ``repartition`` callable (``repartition(g) -> (n,) part``) and
        migrates ``state`` (a sequence of operator-space solver vectors)
        onto the new layout instead of restarting.  Trips without a
        ``repartition`` callable are recorded (``stats.drift_trips``,
        ``response.drift``) but still served by patching — the frozen
        partition is all there is.

        The old fingerprint is fully retired: a subsequent solve against
        the *unmutated* matrix is an operator miss, never a stale hit.
        """
        csr = self._csr.get(fingerprint)
        if csr is None:
            raise KeyError(f"unknown or evicted fingerprint "
                           f"{fingerprint!r}")
        op = self._ops[fingerprint]
        indptr, indices, data = csr
        ip2, ix2, d2 = apply_delta_csr(indptr, indices, data, delta)
        new_fp = matrix_fingerprint(ip2, ix2, d2)
        plan = getattr(op, "plan", None)
        cache = getattr(plan, "_replan", None)

        decision = None
        monitor = self._monitors.pop(fingerprint, None)
        if self.drift is not None:
            if cache is not None:
                part, anc = cache.part, getattr(plan, "anc", None)
            else:
                part = self._parts.get(fingerprint,
                                       self.op_kw.get("part"))
                anc = None
            if part is not None:
                if monitor is None:
                    monitor = DriftMonitor(self.drift)
                    monitor.reset(structure_graph(indptr, indices, data),
                                  part, anc)
                g2 = structure_graph(ip2, ix2, d2)
                decision = monitor.observe(g2, part, anc)
                if decision.repartition:
                    self.stats.drift_trips += 1

        repartitioned = (decision is not None and decision.repartition
                         and self.repartition is not None)
        out_state = tuple(state) if state is not None else None
        if cache is not None and not repartitioned:
            new_plan = apply_edge_delta(plan, delta)
            new_op = dataclasses.replace(op, plan=new_plan)
            self.stats.plan_patches += 1
            patched = True
        else:
            kw = dict(self.op_kw)
            if fingerprint in self._parts:
                kw["part"] = self._parts[fingerprint]
            if repartitioned:
                kw["part"] = np.asarray(
                    self.repartition(structure_graph(ip2, ix2, d2)))
                self._parts[new_fp] = kw["part"]
            new_op = make_operator(ip2, ix2, d2, self.backend, **kw)
            self.stats.plan_rebuilds += 1
            patched = False
            new_plan = getattr(new_op, "plan", None)
            if out_state is not None and plan is not None \
                    and new_plan is not None:
                moved = migrate_state(plan, new_plan, *out_state)
                out_state = moved if isinstance(moved, tuple) else (moved,)
            if monitor is not None:
                new_cache = getattr(new_plan, "_replan", None)
                monitor.reset(
                    structure_graph(ip2, ix2, d2),
                    new_cache.part if new_cache is not None
                    else kw.get("part"),
                    getattr(new_plan, "anc", None))

        self._ops.pop(fingerprint, None)
        self._retire(fingerprint)
        self._install(new_fp, new_op, (ip2, ix2, d2))
        if monitor is not None:
            self._monitors[new_fp] = monitor
        return UpdateResponse(fingerprint=new_fp,
                              old_fingerprint=fingerprint,
                              patched=patched, repartitioned=repartitioned,
                              drift=decision, state=out_state)

    def static_cost(self, indptr, indices, data, nb: int = 1,
                    fingerprint: str | None = None) -> dict:
        """Device-free price of serving a request of width ``nb``: admit
        it into its size class, resolve the operator through the cache,
        trace the solver on an abstract mesh (``analysis.trace``) and run
        the static roofline over the counted per-iteration cost.  No
        compilation, no devices — usable at admission time to pick a
        bucket or reject oversize work.  Cached per (matrix, bucket),
        evicted with the operator.

        When the service fronts a partitioned backend (``part=`` in
        ``op_kw``), the result also carries ``modeled`` — the
        partition-level cost-model summary (``roofline.modeled_makespan``:
        bottleneck makespan, critical PU, per-PU compute/comm split)
        next to the program-level trace price."""
        from ..analysis.trace import audit_operator
        from .roofline import modeled_makespan, static_roofline

        bucket = self.bucket_for(int(nb))
        fp, op, _ = self.operator_for(indptr, indices, data, fingerprint)
        cached = self._cost.get((fp, bucket))
        if cached is not None:
            return cached
        rep = audit_operator(op, nb=bucket if bucket > 1 else None,
                             tol=self.tol, max_iters=self.max_iters,
                             precondition=self.precondition,
                             subject=f"serve {self.backend} nb={bucket}")
        cost = rep.info.get("cost_cg") or rep.info.get("cost_matvec")
        out = {"fingerprint": fp, "bucket": bucket, "ok": rep.ok,
               "diagnostics": [str(d) for d in rep.diagnostics],
               "cost": cost, "roofline": static_roofline(cost)}
        part = self.op_kw.get("part")
        if part is not None:
            from ..sparse.graph import from_edges
            n = len(indptr) - 1
            src = np.repeat(np.arange(n), np.diff(np.asarray(indptr)))
            g = from_edges(n, src, np.asarray(indices), symmetrize=True)
            g.weights[:] = 1.0      # structure only: the matrix values
            # (e.g. negative Laplacian off-diagonals) are not link costs
            out["modeled"] = modeled_makespan(g, part)
        self._cost[(fp, bucket)] = out
        return out

    def solve(self, indptr, indices, data, b,
              fingerprint: str | None = None) -> SolveResponse:
        """Serve one request: admit ``b`` ((n,) or (n, nb)) into its size
        class, resolve the operator through the cache, run the batched
        masked CG, strip the padding columns."""
        b = np.asarray(b)
        single = b.ndim == 1
        bcols = b[:, None] if single else b
        nb = bcols.shape[1]
        bucket = self.bucket_for(nb)
        fp, op, hit = self.operator_for(indptr, indices, data, fingerprint)
        warm = (fp, bucket) in self._warm
        if warm:
            self.stats.bucket_hits += 1
        else:
            self.stats.bucket_misses += 1
            self._warm.add((fp, bucket))
        self.stats.real_cols += nb
        self.stats.padded_cols += bucket - nb
        self.stats.solves += 1
        if bucket > nb:
            pad = np.zeros((bcols.shape[0], bucket - nb), bcols.dtype)
            bcols = np.concatenate([bcols, pad], axis=1)
        res = self._run(fp, op, bcols)
        x = op.gather(res.x)[:, :nb]
        iters = np.asarray(res.iters)[:nb]
        residual = np.asarray(res.residual)[:nb]
        if single:
            x, iters, residual = x[:, 0], iters[0], residual[0]
        return SolveResponse(x=x, iters=iters, residual=residual,
                             fingerprint=fp, bucket=bucket, cache_hit=hit,
                             warm=warm)

    def _run(self, fp, op, bcols) -> CGResult:
        if hasattr(op, "solve"):        # fused distributed program (its
            # own per-(tol, max_iters, precondition) trace cache)
            return op.solve(bcols, tol=self.tol, max_iters=self.max_iters,
                            precondition=self.precondition)
        fn = self._jit.get(fp)
        if fn is None:
            fn = jax.jit(lambda b: cg_solve(
                op, b, tol=self.tol, max_iters=self.max_iters,
                precondition=self.precondition, batched=True))
            self._jit[fp] = fn          # retraces once per size class
        return fn(op.scatter(bcols))


def _solver_traffic(args) -> None:
    """Synthetic traffic mix against a SolverService: a small pool of
    Laplacian systems, Zipf-ish repeat pattern, random batch widths.
    Prints solves/sec, latency percentiles and the cache counters."""
    from ..sparse.generators import grid
    from ..sparse.graph import laplacian_csr

    rng = np.random.default_rng(0)
    pool = []
    for i, side in enumerate((12, 16, 20, 24)[:args.pool]):
        g = grid((side, side))
        pool.append(laplacian_csr(g, shift=0.05 * (i + 1)))
    svc = SolverService(backend="coo", capacity=args.capacity,
                        tol=1e-6, max_iters=500)
    lat = []
    t_all = time.perf_counter()
    for r in range(args.requests):
        indptr, indices, data = pool[int(rng.zipf(1.5)) % len(pool)]
        nb = int(rng.integers(1, 9))
        b = rng.normal(size=(len(indptr) - 1, nb)).astype(np.float32)
        t0 = time.perf_counter()
        resp = svc.solve(indptr, indices, data, b)
        np.asarray(resp.x)
        lat.append(time.perf_counter() - t0)
    wall = time.perf_counter() - t_all
    lat_ms = np.sort(np.array(lat)) * 1e3
    s = svc.stats
    print(f"requests={args.requests} solves/sec={args.requests / wall:.1f}")
    print(f"latency ms: p50={np.percentile(lat_ms, 50):.2f} "
          f"p95={np.percentile(lat_ms, 95):.2f} "
          f"max={lat_ms[-1]:.2f}")
    print(f"operator cache: hits={s.operator_hits} "
          f"misses={s.operator_misses} evictions={s.operator_evictions}")
    print(f"buckets: hits={s.bucket_hits} misses={s.bucket_misses} "
          f"padding_waste={s.padding_waste:.1%}")


# --------------------------------------------------------------------------
# Token serving (scaffold)
# --------------------------------------------------------------------------

def _token_serving(args) -> None:
    from ..configs.registry import get_config
    from ..models import encdec, transformer
    from ..models.steps import make_decode_step

    cfg = get_config(args.arch, smoke=args.smoke)
    mod = encdec if cfg.family == "audio" else transformer
    params, _ = mod.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B = args.batch
    cache_len = args.prompt_len + max(args.gen, 1)
    prompts = rng.integers(0, cfg.vocab, size=(B, args.prompt_len),
                           dtype=np.int32)

    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.asarray(rng.normal(scale=0.02, size=(
            B, cfg.n_img_tokens, cfg.d_model)).astype(np.float32))
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(rng.normal(scale=0.02, size=(
            B, cfg.n_frames, cfg.d_model)).astype(np.float32))

    if cfg.family == "audio":
        prefill = jax.jit(lambda p, b: encdec.prefill_forward(
            p, cfg, b["frames"], b["tokens"], cache_len=cache_len))
    elif cfg.family == "vlm":
        prefill = jax.jit(lambda p, b: transformer.prefill_forward(
            p, cfg, b["tokens"], cache_len=cache_len,
            img_embeds=b["img_embeds"]))
    else:
        prefill = jax.jit(lambda p, b: transformer.prefill_forward(
            p, cfg, b["tokens"], cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    key = jax.random.PRNGKey(1)
    out = [prompts]
    t0 = time.perf_counter()
    for t in range(args.gen):
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(
            sub, logits[:, -1].astype(jnp.float32) / args.temperature,
            axis=-1).astype(jnp.int32)[:, None]
        tok = jnp.minimum(tok, cfg.vocab - 1)
        out.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok,
                               jnp.int32(args.prompt_len + t))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0
    gen = np.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen}")
    if args.gen:        # --gen 0 is prefill-only: no per-token rate exists
        print(f"prefill {t_prefill*1e3:.1f} ms; decode "
              f"{t_decode/args.gen*1e3:.2f} ms/token "
              f"({B*args.gen/t_decode:.1f} tok/s)")
    else:
        print(f"prefill {t_prefill*1e3:.1f} ms; decode skipped (--gen 0)")
    print("sample token ids:",
          gen[0, :args.prompt_len + min(args.gen, 8)].tolist())


def main():
    from ..configs.registry import ARCHS

    ap = argparse.ArgumentParser()
    ap.add_argument("--solver", action="store_true",
                    help="serve CG solves (synthetic traffic) instead of "
                         "tokens")
    ap.add_argument("--requests", type=int, default=32,
                    help="solver mode: synthetic requests to serve")
    ap.add_argument("--pool", type=int, default=3,
                    help="solver mode: distinct matrices in the pool")
    ap.add_argument("--capacity", type=int, default=8,
                    help="solver mode: operator-cache capacity")
    ap.add_argument("--arch", choices=ARCHS, default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()
    if args.solver:
        _solver_traffic(args)
    else:
        _token_serving(args)


if __name__ == "__main__":
    main()
