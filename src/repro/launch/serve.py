"""Serving launcher: batched prefill + decode loop with a KV/state cache.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import ARCHS, get_config
from ..models import encdec, transformer
from ..models.steps import make_decode_step, make_prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mod = encdec if cfg.family == "audio" else transformer
    params, _ = mod.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B = args.batch
    cache_len = args.prompt_len + args.gen
    prompts = rng.integers(0, cfg.vocab, size=(B, args.prompt_len),
                           dtype=np.int32)

    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.asarray(rng.normal(scale=0.02, size=(
            B, cfg.n_img_tokens, cfg.d_model)).astype(np.float32))
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(rng.normal(scale=0.02, size=(
            B, cfg.n_frames, cfg.d_model)).astype(np.float32))

    if cfg.family == "audio":
        prefill = jax.jit(lambda p, b: encdec.prefill_forward(
            p, cfg, b["frames"], b["tokens"], cache_len=cache_len))
    elif cfg.family == "vlm":
        prefill = jax.jit(lambda p, b: transformer.prefill_forward(
            p, cfg, b["tokens"], cache_len=cache_len,
            img_embeds=b["img_embeds"]))
    else:
        prefill = jax.jit(lambda p, b: transformer.prefill_forward(
            p, cfg, b["tokens"], cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    key = jax.random.PRNGKey(1)
    out = [prompts]
    tok = None
    t0 = time.perf_counter()
    for t in range(args.gen):
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(
            sub, logits[:, -1].astype(jnp.float32) / args.temperature,
            axis=-1).astype(jnp.int32)[:, None]
        tok = jnp.minimum(tok, cfg.vocab - 1)
        out.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok,
                               jnp.int32(args.prompt_len + t))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0
    gen = np.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill {t_prefill*1e3:.1f} ms; decode "
          f"{t_decode/args.gen*1e3:.2f} ms/token "
          f"({B*args.gen/t_decode:.1f} tok/s)")
    print("sample token ids:", gen[0, :args.prompt_len + 8].tolist())


if __name__ == "__main__":
    main()
