"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault tolerance demo: run with --fail-at-step N, re-run the same command —
the trainer resumes from the last checkpoint.  Heterogeneous topologies
(--hetero fast_frac,fast_speed,fast_mem) route the global batch with
Algorithm 1 (core.block_sizes.hetero_batch_split).
"""
from __future__ import annotations

import argparse

from ..configs.registry import ARCHS, get_config
from ..core.topology import Topology
from ..train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at-step", type=int, default=-1)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--hetero", default="",
                    help="fast_frac,fast_speed,fast_mem e.g. 0.25,4,5.2")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    topo = None
    if args.hetero:
        frac, spd, mem = (float(x) for x in args.hetero.split(","))
        topo = Topology.topo1(max(args.batch, 4), frac, spd, mem)
    tcfg = TrainerConfig(steps=args.steps, seq_len=args.seq,
                         global_batch=args.batch, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every, lr=args.lr,
                         fail_at_step=args.fail_at_step)
    tr = Trainer(cfg, tcfg, topo=topo)
    if not args.no_resume and tr.maybe_resume():
        print(f"resumed from step {tr.step}")
    if topo is not None:
        print(f"Algorithm-1 batch shares: {tr.shares.tolist()}")
    losses = tr.run()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
