"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md §Roofline
table.

MODEL_FLOPS (useful math) per cell:
  train:   6 * N_active * tokens      (fwd 2x + bwd 4x per param per token)
  prefill: 2 * N_active * tokens
  decode:  2 * N_active * batch   (+ KV-cache attention reads are counted in
           the memory term, not FLOPs)
Ratio MODEL_FLOPS / HLO_FLOPS measures how much compiled compute is useful —
remat recompute, attention scores, and dispatch overhead push it below 1.

Usage:  python -m repro.launch.report [--dir experiments/dryrun] [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(rec: dict) -> float:
    n = rec.get("model_params_active") or rec.get("model_params", 0)
    B, S = rec["global_batch"], rec["seq_len"]
    if rec["mode"] == "train":
        return 6.0 * n * B * S
    if rec["mode"] == "prefill":
        return 2.0 * n * B * S
    return 2.0 * n * B          # decode: one token per sequence


def load(dirpath: Path, mesh: str | None = None) -> list[dict]:
    out = []
    for p in sorted(dirpath.glob("*.json")):
        rec = json.loads(p.read_text())
        if "error" in rec:
            rec.setdefault("arch", p.stem.split("__")[0])
            rec.setdefault("shape", p.stem.split("__")[1])
            rec.setdefault("mesh", p.stem.split("__")[2])
        if mesh and rec.get("mesh", p.stem.split("__")[-1]) != mesh:
            continue
        out.append(rec)
    return out


def fmt_row(rec: dict) -> str:
    if "skipped" in rec:
        return (f"| {rec['arch']} | {rec['shape']} | — | — | — | — | skip | "
                f"— | — | — | — | — | sub-quadratic only |")
    if "error" in rec:
        return (f"| {rec['arch']} | {rec['shape']} | — | — | — | — | ERROR "
                f"| — | — | — | — | — | see json |")
    chips = CHIPS[rec["mesh"]]
    mf = model_flops(rec)
    hlo_global = rec["hlo_flops"] * chips
    ratio = mf / hlo_global if hlo_global else 0.0
    dom = rec["dominant"].replace("_s", "")
    peak = rec.get("memory", {}).get("peak_bytes") or 0
    temp = rec.get("memory", {}).get("temp_bytes") or 0
    frac = rec.get("roofline_fraction", 0.0)
    bound_raw = max(rec["compute_s"], rec["memory_s"], rec["collective_s"])
    # MFU-style fraction: *useful* model FLOPs over the bound — unlike the
    # HLO-compute fraction this cannot be inflated by remat recompute
    t_useful = mf / (chips * 197e12)
    mfu_raw = t_useful / bound_raw if bound_raw else 0.0
    mem_fl = rec.get("memory_s_structural_flash")
    if mem_fl is not None:
        # TPU-adjusted dominance/fraction (see §Roofline measurement notes)
        bound_adj = max(rec["compute_s"], mem_fl, rec["collective_s"])
        frac_adj = rec["compute_s"] / bound_adj if bound_adj else 0.0
        mfu_adj = t_useful / bound_adj if bound_adj else 0.0
        adj = f"{mem_fl:.4f} | {frac_adj:.3f} | {mfu_adj:.3f}"
    else:
        adj = "— | — | —"
    return (f"| {rec['arch']} | {rec['shape']} | {rec['compute_s']:.4f} | "
            f"{rec['memory_s']:.4f} | {rec['collective_s']:.4f} | "
            f"**{dom}** | {frac:.3f} | {mfu_raw:.3f} | {adj} | {ratio:.2f} | "
            f"{(peak + temp) / 2**30:.1f} GiB |")


HEADER = ("| arch | shape | compute s | memory s | collective s | dominant "
          "| roofline frac | MFU frac | mem s (tpu-adj) | frac (tpu-adj) "
          "| MFU (tpu-adj) | useful/HLO | dev mem |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|---|---|")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = load(Path(args.dir), args.mesh)
    print(HEADER)
    for rec in recs:
        print(fmt_row(rec))
    done = [r for r in recs if "compute_s" in r]
    if done:
        worst = min(done, key=lambda r: r.get("roofline_fraction", 1))
        collb = max(done, key=lambda r: r["collective_s"]
                    / max(r["compute_s"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']}"
              f" ({worst.get('roofline_fraction', 0):.3f})")
        print(f"most collective-bound: {collb['arch']}/{collb['shape']}")


if __name__ == "__main__":
    main()
