"""Deterministic synthetic token pipeline.

Serves the role of the input pipeline substrate: deterministic given (seed,
step) — so a restarted job resumes mid-epoch at the exact batch — and
shard-aware (each data-parallel rank can materialize only its slice).

The token stream is a mixture of Zipf-distributed unigrams with short
Markov motifs, which gives a learnable (loss goes down) yet stationary
distribution — adequate for throughput/convergence smoke tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3


class SyntheticLM:
    """batch(step) -> {'tokens','labels'} with labels = next-token."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # motif table: each token deterministically prefers a successor
        self._succ = rng.integers(0, v, size=v, dtype=np.int64)

    def batch(self, step: int, rank: int = 0, world: int = 1):
        cfg = self.cfg
        per = cfg.global_batch // world
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + rank)
        base = rng.zipf(cfg.zipf_a, size=(per, cfg.seq_len + 1))
        base = (base - 1) % cfg.vocab
        # 50% of positions follow the motif successor of the previous token
        follow = rng.random((per, cfg.seq_len)) < 0.5
        seq = base.copy()
        for t in range(1, cfg.seq_len + 1):
            f = follow[:, t - 1]
            seq[f, t] = self._succ[seq[f, t - 1]]
        tokens = seq[:, :-1].astype(np.int32)
        labels = seq[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}
