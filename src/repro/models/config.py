"""Model configuration — one dataclass drives all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                     # 0 -> d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"
    activation: str = "swiglu"
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    max_seq: int = 32768

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    moe_capacity: float = 1.25            # capacity factor (GShard)
    moe_impl: str = "auto"                # dense | shard_map | auto (§Perf)
    seq_sp: str = "auto"                  # on | off | auto — Megatron-SP
    remat: str = "full"                   # full | dots | dots_nb | none —
                                          # activation ckpt of the layer scan
    remat_chunks: int = 0                 # >1: two-level (sqrt-N) remat —
                                          # outer scan of `remat_chunks`
                                          # checkpointed blocks; boundary
                                          # stash (outer+inner)/groups of flat

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4

    # hybrid (RecurrentGemma): repeating unit of mixers
    pattern: tuple[str, ...] = ()         # e.g. ("rec", "rec", "attn")
    window: int = 0                       # local-attention window

    # enc-dec (Whisper)
    enc_layers: int = 0
    n_frames: int = 1500                  # stub audio frontend length

    # VLM
    n_img_tokens: int = 0                 # stub vision frontend length

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding shards
        evenly on any mesh axis (classic vocab padding; padded ids are never
        emitted by the data pipeline)."""
        return -(-self.vocab // 256) * 256

    @property
    def unit(self) -> tuple[str, ...]:
        """Repeating layer-kind unit for the scan."""
        if self.family == "ssm":
            return ("ssm",)
        if self.family == "hybrid":
            return self.pattern or ("rec", "rec", "attn")
        if self.family == "moe":
            return ("moe",)
        return ("dense",)                 # dense / vlm / audio backbones

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.unit)

    @property
    def remainder(self) -> tuple[str, ...]:
        return self.unit[: self.n_layers % len(self.unit)]

    @property
    def seq_shard_activations(self) -> bool:
        """Megatron-style sequence parallelism for the residual stream.

        Measured OFF by default (§Perf): under per-group activation
        checkpointing every remat replay repeats the SP all-gathers, and the
        backward cotangent RS/AG pairs land on f32 intermediates — qwen2.5
        train_4k collective term 12.1s (off) vs 48.4s (on), olmoe 1.39s vs
        3.12s.  SP pays off only with saved (non-remat) boundary
        activations; flip per-config with seq_sp="on" to reproduce the
        measurement."""
        if self.seq_sp != "auto":
            return self.seq_sp == "on"
        return False

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state is O(1)/O(window) in sequence length —
        eligibility for the long_500k shape."""
        return self.family in ("ssm", "hybrid")

    @property
    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        mlp_mults = 3 if self.activation == "swiglu" else 2
        dense_mlp = mlp_mults * d * self.d_ff
        moe_mlp = self.n_experts * mlp_mults * d * self.d_expert \
            + d * self.n_experts
        per = {"dense": attn + dense_mlp,
               "moe": attn + moe_mlp,
               "ssm": self._ssm_params(),
               "rec": self._rec_params() + dense_mlp,
               }
        total = 0
        unit = self.unit
        for i in range(self.n_layers):
            kind = unit[i % len(unit)]
            if kind == "attn":
                kind = "dense"
            total += per.get(kind, attn + dense_mlp)
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "audio":
            total += self.enc_layers * (attn + dense_mlp) * 2  # +cross-attn
        return total

    def _ssm_params(self) -> int:
        d_in = self.ssm_expand * self.d_model
        conv_dim = d_in + 2 * self.ssm_state
        proj_in = self.d_model * (2 * d_in + 2 * self.ssm_state
                                  + d_in // self.ssm_headdim)
        return proj_in + conv_dim * self.conv_kernel + d_in * self.d_model

    def _rec_params(self) -> int:
        d = self.d_model
        return 3 * d * d + d * self.conv_kernel  # in/gate/out + conv

    @property
    def active_param_count(self) -> int:
        """N_active for MoE rooflines (experts scaled by top_k/E)."""
        if self.family != "moe":
            return self.param_count
        d = self.d_model
        mlp_mults = 3 if self.activation == "swiglu" else 2
        attn = d * self.head_dim * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * self.head_dim * d
        active_mlp = self.top_k * mlp_mults * d * self.d_expert
        total = self.n_layers * (attn + active_mlp + d * self.n_experts)
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        return total
