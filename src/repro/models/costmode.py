"""Cost-faithful lowering mode.

XLA's cost_analysis counts a while/scan body ONCE, not times its trip count,
so a scanned layer stack under-reports FLOPs/bytes/collectives by ~n_layers.
Under COST_MODE the models (a) unroll the layer-group scan into a Python
loop and (b) disable query-chunking in attention (the lax.map there is also
a scan).  The dry-run lowers unrolled variants with 1 and 2 groups and
extrapolates linearly — exact for homogeneous stacks:

    cost(G) = a + b * G   =>   b = cost(2) - cost(1),  total = cost(1) + (G-1) b

The deployable (scanned, chunked) program is still compiled for
memory_analysis and as the runnability proof; COST_MODE only affects the
cost-measurement lowering.
"""
from __future__ import annotations

import contextlib

COST_MODE = False


@contextlib.contextmanager
def cost_mode():
    global COST_MODE
    old = COST_MODE
    COST_MODE = True
    try:
        yield
    finally:
        COST_MODE = old
