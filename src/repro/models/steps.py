"""train_step / serve_step builders for every architecture family, plus the
ShapeDtypeStruct input specs the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..train.optimizer import AdamWConfig, adamw_update
from . import encdec, transformer
from .common import cross_entropy
from .config import ModelConfig

AUX_COEF = 0.01


def model_module(cfg: ModelConfig):
    return encdec if cfg.family == "audio" else transformer


def loss_fn(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    if cfg.family == "audio":
        logits, aux = encdec.forward(params, cfg, batch["frames"],
                                     batch["tokens"])
    elif cfg.family == "vlm":
        logits, aux = transformer.forward(params, cfg, batch["tokens"],
                                          img_embeds=batch["img_embeds"])
    else:
        logits, aux = transformer.forward(params, cfg, batch["tokens"])
    ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return ce + AUX_COEF * aux


def make_train_step(cfg: ModelConfig, opt: AdamWConfig,
                    accum_steps: int = 1,
                    grad_compression: str | None = None) -> Callable:
    """(state, batch) -> (state, metrics).  state = {params, opt}.

    ``accum_steps`` > 1 splits the global batch into microbatches and
    accumulates gradients in f32 (lax.scan; unrolled under COST_MODE so the
    roofline extrapolation stays exact).  This is what lets 100B+ models fit
    the per-device activation budget at global_batch 256.

    ``grad_compression="int8"`` makes the *cross-pod* gradient reduction
    manual and int8-quantized (train/compression.py) — 2x fewer inter-pod
    wire bytes than bf16, 4x fewer than f32.  No-op on single-pod meshes.
    """
    from . import costmode

    def grad_fn(params, batch):
        if grad_compression is not None:
            from ..compat import get_ambient_mesh
            mesh = get_ambient_mesh()
            if (mesh is not None and "pod" in mesh.axis_names
                    and mesh.shape["pod"] > 1):
                from ..train.compression import podwise_value_and_grad
                bspecs = batch_specs_sharding(cfg, tuple(mesh.axis_names))
                fn = podwise_value_and_grad(
                    lambda p, b: loss_fn(p, cfg, b), mesh,
                    {k: bspecs[k] for k in batch},
                    compression=grad_compression)
                return fn(params, batch)
        return jax.value_and_grad(
            functools.partial(loss_fn, cfg=cfg))(params, batch=batch)

    def train_step(state, batch):
        if accum_steps == 1:
            loss, grads = grad_fn(state["params"], batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])

            def micro_step(acc, mb):
                loss_acc, g_acc = acc
                loss, g = grad_fn(state["params"], mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_acc + loss, g_acc), None

            if costmode.COST_MODE:
                acc = (jnp.zeros(()), zeros)
                for i in range(accum_steps):
                    mb = jax.tree.map(lambda x: x[i], micro)
                    acc, _ = micro_step(acc, mb)
            else:
                acc, _ = jax.lax.scan(micro_step, (jnp.zeros(()), zeros),
                                      micro)
            loss, grads = acc
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        new_p, new_opt, m = adamw_update(opt, state["params"], grads,
                                         state["opt"])
        return {"params": new_p, "opt": new_opt}, \
            {"loss": loss, **m}

    return train_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    """(params, cache, tokens (B,1), pos) -> (logits, cache)."""
    mod = model_module(cfg)

    def step(params, cache, tokens, pos):
        return mod.decode_step(params, cfg, cache, tokens, pos)

    return step


def make_prefill(cfg: ModelConfig) -> Callable:
    """Prefill: (params, batch) -> (last-token logits, KV/state cache)."""

    def prefill(params, batch):
        if cfg.family == "audio":
            return encdec.prefill_forward(params, cfg, batch["frames"],
                                          batch["tokens"])
        if cfg.family == "vlm":
            return transformer.prefill_forward(
                params, cfg, batch["tokens"],
                img_embeds=batch["img_embeds"])
        return transformer.prefill_forward(params, cfg, batch["tokens"])

    return prefill


# -- dry-run input specs -------------------------------------------------------

def input_specs(cfg: ModelConfig, batch: int, seq: int,
                mode: str = "train") -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    i32 = jnp.int32
    f32 = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if mode in ("train", "prefill"):
        out = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32)}
        if mode == "train":
            out["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)
        if cfg.family == "vlm":
            out["img_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.n_img_tokens, cfg.d_model), f32)
        if cfg.family == "audio":
            out["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.n_frames, cfg.d_model), f32)
        return out
    if mode == "decode":
        return {"tokens": jax.ShapeDtypeStruct((batch, 1), i32),
                "pos": jax.ShapeDtypeStruct((), i32)}
    raise ValueError(mode)


def batch_specs_sharding(cfg: ModelConfig, mesh_axes) -> dict:
    """PartitionSpecs for the batch dict (batch axis over pod+data;
    honors rules_override, e.g. batch=None for global_batch < DP)."""
    from .common import logical_to_spec as l2s
    tok = l2s(("batch", "seq"), mesh_axes=mesh_axes)
    out = {"tokens": tok, "labels": tok}
    if cfg.family == "vlm":
        out["img_embeds"] = l2s(("batch", None, None), mesh_axes=mesh_axes)
    if cfg.family == "audio":
        out["frames"] = l2s(("batch", None, None), mesh_axes=mesh_axes)
    return out
