"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: x -> { gate branch: W_gate -> GeLU } * { rec branch: W_in -> causal
conv1d -> RG-LRU } -> W_out.

RG-LRU:  r_t = sigma(W_a x + b_a);  i_t = sigma(W_x x + b_x)
         log a_t = -c * softplus(Lambda) * r_t          (c = 8)
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)

Training uses jax.lax.associative_scan on the first-order recurrence
(log-space gates for stability); decode is the single-step update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamCollector

_C = 8.0


def init_rglru(col: ParamCollector, d_model: int, conv_kernel: int = 4):
    d = d_model
    p, s = {}, {}
    p["w_in"], s["w_in"] = col.param((d, d), ("embed", "heads"))
    p["w_gate"], s["w_gate"] = col.param((d, d), ("embed", "heads"))
    p["w_out"], s["w_out"] = col.param((d, d), ("heads", "embed"))
    p["conv_w"], s["conv_w"] = col.param((conv_kernel, d), ("conv", "heads"),
                                         scale=0.5)
    p["conv_b"], s["conv_b"] = col.param((d,), ("act_heads",), init="zeros")
    p["w_a"], s["w_a"] = col.param((d, d), ("embed", "heads"))
    p["b_a"], s["b_a"] = col.param((d,), ("act_heads",), init="zeros")
    p["w_x"], s["w_x"] = col.param((d, d), ("embed", "heads"))
    p["b_x"], s["b_x"] = col.param((d,), ("act_heads",), init="zeros")
    # Lambda init so that a^c in [0.9, 0.999] (paper's recommendation)
    p["lam"], s["lam"] = col.param((d,), ("act_heads",), init="ones")
    return p, s


def _causal_conv(xc, w, b):
    K = w.shape[0]
    pad = jnp.pad(xc, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(pad[:, i:i + xc.shape[1]] * w[i] for i in range(K)) + b


def _gates(p, u):
    """u: (..., d) post-conv activations -> (log_a, gated_input) in f32."""
    r = jax.nn.sigmoid((u @ p["w_a"] + p["b_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["w_x"] + p["b_x"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a2 = jnp.exp(2.0 * log_a)
    gx = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-9)) * i * u.astype(jnp.float32)
    return log_a, gx


def rglru_forward(p, x, return_state: bool = False):
    """Training / prefill.  x: (B, S, D) -> (B, S, D)."""
    gate = jax.nn.gelu(x @ p["w_gate"])
    xin = x @ p["w_in"]
    u = _causal_conv(xin, p["conv_w"], p["conv_b"])
    log_a, gx = _gates(p, u)

    def combine(c1, c2):
        la1, b1 = c1
        la2, b2 = c2
        return la1 + la2, jnp.exp(la2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a, gx), axis=1)
    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    if return_state:
        K = p["conv_w"].shape[0]
        cache = {"conv": xin[:, x.shape[1] - (K - 1):], "h": h[:, -1]}
        return out, cache
    return out


def rglru_init_cache(d_model: int, batch: int, conv_kernel: int = 4,
                     dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, conv_kernel - 1, d_model), dtype),
        "h": jnp.zeros((batch, d_model), jnp.float32),
    }


def rglru_decode(p, x, cache):
    """One step.  x: (B, 1, D)."""
    xt = x[:, 0]
    gate = jax.nn.gelu(xt @ p["w_gate"])
    xin = xt @ p["w_in"]                                  # (B, D)
    hist = jnp.concatenate([cache["conv"], xin[:, None]], axis=1)
    u = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    log_a, gx = _gates(p, u)
    h = jnp.exp(log_a) * cache["h"] + gx
    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    return y[:, None], {"conv": hist[:, 1:], "h": h}
