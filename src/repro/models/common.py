"""Shared model building blocks — functional style, params as nested dicts.

Sharding: every parameter is created through ``param(...)`` with *logical*
axis names; ``logical_to_spec`` maps them to mesh axes (MaxText-style rules).
``init`` functions return ``(params, specs)`` twin trees so the launcher can
hand jit exact in/out shardings without tracing.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from ..compat import P

# -- logical axis rules ------------------------------------------------------
# mesh axes: ("pod",) "data", "model".  FSDP shards the embed/d_model axis of
# weights over "data"; TP shards heads / ffn / vocab over "model"; "pod" is
# pure DP (params replicated across pods, gradients all-reduced).

DEFAULT_RULES: dict[str, Any] = {
    "embed": "data",        # d_model axis of weights -> FSDP
    "heads": "model",       # attention heads / q projection
    "kv": None,             # kv heads (small; replicate, see DESIGN)
    "mlp": "model",         # ffn hidden
    "vocab": "model",       # embedding/lm-head vocab axis
    "experts": "model",     # MoE expert axis (EP)
    "expert_mlp": None,     # per-expert hidden (already sharded via experts)
    "layers": None,         # scan axis — never sharded
    "conv": None,
    "state": None,          # SSM state axis
    "batch": ("pod", "data"),
    "seq": None,
    "cache_seq": "model",   # decode KV cache: shard sequence over model axis
    "act_embed": None,      # activation d_model axis
    "seq_sp": "model",      # sequence parallelism: residual stream S axis
                            # sharded over 'model' between TP blocks
                            # (Megatron-SP; halves TP collective bytes)
    "act_heads": "model",
    "act_mlp": "model",
    "act_vocab": "model",
}


_OVERRIDES: dict[str, Any] = {}


@contextlib.contextmanager
def rules_override(**kw):
    """Temporarily override logical-axis rules (e.g. batch=None when the
    global batch is smaller than the data-parallel degree)."""
    global _OVERRIDES
    old = dict(_OVERRIDES)
    _OVERRIDES.update(kw)
    try:
        yield
    finally:
        _OVERRIDES = old


def logical_to_spec(axes: tuple[str | None, ...],
                    rules: dict[str, Any] | None = None,
                    mesh_axes: tuple[str, ...] = ("data", "model")) -> P:
    """Map logical axis names to a PartitionSpec, dropping mesh axes that are
    absent from the target mesh (e.g. 'pod' on the single-pod mesh)."""
    rules = {**(rules or DEFAULT_RULES), **_OVERRIDES}
    out = []
    for ax in axes:
        r = rules.get(ax) if ax else None
        if isinstance(r, tuple):
            r = tuple(m for m in r if m in mesh_axes) or None
            if isinstance(r, tuple) and len(r) == 1:
                r = r[0]
        elif r is not None and r not in mesh_axes:
            r = None
        out.append(r)
    return P(*out)


# -- param creation ----------------------------------------------------------

class ParamCollector:
    """Accumulates twin (params, specs) trees during init."""

    def __init__(self, rng: jax.Array, dtype=jnp.float32,
                 mesh_axes: tuple[str, ...] = ("data", "model"),
                 rules: dict[str, Any] | None = None):
        self.rng = rng
        self.dtype = dtype
        self.mesh_axes = mesh_axes
        self.rules = rules or DEFAULT_RULES

    def next_rng(self) -> jax.Array:
        self.rng, sub = jax.random.split(self.rng)
        return sub

    def param(self, shape: tuple[int, ...], axes: tuple[str | None, ...],
              init: str = "normal", scale: float | None = None):
        spec = logical_to_spec(axes, self.rules, self.mesh_axes)
        if init == "zeros":
            w = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            w = jnp.ones(shape, self.dtype)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[-1]
            s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
            w = (jax.random.normal(self.next_rng(), shape, jnp.float32)
                 * s).astype(self.dtype)
        return w, spec


def maybe_constrain(x: jnp.ndarray, axes: tuple[str | None, ...]):
    """with_sharding_constraint via logical axis names against the ambient
    mesh (``compat.get_ambient_mesh`` — works on 0.4.x, where the previous
    ``jax.sharding.get_abstract_mesh`` spelling silently no-op'd and dryrun
    cells lowered without internal constraints).

    No-op when no mesh is ambient (single-device tests).  Inside
    ``shard_map`` *manual* regions, constraining over a manual axis is an
    error, so manual axes are dropped from the candidate mesh axes — a
    fully-manual region (every mesh axis manual, e.g. the MoE dispatch
    body) skips the constraint entirely, while a partial-manual region
    (e.g. the pod-manual gradient-compression wrapper) still constrains
    over the remaining auto axes.  Genuine spec errors (rank mismatch,
    unknown mesh axis) are deliberately *not* swallowed.
    """
    from ..compat import constrain_to_mesh, get_ambient_mesh, \
        manual_axis_names

    mesh = get_ambient_mesh()
    if mesh is None:
        return x
    axis_names = tuple(getattr(mesh, "axis_names", ()))
    if not axis_names:
        return x
    manual = manual_axis_names()
    avail = tuple(a for a in axis_names if a not in manual)
    if not avail:
        return x                       # fully-manual shard_map region
    spec = logical_to_spec(axes, mesh_axes=avail)
    return constrain_to_mesh(x, mesh, spec)


# -- norms --------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale + bias


def apply_norm(kind: str, x, p):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def init_norm(col: ParamCollector, d: int, kind: str):
    if kind == "rmsnorm":
        w, s = col.param((d,), ("act_embed",), init="ones")
        return {"scale": w}, {"scale": s}
    ws, ss = col.param((d,), ("act_embed",), init="ones")
    wb, sb = col.param((d,), ("act_embed",), init="zeros")
    return {"scale": ws, "bias": wb}, {"scale": ss, "bias": sb}


# -- RoPE ----------------------------------------------------------------------

def rope_table(seq: int, head_dim: int, theta: float = 10000.0,
               offset: int = 0):
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    ang = pos[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: (..., S, H, hd).  cos/sin: (S, hd/2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
                           ).astype(x.dtype)


# -- loss -----------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean CE, stable in f32; vocab axis may be model-sharded (XLA inserts
    the reductions)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
