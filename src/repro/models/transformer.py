"""Unified decoder LM covering dense / MoE / SSM / hybrid / VLM backbones.

Layers are stacked and applied with lax.scan over *groups* (one group = the
config's repeating unit, e.g. ("rec","rec","attn") for RecurrentGemma), with
jax.checkpoint on the group body — compile time is depth-independent and the
remat policy is uniform.  Remainder layers (n_layers % len(unit)) get their
own unscanned params.

All init functions return twin (params, specs) trees; the launcher feeds the
specs straight into jit in_shardings.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from ..compat import P
from . import costmode
from .attention import (attn_decode, attn_forward, attn_prefill,
                        init_attention)
from .common import (ParamCollector, apply_norm, cross_entropy, init_norm,
                     maybe_constrain)
from .config import ModelConfig
from .mlp import init_mlp, init_moe, mlp_forward, moe_forward
from .rglru import init_rglru, rglru_decode, rglru_forward, rglru_init_cache
from .ssm import init_ssm, ssm_decode, ssm_forward, ssm_init_cache


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# -- layer init ---------------------------------------------------------------

def _init_layer(col: ParamCollector, kind: str, cfg: ModelConfig):
    p, s = {}, {}
    p["norm1"], s["norm1"] = init_norm(col, cfg.d_model, cfg.norm)
    if kind in ("dense", "moe", "attn"):
        p["attn"], s["attn"] = init_attention(
            col, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            cfg.qkv_bias)
        p["norm2"], s["norm2"] = init_norm(col, cfg.d_model, cfg.norm)
        if kind == "moe":
            p["ffn"], s["ffn"] = init_moe(col, cfg.d_model, cfg.n_experts,
                                          cfg.d_expert, cfg.activation)
        else:
            p["ffn"], s["ffn"] = init_mlp(col, cfg.d_model, cfg.d_ff,
                                          cfg.activation)
    elif kind == "rec":
        p["rec"], s["rec"] = init_rglru(col, cfg.d_model, cfg.conv_kernel)
        p["norm2"], s["norm2"] = init_norm(col, cfg.d_model, cfg.norm)
        p["ffn"], s["ffn"] = init_mlp(col, cfg.d_model, cfg.d_ff,
                                      cfg.activation)
    elif kind == "ssm":
        p["ssm"], s["ssm"] = init_ssm(col, cfg.d_model, cfg.ssm_state,
                                      cfg.ssm_headdim, cfg.ssm_expand,
                                      cfg.conv_kernel)
    else:
        raise ValueError(kind)
    return p, s


def _stack(trees: list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _stack_specs(spec_tree, prefix: str | None = None):
    return jax.tree.map(lambda s: P(prefix, *s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def init_model(rng, cfg: ModelConfig,
               mesh_axes: tuple[str, ...] = ("data", "model")):
    """Returns (params, specs)."""
    col = ParamCollector(rng, dtype=_dtype(cfg), mesh_axes=mesh_axes)
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    params["embed"], specs["embed"] = col.param(
        (cfg.vocab_padded, cfg.d_model), ("vocab", "embed"), scale=0.02)
    unit = cfg.unit
    group_ps, group_ss = [], []
    for _ in range(cfg.n_groups):
        gp, gs = {}, {}
        for i, kind in enumerate(unit):
            gp[f"{i}:{kind}"], gs[f"{i}:{kind}"] = _init_layer(col, kind, cfg)
        group_ps.append(gp)
        group_ss.append(gs)
    params["layers"] = _stack(group_ps)
    specs["layers"] = _stack_specs(group_ss[0])
    rem_p, rem_s = {}, {}
    for i, kind in enumerate(cfg.remainder):
        rem_p[f"{i}:{kind}"], rem_s[f"{i}:{kind}"] = _init_layer(col, kind,
                                                                 cfg)
    params["rem"] = rem_p
    specs["rem"] = rem_s
    params["final_norm"], specs["final_norm"] = init_norm(
        col, cfg.d_model, cfg.norm)
    if not cfg.tie_embeddings:
        params["lm_head"], specs["lm_head"] = col.param(
            (cfg.d_model, cfg.vocab_padded), ("embed", "vocab"), scale=0.02)
    return params, specs


# -- layer apply ---------------------------------------------------------------

def _apply_layer(kind: str, p, x, cfg: ModelConfig, aux: list):
    # With sequence parallelism the block *outputs* are constrained to the
    # S-sharded layout before the residual add, steering the partitioner to
    # reduce-scatter the TP partial sums instead of all-reduce + slice.
    seq_ax = "seq_sp" if cfg.seq_shard_activations else "seq"
    h = apply_norm(cfg.norm, x, p["norm1"])
    if kind in ("dense", "moe", "attn"):
        window = cfg.window if (kind == "attn" and cfg.family == "hybrid"
                                and cfg.window) else None
        y_attn = attn_forward(p["attn"], h, n_heads=cfg.n_heads,
                              n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                              rope_theta=cfg.rope_theta, window=window)
        x = x + maybe_constrain(y_attn, ("batch", seq_ax, "act_embed"))
        h2 = apply_norm(cfg.norm, x, p["norm2"])
        if kind == "moe":
            y, a = moe_forward(p["ffn"], h2, n_experts=cfg.n_experts,
                               top_k=cfg.top_k, activation=cfg.activation,
                               capacity_factor=cfg.moe_capacity,
                               impl=cfg.moe_impl,
                               seq_sharded=cfg.seq_shard_activations)
            aux.append(a)
            x = x + y
        else:
            y = mlp_forward(p["ffn"], h2, cfg.activation)
            x = x + maybe_constrain(y, ("batch", seq_ax, "act_embed"))
    elif kind == "rec":
        x = x + rglru_forward(p["rec"], h)
        h2 = apply_norm(cfg.norm, x, p["norm2"])
        x = x + mlp_forward(p["ffn"], h2, cfg.activation)
    elif kind == "ssm":
        x = x + ssm_forward(p["ssm"], h, ssm_state=cfg.ssm_state,
                            headdim=cfg.ssm_headdim, expand=cfg.ssm_expand)
    return x


def forward(params, cfg: ModelConfig, tokens: jnp.ndarray,
            img_embeds: jnp.ndarray | None = None,
            remat: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.  Returns (logits, aux_loss)."""
    tokens = maybe_constrain(tokens, ("batch", "seq"))
    x = params["embed"][tokens].astype(_dtype(cfg))
    x = maybe_constrain(x, ("batch", "seq", "act_embed"))
    if img_embeds is not None and cfg.n_img_tokens:
        x = x.at[:, :cfg.n_img_tokens].set(img_embeds.astype(x.dtype))
    unit = cfg.unit
    aux_total = jnp.zeros((), jnp.float32)
    seq_ax = "seq_sp" if cfg.seq_shard_activations else "seq"

    def body(carry, gp):
        x, aux_acc = carry
        aux: list = []
        for i, kind in enumerate(unit):
            x = _apply_layer(kind, gp[f"{i}:{kind}"], x, cfg, aux)
            x = maybe_constrain(x, ("batch", seq_ax, "act_embed"))
        for a in aux:
            aux_acc = aux_acc + a
        return (x, aux_acc), None

    mode = cfg.remat if remat else "none"
    if mode == "full":
        scan_body = jax.checkpoint(body)
    elif mode == "dots":
        # save matmul outputs, recompute the cheap elementwise chains —
        # trades HBM for ~half the remat recompute traffic.  NOTE: saves
        # the S^2 attention-score dots too; use "dots_nb" where that
        # breaks the HBM budget.
        scan_body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_saveable)
    elif mode == "dots_nb":
        # save only no-batch-dim dots (weight projections); the S^2
        # attention einsums (batched) are recomputed — the HBM-safe
        # middle ground between "full" and "dots"
        scan_body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    else:
        scan_body = body
    if cfg.n_groups > 0:
        if costmode.COST_MODE:
            for g in range(cfg.n_groups):
                gp = jax.tree.map(lambda a: a[g], params["layers"])
                (x, aux_total), _ = scan_body((x, aux_total), gp)
        elif (cfg.remat_chunks > 1 and mode != "none"
                and cfg.n_groups % cfg.remat_chunks == 0):
            # two-level (sqrt-N) remat: only `remat_chunks` outer
            # boundaries are stashed; inner boundaries are recomputed
            # inside each outer block's backward.  Cuts the per-device
            # boundary stash from n_groups*|x| to (outer+inner)*|x| at the
            # cost of one extra forward pass of the stack.
            inner = cfg.n_groups // cfg.remat_chunks
            lay2 = jax.tree.map(
                lambda a: a.reshape(cfg.remat_chunks, inner, *a.shape[1:]),
                params["layers"])

            def outer_body(carry, gp_outer):
                carry, _ = jax.lax.scan(scan_body, carry, gp_outer)
                return carry, None

            (x, aux_total), _ = jax.lax.scan(
                jax.checkpoint(outer_body), (x, aux_total), lay2)
        else:
            (x, aux_total), _ = jax.lax.scan(scan_body, (x, aux_total),
                                             params["layers"])
    aux: list = []
    for i, kind in enumerate(cfg.remainder):
        x = _apply_layer(kind, params["rem"][f"{i}:{kind}"], x, cfg, aux)
    for a in aux:
        aux_total = aux_total + a
    x = apply_norm(cfg.norm, x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = x @ head.astype(x.dtype)
    logits = maybe_constrain(logits, ("batch", "seq", "act_vocab"))
    return logits, aux_total / max(cfg.n_layers, 1)


# -- cache ----------------------------------------------------------------------

def _layer_cache(kind: str, cfg: ModelConfig, batch: int, cache_len: int):
    dt = _dtype(cfg)
    if kind in ("dense", "moe"):
        shape = (batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
        return (jnp.zeros(shape, dt), jnp.zeros(shape, dt))
    if kind == "attn":                      # hybrid local attention: ring
        L = min(cfg.window or cache_len, cache_len)
        shape = (batch, L, cfg.n_kv_heads, cfg.head_dim)
        return (jnp.zeros(shape, dt), jnp.zeros(shape, dt))
    if kind == "rec":
        return rglru_init_cache(cfg.d_model, batch, cfg.conv_kernel, dt)
    if kind == "ssm":
        return ssm_init_cache(cfg.d_model, cfg.ssm_state, batch,
                              cfg.ssm_headdim, cfg.ssm_expand,
                              cfg.conv_kernel, dt)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    unit = cfg.unit

    def group_cache():
        return {f"{i}:{kind}": _layer_cache(kind, cfg, batch, cache_len)
                for i, kind in enumerate(unit)}

    stacked = (_stack([group_cache() for _ in range(cfg.n_groups)])
               if cfg.n_groups else {})
    rem = {f"{i}:{kind}": _layer_cache(kind, cfg, batch, cache_len)
           for i, kind in enumerate(cfg.remainder)}
    return {"layers": stacked, "rem": rem}


def cache_specs(cfg: ModelConfig,
                mesh_axes: tuple[str, ...] = ("data", "model")):
    """PartitionSpecs mirroring init_cache: batch over (pod,data); attention
    cache sequence over 'model' (flash-decode style — XLA inserts the
    softmax reductions); ssm/rec states replicated over 'model'."""
    from .common import logical_to_spec as l2s

    def layer_spec(kind):
        if kind in ("dense", "moe", "attn"):
            s = l2s(("batch", "cache_seq", "kv", None), mesh_axes=mesh_axes)
            return (s, s)
        if kind == "rec":
            return {"conv": l2s(("batch", None, "heads"),
                                mesh_axes=mesh_axes),
                    "h": l2s(("batch", "heads"), mesh_axes=mesh_axes)}
        if kind == "ssm":
            # h: (B, H, P, N) — H (24) is not divisible by typical TP
            # degrees; the state is small, so replicate over 'model'.
            return {"conv": l2s(("batch", None, "heads"),
                                mesh_axes=mesh_axes),
                    "h": l2s(("batch", None, None, None),
                             mesh_axes=mesh_axes)}
        raise ValueError(kind)

    unit = cfg.unit
    grp = {f"{i}:{kind}": layer_spec(kind) for i, kind in enumerate(unit)}

    def add_layer_axis(tree):
        return jax.tree.map(lambda s: P(None, *s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    stacked = add_layer_axis(grp) if cfg.n_groups else {}
    rem = {f"{i}:{kind}": layer_spec(kind)
           for i, kind in enumerate(cfg.remainder)}
    return {"layers": stacked, "rem": rem}


# -- prefill ---------------------------------------------------------------------

def _apply_layer_prefill(kind: str, p, x, cfg: ModelConfig, cache_len: int):
    h = apply_norm(cfg.norm, x, p["norm1"])
    if kind in ("dense", "moe", "attn"):
        window = cfg.window if (kind == "attn" and cfg.family == "hybrid"
                                and cfg.window) else None
        clen = min(cfg.window or cache_len, cache_len) if kind == "attn" \
            else cache_len
        y, c = attn_prefill(p["attn"], h, clen, n_heads=cfg.n_heads,
                            n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                            rope_theta=cfg.rope_theta, window=window)
        x = x + y
        h2 = apply_norm(cfg.norm, x, p["norm2"])
        if kind == "moe":
            y2, _ = moe_forward(p["ffn"], h2, n_experts=cfg.n_experts,
                                top_k=cfg.top_k, activation=cfg.activation,
                                capacity_factor=cfg.moe_capacity,
                                impl=cfg.moe_impl,
                                seq_sharded=cfg.seq_shard_activations)
            x = x + y2
        else:
            x = x + mlp_forward(p["ffn"], h2, cfg.activation)
    elif kind == "rec":
        y, c = rglru_forward(p["rec"], h, return_state=True)
        x = x + y
        h2 = apply_norm(cfg.norm, x, p["norm2"])
        x = x + mlp_forward(p["ffn"], h2, cfg.activation)
    elif kind == "ssm":
        y, c = ssm_forward(p["ssm"], h, ssm_state=cfg.ssm_state,
                           headdim=cfg.ssm_headdim, expand=cfg.ssm_expand,
                           return_state=True)
        x = x + y
    else:
        raise ValueError(kind)
    return x, c


def prefill_forward(params, cfg: ModelConfig, tokens: jnp.ndarray,
                    cache_len: int | None = None,
                    img_embeds: jnp.ndarray | None = None):
    """Prefill: returns (last-token logits (B, 1, V), cache).

    The full (B, S, V) logit tensor is never materialized — at 32k seq and
    150k vocab it would dominate memory for no serving purpose.
    """
    B, S = tokens.shape
    cache_len = cache_len or S
    tokens = maybe_constrain(tokens, ("batch", "seq"))
    x = params["embed"][tokens].astype(_dtype(cfg))
    if img_embeds is not None and cfg.n_img_tokens:
        x = x.at[:, :cfg.n_img_tokens].set(img_embeds.astype(x.dtype))
    x = maybe_constrain(x, ("batch", "seq", "act_embed"))
    unit = cfg.unit
    seq_ax = "seq_sp" if cfg.seq_shard_activations else "seq"

    def body(x, gp):
        caches = {}
        for i, kind in enumerate(unit):
            key = f"{i}:{kind}"
            x, caches[key] = _apply_layer_prefill(kind, gp[key], x, cfg,
                                                  cache_len)
            x = maybe_constrain(x, ("batch", seq_ax, "act_embed"))
        return x, caches

    cache: dict[str, Any] = {"layers": {}, "rem": {}}
    if cfg.n_groups > 0:
        if costmode.COST_MODE:
            per_group = []
            for g in range(cfg.n_groups):
                gp = jax.tree.map(lambda a: a[g], params["layers"])
                x, cg = body(x, gp)
                per_group.append(cg)
            cache["layers"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *per_group)
        else:
            x, cache["layers"] = jax.lax.scan(body, x, params["layers"])
    for i, kind in enumerate(cfg.remainder):
        key = f"{i}:{kind}"
        x, cache["rem"][key] = _apply_layer_prefill(
            kind, params["rem"][key], x, cfg, cache_len)
    x = apply_norm(cfg.norm, x[:, -1:], params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = maybe_constrain(x @ head.astype(x.dtype),
                             ("batch", None, "act_vocab"))
    return logits, cache


# -- decode ----------------------------------------------------------------------

def _apply_layer_decode(kind: str, p, c, x, pos, cfg: ModelConfig):
    h = apply_norm(cfg.norm, x, p["norm1"])
    if kind in ("dense", "moe", "attn"):
        window = cfg.window if (kind == "attn" and cfg.family == "hybrid"
                                and cfg.window) else None
        y, c = attn_decode(p["attn"], h, c, pos, n_heads=cfg.n_heads,
                           n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                           rope_theta=cfg.rope_theta, window=window)
        x = x + y
        h2 = apply_norm(cfg.norm, x, p["norm2"])
        if kind == "moe":
            y2, _ = moe_forward(p["ffn"], h2, n_experts=cfg.n_experts,
                                top_k=cfg.top_k, activation=cfg.activation,
                                capacity_factor=2.0, impl=cfg.moe_impl)
            x = x + y2
        else:
            x = x + mlp_forward(p["ffn"], h2, cfg.activation)
    elif kind == "rec":
        y, c = rglru_decode(p["rec"], h, c)
        x = x + y
        h2 = apply_norm(cfg.norm, x, p["norm2"])
        x = x + mlp_forward(p["ffn"], h2, cfg.activation)
    elif kind == "ssm":
        y, c = ssm_decode(p["ssm"], h, c, ssm_state=cfg.ssm_state,
                          headdim=cfg.ssm_headdim, expand=cfg.ssm_expand)
        x = x + y
    return x, c


def decode_step(params, cfg: ModelConfig, cache, tokens: jnp.ndarray,
                pos: jnp.ndarray):
    """One token for the whole batch.  tokens: (B, 1); pos: scalar int32.
    Returns (logits (B, 1, V), new_cache)."""
    x = params["embed"][tokens].astype(_dtype(cfg))
    x = maybe_constrain(x, ("batch", None, "act_embed"))
    unit = cfg.unit

    def body(x, pc):
        gp, gc = pc
        new_c = {}
        for i, kind in enumerate(unit):
            key = f"{i}:{kind}"
            x, new_c[key] = _apply_layer_decode(kind, gp[key], gc[key], x,
                                                pos, cfg)
            x = maybe_constrain(x, ("batch", None, "act_embed"))
        return x, new_c

    new_cache: dict[str, Any] = {"layers": {}, "rem": {}}
    if cfg.n_groups > 0:
        if costmode.COST_MODE:
            per_group = []
            for g in range(cfg.n_groups):
                pc = jax.tree.map(lambda a: a[g],
                                  (params["layers"], cache["layers"]))
                x, cg = body(x, pc)
                per_group.append(cg)
            new_cache["layers"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *per_group)
        else:
            x, new_cache["layers"] = jax.lax.scan(
                body, x, (params["layers"], cache["layers"]))
    for i, kind in enumerate(cfg.remainder):
        key = f"{i}:{kind}"
        x, new_cache["rem"][key] = _apply_layer_decode(
            kind, params["rem"][key], cache["rem"][key], x, pos, cfg)
    x = apply_norm(cfg.norm, x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = maybe_constrain(x @ head.astype(x.dtype),
                             ("batch", None, "act_vocab"))
    return logits, new_cache
