"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Training path: the chunked SSD algorithm (intra-chunk 'attention-like' term
via the decay matrix L = exp(segsum(dA)), inter-chunk state recurrence).
Decode path: the O(1) recurrent update h = h * exp(dt*a) + dt * x B^T.

Shapes (ngroups = 1):
  d_inner = expand * d_model;  H = d_inner / headdim heads;  N = ssm_state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamCollector, rmsnorm


def init_ssm(col: ParamCollector, d_model: int, ssm_state: int,
             headdim: int = 64, expand: int = 2, conv_kernel: int = 4):
    d_in = expand * d_model
    H = d_in // headdim
    conv_dim = d_in + 2 * ssm_state
    p, s = {}, {}
    # separate projections (z, x, B, C, dt) so every output dim shards
    # cleanly: the fused 2*d_in + 2*N + H dim of the reference impl is not
    # divisible by typical TP degrees.
    p["w_z"], s["w_z"] = col.param((d_model, d_in), ("embed", "heads"))
    p["w_x"], s["w_x"] = col.param((d_model, d_in), ("embed", "heads"))
    p["w_B"], s["w_B"] = col.param((d_model, ssm_state), ("embed", None))
    p["w_C"], s["w_C"] = col.param((d_model, ssm_state), ("embed", None))
    p["w_dt"], s["w_dt"] = col.param((d_model, H), ("embed", None))
    p["conv_w"], s["conv_w"] = col.param((conv_kernel, conv_dim),
                                         ("conv", "heads"), scale=0.5)
    p["conv_b"], s["conv_b"] = col.param((conv_dim,), ("act_heads",),
                                         init="zeros")
    p["A_log"], s["A_log"] = col.param((H,), (None,), init="zeros")
    p["D"], s["D"] = col.param((H,), (None,), init="ones")
    p["dt_bias"], s["dt_bias"] = col.param((H,), (None,), init="zeros")
    p["norm_scale"], s["norm_scale"] = col.param((d_in,), ("act_heads",),
                                                 init="ones")
    p["out_proj"], s["out_proj"] = col.param((d_in, d_model),
                                             ("heads", "embed"))
    return p, s


def _segsum(x):
    """x: (..., T) -> (..., T, T) with out[i, j] = sum_{j < k <= i} x_k
    (lower-triangular incl. diagonal at 0; -inf above)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, d, -jnp.inf)


def _split_proj(p, x, d_in, N, H):
    return (x @ p["w_z"], x @ p["w_x"], x @ p["w_B"], x @ p["w_C"],
            x @ p["w_dt"])


def _causal_conv(xbc, w, b):
    """Depthwise causal conv1d.  xbc: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def ssm_forward(p, x, *, ssm_state: int, headdim: int = 64, expand: int = 2,
                chunk: int = 256, return_state: bool = False):
    """Training / prefill SSD.  x: (B, S, D) -> (B, S, D)
    (or (y, cache) when return_state)."""
    Bsz, S, D = x.shape
    d_in = expand * D
    N = ssm_state
    H = d_in // headdim
    P = headdim
    z, xs, Bm, Cm, dt = _split_proj(p, x, d_in, N, H)
    xbc_raw = jnp.concatenate([xs, Bm, Cm], axis=-1)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)

    a = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)

    c = min(chunk, S)
    while S % c:
        c -= 1
    nc = S // c
    xh = xs.reshape(Bsz, nc, c, H, P).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, c, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, c, N).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, c, H)
    dA = (dtc * a).transpose(0, 3, 1, 2)                      # (B,H,nc,c)
    xdt = xh * dtc[..., None]                                 # X * dt

    # intra-chunk
    L = jnp.exp(_segsum(dA))                                  # (B,H,nc,c,c)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xdt)

    # chunk states
    A_cum = jnp.cumsum(dA, axis=-1)                           # (B,H,nc,c)
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xdt)

    # inter-chunk recurrence
    A_last = A_cum[..., -1]                                   # (B,H,nc)
    pad = jnp.pad(A_last, ((0, 0), (0, 0), (1, 0)))
    decay_chunk = jnp.exp(_segsum(pad))                       # (B,H,nc+1,nc+1)
    init = jnp.zeros((Bsz, 1, H, P, N), states.dtype)
    st = jnp.concatenate([init, states], axis=1)              # (B,nc+1,...)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, st)
    prev = new_states[:, :-1]                                 # (B,nc,H,P,N)
    final_state = new_states[:, -1]                           # (B,H,P,N)

    out_decay = jnp.exp(A_cum)                                # (B,H,nc,c)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, prev, out_decay)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] \
        * xs.reshape(Bsz, S, H, P).astype(jnp.float32)
    y = y.reshape(Bsz, S, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    out = y @ p["out_proj"]
    if return_state:
        K = p["conv_w"].shape[0]
        conv_tail = xbc_raw[:, S - (K - 1):]   # pre-conv inputs, K-1 last
        return out, {"conv": conv_tail, "h": final_state}
    return out


def ssm_init_cache(cfg_d_model: int, ssm_state: int, batch: int,
                   headdim: int = 64, expand: int = 2, conv_kernel: int = 4,
                   dtype=jnp.float32):
    d_in = expand * cfg_d_model
    H = d_in // headdim
    conv_dim = d_in + 2 * ssm_state
    return {
        "conv": jnp.zeros((batch, conv_kernel - 1, conv_dim), dtype),
        "h": jnp.zeros((batch, H, headdim, ssm_state), jnp.float32),
    }


def ssm_decode(p, x, cache, *, ssm_state: int, headdim: int = 64,
               expand: int = 2):
    """One decode step.  x: (B, 1, D)."""
    Bsz, _, D = x.shape
    d_in = expand * D
    N = ssm_state
    H = d_in // headdim
    P = headdim
    z, xs, Bm, Cm, dt = _split_proj(p, x[:, 0], d_in, N, H)
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)              # (B, conv_dim)
    hist = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B,K,Cd)
    conv_out = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:]
    xs, Bm, Cm = jnp.split(conv_out, [d_in, d_in + N], axis=-1)

    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    dA = jnp.exp(dtv * a)                                     # (B,H)
    xh = xs.reshape(Bsz, H, P).astype(jnp.float32)
    h = cache["h"] * dA[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xh * dtv[..., None], Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(Bsz, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    return (y @ p["out_proj"])[:, None], {"conv": new_conv, "h": h}
