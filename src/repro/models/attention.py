"""GQA attention: training (chunked, causal / sliding-window), prefill and
decode-with-cache paths.  Pure functions; params from init_attention.

Memory note: full S x S score materialization at 32k+ would blow VMEM/HBM,
so the training/prefill path scans over query chunks (flash-style: only a
(qc, S) strip is ever live).  The Pallas flash kernel (kernels/flash.py) is
the TPU-native version of the same loop; the jnp path here is what the
dry-run lowers (Mosaic doesn't lower on the CPU host backend).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import costmode
from .common import ParamCollector, apply_rope, rope_table


def init_attention(col: ParamCollector, d_model: int, n_heads: int,
                   n_kv: int, head_dim: int, qkv_bias: bool = False):
    p, s = {}, {}
    p["wq"], s["wq"] = col.param((d_model, n_heads * head_dim),
                                 ("embed", "heads"))
    p["wk"], s["wk"] = col.param((d_model, n_kv * head_dim),
                                 ("embed", "kv"))
    p["wv"], s["wv"] = col.param((d_model, n_kv * head_dim),
                                 ("embed", "kv"))
    p["wo"], s["wo"] = col.param((n_heads * head_dim, d_model),
                                 ("heads", "embed"))
    if qkv_bias:
        p["bq"], s["bq"] = col.param((n_heads * head_dim,), ("act_heads",),
                                     init="zeros")
        p["bk"], s["bk"] = col.param((n_kv * head_dim,), (None,),
                                     init="zeros")
        p["bv"], s["bv"] = col.param((n_kv * head_dim,), (None,),
                                     init="zeros")
    return p, s


def _project_qkv(p, x, n_heads, n_kv, head_dim, rope_theta, pos_offset=0,
                 use_rope=True):
    B, S, D = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv, head_dim)
    v = v.reshape(B, S, n_kv, head_dim)
    if use_rope:
        cos, sin = rope_table(S, head_dim, rope_theta, offset=pos_offset)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _pick_chunk(S: int, target: int = 512) -> int:
    if costmode.COST_MODE:
        return S          # no lax.map: scan bodies are cost-counted once
    c = min(S, target)
    while S % c:
        c -= 1
    return c


def _causal_chunked_skip(qg, k, v, scale, nc: int):
    """Unrolled causal attention: chunk ci attends only to keys
    [0, (ci+1)*chunk) — ~47% of the full S^2 FLOPs/bytes at nc=16.
    Unrolled (not lax.map) so every chunk has a static prefix shape AND
    cost_analysis counts each chunk — the §Roofline numbers are faithful.
    qg: (B, Sq, Hkv, G, hd)."""
    B, Sq, Hkv, G, hd = qg.shape
    chunk = Sq // nc
    outs = []
    for ci in range(nc):
        qc = qg[:, ci * chunk:(ci + 1) * chunk]
        if outs:
            # serialize the (independent) chunks: without this barrier the
            # scheduler may keep every chunk's (c, prefix) f32 strip live
            # at once — measured 37-55 GiB temp on 32k prefill; serialized,
            # one strip is live at a time
            qc, _ = jax.lax.optimization_barrier((qc, outs[-1]))
        end = (ci + 1) * chunk
        kc, vc = k[:, :end], v[:, :end]
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                            preferred_element_type=jnp.float32) * scale
        qpos = ci * chunk + jnp.arange(chunk)
        mask = jnp.arange(end)[None, :] <= qpos[:, None]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1).astype(vc.dtype)
        outs.append(jnp.einsum("bhgqk,bkhd->bqhgd", p, vc,
                               preferred_element_type=jnp.float32))
    out = jnp.concatenate(outs, axis=1)
    return out.reshape(B, Sq, Hkv * G, hd).astype(qg.dtype)


def gqa_attend(q, k, v, *, causal: bool = True, window: int | None = None,
               q_offset: int = 0, chunk: int | None = None,
               causal_skip_min_seq: int = 1 << 30):
    """q: (B, Sq, Hq, hd); k, v: (B, Sk, Hkv, hd).  Hq % Hkv == 0.

    Scans over query chunks; each step computes a (qc, Sk) strip in f32.
    ``q_offset`` is the absolute position of q[0] (for decode/windows).

    ``causal_skip_min_seq``: opt-in threshold for the prefix-sliced
    unrolled path (_causal_chunked_skip) that skips the fully-masked upper
    triangle — a measured 35-40% cut of the 32k-prefill roofline bound,
    but OFF by default: the CPU backend assigns every unrolled chunk its
    own f32 strip buffer (no reuse, 37-55 GiB temp at 32k), so the
    fits-in-HBM evidence regresses.  On the TPU target the same
    upper-triangle skip is done properly inside the Pallas flash kernel
    (kernels/flash.py) with O(1) VMEM strips; see EXPERIMENTS.md §Perf.
    """
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = hd ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, hd)
    if (causal and window is None and q_offset == 0 and Sq == Sk
            and Sq >= causal_skip_min_seq and Sq % 16 == 0):
        nc = max(2, min(16, Sq // 2048))
        while Sq % nc:
            nc //= 2
        return _causal_chunked_skip(qg, k, v, scale, nc)
    chunk = chunk or _pick_chunk(Sq)
    nc = Sq // chunk
    qg = qg.reshape(B, nc, chunk, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)

    kpos = jnp.arange(Sk)

    def one_chunk(ci, qc):
        # qc: (B, chunk, Hkv, G, hd).  Operands stay bf16 (halves the bytes
        # XLA moves for SP gathers/reshards); accumulation is f32 via
        # preferred_element_type — exactly the MXU bf16-in/f32-acc contract.
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qc, k,
                            preferred_element_type=jnp.float32) * scale
        qpos = q_offset + ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, Sk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        return jnp.einsum("bhgqk,bkhd->bqhgd", p, v,
                          preferred_element_type=jnp.float32)

    if nc == 1:
        out = one_chunk(0, qg[0])[None]
    else:
        out = jax.lax.map(lambda args: one_chunk(args[0], args[1]),
                          (jnp.arange(nc), qg))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, hd)
    return out.astype(q.dtype)


def attn_forward(p, x, *, n_heads, n_kv, head_dim, rope_theta=10000.0,
                 causal=True, window=None, use_rope=True):
    """Training / encoding path."""
    B, S, D = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim, rope_theta,
                           use_rope=use_rope)
    out = gqa_attend(q, k, v, causal=causal, window=window)
    return out.reshape(B, S, n_heads * head_dim) @ p["wo"]


def attn_prefill(p, x, cache_len, *, n_heads, n_kv, head_dim,
                 rope_theta=10000.0, window=None, use_rope=True):
    """Prefill: forward + build the KV cache (padded to cache_len)."""
    B, S, D = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim, rope_theta,
                           use_rope=use_rope)
    out = gqa_attend(q, k, v, causal=True, window=window)
    y = out.reshape(B, S, n_heads * head_dim) @ p["wo"]
    if window is not None and cache_len <= S:
        # ring-buffer cache (hybrid local attention): keep the last
        # cache_len positions at slots pos % cache_len, matching attn_decode.
        L = cache_len
        tail_k, tail_v = k[:, S - L:], v[:, S - L:]
        slots = (jnp.arange(S - L, S) % L)
        kc = jnp.zeros((B, L, n_kv, head_dim), k.dtype).at[:, slots].set(
            tail_k)
        vc = jnp.zeros((B, L, n_kv, head_dim), v.dtype).at[:, slots].set(
            tail_v)
        return y, (kc, vc)
    kc = jnp.zeros((B, cache_len, n_kv, head_dim), k.dtype).at[:, :S].set(k)
    vc = jnp.zeros((B, cache_len, n_kv, head_dim), v.dtype).at[:, :S].set(v)
    return y, (kc, vc)


def attn_decode(p, x, cache, pos, *, n_heads, n_kv, head_dim,
                rope_theta=10000.0, window=None, use_rope=True):
    """One decode step.  x: (B, 1, D); cache: (k, v) each (B, L, Hkv, hd);
    pos: scalar int32 — current absolute position (same across batch)."""
    B, _, D = x.shape
    kc, vc = cache
    L = kc.shape[1]
    q = (x @ p["wq"])
    k = (x @ p["wk"])
    v = (x @ p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, 1, n_heads, head_dim)
    k = k.reshape(B, 1, n_kv, head_dim)
    v = v.reshape(B, 1, n_kv, head_dim)
    inv = 1.0 / (rope_theta ** (jnp.arange(0, head_dim, 2,
                                           dtype=jnp.float32) / head_dim))
    if use_rope:
        ang = pos.astype(jnp.float32) * inv
        cos, sin = jnp.cos(ang)[None, :], jnp.sin(ang)[None, :]
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if window is None:
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
        kpos = jnp.arange(L)
        valid = kpos <= pos
    else:
        slot = pos % L                     # ring buffer of size window
        kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
        kpos = jnp.arange(L)
        age = (pos - kpos) % L             # ring: 0 = current
        valid = (age < L) & ((kpos <= pos) | (pos >= L))
    G = n_heads // n_kv
    qg = q.reshape(B, n_kv, G, head_dim)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                        kc.astype(jnp.float32)) * head_dim ** -0.5
    logits = jnp.where(valid[None, None, None], logits, -1e30)
    pr = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", pr, vc.astype(jnp.float32))
    out = out.reshape(B, 1, n_heads * head_dim).astype(x.dtype)
    return out @ p["wo"], (kc, vc)
