"""Whisper-style encoder-decoder (arXiv:2212.04356), backbone only.

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, n_frames, d_model).  Encoder: non-causal
self-attention + GELU MLP, sinusoidal positions.  Decoder: causal
self-attention + cross-attention to the encoder output + GELU MLP, learned
positions.  LayerNorm throughout (pre-norm).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import costmode
from .attention import (attn_decode, attn_forward, gqa_attend,
                        init_attention)
from .common import ParamCollector, apply_norm, init_norm, maybe_constrain
from .config import ModelConfig


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _sinusoid(length: int, d: int):
    pos = np.arange(length)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * dim / d))
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=1),
                       jnp.float32)


def _init_cross(col, cfg):
    return init_attention(col, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim)


def init_model(rng, cfg: ModelConfig,
               mesh_axes: tuple[str, ...] = ("data", "model")):
    col = ParamCollector(rng, dtype=_dtype(cfg), mesh_axes=mesh_axes)
    p: dict[str, Any] = {}
    s: dict[str, Any] = {}
    p["embed"], s["embed"] = col.param((cfg.vocab_padded, cfg.d_model),
                                       ("vocab", "embed"), scale=0.02)
    p["pos_dec"], s["pos_dec"] = col.param((cfg.max_seq, cfg.d_model),
                                           (None, "embed"), scale=0.02)

    def enc_layer():
        lp, ls = {}, {}
        lp["norm1"], ls["norm1"] = init_norm(col, cfg.d_model, cfg.norm)
        lp["attn"], ls["attn"] = init_attention(
            col, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
        lp["norm2"], ls["norm2"] = init_norm(col, cfg.d_model, cfg.norm)
        from .mlp import init_mlp
        lp["ffn"], ls["ffn"] = init_mlp(col, cfg.d_model, cfg.d_ff,
                                        cfg.activation)
        return lp, ls

    def dec_layer():
        lp, ls = enc_layer()
        lp["norm_x"], ls["norm_x"] = init_norm(col, cfg.d_model, cfg.norm)
        lp["xattn"], ls["xattn"] = _init_cross(col, cfg)
        return lp, ls

    from .transformer import _stack, _stack_specs
    enc = [enc_layer() for _ in range(cfg.enc_layers)]
    dec = [dec_layer() for _ in range(cfg.n_layers)]
    p["enc"], s["enc"] = _stack([e[0] for e in enc]), _stack_specs(enc[0][1])
    p["dec"], s["dec"] = _stack([d[0] for d in dec]), _stack_specs(dec[0][1])
    p["norm_enc"], s["norm_enc"] = init_norm(col, cfg.d_model, cfg.norm)
    p["norm_dec"], s["norm_dec"] = init_norm(col, cfg.d_model, cfg.norm)
    return p, s


def _maybe_unrolled_scan(body, x, stacked, n):
    """lax.scan, or an unrolled loop under COST_MODE (ys discarded)."""
    if costmode.COST_MODE:
        for g in range(n):
            lp = jax.tree.map(lambda a: a[g], stacked)
            x, _ = body(x, lp)
        return x
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def _attn_args(cfg):
    return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
                use_rope=False)   # Whisper: learned/sinusoidal positions


def _cross_attend(p, x, enc_out, cfg):
    """Cross-attention: q from decoder, k/v from encoder output."""
    B, S, D = x.shape
    Se = enc_out.shape[1]
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = (enc_out @ p["wk"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
    v = (enc_out @ p["wv"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
    out = gqa_attend(q, k, v, causal=False)
    return out.reshape(B, S, -1) @ p["wo"]


def encode(params, cfg: ModelConfig, frames: jnp.ndarray):
    """frames: (B, T, D) stub embeddings -> encoder states."""
    x = frames.astype(_dtype(cfg)) + _sinusoid(
        frames.shape[1], cfg.d_model).astype(_dtype(cfg))
    x = maybe_constrain(x, ("batch", "seq", "act_embed"))
    from .mlp import mlp_forward

    def body(x, lp):
        h = apply_norm(cfg.norm, x, lp["norm1"])
        x = x + attn_forward(lp["attn"], h, causal=False, **_attn_args(cfg))
        h = apply_norm(cfg.norm, x, lp["norm2"])
        x = x + mlp_forward(lp["ffn"], h, cfg.activation)
        return x, None

    x = _maybe_unrolled_scan(jax.checkpoint(body), x, params["enc"],
                             cfg.enc_layers)
    return apply_norm(cfg.norm, x, params["norm_enc"])


def forward(params, cfg: ModelConfig, frames: jnp.ndarray,
            tokens: jnp.ndarray):
    """Training path.  Returns (logits, aux=0)."""
    enc_out = encode(params, cfg, frames)
    B, S = tokens.shape
    x = params["embed"][tokens].astype(_dtype(cfg)) \
        + params["pos_dec"][:S].astype(_dtype(cfg))
    x = maybe_constrain(x, ("batch", "seq", "act_embed"))
    from .mlp import mlp_forward

    def body(x, lp):
        h = apply_norm(cfg.norm, x, lp["norm1"])
        x = x + attn_forward(lp["attn"], h, causal=True, **_attn_args(cfg))
        h = apply_norm(cfg.norm, x, lp["norm_x"])
        x = x + _cross_attend(lp["xattn"], h, enc_out, cfg)
        h = apply_norm(cfg.norm, x, lp["norm2"])
        x = x + mlp_forward(lp["ffn"], h, cfg.activation)
        x = maybe_constrain(x, ("batch", "seq", "act_embed"))
        return x, None

    x = _maybe_unrolled_scan(jax.checkpoint(body), x, params["dec"],
                             cfg.n_layers)
    x = apply_norm(cfg.norm, x, params["norm_dec"])
    logits = x @ params["embed"].T.astype(x.dtype)
    logits = maybe_constrain(logits, ("batch", "seq", "act_vocab"))
    return logits, jnp.zeros((), jnp.float32)


def init_cache(params, cfg: ModelConfig, frames: jnp.ndarray,
               cache_len: int):
    """Precompute encoder output + cross k/v; empty self cache."""
    enc_out = encode(params, cfg, frames)
    B = frames.shape[0]
    Se = frames.shape[1]

    def per_layer(lp):
        k = (enc_out @ lp["xattn"]["wk"]).reshape(B, Se, cfg.n_kv_heads,
                                                  cfg.head_dim)
        v = (enc_out @ lp["xattn"]["wv"]).reshape(B, Se, cfg.n_kv_heads,
                                                  cfg.head_dim)
        return k, v

    cross = jax.vmap(per_layer)(params["dec"])  # stacked over layers? no —
    # params["dec"] is already layer-stacked; vmap maps over that axis.
    dt = _dtype(cfg)
    shape = (cfg.n_layers, B, cache_len, cfg.n_kv_heads, cfg.head_dim)
    return {"cross_k": cross[0], "cross_v": cross[1],
            "self_k": jnp.zeros(shape, dt), "self_v": jnp.zeros(shape, dt)}


def cache_shape(cfg: ModelConfig, batch: int, cache_len: int):
    """ShapeDtypeStructs for the decode cache (dry-run, no allocation)."""
    dt = _dtype(cfg)
    self_s = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
    cross_s = (cfg.n_layers, batch, cfg.n_frames, cfg.n_kv_heads,
               cfg.head_dim)
    sds = jax.ShapeDtypeStruct
    return {"self_k": sds(self_s, dt), "self_v": sds(self_s, dt),
            "cross_k": sds(cross_s, dt), "cross_v": sds(cross_s, dt)}


def cache_specs(cfg: ModelConfig,
                mesh_axes: tuple[str, ...] = ("data", "model")):
    from .common import logical_to_spec as l2s
    # self cache sequence shards over 'model'; cross cache seq (n_frames,
    # typically 1500) is not mesh-divisible -> replicated.
    self_s = l2s((None, "batch", "cache_seq", None, None),
                 mesh_axes=mesh_axes)
    cross_s = l2s((None, "batch", None, None, None), mesh_axes=mesh_axes)
    return {"self_k": self_s, "self_v": self_s,
            "cross_k": cross_s, "cross_v": cross_s}


def prefill_forward(params, cfg: ModelConfig, frames: jnp.ndarray,
                    tokens: jnp.ndarray, cache_len: int | None = None):
    """Encode + decoder prefill.  Returns (last logits (B,1,V), cache)."""
    enc_out = encode(params, cfg, frames)
    B, S = tokens.shape
    cache_len = cache_len or S
    x = params["embed"][tokens].astype(_dtype(cfg)) \
        + params["pos_dec"][:S].astype(_dtype(cfg))
    from .attention import attn_prefill
    from .mlp import mlp_forward
    Se = frames.shape[1]

    def body(x, lp):
        h = apply_norm(cfg.norm, x, lp["norm1"])
        y, (ck, cv) = attn_prefill(lp["attn"], h, cache_len,
                                   **_attn_args(cfg))
        x = x + y
        h = apply_norm(cfg.norm, x, lp["norm_x"])
        x = x + _cross_attend(lp["xattn"], h, enc_out, cfg)
        h = apply_norm(cfg.norm, x, lp["norm2"])
        x = x + mlp_forward(lp["ffn"], h, cfg.activation)
        xk = (enc_out @ lp["xattn"]["wk"]).reshape(B, Se, cfg.n_kv_heads,
                                                   cfg.head_dim)
        xv = (enc_out @ lp["xattn"]["wv"]).reshape(B, Se, cfg.n_kv_heads,
                                                   cfg.head_dim)
        return x, (ck, cv, xk, xv)

    if costmode.COST_MODE:
        outs = []
        for g in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[g], params["dec"])
            x, o = body(x, lp)
            outs.append(o)
        ck, cv, xk, xv = (jnp.stack([o[i] for o in outs])
                          for i in range(4))
    else:
        x, (ck, cv, xk, xv) = jax.lax.scan(body, x, params["dec"])
    x = apply_norm(cfg.norm, x[:, -1:], params["norm_dec"])
    logits = x @ params["embed"].T.astype(x.dtype)
    return logits, {"self_k": ck, "self_v": cv,
                    "cross_k": xk, "cross_v": xv}


def decode_step(params, cfg: ModelConfig, cache, tokens: jnp.ndarray,
                pos: jnp.ndarray):
    """tokens: (B, 1).  Returns (logits, new_cache)."""
    B = tokens.shape[0]
    x = params["embed"][tokens].astype(_dtype(cfg)) \
        + jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos, 1
                                       ).astype(_dtype(cfg))
    from .mlp import mlp_forward

    def body(x, xs):
        lp, ck, cv, xk, xv = xs
        h = apply_norm(cfg.norm, x, lp["norm1"])
        y, (ck, cv) = attn_decode(lp["attn"], h, (ck, cv), pos,
                                  **_attn_args(cfg))
        x = x + y
        h = apply_norm(cfg.norm, x, lp["norm_x"])
        # cross attention against precomputed cross k/v
        q = (h @ lp["xattn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        out = gqa_attend(q, xk, xv, causal=False)
        x = x + out.reshape(B, 1, -1) @ lp["xattn"]["wo"]
        h = apply_norm(cfg.norm, x, lp["norm2"])
        x = x + mlp_forward(lp["ffn"], h, cfg.activation)
        return x, (ck, cv)

    if costmode.COST_MODE:
        outs = []
        for g in range(cfg.n_layers):
            xs = jax.tree.map(lambda a: a[g],
                              (params["dec"], cache["self_k"],
                               cache["self_v"], cache["cross_k"],
                               cache["cross_v"]))
            x, o = body(x, xs)
            outs.append(o)
        new_k = jnp.stack([o[0] for o in outs])
        new_v = jnp.stack([o[1] for o in outs])
    else:
        x, (new_k, new_v) = jax.lax.scan(
            body, x, (params["dec"], cache["self_k"], cache["self_v"],
                      cache["cross_k"], cache["cross_v"]))
    cache = dict(cache, self_k=new_k, self_v=new_v)
    x = apply_norm(cfg.norm, x, params["norm_dec"])
    return x @ params["embed"].T.astype(x.dtype), cache
