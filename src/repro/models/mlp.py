"""Dense MLP (SwiGLU / GELU) and token-choice top-k MoE with capacity-based
scatter dispatch (GShard-style) — expert axis sharded on 'model' (EP).

The MoE layer is also where the paper's LDHT technique hooks into the LM
stack: ``expert_placement.py`` computes a device assignment for experts from
their co-activation graph under heterogeneous HBM caps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from ..compat import P, get_ambient_mesh, shard_map
from .common import ParamCollector, maybe_constrain


def init_mlp(col: ParamCollector, d_model: int, d_ff: int,
             activation: str = "swiglu"):
    p, s = {}, {}
    p["w1"], s["w1"] = col.param((d_model, d_ff), ("embed", "mlp"))
    p["w2"], s["w2"] = col.param((d_ff, d_model), ("mlp", "embed"))
    if activation == "swiglu":
        p["w3"], s["w3"] = col.param((d_model, d_ff), ("embed", "mlp"))
    return p, s


def mlp_forward(p, x, activation: str = "swiglu"):
    if activation == "swiglu":
        return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]
    return jax.nn.gelu(x @ p["w1"]) @ p["w2"]


# -- MoE ----------------------------------------------------------------------

def init_moe(col: ParamCollector, d_model: int, n_experts: int, d_expert: int,
             activation: str = "swiglu"):
    p, s = {}, {}
    p["router"], s["router"] = col.param((d_model, n_experts),
                                         ("embed", None))
    p["w1"], s["w1"] = col.param((n_experts, d_model, d_expert),
                                 ("experts", "embed", "expert_mlp"))
    p["w2"], s["w2"] = col.param((n_experts, d_expert, d_model),
                                 ("experts", "expert_mlp", "embed"))
    if activation == "swiglu":
        p["w3"], s["w3"] = col.param((n_experts, d_model, d_expert),
                                     ("experts", "embed", "expert_mlp"))
    return p, s


def moe_forward(p, x, *, n_experts: int, top_k: int,
                activation: str = "swiglu", capacity_factor: float = 1.25,
                expert_perm: jnp.ndarray | None = None,
                impl: str = "auto", seq_sharded: bool = False):
    """Token-choice top-k MoE.  x: (B, S, D) -> (y, aux_loss).

    impl:
      - "dense":     XLA-SPMD GShard scatter dispatch (paper-faithful
                     baseline).  The partitioner replicates the (B, S*K, D)
                     dispatch intermediates across the mesh — measured
                     collective-bound by the dry-run (§Perf baseline).
      - "shard_map": expert-parallel dispatch hand-sharded over the 'model'
                     axis; dispatch/combine stay device-local and the only
                     collective is one activation-size psum (§Perf optimized).
      - "auto":      shard_map when a mesh with a >1 'model' axis is ambient,
                     dense otherwise (single-device tests).
    """
    if expert_perm is None:
        # LDHT placement travels inside the param tree (set by
        # core.expert_placement.permute_expert_params) so every caller —
        # train_step, prefill, decode — applies it without plumbing.
        expert_perm = p.get("perm")
    if impl == "auto":
        impl = "shard_map" if _ambient_moe_mesh() is not None else "dense"
    if impl == "shard_map":
        mesh = _ambient_moe_mesh()
        if mesh is not None:
            return _moe_forward_shard_map(
                p, x, mesh, n_experts=n_experts, top_k=top_k,
                activation=activation, capacity_factor=capacity_factor,
                expert_perm=expert_perm, seq_sharded=seq_sharded)
    return _moe_forward_dense(p, x, n_experts=n_experts, top_k=top_k,
                              activation=activation,
                              capacity_factor=capacity_factor,
                              expert_perm=expert_perm)


def _ambient_moe_mesh():
    """The ambient mesh (via compat.get_ambient_mesh) iff it can host
    expert parallelism."""
    mesh = get_ambient_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return None
    return mesh


def _moe_forward_dense(p, x, *, n_experts: int, top_k: int,
                       activation: str = "swiglu",
                       capacity_factor: float = 1.25,
                       expert_perm: jnp.ndarray | None = None):
    """x: (B, S, D) -> (y, aux_loss).

    GShard-style *grouped* capacity dispatch: each batch row is a dispatch
    group with capacity C = ceil(S * top_k / E * cf) per expert, so every
    dispatch/combine tensor keeps a leading B axis — sharded over 'data' —
    while the expert axis shards over 'model' (EP).  Without grouping the
    (E, C_global, D) slots replicate across the data axis and per-device
    MoE compute blows up by the DP degree.

    Overflow tokens (> C per expert within a row) lose that expert's
    contribution (standard GShard semantics).  ``expert_perm`` (E,)
    optionally reorders experts to devices — the LDHT expert-placement hook.
    """
    B, S, D = x.shape
    E, K = n_experts, top_k
    logits = (x @ p["router"]).astype(jnp.float32)            # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, exp_ids = jax.lax.top_k(probs, K)              # (B, S, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    aux_ids = exp_ids                 # aux stats in *original* expert ids
    if expert_perm is not None:
        exp_ids = expert_perm[exp_ids]

    C = int(-(-S * K // E) * capacity_factor)
    C = max(4, -(-C // 4) * 4)

    # slot assignment within each row: position in the expert's queue
    flat_e = exp_ids.reshape(B, S * K)                        # (B, S*K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # (B, S*K, E)
    pos = jnp.cumsum(onehot, axis=1) - 1
    slot = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = slot < C                                           # (B, S*K)
    gate_flat = gate_vals.reshape(B, S * K) * keep
    slot = jnp.where(keep, slot, 0)

    # dispatch: (B, E, C, D) via per-row scatter-add
    tok_ids = jnp.repeat(jnp.arange(S), K)                    # (S*K,)
    xk = x[:, tok_ids]                                        # (B, S*K, D)
    brow = jnp.arange(B)[:, None]
    disp = jnp.zeros((B, E, C, D), x.dtype)
    disp = disp.at[brow, flat_e, slot].add(
        jnp.where(keep[..., None], xk, 0))
    disp = maybe_constrain(disp, ("batch", "experts", None, None))

    # expert compute: (B, E, C, D) x (E, D, F); B over data, E over model
    h = jnp.einsum("becd,edf->becf", disp, p["w1"])
    if activation == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("becd,edf->becf", disp, p["w3"])
    else:
        h = jax.nn.gelu(h)
    eout = jnp.einsum("becf,efd->becd", h, p["w2"])           # (B, E, C, D)
    eout = maybe_constrain(eout, ("batch", "experts", None, None))

    # combine: gather each kept (token, k) contribution back to its row
    contrib = eout[brow, flat_e, slot]                        # (B, S*K, D)
    contrib = contrib * gate_flat[..., None].astype(eout.dtype)
    y = jnp.zeros((B, S, D), eout.dtype).at[:, tok_ids].add(contrib)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    f = jnp.mean(jax.nn.one_hot(aux_ids[..., 0], E, dtype=jnp.float32),
                 axis=(0, 1))
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f * pmean)
    return y.astype(x.dtype), aux


# -- expert-parallel shard_map MoE (§Perf optimized path) ----------------------

def _moe_forward_shard_map(p, x, mesh, *, n_experts: int, top_k: int,
                           activation: str, capacity_factor: float,
                           expert_perm: jnp.ndarray | None,
                           seq_sharded: bool = False):
    """Hand-sharded EP dispatch.

    Device grid: batch over ('pod','data') [whatever subset the ambient rules
    map 'batch' to], experts over 'model'.  Per device:

      1. route locally (router weight replicated),
      2. build a slot->token *index map* (B, E_loc, C) for only the experts
         this device owns — integer scatter, O(B*S*K) work,
      3. dispatch = gather x rows through the map (no K-times-activation
         (B, S*K, D) tensor is ever materialized),
      4. expert einsums on (B, E_loc, C, D),
      5. combine = scatter-add back to (B, S, D) weighted by gates,
      6. one psum over 'model' — the layer's only collective.

    This removes the all-gather/all-reduce storm the XLA partitioner emits
    for the scatter-based dense path (measured: >400 GB of collectives per
    layer-group for olmoe train_4k; see EXPERIMENTS.md §Perf).
    """
    from .common import logical_to_spec as l2s

    mesh_axes = tuple(mesh.axis_names)
    x_spec = l2s(("batch", "seq", "act_embed"), mesh_axes=mesh_axes)
    batch_axes = x_spec[0]                      # mesh axes 'batch' maps to
    if batch_axes is None:
        batch_axes = ()
    elif isinstance(batch_axes, str):
        batch_axes = (batch_axes,)
    ep_size = mesh.shape["model"]
    E, K = n_experts, top_k
    if E % ep_size != 0:
        return _moe_forward_dense(p, x, n_experts=n_experts, top_k=top_k,
                                  activation=activation,
                                  capacity_factor=capacity_factor,
                                  expert_perm=expert_perm)
    E_loc = E // ep_size

    p_specs = {
        "router": P(None, None),
        "w1": P("model", None, None),
        "w2": P("model", None, None),
    }
    if "w3" in p:
        p_specs["w3"] = P("model", None, None)
    if "perm" in p:
        p_specs["perm"] = P(None)         # replicated routing permutation
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]

    def body(pl, xl):
        B, S, D = xl.shape                      # B is already local
        j = jax.lax.axis_index("model")
        logits = (xl @ pl["router"]).astype(jnp.float32)      # (B, S, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, exp_ids = jax.lax.top_k(probs, K)          # (B, S, K)
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
        aux_ids = exp_ids             # aux stats in *original* expert ids
        if expert_perm is not None:
            exp_ids = expert_perm[exp_ids]

        C = int(-(-S * K // E) * capacity_factor)
        C = max(4, -(-C // 4) * 4)

        # slot of each (token, k) in its expert's queue, via stable sort —
        # O(T log T) on (B, T) int32 instead of the (B, T, E) one-hot
        # cumsum (E x more memory traffic).  Routing math is replicated and
        # identical on every model-rank.
        T = S * K
        flat_e = exp_ids.reshape(B, T)
        sort_idx = jnp.argsort(flat_e, axis=1, stable=True)       # (B, T)
        se = jnp.take_along_axis(flat_e, sort_idx, axis=1)
        pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
        is_start = jnp.concatenate(
            [jnp.ones((B, 1), bool), se[:, 1:] != se[:, :-1]], axis=1)
        run_start = jax.lax.cummax(jnp.where(is_start, pos, 0), axis=1)
        slot_sorted = pos - run_start
        slot = jnp.zeros_like(flat_e).at[
            jnp.arange(B)[:, None], sort_idx].set(slot_sorted)
        keep = slot < C
        gate_flat = (gate_vals.reshape(B, S * K)
                     * keep).astype(xl.dtype)
        slot = jnp.where(keep, slot, 0)

        # my experts only; non-mine entries are routed out of bounds and
        # dropped by the scatter (a 'mine' write must never collide with a
        # masked one — scatter-set order is unspecified)
        loc_e = flat_e - j * E_loc
        mine = keep & (loc_e >= 0) & (loc_e < E_loc)
        loc_e = jnp.where(mine, loc_e, E_loc)                 # E_loc = OOB
        slot_m = jnp.where(mine, slot, 0)

        # slot->token index map + per-slot gate, via int/f scatter
        tok_ids = jnp.repeat(jnp.arange(S), K)                # (S*K,)
        brow = jnp.arange(B)[:, None]
        slot_tok = jnp.zeros((B, E_loc, C), jnp.int32)
        slot_tok = slot_tok.at[brow, loc_e, slot_m].set(
            jnp.broadcast_to(tok_ids[None], (B, S * K)), mode="drop")
        valid = jnp.zeros((B, E_loc, C), xl.dtype)
        valid = valid.at[brow, loc_e, slot_m].set(
            jnp.ones((B, S * K), xl.dtype), mode="drop")
        gate_slot = jnp.zeros((B, E_loc, C), xl.dtype)
        gate_slot = gate_slot.at[brow, loc_e, slot_m].set(
            gate_flat, mode="drop")

        # dispatch: gather rows of x -> (B, E_loc, C, D)
        disp = xl[brow[:, :, None], slot_tok] * valid[..., None]

        h = jnp.einsum("becd,edf->becf", disp, pl["w1"])
        if activation == "swiglu":
            h = jax.nn.silu(h) * jnp.einsum("becd,edf->becf", disp,
                                            pl["w3"])
        else:
            h = jax.nn.gelu(h)
        eout = jnp.einsum("becf,efd->becd", h, pl["w2"])

        # combine: scatter-add back to tokens, gate-weighted.  When the
        # residual stream is sequence-parallel (seq_sp), reduce-scatter the
        # combine directly into the S-sharded layout — half the bytes of a
        # full psum and no re-scatter afterwards.
        y = jnp.zeros((B, S, D), eout.dtype)
        y = y.at[brow[:, :, None], slot_tok].add(
            eout * (valid * gate_slot)[..., None], mode="drop")
        if seq_scatter:
            y = jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                     tiled=True)
        else:
            y = jax.lax.psum(y, "model")

        # aux loss from *global* token statistics: psum local sums
        f_loc = jnp.sum(jax.nn.one_hot(aux_ids[..., 0], E,
                                       dtype=jnp.float32), axis=(0, 1))
        pm_loc = jnp.sum(probs, axis=(0, 1))
        if batch_axes:
            f_loc = jax.lax.psum(f_loc, batch_axes)
            pm_loc = jax.lax.psum(pm_loc, batch_axes)
        T = B * S * n_batch_shards
        aux = E * jnp.sum((f_loc / T) * (pm_loc / T))
        return y.astype(xl.dtype), aux

    # reduce-scatter the combine only when the caller's residual stream is
    # itself sequence-sharded — otherwise the RS is immediately re-gathered
    # (measured as an extra AG per layer; §Perf olmoe iteration log)
    S_glob = x.shape[1]
    seq_scatter = seq_sharded and S_glob % ep_size == 0 and S_glob > 1
    y_spec = (P(x_spec[0], "model", x_spec[2]) if seq_scatter else x_spec)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(p_specs, x_spec),
        out_specs=(y_spec, P()),
        check_rep=False)
    pp = {k: p[k] for k in p_specs}
    return fn(pp, x)
