"""Recursive Coordinate Bisection with heterogeneous target weights
(zRCB analogue, Sec. III-a).

Each recursion step splits the current vertex set orthogonally to its longest
extent, at the point where the left part receives ``sum(tw_left)`` vertices.
The block set is split to keep the two weight sums as close as possible to
the geometric split (classic RCB uses halves; we use the heterogeneous target
weights from Algorithm 1).
"""
from __future__ import annotations

import numpy as np

from ..sparse.graph import Graph


def partition_rcb(g: Graph, tw: np.ndarray, seed: int = 0) -> np.ndarray:
    assert g.coords is not None, "RCB needs coordinates"
    tw = np.asarray(tw, dtype=np.float64)
    part = np.zeros(g.n, dtype=np.int32)
    _rcb(g.coords, np.arange(g.n), np.arange(len(tw)), tw, part)
    return part


def _split_blocks(block_ids: np.ndarray, tw: np.ndarray):
    """Split blocks into two groups with near-equal total target weight.

    Greedy: sort by weight desc, assign each block to the lighter group.
    Returns (left_ids, right_ids, left_weight_fraction).
    """
    if len(block_ids) == 1:
        raise ValueError("cannot split a single block")
    order = np.argsort(-tw[block_ids], kind="stable")
    left, right = [], []
    wl = wr = 0.0
    for b in block_ids[order]:
        if wl <= wr:
            left.append(b)
            wl += tw[b]
        else:
            right.append(b)
            wr += tw[b]
    frac = wl / (wl + wr)
    return np.array(left), np.array(right), frac


def _rcb(coords: np.ndarray, ids: np.ndarray, block_ids: np.ndarray,
         tw: np.ndarray, part: np.ndarray) -> None:
    if len(block_ids) == 1:
        part[ids] = block_ids[0]
        return
    left_b, right_b, frac = _split_blocks(block_ids, tw)
    pts = coords[ids]
    extent = pts.max(axis=0) - pts.min(axis=0)
    axis = int(np.argmax(extent))
    order = np.argsort(pts[:, axis], kind="stable")
    n_left = int(round(frac * len(ids)))
    # both sides hold blocks, so neither may receive an empty vertex set:
    # an extreme weight skew (frac ~ 0 or ~ 1) used to round to 0 or
    # len(ids) and emit empty blocks downstream
    lo = 1 if len(ids) >= 2 else 0
    n_left = min(max(n_left, lo), len(ids) - lo)
    _rcb(coords, ids[order[:n_left]], left_b, tw, part)
    _rcb(coords, ids[order[n_left:]], right_b, tw, part)
