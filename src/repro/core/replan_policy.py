"""Drift policy for incremental replanning.

Delta patching (:mod:`repro.sparse.replan`) keeps the *partition* frozen
while the graph mutates, so plan quality decays over time: edges
accumulate across block boundaries (the cost-model objective grows) and
blocks drift apart in work (imbalance grows).  The
:class:`DriftMonitor` watches both against the last full partition's
baseline and decides, after every delta, whether the stream has drifted
far enough that a full repartition (plus solver-state migration,
:func:`repro.sparse.replan.migrate_state`) beats continuing to patch.

NumPy-only — usable without JAX, same as the partitioner layer.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .costmodel import CostModel, cost_model_for


@dataclasses.dataclass(frozen=True)
class DriftPolicy:
    """Thresholds for triggering a full repartition.

    ``objective``           — PR 9 cost model the drift is priced with
                              ("cut" | "bottleneck" | a CostModel);
    ``lams`` / ``c_comp``   — forwarded to :func:`cost_model_for`;
    ``max_objective_ratio`` — repartition when the modeled objective
                              exceeds baseline * ratio;
    ``max_imbalance_ratio`` — repartition when work imbalance (max/mean
                              of per-PU rows + nnz) exceeds baseline
                              imbalance * ratio;
    ``max_deltas``          — unconditional repartition after this many
                              observed deltas (None: never by count).
    """
    objective: object = "cut"
    lams: object = None
    c_comp: float = 1.0
    max_objective_ratio: float = 1.5
    max_imbalance_ratio: float = 1.25
    max_deltas: int | None = None

    def model(self) -> CostModel:
        return cost_model_for(self.objective, lams=self.lams,
                              c_comp=self.c_comp)


@dataclasses.dataclass(frozen=True)
class DriftDecision:
    """One :meth:`DriftMonitor.observe` verdict."""
    repartition: bool
    reason: str | None
    objective: float
    objective_ratio: float
    imbalance: float
    imbalance_ratio: float
    deltas_since_full: int


def _work_imbalance(g, part: np.ndarray, k: int) -> float:
    """max/mean of per-PU work, modeled as rows + nnz (vertex count plus
    degree sum) — the quantity a frozen partition lets drift."""
    part = np.asarray(part)
    work = (np.bincount(part, minlength=k).astype(np.float64)
            + np.bincount(part, weights=g.degrees.astype(np.float64),
                          minlength=k))
    mean = work.mean()
    return float(work.max() / mean) if mean > 0 else 1.0


class DriftMonitor:
    """Tracks plan-quality drift of a patched plan vs its last full plan.

    ``reset(g, part, anc)`` records the baseline right after a full
    (re)partition; ``observe(g, part, anc)`` prices the mutated graph on
    the *same* partition and returns a :class:`DriftDecision`.  The
    caller owns acting on it — :class:`repro.launch.serve.SolverService`
    rebuilds the operator and migrates solver state when
    ``decision.repartition`` is True, then calls ``reset`` again.
    """

    def __init__(self, policy: DriftPolicy | None = None):
        self.policy = policy or DriftPolicy()
        self._model = self.policy.model()
        self._base_objective: float | None = None
        self._base_imbalance: float | None = None
        self.deltas_since_full = 0

    @property
    def baseline(self) -> tuple[float, float] | None:
        if self._base_objective is None:
            return None
        return self._base_objective, self._base_imbalance

    def _measure(self, g, part, anc) -> tuple[float, float]:
        part = np.asarray(part)
        anc = np.atleast_2d(np.asarray(anc)) if anc is not None \
            else np.zeros((0, int(part.max()) + 1), dtype=np.int64)
        k = anc.shape[1] if anc.size else int(part.max()) + 1
        return (float(self._model.price(g, part, anc)),
                _work_imbalance(g, part, k))

    def reset(self, g, part, anc=None) -> None:
        """Record the post-repartition baseline."""
        self._base_objective, self._base_imbalance = \
            self._measure(g, part, anc)
        self.deltas_since_full = 0

    def observe(self, g, part, anc=None) -> DriftDecision:
        """Price one post-delta state; trips when a threshold is crossed.

        Must be preceded by :meth:`reset`; observing without a baseline
        raises rather than silently treating the first delta as one.
        """
        if self._base_objective is None:
            raise RuntimeError("DriftMonitor.observe before reset()")
        obj, imb = self._measure(g, part, anc)
        self.deltas_since_full += 1
        if self._base_objective > 0:
            obj_ratio = obj / self._base_objective
        else:
            obj_ratio = float("inf") if obj > 0 else 1.0
        imb_ratio = imb / self._base_imbalance \
            if self._base_imbalance > 0 else 1.0
        pol = self.policy
        reason = None
        if obj_ratio > pol.max_objective_ratio:
            reason = (f"objective {obj:.6g} > {pol.max_objective_ratio:g}x "
                      f"baseline {self._base_objective:.6g}")
        elif imb_ratio > pol.max_imbalance_ratio:
            reason = (f"imbalance {imb:.4g} > {pol.max_imbalance_ratio:g}x "
                      f"baseline {self._base_imbalance:.4g}")
        elif pol.max_deltas is not None \
                and self.deltas_since_full >= pol.max_deltas:
            reason = f"{self.deltas_since_full} deltas since full plan"
        return DriftDecision(
            repartition=reason is not None, reason=reason,
            objective=obj, objective_ratio=float(obj_ratio),
            imbalance=imb, imbalance_ratio=float(imb_ratio),
            deltas_since_full=self.deltas_since_full)
