"""LDHT expert placement — the paper's technique applied to MoE serving.

Experts are the 'graph', expert-parallel device ranks are the heterogeneous
PUs.  The mapping of the paper's LDHT objectives (Sec. II-B):

  Eq. (2)  minimize max_j load(b_j) / c_s(p_j)   — hot experts must not pile
           onto one (or a slow) device; load(e) = expected fraction of
           routed tokens hitting expert e (from router statistics).
  Eq. (3)  |b_j| == E_loc                        — the memory constraint is
           *exact* here: XLA SPMD shards the (E, D, F) expert tensors
           equally, so every rank hosts exactly E/ep_size expert slots.
  Eq. (1)  minimize co-activation cut            — secondary: experts that
           fire together for the same token are co-located, shrinking the
           per-token dispatch fan-out across ranks.

Because the count constraint is exact and E is small (32-64), stage 2 is an
LPT-style greedy under Algorithm-1 budgets plus pairwise-swap refinement
(the FM analogue on the expert quotient graph) instead of the full mesh
partitioners used for meshes.

Outputs a permutation ``perm`` with perm[old_expert] = new_slot such that
new slots [j*E_loc, (j+1)*E_loc) live on rank j.  Apply ``perm`` to the
router output (``moe_forward(..., expert_perm=perm)``) and
``permute_expert_params`` to the stacked weights.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .block_sizes import target_block_sizes
from .topology import Topology


@dataclasses.dataclass(frozen=True)
class PlacementResult:
    perm: np.ndarray            # (E,) old expert id -> new slot id
    rank_of: np.ndarray         # (E,) old expert id -> EP rank
    load_per_rank: np.ndarray   # (ep,) sum of expert loads per rank
    max_load_ratio: float       # Eq. 2 objective: max load_j / speed_j
    coact_cut: float            # Eq. 1 analogue: cross-rank co-activation


def expert_loads(routing_counts: np.ndarray) -> np.ndarray:
    """Normalize router top-k hit counts (E,) to a load distribution."""
    c = np.asarray(routing_counts, dtype=np.float64)
    s = c.sum()
    return c / s if s > 0 else np.full(c.shape, 1.0 / len(c))


def coactivation_graph(topk_ids: np.ndarray, n_experts: int) -> np.ndarray:
    """Dense (E, E) co-routing weights from observed top-k id rows.

    topk_ids: (T, K) int — the router's chosen experts per token."""
    W = np.zeros((n_experts, n_experts), dtype=np.float64)
    for row in np.asarray(topk_ids).reshape(-1, topk_ids.shape[-1]):
        for a in row:
            for b in row:
                if a != b:
                    W[a, b] += 1.0
    return W


def place_experts(loads: np.ndarray, topo: Topology,
                  coact: np.ndarray | None = None,
                  swap_rounds: int = 4) -> PlacementResult:
    """Two-stage LDHT placement of E experts onto topo.k EP ranks.

    Stage 1 (Algorithm 1): per-rank *load budgets* from the PU speeds (the
    slot memory constraint is handled structurally by E_loc).
    Stage 2: LPT greedy into the budget with exactly E_loc slots per rank,
    then pairwise swap refinement on (Eq. 2, then Eq. 1).
    """
    loads = np.asarray(loads, dtype=np.float64)
    E, ep = len(loads), topo.k
    if E % ep != 0:
        raise ValueError(f"E={E} not divisible by ep_size={ep}")
    E_loc = E // ep
    if coact is None:
        coact = np.zeros((E, E))

    # Stage 1: Algorithm-1 budgets on total load 1.0.  Memory caps in load
    # units are effectively infinite (the slot constraint is separate), so
    # budgets are speed-proportional — but we keep the general call so
    # heterogeneous m_cap topologies still bound the budget.
    budgets = target_block_sizes(float(loads.sum()), topo)

    # Stage 2a: LPT greedy — heaviest expert first, to the rank with the
    # most remaining budget that still has a free slot.
    order = np.argsort(-loads)
    rank_of = np.empty(E, dtype=np.int64)
    used = np.zeros(ep, dtype=np.int64)
    acc = np.zeros(ep, dtype=np.float64)
    for e in order:
        headroom = (budgets - acc) / topo.speeds
        headroom[used >= E_loc] = -np.inf
        j = int(np.argmax(headroom))
        rank_of[e] = j
        used[j] += 1
        acc[j] += loads[e]

    speeds = topo.speeds

    def ratio(a):
        return (a / speeds).max()

    def cut(r):
        same = r[:, None] == r[None, :]
        return float(coact[~same].sum())

    # Stage 2b: pairwise swap refinement (FM analogue, swap moves keep the
    # exact-count constraint satisfied).  Restart the scan after every
    # accepted swap — membership lists go stale once ranks change.
    for _ in range(swap_rounds * E):
        improved = False
        jmax = int(np.argmax(acc / speeds))
        for e1 in np.where(rank_of == jmax)[0]:
            for e2 in np.where(rank_of != jmax)[0]:
                j2 = rank_of[e2]
                delta = loads[e1] - loads[e2]
                new_acc = acc.copy()
                new_acc[jmax] -= delta
                new_acc[j2] += delta
                if ratio(new_acc) < ratio(acc) - 1e-15:
                    rank_of[e1], rank_of[e2] = j2, jmax
                    acc = new_acc
                    improved = True
                    break
            if improved:
                break
        if not improved:
            break

    # co-activation polish: same-load-impact swaps that reduce the cut
    for _ in range(swap_rounds):
        improved = False
        base = cut(rank_of)
        for e1 in range(E):
            for e2 in range(e1 + 1, E):
                j1, j2 = rank_of[e1], rank_of[e2]
                if j1 == j2:
                    continue
                delta = loads[e1] - loads[e2]
                new_acc = acc.copy()
                new_acc[j1] -= delta
                new_acc[j2] += delta
                if ratio(new_acc) > ratio(acc) + 1e-12:
                    continue
                trial = rank_of.copy()
                trial[e1], trial[e2] = j2, j1
                c = cut(trial)
                if c < base - 1e-12:
                    rank_of, acc, base = trial, new_acc, c
                    improved = True
        if not improved:
            break

    # slots: experts of rank j occupy [j*E_loc, (j+1)*E_loc)
    perm = np.empty(E, dtype=np.int64)
    nxt = np.array([j * E_loc for j in range(ep)])
    for e in range(E):
        j = rank_of[e]
        perm[e] = nxt[j]
        nxt[j] += 1
    return PlacementResult(perm=perm, rank_of=rank_of, load_per_rank=acc,
                           max_load_ratio=ratio(acc),
                           coact_cut=cut(rank_of))


def permute_expert_params(ffn_params: dict, perm: np.ndarray) -> dict:
    """Reorder stacked expert weights so slot perm[e] holds expert e's
    weights, and embed the routing permutation in the param tree ("perm")
    — moe_forward picks it up automatically on every path (train /
    prefill / decode), keeping semantics exactly equal to the unplaced
    model."""
    import jax.numpy as jnp

    inv = np.argsort(perm)
    out = dict(ffn_params)
    for k in ("w1", "w2", "w3"):
        if k in out:
            out[k] = out[k][inv]
    out["perm"] = jnp.asarray(perm, dtype=jnp.int32)
    return out
