"""Algorithm 1 of the paper: optimal target block sizes for LDHT.

Given n (total unit-weight load), and k PUs with speeds c_s and memory caps
m_cap, compute target weights tw(b_i) that

    minimize  max_i tw(b_i) / c_s(p_i)           (Eq. 2)
    s.t.      tw(b_i) <= m_cap(p_i)              (Eq. 3)
              sum_i tw(b_i) = n

Greedy water-filling: sort PUs by decreasing c_s/m_cap; assign each its
proportional share of the *remaining* load, clamped to its memory.  Theorem 1
proves optimality for (2)+(3); Lemma 1 proves the saturated PUs form a prefix
of the sorted order.  Runs in O(k log k).

Two implementations:
  * ``target_block_sizes`` — NumPy, exact, O(k log k), the reference.
  * ``target_block_sizes_jax`` — jit-able JAX version (scan-free closed form
    via the saturated-prefix structure) for use inside traced programs, e.g.
    elastic re-balancing inside a compiled training loop.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .topology import Topology


def waterfill(load: float, weights: np.ndarray, caps: np.ndarray,
              strict: bool = True) -> np.ndarray:
    """The Algorithm-1 water-fill core: split ``load`` proportionally to
    ``weights`` under per-unit ``caps``, greedily in decreasing
    weight/cap order (Lemma 1: the saturated units form a prefix).

    This is :func:`target_block_sizes` with arbitrary non-negative
    weights — the recursive tree pipeline calls it at every tree level
    (subtree aggregates first, then leaves within each subtree), so a
    saturated member's overflow is absorbed by its *siblings* instead of
    forcing a post-hoc rescale of the global targets.

    ``strict=False`` relaxes the feasibility check: an overfull load
    (``load > sum(caps)``) falls back to cap-ignoring proportional
    shares — the recursion's escape hatch when an upstream partitioner
    overfilled a subtree beyond its memory (the solution is already
    infeasible; the caller's own caps decide what to keep).
    """
    weights = np.asarray(weights, dtype=np.float64)
    caps = np.asarray(caps, dtype=np.float64)
    k = len(weights)
    if load > caps.sum() + 1e-12:
        if strict:
            raise ValueError(
                f"infeasible: load {load} exceeds total memory "
                f"{caps.sum()}")
        w = weights if weights.sum() > 0 else caps
        return w * (float(load) / w.sum())
    if weights.sum() <= 0:
        weights = caps                       # no preference: fill by cap
    order = np.argsort(-(weights / caps), kind="stable")  # Line 1
    tw = np.zeros(k, dtype=np.float64)
    j_load = float(load)                                 # Line 2
    j_speed = float(weights.sum())                       # Line 3
    for idx in order:                                    # Line 4
        des_w = weights[idx] * j_load / j_speed          # Line 5
        if des_w > caps[idx]:                            # Line 6
            tw[idx] = caps[idx]                          # Line 7  (saturated)
        else:
            tw[idx] = des_w                              # Line 10 (non-sat.)
        j_load -= tw[idx]                                # Line 11
        j_speed -= weights[idx]                          # Line 12
    return tw


def target_block_sizes(n: float, topo: Topology,
                       integral: bool = False) -> np.ndarray:
    """Algorithm 1 — returns tw in the ORIGINAL PU order.

    Args:
      n: total load (|V| of the application graph).
      topo: the compute topology (leaves only are used).
      integral: if True, round to integers that still sum to n (largest
        remainder method, respecting memory caps).
    """
    if not topo.feasible(n):
        raise ValueError(
            f"infeasible: load {n} exceeds total memory {topo.total_memory}")
    tw = waterfill(n, topo.speeds, topo.memories)
    if integral:
        tw = _round_preserving_sum(tw, int(round(n)), topo.memories)
    return tw


def tree_target_block_sizes(n: float, topo: Topology, tree=None,
                            fanouts=None) -> np.ndarray:
    """Tree-aware Algorithm 1 (ROADMAP: "pods in Algorithm 1") — returns
    leaf tw in the ORIGINAL PU order.

    Water-fills top-down: the root's load is split among the depth-1
    subtrees by *aggregate* speed under *aggregate* memory, then each
    subtree splits its share among its children, down to the leaves.  A
    saturated member inside an unsaturated subtree is absorbed by its
    siblings at the innermost level — the per-subtree shares never need
    the stage-B rescale of the flat pipeline.  Coincides with the flat
    :func:`target_block_sizes` whenever no PU saturates (proportional
    shares compose), and with it per subtree when one does.

    ``tree`` is anything ``topology.normalize_tree_of`` accepts (pod
    count, pod array, (h-1, k) ancestor table); default is the canonical
    table of ``fanouts`` (default ``topo.fanouts``).
    """
    from .topology import normalize_tree_of
    if not topo.feasible(n):
        raise ValueError(
            f"infeasible: load {n} exceeds total memory {topo.total_memory}")
    anc = normalize_tree_of(tree, topo.k,
                            fanouts if (fanouts is not None or
                                        tree is not None) else topo.fanouts)
    speeds, mems = topo.speeds, topo.memories
    tw = np.zeros(topo.k, dtype=np.float64)

    def rec(pus: np.ndarray, anc_sub: np.ndarray, load: float) -> None:
        if anc_sub.shape[0] == 0:
            tw[pus] = waterfill(load, speeds[pus], mems[pus])
            return
        top = anc_sub[0]
        gids = np.unique(top)
        wg = np.array([speeds[pus[top == g]].sum() for g in gids])
        cg = np.array([mems[pus[top == g]].sum() for g in gids])
        shares = waterfill(load, wg, cg)
        for share, gid in zip(shares, gids):
            sel = top == gid
            rec(pus[sel], anc_sub[1:, sel], float(share))

    rec(np.arange(topo.k), anc, float(n))
    return tw


def _round_preserving_sum(tw: np.ndarray, total: int,
                          mems: np.ndarray) -> np.ndarray:
    """Largest-remainder rounding, keeping sum == total and tw <= m_cap."""
    base = np.floor(tw).astype(np.int64)
    rem = tw - base
    deficit = total - int(base.sum())
    # hand out +1 by largest remainder where memory allows
    order = np.argsort(-rem, kind="stable")
    out = base.astype(np.float64)
    i = 0
    while deficit > 0 and i < 4 * len(tw):
        idx = order[i % len(tw)]
        if out[idx] + 1 <= mems[idx] + 1e-9:
            out[idx] += 1
            deficit -= 1
        i += 1
    if deficit != 0:
        raise ValueError("could not round block sizes within memory caps")
    return out


def saturated_mask(n: float, topo: Topology) -> np.ndarray:
    """Which PUs end up saturated (tw == m_cap) — Lemma 1 diagnostics."""
    tw = target_block_sizes(n, topo)
    return np.isclose(tw, topo.memories) & (tw < n * topo.speeds /
                                            topo.total_speed + 1e-9)


def max_load_ratio(tw: np.ndarray, topo: Topology) -> float:
    """Objective (2): max_i tw(b_i)/c_s(p_i)."""
    return float(np.max(np.asarray(tw) / topo.speeds))


# ---------------------------------------------------------------------------
# JAX version.  Structure: after sorting by c_s/m_cap desc, saturated PUs form
# a prefix (Lemma 1).  For a candidate prefix length s, the assignment is
#   tw_i = m_cap_i                   for i < s
#   tw_i = c_s_i * L_s / S_s         for i >= s
# where L_s = n - sum_{i<s} m_cap_i and S_s = sum_{i>=s} c_s_i.  The correct s
# is the smallest one for which no i >= s violates memory, i.e.
#   max_{i>=s} (c_s_i/m_cap_i) * L_s / S_s <= 1.
# We evaluate all k+1 prefixes vectorized and pick the smallest feasible one —
# O(k) after the sort, fully jit-able, no data-dependent control flow.
# ---------------------------------------------------------------------------

def target_block_sizes_jax(n: jnp.ndarray, speeds: jnp.ndarray,
                           mems: jnp.ndarray) -> jnp.ndarray:
    """jit-able Algorithm 1.  Returns tw in the original PU order.

    Args:
      n: scalar total load.
      speeds, mems: shape (k,) arrays.
    """
    k = speeds.shape[0]
    ratio = speeds / mems
    order = jnp.argsort(-ratio, stable=True)
    s_sorted = speeds[order]
    m_sorted = mems[order]
    r_sorted = ratio[order]

    # prefix sums: cum_mem[s] = sum_{i<s} m_i, suf_speed[s] = sum_{i>=s} c_i
    cum_mem = jnp.concatenate([jnp.zeros(1, m_sorted.dtype),
                               jnp.cumsum(m_sorted)])          # (k+1,)
    total_speed = jnp.sum(s_sorted)
    suf_speed = total_speed - jnp.concatenate(
        [jnp.zeros(1, s_sorted.dtype), jnp.cumsum(s_sorted)])   # (k+1,)

    load_s = n - cum_mem                                        # (k+1,)
    # max ratio among the suffix i >= s; sorted desc => it's r_sorted[s]
    r_suffix_max = jnp.concatenate([r_sorted, jnp.zeros(1, r_sorted.dtype)])
    safe_speed = jnp.where(suf_speed > 0, suf_speed, 1.0)
    feasible = r_suffix_max * load_s / safe_speed <= 1.0 + 1e-12
    feasible = feasible | (suf_speed <= 0)  # s == k: everyone saturated
    s_star = jnp.argmax(feasible)           # smallest feasible prefix length

    idx = jnp.arange(k)
    load = load_s[s_star]
    sspd = jnp.where(suf_speed[s_star] > 0, suf_speed[s_star], 1.0)
    tw_sorted = jnp.where(idx < s_star, m_sorted, s_sorted * load / sspd)

    tw = jnp.zeros_like(tw_sorted).at[order].set(tw_sorted)
    return tw


def hetero_batch_split(global_batch: int, topo: Topology) -> np.ndarray:
    """Per-PU batch share for heterogeneous data parallelism (beyond-paper).

    Uses Algorithm 1 with load = global_batch, memory in units of
    'max microbatch that fits on the PU'.  Returns integral shares summing to
    global_batch.
    """
    return target_block_sizes(float(global_batch), topo,
                              integral=True).astype(np.int64)
