"""Geometric utilities shared by the partitioners — JAX-first.

  * morton_codes      — 2D/3D Morton (Z-order) codes for SFC partitioning and
    k-means seeding.  (Geographer uses Hilbert curves; Morton preserves
    locality nearly as well and has a branch-free TPU-friendly bit-interleave.
    The difference is absorbed by the k-means/refinement phases; noted in
    DESIGN.md.)
  * weighted_split_points — cut a sorted weight sequence at arbitrary target
    fractions (heterogeneous splits for SFC/RCB/RIB).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_MORTON_BITS = 10  # per dim; 2*10=20 / 3*10=30 bit codes fit in uint32


def _part1by1(x: jnp.ndarray) -> jnp.ndarray:
    """Spread 10 bits of x so there is a 0 between each (2D interleave)."""
    x = x.astype(jnp.uint32) & 0x3FF
    x = (x | (x << 8)) & 0x00FF00FF
    x = (x | (x << 4)) & 0x0F0F0F0F
    x = (x | (x << 2)) & 0x33333333
    x = (x | (x << 1)) & 0x55555555
    return x


def _part1by2(x: jnp.ndarray) -> jnp.ndarray:
    """Spread 10 bits of x with 2 zeros between each (3D interleave)."""
    x = x.astype(jnp.uint32) & 0x3FF
    x = (x | (x << 16)) & 0x030000FF
    x = (x | (x << 8)) & 0x0300F00F
    x = (x | (x << 4)) & 0x030C30C3
    x = (x | (x << 2)) & 0x09249249
    return x


@jax.jit
def morton_codes(coords: jnp.ndarray) -> jnp.ndarray:
    """Z-order codes for (n, 2) or (n, 3) points (any float dtype)."""
    lo = jnp.min(coords, axis=0)
    hi = jnp.max(coords, axis=0)
    span = jnp.where(hi > lo, hi - lo, 1.0)
    q = ((coords - lo) / span * (2 ** _MORTON_BITS - 1)).astype(jnp.uint32)
    q = jnp.clip(q, 0, 2 ** _MORTON_BITS - 1)
    if coords.shape[1] == 2:
        return _part1by1(q[:, 0]) | (_part1by1(q[:, 1]) << 1)
    elif coords.shape[1] == 3:
        return (_part1by2(q[:, 0]) | (_part1by2(q[:, 1]) << 1)
                | (_part1by2(q[:, 2]) << 2))
    raise ValueError(f"dim must be 2 or 3, got {coords.shape[1]}")


def weighted_split_assignment(order: np.ndarray,
                              tw: np.ndarray) -> np.ndarray:
    """Assign vertices, visited in `order`, to blocks with target sizes tw.

    Returns part (n,) int32: the first ~tw[0] vertices of the order go to
    block 0, next ~tw[1] to block 1, ... (fractional boundaries rounded so
    each prefix matches cumsum(tw)).
    """
    n = len(order)
    bounds = np.round(np.cumsum(tw)).astype(np.int64)
    bounds[-1] = n
    part = np.zeros(n, dtype=np.int32)
    ranks = np.empty(n, dtype=np.int64)
    ranks[order] = np.arange(n)
    part = np.searchsorted(bounds, ranks, side="right").astype(np.int32)
    return np.minimum(part, len(tw) - 1)


def principal_axis(coords: np.ndarray, iters: int = 50) -> np.ndarray:
    """Principal inertial axis via power iteration on the covariance."""
    c = coords - coords.mean(axis=0, keepdims=True)
    cov = c.T @ c
    v = np.ones(cov.shape[0]) / np.sqrt(cov.shape[0])
    for _ in range(iters):
        v = cov @ v
        nv = np.linalg.norm(v)
        if nv == 0:
            return np.eye(cov.shape[0])[0]
        v /= nv
    return v
