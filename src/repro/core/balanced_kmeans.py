"""Balanced k-means geometric partitioner (geoKM) — von Looz et al. ICPP'18,
used by the paper as Geographer's phase-1, extended here with heterogeneous
target block weights (Algorithm 1 output) and a hierarchical mode (Sec. V).

Method.  Minimize sum of squared point-center distances subject to per-block
target sizes tw_i.  We use the *influence* formulation: each center carries a
multiplicative price gamma_i; points choose argmin_i gamma_i * dist(x, c_i)^2.
Loads above target raise the price, loads below lower it — a tatonnement that
converges to blocks of the requested sizes with compact shapes.

Implementation is JAX-native and jit-compiled: the hot loop is an (n, k)
distance computation (a matmul on the MXU — see kernels/pdist.py for the
Pallas version), a segment-sum for loads/centroids, and a price update.
Fixed trip count via lax.fori_loop keeps it a single XLA program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..sparse.graph import Graph
from .geometry import morton_codes, weighted_split_assignment
from ..kernels import ops as kops


def _init_centers(coords: np.ndarray, tw: np.ndarray) -> np.ndarray:
    """SFC seeding: slice the Morton order at cumulative target weights and
    take each chunk's centroid (Geographer's initialization)."""
    codes = np.asarray(morton_codes(jnp.asarray(coords)))
    order = np.argsort(codes, kind="stable")
    part = weighted_split_assignment(order, tw)
    k = len(tw)
    sums = np.zeros((k, coords.shape[1]), dtype=np.float64)
    np.add.at(sums, part, coords)
    counts = np.maximum(np.bincount(part, minlength=k), 1)
    return (sums / counts[:, None]).astype(np.float32)


@functools.partial(jax.jit, static_argnames=("iters", "price_steps",
                                             "use_pallas"))
def _bkm_loop(coords, centers, tw, iters: int, price_steps: int,
              price_lr: float = 0.18, use_pallas: bool = False):
    """The jit'd optimization loop.

    Per outer iteration: `price_steps` rounds of price adjustment under fixed
    centers (cheap: reuse the distance matrix), then one centroid update.
    Returns (part, centers, prices).
    """
    n = coords.shape[0]
    k = centers.shape[0]
    tw_frac = tw / jnp.sum(tw)

    def assign(dist2, log_price):
        eff = dist2 + log_price[None, :]      # log-domain multiplicative price
        return jnp.argmin(eff, axis=1)

    def outer(it, state):
        centers, log_price = state
        if use_pallas:
            dist2 = kops.pairwise_sqdist(coords, centers)
        else:
            dist2 = (jnp.sum(coords * coords, axis=1, keepdims=True)
                     - 2.0 * coords @ centers.T
                     + jnp.sum(centers * centers, axis=1)[None, :])
        # normalize so prices act on comparable scales
        dist2 = dist2 / (jnp.mean(dist2) + 1e-12)

        def price_round(_, lp):
            part = assign(dist2, lp)
            load = jnp.zeros(k).at[part].add(1.0)
            load_frac = load / n
            # raise price where overloaded, lower where underloaded
            lp = lp + price_lr * jnp.log((load_frac + 1e-6)
                                         / (tw_frac + 1e-6))
            return lp - jnp.mean(lp)

        log_price = jax.lax.fori_loop(0, price_steps, price_round, log_price)
        part = assign(dist2, log_price)
        one_hot_sums = jnp.zeros((k, coords.shape[1])).at[part].add(coords)
        counts = jnp.zeros(k).at[part].add(1.0)
        new_centers = one_hot_sums / jnp.maximum(counts, 1.0)[:, None]
        # keep empty centers where they were
        new_centers = jnp.where(counts[:, None] > 0, new_centers, centers)
        return new_centers, log_price

    centers, log_price = jax.lax.fori_loop(
        0, iters, outer, (centers, jnp.zeros(k, coords.dtype)))
    if use_pallas:
        dist2 = kops.pairwise_sqdist(coords, centers)
    else:
        dist2 = (jnp.sum(coords * coords, axis=1, keepdims=True)
                 - 2.0 * coords @ centers.T
                 + jnp.sum(centers * centers, axis=1)[None, :])
    dist2 = dist2 / (jnp.mean(dist2) + 1e-12)
    part = assign(dist2, log_price)
    return part, centers, log_price


def _exact_rebalance(coords: np.ndarray, centers: np.ndarray,
                     part: np.ndarray, tw: np.ndarray) -> np.ndarray:
    """Post-pass: enforce sizes exactly (floor(tw) sum-preserving) by moving
    the cheapest vertices out of overloaded blocks to the nearest underloaded
    block.  Keeps compactness: candidates are those with the smallest
    (d_target^2 - d_own^2) regret."""
    k = len(tw)
    want = np.round(tw).astype(np.int64)
    want[np.argmax(want)] += len(part) - want.sum()  # fix rounding drift
    d2 = ((coords[:, None, :] - centers[None, :, :]) ** 2).sum(-1) \
        if len(coords) * k <= 5_000_000 else None
    for _ in range(4 * k):
        sizes = np.bincount(part, minlength=k)
        over = np.nonzero(sizes > want)[0]
        under = np.nonzero(sizes < want)[0]
        if len(over) == 0:
            break
        b = over[np.argmax(sizes[over] - want[over])]
        members = np.nonzero(part == b)[0]
        if d2 is not None:
            regret = d2[members][:, under] - d2[members][:, b][:, None]
        else:
            dm = coords[members]
            d_own = ((dm - centers[b]) ** 2).sum(-1)
            d_tgt = ((dm[:, None, :] - centers[under][None]) ** 2).sum(-1)
            regret = d_tgt - d_own[:, None]
        flat = np.argsort(regret, axis=None, kind="stable")
        n_move = int(sizes[b] - want[b])
        moved = 0
        deficit = (want - sizes).clip(min=0)
        for f in flat:
            if moved >= n_move:
                break
            vi, uj = np.unravel_index(f, regret.shape)
            tgt = under[uj]
            if deficit[tgt] > 0 and part[members[vi]] == b:
                part[members[vi]] = tgt
                deficit[tgt] -= 1
                moved += 1
    return part


def partition_balanced_kmeans(g: Graph, tw: np.ndarray, seed: int = 0,
                              iters: int = 30, price_steps: int = 12,
                              exact: bool = True,
                              use_pallas: bool = False) -> np.ndarray:
    """geoKM: balanced k-means with heterogeneous target weights."""
    assert g.coords is not None, "balanced k-means needs coordinates"
    tw = np.asarray(tw, dtype=np.float64)
    coords = np.asarray(g.coords, dtype=np.float32)
    centers0 = _init_centers(coords, tw)
    part, centers, _ = _bkm_loop(jnp.asarray(coords), jnp.asarray(centers0),
                                 jnp.asarray(tw, dtype=jnp.float32),
                                 iters=iters, price_steps=price_steps,
                                 use_pallas=use_pallas)
    part = np.asarray(part, dtype=np.int32).copy()
    if exact:
        part = _exact_rebalance(coords, np.asarray(centers), part, tw)
    return part


def partition_hierarchical_kmeans(g: Graph, tw: np.ndarray,
                                  fanouts: tuple[int, ...], seed: int = 0,
                                  **kw) -> np.ndarray:
    """Hierarchical balanced k-means (Sec. V): partition level-by-level along
    the topology tree so border-sharing blocks land on nearby PUs.

    At level i, each current block is split into fanouts[i+1] children whose
    target weights are the sums of the leaf tw's under each child.
    """
    assert g.coords is not None
    tw = np.asarray(tw, dtype=np.float64)
    k = len(tw)
    assert int(np.prod(fanouts)) == k
    part = np.zeros(g.n, dtype=np.int64)   # block id at current level
    leaf_lo = {0: 0}
    leaf_hi = {0: k}
    for level, fan in enumerate(fanouts):
        new_part = np.zeros_like(part)
        new_lo, new_hi = {}, {}
        for blk in np.unique(part):
            lo, hi = leaf_lo[blk], leaf_hi[blk]
            per_child = (hi - lo) // fan
            child_tw = np.array([tw[lo + c * per_child:
                                    lo + (c + 1) * per_child].sum()
                                 for c in range(fan)])
            mask = part == blk
            ids = np.nonzero(mask)[0]
            sub = Graph(indptr=np.array([0, 0]), indices=np.zeros(0, np.int32),
                        weights=np.zeros(0, np.float32),
                        coords=g.coords[ids])
            sub.indptr = np.zeros(len(ids) + 1, dtype=np.int64)  # coords-only
            # scale child tw to the actual number of points in this block
            scale = len(ids) / max(child_tw.sum(), 1e-9)
            sub_part = partition_balanced_kmeans(sub, child_tw * scale,
                                                 seed=seed, **kw)
            for c in range(fan):
                cid = blk * fan + c
                new_part[ids[sub_part == c]] = cid
                new_lo[cid] = lo + c * per_child
                new_hi[cid] = lo + (c + 1) * per_child
        part, leaf_lo, leaf_hi = new_part, new_lo, new_hi
    # final: blocks are already leaf-indexed (level order == leaf order)
    out = np.zeros(g.n, dtype=np.int32)
    for blk in np.unique(part):
        out[part == blk] = leaf_lo[blk]
    return out
