"""Recursive Inertial Bisection with heterogeneous target weights
(zRIB analogue, Sec. III-a).

Like RCB but splits along the principal inertial axis of the current point
set (not restricted to coordinate axes).
"""
from __future__ import annotations

import numpy as np

from ..sparse.graph import Graph
from .geometry import principal_axis
from .rcb import _split_blocks


def partition_rib(g: Graph, tw: np.ndarray, seed: int = 0) -> np.ndarray:
    assert g.coords is not None, "RIB needs coordinates"
    tw = np.asarray(tw, dtype=np.float64)
    part = np.zeros(g.n, dtype=np.int32)
    _rib(g.coords.astype(np.float64), np.arange(g.n),
         np.arange(len(tw)), tw, part)
    return part


def _rib(coords: np.ndarray, ids: np.ndarray, block_ids: np.ndarray,
         tw: np.ndarray, part: np.ndarray) -> None:
    if len(block_ids) == 1:
        part[ids] = block_ids[0]
        return
    left_b, right_b, frac = _split_blocks(block_ids, tw)
    pts = coords[ids]
    axis = principal_axis(pts)
    proj = pts @ axis
    order = np.argsort(proj, kind="stable")
    n_left = int(round(frac * len(ids)))
    n_left = min(max(n_left, 0), len(ids))
    _rib(coords, ids[order[:n_left]], left_b, tw, part)
    _rib(coords, ids[order[n_left:]], right_b, tw, part)
