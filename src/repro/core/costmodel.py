"""Pluggable partition cost models (the objective layer).

Everything the pipeline optimized through PR 8 was ONE objective — the
summed lambda-weighted tree cut (``metrics.tree_objective``) — hard-coded
into the metrics, the FM gains, and the partition API.  A
:class:`CostModel` makes the objective a value: it prices a partition of
a graph over a k-PU tree machine as per-PU modeled compute (Algorithm-1
speeds x block weight) plus per-level weighted *deduplicated* receive
volume, and the two concrete instances are

  * :class:`CutCost` — the existing summed lambda-cut.  ``price`` is a
    direct delegate to ``metrics.tree_objective`` so results stay
    bit-identical to the pre-costmodel pipeline (locked by
    ``tests/test_costmodel.py`` golden values);
  * :class:`BottleneckCost` — the process-mapping bottleneck (makespan)
    objective of Langguth/Schlag/Schulz: the *max* over PUs of modeled
    compute + weighted receive volume, which is what actually bounds a
    distributed CG iteration (and what the padded tree runtime pays:
    max block size sets B, max per-level receive volume sets S_lvl).

``cost_model_for`` resolves the ``objective="cut"|"bottleneck"`` strings
the partition API threads through (``api.partition(..., objective=)``)
into model instances, pulling speeds from the topology; a measured
machine model later only has to construct a model with calibrated
``lams``/``speeds``/``c_comp`` — no more plumbing passes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..sparse.graph import Graph
from . import metrics
from .topology import Topology


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Prices a partition over a tree machine.

    ``lams``    — (h,) per-tree-level comm weights (``None``: the shared
                  default ladder, ``metrics.resolve_lams``);
    ``speeds``  — (k,) Algorithm-1 PU speeds (``None``: homogeneous);
    ``c_comp``  — modeled compute cost of one weight unit on a unit-speed
                  PU, in units of one innermost-level halo word
                  (``lams[0]``); the compute/comm exchange rate a
                  measured machine model calibrates.

    ``price(g, part, anc)`` is the scalar objective refinement minimizes;
    ``per_pu(g, part, anc)`` the per-PU compute/comm breakdown
    (``metrics.per_pu_model_costs``) every model exposes uniformly.
    """

    lams: tuple | None = None
    speeds: tuple | None = None
    c_comp: float = 1.0

    kind = "?"      # class attribute, overridden per concrete model

    def resolve(self, h: int) -> tuple:
        """(h,) per-level weights for a depth-h ancestor table."""
        return tuple(metrics.resolve_lams(self.lams, h))

    def price(self, g: Graph, part: np.ndarray, anc: np.ndarray) -> float:
        raise NotImplementedError

    def per_pu(self, g: Graph, part: np.ndarray,
               anc: np.ndarray) -> dict:
        """Per-PU modeled compute/comm split (shared across models — the
        cut model reports the same breakdown it just doesn't bound by)."""
        return metrics.per_pu_model_costs(g, part, anc, lams=self.lams,
                                          speeds=self.speeds,
                                          c_comp=self.c_comp)

    def summary(self, g: Graph, part: np.ndarray,
                anc: np.ndarray) -> dict:
        """JSON-friendly price + breakdown (what benchmarks and
        ``SolverService.static_cost`` report)."""
        anc = np.atleast_2d(np.asarray(anc))
        pp = self.per_pu(g, part, anc)
        total = pp["total"]
        return {
            "objective": self.kind,
            "price": self.price(g, part, anc),
            "makespan": float(total.max(initial=0.0)),
            "critical_pu": int(total.argmax()) if len(total) else 0,
            "per_pu_compute": pp["compute"].tolist(),
            "per_pu_comm": pp["comm"].tolist(),
            "max_comm_volume_by_level": [int(v.max(initial=0))
                                         for v in pp["comm_by_level"]],
            "lams": list(self.resolve(anc.shape[0] + 1)),
            "c_comp": float(self.c_comp),
        }


@dataclasses.dataclass(frozen=True)
class CutCost(CostModel):
    """The summed lambda-weighted tree cut — the pre-costmodel objective,
    bit-identical to ``metrics.tree_objective`` (``speeds``/``c_comp``
    only affect the informational ``per_pu`` breakdown, never the
    price)."""

    kind = "cut"

    def price(self, g: Graph, part: np.ndarray, anc: np.ndarray) -> float:
        anc = np.atleast_2d(np.asarray(anc))
        if anc.shape[0] == 0:               # flat machine: plain edge cut
            return metrics.edge_cut(g, part) * float(self.resolve(1)[0])
        return metrics.tree_objective(g, part, anc,
                                      self.resolve(anc.shape[0] + 1))


@dataclasses.dataclass(frozen=True)
class BottleneckCost(CostModel):
    """max over PUs of modeled compute + per-level weighted deduplicated
    receive volume (``metrics.bottleneck_objective``)."""

    kind = "bottleneck"

    def price(self, g: Graph, part: np.ndarray, anc: np.ndarray) -> float:
        return metrics.bottleneck_objective(g, part, anc, lams=self.lams,
                                            speeds=self.speeds,
                                            c_comp=self.c_comp)


COST_MODELS: dict[str, type[CostModel]] = {
    "cut": CutCost,
    "bottleneck": BottleneckCost,
}


def cost_model_for(objective: str | CostModel = "cut",
                   topo: Topology | None = None, lams=None,
                   c_comp: float = 1.0) -> CostModel:
    """Resolve the API-level ``objective=`` argument into a model.

    A :class:`CostModel` instance passes through unchanged (calibrated
    models); a name constructs the registered class with speeds from
    ``topo`` and the given per-level weights."""
    if isinstance(objective, CostModel):
        return objective
    cls = COST_MODELS.get(objective)
    if cls is None:
        raise ValueError(f"unknown objective {objective!r}; choose from "
                         f"{sorted(COST_MODELS)} or pass a CostModel")
    return cls(lams=None if lams is None else
               tuple(float(x) for x in np.atleast_1d(lams)),
               speeds=None if topo is None else tuple(topo.speeds),
               c_comp=float(c_comp))
