"""Partition quality metrics (Sec. II-A / VI-a).

  * edge cut          — weight of edges with endpoints in different blocks
  * comm volume       — per block b: # of vertices outside b adjacent to b
                        (data words b must receive); max over blocks is the
                        paper's maxCommVolume
  * imbalance         — max_i tw_actual(b_i)/tw_target(b_i)
  * load ratio        — objective (2): max_i |b_i| / c_s(p_i)
"""
from __future__ import annotations

import numpy as np

from ..sparse.graph import Graph
from .topology import Topology


def edge_cut(g: Graph, part: np.ndarray) -> float:
    src, dst, w = g.edge_list()
    cut2 = np.sum(w * (part[src] != part[dst]))   # both directions counted
    return float(cut2) / 2.0


def comm_volumes(g: Graph, part: np.ndarray, k: int) -> np.ndarray:
    """Received-words per block: for block b, the number of distinct remote
    vertices adjacent to b (the halo size — exactly what distributed SpMV
    must fetch)."""
    src, dst, _ = g.edge_list()
    pb, pv = part[src], part[dst]
    ext = pb != pv
    # distinct (receiving block, remote vertex) pairs
    pairs = np.unique(pb[ext].astype(np.int64) * g.n + dst[ext].astype(np.int64))
    blocks = pairs // g.n
    return np.bincount(blocks, minlength=k)


def max_comm_volume(g: Graph, part: np.ndarray, k: int) -> int:
    return int(comm_volumes(g, part, k).max(initial=0))


def total_comm_volume(g: Graph, part: np.ndarray, k: int) -> int:
    return int(comm_volumes(g, part, k).sum())


def block_sizes_of(part: np.ndarray, k: int) -> np.ndarray:
    return np.bincount(part, minlength=k)


def imbalance(part: np.ndarray, tw: np.ndarray) -> float:
    """max_i actual/target — 1.0 is perfectly on-target."""
    sizes = block_sizes_of(part, len(tw))
    with np.errstate(divide="ignore"):
        r = sizes / np.maximum(tw, 1e-12)
    return float(r.max())


def load_ratio(part: np.ndarray, topo: Topology) -> float:
    """Objective (2) evaluated on the realized partition."""
    sizes = block_sizes_of(part, topo.k)
    return float(np.max(sizes / topo.speeds))


def memory_violations(part: np.ndarray, topo: Topology,
                      slack: float = 0.0) -> int:
    """# of blocks violating constraint (3), with optional relative slack."""
    sizes = block_sizes_of(part, topo.k)
    return int(np.sum(sizes > topo.memories * (1.0 + slack)))


def boundary_mask(g: Graph, part: np.ndarray) -> np.ndarray:
    """Vertices with >=1 neighbor in another block."""
    src, dst, _ = g.edge_list()
    ext = part[src] != part[dst]
    mask = np.zeros(g.n, dtype=bool)
    mask[src[ext]] = True
    return mask


def summarize(g: Graph, part: np.ndarray, topo: Topology,
              tw: np.ndarray) -> dict:
    return {
        "cut": edge_cut(g, part),
        "max_comm_volume": max_comm_volume(g, part, topo.k),
        "total_comm_volume": total_comm_volume(g, part, topo.k),
        "imbalance": imbalance(part, tw),
        "load_ratio": load_ratio(part, topo),
        "mem_violations": memory_violations(part, topo, slack=0.03),
    }
